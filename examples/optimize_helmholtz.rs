//! Walk the paper's §4.2 optimization ladder (Fig. 15) and explain what
//! each optimization changes, printing paper-vs-measured at each step.
//!
//! A thin client of `flow::Session`: the eight rungs share one parse +
//! lower through the session cache, and each rung is a `mapped` +
//! `simulate` call — no stage wiring in the example.
//!
//! ```bash
//! cargo run --release --example optimize_helmholtz
//! ```

use hbmflow::flow::Session;
use hbmflow::kernels::KernelSource;
use hbmflow::olympus::OlympusOpts;
use hbmflow::platform::Platform;
use hbmflow::report::{self, paper};

fn main() -> anyhow::Result<()> {
    let session = Session::new(Platform::alveo_u280());
    let src = KernelSource::builtin("helmholtz");
    let n = paper::N_ELEMENTS;

    let ladder: Vec<(&str, OlympusOpts)> = vec![
        (
            "serial transfers and compute; 64-bit AXI, one kernel",
            OlympusOpts::baseline(),
        ),
        (
            "ping/pong channels hide host transfers behind compute",
            OlympusOpts::double_buffering(),
        ),
        (
            "256-bit bus packed into ONE kernel: de-packing serializes \
             and the port-limited datapath raises II — a net LOSS",
            OlympusOpts::bus_serial(),
        ),
        (
            "256-bit bus split into four 64-bit lanes, four kernels",
            OlympusOpts::bus_parallel(),
        ),
        (
            "read/compute/write become dataflow stages over streams",
            OlympusOpts::dataflow(1),
        ),
        (
            "compute split in two modules (3+4 loop nests)",
            OlympusOpts::dataflow(2),
        ),
        (
            "gemm | mmult | gemm_inv (no gain: same bottleneck module, \
             lower frequency)",
            OlympusOpts::dataflow(3),
        ),
        (
            "one module per loop nest: compute now just below the read \
             module interval",
            OlympusOpts::dataflow(7),
        ),
    ];

    let mut rows = Vec::new();
    for (i, (why, opts)) in ladder.into_iter().enumerate() {
        let ev = session.mapped(&src, 11, &opts)?.simulate(n);
        let r = ev.sim().expect("simulate evaluation carries a sim result");
        let p = paper::TABLE2[i];
        println!("== {} ==", opts.label());
        println!("   {why}");
        println!(
            "   measured: CU {:.2} / system {:.2} GFLOPS @ {:.0} MHz  |  paper: {:.2} @ {:.0} MHz",
            r.gflops_cu, r.gflops_system, r.freq_mhz, p.gflops, p.f_mhz
        );
        rows.push(vec![
            opts.label(),
            format!("{}", ev.hls.ops()),
            report::f(r.gflops_system),
            report::f(p.gflops),
            format!("{:.2}", r.gflops_system / p.gflops),
        ]);
    }

    println!("\n--- summary (Fig. 15 / Table 2) ---");
    println!(
        "{}",
        report::table(&["implementation", "#Ops", "system", "paper", "ratio"], &rows)
    );
    println!(
        "paper shape checks: serial degrades ~3x; parallel recovers ~3.9x; \
         DF3 <= DF2; DF7 best."
    );
    let st = session.stats();
    println!(
        "(flow cache: {} parse+lower for {} rungs)",
        st.lowered_misses,
        st.mapped_misses
    );
    Ok(())
}
