//! END-TO-END driver: the full three-layer system on a real workload.
//!
//! ```bash
//! make artifacts && cargo run --release --example e2e_cfd
//! ```
//!
//! Proves all layers compose:
//!   L3 (this binary): CFDlang -> teil -> affine -> Olympus system;
//!       batch plan, ping/pong coordination, lane interleaving;
//!   L2/L1 (AOT): the batched Pallas Inverse Helmholtz, lowered to HLO
//!       text at build time, loaded and executed here via PJRT — Python
//!       never runs on this path;
//!   platform model: the same system simulated on the Alveo U280 for the
//!       paper's 2,000,000-element workload.
//!
//! Reports (recorded in EXPERIMENTS.md):
//!   * real numerics: MSE vs f64 oracle for double / fx64 / fx32
//!     (paper §4.2: 9.39e-22 and 3.58e-12);
//!   * measured XLA-CPU datapath throughput;
//!   * simulated FPGA GFLOPS / power / GFLOPS/W for the same system.

use hbmflow::coordinator::{Driver, HelmholtzWorkload};
use hbmflow::datatype::DataType;
use hbmflow::flow::Session;
use hbmflow::kernels::KernelSource;
use hbmflow::olympus::OlympusOpts;
use hbmflow::platform::Platform;
use hbmflow::report::{self, paper};
use hbmflow::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    let p = 11usize;
    let n_real = 2048usize; // elements executed with real numerics
    // one flow Session: the three data formats share a parse + lower
    let session = Session::new(Platform::alveo_u280());
    let src = KernelSource::builtin("helmholtz");
    let mut rt = Runtime::from_default_dir()?;
    println!(
        "PJRT platform: {}  |  artifacts: {}",
        rt.platform(),
        rt.manifest.artifacts.len()
    );

    let workload = HelmholtzWorkload::generate(p, n_real, 7_777);
    let mut rows = Vec::new();

    for dtype in [DataType::F64, DataType::Fx64, DataType::Fx32] {
        // --- generate the system for this data format (flow Mapped) ---
        let opts = if dtype.is_fixed() {
            OlympusOpts::fixed_point(dtype)
        } else {
            OlympusOpts::dataflow(7)
        };
        let mapped = session.mapped(&src, p, &opts)?;

        // --- real numerics through the AOT artifact ---
        let artifact = Driver::artifact_for(&rt, &mapped.spec, p)?;
        let mut driver = Driver::new(&mut rt, mapped.spec.clone(), artifact.clone());
        let run = driver.run(&workload, 64)?;

        // --- simulated FPGA execution of the same system, N_eq = 2M ---
        let ev = mapped.simulate(paper::N_ELEMENTS);
        let simr = ev.sim().expect("simulate evaluation carries a sim result");

        println!("\n=== {} ===", dtype.display());
        println!(
            "  real numerics : {} elements via {}  ({} invocations, {:.2}s wall, {:.2} GFLOPS XLA-CPU)",
            run.elements, artifact, run.invocations, run.wall_s, run.measured_gflops
        );
        println!(
            "  MSE vs oracle : {:.3e}   max|err| {:.3e}",
            run.mse_vs_oracle, run.max_abs_err
        );
        println!(
            "  simulated FPGA: CU {:.1} / system {:.1} GFLOPS @ {:.0} MHz, {:.1} W, {:.2} GFLOPS/W",
            simr.gflops_cu,
            simr.gflops_system,
            simr.freq_mhz,
            simr.avg_power_w,
            simr.efficiency_gflops_w
        );
        rows.push(vec![
            dtype.display().to_string(),
            format!("{:.2e}", run.mse_vs_oracle),
            report::f(run.measured_gflops),
            report::f(simr.gflops_system),
            format!("{:.2}", simr.efficiency_gflops_w),
        ]);
    }

    println!(
        "\n--- end-to-end summary (p = {p}, real n = {n_real}, simulated N_eq = {}) ---",
        paper::N_ELEMENTS
    );
    println!(
        "{}",
        report::table(
            &["dtype", "MSE vs f64", "XLA GFLOPS", "sim FPGA", "GF/W"],
            &rows
        )
    );
    println!(
        "paper anchors: MSE fx64 {:.2e}, fx32 {:.2e}; FPGA fx32 ~103 GOPS, ~4 GOPS/W",
        paper::MSE_FX64,
        paper::MSE_FX32
    );
    Ok(())
}
