//! Scratch resource-table dump across a few option sets (debug aid) —
//! a thin client of `flow::Session`.

use hbmflow::flow::Session;
use hbmflow::kernels::KernelSource;
use hbmflow::olympus::OlympusOpts;
use hbmflow::platform::Platform;

fn main() {
    let session = Session::new(Platform::alveo_u280());
    let src = KernelSource::builtin("helmholtz");
    for (name, opts) in [
        ("baseline", OlympusOpts::baseline()),
        ("df1", OlympusOpts::dataflow(1)),
        ("df7", OlympusOpts::dataflow(7)),
        ("df7x2", OlympusOpts::dataflow(7).with_cus(2)),
        ("fx64", OlympusOpts::fixed_point(hbmflow::datatype::DataType::Fx64)),
        ("fx32", OlympusOpts::fixed_point(hbmflow::datatype::DataType::Fx32)),
    ] {
        let ev = session.mapped(&src, 11, &opts).unwrap().estimate();
        let e = &ev.hls;
        let u = e.utilization(session.platform());
        println!("{name:9} lut {:7} ({:4.1}%)  ff {:7} ({:4.1}%)  bram {:5} ({:4.1}%)  uram {:4} ({:5.1}%)  dsp {:5} ({:4.1}%)  f={:.1} span={}",
            e.total.lut, u[0]*100.0, e.total.ff, u[1]*100.0, e.total.bram, u[2]*100.0,
            e.total.uram, u[3]*100.0, e.total.dsp, u[4]*100.0, e.fmax_mhz, e.slr_span);
    }
}
