use hbmflow::dsl;
use hbmflow::ir::{lower, rewrite, teil};
use hbmflow::olympus::{generate, OlympusOpts};
use hbmflow::platform::Platform;
use hbmflow::hls::estimate;

fn main() {
    let prog = dsl::parse(&dsl::inverse_helmholtz_source(11)).unwrap();
    let m = rewrite::optimize(teil::from_ast(&prog).unwrap());
    let k = lower::lower_kernel(&m, "helmholtz").unwrap();
    let platform = Platform::alveo_u280();
    for (name, opts) in [
        ("baseline", OlympusOpts::baseline()),
        ("df1", OlympusOpts::dataflow(1)),
        ("df7", OlympusOpts::dataflow(7)),
        ("df7x2", OlympusOpts::dataflow(7).with_cus(2)),
        ("fx64", OlympusOpts::fixed_point(hbmflow::datatype::DataType::Fx64)),
        ("fx32", OlympusOpts::fixed_point(hbmflow::datatype::DataType::Fx32)),
    ] {
        let s = generate(&k, &opts, &platform).unwrap();
        let e = estimate(&s, &platform);
        let u = e.utilization(&platform);
        println!("{name:9} lut {:7} ({:4.1}%)  ff {:7} ({:4.1}%)  bram {:5} ({:4.1}%)  uram {:4} ({:5.1}%)  dsp {:5} ({:4.1}%)  f={:.1} span={}",
            e.total.lut, u[0]*100.0, e.total.ff, u[1]*100.0, e.total.bram, u[2]*100.0,
            e.total.uram, u[3]*100.0, e.total.dsp, u[4]*100.0, e.fmax_mhz, e.slr_span);
    }
}
