//! Quickstart: the whole flow on one page, through the typed `flow`
//! pipeline (the crate's public API).
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```
//!
//! Parses the paper's Inverse Helmholtz DSL program (Fig. 2), walks the
//! staged pipeline (`Parsed` → `Lowered` → `Mapped` → `Evaluated`), and
//! simulates the paper's 2M-element workload on the Alveo U280 model.

use hbmflow::flow::Flow;
use hbmflow::kernels::KernelSource;
use hbmflow::olympus::{self, OlympusOpts};
use hbmflow::platform::Platform;

fn main() -> anyhow::Result<()> {
    // 1. The DSL program (paper Fig. 2, p = 11) enters the flow.
    let flow = Flow::from_source(KernelSource::builtin("helmholtz"));

    // 2. Parsed: AST + lossless rewrite (contraction factorization).
    let parsed = flow.parse(11)?;
    println!("--- CFDlang source ---\n{}", parsed.provenance.source);
    println!(
        "contraction factorization: {} -> {} flops/element (paper Eq. 2: 177,023)\n",
        parsed.rewrite.naive_flops, parsed.rewrite.optimized_flops
    );

    // 3. Lowered: the affine kernel plus access/liveness analyses.
    let lowered = parsed.lower()?;
    println!("{}\n", lowered.kernel);

    // 4. Mapped: Olympus system generation on the Alveo U280.
    let platform = Platform::alveo_u280();
    let mapped = lowered.map(&OlympusOpts::dataflow(7), &platform)?;
    println!(
        "dataflow groups: {:?}\n",
        mapped
            .spec
            .schedule
            .groups
            .iter()
            .map(|g| g.name.as_str())
            .collect::<Vec<_>>()
    );
    println!(
        "system: {} lanes x {} CU(s), {} HBM PCs, batch E = {} elements",
        mapped.spec.lanes,
        mapped.spec.num_cus,
        mapped.spec.total_pcs(),
        mapped.spec.batch_elements
    );
    println!("{}", olympus::config::system_cfg(&mapped.spec));

    // 5. Evaluated: HLS estimate + system simulation (N_eq = 2,000,000).
    let ev = mapped.simulate(2_000_000);
    let est = &ev.hls;
    let r = ev.sim().expect("simulate evaluation carries a sim result");
    println!(
        "estimate: {} ops, fmax {:.1} MHz, DSP {} LUT {}",
        est.ops(),
        est.fmax_mhz,
        est.total.dsp,
        est.total.lut
    );
    println!(
        "simulated: CU {:.1} GFLOPS, system {:.1} GFLOPS, {:.1} W, {:.2} GFLOPS/W",
        r.gflops_cu, r.gflops_system, r.avg_power_w, r.efficiency_gflops_w
    );
    println!("(paper Fig. 15 Dataflow-7: 43.4 GFLOPS)");
    Ok(())
}
