//! Quickstart: the whole flow on one page.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```
//!
//! Parses the paper's Inverse Helmholtz DSL program (Fig. 2), runs the
//! compiler pipeline (teil -> rewrite -> affine -> schedule), generates
//! the HBM system with Olympus, estimates it like Vitis HLS would, and
//! simulates the paper's 2M-element workload.

use hbmflow::dsl;
use hbmflow::hls;
use hbmflow::ir::{lower, rewrite, schedule, teil};
use hbmflow::olympus::{self, OlympusOpts};
use hbmflow::platform::Platform;
use hbmflow::sim;

fn main() -> anyhow::Result<()> {
    // 1. The DSL program (paper Fig. 2, p = 11).
    let src = dsl::inverse_helmholtz_source(11);
    println!("--- CFDlang source ---\n{src}");

    // 2. Front-end + middle-end: parse, build teil, factorize.
    let program = dsl::parse(&src).map_err(anyhow::Error::msg)?;
    let module = teil::from_ast(&program).map_err(anyhow::Error::msg)?;
    let naive_flops = module.flops();
    let module = rewrite::optimize(module);
    println!(
        "contraction factorization: {} -> {} flops/element (paper Eq. 2: 177,023)\n",
        naive_flops,
        module.flops()
    );

    // 3. Back-end: lower to the affine kernel, schedule 7 dataflow groups.
    let kernel = lower::lower_kernel(&module, "helmholtz").map_err(anyhow::Error::msg)?;
    let sched = schedule::fixed(&kernel, 7).map_err(anyhow::Error::msg)?;
    println!("{kernel}\n");
    println!(
        "dataflow groups: {:?}\n",
        sched.groups.iter().map(|g| g.name.as_str()).collect::<Vec<_>>()
    );

    // 4. Olympus system generation on the Alveo U280.
    let platform = Platform::alveo_u280();
    let opts = OlympusOpts::dataflow(7);
    let spec = olympus::generate(&kernel, &opts, &platform).map_err(anyhow::Error::msg)?;
    println!(
        "system: {} lanes x {} CU(s), {} HBM PCs, batch E = {} elements",
        spec.lanes,
        spec.num_cus,
        spec.total_pcs(),
        spec.batch_elements
    );
    println!("{}", olympus::config::system_cfg(&spec));

    // 5. HLS estimate + system simulation (N_eq = 2,000,000).
    let est = hls::estimate(&spec, &platform);
    let r = sim::simulate(&spec, &est, &platform, 2_000_000);
    println!(
        "estimate: {} ops, fmax {:.1} MHz, DSP {} LUT {}",
        est.ops(),
        est.fmax_mhz,
        est.total.dsp,
        est.total.lut
    );
    println!(
        "simulated: CU {:.1} GFLOPS, system {:.1} GFLOPS, {:.1} W, {:.2} GFLOPS/W",
        r.gflops_cu, r.gflops_system, r.avg_power_w, r.efficiency_gflops_w
    );
    println!("(paper Fig. 15 Dataflow-7: 43.4 GFLOPS)");
    Ok(())
}
