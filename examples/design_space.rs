//! Design-space exploration (the exploration the paper leaves "up to
//! the designer", §3.6.4) — a thin client of the first-class `dse`
//! subsystem: declare the space, explore it in parallel, read the
//! Pareto frontier.
//!
//! ```bash
//! cargo run --release --example design_space
//! # equivalent CLI: cargo run --release -- dse --kernel helmholtz --pareto-only
//! ```

use hbmflow::datatype::DataType;
use hbmflow::dse::{self, SearchSpace};
use hbmflow::flow::Session;
use hbmflow::platform::Platform;
use hbmflow::report::paper;

fn main() -> anyhow::Result<()> {
    // The flow Session is the entry point: a shared artifact cache the
    // whole sweep evaluates over (one parse + one lower per degree).
    let session = Session::new(Platform::alveo_u280());

    // The full default space: every OlympusOpts axis the paper's Figs.
    // 15-17 walk by hand (dtype x bus x dataflow x sharing x FIFO x CUs),
    // times polynomial degree. Narrow any axis before exploring to zoom.
    let space = SearchSpace::default_for("helmholtz");
    let ex = dse::explore_in(&session, &space, paper::N_ELEMENTS, None)
        .map_err(anyhow::Error::msg)?;

    // Ranked table of the 15 best feasible designs + frontier markers.
    println!("{}", dse::report::text(&ex, 15, false));

    // The designer's two classic picks, straight from the data.
    let ranked = ex.ranked();
    let Some(&best) = ranked.first() else {
        anyhow::bail!("no feasible design in the space");
    };
    let best_perf = &ex.outcomes[best];
    let best_eff = ranked
        .iter()
        .max_by(|&&a, &&b| {
            let e = |i: usize| {
                ex.outcomes[i]
                    .result
                    .as_ref()
                    .unwrap()
                    .sim
                    .efficiency_gflops_w
            };
            e(a).total_cmp(&e(b))
        })
        .map(|&i| &ex.outcomes[i])
        .expect("at least one feasible design");
    println!(
        "best throughput : {} ({:.1} GFLOPS system)",
        best_perf.point.label(),
        best_perf.result.as_ref().unwrap().sim.gflops_system
    );
    println!(
        "best efficiency : {} ({:.2} GFLOPS/W)",
        best_eff.point.label(),
        best_eff.result.as_ref().unwrap().sim.efficiency_gflops_w
    );
    println!(
        "\npaper's conclusion holds when replication is PCIe-bound: \
         \"the design can be optimized for power efficiency by only \
         instantiating one compute unit\" — best-efficiency CU count = {}",
        best_eff.point.opts.num_cus
    );

    // Sanity: the paper's Fig. 16 custom-precision pick is on (or its
    // FIFO-refined variant carries) the computed frontier.
    if let Some(i) = ex.find_config(DataType::Fx32, 11, Some(7), 1) {
        println!(
            "Fig. 16 fx32 p=11 DF7 1CU: {}",
            if ex.is_on_frontier(i) {
                "on the Pareto frontier"
            } else {
                "off the frontier (investigate!)"
            }
        );
    }

    // The point of the shared cache: thousands of candidates, two
    // front-end runs (p = 7 and p = 11).
    let st = session.stats();
    println!(
        "\nflow cache: {} parse+lower runs served {} candidates \
         ({} lowered-cache hits)",
        st.lowered_misses,
        ex.enumerated(),
        st.lowered_hits
    );
    Ok(())
}
