//! Design-space exploration: dtype x polynomial degree x CU count
//! (the exploration the paper leaves "up to the designer", §3.6.4),
//! with feasibility from the HLS estimator and objectives from the
//! simulator.
//!
//! ```bash
//! cargo run --release --example design_space
//! ```

use hbmflow::cli::build_kernel;
use hbmflow::datatype::DataType;
use hbmflow::hls;
use hbmflow::olympus::{self, OlympusOpts};
use hbmflow::platform::Platform;
use hbmflow::report::{self, paper};
use hbmflow::sim::{self, SimResult};

struct Candidate {
    name: String,
    r: SimResult,
    feasible: bool,
}

fn main() -> anyhow::Result<()> {
    let platform = Platform::alveo_u280();
    let n = paper::N_ELEMENTS;
    let mut candidates: Vec<Candidate> = Vec::new();

    for p in [7usize, 11] {
        let kernel = build_kernel("helmholtz", p)?;
        for dtype in [DataType::F64, DataType::F32, DataType::Fx64, DataType::Fx32] {
            for cus in 1..=4usize {
                let mut opts = if dtype.is_fixed() {
                    OlympusOpts::fixed_point(dtype)
                } else {
                    let mut o = OlympusOpts::dataflow(7);
                    o.dtype = dtype;
                    o
                };
                opts = opts.with_cus(cus);
                let Ok(spec) = olympus::generate(&kernel, &opts, &platform) else {
                    continue;
                };
                let est = hls::estimate(&spec, &platform);
                let feasible = est.total.fits_in(&platform.total_resources());
                let r = sim::simulate(&spec, &est, &platform, n);
                candidates.push(Candidate {
                    name: format!("{} p={p} x{cus}CU", dtype.display()),
                    r,
                    feasible,
                });
            }
        }
    }

    let rows: Vec<Vec<String>> = candidates
        .iter()
        .map(|c| {
            vec![
                c.name.clone(),
                if c.feasible { "yes" } else { "NO" }.into(),
                report::f(c.r.freq_mhz),
                report::f(c.r.gflops_cu),
                report::f(c.r.gflops_system),
                format!("{:.2}", c.r.efficiency_gflops_w),
                c.r.bottleneck.clone(),
            ]
        })
        .collect();
    println!(
        "{}",
        report::table(
            &["configuration", "fits", "f(MHz)", "CU", "System", "GF/W", "bound"],
            &rows
        )
    );

    let feasible: Vec<&Candidate> = candidates.iter().filter(|c| c.feasible).collect();
    let best_perf = feasible
        .iter()
        .max_by(|a, b| a.r.gflops_system.total_cmp(&b.r.gflops_system))
        .unwrap();
    let best_eff = feasible
        .iter()
        .max_by(|a, b| a.r.efficiency_gflops_w.total_cmp(&b.r.efficiency_gflops_w))
        .unwrap();
    println!(
        "best throughput : {} ({:.1} GFLOPS system)",
        best_perf.name, best_perf.r.gflops_system
    );
    println!(
        "best efficiency : {} ({:.2} GFLOPS/W)",
        best_eff.name, best_eff.r.efficiency_gflops_w
    );
    println!(
        "\npaper's conclusion holds when replication is PCIe-bound: \
         \"the design can be optimized for power efficiency by only \
         instantiating one compute unit\" — best-efficiency CU count = {}",
        best_eff.name.chars().rev().nth(2).unwrap_or('1')
    );
    Ok(())
}
