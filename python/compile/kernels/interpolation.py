"""L1 Pallas kernel: isotropic Interpolation operator (paper §4.3).

Maps u in R^{NxNxN} to u' in R^{MxMxM} through A in R^{MxN} applied along
every mode. The paper evaluates M = N = 11; the kernel supports M != N
(prolongation/restriction between polynomial degrees).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .quant import FixedFormat, quantize


def _interp_kernel(a_ref, u_ref, o_ref, *, fmt: FixedFormat | None):
    a = a_ref[...]
    u = u_ref[0]
    if fmt is not None:
        a = quantize(a, fmt)
        u = quantize(u, fmt)
    m, n = a.shape

    def maybe_quant(v):
        return quantize(v, fmt) if fmt is not None else v

    # mode 0: (m, n) @ (n, n*n)
    x = jnp.dot(a, u.reshape(n, n * n), precision="highest").reshape(m, n, n)
    x = maybe_quant(x)
    # mode 1
    x = jnp.swapaxes(x, 0, 1)  # (n, m, n)
    x = jnp.dot(a, x.reshape(n, m * n), precision="highest").reshape(m, m, n)
    x = jnp.swapaxes(x, 0, 1)  # (m, m, n)
    x = maybe_quant(x)
    # mode 2
    x = jnp.moveaxis(x, 2, 0)  # (n, m, m)
    x = jnp.dot(a, x.reshape(n, m * m), precision="highest").reshape(m, m, m)
    x = jnp.moveaxis(x, 0, 2)
    o_ref[0] = maybe_quant(x)


@functools.partial(jax.jit, static_argnames=("fmt",))
def interpolation_pallas(a, u, fmt: FixedFormat | None = None):
    """Batched interpolation via pallas_call.

    Args:
      a: (M, N) operator. u: (B, N, N, N). Returns (B, M, M, M).
    """
    b, n = u.shape[0], u.shape[1]
    m = a.shape[0]
    kernel = functools.partial(_interp_kernel, fmt=fmt)
    return pl.pallas_call(
        kernel,
        grid=(b,),
        in_specs=[
            pl.BlockSpec((m, n), lambda i: (0, 0)),
            pl.BlockSpec((1, n, n, n), lambda i: (i, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, m, m, m), lambda i: (i, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, m, m, m), u.dtype),
        interpret=True,
    )(a, u)
