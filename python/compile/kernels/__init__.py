"""L1 Pallas kernels for the CFD tensor operators (paper §2.1, §4.3).

All kernels are lowered with interpret=True (CPU PJRT cannot execute
Mosaic custom-calls); correctness is checked against `ref` by pytest.
"""

from . import gradient, helmholtz, interpolation, quant, ref  # noqa: F401
from .gradient import gradient_pallas  # noqa: F401
from .helmholtz import inverse_helmholtz_pallas  # noqa: F401
from .interpolation import interpolation_pallas  # noqa: F401
from .quant import FORMATS, FX32, FX64, FixedFormat, quantize  # noqa: F401
