"""Pure-jnp correctness oracles for the CFD tensor kernels.

These are the ground truth for the Pallas kernels (L1) and for the Rust
native baseline (cross-checked through the PJRT runtime). They implement
the three operators evaluated in the paper:

  * Inverse Helmholtz (Eq. 1a-1c):
        t = S x0 S x1 S x2 u        (three mode products, Eq. 1a)
        r = D * t                    (Hadamard, Eq. 1b)
        v = S^T x0 S^T x1 S^T x2 r   (three mode products, Eq. 1c)
  * Interpolation: u' = A x0 A x1 A x2 u   (isotropic operator A in R^{MxN})
  * Gradient: (Dx x0 u, Dy x1 u, Dz x2 u) on an (nx, ny, nz) element

`mode_apply(A, x, mode)` is the n-mode tensor-matrix product
(A x_n u)_{..i..} = sum_l A[i, l] * u[..l..].

The FLOP model matches the paper's Eq. 2: each mode product on a p^3
element costs 2*p^4 flops, the Hadamard costs p^3, so Inverse Helmholtz
costs (12p + 1) * p^3 per element.
"""

from __future__ import annotations

import jax.numpy as jnp


def mode_apply(a, x, mode: int):
    """n-mode product: contract `a`'s second index with `x`'s `mode` index.

    result[.., i, ..] = sum_l a[i, l] * x[.., l, ..]
    """
    x = jnp.moveaxis(x, mode, 0)
    shp = x.shape
    y = jnp.dot(a, x.reshape(shp[0], -1), precision="highest")
    y = y.reshape((a.shape[0],) + shp[1:])
    return jnp.moveaxis(y, 0, mode)


def inverse_helmholtz(s, d, u):
    """Inverse Helmholtz operator on a single (p, p, p) element.

    Args:
      s: (p, p) spectral operator matrix.
      d: (p, p, p) diagonal (Hadamard) factor.
      u: (p, p, p) input element.
    Returns:
      v: (p, p, p) output element.
    """
    t = mode_apply(s, mode_apply(s, mode_apply(s, u, 0), 1), 2)
    r = d * t
    st = s.T
    v = mode_apply(st, mode_apply(st, mode_apply(st, r, 0), 1), 2)
    return v


def interpolation(a, u):
    """Isotropic interpolation u' = A (x) A (x) A (x) u, A in R^{MxN}."""
    return mode_apply(a, mode_apply(a, mode_apply(a, u, 0), 1), 2)


def gradient(dx, dy, dz, u):
    """Spectral gradient of u along all three dimensions.

    Args:
      dx: (nx, nx) derivative matrix, dy: (ny, ny), dz: (nz, nz).
      u: (nx, ny, nz) element.
    Returns:
      (gx, gy, gz) each of shape (nx, ny, nz).
    """
    return (
        mode_apply(dx, u, 0),
        mode_apply(dy, u, 1),
        mode_apply(dz, u, 2),
    )


# ---------------------------------------------------------------------------
# Batched references (the implicit CFDlang "element loop").
# ---------------------------------------------------------------------------


def inverse_helmholtz_batch(s, d, u):
    """Batched Inverse Helmholtz: d, u are (B, p, p, p); s is shared."""
    import jax

    return jax.vmap(lambda de, ue: inverse_helmholtz(s, de, ue))(d, u)


def interpolation_batch(a, u):
    import jax

    return jax.vmap(lambda ue: interpolation(a, ue))(u)


def gradient_batch(dx, dy, dz, u):
    import jax

    return jax.vmap(lambda ue: gradient(dx, dy, dz, ue))(u)


# ---------------------------------------------------------------------------
# FLOP model (paper Eq. 2 / Eq. 3).
# ---------------------------------------------------------------------------


def helmholtz_flops_per_element(p: int) -> int:
    """(12p + 1) * p^3 — 177,023 for p=11; 29,155 for p=7 (paper Eq. 2)."""
    return (12 * p + 1) * p**3


def interpolation_flops_per_element(m: int, n: int) -> int:
    """Three mode products mapping n^3 -> m^3 through A in R^{mxn}."""
    # mode 0: m*n^2 outputs, 2n flops each; mode 1: m^2*n, 2n; mode 2: m^3, 2n
    return 2 * n * (m * n * n + m * m * n + m * m * m)


def gradient_flops_per_element(nx: int, ny: int, nz: int) -> int:
    return 2 * (nx * nx * ny * nz + nx * ny * ny * nz + nx * ny * nz * nz)
