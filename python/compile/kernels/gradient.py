"""L1 Pallas kernel: spectral Gradient operator (paper §4.3).

Computes the gradient of u along all three dimensions with per-axis
derivative matrices. The paper evaluates an (8, 7, 6) element; the
anisotropic shape exercises non-square mode products.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .quant import FixedFormat, quantize


def _grad_kernel(dx_ref, dy_ref, dz_ref, u_ref, gx_ref, gy_ref, gz_ref, *, fmt):
    dx, dy, dz = dx_ref[...], dy_ref[...], dz_ref[...]
    u = u_ref[0]
    if fmt is not None:
        dx, dy, dz = (quantize(m, fmt) for m in (dx, dy, dz))
        u = quantize(u, fmt)
    nx, ny, nz = u.shape

    def maybe_quant(v):
        return quantize(v, fmt) if fmt is not None else v

    # gx: mode-0 product, (nx, nx) @ (nx, ny*nz)
    gx = jnp.dot(dx, u.reshape(nx, ny * nz), precision="highest")
    gx_ref[0] = maybe_quant(gx.reshape(nx, ny, nz))

    # gy: mode-1 product
    uy = jnp.swapaxes(u, 0, 1)  # (ny, nx, nz)
    gy = jnp.dot(dy, uy.reshape(ny, nx * nz), precision="highest")
    gy_ref[0] = maybe_quant(jnp.swapaxes(gy.reshape(ny, nx, nz), 0, 1))

    # gz: mode-2 product
    uz = jnp.moveaxis(u, 2, 0)  # (nz, nx, ny)
    gz = jnp.dot(dz, uz.reshape(nz, nx * ny), precision="highest")
    gz_ref[0] = maybe_quant(jnp.moveaxis(gz.reshape(nz, nx, ny), 0, 2))


@functools.partial(jax.jit, static_argnames=("fmt",))
def gradient_pallas(dx, dy, dz, u, fmt: FixedFormat | None = None):
    """Batched gradient via pallas_call.

    Args:
      dx: (nx, nx), dy: (ny, ny), dz: (nz, nz) derivative matrices.
      u: (B, nx, ny, nz).
    Returns:
      (gx, gy, gz), each (B, nx, ny, nz).
    """
    b, nx, ny, nz = u.shape
    kernel = functools.partial(_grad_kernel, fmt=fmt)
    out = jax.ShapeDtypeStruct(u.shape, u.dtype)
    el = lambda i: (i, 0, 0, 0)
    return pl.pallas_call(
        kernel,
        grid=(b,),
        in_specs=[
            pl.BlockSpec((nx, nx), lambda i: (0, 0)),
            pl.BlockSpec((ny, ny), lambda i: (0, 0)),
            pl.BlockSpec((nz, nz), lambda i: (0, 0)),
            pl.BlockSpec((1, nx, ny, nz), el),
        ],
        out_specs=[
            pl.BlockSpec((1, nx, ny, nz), el),
            pl.BlockSpec((1, nx, ny, nz), el),
            pl.BlockSpec((1, nx, ny, nz), el),
        ],
        out_shape=[out, out, out],
        interpret=True,
    )(dx, dy, dz, u)
