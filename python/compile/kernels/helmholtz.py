"""L1 Pallas kernel: Inverse Helmholtz operator (paper Eq. 1a-1c).

Hardware adaptation (see DESIGN.md §Hardware-Adaptation): the paper's
FPGA compute unit packs four 64-bit "lanes" onto a 256-bit AXI port and
pipelines seven loop nests through BRAM-buffered dataflow stages. On TPU
the same insight — stream one element's working set into fast memory,
run the contractions at full multiplier utilization, stream the result
out — maps to:

  * grid over elements (one element per grid step; Pallas double-buffers
    the HBM<->VMEM transfers across steps, which is exactly the paper's
    Read/Write dataflow overlap);
  * BlockSpec-selected (p, p, p) blocks of D/u/v in VMEM (~10.4 KiB per
    f64 tensor at p=11 — far below the ~16 MiB VMEM budget, so the
    shared S matrix is simply replicated into every step);
  * each mode product reshaped to a (p, p) x (p, p^2) GEMM so the MXU
    systolic array implements the paper's 11-multiplier MAC chains.

The kernel MUST be lowered with interpret=True: real TPU lowering emits
a Mosaic custom-call that the CPU PJRT plugin cannot execute.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .quant import FixedFormat, quantize


def _mode_products(a, x, fmt: FixedFormat | None):
    """Apply `a` along all three modes of the (p, p, p) tensor `x`.

    Written with explicit reshape/dot (not einsum) so each mode is a
    single MXU-shaped GEMM. Optionally fake-quantizes after each mode
    product — the point where the FPGA datapath stores to BRAM.
    """
    p = a.shape[0]
    q = a.shape[1]

    def maybe_quant(v):
        return quantize(v, fmt) if fmt is not None else v

    # mode 0: (p, q) @ (q, q*q) -> (p, q, q)
    x = jnp.dot(a, x.reshape(q, q * q), precision="highest").reshape(p, q, q)
    x = maybe_quant(x)
    # mode 1: move axis 1 first, (p, q) @ (q, p*q) -> (p, p, q)
    x = jnp.swapaxes(x, 0, 1)
    x = jnp.dot(a, x.reshape(q, p * q), precision="highest").reshape(p, p, q)
    x = jnp.swapaxes(x, 0, 1)
    x = maybe_quant(x)
    # mode 2: move axis 2 first, (p, q) @ (q, p*p) -> (p, p, p)
    x = jnp.moveaxis(x, 2, 0)
    x = jnp.dot(a, x.reshape(q, p * p), precision="highest").reshape(p, p, p)
    x = jnp.moveaxis(x, 0, 2)
    return maybe_quant(x)


def _helmholtz_kernel(s_ref, d_ref, u_ref, v_ref, *, fmt: FixedFormat | None):
    """Pallas kernel body: one element per grid step."""
    s = s_ref[...]
    d = d_ref[0]  # (1, p, p, p) block -> (p, p, p)
    u = u_ref[0]
    if fmt is not None:
        s = quantize(s, fmt)
        d = quantize(d, fmt)
        u = quantize(u, fmt)
    t = _mode_products(s, u, fmt)
    r = d * t
    if fmt is not None:
        r = quantize(r, fmt)
    v = _mode_products(s.T, r, fmt)
    v_ref[0] = v


@functools.partial(jax.jit, static_argnames=("fmt",))
def inverse_helmholtz_pallas(s, d, u, fmt: FixedFormat | None = None):
    """Batched Inverse Helmholtz via pallas_call.

    Args:
      s: (p, p) operator matrix (shared across the batch).
      d: (B, p, p, p) Hadamard factors.
      u: (B, p, p, p) inputs.
      fmt: optional fixed-point format for fake-quantized arithmetic.
    Returns:
      v: (B, p, p, p).
    """
    b, p = u.shape[0], u.shape[1]
    kernel = functools.partial(_helmholtz_kernel, fmt=fmt)
    return pl.pallas_call(
        kernel,
        grid=(b,),
        in_specs=[
            pl.BlockSpec((p, p), lambda i: (0, 0)),
            pl.BlockSpec((1, p, p, p), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((1, p, p, p), lambda i: (i, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, p, p, p), lambda i: (i, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct(u.shape, u.dtype),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(s, d, u)


def _mode_products_batched(a, x, fmt: FixedFormat | None):
    """Apply `a` along axes 1..3 of a (blk, p, p, p) batch.

    Each mode is one (blk*p*p, p) x (p, p) GEMM — a tall MXU matmul that
    amortizes the systolic-array fill across the whole block. This is the
    §Perf L1 optimization: the one-element-per-grid-step kernel lowers
    (under interpret=True) to a serial while-loop of tiny GEMMs; blocking
    the batch turns it into three large GEMMs per pass.
    """
    rows = a.shape[0]

    def maybe_quant(v):
        return quantize(v, fmt) if fmt is not None else v

    for ax in (1, 2, 3):
        z = jnp.moveaxis(x, ax, 3)
        lead = z.shape[:-1]
        cols = z.shape[-1]
        y = jnp.dot(
            z.reshape(-1, cols), a.T, precision="highest"
        ).reshape(lead + (rows,))
        x = maybe_quant(jnp.moveaxis(y, 3, ax))
    return x


def _helmholtz_kernel_blocked(s_ref, d_ref, u_ref, v_ref, *, fmt):
    """Pallas kernel body: a whole block of elements per grid step."""
    s = s_ref[...]
    d = d_ref[...]
    u = u_ref[...]
    if fmt is not None:
        s = quantize(s, fmt)
        d = quantize(d, fmt)
        u = quantize(u, fmt)
    t = _mode_products_batched(s, u, fmt)
    r = d * t
    if fmt is not None:
        r = quantize(r, fmt)
    v_ref[...] = _mode_products_batched(s.T, r, fmt)


@functools.partial(jax.jit, static_argnames=("fmt",))
def inverse_helmholtz_pallas_blocked(s, d, u, fmt: FixedFormat | None = None):
    """Batch-blocked Inverse Helmholtz: one grid step, batched GEMMs.

    Numerically identical to `inverse_helmholtz_pallas` (same contraction
    order and quantization points); only the iteration space changes.
    """
    b, p = u.shape[0], u.shape[1]
    kernel = functools.partial(_helmholtz_kernel_blocked, fmt=fmt)
    full = pl.BlockSpec((b, p, p, p), lambda: (0, 0, 0, 0))
    return pl.pallas_call(
        kernel,
        grid=(),
        in_specs=[pl.BlockSpec((p, p), lambda: (0, 0)), full, full],
        out_specs=full,
        out_shape=jax.ShapeDtypeStruct(u.shape, u.dtype),
        interpret=True,
    )(s, d, u)


def vmem_bytes_per_step(p: int, dtype_bytes: int) -> int:
    """VMEM working set of one grid step (S + D + u + v + t/r temps).

    Used by the DESIGN.md roofline estimate: the Pallas pipeline holds
    two grid steps in flight (double buffering), so the footprint must
    stay below VMEM/2.
    """
    s = p * p
    per_elem = 3 * p**3  # d, u, v blocks
    temps = 2 * p**3  # t and r live simultaneously at the Hadamard
    return (s + per_elem + temps) * dtype_bytes
