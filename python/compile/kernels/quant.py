"""Fixed-point emulation via fake quantization (paper §3.6.4).

The paper converts the datapath from IEEE double to `ap_fixed` formats:

  * Fixed Point 64 = Q24.40 (24 integer bits incl. sign, 40 fractional)
  * Fixed Point 32 = Q8.24  (8 integer bits incl. sign, 24 fractional)

On TPU/XLA we cannot synthesize ap_fixed datapaths, so we emulate the
numerics with *fake quantization*: every operator result is rounded to
the fixed-point grid (step 2^-frac_bits) and saturated to the format's
dynamic range. The carrier type is f64 for both formats: Q24.40 and
Q8.24 grid points with |x| < 2^23 are exactly representable in an f64
mantissa (52 bits >= int_bits-1 + frac_bits for Q8.24; for Q24.40 the
inputs are scaled to [-1, 1] per the paper, so magnitudes stay far below
the 2^12 exactness bound).

Quantization is applied at *operator* granularity (after each mode
product / Hadamard), mirroring where the HLS datapath truncates stored
intermediates. Intra-accumulation rounding (per-MAC) is not modeled; the
measured MSE therefore bounds the paper's from below while preserving the
headline ratio MSE(fx32)/MSE(fx64) ~ 2^32 (paper: 3.58e-12 / 9.39e-22).
See DESIGN.md "Hardware substitutions".
"""

from __future__ import annotations

from dataclasses import dataclass

import jax.numpy as jnp


@dataclass(frozen=True)
class FixedFormat:
    """A signed fixed-point format with int_bits + frac_bits total bits."""

    int_bits: int  # integer bits, including the sign bit
    frac_bits: int

    @property
    def total_bits(self) -> int:
        return self.int_bits + self.frac_bits

    @property
    def scale(self) -> float:
        return float(2**self.frac_bits)

    @property
    def max_value(self) -> float:
        """Largest representable value: 2^(int_bits-1) - 2^-frac_bits."""
        return float(2 ** (self.int_bits - 1)) - 1.0 / self.scale

    @property
    def min_value(self) -> float:
        return -float(2 ** (self.int_bits - 1))

    @property
    def name(self) -> str:
        return f"q{self.int_bits}_{self.frac_bits}"


# The two formats evaluated in the paper (§3.6.4).
FX64 = FixedFormat(int_bits=24, frac_bits=40)
FX32 = FixedFormat(int_bits=8, frac_bits=24)

FORMATS = {"fx64": FX64, "fx32": FX32}


def quantize(x, fmt: FixedFormat):
    """Round `x` to the fixed-point grid and saturate to the range.

    Round-half-to-even matches the default `ap_fixed` quantization mode
    used by Vitis HLS arithmetic results stored back to registers.
    """
    y = jnp.round(x * fmt.scale) / fmt.scale
    return jnp.clip(y, fmt.min_value, fmt.max_value)


def quantization_noise_power(fmt: FixedFormat) -> float:
    """Expected MSE contribution of one rounding: step^2 / 12."""
    step = 1.0 / fmt.scale
    return step * step / 12.0
