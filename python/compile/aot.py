"""AOT: lower the L2 models to HLO *text* artifacts + a JSON manifest.

Interchange format is HLO text, NOT a serialized HloModuleProto: jax
>= 0.5 emits protos with 64-bit instruction ids which xla_extension
0.5.1 (the version behind the published `xla` 0.1.6 crate) rejects
(`proto.id() <= INT_MAX`). The text parser reassigns ids, so text
round-trips cleanly. Lowering uses return_tuple=True; the Rust side
unwraps with `to_tuple1()` / `to_tuple()`.

Run via `make artifacts` (no-op when inputs are unchanged):

    cd python && python -m compile.aot --out-dir ../artifacts

Python runs ONCE here and never on the request path.
"""

from __future__ import annotations

import argparse
import json
import os

import jax

jax.config.update("jax_enable_x64", True)

from jax._src.lib import xla_client as xc  # noqa: E402

from . import model  # noqa: E402
from .kernels import ref  # noqa: E402

GRADIENT_DIMS = (8, 7, 6)  # the paper's anisotropic gradient element


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec_json(spec) -> dict:
    return {"shape": list(spec.shape), "dtype": str(spec.dtype)}


def _entries(quick: bool):
    """Yield (name, fn, arg_specs, meta) for every artifact to build."""
    helmholtz = []
    if quick:
        helmholtz = [
            (7, "f64", 8, "pallas"),
            (7, "f64", 8, "ref"),
            (7, "fx32", 8, "pallas"),
        ]
    else:
        for p in (7, 11):
            for dtype in ("f64", "f32", "fx64", "fx32"):
                helmholtz.append((p, dtype, 32, "pallas"))
        # small-batch variants for tests / quick runs
        helmholtz += [
            (11, "f64", 8, "pallas"),
            (11, "fx32", 8, "pallas"),
            (7, "f64", 8, "pallas"),
        ]
        # pure-jnp "optimized CPU" analogs (paper Fig. 19 Intel bars)
        helmholtz += [
            (11, "f64", 32, "ref"),
            (7, "f64", 32, "ref"),
        ]
        # §Perf batch-blocked L1 variants (see EXPERIMENTS.md §Perf)
        helmholtz += [
            (11, "f64", 32, "pallas_blocked"),
            (11, "fx32", 32, "pallas_blocked"),
            (7, "f64", 32, "pallas_blocked"),
        ]

    for p, dtype, batch, variant in helmholtz:
        suffix = "" if variant == "pallas" else f"_{variant}"
        name = f"helmholtz_p{p}_{dtype}_b{batch}{suffix}"
        fn = model.helmholtz_model(dtype, variant)
        specs = model.helmholtz_arg_specs(p, batch, dtype)
        meta = {
            "kernel": "helmholtz",
            "p": p,
            "dtype": dtype,
            "batch": batch,
            "variant": variant,
            "flops_per_element": ref.helmholtz_flops_per_element(p),
            "num_outputs": 1,
        }
        yield name, fn, specs, meta

    interp = [(11, 11, 32, "f64", "pallas")]
    if not quick:
        interp += [(11, 11, 32, "f64", "ref"), (11, 11, 8, "f64", "pallas")]
    for m, n, batch, dtype, variant in interp:
        suffix = "" if variant == "pallas" else f"_{variant}"
        name = f"interp_m{m}n{n}_{dtype}_b{batch}{suffix}"
        fn = model.interpolation_model(dtype, variant)
        specs = model.interpolation_arg_specs(m, n, batch, dtype)
        meta = {
            "kernel": "interpolation",
            "m": m,
            "n": n,
            "p": n,
            "dtype": dtype,
            "batch": batch,
            "variant": variant,
            "flops_per_element": ref.interpolation_flops_per_element(m, n),
            "num_outputs": 1,
        }
        yield name, fn, specs, meta

    grads = [(GRADIENT_DIMS, 32, "f64", "pallas")]
    if not quick:
        grads += [(GRADIENT_DIMS, 32, "f64", "ref")]
    for dims, batch, dtype, variant in grads:
        suffix = "" if variant == "pallas" else f"_{variant}"
        nx, ny, nz = dims
        name = f"gradient_{nx}x{ny}x{nz}_{dtype}_b{batch}{suffix}"
        fn = model.gradient_model(dtype, variant)
        specs = model.gradient_arg_specs(dims, batch, dtype)
        meta = {
            "kernel": "gradient",
            "dims": list(dims),
            "p": nx,
            "dtype": dtype,
            "batch": batch,
            "variant": variant,
            "flops_per_element": ref.gradient_flops_per_element(*dims),
            "num_outputs": 3,
        }
        yield name, fn, specs, meta


def build(out_dir: str, quick: bool = False) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    manifest = {"format": "hlo-text", "artifacts": []}
    for name, fn, specs, meta in _entries(quick):
        path = f"{name}.hlo.txt"
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        with open(os.path.join(out_dir, path), "w") as f:
            f.write(text)
        entry = dict(meta)
        entry["name"] = name
        entry["path"] = path
        entry["inputs"] = [_spec_json(s) for s in specs]
        manifest["artifacts"].append(entry)
        print(f"  wrote {path} ({len(text)} chars)")
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"manifest: {len(manifest['artifacts'])} artifacts -> {out_dir}")
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--quick", action="store_true", help="small subset for smoke tests"
    )
    args = ap.parse_args()
    build(args.out_dir, quick=args.quick)


if __name__ == "__main__":
    main()
