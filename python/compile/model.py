"""L2: batched JAX compute graphs calling the L1 Pallas kernels.

Each model is the body of one FPGA compute-unit invocation in the paper's
target architecture (Fig. 4): a batch of B independent elements streamed
through the operator. The Rust coordinator (L3) executes the lowered HLO
for every CU dispatch; Python never runs on the request path.

Two variants exist per operator:

  * ``pallas`` — the L1 kernel (the "accelerator datapath" analog);
  * ``ref``    — the pure-jnp graph (lowered separately; XLA-CPU fuses it
    aggressively, and the Rust baselines use it as the "highly-optimized
    Intel implementation" analog of paper §4.3).

Fixed-point variants (fx64 = Q24.40, fx32 = Q8.24) use fake quantization
on an f64 carrier (see kernels.quant).
"""

from __future__ import annotations

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402

from .kernels import gradient as gradient_k  # noqa: E402
from .kernels import helmholtz as helmholtz_k  # noqa: E402
from .kernels import interpolation as interpolation_k  # noqa: E402
from .kernels import quant, ref  # noqa: E402

#: dtype name -> (carrier jnp dtype, fixed-point format or None)
DTYPES = {
    "f64": (jnp.float64, None),
    "f32": (jnp.float32, None),
    "fx64": (jnp.float64, quant.FX64),
    "fx32": (jnp.float64, quant.FX32),
}


def _quantized_ref_helmholtz(s, d, u, fmt):
    """Reference helmholtz with operator-granularity fake quantization."""
    s, d, u = (quant.quantize(x, fmt) for x in (s, d, u))
    qq = lambda x: quant.quantize(x, fmt)
    t = qq(ref.mode_apply(s, u, 0))
    t = qq(ref.mode_apply(s, t, 1))
    t = qq(ref.mode_apply(s, t, 2))
    r = qq(d * t)
    v = qq(ref.mode_apply(s.T, r, 0))
    v = qq(ref.mode_apply(s.T, v, 1))
    v = qq(ref.mode_apply(s.T, v, 2))
    return v


def helmholtz_model(dtype: str, variant: str = "pallas"):
    """Returns fn(s, d, u) -> v for a batch of elements.

    s: (p, p); d, u: (B, p, p, p). Output is a 1-tuple (AOT lowers with
    return_tuple=True; the Rust side unwraps with to_tuple1).
    """
    _, fmt = DTYPES[dtype]

    if variant == "pallas":

        def fn(s, d, u):
            return (helmholtz_k.inverse_helmholtz_pallas(s, d, u, fmt=fmt),)

    elif variant == "pallas_blocked":
        # §Perf L1 variant: whole batch per grid step (batched GEMMs)

        def fn(s, d, u):
            return (
                helmholtz_k.inverse_helmholtz_pallas_blocked(s, d, u, fmt=fmt),
            )

    elif variant == "ref":

        def fn(s, d, u):
            if fmt is None:
                return (ref.inverse_helmholtz_batch(s, d, u),)
            return (
                jax.vmap(
                    lambda de, ue: _quantized_ref_helmholtz(s, de, ue, fmt)
                )(d, u),
            )

    else:
        raise ValueError(f"unknown variant {variant!r}")
    return fn


def interpolation_model(dtype: str, variant: str = "pallas"):
    """Returns fn(a, u) -> u' for a batch; a: (M, N), u: (B, N, N, N)."""
    _, fmt = DTYPES[dtype]

    if variant == "pallas":

        def fn(a, u):
            return (interpolation_k.interpolation_pallas(a, u, fmt=fmt),)

    elif variant == "ref":

        def fn(a, u):
            if fmt is not None:
                a = quant.quantize(a, fmt)
                u = quant.quantize(u, fmt)
            return (ref.interpolation_batch(a, u),)

    else:
        raise ValueError(f"unknown variant {variant!r}")
    return fn


def gradient_model(dtype: str, variant: str = "pallas"):
    """Returns fn(dx, dy, dz, u) -> (gx, gy, gz) for a batch."""
    _, fmt = DTYPES[dtype]

    if variant == "pallas":

        def fn(dx, dy, dz, u):
            return gradient_k.gradient_pallas(dx, dy, dz, u, fmt=fmt)

    elif variant == "ref":

        def fn(dx, dy, dz, u):
            if fmt is not None:
                dx, dy, dz, u = (
                    quant.quantize(x, fmt) for x in (dx, dy, dz, u)
                )
            return ref.gradient_batch(dx, dy, dz, u)

    else:
        raise ValueError(f"unknown variant {variant!r}")
    return fn


def helmholtz_arg_specs(p: int, batch: int, dtype: str):
    """ShapeDtypeStructs for lowering a helmholtz model."""
    carrier, _ = DTYPES[dtype]
    return (
        jax.ShapeDtypeStruct((p, p), carrier),
        jax.ShapeDtypeStruct((batch, p, p, p), carrier),
        jax.ShapeDtypeStruct((batch, p, p, p), carrier),
    )


def interpolation_arg_specs(m: int, n: int, batch: int, dtype: str):
    carrier, _ = DTYPES[dtype]
    return (
        jax.ShapeDtypeStruct((m, n), carrier),
        jax.ShapeDtypeStruct((batch, n, n, n), carrier),
    )


def gradient_arg_specs(dims, batch: int, dtype: str):
    carrier, _ = DTYPES[dtype]
    nx, ny, nz = dims
    return (
        jax.ShapeDtypeStruct((nx, nx), carrier),
        jax.ShapeDtypeStruct((ny, ny), carrier),
        jax.ShapeDtypeStruct((nz, nz), carrier),
        jax.ShapeDtypeStruct((batch, nx, ny, nz), carrier),
    )
