"""AOT pipeline tests: model lowering, HLO-text emission, manifest shape."""

import json
import os

import jax

jax.config.update("jax_enable_x64", True)

import numpy as np
import pytest

from compile import aot, model
from compile.kernels import ref


def test_helmholtz_model_executes_like_ref():
    fn = model.helmholtz_model("f64", "pallas")
    p, b = 7, 4
    rng = np.random.default_rng(1)
    s = rng.uniform(-1, 1, (p, p))
    d = rng.uniform(-1, 1, (b, p, p, p))
    u = rng.uniform(-1, 1, (b, p, p, p))
    (v,) = fn(s, d, u)
    want = ref.inverse_helmholtz_batch(s, d, u)
    np.testing.assert_allclose(np.asarray(v), np.asarray(want), rtol=1e-12)


def test_ref_variant_matches_pallas_variant():
    p, b = 5, 3
    rng = np.random.default_rng(2)
    s = rng.uniform(-1, 1, (p, p))
    d = rng.uniform(-1, 1, (b, p, p, p))
    u = rng.uniform(-1, 1, (b, p, p, p))
    (v1,) = model.helmholtz_model("f64", "pallas")(s, d, u)
    (v2,) = model.helmholtz_model("f64", "ref")(s, d, u)
    np.testing.assert_allclose(np.asarray(v1), np.asarray(v2), rtol=1e-12)


def test_fx_ref_variant_matches_fx_pallas_variant():
    p, b = 5, 2
    rng = np.random.default_rng(3)
    s = rng.uniform(-1, 1, (p, p))
    d = rng.uniform(-1, 1, (b, p, p, p))
    u = rng.uniform(-1, 1, (b, p, p, p))
    (v1,) = model.helmholtz_model("fx32", "pallas")(s, d, u)
    (v2,) = model.helmholtz_model("fx32", "ref")(s, d, u)
    np.testing.assert_allclose(np.asarray(v1), np.asarray(v2), rtol=1e-9)


def test_lowering_produces_hlo_text():
    fn = model.helmholtz_model("f64", "pallas")
    specs = model.helmholtz_arg_specs(5, 2, "f64")
    lowered = jax.jit(fn).lower(*specs)
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    assert "ENTRY" in text
    # return_tuple=True: root must be a tuple
    assert "tuple(" in text or "(f64[" in text


def test_quick_build_writes_manifest(tmp_path):
    out = str(tmp_path / "artifacts")
    manifest = aot.build(out, quick=True)
    with open(os.path.join(out, "manifest.json")) as f:
        on_disk = json.load(f)
    assert on_disk == manifest
    assert on_disk["format"] == "hlo-text"
    names = {a["name"] for a in on_disk["artifacts"]}
    assert "helmholtz_p7_f64_b8" in names
    for a in on_disk["artifacts"]:
        path = os.path.join(out, a["path"])
        assert os.path.exists(path)
        with open(path) as f:
            head = f.read(200)
        assert "HloModule" in head
        assert a["flops_per_element"] > 0
        assert all(len(i["shape"]) >= 2 for i in a["inputs"])


def test_arg_specs_shapes():
    s, d, u = model.helmholtz_arg_specs(11, 32, "f64")
    assert s.shape == (11, 11)
    assert d.shape == (32, 11, 11, 11)
    assert u.shape == (32, 11, 11, 11)
    assert str(s.dtype) == "float64"
    s32, _, _ = model.helmholtz_arg_specs(7, 8, "f32")
    assert str(s32.dtype) == "float32"
    # fixed-point carriers are f64
    sq, _, _ = model.helmholtz_arg_specs(7, 8, "fx32")
    assert str(sq.dtype) == "float64"


def test_unknown_variant_raises():
    with pytest.raises(ValueError):
        model.helmholtz_model("f64", "nope")
    with pytest.raises(KeyError):
        model.helmholtz_model("f128")
