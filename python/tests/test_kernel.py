"""Kernel-vs-reference correctness: the CORE L1 signal.

Hypothesis sweeps shapes/dtypes; every Pallas kernel must match the
pure-jnp oracle to float tolerance.
"""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import (
    gradient_pallas,
    inverse_helmholtz_pallas,
    interpolation_pallas,
    ref,
)
from compile.kernels.helmholtz import inverse_helmholtz_pallas_blocked
from compile.kernels.quant import FX32, FX64

RNG = np.random.default_rng(0)


def _rand(shape, dtype=np.float64, scale=1.0):
    # Paper §3.6.4: physical data is rescaled into [-1, 1].
    return (RNG.uniform(-scale, scale, size=shape)).astype(dtype)


# ---------------------------------------------------------------------------
# Inverse Helmholtz
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("p", [3, 7, 11])
@pytest.mark.parametrize("batch", [1, 5])
def test_helmholtz_matches_ref_f64(p, batch):
    s = _rand((p, p))
    d = _rand((batch, p, p, p))
    u = _rand((batch, p, p, p))
    got = inverse_helmholtz_pallas(s, d, u)
    want = ref.inverse_helmholtz_batch(s, d, u)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-12)


def test_helmholtz_matches_ref_f32():
    p, batch = 7, 3
    s = _rand((p, p), np.float32)
    d = _rand((batch, p, p, p), np.float32)
    u = _rand((batch, p, p, p), np.float32)
    got = inverse_helmholtz_pallas(s, d, u)
    want = ref.inverse_helmholtz_batch(s, d, u)
    assert got.dtype == jnp.float32
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5)


def test_helmholtz_identity_s_is_hadamard():
    """With S = I the operator reduces to v = d * u."""
    p, batch = 5, 2
    s = np.eye(p)
    d = _rand((batch, p, p, p))
    u = _rand((batch, p, p, p))
    got = inverse_helmholtz_pallas(s, d, u)
    np.testing.assert_allclose(np.asarray(got), d * u, rtol=1e-13)


def test_helmholtz_linearity_in_u():
    p, batch = 4, 2
    s = _rand((p, p))
    d = _rand((batch, p, p, p))
    u1 = _rand((batch, p, p, p))
    u2 = _rand((batch, p, p, p))
    lhs = inverse_helmholtz_pallas(s, d, u1 + 2.0 * u2)
    rhs = inverse_helmholtz_pallas(s, d, u1) + 2.0 * inverse_helmholtz_pallas(
        s, d, u2
    )
    np.testing.assert_allclose(np.asarray(lhs), np.asarray(rhs), rtol=1e-11)


@settings(max_examples=20, deadline=None)
@given(
    p=st.integers(min_value=2, max_value=9),
    batch=st.integers(min_value=1, max_value=4),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_helmholtz_hypothesis_sweep(p, batch, seed):
    rng = np.random.default_rng(seed)
    s = rng.uniform(-1, 1, (p, p))
    d = rng.uniform(-1, 1, (batch, p, p, p))
    u = rng.uniform(-1, 1, (batch, p, p, p))
    got = inverse_helmholtz_pallas(s, d, u)
    want = ref.inverse_helmholtz_batch(s, d, u)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-11)


@pytest.mark.parametrize("p,batch", [(5, 4), (11, 8)])
def test_blocked_kernel_matches_per_element_kernel(p, batch):
    """The §Perf batch-blocked variant is numerically equivalent."""
    s = _rand((p, p)) / p
    d = _rand((batch, p, p, p))
    u = _rand((batch, p, p, p))
    a = inverse_helmholtz_pallas(s, d, u)
    b = inverse_helmholtz_pallas_blocked(s, d, u)
    np.testing.assert_allclose(
        np.asarray(a), np.asarray(b), rtol=1e-9, atol=1e-14
    )


@pytest.mark.parametrize("fmt", [FX64, FX32])
def test_blocked_kernel_matches_quantized(fmt):
    p, batch = 7, 4
    s = _rand((p, p)) / p
    d = _rand((batch, p, p, p))
    u = _rand((batch, p, p, p))
    a = inverse_helmholtz_pallas(s, d, u, fmt=fmt)
    b = inverse_helmholtz_pallas_blocked(s, d, u, fmt=fmt)
    np.testing.assert_allclose(
        np.asarray(a), np.asarray(b), rtol=1e-9, atol=1e-12
    )


def test_blocked_kernel_matches_ref():
    p, batch = 7, 6
    s = _rand((p, p))
    d = _rand((batch, p, p, p))
    u = _rand((batch, p, p, p))
    got = inverse_helmholtz_pallas_blocked(s, d, u)
    want = ref.inverse_helmholtz_batch(s, d, u)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-11)


# ---------------------------------------------------------------------------
# Interpolation
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("m,n", [(11, 11), (7, 11), (11, 7), (3, 5)])
def test_interpolation_matches_ref(m, n):
    batch = 3
    a = _rand((m, n))
    u = _rand((batch, n, n, n))
    got = interpolation_pallas(a, u)
    want = ref.interpolation_batch(a, u)
    assert got.shape == (batch, m, m, m)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-12)


def test_interpolation_identity():
    n, batch = 6, 2
    u = _rand((batch, n, n, n))
    got = interpolation_pallas(np.eye(n), u)
    np.testing.assert_allclose(np.asarray(got), u, rtol=1e-14)


@settings(max_examples=15, deadline=None)
@given(
    m=st.integers(min_value=2, max_value=8),
    n=st.integers(min_value=2, max_value=8),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_interpolation_hypothesis_sweep(m, n, seed):
    rng = np.random.default_rng(seed)
    a = rng.uniform(-1, 1, (m, n))
    u = rng.uniform(-1, 1, (2, n, n, n))
    got = interpolation_pallas(a, u)
    want = ref.interpolation_batch(a, u)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-11)


# ---------------------------------------------------------------------------
# Gradient
# ---------------------------------------------------------------------------


def test_gradient_matches_ref_paper_dims():
    nx, ny, nz, batch = 8, 7, 6, 4
    dx, dy, dz = _rand((nx, nx)), _rand((ny, ny)), _rand((nz, nz))
    u = _rand((batch, nx, ny, nz))
    gx, gy, gz = gradient_pallas(dx, dy, dz, u)
    wx, wy, wz = ref.gradient_batch(dx, dy, dz, u)
    for got, want in ((gx, wx), (gy, wy), (gz, wz)):
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(want), rtol=1e-12
        )


def test_gradient_of_constant_is_zero():
    """Derivative matrices annihilate constants: rows sum to 0."""
    nx, ny, nz = 5, 4, 3
    # build matrices with zero row sums
    def zrows(n):
        m = _rand((n, n))
        return m - m.mean(axis=1, keepdims=True)

    dx, dy, dz = zrows(nx), zrows(ny), zrows(nz)
    u = np.ones((2, nx, ny, nz))
    gx, gy, gz = gradient_pallas(dx, dy, dz, u)
    for g in (gx, gy, gz):
        np.testing.assert_allclose(np.asarray(g), 0.0, atol=1e-13)


@settings(max_examples=15, deadline=None)
@given(
    nx=st.integers(min_value=2, max_value=8),
    ny=st.integers(min_value=2, max_value=8),
    nz=st.integers(min_value=2, max_value=8),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_gradient_hypothesis_sweep(nx, ny, nz, seed):
    rng = np.random.default_rng(seed)
    dx = rng.uniform(-1, 1, (nx, nx))
    dy = rng.uniform(-1, 1, (ny, ny))
    dz = rng.uniform(-1, 1, (nz, nz))
    u = rng.uniform(-1, 1, (2, nx, ny, nz))
    got = gradient_pallas(dx, dy, dz, u)
    want = ref.gradient_batch(dx, dy, dz, u)
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w), rtol=1e-11)


# ---------------------------------------------------------------------------
# FLOP model (paper Eq. 2)
# ---------------------------------------------------------------------------


def test_flops_per_element_paper_values():
    assert ref.helmholtz_flops_per_element(11) == 177_023
    assert ref.helmholtz_flops_per_element(7) == 29_155
