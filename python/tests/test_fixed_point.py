"""Fixed-point (fake-quantized) numerics: paper §3.6.4 / §4.2 MSE claims.

Paper: Fixed Point 64 (Q24.40) MSE = 9.39e-22; Fixed Point 32 (Q8.24)
MSE = 3.58e-12 vs double, for inputs rescaled to [-1, 1]. Our fake
quantization rounds at operator granularity (not per-MAC) so measured
MSE bounds the paper's from below; the headline *ratio*
MSE(fx32)/MSE(fx64) ~ 2^32 must hold.
"""

import jax

jax.config.update("jax_enable_x64", True)

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import FX32, FX64, inverse_helmholtz_pallas, quantize, ref
from compile.kernels.quant import FORMATS, FixedFormat

RNG = np.random.default_rng(7)


def _unit(shape):
    return RNG.uniform(-1.0, 1.0, size=shape)


# ---------------------------------------------------------------------------
# quantize()
# ---------------------------------------------------------------------------


def test_quantize_grid_exactness_fx32():
    # Q8.24 grid points must round-trip exactly through the f64 carrier.
    k = np.array([-(2**31), -1, 0, 1, 2**31 - 1], dtype=np.float64)
    x = k / FX32.scale
    np.testing.assert_array_equal(np.asarray(quantize(x, FX32)), x)


def test_quantize_rounds_to_nearest():
    step = 1.0 / FX32.scale
    x = np.array([0.26 * step, 0.74 * step])
    got = np.asarray(quantize(x, FX32))
    np.testing.assert_allclose(got, [0.0, step], atol=0)


def test_quantize_saturates():
    big = np.array([1e9, -1e9])
    got = np.asarray(quantize(big, FX32))
    assert got[0] == pytest.approx(FX32.max_value)
    assert got[1] == pytest.approx(FX32.min_value)


def test_format_properties():
    assert FX64.total_bits == 64 and FX32.total_bits == 32
    assert FX64.name == "q24_40" and FX32.name == "q8_24"
    assert FX32.max_value < 128.0 and FX32.min_value == -128.0


@settings(max_examples=30, deadline=None)
@given(
    seed=st.integers(min_value=0, max_value=2**31 - 1),
    fmt_name=st.sampled_from(["fx64", "fx32"]),
)
def test_quantize_error_bounded_by_half_step(seed, fmt_name):
    fmt: FixedFormat = FORMATS[fmt_name]
    rng = np.random.default_rng(seed)
    x = rng.uniform(-1, 1, 64)
    err = np.abs(np.asarray(quantize(x, fmt)) - x)
    assert np.all(err <= 0.5 / fmt.scale + 1e-18)


def test_quantize_idempotent():
    x = _unit(100)
    q1 = np.asarray(quantize(x, FX32))
    q2 = np.asarray(quantize(q1, FX32))
    np.testing.assert_array_equal(q1, q2)


# ---------------------------------------------------------------------------
# End-to-end MSE through the Helmholtz kernel (paper §4.2)
# ---------------------------------------------------------------------------


def _mse(p, fmt, batch=8):
    s = _unit((p, p))
    d = _unit((batch, p, p, p))
    u = _unit((batch, p, p, p))
    exact = np.asarray(ref.inverse_helmholtz_batch(s, d, u))
    fx = np.asarray(inverse_helmholtz_pallas(s, d, u, fmt=fmt))
    return float(np.mean((exact - fx) ** 2))


def test_fx64_mse_tiny():
    mse = _mse(11, FX64)
    # Paper: 9.39e-22 (per-MAC rounding). Operator-granularity rounding
    # bounds it from below; anything <= 1e-20 preserves the claim.
    assert 0.0 < mse < 1e-20


def test_fx32_mse_small():
    mse = _mse(11, FX32)
    # Paper: 3.58e-12.
    assert 1e-18 < mse < 1e-10


def test_fx_ratio_is_about_2_to_32():
    """MSE scales with step^2; step ratio is 2^16 so MSE ratio ~ 2^32."""
    m64 = _mse(7, FX64)
    m32 = _mse(7, FX32)
    ratio = m32 / m64
    assert 2**26 < ratio < 2**38


def test_fx32_preserves_shape_of_solution():
    """Quantized output stays within float tolerance of the exact op."""
    p, batch = 7, 4
    s, d, u = _unit((p, p)), _unit((batch, p, p, p)), _unit((batch, p, p, p))
    exact = np.asarray(ref.inverse_helmholtz_batch(s, d, u))
    fx = np.asarray(inverse_helmholtz_pallas(s, d, u, fmt=FX32))
    np.testing.assert_allclose(fx, exact, atol=1e-4)
