//! hbmflow binary: the L3 coordinator CLI. All logic lives in the
//! library (`hbmflow::cli`) so it is unit-testable.

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    match hbmflow::cli::main_with_args(&argv) {
        Ok(out) => println!("{out}"),
        Err(e) => {
            eprintln!("error: {e:#}");
            std::process::exit(1);
        }
    }
}
