//! Resource estimation (substitutes the Vitis HLS synthesis report).
//!
//! Calibration provenance: per-operator DSP costs are the standard
//! Xilinx UltraScale+ floating-point/integer IP figures; LUT/FF costs
//! and infrastructure terms are fitted against the paper's own Table 3
//! synthesis reports (see DESIGN.md). DSP counts land within ~2% of the
//! paper rows; LUT/FF within ~15-30%; BRAM/URAM reproduce the paper's
//! qualitative switches (URAM -> 0 below the 8 KiB eligibility bound,
//! fx32 BRAM blow-up from lane doubling).

use crate::datatype::DataType;
use crate::olympus::SystemSpec;
use crate::platform::Resources;

/// Per-operator implementation cost.
#[derive(Debug, Clone, Copy)]
pub struct OpCost {
    pub dsp: u64,
    pub lut: u64,
    pub ff: u64,
}

/// Multiplier cost by data type (UltraScale+ IP figures).
pub fn mult_cost(d: DataType) -> OpCost {
    match d {
        DataType::F64 => OpCost {
            dsp: 10,
            lut: 430,
            ff: 700,
        },
        DataType::F32 => OpCost {
            dsp: 3,
            lut: 250,
            ff: 400,
        },
        // 64x64 fixed multiplier: 16 DSP48E2 partial products
        DataType::Fx64 => OpCost {
            dsp: 16,
            lut: 150,
            ff: 260,
        },
        DataType::Fx32 => OpCost {
            dsp: 4,
            lut: 80,
            ff: 140,
        },
    }
}

/// Adder cost by data type.
pub fn add_cost(d: DataType) -> OpCost {
    match d {
        DataType::F64 => OpCost {
            dsp: 1,
            lut: 520,
            ff: 750,
        },
        DataType::F32 => OpCost {
            dsp: 1,
            lut: 320,
            ff: 440,
        },
        // fixed adds are pure carry chains
        DataType::Fx64 => OpCost {
            dsp: 0,
            lut: 64,
            ff: 130,
        },
        DataType::Fx32 => OpCost {
            dsp: 0,
            lut: 32,
            ff: 70,
        },
    }
}

/// LUT cost of a fixed-point multiplier implemented without DSPs
/// (the paper's `#pragma HLS allocation` shift, §4.2).
pub fn lut_mult_cost(d: DataType) -> u64 {
    match d {
        DataType::Fx64 => 2_900,
        DataType::Fx32 => 820,
        _ => 0,
    }
}

/// Static-region (shell) resources: PCIe DMA, HBM controller glue,
/// clocking. Counted once per design, matching how the paper's Table 3
/// percentages include the platform region.
pub fn shell() -> Resources {
    Resources {
        lut: 98_000,
        ff: 160_000,
        bram: 80,
        uram: 0,
        dsp: 4,
    }
}

/// Infrastructure terms (fitted; see module docs).
const CU_BASE_LUT: u64 = 12_000;
const CU_BASE_FF: u64 = 18_000;
const AXI_PORT_LUT: u64 = 6_000;
const AXI_PORT_FF: u64 = 8_000;
const AXI_PORT_DSP: u64 = 7; // address generation
/// Per dataflow-module control/stream logic; scales with the data width.
const MODULE_LUT_PER_BIT: u64 = 36; // 64-bit lane -> ~2.3k LUT
const MODULE_FF_PER_BIT: u64 = 53;
const PACKING_LUT_PER_LANE: u64 = 6_000; // wide-bus (de)packing
const PACKING_FF_PER_LANE: u64 = 8_000;
const SERIAL_ALIGN_LUT: u64 = 22_000; // paper: serial alignment "complexity"
const SERIAL_ALIGN_FF: u64 = 26_000;

/// On-chip memory for one lane's kernel instance:
/// (bram_halves, uram, lutram_lut).
///
/// Everything comes from the `mnemosyne::MemoryPlan` Olympus attached
/// to the spec — per-group buffered copies, lifetime-shared banks,
/// partition factors from the affine access analysis, and stream FIFO
/// depths. (The old private `partitions_for` heuristic that re-derived
/// factors here is retired; see DESIGN.md "On-chip memory plan".)
fn lane_memory(spec: &SystemSpec) -> (u64, u64, u64) {
    let mut bram_halves = 0u64;
    let mut uram = 0u64;
    let mut lutram = 0u64;
    for a in &spec.memory.arrays {
        let (b, u, l) = a.footprint();
        bram_halves += b;
        uram += u;
        lutram += l;
    }
    // reuse-aware scratchpads fronting indexed buffers (empty under
    // the bypass scheme and on dense kernels)
    for c in &spec.memory.caches {
        let (b, u, l) = c.footprint();
        bram_halves += b;
        uram += u;
        lutram += l;
    }
    bram_halves += spec.memory.fifo_bram_halves();
    (bram_halves, uram, lutram)
}

/// Resources of one CU.
pub fn per_cu(spec: &SystemSpec) -> Resources {
    let (mults, adds) = super::count_ops(spec);
    let dtype = spec.dtype;
    let mc = mult_cost(dtype);
    let ac = add_cost(dtype);

    // paper §4.2: one of the seven modules' fixed multipliers shifted to
    // LUTs to relieve DSP pressure
    let groups = spec.schedule.num_groups().max(1) as u64;
    let shifted_mults = if spec.opts.lut_mult_shift && dtype.is_fixed() {
        (mults as u64) / groups
    } else {
        0
    };
    let dsp_mults = mults as u64 - shifted_mults;

    let mut lut = CU_BASE_LUT
        + dsp_mults * mc.lut
        + shifted_mults * lut_mult_cost(dtype)
        + adds as u64 * ac.lut;
    let mut ff = CU_BASE_FF + mults as u64 * mc.ff + adds as u64 * ac.ff;
    let mut dsp = dsp_mults * mc.dsp + adds as u64 * ac.dsp;

    // AXI ports
    let ports = spec.channels[0].all().len() as u64;
    lut += ports * AXI_PORT_LUT;
    ff += ports * AXI_PORT_FF;
    dsp += ports * AXI_PORT_DSP;

    // dataflow modules: per lane, each compute group + read + write
    let modules = if spec.dataflow {
        spec.lanes as u64 * (groups + 2)
    } else {
        3 // read / flat compute / write phases
    };
    lut += modules * MODULE_LUT_PER_BIT * spec.dtype.bits() as u64;
    ff += modules * MODULE_FF_PER_BIT * spec.dtype.bits() as u64;

    // wide-bus packing logic
    if spec.bus_bits > 64 {
        if spec.serial_packing {
            lut += SERIAL_ALIGN_LUT;
            ff += SERIAL_ALIGN_FF;
        } else {
            lut += spec.lanes as u64 * PACKING_LUT_PER_LANE;
            ff += spec.lanes as u64 * PACKING_FF_PER_LANE;
        }
    }

    let (bram_halves, uram_lane, lutram_lane) = lane_memory(spec);
    // AXI interconnect + burst buffers per CU (fitted to the constant
    // ~160-250 BRAM floor of every Table 3 row).
    let infra_bram = 90 + 16 * ports;
    let bram = (bram_halves * spec.lanes as u64).div_ceil(2) + infra_bram;
    let uram = uram_lane * spec.lanes as u64;
    lut += lutram_lane * spec.lanes as u64;

    Resources {
        lut,
        ff,
        bram,
        uram,
        dsp,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datatype::DataType;
    use crate::dsl;
    use crate::hls::estimate;
    use crate::ir::{lower, rewrite, teil};
    use crate::olympus::{generate, OlympusOpts};
    use crate::platform::Platform;

    fn spec_p(p: usize, opts: OlympusOpts) -> SystemSpec {
        let prog = dsl::parse(&dsl::inverse_helmholtz_source(p)).unwrap();
        let m = rewrite::optimize(teil::from_ast(&prog).unwrap());
        let k = lower::lower_kernel(&m, "helmholtz").unwrap();
        generate(&k, &opts, &Platform::alveo_u280()).unwrap()
    }

    fn total(p: usize, opts: OlympusOpts) -> Resources {
        let platform = Platform::alveo_u280();
        estimate(&spec_p(p, opts), &platform).total
    }

    fn within(value: u64, paper: u64, tol: f64) -> bool {
        let v = value as f64;
        let p = paper as f64;
        (v - p).abs() / p <= tol
    }

    #[test]
    fn dsp_tracks_paper_table3_closely() {
        // Paper Table 3 DSP column; DSP is the most mechanical resource.
        assert!(within(total(11, OlympusOpts::baseline()).dsp, 150, 0.15));
        assert!(within(total(11, OlympusOpts::dataflow(1)).dsp, 592, 0.35));
        assert!(within(total(11, OlympusOpts::dataflow(2)).dsp, 1068, 0.15));
        assert!(within(total(11, OlympusOpts::dataflow(3)).dsp, 1096, 0.15));
        assert!(within(total(11, OlympusOpts::dataflow(7)).dsp, 3016, 0.10));
        assert!(within(
            total(11, OlympusOpts::fixed_point(DataType::Fx64)).dsp,
            4368,
            0.10
        ));
        assert!(within(
            total(11, OlympusOpts::fixed_point(DataType::Fx32)).dsp,
            2294,
            0.15
        ));
    }

    #[test]
    fn lut_grows_monotonically_along_the_ladder() {
        let ladder = [
            OlympusOpts::baseline(),
            OlympusOpts::dataflow(1),
            OlympusOpts::dataflow(2),
            OlympusOpts::dataflow(7),
        ];
        let luts: Vec<u64> = ladder.iter().map(|o| total(11, o.clone()).lut).collect();
        assert!(luts.windows(2).all(|w| w[0] < w[1]), "{luts:?}");
    }

    #[test]
    fn lut_magnitudes_track_table3_loosely() {
        assert!(within(total(11, OlympusOpts::baseline()).lut, 141_137, 0.30));
        assert!(within(
            total(11, OlympusOpts::dataflow(7)).lut,
            473_743,
            0.30
        ));
    }

    #[test]
    fn uram_zero_below_eligibility() {
        // Paper Table 4: every p=7 row and the fx32 rows have URAM = 0.
        assert_eq!(total(7, OlympusOpts::dataflow(7)).uram, 0);
        assert_eq!(
            total(7, OlympusOpts::fixed_point(DataType::Fx64)).uram,
            0
        );
        assert_eq!(
            total(11, OlympusOpts::fixed_point(DataType::Fx32)).uram,
            0,
            "fx32 arrays are 5.3 KiB — too small for URAM"
        );
        assert!(total(11, OlympusOpts::dataflow(7)).uram > 0);
    }

    #[test]
    fn fx32_bram_blows_up_vs_fx64() {
        // Paper: "The BRAM increased by about four times while the URAM
        // decreased to zero."
        let b64 = total(11, OlympusOpts::fixed_point(DataType::Fx64)).bram;
        let b32 = total(11, OlympusOpts::fixed_point(DataType::Fx32)).bram;
        assert!(
            b32 as f64 > 1.8 * b64 as f64,
            "fx32 {b32} vs fx64 {b64}"
        );
    }

    #[test]
    fn mem_sharing_cuts_uram() {
        // Paper Table 3: Mem Sharing reduces URAM 240 -> 124 (-48%) on
        // the 1-compute dataflow variant.
        let no = total(11, OlympusOpts::dataflow(1));
        let yes = total(11, OlympusOpts::mem_sharing());
        assert!(
            (yes.uram as f64) < 0.8 * no.uram as f64,
            "sharing {} vs none {}",
            yes.uram,
            no.uram
        );
        assert!(yes.bram <= no.bram);
        assert_eq!(yes.dsp, no.dsp, "sharing must not change the datapath");
    }

    #[test]
    fn lut_mult_shift_trades_dsp_for_lut() {
        let mut o = OlympusOpts::fixed_point(DataType::Fx64);
        let base = total(11, o.clone());
        o.lut_mult_shift = true;
        let shifted = total(11, o);
        assert!(shifted.dsp < base.dsp);
        assert!(shifted.lut > base.lut);
    }

    #[test]
    fn smaller_fifos_cut_bram() {
        let full = total(11, OlympusOpts::dataflow(7));
        let small = total(11, OlympusOpts::dataflow(7).with_fifo_depth(64));
        assert!(small.bram < full.bram);
    }

    #[test]
    fn partition_cap_cuts_uram_banks() {
        // capping the factor below the p=11 reduction trip provisions
        // fewer URAM banks per tensor — the resource side of the
        // bank-conflict trade the dse memory axis explores
        let full = total(11, OlympusOpts::dataflow(7));
        let capped = total(11, OlympusOpts::dataflow(7).with_partition_cap(4));
        assert!(
            capped.uram < full.uram / 2,
            "capped {} vs full {}",
            capped.uram,
            full.uram
        );
        assert_eq!(capped.dsp, full.dsp, "the datapath is untouched");
    }

    #[test]
    fn resources_and_plan_agree_on_banks() {
        // the estimator consumes the plan verbatim: URAM count equals
        // lanes x the plan's URAM-array bank total
        let s = spec_p(11, OlympusOpts::dataflow(7));
        let planned: u64 = s
            .memory
            .arrays
            .iter()
            .map(|a| a.footprint().1)
            .sum();
        assert_eq!(per_cu(&s).uram, planned * s.lanes as u64);
    }

    #[test]
    fn p7_uses_fewer_resources_than_p11() {
        let r11 = total(11, OlympusOpts::dataflow(7));
        let r7 = total(7, OlympusOpts::dataflow(7));
        assert!(r7.lut < r11.lut);
        assert!(r7.dsp < r11.dsp);
    }
}
