//! Resource estimation (substitutes the Vitis HLS synthesis report).
//!
//! Calibration provenance: per-operator DSP costs are the standard
//! Xilinx UltraScale+ floating-point/integer IP figures; LUT/FF costs
//! and infrastructure terms are fitted against the paper's own Table 3
//! synthesis reports (see DESIGN.md). DSP counts land within ~2% of the
//! paper rows; LUT/FF within ~15-30%; BRAM/URAM reproduce the paper's
//! qualitative switches (URAM -> 0 below the 8 KiB eligibility bound,
//! fx32 BRAM blow-up from lane doubling).

use crate::datatype::DataType;
use crate::ir::affine::NestKind;
use crate::olympus::SystemSpec;
use crate::platform::Resources;

/// Per-operator implementation cost.
#[derive(Debug, Clone, Copy)]
pub struct OpCost {
    pub dsp: u64,
    pub lut: u64,
    pub ff: u64,
}

/// Multiplier cost by data type (UltraScale+ IP figures).
pub fn mult_cost(d: DataType) -> OpCost {
    match d {
        DataType::F64 => OpCost {
            dsp: 10,
            lut: 430,
            ff: 700,
        },
        DataType::F32 => OpCost {
            dsp: 3,
            lut: 250,
            ff: 400,
        },
        // 64x64 fixed multiplier: 16 DSP48E2 partial products
        DataType::Fx64 => OpCost {
            dsp: 16,
            lut: 150,
            ff: 260,
        },
        DataType::Fx32 => OpCost {
            dsp: 4,
            lut: 80,
            ff: 140,
        },
    }
}

/// Adder cost by data type.
pub fn add_cost(d: DataType) -> OpCost {
    match d {
        DataType::F64 => OpCost {
            dsp: 1,
            lut: 520,
            ff: 750,
        },
        DataType::F32 => OpCost {
            dsp: 1,
            lut: 320,
            ff: 440,
        },
        // fixed adds are pure carry chains
        DataType::Fx64 => OpCost {
            dsp: 0,
            lut: 64,
            ff: 130,
        },
        DataType::Fx32 => OpCost {
            dsp: 0,
            lut: 32,
            ff: 70,
        },
    }
}

/// LUT cost of a fixed-point multiplier implemented without DSPs
/// (the paper's `#pragma HLS allocation` shift, §4.2).
pub fn lut_mult_cost(d: DataType) -> u64 {
    match d {
        DataType::Fx64 => 2_900,
        DataType::Fx32 => 820,
        _ => 0,
    }
}

/// Static-region (shell) resources: PCIe DMA, HBM controller glue,
/// clocking. Counted once per design, matching how the paper's Table 3
/// percentages include the platform region.
pub fn shell() -> Resources {
    Resources {
        lut: 98_000,
        ff: 160_000,
        bram: 80,
        uram: 0,
        dsp: 4,
    }
}

/// Infrastructure terms (fitted; see module docs).
const CU_BASE_LUT: u64 = 12_000;
const CU_BASE_FF: u64 = 18_000;
const AXI_PORT_LUT: u64 = 6_000;
const AXI_PORT_FF: u64 = 8_000;
const AXI_PORT_DSP: u64 = 7; // address generation
/// Per dataflow-module control/stream logic; scales with the data width.
const MODULE_LUT_PER_BIT: u64 = 36; // 64-bit lane -> ~2.3k LUT
const MODULE_FF_PER_BIT: u64 = 53;
const PACKING_LUT_PER_LANE: u64 = 6_000; // wide-bus (de)packing
const PACKING_FF_PER_LANE: u64 = 8_000;
const SERIAL_ALIGN_LUT: u64 = 22_000; // paper: serial alignment "complexity"
const SERIAL_ALIGN_FF: u64 = 26_000;

/// URAM eligibility threshold: Vitis maps arrays to URAM only when they
/// are large enough; 8 KiB reproduces the paper's switches (p=11 doubles
/// -> URAM; p=7 or 32-bit -> BRAM; Tables 3-4).
const URAM_MIN_BYTES: u64 = 8 * 1024;
/// Below this, arrays land in LUTRAM (distributed memory), not BRAM.
const LUTRAM_MAX_BYTES: u64 = 2 * 1024;
/// BRAM36 tile: 4 KiB payload; a half tile (BRAM18) holds 2 KiB.
const BRAM_TILE_BYTES: u64 = 4 * 1024;

/// Storage mapping of one array instance: (bram_halves, uram, lutram_lut).
///
/// Partitioned (unroll-cyclic) arrays map each bank independently; banks
/// of URAM-eligible arrays stay in URAM (this is what produces the
/// paper's URAM 240/252 counts for the p=11 double dataflow variants),
/// while small banks pack into BRAM18 halves.
fn map_array(bytes: u64, partitions: u64) -> (u64, u64, u64) {
    let parts = partitions.max(1);
    if bytes >= URAM_MIN_BYTES {
        return (0, parts, 0);
    }
    if bytes < LUTRAM_MAX_BYTES {
        // distributed RAM: ~1 LUT per 64 bits plus addressing
        return (0, 0, bytes / 4 + 32);
    }
    let per_bank = bytes.div_ceil(parts);
    let halves_per_bank = if per_bank <= BRAM_TILE_BYTES / 2 {
        1
    } else {
        2 * per_bank.div_ceil(BRAM_TILE_BYTES)
    };
    (parts * halves_per_bank, 0, 0)
}

/// Buffer partitioning factor: arrays *read* by an unrolled contraction
/// must sustain `red_trip` parallel reads -> cyclic partitioning.
/// (Writes are one element per cycle and need no partitioning.)
fn partitions_for(spec: &SystemSpec, buf: usize) -> u64 {
    spec.kernel
        .nests
        .iter()
        .filter(|n| n.reads.contains(&buf))
        .filter_map(|n| match n.kind {
            NestKind::Contraction { .. } => Some(n.red_trip as u64),
            _ => None,
        })
        .max()
        .unwrap_or(1)
}

/// On-chip memory for one lane's kernel instance:
/// (bram_halves, uram, lutram_lut).
fn lane_memory(spec: &SystemSpec) -> (u64, u64, u64) {
    let k = &spec.kernel;
    let bytes_of = |words: usize| words as u64 * spec.dtype.bytes() as u64;
    let mut bram_halves = 0u64;
    let mut uram = 0u64;
    let mut lutram = 0u64;
    let mut acc = |m: (u64, u64, u64)| {
        bram_halves += m.0;
        uram += m.1;
        lutram += m.2;
    };

    if spec.dataflow && spec.schedule.num_groups() > 1 {
        // Every group buffers each array it reads that is produced
        // outside the group (paper §4.2: "the S array is needed by both
        // modules and must be buffered twice"). The group's last write
        // is streamed out — the *consumer* buffers it.
        for g in &spec.schedule.groups {
            let local: Vec<usize> = g.nests().map(|ni| k.nests[ni].write).collect();
            let mut buffered: Vec<usize> = Vec::new();
            for ni in g.nests() {
                for &r in &k.nests[ni].reads {
                    if !local.contains(&r) && !buffered.contains(&r) {
                        buffered.push(r);
                    }
                }
            }
            for b in buffered {
                acc(map_array(
                    bytes_of(k.buffers[b].words()),
                    partitions_for(spec, b),
                ));
            }
            // intra-group temporaries: writes consumed by a later nest
            // of the same group
            for (pos, ni) in g.nests().enumerate() {
                let w = k.nests[ni].write;
                let read_later = g
                    .nests()
                    .skip(pos + 1)
                    .any(|nj| k.nests[nj].reads.contains(&w));
                if read_later {
                    acc(map_array(
                        bytes_of(k.buffers[w].words()),
                        partitions_for(spec, w),
                    ));
                }
            }
        }
        // inter-group stream FIFOs
        for w in stream_widths(spec) {
            let depth_words = spec.opts.fifo_depth.unwrap_or(w);
            let fifo_bytes = depth_words as u64 * spec.dtype.bytes() as u64;
            bram_halves += if fifo_bytes <= BRAM_TILE_BYTES / 2 {
                1
            } else {
                2 * fifo_bytes.div_ceil(BRAM_TILE_BYTES)
            };
        }
    } else {
        // flat kernel (or 1-group dataflow): every buffer lives once;
        // Mnemosyne sharing applies to the temps.
        match &spec.sharing {
            Some(plan) => {
                for bank in &plan.banks {
                    let parts = bank
                        .residents
                        .iter()
                        .map(|&b| partitions_for(spec, b))
                        .max()
                        .unwrap_or(1);
                    acc(map_array(bytes_of(bank.words), parts));
                }
                for (b, buf) in k.buffers.iter().enumerate() {
                    if buf.kind != crate::ir::affine::BufKind::Temp {
                        acc(map_array(
                            bytes_of(buf.words()),
                            partitions_for(spec, b),
                        ));
                    }
                }
            }
            None => {
                for (b, buf) in k.buffers.iter().enumerate() {
                    acc(map_array(
                        bytes_of(buf.words()),
                        partitions_for(spec, b),
                    ));
                }
            }
        }
    }
    (bram_halves, uram, lutram)
}

/// Width (in words) of each inter-group stream: the producing group's
/// output array.
fn stream_widths(spec: &SystemSpec) -> Vec<usize> {
    let k = &spec.kernel;
    let mut widths = Vec::new();
    for (gi, g) in spec.schedule.groups.iter().enumerate() {
        if gi + 1 == spec.schedule.groups.len() {
            break;
        }
        let last = g.end - 1;
        widths.push(k.buffers[k.nests[last].write].words());
    }
    widths
}

/// Resources of one CU.
pub fn per_cu(spec: &SystemSpec) -> Resources {
    let (mults, adds) = super::count_ops(spec);
    let dtype = spec.dtype;
    let mc = mult_cost(dtype);
    let ac = add_cost(dtype);

    // paper §4.2: one of the seven modules' fixed multipliers shifted to
    // LUTs to relieve DSP pressure
    let groups = spec.schedule.num_groups().max(1) as u64;
    let shifted_mults = if spec.opts.lut_mult_shift && dtype.is_fixed() {
        (mults as u64) / groups
    } else {
        0
    };
    let dsp_mults = mults as u64 - shifted_mults;

    let mut lut = CU_BASE_LUT
        + dsp_mults * mc.lut
        + shifted_mults * lut_mult_cost(dtype)
        + adds as u64 * ac.lut;
    let mut ff = CU_BASE_FF + mults as u64 * mc.ff + adds as u64 * ac.ff;
    let mut dsp = dsp_mults * mc.dsp + adds as u64 * ac.dsp;

    // AXI ports
    let ports = spec.channels[0].all().len() as u64;
    lut += ports * AXI_PORT_LUT;
    ff += ports * AXI_PORT_FF;
    dsp += ports * AXI_PORT_DSP;

    // dataflow modules: per lane, each compute group + read + write
    let modules = if spec.dataflow {
        spec.lanes as u64 * (groups + 2)
    } else {
        3 // read / flat compute / write phases
    };
    lut += modules * MODULE_LUT_PER_BIT * spec.dtype.bits() as u64;
    ff += modules * MODULE_FF_PER_BIT * spec.dtype.bits() as u64;

    // wide-bus packing logic
    if spec.bus_bits > 64 {
        if spec.serial_packing {
            lut += SERIAL_ALIGN_LUT;
            ff += SERIAL_ALIGN_FF;
        } else {
            lut += spec.lanes as u64 * PACKING_LUT_PER_LANE;
            ff += spec.lanes as u64 * PACKING_FF_PER_LANE;
        }
    }

    let (bram_halves, uram_lane, lutram_lane) = lane_memory(spec);
    // AXI interconnect + burst buffers per CU (fitted to the constant
    // ~160-250 BRAM floor of every Table 3 row).
    let infra_bram = 90 + 16 * ports;
    let bram = (bram_halves * spec.lanes as u64).div_ceil(2) + infra_bram;
    let uram = uram_lane * spec.lanes as u64;
    lut += lutram_lane * spec.lanes as u64;

    Resources {
        lut,
        ff,
        bram,
        uram,
        dsp,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datatype::DataType;
    use crate::dsl;
    use crate::hls::estimate;
    use crate::ir::{lower, rewrite, teil};
    use crate::olympus::{generate, OlympusOpts};
    use crate::platform::Platform;

    fn spec_p(p: usize, opts: OlympusOpts) -> SystemSpec {
        let prog = dsl::parse(&dsl::inverse_helmholtz_source(p)).unwrap();
        let m = rewrite::optimize(teil::from_ast(&prog).unwrap());
        let k = lower::lower_kernel(&m, "helmholtz").unwrap();
        generate(&k, &opts, &Platform::alveo_u280()).unwrap()
    }

    fn total(p: usize, opts: OlympusOpts) -> Resources {
        let platform = Platform::alveo_u280();
        estimate(&spec_p(p, opts), &platform).total
    }

    fn within(value: u64, paper: u64, tol: f64) -> bool {
        let v = value as f64;
        let p = paper as f64;
        (v - p).abs() / p <= tol
    }

    #[test]
    fn dsp_tracks_paper_table3_closely() {
        // Paper Table 3 DSP column; DSP is the most mechanical resource.
        assert!(within(total(11, OlympusOpts::baseline()).dsp, 150, 0.15));
        assert!(within(total(11, OlympusOpts::dataflow(1)).dsp, 592, 0.35));
        assert!(within(total(11, OlympusOpts::dataflow(2)).dsp, 1068, 0.15));
        assert!(within(total(11, OlympusOpts::dataflow(3)).dsp, 1096, 0.15));
        assert!(within(total(11, OlympusOpts::dataflow(7)).dsp, 3016, 0.10));
        assert!(within(
            total(11, OlympusOpts::fixed_point(DataType::Fx64)).dsp,
            4368,
            0.10
        ));
        assert!(within(
            total(11, OlympusOpts::fixed_point(DataType::Fx32)).dsp,
            2294,
            0.15
        ));
    }

    #[test]
    fn lut_grows_monotonically_along_the_ladder() {
        let ladder = [
            OlympusOpts::baseline(),
            OlympusOpts::dataflow(1),
            OlympusOpts::dataflow(2),
            OlympusOpts::dataflow(7),
        ];
        let luts: Vec<u64> = ladder.iter().map(|o| total(11, o.clone()).lut).collect();
        assert!(luts.windows(2).all(|w| w[0] < w[1]), "{luts:?}");
    }

    #[test]
    fn lut_magnitudes_track_table3_loosely() {
        assert!(within(total(11, OlympusOpts::baseline()).lut, 141_137, 0.30));
        assert!(within(
            total(11, OlympusOpts::dataflow(7)).lut,
            473_743,
            0.30
        ));
    }

    #[test]
    fn uram_zero_below_eligibility() {
        // Paper Table 4: every p=7 row and the fx32 rows have URAM = 0.
        assert_eq!(total(7, OlympusOpts::dataflow(7)).uram, 0);
        assert_eq!(
            total(7, OlympusOpts::fixed_point(DataType::Fx64)).uram,
            0
        );
        assert_eq!(
            total(11, OlympusOpts::fixed_point(DataType::Fx32)).uram,
            0,
            "fx32 arrays are 5.3 KiB — too small for URAM"
        );
        assert!(total(11, OlympusOpts::dataflow(7)).uram > 0);
    }

    #[test]
    fn fx32_bram_blows_up_vs_fx64() {
        // Paper: "The BRAM increased by about four times while the URAM
        // decreased to zero."
        let b64 = total(11, OlympusOpts::fixed_point(DataType::Fx64)).bram;
        let b32 = total(11, OlympusOpts::fixed_point(DataType::Fx32)).bram;
        assert!(
            b32 as f64 > 1.8 * b64 as f64,
            "fx32 {b32} vs fx64 {b64}"
        );
    }

    #[test]
    fn mem_sharing_cuts_uram() {
        // Paper Table 3: Mem Sharing reduces URAM 240 -> 124 (-48%) on
        // the 1-compute dataflow variant.
        let no = total(11, OlympusOpts::dataflow(1));
        let yes = total(11, OlympusOpts::mem_sharing());
        assert!(
            (yes.uram as f64) < 0.8 * no.uram as f64,
            "sharing {} vs none {}",
            yes.uram,
            no.uram
        );
        assert!(yes.bram <= no.bram);
        assert_eq!(yes.dsp, no.dsp, "sharing must not change the datapath");
    }

    #[test]
    fn lut_mult_shift_trades_dsp_for_lut() {
        let mut o = OlympusOpts::fixed_point(DataType::Fx64);
        let base = total(11, o.clone());
        o.lut_mult_shift = true;
        let shifted = total(11, o);
        assert!(shifted.dsp < base.dsp);
        assert!(shifted.lut > base.lut);
    }

    #[test]
    fn smaller_fifos_cut_bram() {
        let full = total(11, OlympusOpts::dataflow(7));
        let small = total(11, OlympusOpts::dataflow(7).with_fifo_depth(64));
        assert!(small.bram < full.bram);
    }

    #[test]
    fn p7_uses_fewer_resources_than_p11() {
        let r11 = total(11, OlympusOpts::dataflow(7));
        let r7 = total(7, OlympusOpts::dataflow(7));
        assert!(r7.lut < r11.lut);
        assert!(r7.dsp < r11.dsp);
    }
}
