//! Achieved-frequency model (substitutes Vivado place-and-route).
//!
//! Paper §3.5: "When timing is not met, Vitis automatically downscales
//! the execution frequency." We model the achieved fmax as a congestion
//! function of device utilization and SLR spanning, calibrated against
//! the paper's own reports:
//!
//!   | design                  | LUT%  | DSP%  | span | paper fmax |
//!   |-------------------------|-------|-------|------|------------|
//!   | Baseline                | 10.8  |  1.7  | 1    | 274.6      |
//!   | Dataflow (7), double    | 36.4  | 33.4  | 1    | 199.5      |
//!   | Fixed 64                | 19.5  | 48.4  | 1    | 233.8      |
//!   | Double, p=11, 2 CUs     | 58.4  | 66.7  | 2    | 146.0      |
//!
//! A linear congestion model `f = 305 − 2.45·LUT% − 0.5·max(0,DSP%−30)
//! − 0.3·max(0,BRAM%−40) − 9·(span−1)` lands within ~10% of every row
//! while preserving the orderings the evaluation depends on (more
//! resources → lower f; multi-CU collapse; fixed-point frequency gain).

use crate::olympus::SystemSpec;
use crate::platform::{Platform, Resources};

/// Routing ceiling for tiny designs on the HBM-enabled die.
const F_CEILING_MHZ: f64 = 305.0;
const LUT_SLOPE: f64 = 1.42;
const DSP_SLOPE: f64 = 0.50;
const DSP_KNEE: f64 = 30.0;
const BRAM_SLOPE: f64 = 0.30;
const BRAM_KNEE: f64 = 40.0;
const SLR_PENALTY_MHZ: f64 = 9.0;
/// Nothing routes below this on a driven design.
const F_FLOOR_MHZ: f64 = 60.0;

/// Achieved frequency in MHz for a design with `total` resources.
pub fn fmax(
    total: &Resources,
    platform: &Platform,
    spec: &SystemSpec,
    slr_span: usize,
) -> f64 {
    let budget = platform.total_resources();
    let u = total.utilization(&budget);
    let lut_pct = u[0] * 100.0;
    let dsp_pct = u[4] * 100.0;
    let bram_pct = u[2] * 100.0;
    let f_route = F_CEILING_MHZ
        - LUT_SLOPE * lut_pct
        - DSP_SLOPE * (dsp_pct - DSP_KNEE).max(0.0)
        - BRAM_SLOPE * (bram_pct - BRAM_KNEE).max(0.0)
        - SLR_PENALTY_MHZ * (slr_span.saturating_sub(1)) as f64;
    f_route.clamp(F_FLOOR_MHZ, spec.opts.target_freq_mhz)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datatype::DataType;
    use crate::dsl;
    use crate::hls::estimate;
    use crate::ir::{lower, rewrite, teil};
    use crate::olympus::{generate, OlympusOpts};

    fn fmax_of(p: usize, opts: OlympusOpts) -> f64 {
        let prog = dsl::parse(&dsl::inverse_helmholtz_source(p)).unwrap();
        let m = rewrite::optimize(teil::from_ast(&prog).unwrap());
        let k = lower::lower_kernel(&m, "helmholtz").unwrap();
        let platform = Platform::alveo_u280();
        let s = generate(&k, &opts, &platform).unwrap();
        estimate(&s, &platform).fmax_mhz
    }

    #[test]
    fn baseline_lands_near_paper() {
        let f = fmax_of(11, OlympusOpts::baseline());
        // paper: 274.6 MHz
        assert!((240.0..310.0).contains(&f), "{f}");
    }

    #[test]
    fn dataflow7_drops_frequency() {
        let f1 = fmax_of(11, OlympusOpts::dataflow(1));
        let f7 = fmax_of(11, OlympusOpts::dataflow(7));
        assert!(f7 < f1, "more modules route worse: {f7} vs {f1}");
        // paper: 199.5 MHz
        assert!((160.0..260.0).contains(&f7), "{f7}");
    }

    #[test]
    fn fixed64_beats_double_dataflow7() {
        // Paper §4.2: "the simplification of the logic allowing the
        // frequency to be higher" (199.5 -> 233.8 MHz).
        let fd = fmax_of(11, OlympusOpts::dataflow(7));
        let f64_ = fmax_of(11, OlympusOpts::fixed_point(DataType::Fx64));
        assert!(f64_ > fd, "{f64_} vs {fd}");
    }

    #[test]
    fn multi_cu_frequency_collapses() {
        // Paper Table 5: Double p=11 2 CUs -> 146 MHz.
        let f1 = fmax_of(11, OlympusOpts::dataflow(7));
        let f2 = fmax_of(11, OlympusOpts::dataflow(7).with_cus(2));
        assert!(f2 < f1);
        assert!((110.0..200.0).contains(&f2), "{f2}");
    }

    #[test]
    fn never_exceeds_target() {
        let f = fmax_of(7, OlympusOpts::dataflow(7).with_cus(2));
        assert!(f <= 225.0);
        let fb = fmax_of(3, OlympusOpts::baseline());
        assert!(fb <= 450.0);
    }

    #[test]
    fn floor_is_respected() {
        // pathological giant design still returns a usable frequency
        let f = fmax_of(11, OlympusOpts::fixed_point(DataType::Fx32).with_cus(3));
        assert!(f >= F_FLOOR_MHZ);
    }
}
