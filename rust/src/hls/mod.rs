//! HLS resource/timing estimation (substitutes Vitis HLS + P&R; see
//! DESIGN.md "Hardware substitutions").
//!
//! Three layers, each mechanistic with documented calibration constants:
//!
//!  * `ops`       — operator allocation (multipliers/adders per CU) using
//!                  the sharing rules Vitis exhibited in the paper's
//!                  Table 2 (one operator set per dataflow module; wide
//!                  flat buses are memory-port limited to 2+2).
//!  * `resources` — LUT/FF/DSP from per-operator costs; BRAM/URAM read
//!                  off the `mnemosyne::MemoryPlan` on the spec (banked
//!                  arrays, shared banks, FIFO sizing — one source of
//!                  truth with the simulator's conflict model).
//!  * `timing`    — achieved frequency from a congestion model over
//!                  utilization (calibrated against the paper's own
//!                  fmax reports, Tables 3–5).

pub mod resources;
pub mod timing;

use crate::ir::affine::NestKind;
use crate::olympus::SystemSpec;
use crate::platform::{Platform, Resources};

/// Full estimate for a generated system.
#[derive(Debug, Clone)]
pub struct Estimate {
    /// Per-CU operator allocation (Table 2 "# Ops" = mults + adds).
    pub mults: u32,
    pub adds: u32,
    /// Initiation interval of the contraction nests (1 unless the flat
    /// wide-bus port limitation bites; paper §4.2 "II violation").
    pub ii: u32,
    /// Resources of one CU.
    pub per_cu: Resources,
    /// Whole-design resources (CUs + shell).
    pub total: Resources,
    /// Achieved frequency after the routing model (MHz).
    pub fmax_mhz: f64,
    /// SLRs the design spans (paper Challenge 5).
    pub slr_span: usize,
}

impl Estimate {
    pub fn ops(&self) -> u32 {
        self.mults + self.adds
    }

    /// Table 2 "Ideal GFLOPS" = #Ops x f.
    pub fn ideal_gflops(&self) -> f64 {
        self.ops() as f64 * self.fmax_mhz * 1e6 / 1e9
    }

    pub fn utilization(&self, platform: &Platform) -> [f64; 5] {
        self.total.utilization(&platform.total_resources())
    }
}

/// Whether the flat wide-bus configuration limits operator allocation
/// (paper: "the HLS tool used a different local memory type with fewer
/// read ports … only used two adders and two multipliers per kernel").
pub fn port_limited(spec: &SystemSpec) -> bool {
    spec.bus_bits > 64 && !spec.dataflow
}

/// Operator allocation per CU (reproduces Table 2 "# Ops" exactly).
pub fn count_ops(spec: &SystemSpec) -> (u32, u32) {
    if port_limited(spec) {
        // 2 multipliers + 2 adders per kernel, pipelined
        return (2 * spec.lanes as u32, 2 * spec.lanes as u32);
    }
    let k = &spec.kernel;
    let mut mults = 0u32;
    let mut adds = 0u32;
    for g in &spec.schedule.groups {
        // one operator set per dataflow module, shared across its nests
        let mut gm = 0u32;
        let mut ga = 0u32;
        for ni in g.nests() {
            let n = &k.nests[ni];
            match n.kind {
                NestKind::Contraction { .. } => {
                    gm = gm.max(n.multipliers());
                    ga = ga.max(n.adders());
                }
                NestKind::Elementwise(_) => {
                    gm = gm.max(n.multipliers());
                    ga = ga.max(n.adders());
                }
                // a scatter-add carries the assembly accumulator; plain
                // gathers/scatters/permutes move data without arithmetic
                NestKind::Scatter { add: true, .. } => {
                    ga = ga.max(n.adders());
                }
                NestKind::Permute { .. }
                | NestKind::Gather { .. }
                | NestKind::Scatter { add: false, .. } => {}
            }
        }
        mults += gm;
        adds += ga;
    }
    (mults * spec.lanes as u32, adds * spec.lanes as u32)
}

/// Contraction-nest initiation interval.
pub fn initiation_interval(spec: &SystemSpec) -> u32 {
    if !port_limited(spec) {
        return 1;
    }
    // unroll the reduction over the 2 available multipliers
    let red = spec
        .kernel
        .nests
        .iter()
        .filter(|n| matches!(n.kind, NestKind::Contraction { .. }))
        .map(|n| n.red_trip)
        .max()
        .unwrap_or(1) as u32;
    red.div_ceil(2)
}

/// Produce the full estimate for a system on a platform.
pub fn estimate(spec: &SystemSpec, platform: &Platform) -> Estimate {
    let (mults, adds) = count_ops(spec);
    let ii = initiation_interval(spec);
    let per_cu = resources::per_cu(spec);
    let shell = resources::shell();
    let total = shell.add(&per_cu.scale(spec.num_cus as u64));
    let slr_span = platform.slr_span(&total);
    let fmax_mhz = timing::fmax(&total, platform, spec, slr_span);
    Estimate {
        mults,
        adds,
        ii,
        per_cu,
        total,
        fmax_mhz,
        slr_span,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datatype::DataType;
    use crate::dsl;
    use crate::ir::{lower, rewrite, teil};
    use crate::olympus::{generate, OlympusOpts};

    fn spec(opts: OlympusOpts) -> SystemSpec {
        let prog = dsl::parse(&dsl::inverse_helmholtz_source(11)).unwrap();
        let m = rewrite::optimize(teil::from_ast(&prog).unwrap());
        let k = lower::lower_kernel(&m, "helmholtz").unwrap();
        generate(&k, &opts, &Platform::alveo_u280()).unwrap()
    }

    fn ops_of(opts: OlympusOpts) -> u32 {
        let s = spec(opts);
        let (m, a) = count_ops(&s);
        m + a
    }

    #[test]
    fn table2_op_counts_reproduce_exactly() {
        // Paper Table 2, "# Ops" column.
        assert_eq!(ops_of(OlympusOpts::baseline()), 22);
        assert_eq!(ops_of(OlympusOpts::double_buffering()), 22);
        assert_eq!(ops_of(OlympusOpts::bus_serial()), 4);
        assert_eq!(ops_of(OlympusOpts::bus_parallel()), 16);
        assert_eq!(ops_of(OlympusOpts::dataflow(1)), 88);
        assert_eq!(ops_of(OlympusOpts::dataflow(2)), 176);
        assert_eq!(ops_of(OlympusOpts::dataflow(3)), 180);
        assert_eq!(ops_of(OlympusOpts::dataflow(7)), 532);
    }

    #[test]
    fn ii_violation_only_on_flat_wide_bus() {
        assert_eq!(initiation_interval(&spec(OlympusOpts::baseline())), 1);
        assert_eq!(initiation_interval(&spec(OlympusOpts::dataflow(7))), 1);
        let s = spec(OlympusOpts::bus_serial());
        assert!(initiation_interval(&s) > 1, "paper: II raised to ~4-6");
        assert_eq!(initiation_interval(&s), 6); // ceil(11 / 2)
        assert!(port_limited(&s));
        assert!(port_limited(&spec(OlympusOpts::bus_parallel())));
    }

    #[test]
    fn estimate_is_consistent() {
        let platform = Platform::alveo_u280();
        let s = spec(OlympusOpts::dataflow(7));
        let e = estimate(&s, &platform);
        assert_eq!(e.ops(), 532);
        assert!(e.fmax_mhz > 100.0 && e.fmax_mhz <= 450.0);
        assert!(e.total.lut > e.per_cu.lut);
        assert!(e.ideal_gflops() > 0.0);
        assert!(e.slr_span >= 1);
    }

    #[test]
    fn fx32_ops_double_via_eight_lanes() {
        let d = ops_of(OlympusOpts::fixed_point(DataType::Fx64));
        let f = ops_of(OlympusOpts::fixed_point(DataType::Fx32));
        assert_eq!(d, 532);
        assert_eq!(f, 1064, "8 lanes instead of 4");
    }
}
