//! Closed-form makespan bounds — the simulator's fast path.
//!
//! The event timeline in [`event`](super::event) costs O(`n_batches`)
//! per design point; at sweep scale (PR 5 made compilation cached and
//! cheap) it is the cost center. This module bounds the same makespan
//! in O(1) from the identical [`TimelineConfig`] inputs — the per-stage
//! intervals, `hbm::traffic` penalties, and bank-conflict stalls all
//! enter through `t_batch` exactly as they do for the event simulator,
//! so the two modes disagree **only** on how precisely they resolve the
//! batch-level transfer/compute interleaving.
//!
//! ## Bound derivation
//!
//! Write `n = n_batches`, `c = n_cus`, and `chain = t_in + t_batch +
//! t_out`. Rounds are `r = ceil(n / c)`.
//!
//! **Lower bound** (any schedule): every resource must serve its load
//! and the first batch traverses the full chain, so
//! `L = max(n·t_in, n·t_out, r·t_batch, chain)`; without double
//! buffering each CU fully drains one batch before the next input may
//! start, giving the additional term `r·chain`.
//!
//! **Upper bound, double buffering**: let `λ = max(t_in, t_out,
//! t_batch/c)`. Induction over the scheduler's recurrences shows
//! `in_done[b] ≤ (b+1)λ`, `comp_done[b] ≤ (b+1)λ + cλ`, and
//! `out_done[b] ≤ (b+1)λ + (c+1)λ`, hence `U = (n + c + 1)·λ`.
//!
//! **Upper bound, single buffer**: let `λ₁ = max(t_in, t_out,
//! chain/c)`. The same induction gives `in_done[b] ≤ (b+1)λ₁`,
//! `comp_done[b] ≤ (b+1)λ₁ + t_batch`, `out_done[b] ≤ (b+1)λ₁ +
//! t_batch + t_out`, hence `U = n·λ₁ + t_batch + t_out`.
//!
//! **Gap contract**: in every case `L ≥ n·λ` (respectively `n·λ₁`), so
//!
//! ```text
//! rel_gap = U/L − 1  ≤  (c + 1) / n_batches
//! ```
//!
//! — the tolerance `dse` pruning relies on, pinned per point by
//! `tests/sim_differential.rs`. Long timelines (hundreds of batches)
//! have sub-percent bounds; tiny ones (a kernel whose batch swallows
//! the workload in a handful of batches) are loose but still honor the
//! contract, and `dse` falls back to the event simulator exactly when
//! the bounds cannot prove a candidate dominated.
//!
//! Both bounds carry a ±1e-9 relative guard so they also bracket the
//! event simulator's *floating-point* result (its chained additions
//! accumulate at most ~`n` ulps of drift against the closed forms).

use super::event::TimelineConfig;
use super::SimResult;
use crate::hls::Estimate;
use crate::olympus::SystemSpec;
use crate::platform::Platform;

/// Relative guard absorbing the event simulator's float accumulation
/// (≤ ~n ulps ≈ 2e-10 at a million batches) on either bound.
const EPS: f64 = 1e-9;

/// Closed-form bracket on the event timeline's makespan (seconds).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AnalyticBounds {
    pub lower_s: f64,
    pub upper_s: f64,
}

impl AnalyticBounds {
    /// Relative width of the bracket, `upper/lower − 1`. Bounded by
    /// `(n_cus + 1) / n_batches` per the module-level derivation.
    pub fn rel_gap(&self) -> f64 {
        if self.lower_s <= 0.0 {
            if self.upper_s > 0.0 {
                f64::INFINITY
            } else {
                0.0
            }
        } else {
            self.upper_s / self.lower_s - 1.0
        }
    }

    /// Whether a measured makespan falls inside the bracket.
    pub fn brackets(&self, total_s: f64) -> bool {
        self.lower_s <= total_s && total_s <= self.upper_s
    }
}

/// Bound the makespan of a batch timeline in closed form.
pub fn bounds(cfg: &TimelineConfig) -> AnalyticBounds {
    assert!(cfg.n_cus >= 1);
    if cfg.n_batches == 0 {
        return AnalyticBounds { lower_s: 0.0, upper_s: 0.0 };
    }
    let n = cfg.n_batches as f64;
    let c = cfg.n_cus as f64;
    let rounds = cfg.n_batches.div_ceil(cfg.n_cus as u64) as f64;
    let chain = cfg.t_in + cfg.t_batch + cfg.t_out;

    // resource busy times + first-batch chain latency
    let mut lower = (n * cfg.t_in)
        .max(n * cfg.t_out)
        .max(rounds * cfg.t_batch)
        .max(chain);
    let upper = if cfg.double_buffering {
        let lambda = cfg.t_in.max(cfg.t_out).max(cfg.t_batch / c);
        (n + c + 1.0) * lambda
    } else {
        // single slot: each CU drains a full chain per batch
        lower = lower.max(rounds * chain);
        let lambda = cfg.t_in.max(cfg.t_out).max(chain / c);
        n * lambda + cfg.t_batch + cfg.t_out
    };
    AnalyticBounds {
        lower_s: lower * (1.0 - EPS),
        upper_s: upper * (1.0 + EPS),
    }
}

/// Simulate a workload in closed form: same inputs and derived metrics
/// as [`sim::simulate`](super::simulate), but the makespan is the
/// **conservative upper bound** (an analytic result never flatters a
/// design — `dse` pruning depends on that orientation) and the
/// [`SimResult::analytic`] field carries the full bracket.
pub fn simulate_analytic(
    spec: &SystemSpec,
    est: &Estimate,
    platform: &Platform,
    n_elements: u64,
) -> SimResult {
    let (si, cfg) = super::batch_workload(spec, est, platform, n_elements, 1);
    let b = bounds(&cfg);
    let n = cfg.n_batches as f64;
    // busy times have exact closed forms (the event sim accumulates the
    // identical quantities term by term)
    let cu_busy_s =
        cfg.n_batches.div_ceil(cfg.n_cus as u64) as f64 * cfg.t_batch;
    let pcie_busy_s = (n * cfg.t_in).max(n * cfg.t_out);
    let tl = super::event::Timeline {
        total_s: b.upper_s,
        cu_busy_s,
        pcie_busy_s,
        pcie_bound: pcie_busy_s > cu_busy_s,
    };
    let mut r: SimResult = super::finish_sim(spec, est, platform, n_elements, &si, tl);
    r.analytic = Some(b);
    r
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(n: u64, cus: usize, db: bool, t_in: f64, t_b: f64, t_out: f64) -> TimelineConfig {
        TimelineConfig {
            n_batches: n,
            n_cus: cus,
            t_in,
            t_batch: t_b,
            t_out,
            double_buffering: db,
        }
    }

    #[test]
    fn bounds_bracket_the_event_timeline_on_random_workloads() {
        crate::util::prop::check("analytic brackets event", 128, |rng| {
            let c = cfg(
                rng.range_u64(1, 600),
                rng.range_usize(1, 10),
                rng.bool(),
                rng.range_f64(0.0, 2.0),
                rng.range_f64(0.0, 2.0),
                rng.range_f64(0.0, 2.0),
            );
            let t = super::super::event::run_timeline_sequential(c);
            let b = bounds(&c);
            crate::util::prop::assert_prop(
                b.brackets(t.total_s),
                format!("{b:?} misses {} on {c:?}", t.total_s),
            )?;
            // the pinned gap contract
            let contract = (c.n_cus as f64 + 1.0) / c.n_batches as f64;
            crate::util::prop::assert_prop(
                b.rel_gap() <= contract + 1e-6,
                format!("gap {} > contract {contract} on {c:?}", b.rel_gap()),
            )
        });
    }

    #[test]
    fn serial_chain_bounds_are_exact() {
        // 1 CU, no double buffering: the event makespan is exactly
        // n·chain — both bounds collapse onto it (modulo the eps guard)
        let c = cfg(10, 1, false, 1.0, 2.0, 0.5);
        let b = bounds(&c);
        assert!((b.lower_s - 35.0).abs() < 1e-6, "{b:?}");
        assert!(b.upper_s >= 35.0 && b.upper_s < 37.6, "{b:?}");
        assert!(b.brackets(35.0));
    }

    #[test]
    fn empty_workload_bounds_are_zero() {
        let b = bounds(&cfg(0, 3, true, 1.0, 1.0, 1.0));
        assert_eq!(b.lower_s, 0.0);
        assert_eq!(b.upper_s, 0.0);
        assert_eq!(b.rel_gap(), 0.0);
        assert!(b.brackets(0.0));
    }

    #[test]
    fn gap_shrinks_with_batch_count() {
        let g = |n| bounds(&cfg(n, 4, true, 0.5, 2.0, 0.25)).rel_gap();
        assert!(g(1_000) < g(100));
        assert!(g(100) < g(10));
        assert!(g(1_000) < 0.01, "{}", g(1_000));
    }
}
