//! Discrete-event batch timeline (paper §3.6.1, Fig. 14a).
//!
//! Three resource classes pace a workload of `n_batches` dealt
//! round-robin to the CUs:
//!
//!  * **PCIe, per direction** — the link is full duplex: host→HBM input
//!    transfers and HBM→host output transfers ride independent FIFO
//!    queues, so the two directions never contend with each other, and
//!    the *slower* direction sets the transfer pace (for the Helmholtz
//!    kernel that is the input side, which outweighs outputs roughly
//!    3:1). Within a direction, transfers serialize across **all** CUs
//!    in global batch order — the effect that caps multi-CU system
//!    throughput in Fig. 17.
//!  * **CUs** — one compute resource each; a batch occupies its CU for
//!    `t_batch` seconds after its inputs land.
//!  * **buffer slots** — double buffering gives each CU two batch slots
//!    (ping/pong): the input transfer of per-CU batch `j` may start once
//!    batch `j − 2`'s compute has drained its slot, overlapping transfer
//!    with compute; without it the single slot forces the full
//!    in → compute → out chain per batch.
//!
//! The simulation is a deterministic list scheduler over completion
//! times, not an event queue: batches are issued in global order, each
//! taking `max(link free, slot free)` as its transfer start. Outputs are
//! the makespan, the busiest CU's busy time, and the busiest PCIe
//! *direction*'s busy time (`pcie_busy_s` — the quantity `pcie_bound`
//! compares against compute). Property tests pin the lower bounds
//! (no resource beats its busy time; chain latency) and monotonicity in
//! batch count.

/// Timeline inputs (all times in seconds).
#[derive(Debug, Clone, Copy)]
pub struct TimelineConfig {
    pub n_batches: u64,
    pub n_cus: usize,
    pub t_in: f64,
    pub t_batch: f64,
    pub t_out: f64,
    pub double_buffering: bool,
}

/// Timeline outputs.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Timeline {
    /// Wall-clock makespan (system time).
    pub total_s: f64,
    /// Busy time of the most-loaded CU (kernel-only time).
    pub cu_busy_s: f64,
    /// Busy time of the most-loaded PCIe direction.
    pub pcie_busy_s: f64,
    /// True when a PCIe direction is the limiting resource.
    pub pcie_bound: bool,
}

/// Run the discrete-event timeline.
pub fn run_timeline(cfg: TimelineConfig) -> Timeline {
    assert!(cfg.n_cus >= 1);
    let n = cfg.n_batches as usize;
    // Per-batch completion times; batches are dealt round-robin to CUs.
    let mut comp_done: Vec<f64> = vec![0.0; n];
    let mut out_done: Vec<f64> = vec![0.0; n];
    let mut in_done: Vec<f64> = vec![0.0; n];

    // full-duplex PCIe: independent in/out directions, each FIFO
    let mut in_link_free = 0.0f64;
    let mut out_link_free = 0.0f64;
    let mut cu_free = vec![0.0f64; cfg.n_cus];
    let mut cu_busy = vec![0.0f64; cfg.n_cus];
    // per-CU buffer slots: ping/pong when double buffering
    let slots = if cfg.double_buffering { 2usize } else { 1 };

    let mut per_cu_batches: Vec<Vec<usize>> = vec![Vec::new(); cfg.n_cus];
    for b in 0..n {
        per_cu_batches[b % cfg.n_cus].push(b);
    }

    // host enqueues input transfers in global batch order
    for b in 0..n {
        let cu = b % cfg.n_cus;
        let j = b / cfg.n_cus; // per-CU sequence number
        // the CU's buffer slot must be free: with ping/pong the inputs
        // of per-CU batch j reuse the slot of batch j - slots
        let slot_free = if j >= slots {
            let prev = per_cu_batches[cu][j - slots];
            if cfg.double_buffering {
                // input channel reusable once that batch's compute read it
                comp_done[prev]
            } else {
                // single buffer: must be fully drained first
                out_done[prev]
            }
        } else {
            0.0
        };
        let in_start = in_link_free.max(slot_free);
        in_done[b] = in_start + cfg.t_in;
        in_link_free = in_done[b];

        let comp_start = cu_free[cu].max(in_done[b]);
        comp_done[b] = comp_start + cfg.t_batch;
        cu_free[cu] = comp_done[b];
        cu_busy[cu] += cfg.t_batch;

        // output transfer on the return direction
        let out_start = out_link_free.max(comp_done[b]);
        out_done[b] = out_start + cfg.t_out;
        out_link_free = out_done[b];
    }

    let total_s = out_done.iter().copied().fold(0.0, f64::max);
    let cu_busy_s = cu_busy.iter().copied().fold(0.0, f64::max);
    let in_busy = cfg.n_batches as f64 * cfg.t_in;
    let out_busy = cfg.n_batches as f64 * cfg.t_out;
    let pcie_busy_s = in_busy.max(out_busy);
    Timeline {
        total_s,
        cu_busy_s,
        pcie_busy_s,
        pcie_bound: pcie_busy_s > cu_busy_s,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    fn cfg(n: u64, cus: usize, db: bool, t_in: f64, t_b: f64, t_out: f64) -> TimelineConfig {
        TimelineConfig {
            n_batches: n,
            n_cus: cus,
            t_in,
            t_batch: t_b,
            t_out,
            double_buffering: db,
        }
    }

    #[test]
    fn serial_chain_without_double_buffering() {
        // 1 CU, no overlap: makespan = n * (in + batch + out)
        let t = run_timeline(cfg(10, 1, false, 1.0, 2.0, 0.5));
        assert!((t.total_s - 10.0 * 3.5).abs() < 1e-9, "{t:?}");
    }

    #[test]
    fn double_buffering_overlaps_compute_with_transfers() {
        // compute dominates: makespan ~ fill + n*t_batch
        let t = run_timeline(cfg(100, 1, true, 0.2, 2.0, 0.1));
        let ideal = 0.2 + 100.0 * 2.0 + 0.1;
        assert!(t.total_s < ideal * 1.05, "{} vs {ideal}", t.total_s);
        assert!(!t.pcie_bound);
    }

    #[test]
    fn transfer_bound_when_pcie_dominates() {
        let t = run_timeline(cfg(100, 1, true, 2.0, 0.5, 1.0));
        assert!(t.pcie_bound);
        // full duplex: the slow direction (in, 2.0 s) sets the pace
        assert!(t.total_s >= 100.0 * 2.0);
        assert!(t.total_s < 100.0 * 2.6);
    }

    #[test]
    fn multi_cu_compute_scales_but_pcie_serializes() {
        let one = run_timeline(cfg(120, 1, true, 0.5, 2.0, 0.25));
        let four = run_timeline(cfg(120, 4, true, 0.5, 2.0, 0.25));
        // per-CU busy time shrinks 4x
        assert!((four.cu_busy_s - one.cu_busy_s / 4.0).abs() < 1e-9);
        // but the makespan is now pinned by the serialized transfers
        assert!(four.total_s >= four.pcie_busy_s * 0.99);
        assert!(four.total_s < one.total_s, "still some gain");
    }

    #[test]
    fn makespan_lower_bounds() {
        prop::check("timeline lower bounds", 64, |rng| {
            let n = rng.range_u64(1, 40);
            let cus = rng.range_usize(1, 4);
            let db = rng.bool();
            let t_in = rng.range_f64(0.01, 2.0);
            let t_b = rng.range_f64(0.01, 2.0);
            let t_out = rng.range_f64(0.01, 2.0);
            let t = run_timeline(cfg(n, cus, db, t_in, t_b, t_out));
            // no resource can beat its busy time; chain latency bound
            let per_cu = (n as f64 / cus as f64).ceil() * t_b;
            let lower = (n as f64 * t_in.max(t_out))
                .max(per_cu)
                .max(t_in + t_b + t_out);
            prop::assert_prop(
                t.total_s >= lower - 1e-9,
                format!("total {} < lower {}", t.total_s, lower),
            )?;
            // sanity: makespan no worse than fully serial everything
            let serial = n as f64 * (t_in + t_b + t_out);
            prop::assert_prop(
                t.total_s <= serial + 1e-9,
                format!("total {} > serial {}", t.total_s, serial),
            )
        });
    }

    #[test]
    fn monotone_in_batch_count() {
        prop::check("timeline monotonicity", 32, |rng| {
            let cus = rng.range_usize(1, 3);
            let db = rng.bool();
            let t_in = rng.range_f64(0.01, 1.0);
            let t_b = rng.range_f64(0.01, 1.0);
            let t_out = rng.range_f64(0.01, 1.0);
            let n = rng.range_u64(1, 30);
            let a = run_timeline(cfg(n, cus, db, t_in, t_b, t_out));
            let b = run_timeline(cfg(n + 5, cus, db, t_in, t_b, t_out));
            prop::assert_prop(
                b.total_s >= a.total_s,
                format!("{} then {}", a.total_s, b.total_s),
            )
        });
    }

    #[test]
    fn empty_workload_is_zero() {
        let t = run_timeline(cfg(0, 2, true, 1.0, 1.0, 1.0));
        assert_eq!(t.total_s, 0.0);
        assert_eq!(t.cu_busy_s, 0.0);
    }
}
