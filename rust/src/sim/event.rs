//! Discrete-event batch timeline (paper §3.6.1, Fig. 14a).
//!
//! Three resource classes pace a workload of `n_batches` dealt
//! round-robin to the CUs:
//!
//!  * **PCIe, per direction** — the link is full duplex: host→HBM input
//!    transfers and HBM→host output transfers ride independent FIFO
//!    queues, so the two directions never contend with each other, and
//!    the *slower* direction sets the transfer pace (for the Helmholtz
//!    kernel that is the input side, which outweighs outputs roughly
//!    3:1). Within a direction, transfers serialize across **all** CUs
//!    in global batch order — the effect that caps multi-CU system
//!    throughput in Fig. 17.
//!  * **CUs** — one compute resource each; a batch occupies its CU for
//!    `t_batch` seconds after its inputs land.
//!  * **buffer slots** — double buffering gives each CU two batch slots
//!    (ping/pong): the input transfer of per-CU batch `j` may start once
//!    batch `j − 2`'s compute has drained its slot, overlapping transfer
//!    with compute; without it the single slot forces the full
//!    in → compute → out chain per batch.
//!
//! The simulation is a deterministic list scheduler over completion
//! times, not an event queue: batches are issued in global order, each
//! taking `max(link free, slot free)` as its transfer start. Outputs are
//! the makespan, the busiest CU's busy time, and the busiest PCIe
//! *direction*'s busy time (`pcie_busy_s` — the quantity `pcie_bound`
//! compares against compute). Property tests pin the lower bounds
//! (no resource beats its busy time; chain latency) and monotonicity in
//! batch count.
//!
//! ## Sequential and parallel advancement
//!
//! Two interchangeable schedulers advance the same recurrences:
//!
//!  * [`TimelineMode::Sequential`] — the reference single-threaded list
//!    scheduler, one pass over batches in global order with O(`n_cus`)
//!    state (per-CU ping/pong history rings replace the per-batch
//!    completion arrays, so a million-batch timeline allocates nothing
//!    proportional to `n_batches`).
//!  * [`TimelineMode::Parallel`] — the model's only cross-CU coupling is
//!    the two per-direction PCIe FIFOs, so each *round* of `n_cus`
//!    batches splits into three phases: (A) the coordinator drains the
//!    input-direction queue in global batch order, (B) every CU advances
//!    its own compute timeline independently on a worker pool (scoped
//!    threads, the same discipline as `Session::evaluate_batch`), (C)
//!    the coordinator drains the output-direction queue in global batch
//!    order. Phases are separated by barriers; completion times cross
//!    threads as raw `f64` bit patterns in relaxed atomics (the barriers
//!    provide the happens-before edges, the atomics are only transport).
//!
//! Both schedulers execute the **identical sequence of float operations
//! in the identical data-dependency order**, so their results are
//! bit-identical — pinned by the property tests below and by the
//! `SimResult` field-for-field comparison in `tests/sim_differential.rs`.
//! The per-round compute phase is two flops per CU, so the parallel
//! path only amortizes its barrier cost on long many-CU timelines;
//! [`run_timeline`] picks it automatically past
//! [`PARALLEL_MIN_BATCHES`]. For sweep-scale throughput the closed-form
//! bounds in [`sim::analytic`](super::analytic) are the bigger lever.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Barrier, Mutex};

/// Timeline inputs (all times in seconds).
#[derive(Debug, Clone, Copy)]
pub struct TimelineConfig {
    pub n_batches: u64,
    pub n_cus: usize,
    pub t_in: f64,
    pub t_batch: f64,
    pub t_out: f64,
    pub double_buffering: bool,
}

/// Timeline outputs.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Timeline {
    /// Wall-clock makespan (system time).
    pub total_s: f64,
    /// Busy time of the most-loaded CU (kernel-only time).
    pub cu_busy_s: f64,
    /// Busy time of the most-loaded PCIe direction.
    pub pcie_busy_s: f64,
    /// True when a PCIe direction is the limiting resource.
    pub pcie_bound: bool,
}

/// How [`run_timeline_with`] advances the CU timelines. Every mode
/// produces bit-identical [`Timeline`]s; the choice is purely a
/// wall-clock matter.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum TimelineMode {
    /// Pick [`Sequential`](TimelineMode::Sequential) or
    /// [`Parallel`](TimelineMode::Parallel) from the workload shape
    /// (parallel needs `n_cus >= 2` and at least
    /// [`PARALLEL_MIN_BATCHES`] batches to amortize barrier cost).
    #[default]
    Auto,
    /// The reference single-threaded list scheduler.
    Sequential,
    /// Per-CU advancement on scoped worker threads, per-direction
    /// transfer queues merged deterministically by a coordinator.
    Parallel,
}

/// Below this many batches the per-round barrier cost of the parallel
/// scheduler outweighs its two-flops-per-CU compute phase, so
/// [`TimelineMode::Auto`] stays sequential.
pub const PARALLEL_MIN_BATCHES: u64 = 65_536;

/// Run the discrete-event timeline ([`TimelineMode::Auto`]).
pub fn run_timeline(cfg: TimelineConfig) -> Timeline {
    run_timeline_with(cfg, TimelineMode::Auto)
}

/// Run the discrete-event timeline with an explicit scheduler choice.
pub fn run_timeline_with(cfg: TimelineConfig, mode: TimelineMode) -> Timeline {
    match mode {
        TimelineMode::Sequential => run_timeline_sequential(cfg),
        TimelineMode::Parallel => run_timeline_parallel(cfg, None),
        TimelineMode::Auto => {
            if cfg.n_cus >= 2 && cfg.n_batches >= PARALLEL_MIN_BATCHES {
                run_timeline_parallel(cfg, None)
            } else {
                run_timeline_sequential(cfg)
            }
        }
    }
}

/// The reference sequential list scheduler.
pub fn run_timeline_sequential(cfg: TimelineConfig) -> Timeline {
    assert!(cfg.n_cus >= 1);
    let n = cfg.n_batches as usize;
    // per-CU buffer slots: ping/pong when double buffering
    let slots = if cfg.double_buffering { 2usize } else { 1 };

    // full-duplex PCIe: independent in/out directions, each FIFO
    let mut in_link_free = 0.0f64;
    let mut out_link_free = 0.0f64;
    let mut cu_free = vec![0.0f64; cfg.n_cus];
    let mut cu_busy = vec![0.0f64; cfg.n_cus];
    // Per-CU ping/pong history rings: a slot-free test only ever reaches
    // back `slots <= 2` per-CU batches, so two cells per CU replace the
    // per-batch completion arrays. Cell `j % 2` holds per-CU batch j;
    // it is read (as batch j - slots) before being overwritten.
    let mut comp_hist = vec![[0.0f64; 2]; cfg.n_cus];
    let mut out_hist = vec![[0.0f64; 2]; cfg.n_cus];

    // host enqueues input transfers in global batch order
    for b in 0..n {
        let cu = b % cfg.n_cus;
        let j = b / cfg.n_cus; // per-CU sequence number
        // the CU's buffer slot must be free: with ping/pong the inputs
        // of per-CU batch j reuse the slot of batch j - slots
        let slot_free = if j >= slots {
            if cfg.double_buffering {
                // input channel reusable once that batch's compute read it
                comp_hist[cu][(j - slots) % 2]
            } else {
                // single buffer: must be fully drained first
                out_hist[cu][(j - slots) % 2]
            }
        } else {
            0.0
        };
        let in_start = in_link_free.max(slot_free);
        let in_done = in_start + cfg.t_in;
        in_link_free = in_done;

        let comp_start = cu_free[cu].max(in_done);
        let comp_done = comp_start + cfg.t_batch;
        cu_free[cu] = comp_done;
        cu_busy[cu] += cfg.t_batch;
        comp_hist[cu][j % 2] = comp_done;

        // output transfer on the return direction; out_done is
        // nondecreasing in b (each waits on the previous), so the final
        // out_link_free is the makespan
        let out_start = out_link_free.max(comp_done);
        let out_done = out_start + cfg.t_out;
        out_link_free = out_done;
        out_hist[cu][j % 2] = out_done;
    }

    finish(cfg, out_link_free, &cu_busy)
}

/// The parallel scheduler: per-CU compute advancement on `workers`
/// scoped threads (default: available parallelism, clamped to
/// `[1, n_cus]`), per-direction transfer queues merged by the
/// coordinator. Bit-identical to [`run_timeline_sequential`].
pub fn run_timeline_parallel(cfg: TimelineConfig, workers: Option<usize>) -> Timeline {
    assert!(cfg.n_cus >= 1);
    let n = cfg.n_batches as usize;
    if n == 0 {
        return finish(cfg, 0.0, &[0.0]);
    }
    let w = workers
        .unwrap_or_else(|| {
            std::thread::available_parallelism().map_or(1, |p| p.get())
        })
        .clamp(1, cfg.n_cus);
    let slots = if cfg.double_buffering { 2usize } else { 1 };
    let rounds = n.div_ceil(cfg.n_cus);

    // Cross-thread mailboxes, one cell per CU: completion times as raw
    // f64 bit patterns. The two barriers per round order every store
    // before its readers' loads, so Relaxed is transport, not sync.
    let in_done: Vec<AtomicU64> = (0..cfg.n_cus).map(|_| AtomicU64::new(0)).collect();
    let comp_done: Vec<AtomicU64> = (0..cfg.n_cus).map(|_| AtomicU64::new(0)).collect();
    // coordinator + workers meet twice per round: A→B and B→C
    let barrier = Barrier::new(w + 1);
    // per-worker result slots, same discipline as Session::evaluate_batch
    let busy_out: Vec<Mutex<Vec<f64>>> = (0..w).map(|_| Mutex::new(Vec::new())).collect();
    let chunk = cfg.n_cus.div_ceil(w);

    let mut out_link_free = 0.0f64;
    std::thread::scope(|scope| {
        for wi in 0..w {
            let (c0, c1) = (wi * chunk, ((wi + 1) * chunk).min(cfg.n_cus));
            let (barrier, in_done, comp_done, busy_slot) =
                (&barrier, &in_done, &comp_done, &busy_out[wi]);
            scope.spawn(move || {
                let cus = c0..c1; // may be empty; still meets every barrier
                let mut cu_free = vec![0.0f64; cus.len()];
                let mut cu_busy = vec![0.0f64; cus.len()];
                for r in 0..rounds {
                    barrier.wait(); // phase A done: in_done[cu] valid
                    let lo = r * cfg.n_cus;
                    for cu in cus.clone() {
                        if lo + cu >= n {
                            break; // partial last round
                        }
                        let ind = f64::from_bits(in_done[cu].load(Ordering::Relaxed));
                        let comp = cu_free[cu - c0].max(ind) + cfg.t_batch;
                        cu_free[cu - c0] = comp;
                        cu_busy[cu - c0] += cfg.t_batch;
                        comp_done[cu].store(comp.to_bits(), Ordering::Relaxed);
                    }
                    barrier.wait(); // phase B done: comp_done[cu] valid
                }
                *busy_slot.lock().unwrap() = cu_busy;
            });
        }

        // coordinator: both transfer directions, in global batch order
        let mut in_link_free = 0.0f64;
        let mut comp_hist = vec![[0.0f64; 2]; cfg.n_cus];
        let mut out_hist = vec![[0.0f64; 2]; cfg.n_cus];
        for r in 0..rounds {
            let lo = r * cfg.n_cus;
            let in_round = cfg.n_cus.min(n - lo); // CUs with a batch this round
            for cu in 0..in_round {
                // phase A: input-direction FIFO (batch b = lo + cu,
                // per-CU sequence number j = r — identical recurrence
                // to the sequential scheduler)
                let slot_free = if r >= slots {
                    if cfg.double_buffering {
                        comp_hist[cu][(r - slots) % 2]
                    } else {
                        out_hist[cu][(r - slots) % 2]
                    }
                } else {
                    0.0
                };
                let t = in_link_free.max(slot_free) + cfg.t_in;
                in_link_free = t;
                in_done[cu].store(t.to_bits(), Ordering::Relaxed);
            }
            barrier.wait(); // release phase B
            barrier.wait(); // phase B done
            for cu in 0..in_round {
                // phase C: output-direction FIFO
                let comp = f64::from_bits(comp_done[cu].load(Ordering::Relaxed));
                comp_hist[cu][r % 2] = comp;
                let out = out_link_free.max(comp) + cfg.t_out;
                out_link_free = out;
                out_hist[cu][r % 2] = out;
            }
        }
    });

    let cu_busy: Vec<f64> = busy_out
        .iter()
        .flat_map(|s| s.lock().unwrap().clone())
        .collect();
    finish(cfg, out_link_free, &cu_busy)
}

/// Assemble the [`Timeline`] from the makespan and per-CU busy times.
fn finish(cfg: TimelineConfig, total_s: f64, cu_busy: &[f64]) -> Timeline {
    let cu_busy_s = cu_busy.iter().copied().fold(0.0, f64::max);
    let in_busy = cfg.n_batches as f64 * cfg.t_in;
    let out_busy = cfg.n_batches as f64 * cfg.t_out;
    let pcie_busy_s = in_busy.max(out_busy);
    Timeline {
        total_s,
        cu_busy_s,
        pcie_busy_s,
        pcie_bound: pcie_busy_s > cu_busy_s,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    fn cfg(n: u64, cus: usize, db: bool, t_in: f64, t_b: f64, t_out: f64) -> TimelineConfig {
        TimelineConfig {
            n_batches: n,
            n_cus: cus,
            t_in,
            t_batch: t_b,
            t_out,
            double_buffering: db,
        }
    }

    #[test]
    fn serial_chain_without_double_buffering() {
        // 1 CU, no overlap: makespan = n * (in + batch + out)
        let t = run_timeline(cfg(10, 1, false, 1.0, 2.0, 0.5));
        assert!((t.total_s - 10.0 * 3.5).abs() < 1e-9, "{t:?}");
    }

    #[test]
    fn double_buffering_overlaps_compute_with_transfers() {
        // compute dominates: makespan ~ fill + n*t_batch
        let t = run_timeline(cfg(100, 1, true, 0.2, 2.0, 0.1));
        let ideal = 0.2 + 100.0 * 2.0 + 0.1;
        assert!(t.total_s < ideal * 1.05, "{} vs {ideal}", t.total_s);
        assert!(!t.pcie_bound);
    }

    #[test]
    fn transfer_bound_when_pcie_dominates() {
        let t = run_timeline(cfg(100, 1, true, 2.0, 0.5, 1.0));
        assert!(t.pcie_bound);
        // full duplex: the slow direction (in, 2.0 s) sets the pace
        assert!(t.total_s >= 100.0 * 2.0);
        assert!(t.total_s < 100.0 * 2.6);
    }

    #[test]
    fn multi_cu_compute_scales_but_pcie_serializes() {
        let one = run_timeline(cfg(120, 1, true, 0.5, 2.0, 0.25));
        let four = run_timeline(cfg(120, 4, true, 0.5, 2.0, 0.25));
        // per-CU busy time shrinks 4x
        assert!((four.cu_busy_s - one.cu_busy_s / 4.0).abs() < 1e-9);
        // but the makespan is now pinned by the serialized transfers
        assert!(four.total_s >= four.pcie_busy_s * 0.99);
        assert!(four.total_s < one.total_s, "still some gain");
    }

    #[test]
    fn makespan_lower_bounds() {
        prop::check("timeline lower bounds", 64, |rng| {
            let n = rng.range_u64(1, 40);
            let cus = rng.range_usize(1, 4);
            let db = rng.bool();
            let t_in = rng.range_f64(0.01, 2.0);
            let t_b = rng.range_f64(0.01, 2.0);
            let t_out = rng.range_f64(0.01, 2.0);
            let t = run_timeline(cfg(n, cus, db, t_in, t_b, t_out));
            // no resource can beat its busy time; chain latency bound
            let per_cu = (n as f64 / cus as f64).ceil() * t_b;
            let lower = (n as f64 * t_in.max(t_out))
                .max(per_cu)
                .max(t_in + t_b + t_out);
            prop::assert_prop(
                t.total_s >= lower - 1e-9,
                format!("total {} < lower {}", t.total_s, lower),
            )?;
            // sanity: makespan no worse than fully serial everything
            let serial = n as f64 * (t_in + t_b + t_out);
            prop::assert_prop(
                t.total_s <= serial + 1e-9,
                format!("total {} > serial {}", t.total_s, serial),
            )
        });
    }

    #[test]
    fn monotone_in_batch_count() {
        prop::check("timeline monotonicity", 32, |rng| {
            let cus = rng.range_usize(1, 3);
            let db = rng.bool();
            let t_in = rng.range_f64(0.01, 1.0);
            let t_b = rng.range_f64(0.01, 1.0);
            let t_out = rng.range_f64(0.01, 1.0);
            let n = rng.range_u64(1, 30);
            let a = run_timeline(cfg(n, cus, db, t_in, t_b, t_out));
            let b = run_timeline(cfg(n + 5, cus, db, t_in, t_b, t_out));
            prop::assert_prop(
                b.total_s >= a.total_s,
                format!("{} then {}", a.total_s, b.total_s),
            )
        });
    }

    #[test]
    fn empty_workload_is_zero() {
        let t = run_timeline(cfg(0, 2, true, 1.0, 1.0, 1.0));
        assert_eq!(t.total_s, 0.0);
        assert_eq!(t.cu_busy_s, 0.0);
        for mode in [TimelineMode::Sequential, TimelineMode::Parallel] {
            assert_eq!(run_timeline_with(cfg(0, 2, true, 1.0, 1.0, 1.0), mode), t);
        }
    }

    /// Field-for-field bit identity of the two schedulers over random
    /// workload shapes — the tentpole invariant of the parallel queue.
    #[test]
    fn parallel_timeline_is_bit_identical_to_sequential() {
        prop::check("parallel == sequential (bitwise)", 96, |rng| {
            let c = cfg(
                rng.range_u64(0, 500),
                rng.range_usize(1, 12),
                rng.bool(),
                rng.range_f64(0.0, 2.0),
                rng.range_f64(0.0, 2.0),
                rng.range_f64(0.0, 2.0),
            );
            let seq = run_timeline_sequential(c);
            // exercise several pool widths, including degenerate 1
            for workers in [1usize, 2, 3, 8] {
                let par = run_timeline_parallel(c, Some(workers));
                prop::assert_prop(
                    par.total_s.to_bits() == seq.total_s.to_bits()
                        && par.cu_busy_s.to_bits() == seq.cu_busy_s.to_bits()
                        && par.pcie_busy_s.to_bits() == seq.pcie_busy_s.to_bits()
                        && par.pcie_bound == seq.pcie_bound,
                    format!("{workers} workers: {par:?} != {seq:?} on {c:?}"),
                )?;
            }
            Ok(())
        });
    }

    /// The auto gate routes large many-CU workloads to the parallel
    /// scheduler; the result is the same either way (it must be — the
    /// schedulers are bit-identical).
    #[test]
    fn auto_mode_matches_both_schedulers_across_the_gate() {
        for n in [PARALLEL_MIN_BATCHES - 1, PARALLEL_MIN_BATCHES + 1] {
            let c = cfg(n, 4, true, 1e-5, 4e-5, 0.5e-5);
            let auto = run_timeline_with(c, TimelineMode::Auto);
            assert_eq!(auto, run_timeline_sequential(c));
            assert_eq!(auto, run_timeline_parallel(c, Some(2)));
        }
    }
}
