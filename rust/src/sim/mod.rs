//! Cycle-approximate system simulation (substitutes execution on the
//! Alveo U280; see DESIGN.md "Hardware substitutions").
//!
//! Two levels:
//!
//!  * `stages` — per-element cycle intervals of the CU's dataflow stages
//!    (Read, compute groups, Write), mechanistic from the affine IR:
//!    a contraction nest takes `iterations x II` cycles — inflated by
//!    the memory plan's bank-conflict factor when the plan provisions
//!    fewer read ports than the unrolled reduction demands
//!    (`mnemosyne::MemoryPlan::nest_conflict_factor`; zero stalls at
//!    the uncapped default); a group that
//!    randomly accesses an external array first buffers it (the paper's
//!    "data streamed in gets stored in an internal buffer"); elementwise
//!    consumers are stream-order and need no buffering (the paper's
//!    mmult observation). The Read module delivers one word per lane per
//!    cycle (64-bit lanes on the 256-bit AXI port) when its route through
//!    the HBM switch sustains it; turnaround, contention, and
//!    switch-crossing throttles come per-channel from `hbm::traffic`
//!    (no flat direction-switch constant — see DESIGN.md §"Memory
//!    interconnect model" for what replaced it).
//!
//!  * `timeline` — a discrete-event simulation over batches: the PCIe
//!    link is **full duplex** — host→HBM and HBM→host transfers ride
//!    independent per-direction queues, and the *slower direction* sets
//!    the pace (inputs here, which outweigh outputs ~3:1 for the
//!    Helmholtz kernel) — but each direction serializes across all CUs,
//!    the effect that kills multi-CU system throughput in Fig. 17. This
//!    matches the coordinator's host program, which issues `TransferIn`
//!    and `TransferOut` steps on independent queues; each CU is a
//!    resource, and double buffering gives each CU two outstanding batch
//!    slots (ping/pong).
//!
//! One documented fudge factor: `STALL_FACTOR` (dataflow handshake +
//! pipeline fill overheads Vitis reports as a few extra percent; fitted
//! once against the paper's Dataflow-1 row, applied uniformly).

pub mod analytic;
pub mod compose;
pub mod event;
pub mod metrics;

use crate::hbm;
use crate::hls::Estimate;
use crate::ir::affine::NestKind;
use crate::mnemosyne::CacheScheme;
use crate::olympus::SystemSpec;
use crate::platform::{power::PowerModel, Platform};

pub use metrics::SimResult;

/// Uniform dataflow/control overhead factor (see module docs).
pub const STALL_FACTOR: f64 = 1.14;

/// Per-element cycle interval of each CU stage, per lane.
#[derive(Debug, Clone)]
pub struct StageIntervals {
    /// (name, cycles per element)
    pub stages: Vec<(String, u64)>,
    /// Switch round-trip latency the pipeline fills once per batch
    /// (from the same `hbm::traffic` penalty pass that shaped the
    /// stage intervals — kept here so `batch_cycles` never recomputes
    /// or drifts from it).
    pub fill_cycles: u64,
    /// Bank-conflict stall cycles per element, already folded into the
    /// compute-stage intervals: when the memory plan provisions fewer
    /// banks than a nest's unrolled reduction demands, every iteration
    /// takes `ceil(demand / ports)` cycles
    /// (`mnemosyne::MemoryPlan::nest_conflict_factor`), composed with
    /// the port-limited II by max (both serialize the same reads).
    /// Zero at the plan's uncapped default.
    pub conflict_stalls: u64,
}

impl StageIntervals {
    pub fn max_interval(&self) -> u64 {
        self.stages.iter().map(|s| s.1).max().unwrap_or(0)
    }

    pub fn sum(&self) -> u64 {
        self.stages.iter().map(|s| s.1).sum()
    }

    pub fn bottleneck(&self) -> &str {
        // ties resolve to the earliest stage (the read module wins a tie
        // against an equally-long compute group, matching the paper's
        // DF7 observation)
        let mx = self.max_interval();
        self.stages
            .iter()
            .find(|s| s.1 == mx)
            .map(|s| s.0.as_str())
            .unwrap_or("none")
    }
}

/// Compute the per-element stage intervals of the generated CU.
pub fn stages(spec: &SystemSpec, est: &Estimate) -> StageIntervals {
    let k = &spec.kernel;
    let ii = est.ii as u64;
    let in_words = k.input_words() as u64;
    let out_words = k.output_words() as u64;

    let mut stages: Vec<(String, u64)> = Vec::new();

    // Challenge 2 + switch geometry, per channel from the routed map:
    // tWTR/tRTW turnarounds when a CU's directions share a channel,
    // cross-direction contention when dataflow overlaps Read and Write
    // on that channel, and a bandwidth throttle on routes whose switch
    // crossings outrun the outstanding-transaction window.
    let pen = hbm::traffic::stage_penalty(spec);

    // Read module: one word per lane per cycle on the 64-bit lane slice;
    // the serial wide-bus variant re-serializes the packed words into a
    // single kernel's buffers (paper: the optimization *degrades*).
    let read_words = if spec.serial_packing {
        in_words / (spec.bus_bits as u64 / spec.dtype.bits() as u64) + in_words
    } else {
        in_words
    };
    let read = throttle(
        read_words + pen.read_turnaround + pen.read_contention,
        pen.read_slowdown,
    );
    stages.push(("read".into(), read));

    // Bank-conflict stalls: the memory plan provisions each array's
    // parallel-read ports; a nest whose unrolled reduction outruns them
    // (a DSE-capped partition factor) takes `ceil(demand / ports)`
    // cycles per iteration instead of one.
    let mut conflict_stalls = 0u64;

    if spec.dataflow {
        let multi = spec.schedule.num_groups() > 1;
        for (gi, g) in spec.schedule.groups.iter().enumerate() {
            let local: Vec<usize> = g.nests().map(|ni| k.nests[ni].write).collect();
            // arrays this group must buffer before computing: external
            // reads consumed with reuse/random access (contraction or
            // permute nests). Elementwise reads are stream-order.
            let mut fill = 0u64;
            let mut seen: Vec<usize> = Vec::new();
            for ni in g.nests() {
                let n = &k.nests[ni];
                if !n.kind.is_random_access() {
                    continue;
                }
                // indexed nests keep their irregular operand off chip
                // unless the plan fully buffers it: a gather's data
                // array pre-fills only under `FullBuffer` (the index
                // stream is in order), and scatter targets never
                // pre-fill — both directions pay their row-miss price
                // in `hbm::traffic` instead
                let fills: &[usize] = match n.kind {
                    NestKind::Scatter { .. } => &[],
                    NestKind::Gather { .. } => {
                        if spec.opts.cache_scheme == CacheScheme::FullBuffer {
                            &n.reads[..1]
                        } else {
                            &[]
                        }
                    }
                    _ => &n.reads[..],
                };
                for &r in fills {
                    if !local.contains(&r) && !seen.contains(&r) {
                        seen.push(r);
                        fill += k.buffers[r].words() as u64;
                    }
                }
            }
            // the plan's per-group buffered copies serve multi-group
            // schedules; flat/1-group reads hit the global storage
            let plan_group = if multi { Some(gi) } else { None };
            let mut compute = 0u64;
            for ni in g.nests() {
                let cf = spec.memory.nest_conflict_factor(k, ni, plan_group);
                let iters = k.nests[ni].iterations();
                // ports and II serialize the same reads: compose by max
                compute += iters * ii.max(cf);
                conflict_stalls += iters * (ii.max(cf) - ii);
            }
            stages.push((g.name.clone(), fill + compute));
        }
    } else {
        // flat kernel: local buffers are filled by the read phase; the
        // compute phase runs every nest back to back — and it serializes
        // with read/write (no overlap), which `timeline` accounts for by
        // summing the stages instead of pipelining them.
        let mut compute = 0u64;
        for (ni, n) in k.nests.iter().enumerate() {
            let cf = spec.memory.nest_conflict_factor(k, ni, None);
            // a port-limited II (flat wide bus: 2 words/cycle from the
            // local memory) and a bank cap (factor words/cycle from the
            // banks) throttle the same unrolled reads — the slower of
            // the two sets the pace, so they compose by max, never by
            // product
            compute += n.iterations() * ii.max(cf);
            conflict_stalls += n.iterations() * (ii.max(cf) - ii);
        }
        stages.push(("compute".into(), compute));
    }

    let write = throttle(
        out_words + pen.write_turnaround + pen.write_contention,
        pen.write_slowdown,
    );
    stages.push(("write".into(), write));
    StageIntervals {
        stages,
        fill_cycles: pen.fill_cycles,
        conflict_stalls,
    }
}

/// Inflate a stage interval by a switch-crossing bandwidth factor
/// (exact identity at the calibrated local rate of 1.0).
fn throttle(cycles: u64, slowdown: f64) -> u64 {
    (cycles as f64 * slowdown).ceil() as u64
}

/// Cycles for one batch on one CU (all lanes in lockstep). The switch
/// round-trip of the CU's longest route is filled once per batch before
/// the first word lands (`hbm::traffic`).
pub fn batch_cycles(spec: &SystemSpec, si: &StageIntervals) -> u64 {
    let per_lane_elements = (spec.batch_elements / spec.lanes.max(1)) as u64;
    let raw = si.fill_cycles
        + if spec.dataflow {
            // pipelined stages: fill + steady state at the bottleneck
            si.sum() + per_lane_elements.saturating_sub(1) * si.max_interval()
        } else {
            // serial read -> compute -> write per element
            per_lane_elements * si.sum()
        };
    (raw as f64 * STALL_FACTOR) as u64
}

/// Steady-state element service interval of one CU in cycles — the
/// denominator for per-channel utilization.
fn element_interval(spec: &SystemSpec, si: &StageIntervals) -> u64 {
    if spec.dataflow {
        si.max_interval()
    } else {
        si.sum()
    }
}

/// Simulate a full workload of `n_elements` on the generated system.
pub fn simulate(
    spec: &SystemSpec,
    est: &Estimate,
    platform: &Platform,
    n_elements: u64,
) -> SimResult {
    simulate_multi_fpga(spec, est, platform, n_elements, 1)
}

/// [`simulate`] with an explicit event-timeline scheduler choice
/// (sequential vs parallel — bit-identical results either way; the
/// regression pins in `tests/sim_differential.rs` run both). For the
/// closed-form fast path see [`analytic::simulate_analytic`].
pub fn simulate_with_timeline(
    spec: &SystemSpec,
    est: &Estimate,
    platform: &Platform,
    n_elements: u64,
    mode: event::TimelineMode,
) -> SimResult {
    simulate_multi_fpga_with(spec, est, platform, n_elements, 1, mode)
}

/// The paper's §5 what-if: "if the host were interfaced with multiple
/// FPGAs and were able to send data in parallel to all of them,
/// replicating the compute units onto separate FPGAs would achieve
/// increased performance." Each card gets its own full-duplex PCIe link
/// and its own copy of the system; the workload splits evenly.
pub fn simulate_multi_fpga(
    spec: &SystemSpec,
    est: &Estimate,
    platform: &Platform,
    n_elements: u64,
    n_fpgas: u64,
) -> SimResult {
    simulate_multi_fpga_with(
        spec,
        est,
        platform,
        n_elements,
        n_fpgas,
        event::TimelineMode::Auto,
    )
}

/// [`simulate_multi_fpga`] with an explicit timeline scheduler choice.
pub fn simulate_multi_fpga_with(
    spec: &SystemSpec,
    est: &Estimate,
    platform: &Platform,
    n_elements: u64,
    n_fpgas: u64,
    mode: event::TimelineMode,
) -> SimResult {
    let (si, cfg) = batch_workload(spec, est, platform, n_elements, n_fpgas);
    let tl = event::run_timeline_with(cfg, mode);
    // makespan = the busiest card's timeline; all cards process the full
    // workload together
    finish_sim(spec, est, platform, n_elements, &si, tl)
}

/// Shared front half of the event and analytic simulators: per-element
/// stage intervals plus the batch-timeline inputs (batch compute time,
/// per-direction transfer times, per-card batch count).
pub(crate) fn batch_workload(
    spec: &SystemSpec,
    est: &Estimate,
    platform: &Platform,
    n_elements: u64,
    n_fpgas: u64,
) -> (StageIntervals, event::TimelineConfig) {
    assert!(n_fpgas >= 1);
    let si = stages(spec, est);
    let freq_hz = est.fmax_mhz * 1e6;
    let t_batch = batch_cycles(spec, &si) as f64 / freq_hz;

    let e = spec.batch_elements as u64;
    // per-card share (cards run in parallel on independent PCIe links)
    let n_batches = n_elements.div_ceil(e).div_ceil(n_fpgas);
    let t_in = (spec.input_bytes_per_element() * e) as f64
        / platform.pcie_eff_bytes_per_sec;
    let t_out = (spec.output_bytes_per_element() * e) as f64
        / platform.pcie_eff_bytes_per_sec;

    let cfg = event::TimelineConfig {
        n_batches,
        n_cus: spec.num_cus,
        t_in,
        t_batch,
        t_out,
        double_buffering: spec.double_buffering,
    };
    (si, cfg)
}

/// Shared back half: assemble the [`SimResult`] from a timeline (event
/// or analytic) plus the workload-independent power and interconnect
/// reports.
pub(crate) fn finish_sim(
    spec: &SystemSpec,
    est: &Estimate,
    _platform: &Platform,
    n_elements: u64,
    si: &StageIntervals,
    tl: event::Timeline,
) -> SimResult {
    let total_flops = n_elements * spec.flops_per_element();
    let power = PowerModel::default();
    let avg_power_w = power.average_power_w(
        &est.total,
        est.fmax_mhz,
        spec.total_pcs() as u32,
    );
    let hbm_report = hbm::traffic::report(spec, element_interval(spec, si));

    metrics::SimResult::new(
        spec,
        est,
        si,
        total_flops,
        tl,
        avg_power_w,
        hbm_report,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datatype::DataType;
    use crate::dsl;
    use crate::hls::estimate;
    use crate::ir::{lower, rewrite, teil};
    use crate::olympus::{generate, OlympusOpts};

    fn sim(p: usize, opts: OlympusOpts, n: u64) -> SimResult {
        let prog = dsl::parse(&dsl::inverse_helmholtz_source(p)).unwrap();
        let m = rewrite::optimize(teil::from_ast(&prog).unwrap());
        let k = lower::lower_kernel(&m, "helmholtz").unwrap();
        let platform = Platform::alveo_u280();
        let s = generate(&k, &opts, &platform).unwrap();
        let e = estimate(&s, &platform);
        simulate(&s, &e, &platform, n)
    }

    const N: u64 = 2_000_000; // the paper's N_eq

    #[test]
    fn baseline_lands_near_paper_fig15() {
        // Paper: Baseline = 2.903 GFLOPS system, CU ~9.2% higher.
        let r = sim(11, OlympusOpts::baseline(), N);
        assert!(
            (2.0..4.5).contains(&r.gflops_system),
            "baseline system {} GFLOPS",
            r.gflops_system
        );
        assert!(r.gflops_cu > r.gflops_system);
        let gap = (r.gflops_cu - r.gflops_system) / r.gflops_cu;
        assert!((0.02..0.25).contains(&gap), "CU/system gap {gap}");
    }

    #[test]
    fn double_buffering_hides_transfers() {
        // Paper: after double buffering "the system performance is now
        // the same as the CU performance".
        let r = sim(11, OlympusOpts::double_buffering(), N);
        let gap = (r.gflops_cu - r.gflops_system) / r.gflops_cu;
        assert!(gap < 0.05, "gap {gap}");
    }

    #[test]
    fn bus_serial_degrades_bus_parallel_recovers() {
        // Paper Fig. 15: serial ~3x degradation; parallel ~3.9x over serial.
        let db = sim(11, OlympusOpts::double_buffering(), N);
        let ser = sim(11, OlympusOpts::bus_serial(), N);
        let par = sim(11, OlympusOpts::bus_parallel(), N);
        assert!(
            ser.gflops_system < db.gflops_system / 2.0,
            "serial {} vs db {}",
            ser.gflops_system,
            db.gflops_system
        );
        let speedup = par.gflops_system / ser.gflops_system;
        assert!((3.0..5.0).contains(&speedup), "parallel/serial {speedup}");
    }

    #[test]
    fn dataflow_ladder_matches_paper_shape() {
        // Paper: DF1 3.68x over BusOpt-parallel; DF2 1.7x over DF1;
        // DF3 <= DF2; DF7 best.
        let par = sim(11, OlympusOpts::bus_parallel(), N);
        let d1 = sim(11, OlympusOpts::dataflow(1), N);
        let d2 = sim(11, OlympusOpts::dataflow(2), N);
        let d3 = sim(11, OlympusOpts::dataflow(3), N);
        let d7 = sim(11, OlympusOpts::dataflow(7), N);
        assert!(d1.gflops_system > 2.5 * par.gflops_system);
        assert!(d2.gflops_system > 1.3 * d1.gflops_system);
        assert!(d3.gflops_system <= 1.05 * d2.gflops_system);
        assert!(d7.gflops_system > d2.gflops_system);
        // headline: DF7 lands in the paper's 43 GFLOPS neighborhood
        assert!(
            (30.0..60.0).contains(&d7.gflops_system),
            "DF7 {}",
            d7.gflops_system
        );
    }

    #[test]
    fn fixed_point_speeds_up() {
        // Paper: FX64 1.19x over double; FX32 2.37x, reaching ~103.
        let d = sim(11, OlympusOpts::dataflow(7), N);
        let f64_ = sim(11, OlympusOpts::fixed_point(DataType::Fx64), N);
        let f32_ = sim(11, OlympusOpts::fixed_point(DataType::Fx32), N);
        assert!(f64_.gflops_system > d.gflops_system);
        assert!(f32_.gflops_system > 1.7 * d.gflops_system);
        assert!(
            (70.0..140.0).contains(&f32_.gflops_system),
            "FX32 {}",
            f32_.gflops_system
        );
    }

    #[test]
    fn multi_cu_kernel_scales_but_system_drops() {
        // Paper Fig. 17: CU-only GFLOPS scales; system GFLOPS drops
        // because PCIe transfers serialize.
        let one = sim(11, OlympusOpts::fixed_point(DataType::Fx32), N);
        let three = sim(11, OlympusOpts::fixed_point(DataType::Fx32).with_cus(3), N);
        assert!(three.gflops_cu > 1.3 * one.gflops_cu);
        assert!(
            three.gflops_system < three.gflops_cu / 1.3,
            "system {} vs cu {}",
            three.gflops_system,
            three.gflops_cu
        );
        assert_eq!(three.bottleneck, "pcie");
    }

    #[test]
    fn efficiency_metrics_consistent() {
        let r = sim(11, OlympusOpts::fixed_point(DataType::Fx32), N);
        assert!(r.avg_power_w > 20.0 && r.avg_power_w < 80.0);
        let eff = r.gflops_system / r.avg_power_w;
        assert!((r.efficiency_gflops_w - eff).abs() < 1e-9);
        // paper headline: ~4 GOPS/W
        assert!((2.0..7.0).contains(&eff), "efficiency {eff}");
        assert!(r.energy_j > 0.0);
    }

    #[test]
    fn read_module_bounds_df7() {
        // Paper: for DF7 the compute modules end up slightly below the
        // read module -> read is the bottleneck stage.
        let prog = dsl::parse(&dsl::inverse_helmholtz_source(11)).unwrap();
        let m = rewrite::optimize(teil::from_ast(&prog).unwrap());
        let k = lower::lower_kernel(&m, "helmholtz").unwrap();
        let platform = Platform::alveo_u280();
        let s = generate(&k, &OlympusOpts::dataflow(7), &platform).unwrap();
        let e = estimate(&s, &platform);
        let si = stages(&s, &e);
        assert_eq!(si.bottleneck(), "read");
        assert_eq!(si.stages[0].1, 121 + 2 * 1331);
    }

    #[test]
    fn uncapped_plan_has_zero_conflict_stalls() {
        // acceptance: at the plan's chosen partition factor the banks
        // sustain the unrolled reduction — no stalls anywhere on the
        // ladder
        for opts in [
            OlympusOpts::baseline(),
            OlympusOpts::dataflow(1),
            OlympusOpts::dataflow(7),
            OlympusOpts::mem_sharing(),
        ] {
            let r = sim(11, opts, 100_000);
            assert_eq!(r.conflict_stalls, 0, "{}", r.label);
        }
    }

    #[test]
    fn capped_plan_charges_stalls_and_slows_down() {
        // capping the partition factor below the p=11 reduction trip
        // under-provisions ports: ceil(11/4) = 3 cycles per unrolled
        // iteration -> >0 stalls and lower throughput
        let full = sim(11, OlympusOpts::dataflow(7), 200_000);
        let capped = sim(11, OlympusOpts::dataflow(7).with_partition_cap(4), 200_000);
        assert_eq!(full.conflict_stalls, 0);
        assert!(capped.conflict_stalls > 0);
        // each gemm group now runs 3x its iterations: 2 extra cycles
        // per iteration on six contraction groups of 1331 iterations
        assert_eq!(capped.conflict_stalls, 6 * 1331 * 2);
        // the bottleneck moves from the read module (2783 cyc) to the
        // stalled gemm groups (fill 1452 + 3x1331 compute = 5445 cyc)
        assert!(
            capped.gflops_system < 0.8 * full.gflops_system,
            "capped {} vs full {}",
            capped.gflops_system,
            full.gflops_system
        );
        assert_ne!(capped.bottleneck, "read");
    }

    #[test]
    fn capped_banks_compose_with_port_limited_ii_by_max() {
        // bus-serial is port-limited: II = ceil(11/2) = 6 already
        // serializes the unrolled reads over the two local-memory
        // ports, so a bank cap adds nothing until ceil(11/cap) exceeds
        // the II — the two throttles must never multiply
        let mild = sim(11, OlympusOpts::bus_serial().with_partition_cap(4), 100_000);
        assert_eq!(mild.conflict_stalls, 0, "ceil(11/4)=3 <= II=6");
        let harsh = sim(11, OlympusOpts::bus_serial().with_partition_cap(1), 100_000);
        // six gemm nests of 1331 iterations each pay ceil(11/1) - II
        assert_eq!(harsh.conflict_stalls, 6 * 1331 * (11 - 6));
    }

    #[test]
    fn more_elements_scale_time_linearly() {
        let a = sim(11, OlympusOpts::dataflow(7), 500_000);
        let b = sim(11, OlympusOpts::dataflow(7), 1_000_000);
        let ratio = b.total_time_s / a.total_time_s;
        assert!((1.8..2.2).contains(&ratio), "{ratio}");
    }

    #[test]
    fn shared_channel_pays_turnaround_and_contention() {
        // paper Challenge 2: separating reads and writes onto different
        // channels removes the controller turnaround penalty. 8 CUs use
        // shared ping/pong channels; 4 CUs separate the directions. On
        // the shared layout each overlapped stage also waits out the
        // other direction's words on the wire (channel-bound pipeline).
        let prog = dsl::parse(&dsl::inverse_helmholtz_source(11)).unwrap();
        let m = rewrite::optimize(teil::from_ast(&prog).unwrap());
        let k = lower::lower_kernel(&m, "helmholtz").unwrap();
        let platform = Platform::alveo_u280();
        let mk = |cus: usize| {
            let s = generate(&k, &OlympusOpts::dataflow(7).with_cus(cus), &platform).unwrap();
            let e = estimate(&s, &platform);
            stages(&s, &e)
        };
        let separate = mk(4); // <8 CUs: separate in/out channels
        let shared = mk(8); // ping/pong channels carry both directions
        let t = platform.hbm.switch;
        let turn = t.t_wtr_cycles + t.t_rtw_cycles;
        let in_words = (121 + 2 * 1331) as u64;
        let out_words = 1331u64;
        assert_eq!(separate.stages[0].1, in_words, "separated reads are clean");
        assert_eq!(
            shared.stages[0].1,
            in_words + out_words + turn,
            "shared reads see the channel's full busy time"
        );
        let wl = shared.stages.last().unwrap().1;
        let ws = separate.stages.last().unwrap().1;
        assert_eq!(ws, out_words);
        assert_eq!(wl, out_words + in_words + turn);
    }

    #[test]
    fn multi_fpga_restores_replication_scaling() {
        // Paper §5: with one PCIe link per card, replication pays again.
        let prog = dsl::parse(&dsl::inverse_helmholtz_source(11)).unwrap();
        let m = rewrite::optimize(teil::from_ast(&prog).unwrap());
        let k = lower::lower_kernel(&m, "helmholtz").unwrap();
        let platform = Platform::alveo_u280();
        let opts = OlympusOpts::fixed_point(DataType::Fx32);
        let s = generate(&k, &opts, &platform).unwrap();
        let e = estimate(&s, &platform);
        let one = simulate_multi_fpga(&s, &e, &platform, N, 1);
        let four = simulate_multi_fpga(&s, &e, &platform, N, 4);
        let scaling = four.gflops_system / one.gflops_system;
        assert!(
            (3.0..4.3).contains(&scaling),
            "4 cards should scale ~4x: {scaling}"
        );
    }

    #[test]
    fn ddr4_limits_compute_units() {
        let prog = dsl::parse(&dsl::inverse_helmholtz_source(11)).unwrap();
        let m = rewrite::optimize(teil::from_ast(&prog).unwrap());
        let k = lower::lower_kernel(&m, "helmholtz").unwrap();
        let platform = Platform::alveo_u280();
        // two CUs without double buffering fit the two banks
        let ok = generate(&k, &OlympusOpts::baseline().on_ddr4().with_cus(2), &platform);
        assert!(ok.is_ok());
        // three do not; double buffering caps at one
        assert!(
            generate(&k, &OlympusOpts::baseline().on_ddr4().with_cus(3), &platform).is_err()
        );
        assert!(generate(
            &k,
            &OlympusOpts::dataflow(7).on_ddr4().with_cus(2),
            &platform
        )
        .is_err());
        let one_db = generate(&k, &OlympusOpts::dataflow(7).on_ddr4(), &platform).unwrap();
        assert_eq!(one_db.total_pcs(), 2, "ping/pong on the two banks");
    }

    #[test]
    fn p7_performs_slightly_below_p11() {
        // Paper Fig. 16: p=7 implementations are slightly slower.
        let p11 = sim(11, OlympusOpts::dataflow(7), N);
        let p7 = sim(7, OlympusOpts::dataflow(7), N);
        assert!(p7.gflops_system < p11.gflops_system);
        assert!(p7.gflops_system > 0.3 * p11.gflops_system);
    }
}
