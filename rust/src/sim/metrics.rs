//! Simulation metrics: the quantities the paper reports (GFLOPS,
//! GFLOPS/W, power, efficiency vs ideal — §4.1, Table 2, Fig. 15-18),
//! plus the interconnect-side diagnostics the `hbm` model produces
//! (per-channel utilization, switch crossings, fill latency) and the
//! on-chip memory-plan diagnostics (banks, shared words, bank-conflict
//! stalls) derived from the `mnemosyne::MemoryPlan` on the spec.

use super::event::Timeline;
use super::StageIntervals;
use crate::hbm::HbmReport;
use crate::hls::Estimate;
use crate::olympus::SystemSpec;

/// Result of simulating one system on one workload.
#[derive(Debug, Clone)]
pub struct SimResult {
    pub label: String,
    /// Wall-clock including host transfers (the paper's "System" bars).
    pub total_time_s: f64,
    /// Kernel-only time (the paper's "CU" bars).
    pub cu_time_s: f64,
    pub transfer_time_s: f64,
    pub gflops_system: f64,
    pub gflops_cu: f64,
    pub freq_mhz: f64,
    /// #Ops x f (Table 2 "Ideal GFLOPS").
    pub ideal_gflops: f64,
    /// achieved / ideal (Table 2 "Efficiency").
    pub efficiency_vs_ideal: f64,
    pub avg_power_w: f64,
    pub efficiency_gflops_w: f64,
    pub energy_j: f64,
    pub batches: u64,
    pub batch_elements: usize,
    /// Per-stage cycles per element (diagnostics; Fig. 11 intervals).
    pub stage_intervals: Vec<(String, u64)>,
    /// Name of the limiting stage or "pcie".
    pub bottleneck: String,
    pub total_flops: u64,
    /// Busy fraction of each allocated pseudo-channel while its CU
    /// streams, `(channel, utilization)` in channel order.
    pub channel_utilization: Vec<(u32, f64)>,
    pub max_channel_utilization: f64,
    /// Port→channel routes crossing at least one switch boundary.
    pub switch_crossings: u64,
    /// Switch round-trip latency filled once per batch (cycles).
    pub hbm_fill_cycles: u64,
    /// Bank-conflict stall cycles per element (0 unless the memory
    /// plan's partition factor is capped below the access degree).
    pub conflict_stalls: u64,
    /// Memory-plan summary: total banks per lane.
    pub mem_banks: usize,
    /// Memory-plan summary: physical on-chip words per lane.
    pub mem_shared_words: usize,
    /// Memory-plan summary: words before lifetime sharing.
    pub mem_unshared_words: usize,
    /// Closed-form makespan bounds when this result came from
    /// `sim::analytic` (its `total_time_s` is then the conservative
    /// upper bound); `None` for full event-timeline results.
    pub analytic: Option<super::analytic::AnalyticBounds>,
}

impl SimResult {
    pub fn new(
        spec: &SystemSpec,
        est: &Estimate,
        si: &StageIntervals,
        total_flops: u64,
        tl: Timeline,
        avg_power_w: f64,
        hbm: HbmReport,
    ) -> SimResult {
        let mem = spec.memory.stats(&spec.kernel);
        let gflops_system = total_flops as f64 / tl.total_s.max(1e-12) / 1e9;
        let gflops_cu = total_flops as f64 / tl.cu_busy_s.max(1e-12) / 1e9;
        let ideal = est.ideal_gflops() * spec.num_cus as f64;
        let bottleneck = if tl.pcie_bound {
            "pcie".to_string()
        } else {
            si.bottleneck().to_string()
        };
        SimResult {
            label: spec.opts.label(),
            total_time_s: tl.total_s,
            cu_time_s: tl.cu_busy_s,
            transfer_time_s: tl.pcie_busy_s,
            gflops_system,
            gflops_cu,
            freq_mhz: est.fmax_mhz,
            ideal_gflops: ideal,
            efficiency_vs_ideal: gflops_cu / ideal.max(1e-12),
            avg_power_w,
            efficiency_gflops_w: gflops_system / avg_power_w.max(1e-12),
            energy_j: avg_power_w * tl.total_s,
            batches: (total_flops / spec.flops_per_element().max(1))
                .div_ceil(spec.batch_elements as u64),
            batch_elements: spec.batch_elements,
            stage_intervals: si.stages.clone(),
            bottleneck,
            total_flops,
            channel_utilization: hbm
                .channels
                .iter()
                .map(|c| (c.channel, c.utilization))
                .collect(),
            max_channel_utilization: hbm.max_utilization,
            switch_crossings: hbm.switch_crossings,
            hbm_fill_cycles: hbm.fill_cycles,
            conflict_stalls: si.conflict_stalls,
            mem_banks: mem.banks,
            mem_shared_words: mem.shared_words,
            mem_unshared_words: mem.unshared_words,
            analytic: None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dsl;
    use crate::hls::estimate;
    use crate::ir::{lower, rewrite, teil};
    use crate::olympus::{generate, OlympusOpts};
    use crate::platform::Platform;

    #[test]
    fn metrics_are_self_consistent() {
        let prog = dsl::parse(&dsl::inverse_helmholtz_source(11)).unwrap();
        let m = rewrite::optimize(teil::from_ast(&prog).unwrap());
        let k = lower::lower_kernel(&m, "helmholtz").unwrap();
        let platform = Platform::alveo_u280();
        let s = generate(&k, &OlympusOpts::dataflow(7), &platform).unwrap();
        let e = estimate(&s, &platform);
        let r = crate::sim::simulate(&s, &e, &platform, 100_000);
        // system throughput can never beat kernel-only throughput
        assert!(r.gflops_system <= r.gflops_cu * (1.0 + 1e-9));
        // efficiency vs ideal in (0, 1]
        assert!(r.efficiency_vs_ideal > 0.0 && r.efficiency_vs_ideal <= 1.0);
        // energy = power x time
        assert!((r.energy_j - r.avg_power_w * r.total_time_s).abs() < 1e-6);
        // flops bookkeeping
        assert_eq!(r.total_flops, 100_000 * 177_023);
        assert!(r.batches >= 1);
        // interconnect diagnostics: every allocated channel is reported,
        // utilizations are sane, and the default layout never crosses
        assert_eq!(r.channel_utilization.len(), s.total_pcs());
        for &(_, u) in &r.channel_utilization {
            assert!(u > 0.0 && u <= 1.0, "channel utilization {u}");
        }
        assert!(r.max_channel_utilization <= 1.0);
        assert_eq!(r.switch_crossings, 0, "local-first allocation");
        assert!(r.hbm_fill_cycles > 0);
        // memory-plan diagnostics mirror the spec's plan
        assert_eq!(r.conflict_stalls, 0, "uncapped plan is conflict-free");
        assert_eq!(r.mem_banks, s.memory.total_banks());
        assert_eq!(r.mem_shared_words, s.memory.shared_words());
        assert_eq!(r.mem_unshared_words, s.memory.unshared_words(&s.kernel));
    }
}
