//! Composed-pipeline simulation: chain per-stage batch timelines
//! through FIFO credit, bound them in closed form, and price the
//! time-multiplexed alternative (DESIGN.md §2.10).
//!
//! ## The composed timeline
//!
//! A [`ComposedSystem`](crate::olympus::ComposedSystem) marches batches
//! of its common size through every stage in order. Only stage 0 pays
//! the serialized PCIe input and only the last stage pays the output;
//! inner edges are on-chip FIFOs with zero transfer time. Per batch
//! `b`, stage `k` starts at
//!
//! ```text
//! start[k][b] = max( done[k-1][b],            // upstream data ready
//!                    cu_free[k][b mod c_k],   // a CU of the stage free
//!                    start[k+1][b - credit] ) // FIFO space downstream
//! ```
//!
//! where `credit` is how many producer batches the link FIFO can hold
//! (≥ 1: the FIFO always buffers the batch in flight). Backpressure on
//! the consumer's *start* times (not completions) keeps the steady-state
//! period at the slowest stage's rate — the pipeline never deadlocks on
//! its own credit.
//!
//! ## Closed-form bounds
//!
//! With `λ = max(t_in, t_out, max_k t_k)` and `K` stages, induction over
//! the recurrence gives `start[k][b] ≤ (k + 1 + b)·λ` and a makespan of
//! at most `(n + K + 1)·λ`; every resource's busy time and the first
//! batch's full chain bound it from below. Both carry the same ±1e-9
//! float guard as the single-kernel bounds in [`analytic`](super::analytic).
//!
//! ## Time-multiplexed baseline
//!
//! The layout alternative to fusing stages on-chip is running each
//! kernel as its own full-device configuration, round-tripping every
//! intermediate through the host: its cost is the *sum* of the member
//! systems' standalone event-timeline makespans — what `dse`'s
//! composition axis and the acceptance test compare against.

use super::analytic::AnalyticBounds;
use super::event::TimelineMode;
use crate::hls;
use crate::olympus::ComposedSystem;
use crate::platform::{Platform, Resources};

/// Same float-accumulation guard as the single-kernel analytic bounds.
const EPS: f64 = 1e-9;

/// One stage of a composed timeline.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ComposedStage {
    /// Seconds one CU spends computing one common-size batch.
    pub t_batch: f64,
    /// CUs executing the stage's batches round-robin.
    pub n_cus: usize,
    /// Batches this stage may start ahead of the next stage's starts
    /// (FIFO capacity of the outgoing link, in batches; ≥ 1). Unused on
    /// the last stage.
    pub credit: u64,
}

/// Inputs of the composed event timeline and its closed-form bounds.
#[derive(Debug, Clone, PartialEq)]
pub struct ComposedTimelineConfig {
    pub n_batches: u64,
    /// Serialized PCIe seconds to deliver one batch to stage 0.
    pub t_in: f64,
    /// Serialized PCIe seconds to drain one batch from the last stage.
    pub t_out: f64,
    pub stages: Vec<ComposedStage>,
}

/// Run the composed event timeline; returns the makespan in seconds.
pub fn run_composed_timeline(cfg: &ComposedTimelineConfig) -> f64 {
    assert!(!cfg.stages.is_empty());
    if cfg.n_batches == 0 {
        return 0.0;
    }
    let ks = cfg.stages.len();
    let mut in_link_free = 0.0f64;
    let mut out_link_free = 0.0f64;
    let mut cu_free: Vec<Vec<f64>> = cfg
        .stages
        .iter()
        .map(|s| vec![0.0; s.n_cus.max(1)])
        .collect();
    // start times per stage, indexed by batch (read by the upstream
    // stage's backpressure term)
    let mut starts: Vec<Vec<f64>> =
        vec![Vec::with_capacity(cfg.n_batches as usize); ks];
    for b in 0..cfg.n_batches {
        let in_done = in_link_free + cfg.t_in;
        in_link_free = in_done;
        let mut upstream = in_done;
        for (k, st) in cfg.stages.iter().enumerate() {
            let cus = st.n_cus.max(1);
            let cu = (b % cus as u64) as usize;
            let mut ready = upstream.max(cu_free[k][cu]);
            if k + 1 < ks {
                let credit = st.credit.max(1);
                if b >= credit {
                    ready = ready.max(starts[k + 1][(b - credit) as usize]);
                }
            }
            starts[k].push(ready);
            let done = ready + st.t_batch;
            cu_free[k][cu] = done;
            upstream = done;
        }
        out_link_free = out_link_free.max(upstream) + cfg.t_out;
    }
    out_link_free
}

/// Closed-form bracket on [`run_composed_timeline`]'s makespan.
pub fn composed_bounds(cfg: &ComposedTimelineConfig) -> AnalyticBounds {
    assert!(!cfg.stages.is_empty());
    if cfg.n_batches == 0 {
        return AnalyticBounds {
            lower_s: 0.0,
            upper_s: 0.0,
        };
    }
    let n = cfg.n_batches as f64;
    let sum_t: f64 = cfg.stages.iter().map(|s| s.t_batch).sum();
    let chain = cfg.t_in + sum_t + cfg.t_out;
    // every resource must serve its load, and batch 0 walks the chain
    let mut lower = (n * cfg.t_in).max(n * cfg.t_out).max(chain);
    for s in &cfg.stages {
        let rounds = cfg.n_batches.div_ceil(s.n_cus.max(1) as u64) as f64;
        lower = lower.max(rounds * s.t_batch);
    }
    let lambda = cfg
        .stages
        .iter()
        .map(|s| s.t_batch)
        .fold(cfg.t_in.max(cfg.t_out), f64::max);
    let k = cfg.stages.len() as f64;
    let upper = (n + k + 1.0) * lambda;
    AnalyticBounds {
        lower_s: lower * (1.0 - EPS),
        upper_s: upper * (1.0 + EPS),
    }
}

/// Result of simulating a composed system: the FIFO-routed pipeline
/// makespan, its closed-form bracket, and the time-multiplexed
/// (HBM/host round-trip) baseline it competes with.
#[derive(Debug, Clone)]
pub struct ComposedSimResult {
    pub label: String,
    pub n_elements: u64,
    pub n_batches: u64,
    pub batch_elements: usize,
    /// Common clock: the slowest member's fmax.
    pub freq_mhz: f64,
    pub stage_names: Vec<String>,
    /// Per-stage seconds per common batch (at the common clock).
    pub stage_t_batch_s: Vec<f64>,
    /// Serialized PCIe seconds per batch, in and out.
    pub pcie_in_s: f64,
    pub pcie_out_s: f64,
    /// FIFO-routed composed event-timeline makespan.
    pub total_s: f64,
    /// Closed-form bracket on `total_s`.
    pub analytic: AnalyticBounds,
    /// Sum of the members' standalone event-timeline makespans (each
    /// stage as its own configuration, every edge through the host).
    pub time_multiplexed_s: f64,
    /// `time_multiplexed_s / total_s` — > 1 when fusing on-chip wins.
    pub speedup_vs_time_multiplexed: f64,
    /// The resource binding the steady state: a stage name or pcie-in/out.
    pub bottleneck: String,
    pub total_flops: u64,
    pub gflops_system: f64,
    /// Whole-device resources of the composed design.
    pub resources: Resources,
}

/// Derive the composed timeline inputs from a generated system.
pub fn composed_timeline_config(
    sys: &ComposedSystem,
    platform: &Platform,
    n_elements: u64,
) -> ComposedTimelineConfig {
    let ests: Vec<hls::Estimate> = sys
        .stages
        .iter()
        .map(|s| hls::estimate(s, platform))
        .collect();
    let freq_mhz = ests
        .iter()
        .map(|e| e.fmax_mhz)
        .fold(f64::INFINITY, f64::min);
    let freq_hz = freq_mhz * 1e6;
    let e = sys.batch_elements as u64;
    let n_batches = n_elements.div_ceil(e.max(1));
    let batch_words = |words: usize| words as u64 * e;
    let stages: Vec<ComposedStage> = sys
        .stages
        .iter()
        .zip(&ests)
        .enumerate()
        .map(|(k, (spec, est))| {
            let si = super::stages(spec, est);
            let t_batch = super::batch_cycles(spec, &si) as f64 / freq_hz;
            // FIFO capacity of the outgoing link in producer batches
            let credit = match sys.links.get(k) {
                Some(l) => (l.fifo.depth_words as u64
                    / batch_words(spec.kernel.output_words()).max(1))
                .max(1),
                None => 1,
            };
            ComposedStage {
                t_batch,
                n_cus: spec.num_cus,
                credit,
            }
        })
        .collect();
    let first = &sys.stages[0];
    let last = sys.stages.last().expect("composed systems have stages");
    let t_in = (first.input_bytes_per_element() * e) as f64
        / platform.pcie_eff_bytes_per_sec;
    let t_out = (last.output_bytes_per_element() * e) as f64
        / platform.pcie_eff_bytes_per_sec;
    ComposedTimelineConfig {
        n_batches,
        t_in,
        t_out,
        stages,
    }
}

/// Simulate a composed system end to end: FIFO-routed event timeline,
/// closed-form bracket, and the time-multiplexed baseline.
pub fn simulate_composed(
    sys: &ComposedSystem,
    platform: &Platform,
    n_elements: u64,
) -> ComposedSimResult {
    let cfg = composed_timeline_config(sys, platform, n_elements);
    let total_s = run_composed_timeline(&cfg);
    let analytic = composed_bounds(&cfg);

    // the layout alternative: each member standalone, every edge a
    // host/HBM round trip — makespans add (one device, reconfigured)
    let mut time_multiplexed_s = 0.0;
    for spec in &sys.stages {
        let est = hls::estimate(spec, platform);
        let r = super::simulate_with_timeline(
            spec,
            &est,
            platform,
            n_elements,
            TimelineMode::Auto,
        );
        time_multiplexed_s += r.total_time_s;
    }

    let ests: Vec<hls::Estimate> = sys
        .stages
        .iter()
        .map(|s| hls::estimate(s, platform))
        .collect();
    let freq_mhz = ests
        .iter()
        .map(|e| e.fmax_mhz)
        .fold(f64::INFINITY, f64::min);
    let stage_names: Vec<String> = sys
        .stages
        .iter()
        .map(|s| s.kernel.name.clone())
        .collect();

    // steady-state bottleneck: the largest per-batch service time
    let n = cfg.n_batches as f64;
    let mut bottleneck = ("pcie-in".to_string(), n * cfg.t_in);
    if n * cfg.t_out > bottleneck.1 {
        bottleneck = ("pcie-out".to_string(), n * cfg.t_out);
    }
    for (name, st) in stage_names.iter().zip(&cfg.stages) {
        let busy =
            cfg.n_batches.div_ceil(st.n_cus.max(1) as u64) as f64 * st.t_batch;
        if busy > bottleneck.1 {
            bottleneck = (name.clone(), busy);
        }
    }

    // every element traverses every stage
    let flops_per_element: u64 = sys
        .stages
        .iter()
        .map(|s| s.flops_per_element())
        .sum();
    let total_flops = n_elements * flops_per_element;
    let gflops_system = if total_s > 0.0 {
        total_flops as f64 / total_s / 1e9
    } else {
        0.0
    };

    ComposedSimResult {
        label: sys.name.clone(),
        n_elements,
        n_batches: cfg.n_batches,
        batch_elements: sys.batch_elements,
        freq_mhz,
        stage_names,
        stage_t_batch_s: cfg.stages.iter().map(|s| s.t_batch).collect(),
        pcie_in_s: cfg.t_in,
        pcie_out_s: cfg.t_out,
        total_s,
        analytic,
        time_multiplexed_s,
        speedup_vs_time_multiplexed: if total_s > 0.0 {
            time_multiplexed_s / total_s
        } else {
            0.0
        },
        bottleneck: bottleneck.0,
        total_flops,
        gflops_system,
        resources: sys.resources,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    fn cfg(
        n: u64,
        t_in: f64,
        t_out: f64,
        stages: &[(f64, usize, u64)],
    ) -> ComposedTimelineConfig {
        ComposedTimelineConfig {
            n_batches: n,
            t_in,
            t_out,
            stages: stages
                .iter()
                .map(|&(t_batch, n_cus, credit)| ComposedStage {
                    t_batch,
                    n_cus,
                    credit,
                })
                .collect(),
        }
    }

    #[test]
    fn single_stage_single_cu_chain_is_exact() {
        // 1 stage, 1 CU, credit moot: fully serial chain per batch with
        // transfer overlap — bounded by hand-checkable extremes
        let c = cfg(10, 1.0, 0.5, &[(2.0, 1, 1)]);
        let t = run_composed_timeline(&c);
        // steady state paced by the 2.0 s compute: ~chain + 9 * 2.0
        assert!(t >= 3.5 + 9.0 * 2.0 - 1e-9, "{t}");
        assert!(t <= 3.5 + 9.0 * 2.5 + 1e-9, "{t}");
        assert!(composed_bounds(&c).brackets(t));
    }

    #[test]
    fn empty_workload_is_zero() {
        let c = cfg(0, 1.0, 1.0, &[(1.0, 1, 1)]);
        assert_eq!(run_composed_timeline(&c), 0.0);
        let b = composed_bounds(&c);
        assert_eq!((b.lower_s, b.upper_s), (0.0, 0.0));
    }

    #[test]
    fn pipeline_overlaps_stages() {
        // 3 equal stages: the pipeline must approach 1 batch per t_batch,
        // NOT 1 batch per 3*t_batch (which serial execution would cost)
        let c = cfg(100, 0.1, 0.1, &[(1.0, 1, 1), (1.0, 1, 1), (1.0, 1, 1)]);
        let t = run_composed_timeline(&c);
        assert!(t < 100.0 * 1.5, "pipeline failed to overlap: {t}");
        assert!(t >= 100.0 * 1.0, "cannot beat the bottleneck rate: {t}");
        assert!(composed_bounds(&c).brackets(t));
    }

    #[test]
    fn property_bounds_bracket_the_composed_timeline() {
        prop::check("composed bounds bracket", 128, |rng| {
            let ks = rng.range_usize(1, 5);
            let stages: Vec<(f64, usize, u64)> = (0..ks)
                .map(|_| {
                    (
                        rng.range_f64(0.0, 2.0),
                        rng.range_usize(1, 4),
                        rng.range_u64(1, 4),
                    )
                })
                .collect();
            let c = cfg(
                rng.range_u64(1, 400),
                rng.range_f64(0.0, 2.0),
                rng.range_f64(0.0, 2.0),
                &stages,
            );
            let t = run_composed_timeline(&c);
            let b = composed_bounds(&c);
            prop::assert_prop(
                b.brackets(t),
                format!("{b:?} misses {t} on {c:?}"),
            )
        });
    }

    #[test]
    fn more_credit_never_slows_the_pipeline() {
        // a deeper FIFO can only relax the backpressure constraint
        let tight = cfg(200, 0.2, 0.2, &[(1.0, 1, 1), (0.3, 1, 1)]);
        let deep = cfg(200, 0.2, 0.2, &[(1.0, 1, 8), (0.3, 1, 8)]);
        assert!(
            run_composed_timeline(&deep) <= run_composed_timeline(&tight) + 1e-9
        );
    }
}
