//! The shared artifact cache and batch evaluation service.
//!
//! A [`Session`] owns a platform model and memoizes pipeline stages
//! across configurations: `Parsed` and `Lowered` are keyed by the
//! source fingerprint (one parse + one lower per distinct program text,
//! no matter how many option sets evaluate it), `Mapped` is keyed by
//! (fingerprint, options) and shared across evaluation kinds. The cache
//! is a plain mutex: parse/lower run *under* the lock, so concurrent
//! requests for the same program wait for the first computation instead
//! of duplicating it — the once-per-key guarantee
//! [`Session::stats`]-based regression tests pin. Mapping (Olympus
//! generation) runs outside the lock; a rare race there re-generates a
//! spec and keeps the first insert.
//!
//! [`Session::evaluate_batch`] is the paper-flow counterpart of a
//! request batch in a serving system: many (source, degree, options,
//! evaluation) requests run concurrently on a scoped-thread pool over
//! the shared cache, with results in request order. It absorbs the
//! worker pool that used to be private to `dse::eval`.
//!
//! Evaluation kinds compose with the cache: `EvalKind::Estimate`,
//! `EvalKind::Simulate` (the full event timeline), and
//! `EvalKind::SimulateAnalytic` (the closed-form `sim::analytic` fast
//! path) all share the same `Mapped` entry, and the `Mapped` value
//! memoizes its HLS estimate — so dse's adaptive two-pass sweep
//! (analytic screen over every candidate, exact event sim only for the
//! survivors) pays for generation and estimation exactly once per
//! candidate no matter how many passes re-request it.
//!
//! ```
//! use hbmflow::flow::{EvalKind, FlowRequest, Session};
//! use hbmflow::kernels::KernelSource;
//! use hbmflow::olympus::OlympusOpts;
//! use hbmflow::platform::Platform;
//!
//! let session = Session::new(Platform::alveo_u280());
//! let src = KernelSource::builtin("helmholtz");
//! let reqs: Vec<FlowRequest> = [1, 2]
//!     .iter()
//!     .map(|&cus| FlowRequest {
//!         source: src.clone(),
//!         p: 7,
//!         opts: OlympusOpts::dataflow(7).with_cus(cus),
//!         eval: EvalKind::Estimate,
//!     })
//!     .collect();
//! let results = session.evaluate_batch(&reqs);
//! assert!(results.iter().all(|r| r.result.is_ok()));
//! // both configurations shared one parse + one lower
//! assert_eq!(session.stats().parsed_misses, 1);
//! assert_eq!(session.stats().lowered_misses, 1);
//! ```

use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use crate::kernels::KernelSource;
use crate::olympus::OlympusOpts;
use crate::platform::Platform;

use super::{fingerprint, parse_text, EvalKind, Evaluated, FlowError, Lowered, Mapped, Parsed};

/// One batch-evaluation request: a program at a degree, an option set,
/// and how to evaluate the generated system.
#[derive(Debug, Clone)]
pub struct FlowRequest {
    pub source: KernelSource,
    pub p: usize,
    pub opts: OlympusOpts,
    pub eval: EvalKind,
}

/// One batch-evaluation answer, in request order. `Err` carries the
/// stage that refused (parse error, infeasible channel allocation, …) —
/// infeasibility is part of the answer, not a missing row.
#[derive(Debug, Clone)]
pub struct FlowResult {
    pub request: FlowRequest,
    pub result: Result<Evaluated, FlowError>,
}

/// Cache traffic counters (monotonic over the session's lifetime).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct SessionStats {
    pub parsed_misses: u64,
    pub parsed_hits: u64,
    pub lowered_misses: u64,
    pub lowered_hits: u64,
    pub mapped_misses: u64,
    pub mapped_hits: u64,
    /// End-to-end [`Session::evaluate`] calls (every batch slot counts
    /// one). Resumable sweeps use this to prove no point is ever
    /// evaluated twice across a kill/resume boundary.
    pub eval_calls: u64,
}

/// (fingerprint, degree) — one entry per distinct program text.
type SourceKey = (String, usize);
/// (fingerprint, degree, canonical options debug string).
type MapKey = (String, usize, String);

#[derive(Default)]
struct State {
    parsed: HashMap<SourceKey, Arc<Parsed>>,
    lowered: HashMap<SourceKey, Arc<Lowered>>,
    mapped: HashMap<MapKey, Arc<Mapped>>,
    stats: SessionStats,
}

/// Thread-safe staged-artifact cache over one platform model.
pub struct Session {
    platform: Platform,
    state: Mutex<State>,
}

impl Session {
    pub fn new(platform: Platform) -> Session {
        Session {
            platform,
            state: Mutex::new(State::default()),
        }
    }

    /// The platform every `Mapped`/`Evaluated` artifact targets.
    pub fn platform(&self) -> &Platform {
        &self.platform
    }

    /// Snapshot of the cache counters.
    pub fn stats(&self) -> SessionStats {
        self.state.lock().unwrap().stats
    }

    /// Resolve the source text and its cache key (re-reads file sources
    /// so an on-disk edit mid-session becomes a new cache entry instead
    /// of a stale hit).
    fn source_key(
        &self,
        source: &KernelSource,
        p: usize,
    ) -> Result<(SourceKey, String), FlowError> {
        let text = source.source(p).map_err(FlowError::parse)?;
        let fp = fingerprint(&source.name(), &text);
        Ok(((fp, p), text))
    }

    fn parsed_locked(
        st: &mut State,
        source: &KernelSource,
        p: usize,
        key: SourceKey,
        text: String,
    ) -> Result<Arc<Parsed>, FlowError> {
        if let Some(a) = st.parsed.get(&key) {
            st.stats.parsed_hits += 1;
            return Ok(a.clone());
        }
        st.stats.parsed_misses += 1;
        let parsed = Arc::new(parse_text(&source.name(), &source.origin(), p, text)?);
        st.parsed.insert(key, parsed.clone());
        Ok(parsed)
    }

    /// The memoized `Parsed` stage for a source at degree `p`.
    pub fn parsed(&self, source: &KernelSource, p: usize) -> Result<Arc<Parsed>, FlowError> {
        let (key, text) = self.source_key(source, p)?;
        let mut st = self.state.lock().unwrap();
        Self::parsed_locked(&mut st, source, p, key, text)
    }

    /// The memoized `Lowered` stage for a source at degree `p`.
    pub fn lowered(&self, source: &KernelSource, p: usize) -> Result<Arc<Lowered>, FlowError> {
        let (key, text) = self.source_key(source, p)?;
        let mut st = self.state.lock().unwrap();
        if let Some(l) = st.lowered.get(&key) {
            st.stats.lowered_hits += 1;
            return Ok(l.clone());
        }
        let parsed = Self::parsed_locked(&mut st, source, p, key.clone(), text)?;
        st.stats.lowered_misses += 1;
        let lowered = Arc::new(parsed.lower()?);
        st.lowered.insert(key, lowered.clone());
        Ok(lowered)
    }

    /// The memoized `Mapped` stage for (source, degree, options) on the
    /// session's platform — shared across evaluation kinds.
    pub fn mapped(
        &self,
        source: &KernelSource,
        p: usize,
        opts: &OlympusOpts,
    ) -> Result<Arc<Mapped>, FlowError> {
        let lowered = self.lowered(source, p)?;
        let key: MapKey = (
            lowered.provenance.fingerprint.clone(),
            p,
            format!("{opts:?}"),
        );
        {
            let mut st = self.state.lock().unwrap();
            if let Some(m) = st.mapped.get(&key) {
                st.stats.mapped_hits += 1;
                return Ok(m.clone());
            }
            st.stats.mapped_misses += 1;
        }
        // generate outside the lock: mapping is per-configuration work,
        // the part a batch wants parallel
        let mapped = Arc::new(lowered.map(opts, &self.platform)?);
        let mut st = self.state.lock().unwrap();
        let m = match st.mapped.get(&key) {
            Some(existing) => existing.clone(),
            None => {
                st.mapped.insert(key, mapped.clone());
                mapped
            }
        };
        Ok(m)
    }

    /// Run one request end to end over the cache.
    pub fn evaluate(&self, req: &FlowRequest) -> FlowResult {
        self.state.lock().unwrap().stats.eval_calls += 1;
        let result = self
            .mapped(&req.source, req.p, &req.opts)
            .map(|m| m.evaluate(req.eval));
        FlowResult {
            request: req.clone(),
            result,
        }
    }

    /// Evaluate many requests concurrently over the shared cache with
    /// one worker per available core; results are in request order.
    pub fn evaluate_batch(&self, reqs: &[FlowRequest]) -> Vec<FlowResult> {
        self.evaluate_batch_with(reqs, None)
    }

    /// [`Session::evaluate_batch`] with an explicit worker count
    /// (`None` = one per available core). The scoped-thread pool claims
    /// requests off an atomic cursor; a single worker degenerates to a
    /// plain sequential loop.
    pub fn evaluate_batch_with(
        &self,
        reqs: &[FlowRequest],
        threads: Option<usize>,
    ) -> Vec<FlowResult> {
        let workers = threads
            .unwrap_or_else(default_threads)
            .clamp(1, reqs.len().max(1));
        if workers <= 1 {
            return reqs.iter().map(|r| self.evaluate(r)).collect();
        }
        let cursor = AtomicUsize::new(0);
        let slots: Vec<Mutex<Option<FlowResult>>> =
            reqs.iter().map(|_| Mutex::new(None)).collect();
        std::thread::scope(|scope| {
            for _ in 0..workers {
                scope.spawn(|| loop {
                    let i = cursor.fetch_add(1, Ordering::Relaxed);
                    if i >= reqs.len() {
                        break;
                    }
                    *slots[i].lock().unwrap() = Some(self.evaluate(&reqs[i]));
                });
            }
        });
        slots
            .into_iter()
            .map(|slot| {
                slot.into_inner()
                    .unwrap()
                    .expect("worker pool filled every slot")
            })
            .collect()
    }
}

/// Worker count when the caller does not specify one.
pub fn default_threads() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datatype::DataType;

    fn session() -> Session {
        Session::new(Platform::alveo_u280())
    }

    #[test]
    fn parsed_and_lowered_are_cached_per_degree() {
        let s = session();
        let src = KernelSource::builtin("helmholtz");
        let a = s.lowered(&src, 7).unwrap();
        let b = s.lowered(&src, 7).unwrap();
        assert!(Arc::ptr_eq(&a, &b), "same Arc from the cache");
        s.lowered(&src, 11).unwrap();
        let st = s.stats();
        assert_eq!(st.parsed_misses, 2);
        assert_eq!(st.lowered_misses, 2);
        assert_eq!(st.lowered_hits, 1);
    }

    #[test]
    fn mapped_is_shared_across_evaluation_kinds() {
        let s = session();
        let src = KernelSource::builtin("helmholtz");
        let opts = OlympusOpts::dataflow(7);
        let est = s.evaluate(&FlowRequest {
            source: src.clone(),
            p: 7,
            opts: opts.clone(),
            eval: EvalKind::Estimate,
        });
        let sim = s.evaluate(&FlowRequest {
            source: src.clone(),
            p: 7,
            opts,
            eval: EvalKind::Simulate { elements: 100_000 },
        });
        assert!(est.result.is_ok() && sim.result.is_ok());
        let st = s.stats();
        assert_eq!(st.mapped_misses, 1, "{st:?}");
        assert_eq!(st.mapped_hits, 1, "{st:?}");
        assert_eq!(st.lowered_misses, 1, "{st:?}");
    }

    #[test]
    fn distinct_options_map_separately() {
        let s = session();
        let src = KernelSource::builtin("helmholtz");
        s.mapped(&src, 7, &OlympusOpts::baseline()).unwrap();
        s.mapped(&src, 7, &OlympusOpts::dataflow(7)).unwrap();
        s.mapped(&src, 7, &OlympusOpts::baseline()).unwrap();
        let st = s.stats();
        assert_eq!(st.mapped_misses, 2);
        assert_eq!(st.mapped_hits, 1);
    }

    #[test]
    fn inline_edits_are_new_cache_entries() {
        let s = session();
        let a = KernelSource::inline(
            "k",
            "var input a : [3]\nvar input b : [3]\nvar output c : [3]\nc = a + b\n",
        );
        let b = KernelSource::inline(
            "k",
            "var input a : [3]\nvar input b : [3]\nvar output c : [3]\nc = a - b\n",
        );
        s.parsed(&a, 0).unwrap();
        s.parsed(&b, 0).unwrap();
        assert_eq!(s.stats().parsed_misses, 2, "texts differ, keys differ");
    }

    #[test]
    fn batch_results_come_back_in_request_order() {
        let s = session();
        let src = KernelSource::builtin("helmholtz");
        let reqs: Vec<FlowRequest> = [1usize, 2, 3, 17]
            .iter()
            .map(|&cus| FlowRequest {
                source: src.clone(),
                p: 7,
                opts: OlympusOpts::double_buffering().with_cus(cus),
                eval: EvalKind::Estimate,
            })
            .collect();
        let out = s.evaluate_batch_with(&reqs, Some(3));
        assert_eq!(out.len(), 4);
        for (r, want) in out.iter().zip([1usize, 2, 3, 17]) {
            assert_eq!(r.request.opts.num_cus, want);
        }
        // 17 CUs with double buffering exceeds the 16-channel-pair limit
        assert!(out[3].result.is_err());
        assert!(out[..3].iter().all(|r| r.result.is_ok()));
        let st = s.stats();
        assert_eq!(st.parsed_misses, 1);
        assert_eq!(st.lowered_misses, 1);
    }

    #[test]
    fn errors_carry_dtype_independent_reasons() {
        let s = session();
        let bad = KernelSource::builtin("warp-drive");
        let err = s
            .evaluate(&FlowRequest {
                source: bad,
                p: 7,
                opts: OlympusOpts::fixed_point(DataType::Fx32),
                eval: EvalKind::Estimate,
            })
            .result
            .unwrap_err();
        assert!(err.to_string().contains("unknown kernel"), "{err}");
    }
}
