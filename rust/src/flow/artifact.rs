//! Versioned JSON persistence for the pipeline's staged artifacts.
//!
//! Every [`Artifact`] document is self-contained: it embeds the
//! canonical program source plus the configuration that produced the
//! stage, and it records the stage's derived summary numbers. Reload
//! re-derives the in-memory values from the embedded source through the
//! same deterministic stage transitions, then cross-checks every
//! recorded section against the recomputation — so a reloaded artifact
//! is guaranteed to produce bit-identical downstream results, and an
//! artifact written by an incompatible pipeline build (or hand-edited)
//! is rejected instead of silently re-interpreted.
//!
//! Schema version policy: [`SCHEMA_VERSION`] bumps whenever a stage's
//! semantics change (new rewrite rules, different banking, a retimed
//! simulator). Readers reject any other version — there is no silent
//! migration, because the recorded numbers would no longer reproduce.

use std::path::Path;

use crate::datatype::DataType;
use crate::hls::Estimate;
use crate::olympus::{BusMode, CacheScheme, ChannelPolicy, MemoryKind, OlympusOpts, SystemSpec};
use crate::platform::{Platform, Resources};
use crate::sim::SimResult;
use crate::util::json::{self, Json};

use super::{
    parse_text, EvalKind, Evaluated, FlowError, Lowered, Mapped, Parsed, RewriteTrace,
};

/// Artifact document format version (see the module docs for the bump
/// policy). v3 added the `vitis` section on mapped artifacts.
pub const SCHEMA_VERSION: u64 = 3;

/// Any pipeline stage, wrapped for persistence.
#[derive(Debug, Clone)]
pub enum Artifact {
    Parsed(Parsed),
    Lowered(Lowered),
    Mapped(Mapped),
    Evaluated(Evaluated),
}

impl Artifact {
    /// The stage tag written into the document.
    pub fn stage(&self) -> &'static str {
        match self {
            Artifact::Parsed(_) => "parsed",
            Artifact::Lowered(_) => "lowered",
            Artifact::Mapped(_) => "mapped",
            Artifact::Evaluated(_) => "evaluated",
        }
    }

    /// Serialize to the versioned JSON document.
    pub fn to_json(&self) -> Json {
        let pv = match self {
            Artifact::Parsed(a) => &a.provenance,
            Artifact::Lowered(a) => &a.provenance,
            Artifact::Mapped(a) => &a.provenance,
            Artifact::Evaluated(a) => &a.provenance,
        };
        let mut pairs = vec![
            ("schema", Json::Num(SCHEMA_VERSION as f64)),
            ("stage", Json::str(self.stage())),
            ("kernel", Json::str(pv.kernel.as_str())),
            ("p", Json::Num(pv.p as f64)),
            ("fingerprint", Json::str(pv.fingerprint.as_str())),
            ("source", Json::str(pv.source.as_str())),
        ];
        match self {
            Artifact::Parsed(a) => {
                pairs.push(("rewrite", rewrite_json(&a.rewrite)));
            }
            Artifact::Lowered(a) => {
                pairs.push(("rewrite", rewrite_json(&a.rewrite)));
                pairs.push(("lowered", lowered_json(a)));
            }
            Artifact::Mapped(a) => {
                pairs.push(("rewrite", rewrite_json(&a.rewrite)));
                pairs.push(("opts", opts_to_json(&a.opts)));
                pairs.push(("platform", Json::str(a.platform.name.as_str())));
                pairs.push(("system", system_json(&a.spec)));
                pairs.push(("vitis", vitis_json(a)));
            }
            // evaluated artifacts record results, not the rewrite trace
            // (it is re-derived and unchecked on load)
            Artifact::Evaluated(a) => {
                pairs.push(("opts", opts_to_json(&a.opts)));
                pairs.push(("platform", Json::str(a.platform_name.as_str())));
                pairs.push(("eval", kind_json(a.kind)));
                pairs.push(("hls", hls_json(&a.hls)));
                pairs.push((
                    "sim",
                    match &a.sim {
                        Some(r) => sim_json(r),
                        None => Json::Null,
                    },
                ));
            }
        }
        Json::obj(pairs)
    }

    /// Reconstruct a stage from its document: re-derive from the
    /// embedded source and cross-check every recorded section.
    /// `origin` names the document in error messages.
    pub fn from_json(v: &Json, origin: &str) -> Result<Artifact, FlowError> {
        let schema = v
            .get("schema")
            .as_u64()
            .ok_or_else(|| FlowError::artifact(format!("{origin}: missing schema")))?;
        if schema != SCHEMA_VERSION {
            return Err(FlowError::artifact(format!(
                "{origin}: artifact schema v{schema}, this build reads v{SCHEMA_VERSION} \
                 (regenerate the artifact with this build)"
            )));
        }
        let stage = req_str(v, "stage", origin)?;
        if !["parsed", "lowered", "mapped", "evaluated"].contains(&stage) {
            return Err(FlowError::artifact(format!(
                "{origin}: unknown stage {stage} (parsed|lowered|mapped|evaluated)"
            )));
        }
        let kernel = req_str(v, "kernel", origin)?.to_string();
        let p = req_num(v, "p", origin)? as usize;
        let recorded_fp = req_str(v, "fingerprint", origin)?.to_string();
        let source = req_str(v, "source", origin)?.to_string();

        let parsed = parse_text(&kernel, origin, p, source)?;
        if parsed.provenance.fingerprint != recorded_fp {
            return Err(FlowError::artifact(format!(
                "{origin}: fingerprint {} does not match the embedded source ({}) — \
                 artifact edited?",
                recorded_fp, parsed.provenance.fingerprint
            )));
        }
        if stage != "evaluated" {
            verify(v, "rewrite", &rewrite_json(&parsed.rewrite), origin)?;
        }
        if stage == "parsed" {
            return Ok(Artifact::Parsed(parsed));
        }

        let lowered = parsed.lower()?;
        if stage == "lowered" {
            verify(v, "lowered", &lowered_json(&lowered), origin)?;
            return Ok(Artifact::Lowered(lowered));
        }

        let opts = opts_from_json(v.get("opts"))
            .map_err(|e| FlowError::artifact(format!("{origin}: opts: {e}")))?;
        let platform = platform_from_name(req_str(v, "platform", origin)?, origin)?;
        let mapped = lowered.map(&opts, &platform)?;
        match stage {
            "mapped" => {
                verify(v, "system", &system_json(&mapped.spec), origin)?;
                verify(v, "vitis", &vitis_json(&mapped), origin)?;
                Ok(Artifact::Mapped(mapped))
            }
            // the guard above admitted only the four known tags
            _ => {
                let kind = kind_from_json(v.get("eval"))
                    .map_err(|e| FlowError::artifact(format!("{origin}: eval: {e}")))?;
                let ev = mapped.evaluate(kind);
                verify(v, "hls", &hls_json(&ev.hls), origin)?;
                let sim = match &ev.sim {
                    Some(r) => sim_json(r),
                    None => Json::Null,
                };
                verify(v, "sim", &sim, origin)?;
                Ok(Artifact::Evaluated(ev))
            }
        }
    }

    /// Write the document to `path`.
    pub fn save(&self, path: impl AsRef<Path>) -> Result<(), FlowError> {
        let path = path.as_ref();
        std::fs::write(path, self.to_json().to_string()).map_err(|e| {
            FlowError::artifact(format!("cannot write {}: {e}", path.display()))
        })
    }

    /// Read and reconstruct a document from `path`.
    pub fn load(path: impl AsRef<Path>) -> Result<Artifact, FlowError> {
        let path = path.as_ref();
        let origin = format!("artifact {}", path.display());
        let text = std::fs::read_to_string(path)
            .map_err(|e| FlowError::artifact(format!("cannot read {}: {e}", path.display())))?;
        let v = json::parse(&text)
            .map_err(|e| FlowError::artifact(format!("{origin}: {e}")))?;
        Artifact::from_json(&v, &origin)
    }
}

// ---- section encoders (deterministic: BTreeMap key order) ----

fn rewrite_json(rw: &RewriteTrace) -> Json {
    Json::obj(vec![
        ("naive_flops", Json::Num(rw.naive_flops as f64)),
        ("optimized_flops", Json::Num(rw.optimized_flops as f64)),
    ])
}

fn lowered_json(l: &Lowered) -> Json {
    Json::obj(vec![
        ("nests", Json::Num(l.kernel.nests.len() as f64)),
        ("buffers", Json::Num(l.kernel.buffers.len() as f64)),
        (
            "flops_per_element",
            Json::Num(l.kernel.flops_per_element() as f64),
        ),
        (
            "max_read_degree",
            Json::Num(crate::ir::access::max_read_degree(&l.kernel) as f64),
        ),
        (
            "temp_lifetimes",
            Json::Num(l.liveness.intervals.iter().flatten().count() as f64),
        ),
        (
            "shareable_pairs",
            Json::Num(l.liveness.compat.len() as f64),
        ),
    ])
}

fn system_json(spec: &SystemSpec) -> Json {
    let mem = spec.memory.stats(&spec.kernel);
    let channels: Vec<Json> = spec
        .channels
        .iter()
        .map(|c| {
            Json::obj(vec![
                (
                    "read",
                    Json::Arr(c.read.iter().map(|&x| Json::Num(x as f64)).collect()),
                ),
                (
                    "write",
                    Json::Arr(c.write.iter().map(|&x| Json::Num(x as f64)).collect()),
                ),
            ])
        })
        .collect();
    Json::obj(vec![
        ("name", Json::str(spec.name.as_str())),
        ("lanes", Json::Num(spec.lanes as f64)),
        ("bus_bits", Json::Num(spec.bus_bits as f64)),
        ("serial_packing", Json::Bool(spec.serial_packing)),
        ("num_cus", Json::Num(spec.num_cus as f64)),
        ("batch_elements", Json::Num(spec.batch_elements as f64)),
        ("double_buffering", Json::Bool(spec.double_buffering)),
        ("dataflow", Json::Bool(spec.dataflow)),
        ("schedule_groups", Json::Num(spec.schedule.num_groups() as f64)),
        ("total_pcs", Json::Num(spec.total_pcs() as f64)),
        ("mem_banks", Json::Num(mem.banks as f64)),
        ("mem_shared_words", Json::Num(mem.shared_words as f64)),
        ("mem_unshared_words", Json::Num(mem.unshared_words as f64)),
        ("channels", Json::Arr(channels)),
    ])
}

/// Schema v3: the Vitis emission contract of a mapped system — emit
/// schema, package file list, and payload fingerprint. Verified on
/// load (like every section), so a reloaded artifact is guaranteed to
/// re-emit its package bit-exactly.
fn vitis_json(a: &Mapped) -> Json {
    let pkg = a.vitis_package();
    let files: Vec<Json> = pkg.files().iter().map(|(p, _)| Json::str(p.as_str())).collect();
    Json::obj(vec![
        ("emit_schema", Json::Num(crate::codegen::vitis::EMIT_SCHEMA_VERSION as f64)),
        ("files", Json::Arr(files)),
        ("fingerprint", Json::str(pkg.fingerprint())),
    ])
}

pub(crate) fn resources_json(r: &Resources) -> Json {
    Json::obj(vec![
        ("lut", Json::Num(r.lut as f64)),
        ("ff", Json::Num(r.ff as f64)),
        ("bram", Json::Num(r.bram as f64)),
        ("uram", Json::Num(r.uram as f64)),
        ("dsp", Json::Num(r.dsp as f64)),
    ])
}

fn hls_json(e: &Estimate) -> Json {
    Json::obj(vec![
        ("mults", Json::Num(e.mults as f64)),
        ("adds", Json::Num(e.adds as f64)),
        ("ii", Json::Num(e.ii as f64)),
        ("fmax_mhz", Json::Num(e.fmax_mhz)),
        ("slr_span", Json::Num(e.slr_span as f64)),
        ("per_cu", resources_json(&e.per_cu)),
        ("total", resources_json(&e.total)),
    ])
}

pub(crate) fn sim_json(r: &SimResult) -> Json {
    let stages: Vec<Json> = r
        .stage_intervals
        .iter()
        .map(|(name, cycles)| {
            Json::obj(vec![
                ("stage", Json::str(name.as_str())),
                ("cycles", Json::Num(*cycles as f64)),
            ])
        })
        .collect();
    let channels: Vec<Json> = r
        .channel_utilization
        .iter()
        .map(|(pc, u)| {
            Json::obj(vec![
                ("channel", Json::Num(*pc as f64)),
                ("utilization", Json::Num(*u)),
            ])
        })
        .collect();
    Json::obj(vec![
        ("label", Json::str(r.label.as_str())),
        ("total_time_s", Json::Num(r.total_time_s)),
        ("cu_time_s", Json::Num(r.cu_time_s)),
        ("transfer_time_s", Json::Num(r.transfer_time_s)),
        ("gflops_system", Json::Num(r.gflops_system)),
        ("gflops_cu", Json::Num(r.gflops_cu)),
        ("freq_mhz", Json::Num(r.freq_mhz)),
        ("ideal_gflops", Json::Num(r.ideal_gflops)),
        ("efficiency_vs_ideal", Json::Num(r.efficiency_vs_ideal)),
        ("avg_power_w", Json::Num(r.avg_power_w)),
        ("efficiency_gflops_w", Json::Num(r.efficiency_gflops_w)),
        ("energy_j", Json::Num(r.energy_j)),
        ("batches", Json::Num(r.batches as f64)),
        ("batch_elements", Json::Num(r.batch_elements as f64)),
        ("bottleneck", Json::str(r.bottleneck.as_str())),
        ("total_flops", Json::Num(r.total_flops as f64)),
        ("max_channel_utilization", Json::Num(r.max_channel_utilization)),
        ("switch_crossings", Json::Num(r.switch_crossings as f64)),
        ("hbm_fill_cycles", Json::Num(r.hbm_fill_cycles as f64)),
        ("conflict_stalls", Json::Num(r.conflict_stalls as f64)),
        ("mem_banks", Json::Num(r.mem_banks as f64)),
        ("mem_shared_words", Json::Num(r.mem_shared_words as f64)),
        ("mem_unshared_words", Json::Num(r.mem_unshared_words as f64)),
        ("stage_intervals", Json::Arr(stages)),
        ("channel_utilization", Json::Arr(channels)),
        // schema v2: closed-form bracket for analytic-mode results
        (
            "analytic",
            match r.analytic {
                Some(b) => Json::obj(vec![
                    ("lower_s", Json::Num(b.lower_s)),
                    ("upper_s", Json::Num(b.upper_s)),
                ]),
                None => Json::Null,
            },
        ),
    ])
}

/// Decode a [`resources_json`] section directly — no re-derivation.
pub(crate) fn resources_from_json(v: &Json) -> Result<Resources, String> {
    let n = |key: &str| v.get(key).as_u64().ok_or_else(|| format!("bad {key}"));
    Ok(Resources {
        lut: n("lut")?,
        ff: n("ff")?,
        bram: n("bram")?,
        uram: n("uram")?,
        dsp: n("dsp")?,
    })
}

/// Decode a [`sim_json`] section directly, *without* re-deriving it
/// from the embedded source the way [`Artifact::from_json`] does.
///
/// The dse sweep checkpoints use this: a resumed sweep must restore
/// thousands of per-point results without re-running the simulator
/// (that would defeat resuming). Rust's `f64` Display is
/// shortest-round-trip, so every float comes back bit-identical and
/// the restored frontier equals the uninterrupted one exactly.
pub(crate) fn sim_from_json(v: &Json) -> Result<SimResult, String> {
    let num = |key: &str| v.get(key).as_f64().ok_or_else(|| format!("bad {key}"));
    let int = |key: &str| v.get(key).as_u64().ok_or_else(|| format!("bad {key}"));
    let txt = |key: &str| {
        v.get(key)
            .as_str()
            .map(str::to_string)
            .ok_or_else(|| format!("bad {key}"))
    };
    let mut stage_intervals = Vec::new();
    for e in v
        .get("stage_intervals")
        .as_arr()
        .ok_or("bad stage_intervals")?
    {
        stage_intervals.push((
            e.get("stage").as_str().ok_or("bad stage")?.to_string(),
            e.get("cycles").as_u64().ok_or("bad cycles")?,
        ));
    }
    let mut channel_utilization = Vec::new();
    for e in v
        .get("channel_utilization")
        .as_arr()
        .ok_or("bad channel_utilization")?
    {
        channel_utilization.push((
            e.get("channel").as_u64().ok_or("bad channel")? as u32,
            e.get("utilization").as_f64().ok_or("bad utilization")?,
        ));
    }
    let analytic = match v.get("analytic") {
        Json::Null => None,
        b => Some(crate::sim::analytic::AnalyticBounds {
            lower_s: b.get("lower_s").as_f64().ok_or("bad analytic.lower_s")?,
            upper_s: b.get("upper_s").as_f64().ok_or("bad analytic.upper_s")?,
        }),
    };
    Ok(SimResult {
        label: txt("label")?,
        total_time_s: num("total_time_s")?,
        cu_time_s: num("cu_time_s")?,
        transfer_time_s: num("transfer_time_s")?,
        gflops_system: num("gflops_system")?,
        gflops_cu: num("gflops_cu")?,
        freq_mhz: num("freq_mhz")?,
        ideal_gflops: num("ideal_gflops")?,
        efficiency_vs_ideal: num("efficiency_vs_ideal")?,
        avg_power_w: num("avg_power_w")?,
        efficiency_gflops_w: num("efficiency_gflops_w")?,
        energy_j: num("energy_j")?,
        batches: int("batches")?,
        batch_elements: int("batch_elements")? as usize,
        stage_intervals,
        bottleneck: txt("bottleneck")?,
        total_flops: int("total_flops")?,
        channel_utilization,
        max_channel_utilization: num("max_channel_utilization")?,
        switch_crossings: int("switch_crossings")?,
        hbm_fill_cycles: int("hbm_fill_cycles")?,
        conflict_stalls: int("conflict_stalls")?,
        mem_banks: int("mem_banks")? as usize,
        mem_shared_words: int("mem_shared_words")? as usize,
        mem_unshared_words: int("mem_unshared_words")? as usize,
        analytic,
    })
}

fn kind_json(kind: EvalKind) -> Json {
    match kind {
        EvalKind::Estimate => Json::obj(vec![("kind", Json::str("estimate"))]),
        EvalKind::Simulate { elements } => Json::obj(vec![
            ("kind", Json::str("simulate")),
            ("elements", Json::Num(elements as f64)),
        ]),
        EvalKind::SimulateAnalytic { elements } => Json::obj(vec![
            ("kind", Json::str("simulate_analytic")),
            ("elements", Json::Num(elements as f64)),
        ]),
    }
}

fn kind_from_json(v: &Json) -> Result<EvalKind, String> {
    match v.get("kind").as_str() {
        Some("estimate") => Ok(EvalKind::Estimate),
        Some("simulate") => Ok(EvalKind::Simulate {
            elements: v
                .get("elements")
                .as_u64()
                .ok_or("simulate kind needs elements")?,
        }),
        Some("simulate_analytic") => Ok(EvalKind::SimulateAnalytic {
            elements: v
                .get("elements")
                .as_u64()
                .ok_or("simulate_analytic kind needs elements")?,
        }),
        other => Err(format!("unknown eval kind {other:?}")),
    }
}

/// Encode designer options; the exact inverse of [`opts_from_json`].
pub fn opts_to_json(o: &OlympusOpts) -> Json {
    let policy = match &o.channel_policy {
        ChannelPolicy::Pinned(pins) => Json::obj(vec![(
            "pinned",
            Json::Arr(
                pins.iter()
                    .map(|cu| {
                        Json::Arr(cu.iter().map(|&c| Json::Num(c as f64)).collect())
                    })
                    .collect(),
            ),
        )]),
        p => Json::str(p.name()),
    };
    Json::obj(vec![
        ("double_buffering", Json::Bool(o.double_buffering)),
        ("bus", Json::str(o.bus.name())),
        ("memory", Json::str(o.memory.name())),
        ("dataflow", opt_num(o.dataflow)),
        ("mem_sharing", Json::Bool(o.mem_sharing)),
        ("partition_cap", opt_num(o.partition_cap)),
        ("dtype", Json::str(o.dtype.name())),
        ("num_cus", Json::Num(o.num_cus as f64)),
        ("fifo_depth", opt_num(o.fifo_depth)),
        ("lut_mult_shift", Json::Bool(o.lut_mult_shift)),
        ("target_freq_mhz", Json::Num(o.target_freq_mhz)),
        ("channel_policy", policy),
        ("cache_scheme", Json::Str(o.cache_scheme.name())),
    ])
}

/// Decode designer options written by [`opts_to_json`].
pub fn opts_from_json(v: &Json) -> Result<OlympusOpts, String> {
    let bus_name = v.get("bus").as_str().ok_or("missing bus")?;
    let bus = BusMode::parse(bus_name).ok_or_else(|| format!("unknown bus {bus_name}"))?;
    let mem_name = v.get("memory").as_str().ok_or("missing memory")?;
    let memory =
        MemoryKind::parse(mem_name).ok_or_else(|| format!("unknown memory {mem_name}"))?;
    let dt_name = v.get("dtype").as_str().ok_or("missing dtype")?;
    let dtype =
        DataType::parse(dt_name).ok_or_else(|| format!("unknown dtype {dt_name}"))?;
    let channel_policy = match v.get("channel_policy") {
        Json::Str(s) => {
            ChannelPolicy::parse(s).ok_or_else(|| format!("unknown policy {s}"))?
        }
        pinned @ Json::Obj(_) => {
            let pins = pinned
                .get("pinned")
                .as_arr()
                .ok_or("pinned policy needs channel lists")?;
            let mut cus = Vec::new();
            for cu in pins {
                let list = cu.as_arr().ok_or("pinned entry must be an array")?;
                let mut chans = Vec::new();
                for c in list {
                    chans.push(c.as_u64().ok_or("pinned channel must be a number")? as u32);
                }
                cus.push(chans);
            }
            ChannelPolicy::Pinned(cus)
        }
        other => return Err(format!("bad channel_policy {other}")),
    };
    let cache_scheme = match v.get("cache_scheme") {
        // artifacts written before the irregular-access subsystem carry
        // no cache axis: the only scheme they could have generated
        Json::Null => CacheScheme::Bypass,
        Json::Str(s) => {
            CacheScheme::parse(s).ok_or_else(|| format!("unknown cache scheme {s}"))?
        }
        other => return Err(format!("bad cache_scheme {other}")),
    };
    Ok(OlympusOpts {
        double_buffering: req_bool(v, "double_buffering")?,
        bus,
        memory,
        dataflow: opt_usize(v, "dataflow")?,
        mem_sharing: req_bool(v, "mem_sharing")?,
        partition_cap: opt_usize(v, "partition_cap")?,
        dtype,
        num_cus: v.get("num_cus").as_u64().ok_or("missing num_cus")? as usize,
        fifo_depth: opt_usize(v, "fifo_depth")?,
        lut_mult_shift: req_bool(v, "lut_mult_shift")?,
        target_freq_mhz: v
            .get("target_freq_mhz")
            .as_f64()
            .ok_or("missing target_freq_mhz")?,
        channel_policy,
        cache_scheme,
    })
}

fn platform_from_name(name: &str, origin: &str) -> Result<Platform, FlowError> {
    match name {
        "xilinx_u280" => Ok(Platform::alveo_u280()),
        other => Err(FlowError::artifact(format!(
            "{origin}: unknown platform {other} (this build models xilinx_u280)"
        ))),
    }
}

// ---- decode / verify helpers ----

fn opt_num(x: Option<usize>) -> Json {
    match x {
        Some(n) => Json::Num(n as f64),
        None => Json::Null,
    }
}

fn opt_usize(v: &Json, key: &str) -> Result<Option<usize>, String> {
    match v.get(key) {
        Json::Null => Ok(None),
        Json::Num(n) => Ok(Some(*n as usize)),
        other => Err(format!("bad {key}: {other}")),
    }
}

fn req_bool(v: &Json, key: &str) -> Result<bool, String> {
    match v.get(key) {
        Json::Bool(b) => Ok(*b),
        other => Err(format!("bad {key}: {other}")),
    }
}

fn req_str<'a>(v: &'a Json, key: &str, origin: &str) -> Result<&'a str, FlowError> {
    v.get(key)
        .as_str()
        .ok_or_else(|| FlowError::artifact(format!("{origin}: missing {key}")))
}

fn req_num(v: &Json, key: &str, origin: &str) -> Result<f64, FlowError> {
    v.get(key)
        .as_f64()
        .ok_or_else(|| FlowError::artifact(format!("{origin}: missing {key}")))
}

/// A recorded section must equal its recomputation exactly — the drift
/// guard behind the schema version policy.
fn verify(v: &Json, key: &str, recomputed: &Json, origin: &str) -> Result<(), FlowError> {
    let recorded = v.get(key);
    if recorded != recomputed {
        return Err(FlowError::artifact(format!(
            "{origin}: recorded {key} section disagrees with this build's pipeline — \
             the artifact came from an incompatible build (schema policy: regenerate)"
        )));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::flow::Flow;
    use crate::kernels::KernelSource;

    fn pinned_opts() -> OlympusOpts {
        let mut o = OlympusOpts::fixed_point(DataType::Fx32).with_cus(2);
        o.partition_cap = Some(4);
        o.channel_policy = ChannelPolicy::Pinned(vec![vec![0, 1], vec![2, 3]]);
        o
    }

    #[test]
    fn opts_roundtrip_through_json() {
        for o in [
            OlympusOpts::baseline(),
            OlympusOpts::dataflow(7),
            OlympusOpts::mem_sharing(),
            OlympusOpts::bus_serial().on_ddr4(),
            pinned_opts(),
            OlympusOpts::baseline().with_cache_scheme(CacheScheme::Cached(128)),
            OlympusOpts::baseline().with_cache_scheme(CacheScheme::FullBuffer),
        ] {
            let j = opts_to_json(&o);
            let back = opts_from_json(&j).unwrap();
            assert_eq!(format!("{o:?}"), format!("{back:?}"), "{j}");
        }
    }

    #[test]
    fn pre_cache_artifacts_decode_to_bypass() {
        // an opts object written before the irregular-access subsystem
        // has no cache_scheme key; decoding defaults it to Bypass
        let mut j = opts_to_json(&OlympusOpts::baseline());
        if let Json::Obj(fields) = &mut j {
            fields.remove("cache_scheme");
        }
        let back = opts_from_json(&j).unwrap();
        assert_eq!(back.cache_scheme, CacheScheme::Bypass);
    }

    #[test]
    fn parsed_artifact_roundtrips_in_memory() {
        let parsed = Flow::from_source(KernelSource::builtin("helmholtz"))
            .parse(7)
            .unwrap();
        let j = Artifact::Parsed(parsed.clone()).to_json();
        let back = Artifact::from_json(&j, "test").unwrap();
        let Artifact::Parsed(b) = back else {
            panic!("stage changed");
        };
        assert_eq!(b.provenance, parsed.provenance);
        assert_eq!(b.module, parsed.module);
    }

    #[test]
    fn future_schema_versions_are_rejected() {
        let parsed = Flow::from_source(KernelSource::builtin("gradient"))
            .parse(8)
            .unwrap();
        let text = Artifact::Parsed(parsed).to_json().to_string();
        let bumped = text.replace(
            &format!("\"schema\":{SCHEMA_VERSION}"),
            "\"schema\":99",
        );
        assert_ne!(text, bumped, "replacement must hit");
        let v = json::parse(&bumped).unwrap();
        let err = Artifact::from_json(&v, "test").unwrap_err();
        assert!(err.to_string().contains("schema v99"), "{err}");
    }

    #[test]
    fn unknown_stages_are_rejected_up_front() {
        let parsed = Flow::from_source(KernelSource::builtin("gradient"))
            .parse(8)
            .unwrap();
        let text = Artifact::Parsed(parsed).to_json().to_string();
        let wrong = text.replace("\"stage\":\"parsed\"", "\"stage\":\"estimate\"");
        assert_ne!(text, wrong, "replacement must hit");
        let v = json::parse(&wrong).unwrap();
        let err = Artifact::from_json(&v, "test").unwrap_err();
        // named immediately — not a misleading missing-opts error later
        assert!(err.to_string().contains("unknown stage estimate"), "{err}");
    }

    #[test]
    fn tampered_fingerprints_are_rejected() {
        let parsed = Flow::from_source(KernelSource::builtin("gradient"))
            .parse(8)
            .unwrap();
        let fp = parsed.provenance.fingerprint.clone();
        let text = Artifact::Parsed(parsed).to_json().to_string();
        let tampered = text.replace(&fp, "0000000000000000");
        assert_ne!(text, tampered);
        let v = json::parse(&tampered).unwrap();
        let err = Artifact::from_json(&v, "test").unwrap_err();
        assert!(err.to_string().contains("fingerprint"), "{err}");
    }

    #[test]
    fn drifted_sections_are_rejected() {
        let lowered = Flow::from_source(KernelSource::builtin("helmholtz"))
            .parse(7)
            .unwrap()
            .lower()
            .unwrap();
        let text = Artifact::Lowered(lowered).to_json().to_string();
        // pretend a different build recorded fewer nests
        let drifted = text.replace("\"nests\":7", "\"nests\":6");
        assert_ne!(text, drifted, "helmholtz lowers to 7 nests");
        let v = json::parse(&drifted).unwrap();
        let err = Artifact::from_json(&v, "test").unwrap_err();
        assert!(err.to_string().contains("incompatible build"), "{err}");
    }

    #[test]
    fn save_and_load_roundtrip_on_disk() {
        let mapped = Flow::from_source(KernelSource::builtin("helmholtz"))
            .parse(7)
            .unwrap()
            .lower()
            .unwrap()
            .map(
                &OlympusOpts::fixed_point(DataType::Fx32),
                &Platform::alveo_u280(),
            )
            .unwrap();
        let path = std::env::temp_dir().join("hbmflow_artifact_unit.json");
        Artifact::Mapped(mapped.clone()).save(&path).unwrap();
        let back = Artifact::load(&path).unwrap();
        let Artifact::Mapped(b) = back else {
            panic!("stage changed");
        };
        assert_eq!(b.spec.name, mapped.spec.name);
        assert_eq!(b.spec.batch_elements, mapped.spec.batch_elements);
        assert_eq!(format!("{:?}", b.opts), format!("{:?}", mapped.opts));
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn sim_and_resources_sections_decode_directly_and_bit_exactly() {
        let mapped = Flow::from_source(KernelSource::builtin("helmholtz"))
            .parse(7)
            .unwrap()
            .lower()
            .unwrap()
            .map(&OlympusOpts::dataflow(7), &Platform::alveo_u280())
            .unwrap();
        // both simulation kinds: the event timeline (analytic: None)
        // and the closed-form path (analytic bracket present)
        for kind in [
            EvalKind::Simulate { elements: 100_000 },
            EvalKind::SimulateAnalytic { elements: 100_000 },
        ] {
            let ev = mapped.evaluate(kind);
            let sim = ev.sim.as_ref().unwrap();
            // through *text*, the way checkpoints store it
            let text = sim_json(sim).to_string();
            let back = sim_from_json(&json::parse(&text).unwrap()).unwrap();
            // f64 Display/Debug is shortest-round-trip: equal Debug
            // strings mean bit-identical values
            assert_eq!(format!("{sim:?}"), format!("{back:?}"));
            let r = resources_from_json(&resources_json(&ev.hls.total)).unwrap();
            assert_eq!(r, ev.hls.total);
        }
    }

    #[test]
    fn missing_files_report_the_path() {
        let err = Artifact::load("/no/such/artifact.json").unwrap_err();
        assert!(err.to_string().contains("/no/such/artifact.json"), "{err}");
    }
}
