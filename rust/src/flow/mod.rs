//! The typed compile pipeline — THE public API of the crate.
//!
//! The paper's tool flow is a sequence of well-defined stages (DSL →
//! lossless tensor rewriting → affine lowering → Olympus system
//! generation → Mnemosyne memory planning → HLS/sim evaluation). This
//! module exposes that pipeline as *typed staged artifacts* with
//! explicit fallible transitions, so every consumer — the CLI, the dse
//! explorer, the runtime coordinator, the examples — drives one
//! pipeline definition instead of re-wiring the stages by hand:
//!
//! ```text
//! Flow::from_source(KernelSource)
//!    │ parse(p)         parse + semantic check + lossless rewrite
//!    ▼
//! Parsed               AST + rewritten teil module + rewrite trace
//!    │ lower()          affine lowering + access/liveness analyses
//!    ▼
//! Lowered              affine kernel + access map + liveness
//!    │ map(opts, plat)  Olympus generation + Mnemosyne memory plan
//!    ▼
//! Mapped               SystemSpec (schedule, plan, routed channels)
//!    │ estimate() / simulate(n)
//!    ▼
//! Evaluated            HLS estimate, optionally a SimResult
//! ```
//!
//! Every stage is an owned, serializable value: [`Artifact`] wraps any
//! stage in a versioned JSON document (`util::json`) that embeds the
//! canonical program source, so artifacts persist to disk and reload to
//! values that produce bit-identical downstream results
//! (`hbmflow compile --save-artifact` / `--from-artifact`). On top of
//! the stages, [`Session`] is a thread-safe artifact cache keyed by
//! (source fingerprint, degree, options) that memoizes `Parsed` /
//! `Lowered` across configurations and `Mapped` across evaluation
//! kinds, and [`Session::evaluate_batch`] runs many configurations
//! concurrently over the shared cache.
//!
//! ```
//! use hbmflow::flow::Flow;
//! use hbmflow::kernels::KernelSource;
//! use hbmflow::olympus::OlympusOpts;
//! use hbmflow::platform::Platform;
//!
//! let flow = Flow::from_source(KernelSource::builtin("helmholtz"));
//! let mapped = flow
//!     .parse(7)?
//!     .lower()?
//!     .map(&OlympusOpts::dataflow(7), &Platform::alveo_u280())?;
//! let ev = mapped.estimate();
//! assert!(ev.hls.fmax_mhz > 0.0);
//! # Ok::<(), hbmflow::flow::FlowError>(())
//! ```

pub mod artifact;
pub mod session;

pub use artifact::{Artifact, SCHEMA_VERSION};
pub use session::{FlowRequest, FlowResult, Session, SessionStats};

use std::fmt;

use crate::coordinator::{GenericWorkload, OracleCheck};
use crate::dsl::{self, Program};
use crate::hls::{self, Estimate};
use crate::ir::affine::Kernel;
use crate::ir::{access, liveness, lower, rewrite, teil};
use crate::kernels::KernelSource;
use crate::olympus::{self, OlympusOpts, SystemSpec};
use crate::platform::Platform;
use crate::sim::{self, SimResult};

/// Which pipeline stage an error came from.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FlowStage {
    Parse,
    Lower,
    Map,
    Evaluate,
    Artifact,
}

impl FlowStage {
    pub fn name(self) -> &'static str {
        match self {
            FlowStage::Parse => "parse",
            FlowStage::Lower => "lower",
            FlowStage::Map => "map",
            FlowStage::Evaluate => "evaluate",
            FlowStage::Artifact => "artifact",
        }
    }
}

/// A failed stage transition: the stage that refused plus the reason
/// reported by the stage implementation (dsl/ir/olympus/mnemosyne).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FlowError {
    pub stage: FlowStage,
    pub message: String,
}

impl FlowError {
    pub(crate) fn parse(m: impl Into<String>) -> FlowError {
        FlowError {
            stage: FlowStage::Parse,
            message: m.into(),
        }
    }

    pub(crate) fn lower(m: impl Into<String>) -> FlowError {
        FlowError {
            stage: FlowStage::Lower,
            message: m.into(),
        }
    }

    pub(crate) fn map(m: impl Into<String>) -> FlowError {
        FlowError {
            stage: FlowStage::Map,
            message: m.into(),
        }
    }

    pub(crate) fn evaluate(m: impl Into<String>) -> FlowError {
        FlowError {
            stage: FlowStage::Evaluate,
            message: m.into(),
        }
    }

    pub(crate) fn artifact(m: impl Into<String>) -> FlowError {
        FlowError {
            stage: FlowStage::Artifact,
            message: m.into(),
        }
    }
}

impl fmt::Display for FlowError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}: {}", self.stage.name(), self.message)
    }
}

impl std::error::Error for FlowError {}

/// Where an artifact chain came from: the kernel's display name, the
/// degree it was generated at, the canonical program source, and the
/// FNV-1a fingerprint of (name, source) that keys the [`Session`] cache
/// and pins persisted artifacts to their program text.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Provenance {
    pub kernel: String,
    pub p: usize,
    /// Hex FNV-1a 64 over `kernel NUL source`.
    pub fingerprint: String,
    /// The exact CFDlang text the chain was built from (artifacts embed
    /// it, so a reload never depends on the original file still
    /// existing or being unchanged).
    pub source: String,
}

/// FNV-1a 64 fingerprint of a named program text, in hex.
pub fn fingerprint(kernel: &str, source: &str) -> String {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    let mut eat = |bytes: &[u8]| {
        for &b in bytes {
            h ^= b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
    };
    eat(kernel.as_bytes());
    eat(&[0]);
    eat(source.as_bytes());
    format!("{h:016x}")
}

/// What the lossless rewriter did to the program (paper §3.4.1): the
/// naive contraction cost versus the factorized mode-product cost.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RewriteTrace {
    pub naive_flops: u64,
    pub optimized_flops: u64,
}

/// Stage 1: the validated AST plus the rewritten teil module.
#[derive(Debug, Clone)]
pub struct Parsed {
    pub provenance: Provenance,
    /// Semantically validated CFDlang AST.
    pub program: Program,
    /// The rewritten (factorized, GEMM-shaped) teil module the hardware
    /// flow implements — also the numerics oracle's semantics.
    pub module: teil::Module,
    pub rewrite: RewriteTrace,
}

/// Stage 2: the affine kernel plus its access/liveness analyses.
#[derive(Debug, Clone)]
pub struct Lowered {
    pub provenance: Provenance,
    pub module: teil::Module,
    pub rewrite: RewriteTrace,
    /// Loop nests + buffers (the datapath the hardware implements).
    pub kernel: Kernel,
    /// Per-buffer parallel-read demand (drives Mnemosyne banking).
    pub access: access::AccessMap,
    /// Temp-buffer lifetimes (drives Mnemosyne sharing).
    pub liveness: liveness::Liveness,
}

/// Stage 3: the generated system for one `OlympusOpts` + platform.
#[derive(Debug, Clone)]
pub struct Mapped {
    pub provenance: Provenance,
    pub module: teil::Module,
    pub rewrite: RewriteTrace,
    pub opts: OlympusOpts,
    pub platform: Platform,
    /// Kernel, schedule, memory plan, routed channel map, batch sizing.
    pub spec: SystemSpec,
    /// The HLS estimate is a pure function of (spec, platform); computed
    /// once on first evaluation and reused across evaluation kinds —
    /// dse's adaptive two-pass (analytic screen, then exact sim for the
    /// survivors) re-evaluates the same `Mapped` and must not pay for a
    /// second estimate.
    estimate_cache: std::sync::OnceLock<Estimate>,
}

/// How to evaluate a mapped system.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EvalKind {
    /// HLS resource + frequency estimate only.
    Estimate,
    /// Estimate plus the cycle-approximate system simulation over
    /// `elements` spectral elements.
    Simulate { elements: u64 },
    /// Estimate plus the closed-form fast-path simulation
    /// (`sim::analytic`) over `elements` spectral elements: the
    /// result's makespan is a conservative upper bound and its
    /// `analytic` field carries the bracket.
    SimulateAnalytic { elements: u64 },
}

/// Stage 4: measured answers for one configuration.
#[derive(Debug, Clone)]
pub struct Evaluated {
    pub provenance: Provenance,
    pub opts: OlympusOpts,
    pub platform_name: String,
    pub kind: EvalKind,
    pub hls: Estimate,
    /// Present for [`EvalKind::Simulate`] and
    /// [`EvalKind::SimulateAnalytic`] requests.
    pub sim: Option<SimResult>,
}

/// Entry point: a program source about to enter the pipeline.
///
/// ```
/// use hbmflow::flow::Flow;
/// use hbmflow::kernels::KernelSource;
///
/// // any front-door source: builtin, .cfd file, or inline text
/// let src = "var input a : [4]\nvar input b : [4]\n\
///            var output c : [4]\nc = a + b\n";
/// let parsed = Flow::from_source(KernelSource::inline("axpy", src)).parse(0)?;
/// assert_eq!(parsed.provenance.kernel, "axpy");
/// let lowered = parsed.lower()?;
/// assert_eq!(lowered.kernel.nests.len(), 1);
/// # Ok::<(), hbmflow::flow::FlowError>(())
/// ```
#[derive(Debug, Clone)]
pub struct Flow {
    source: KernelSource,
}

impl Flow {
    pub fn from_source(source: KernelSource) -> Flow {
        Flow { source }
    }

    pub fn source(&self) -> &KernelSource {
        &self.source
    }

    /// Stage transition: resolve the source text at degree `p`, parse,
    /// semantically validate, and run the lossless rewriter.
    pub fn parse(&self, p: usize) -> Result<Parsed, FlowError> {
        let text = self.source.source(p).map_err(FlowError::parse)?;
        parse_text(&self.source.name(), &self.source.origin(), p, text)
    }
}

/// Parse + rewrite a resolved program text (shared by [`Flow::parse`],
/// the [`Session`] cache, and artifact reload, so all three produce the
/// same `Parsed` value for the same text).
pub(crate) fn parse_text(
    kernel: &str,
    origin: &str,
    p: usize,
    source: String,
) -> Result<Parsed, FlowError> {
    let program =
        dsl::parse(&source).map_err(|e| FlowError::parse(format!("{origin}: {e}")))?;
    let naive = teil::from_ast(&program)
        .map_err(|e| FlowError::parse(format!("{origin}: {e}")))?;
    let naive_flops = naive.flops();
    let module = rewrite::optimize(naive);
    let rewrite = RewriteTrace {
        naive_flops,
        optimized_flops: module.flops(),
    };
    Ok(Parsed {
        provenance: Provenance {
            kernel: kernel.to_string(),
            p,
            fingerprint: fingerprint(kernel, &source),
            source,
        },
        program,
        module,
        rewrite,
    })
}

impl Parsed {
    /// Stage transition: lower the rewritten module to the affine
    /// kernel and run the access/liveness analyses the memory planner
    /// consumes.
    pub fn lower(&self) -> Result<Lowered, FlowError> {
        let kernel = lower::lower_kernel(&self.module, &self.provenance.kernel)
            .map_err(|e| FlowError::lower(format!("{}: {e}", self.provenance.kernel)))?;
        let access = access::analyze(&kernel);
        let liveness = liveness::analyze(&kernel);
        Ok(Lowered {
            provenance: self.provenance.clone(),
            module: self.module.clone(),
            rewrite: self.rewrite,
            kernel,
            access,
            liveness,
        })
    }
}

impl Lowered {
    /// Stage transition: generate the system architecture (compute
    /// units, lanes, schedule, memory plan, routed channels, batch
    /// sizing) for one option set on one platform.
    pub fn map(&self, opts: &OlympusOpts, platform: &Platform) -> Result<Mapped, FlowError> {
        let spec =
            olympus::generate(&self.kernel, opts, platform).map_err(FlowError::map)?;
        Ok(Mapped {
            provenance: self.provenance.clone(),
            module: self.module.clone(),
            rewrite: self.rewrite,
            opts: opts.clone(),
            platform: platform.clone(),
            spec,
            estimate_cache: std::sync::OnceLock::new(),
        })
    }
}

impl Mapped {
    /// The memoized HLS estimate (computed on first use; see
    /// `estimate_cache`).
    fn hls_estimate(&self) -> &Estimate {
        self.estimate_cache
            .get_or_init(|| hls::estimate(&self.spec, &self.platform))
    }

    /// Stage transition: estimate, and for the simulating
    /// [`EvalKind`]s also simulate, the generated system. Infallible —
    /// a `Mapped` value is already a validated system.
    pub fn evaluate(&self, kind: EvalKind) -> Evaluated {
        let hls = self.hls_estimate().clone();
        let sim = match kind {
            EvalKind::Estimate => None,
            EvalKind::Simulate { elements } => {
                Some(sim::simulate(&self.spec, &hls, &self.platform, elements))
            }
            EvalKind::SimulateAnalytic { elements } => Some(
                sim::analytic::simulate_analytic(&self.spec, &hls, &self.platform, elements),
            ),
        };
        Evaluated {
            provenance: self.provenance.clone(),
            opts: self.opts.clone(),
            platform_name: self.platform.name.clone(),
            kind,
            hls,
            sim,
        }
    }

    /// HLS resource + frequency estimate only.
    pub fn estimate(&self) -> Evaluated {
        self.evaluate(EvalKind::Estimate)
    }

    /// Estimate plus the cycle-approximate system simulation.
    pub fn simulate(&self, elements: u64) -> Evaluated {
        self.evaluate(EvalKind::Simulate { elements })
    }

    /// Estimate plus the closed-form fast-path simulation.
    pub fn simulate_analytic(&self, elements: u64) -> Evaluated {
        self.evaluate(EvalKind::SimulateAnalytic { elements })
    }

    /// The Vitis package for this system (see `codegen::vitis`):
    /// kernel C++, host, link cfg, Makefile, and manifest, rendered
    /// in memory. Byte-deterministic for a given system.
    pub fn vitis_package(&self) -> crate::codegen::vitis::VitisPackage {
        crate::codegen::vitis::emit(&self.spec, &self.platform)
    }

    /// Stage exit: write the Vitis package under `dir`, creating the
    /// `src/` subdirectory as needed. Returns the written paths.
    pub fn emit_vitis(
        &self,
        dir: impl AsRef<std::path::Path>,
    ) -> Result<Vec<std::path::PathBuf>, FlowError> {
        self.vitis_package()
            .write_to(dir.as_ref())
            .map_err(FlowError::artifact)
    }

    /// The generic numerics oracle: the lowered kernel interpreted on
    /// seeded inputs versus `teil::eval` of the rewritten module.
    pub fn oracle(&self, seed: u64, elements: usize) -> Result<OracleCheck, FlowError> {
        GenericWorkload::new(
            &self.provenance.kernel,
            self.module.clone(),
            self.spec.kernel.clone(),
            seed,
        )
        .check(elements)
        .map_err(FlowError::evaluate)
    }
}

impl Evaluated {
    /// The simulation result, when this evaluation ran one.
    pub fn sim(&self) -> Option<&SimResult> {
        self.sim.as_ref()
    }
}

/// Stage 3, composed form: several lowered kernels fused on one device
/// as a FIFO-chained pipeline (DESIGN.md §2.10). The composed analog of
/// [`Mapped`] — produced by [`compose`], evaluated by
/// [`Composed::simulate`].
#[derive(Debug, Clone)]
pub struct Composed {
    /// Per-member provenance, in pipeline order.
    pub provenance: Vec<Provenance>,
    /// The option set every member was generated with.
    pub opts: OlympusOpts,
    pub platform: Platform,
    /// Partitioned channels, common batch, link FIFOs, pooled resources.
    pub system: olympus::ComposedSystem,
}

/// Stage transition: place several lowered kernels on one device. The
/// members share one `OlympusOpts` (each gets its own generated system;
/// `olympus::compose` partitions the channels, aligns the batch, sizes
/// the link FIFOs, and checks the pooled resource budget).
pub fn compose(
    stages: &[Lowered],
    opts: &OlympusOpts,
    platform: &Platform,
) -> Result<Composed, FlowError> {
    let members: Vec<(&Kernel, OlympusOpts)> = stages
        .iter()
        .map(|l| (&l.kernel, opts.clone()))
        .collect();
    let system = olympus::compose(&members, platform).map_err(FlowError::map)?;
    Ok(Composed {
        provenance: stages.iter().map(|l| l.provenance.clone()).collect(),
        opts: opts.clone(),
        platform: platform.clone(),
        system,
    })
}

impl Composed {
    /// Run the composed pipeline simulation: FIFO-routed event timeline,
    /// closed-form bracket, and the time-multiplexed baseline.
    pub fn simulate(&self, elements: u64) -> sim::compose::ComposedSimResult {
        sim::compose::simulate_composed(&self.system, &self.platform, elements)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stages_chain_for_a_builtin() {
        let flow = Flow::from_source(KernelSource::builtin("helmholtz"));
        let parsed = flow.parse(7).unwrap();
        assert_eq!(parsed.provenance.kernel, "helmholtz");
        assert_eq!(parsed.provenance.p, 7);
        assert!(parsed.rewrite.optimized_flops < parsed.rewrite.naive_flops);
        let lowered = parsed.lower().unwrap();
        assert!(!lowered.kernel.nests.is_empty());
        assert_eq!(lowered.access.read_degree.len(), lowered.kernel.buffers.len());
        let mapped = lowered
            .map(&OlympusOpts::dataflow(7), &Platform::alveo_u280())
            .unwrap();
        assert_eq!(mapped.spec.schedule.num_groups(), 7);
        let ev = mapped.simulate(100_000);
        assert!(ev.sim().is_some());
        assert!(ev.sim().unwrap().gflops_system > 0.0);
        assert!(ev.hls.fmax_mhz > 0.0);
    }

    #[test]
    fn estimate_kind_skips_the_simulation() {
        let mapped = Flow::from_source(KernelSource::builtin("gradient"))
            .parse(8)
            .unwrap()
            .lower()
            .unwrap()
            .map(&OlympusOpts::baseline(), &Platform::alveo_u280())
            .unwrap();
        let ev = mapped.estimate();
        assert_eq!(ev.kind, EvalKind::Estimate);
        assert!(ev.sim().is_none());
        assert!(ev.hls.ops() > 0);
    }

    #[test]
    fn parse_errors_name_the_stage_and_origin() {
        let bad = KernelSource::inline("bad", "var input a : [2]\na = = a\n");
        let err = Flow::from_source(bad).parse(0).unwrap_err();
        assert_eq!(err.stage, FlowStage::Parse);
        assert!(err.to_string().starts_with("parse:"), "{err}");
        assert!(err.to_string().contains("inline bad"), "{err}");
    }

    #[test]
    fn map_errors_carry_the_olympus_reason() {
        let lowered = Flow::from_source(KernelSource::builtin("helmholtz"))
            .parse(7)
            .unwrap()
            .lower()
            .unwrap();
        let err = lowered
            .map(
                &OlympusOpts::double_buffering().with_cus(17),
                &Platform::alveo_u280(),
            )
            .unwrap_err();
        assert_eq!(err.stage, FlowStage::Map);
        assert!(err.to_string().contains("num_cus"), "{err}");
    }

    #[test]
    fn fingerprints_separate_name_text_and_degree() {
        let a = fingerprint("k", "x = y\n");
        assert_eq!(a, fingerprint("k", "x = y\n"));
        assert_ne!(a, fingerprint("k2", "x = y\n"));
        assert_ne!(a, fingerprint("k", "x = z\n"));
        // builtins fold p into the generated text, so degrees differ too
        let h7 = Flow::from_source(KernelSource::builtin("helmholtz"))
            .parse(7)
            .unwrap();
        let h11 = Flow::from_source(KernelSource::builtin("helmholtz"))
            .parse(11)
            .unwrap();
        assert_ne!(h7.provenance.fingerprint, h11.provenance.fingerprint);
    }

    #[test]
    fn composed_stage_fuses_lowered_kernels() {
        let lowered: Vec<Lowered> = ["interpolation", "gradient"]
            .iter()
            .map(|k| {
                Flow::from_source(KernelSource::builtin(k))
                    .parse(7)
                    .unwrap()
                    .lower()
                    .unwrap()
            })
            .collect();
        let c = compose(&lowered, &OlympusOpts::baseline(), &Platform::alveo_u280())
            .unwrap();
        assert_eq!(c.system.stages.len(), 2);
        assert_eq!(c.provenance.len(), 2);
        let r = c.simulate(10_000);
        assert!(r.total_s > 0.0);
        assert!(r.analytic.brackets(r.total_s), "{:?} vs {}", r.analytic, r.total_s);
        // a compose failure reports through the map stage
        let err = compose(&[], &OlympusOpts::baseline(), &Platform::alveo_u280())
            .unwrap_err();
        assert_eq!(err.stage, FlowStage::Map);
    }

    #[test]
    fn oracle_is_exact_for_f64_lowering() {
        let mapped = Flow::from_source(KernelSource::builtin("helmholtz"))
            .parse(7)
            .unwrap()
            .lower()
            .unwrap()
            .map(&OlympusOpts::baseline(), &Platform::alveo_u280())
            .unwrap();
        let o = mapped.oracle(2024, 2).unwrap();
        assert_eq!(o.mse, 0.0, "exact lowering: {}", o.mse);
    }
}
