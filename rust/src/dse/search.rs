//! Budget-aware, resumable search over a [`SearchSpace`] (DESIGN.md
//! §2.8).
//!
//! The eager explorer ([`crate::dse::explore`]) materializes and
//! evaluates the whole cross product — fine for the paper's ~2k-point
//! helmholtz space, hopeless for a realistic multi-kernel sweep. This
//! engine replaces it with a streaming pipeline:
//!
//!  * candidates come from a pluggable [`Strategy`] — the lazy
//!    exhaustive stream ([`SearchSpace::candidates`]), seeded uniform
//!    sampling, Latin-hypercube sampling, or a hill-climb refinement
//!    seeded from an LHS frontier;
//!  * every batch goes through the PR 6 analytic screen first: a
//!    candidate whose *optimistic* objective vector (analytic lower
//!    bound) is dominated by a batch rival's *conservative* vector
//!    (upper bound) — or by a frontier member's exact vector — is
//!    provably dominated for any true makespans inside the brackets
//!    and never reaches the event simulator;
//!  * the Pareto frontier is maintained incrementally
//!    ([`super::pareto::Frontier`]); only frontier members stay
//!    resident, so peak memory is O(batch + frontier) regardless of
//!    how many points the sweep considers;
//!  * after every batch the sweep state (cursor, counters, frontier
//!    members with their full evaluations) is persisted as a versioned
//!    checkpoint ([`super::checkpoint`]); a killed sweep resumes where
//!    it stopped by *replaying* the deterministic candidate sequence
//!    without re-evaluating anything before the cursor.
//!
//! Frontier equivalence: with [`Strategy::Stream`] and pruning on, the
//! final frontier is bit-identical to the eager
//! [`crate::dse::Fidelity::Exact`] frontier. Pruning only ever removes
//! truly dominated candidates (the bracket argument above; domination
//! chains terminate at an exactly-evaluated survivor), the incremental
//! frontier equals the batch pairwise scan, and frontier members always
//! carry full event-simulation numbers from the same code path — so
//! even the float bits agree. `tests/dse_search.rs` pins all of it.

use std::collections::{HashMap, HashSet};
use std::path::PathBuf;

use crate::flow;
use crate::kernels::KernelSource;
use crate::platform::Platform;
use crate::util::json::Json;
use crate::util::prng::Prng;

use super::checkpoint::{self, Checkpoint};
use super::eval::{self, EvalOutcome};
use super::pareto::{self, Frontier};
use super::space::{coherent, DegreeMap, DesignPoint, SearchSpace};
use super::Exploration;

/// Sample count when a sampling strategy is given no `--budget`.
pub const DEFAULT_SAMPLE_BUDGET: usize = 256;

/// How the sweep walks the space.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Strategy {
    /// Exhaustive, in enumeration order, lazily streamed. With pruning
    /// on this reproduces the eager exact frontier bit-for-bit.
    #[default]
    Stream,
    /// Seeded uniform sampling over the axis lists (duplicate and
    /// incoherent draws are discarded, so fewer than `budget` points
    /// may come back from a small space).
    Random,
    /// Latin-hypercube sampling: every axis is stratified across the
    /// sample count, so `budget` points cover each axis evenly instead
    /// of clumping the way independent uniform draws do.
    Lhs,
    /// LHS seeding with half the budget, then greedy refinement: each
    /// round mutates one axis of every current frontier member and
    /// evaluates the unseen neighbors until the budget is spent.
    HillClimb,
}

impl Strategy {
    pub fn parse(s: &str) -> Option<Strategy> {
        match s {
            "stream" => Some(Strategy::Stream),
            "random" => Some(Strategy::Random),
            "lhs" => Some(Strategy::Lhs),
            "hillclimb" | "hill-climb" => Some(Strategy::HillClimb),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Strategy::Stream => "stream",
            Strategy::Random => "random",
            Strategy::Lhs => "lhs",
            Strategy::HillClimb => "hillclimb",
        }
    }
}

/// Everything that parameterizes a sweep besides the space itself.
#[derive(Debug, Clone)]
pub struct SearchConfig {
    pub strategy: Strategy,
    /// PRNG seed for the sampling strategies; the same seed reproduces
    /// the same candidate sequence exactly (and therefore the same
    /// report), independent of thread count.
    pub seed: u64,
    /// Budget semantics per strategy: `Stream` caps the candidates
    /// considered (`None` = the whole space); `Random`/`Lhs` is the
    /// sample count (`None` = [`DEFAULT_SAMPLE_BUDGET`]); `HillClimb`
    /// is the total evaluation budget, half spent on LHS seeding.
    pub budget: Option<usize>,
    /// Candidates evaluated (and checkpointed) per batch.
    pub batch: usize,
    /// Worker threads per batch (`None` = one per core). Results are
    /// deterministic regardless.
    pub threads: Option<usize>,
    /// Analytic screen on (the default). Off = every candidate pays
    /// for full event simulation (the CLI's `--exact`).
    pub prune: bool,
    /// Checkpoint file: loaded (if present) before the sweep and
    /// rewritten atomically after every batch.
    pub checkpoint: Option<PathBuf>,
    /// Stop after this many batches *this invocation* (the kill switch
    /// resumability tests — and patient users — script against).
    pub stop_after: Option<usize>,
}

impl Default for SearchConfig {
    fn default() -> SearchConfig {
        SearchConfig {
            strategy: Strategy::Stream,
            seed: 0,
            budget: None,
            batch: 64,
            threads: None,
            prune: true,
            checkpoint: None,
            stop_after: None,
        }
    }
}

/// Counters describing everything a sweep considered (the resident
/// `outcomes` hold only frontier members).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct SweepStats {
    /// Candidates taken off the stream (evaluated at least analytically).
    pub considered: usize,
    pub feasible: usize,
    pub over_budget: usize,
    /// Candidates Olympus refused to generate.
    pub rejected: usize,
    /// Feasible candidates the analytic screen proved dominated — they
    /// never reached the event simulator.
    pub pruned: usize,
    /// Full event simulations actually run.
    pub exact_sims: usize,
    /// Max simultaneously-resident evaluated points (batch + exact
    /// survivors + retained frontier) — the memory-boundedness witness.
    pub peak_resident: usize,
    /// Max frontier size ever held.
    pub frontier_peak: usize,
    /// Cursor this invocation resumed from, if it restored a checkpoint.
    pub resumed_from: Option<usize>,
    /// The stream was exhausted (or the budget spent); a `false` here
    /// means the sweep stopped early and can be resumed.
    pub complete: bool,
}

impl SweepStats {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("considered", Json::num(self.considered as f64)),
            ("feasible", Json::num(self.feasible as f64)),
            ("over_budget", Json::num(self.over_budget as f64)),
            ("rejected", Json::num(self.rejected as f64)),
            ("pruned", Json::num(self.pruned as f64)),
            ("exact_sims", Json::num(self.exact_sims as f64)),
            ("peak_resident", Json::num(self.peak_resident as f64)),
            ("frontier_peak", Json::num(self.frontier_peak as f64)),
            (
                "resumed_from",
                match self.resumed_from {
                    Some(c) => Json::num(c as f64),
                    None => Json::Null,
                },
            ),
            ("complete", Json::Bool(self.complete)),
        ])
    }

    pub fn from_json(v: &Json) -> Result<SweepStats, String> {
        let n = |key: &str| {
            v.get(key)
                .as_u64()
                .map(|x| x as usize)
                .ok_or_else(|| format!("bad {key}"))
        };
        Ok(SweepStats {
            considered: n("considered")?,
            feasible: n("feasible")?,
            over_budget: n("over_budget")?,
            rejected: n("rejected")?,
            pruned: n("pruned")?,
            exact_sims: n("exact_sims")?,
            peak_resident: n("peak_resident")?,
            frontier_peak: n("frontier_peak")?,
            resumed_from: match v.get("resumed_from") {
                Json::Null => None,
                x => Some(x.as_u64().ok_or("bad resumed_from")? as usize),
            },
            complete: matches!(v.get("complete"), Json::Bool(true)),
        })
    }
}

/// [`search_in`] over a throwaway session.
pub fn search(
    space: &SearchSpace,
    platform: &Platform,
    n_elements: u64,
    cfg: &SearchConfig,
) -> Result<Exploration, String> {
    search_in(&flow::Session::new(platform.clone()), space, n_elements, cfg)
}

/// Run a budget-aware sweep over a caller-owned session. The returned
/// [`Exploration`] holds only the frontier members as outcomes (in
/// first-admission order) plus the sweep counters in `stats`.
pub fn search_in(
    session: &flow::Session,
    space: &SearchSpace,
    n_elements: u64,
    cfg: &SearchConfig,
) -> Result<Exploration, String> {
    if cfg.batch == 0 {
        return Err("batch size must be at least 1".into());
    }
    if cfg.strategy == Strategy::HillClimb && cfg.checkpoint.is_some() {
        return Err("hill-climb sweeps are not resumable (refinement depends \
                    on evaluated results); drop --resume or use \
                    stream/random/lhs"
            .into());
    }
    let source = space.source.snapshot()?;
    let info = super::degree_map(session, &source, &space.degrees)?;
    let key = checkpoint::space_key(space, &info, session.platform(), n_elements, cfg);

    let mut sweep = Sweep {
        session,
        source: &source,
        n_elements,
        cfg,
        key,
        frontier: Frontier::new(),
        kept: HashMap::new(),
        stats: SweepStats::default(),
        cursor: 0,
    };

    if let Some(path) = &cfg.checkpoint {
        if path.exists() {
            let ck = checkpoint::load(path, &sweep.key)?;
            sweep.restore(ck);
            if sweep.stats.complete {
                return Ok(sweep.finish(space));
            }
        }
    }

    match cfg.strategy {
        Strategy::Stream => {
            let mut stream: Box<dyn Iterator<Item = DesignPoint> + '_> =
                match cfg.budget {
                    Some(b) => Box::new(space.candidates(&info).take(b)),
                    None => Box::new(space.candidates(&info)),
                };
            sweep.run_stream(&mut stream)?;
        }
        Strategy::Random => {
            let budget = cfg.budget.unwrap_or(DEFAULT_SAMPLE_BUDGET);
            let pts = random_sample(space, &info, budget, cfg.seed);
            sweep.run_stream(&mut pts.into_iter())?;
        }
        Strategy::Lhs => {
            let budget = cfg.budget.unwrap_or(DEFAULT_SAMPLE_BUDGET);
            let pts = lhs_sample(space, &info, budget, cfg.seed);
            sweep.run_stream(&mut pts.into_iter())?;
        }
        Strategy::HillClimb => sweep.run_hillclimb(space, &info)?,
    }
    Ok(sweep.finish(space))
}

/// One in-flight sweep: the incremental frontier, the retained outcomes
/// (frontier members only), and the stream cursor.
struct Sweep<'a> {
    session: &'a flow::Session,
    source: &'a KernelSource,
    n_elements: u64,
    cfg: &'a SearchConfig,
    key: String,
    frontier: Frontier,
    kept: HashMap<usize, EvalOutcome>,
    stats: SweepStats,
    cursor: usize,
}

impl Sweep<'_> {
    fn restore(&mut self, ck: Checkpoint) {
        for (seq, point, ev) in ck.frontier {
            let v = pareto::objectives(&ev);
            if self.frontier.offer(seq, v) {
                self.kept.insert(
                    seq,
                    EvalOutcome {
                        point,
                        result: Ok(ev),
                    },
                );
            }
        }
        self.stats = ck.stats;
        self.stats.resumed_from = Some(ck.cursor);
        self.cursor = ck.cursor;
    }

    /// Drive a deterministic candidate stream through batched
    /// screen-evaluate-offer rounds, checkpointing after each.
    fn run_stream(
        &mut self,
        stream: &mut dyn Iterator<Item = DesignPoint>,
    ) -> Result<(), String> {
        // resume-by-replay: candidates before the cursor were already
        // evaluated by the previous invocation — skip, never re-evaluate
        for _ in 0..self.cursor {
            if stream.next().is_none() {
                break;
            }
        }
        let mut batches = 0usize;
        loop {
            if self.cfg.stop_after.is_some_and(|lim| batches >= lim) {
                break;
            }
            let batch: Vec<DesignPoint> =
                stream.by_ref().take(self.cfg.batch).collect();
            if batch.is_empty() {
                self.stats.complete = true;
            } else {
                self.process_batch(batch);
                batches += 1;
            }
            self.save()?;
            if self.stats.complete {
                break;
            }
        }
        Ok(())
    }

    fn run_hillclimb(
        &mut self,
        space: &SearchSpace,
        info: &DegreeMap,
    ) -> Result<(), String> {
        let budget = self.cfg.budget.unwrap_or(DEFAULT_SAMPLE_BUDGET).max(1);
        let seeds = lhs_sample(space, info, (budget / 2).max(1), self.cfg.seed);
        let mut seen: HashSet<String> =
            seeds.iter().map(|pt| pt.fingerprint()).collect();
        for chunk in seeds.chunks(self.cfg.batch) {
            self.process_batch(chunk.to_vec());
        }
        // refinement: one single-axis mutation per frontier member per
        // round; unseen coherent neighbors are evaluated as a batch
        let mut rng = Prng::new(self.cfg.seed ^ 0x9e37_79b9_7f4a_7c15);
        while self.stats.considered < budget {
            let room = (budget - self.stats.considered).min(self.cfg.batch);
            let members: Vec<DesignPoint> = self
                .frontier
                .keys()
                .iter()
                .map(|k| self.kept[k].point.clone())
                .collect();
            let mut neighbors = Vec::new();
            for m in &members {
                if neighbors.len() >= room {
                    break;
                }
                if let Some(nb) = mutate(space, info, m, &mut rng) {
                    if seen.insert(nb.fingerprint()) {
                        neighbors.push(nb);
                    }
                }
            }
            if neighbors.is_empty() {
                break;
            }
            self.process_batch(neighbors);
        }
        self.stats.complete = true;
        Ok(())
    }

    fn process_batch(&mut self, points: Vec<DesignPoint>) {
        let base = self.cursor;
        let n = points.len();
        self.stats.considered += n;
        self.cursor += n;
        let (outcomes, exact_mask, survivors) = if self.cfg.prune {
            self.screened(points)
        } else {
            let outs = eval::evaluate(
                self.session,
                self.source,
                points,
                self.n_elements,
                self.cfg.threads,
            );
            self.stats.exact_sims += outs.len();
            let mask = vec![true; outs.len()];
            let survivors = outs.len();
            (outs, mask, survivors)
        };
        for (bi, o) in outcomes.iter().enumerate() {
            if o.result.is_err() {
                self.stats.rejected += 1;
                continue;
            }
            if !o.is_feasible() {
                self.stats.over_budget += 1;
                continue;
            }
            self.stats.feasible += 1;
            // pruned candidates carry conservative analytic numbers —
            // they are provably dominated and never join the frontier
            if !exact_mask[bi] {
                continue;
            }
            let v = pareto::objectives(o.result.as_ref().unwrap());
            if self.frontier.offer(base + bi, v) {
                self.kept.insert(base + bi, o.clone());
            }
        }
        let keys: HashSet<usize> = self.frontier.keys().into_iter().collect();
        self.kept.retain(|k, _| keys.contains(k));
        self.stats.frontier_peak =
            self.stats.frontier_peak.max(self.frontier.peak_len());
        self.stats.peak_resident = self
            .stats
            .peak_resident
            .max(n + survivors + self.kept.len());
    }

    /// The analytic screen over one batch: evaluate everything with
    /// the closed-form bounds, prove what can be proven dominated
    /// (against batch rivals' conservative vectors *and* the current
    /// frontier's exact vectors), then run the event simulator only
    /// for the survivors.
    fn screened(
        &mut self,
        points: Vec<DesignPoint>,
    ) -> (Vec<EvalOutcome>, Vec<bool>, usize) {
        let mut outs = eval::evaluate_analytic(
            self.session,
            self.source,
            points,
            self.n_elements,
            self.cfg.threads,
        );
        let feas: Vec<usize> =
            (0..outs.len()).filter(|&i| outs[i].is_feasible()).collect();
        let vectors: Vec<Option<(Vec<f64>, Vec<f64>)>> = feas
            .iter()
            .map(|&i| {
                let e = outs[i].result.as_ref().unwrap();
                e.sim.analytic.map(|b| {
                    (
                        pareto::objectives_with_time(e, b.lower_s),
                        pareto::objectives_with_time(e, b.upper_s),
                    )
                })
            })
            .collect();
        let mut exact_mask = vec![false; outs.len()];
        let mut surv = Vec::new();
        for (fi, &i) in feas.iter().enumerate() {
            let dominated = match &vectors[fi] {
                // a result without a bracket screens as unprunable
                None => false,
                Some((opt, _)) => {
                    vectors.iter().enumerate().any(|(fj, v)| {
                        fj != fi
                            && v.as_ref().is_some_and(|(_, cons)| {
                                pareto::dominates(cons, opt)
                            })
                    }) || self
                        .frontier
                        .entries()
                        .iter()
                        .any(|(_, exact)| pareto::dominates(exact, opt))
                }
            };
            if dominated {
                self.stats.pruned += 1;
            } else {
                surv.push(i);
                exact_mask[i] = true;
            }
        }
        let pts: Vec<DesignPoint> =
            surv.iter().map(|&i| outs[i].point.clone()).collect();
        let exact = eval::evaluate(
            self.session,
            self.source,
            pts,
            self.n_elements,
            self.cfg.threads,
        );
        self.stats.exact_sims += exact.len();
        let n_surv = surv.len();
        for (&i, o) in surv.iter().zip(exact) {
            outs[i] = o;
        }
        (outs, exact_mask, n_surv)
    }

    fn save(&self) -> Result<(), String> {
        let Some(path) = &self.cfg.checkpoint else {
            return Ok(());
        };
        let entries: Vec<(usize, &EvalOutcome)> = self
            .frontier
            .keys()
            .into_iter()
            .map(|k| (k, &self.kept[&k]))
            .collect();
        checkpoint::save(path, &self.key, self.cursor, &self.stats, &entries)
    }

    fn finish(self, space: &SearchSpace) -> Exploration {
        let keys = self.frontier.keys();
        let mut kept = self.kept;
        let outcomes: Vec<EvalOutcome> = keys
            .iter()
            .map(|k| kept.remove(k).expect("frontier member retained"))
            .collect();
        let frontier = (0..outcomes.len()).collect();
        Exploration {
            kernel: space.kernel.clone(),
            n_elements: self.n_elements,
            outcomes,
            frontier,
            stats: Some(self.stats),
        }
    }
}

// ---- samplers ----

/// Axis indices in enumeration nesting order; see
/// [`SearchSpace::axis_lens`].
type AxisIdx = [usize; 12];

fn build_point(
    space: &SearchSpace,
    info: &DegreeMap,
    idx: &AxisIdx,
) -> Option<DesignPoint> {
    let dataflow = space.dataflow[idx[5]];
    let sharing = space.mem_sharing[idx[6]];
    let fifo = space.fifo_depths[idx[7]];
    if !coherent(dataflow, sharing, fifo) {
        return None;
    }
    let mut pt = space.point(
        space.degrees[idx[0]],
        space.dtypes[idx[1]],
        space.memories[idx[2]],
        space.bus_modes[idx[3]],
        space.double_buffering[idx[4]],
        dataflow,
        sharing,
        space.partition_caps[idx[8]],
        space.cache_schemes[idx[9]],
        fifo,
        space.channel_policies[idx[10]].clone(),
        space.cu_counts[idx[11]],
    );
    normalize(info, &mut pt);
    Some(pt)
}

/// The explorer's normalization, applied to a sampled point.
fn normalize(info: &DegreeMap, pt: &mut DesignPoint) {
    if let Some(i) = info.get(&pt.p) {
        if let Some(g) = pt.opts.dataflow {
            pt.opts.dataflow = Some(g.min(i.nests));
        }
        if let Some(c) = pt.opts.partition_cap {
            if c >= i.max_read_degree {
                pt.opts.partition_cap = None;
            }
        }
        if !i.has_indexed {
            pt.opts.cache_scheme = crate::olympus::CacheScheme::Bypass;
        }
    }
}

/// Seeded uniform sampling: one index draw per axis per attempt, in
/// nesting order, so the sequence is a pure function of the seed.
/// Incoherent combinations and normalization duplicates are discarded;
/// the attempt cap keeps tiny spaces from spinning forever.
fn random_sample(
    space: &SearchSpace,
    info: &DegreeMap,
    budget: usize,
    seed: u64,
) -> Vec<DesignPoint> {
    let lens = space.axis_lens();
    if lens.contains(&0) {
        return Vec::new();
    }
    let mut rng = Prng::new(seed);
    let mut out = Vec::new();
    let mut seen = HashSet::new();
    let max_attempts = budget.saturating_mul(64) + 256;
    let mut attempts = 0usize;
    while out.len() < budget && attempts < max_attempts {
        attempts += 1;
        let mut idx = [0usize; 12];
        for (slot, &l) in idx.iter_mut().zip(lens.iter()) {
            *slot = rng.range_usize(0, l - 1);
        }
        if let Some(pt) = build_point(space, info, &idx) {
            if seen.insert(pt.fingerprint()) {
                out.push(pt);
            }
        }
    }
    out
}

/// Latin-hypercube sampling: each axis gets an independent seeded
/// permutation of the `n` strata, so every axis value appears in a
/// near-equal share of the samples. Incoherent and duplicate points
/// drop out, so at most — not exactly — `n` points come back.
fn lhs_sample(
    space: &SearchSpace,
    info: &DegreeMap,
    n: usize,
    seed: u64,
) -> Vec<DesignPoint> {
    let lens = space.axis_lens();
    if n == 0 || lens.contains(&0) {
        return Vec::new();
    }
    let mut rng = Prng::new(seed);
    let perms: Vec<Vec<usize>> = lens
        .iter()
        .map(|_| {
            let mut p: Vec<usize> = (0..n).collect();
            for i in (1..n).rev() {
                let j = rng.range_usize(0, i);
                p.swap(i, j);
            }
            p
        })
        .collect();
    let mut out = Vec::new();
    let mut seen = HashSet::new();
    for s in 0..n {
        let mut idx = [0usize; 12];
        for (a, slot) in idx.iter_mut().enumerate() {
            *slot = perms[a][s] * lens[a] / n;
        }
        if let Some(pt) = build_point(space, info, &idx) {
            if seen.insert(pt.fingerprint()) {
                out.push(pt);
            }
        }
    }
    out
}

/// One hill-climb move: re-draw a single axis of a frontier member
/// from its axis list. Returns `None` for incoherent results.
fn mutate(
    space: &SearchSpace,
    info: &DegreeMap,
    m: &DesignPoint,
    rng: &mut Prng,
) -> Option<DesignPoint> {
    let o = &m.opts;
    // undo the multi-CU FIFO override so the coherence filter judges
    // the axis value, not the methodology's forced depth
    let raw_fifo = if o.num_cus > 1 && o.fifo_depth == Some(64) {
        None
    } else {
        o.fifo_depth
    };
    let mut p = m.p;
    let mut dtype = o.dtype;
    let mut memory = o.memory;
    let mut bus = o.bus;
    let mut db = o.double_buffering;
    let mut dataflow = o.dataflow;
    let mut sharing = o.mem_sharing;
    let mut fifo = raw_fifo;
    let mut cap = o.partition_cap;
    let mut cache = o.cache_scheme;
    let mut policy = o.channel_policy.clone();
    let mut cus = o.num_cus;
    match rng.range_usize(0, 11) {
        0 => p = *rng.choose(&space.degrees),
        1 => dtype = *rng.choose(&space.dtypes),
        2 => memory = *rng.choose(&space.memories),
        3 => bus = *rng.choose(&space.bus_modes),
        4 => db = *rng.choose(&space.double_buffering),
        5 => dataflow = *rng.choose(&space.dataflow),
        6 => sharing = *rng.choose(&space.mem_sharing),
        7 => fifo = *rng.choose(&space.fifo_depths),
        8 => cap = *rng.choose(&space.partition_caps),
        9 => cache = *rng.choose(&space.cache_schemes),
        10 => policy = rng.choose(&space.channel_policies).clone(),
        _ => cus = *rng.choose(&space.cu_counts),
    }
    if !coherent(dataflow, sharing, fifo) {
        return None;
    }
    let mut pt = space.point(
        p, dtype, memory, bus, db, dataflow, sharing, cap, cache, fifo, policy,
        cus,
    );
    normalize(info, &mut pt);
    Some(pt)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datatype::DataType;
    use crate::olympus::BusMode;
    use crate::platform::Platform;

    fn tiny_space() -> SearchSpace {
        let mut s = SearchSpace::default_for("helmholtz");
        s.degrees = vec![11];
        s.dtypes = vec![DataType::F64, DataType::Fx32];
        s.cu_counts = vec![1];
        s.dataflow = vec![Some(2), Some(7)];
        s.double_buffering = vec![true];
        s.bus_modes = vec![BusMode::Wide256Parallel];
        s.mem_sharing = vec![false];
        s.fifo_depths = vec![None];
        s
    }

    fn info_for(s: &SearchSpace) -> DegreeMap {
        let session = flow::Session::new(Platform::alveo_u280());
        let source = s.source.snapshot().unwrap();
        super::super::degree_map(&session, &source, &s.degrees).unwrap()
    }

    #[test]
    fn samplers_are_seed_deterministic_and_in_space() {
        let s = tiny_space();
        let info = info_for(&s);
        let full: HashSet<String> =
            s.candidates(&info).map(|pt| pt.fingerprint()).collect();
        for sampler in [random_sample, lhs_sample] {
            let a = sampler(&s, &info, 3, 42);
            let b = sampler(&s, &info, 3, 42);
            let fa: Vec<String> = a.iter().map(|pt| pt.fingerprint()).collect();
            let fb: Vec<String> = b.iter().map(|pt| pt.fingerprint()).collect();
            assert_eq!(fa, fb, "same seed, same sequence");
            assert!(!a.is_empty());
            assert!(fa.iter().all(|f| full.contains(f)), "samples ⊆ space");
            let uniq: HashSet<&String> = fa.iter().collect();
            assert_eq!(uniq.len(), fa.len(), "no duplicates");
        }
        let c = random_sample(&s, &info, 3, 43);
        let d = random_sample(&s, &info, 3, 42);
        let fc: Vec<String> = c.iter().map(|pt| pt.fingerprint()).collect();
        let fd: Vec<String> = d.iter().map(|pt| pt.fingerprint()).collect();
        assert_ne!(fc, fd, "different seeds explore differently");
    }

    #[test]
    fn lhs_covers_axes_more_evenly_than_a_degenerate_draw() {
        // with budget = axis length, LHS hits every dtype exactly once
        let mut s = tiny_space();
        s.dataflow = vec![Some(7)];
        let info = info_for(&s);
        let pts = lhs_sample(&s, &info, 2, 7);
        let dtypes: HashSet<&str> =
            pts.iter().map(|pt| pt.opts.dtype.name()).collect();
        assert_eq!(dtypes.len(), 2, "both strata covered: {pts:?}");
    }

    #[test]
    fn hillclimb_mutations_stay_inside_the_space() {
        let s = tiny_space();
        let info = info_for(&s);
        let full: HashSet<String> =
            s.candidates(&info).map(|pt| pt.fingerprint()).collect();
        let member = s.candidates(&info).next().unwrap();
        let mut rng = Prng::new(9);
        let mut produced = 0;
        for _ in 0..64 {
            if let Some(nb) = mutate(&s, &info, &member, &mut rng) {
                assert!(
                    full.contains(&nb.fingerprint()),
                    "{}",
                    nb.fingerprint()
                );
                produced += 1;
            }
        }
        assert!(produced > 0, "some coherent neighbors exist");
    }

    #[test]
    fn zero_batch_and_hillclimb_resume_are_errors() {
        let s = tiny_space();
        let platform = Platform::alveo_u280();
        let cfg = SearchConfig {
            batch: 0,
            ..SearchConfig::default()
        };
        assert!(search(&s, &platform, 1000, &cfg).unwrap_err().contains("batch"));
        let cfg = SearchConfig {
            strategy: Strategy::HillClimb,
            checkpoint: Some(std::env::temp_dir().join("never_written.json")),
            ..SearchConfig::default()
        };
        let err = search(&s, &platform, 1000, &cfg).unwrap_err();
        assert!(err.contains("not resumable"), "{err}");
    }
}
