//! Declarative design-space definition over `OlympusOpts` axes.
//!
//! The paper leaves exploration "up to the designer" (§3.6.4); here the
//! space itself is a value: a `SearchSpace` is the cross product of
//! independent axes — data type, bus mode, dataflow decomposition,
//! Mnemosyne sharing, memory-plan partition cap, FIFO depth, CU count,
//! HBM vs DDR4 — times kernel
//! and polynomial degree. `enumerate` expands it into concrete
//! `DesignPoint`s, pruning only combinations that are *structurally*
//! meaningless (FIFO depth without dataflow streams; sharing on multi-
//! group schedules, which the resource model scopes away per §3.6.4).
//! Everything else — including configurations Olympus will reject, like
//! three CUs on the two DDR4 banks — is enumerated and left to the
//! evaluator, so infeasibility is *reported*, not silently skipped.

use crate::datatype::DataType;
use crate::kernels::KernelSource;
use crate::olympus::{BusMode, ChannelPolicy, MemoryKind, OlympusOpts};

/// One concrete candidate: `kernel` at degree `p` generated with `opts`.
#[derive(Debug, Clone)]
pub struct DesignPoint {
    pub kernel: String,
    pub p: usize,
    pub opts: OlympusOpts,
}

impl DesignPoint {
    /// Row label, e.g. `"Fixed Point 32 (p-dataflow 7) p=11 x1CU"`.
    pub fn label(&self) -> String {
        format!("{} p={} x{}CU", self.opts.label(), self.p, self.opts.num_cus)
    }

    /// Stable identity string used to deduplicate points whose axis
    /// values normalize to the same generated system (e.g. the multi-CU
    /// methodology forces `fifo_depth = Some(64)`, collapsing the naive
    /// FIFO axis value onto the reduced one).
    pub fn fingerprint(&self) -> String {
        format!("{}|p={}|{:?}", self.kernel, self.p, self.opts)
    }
}

/// The cross product of exploration axes for one kernel.
///
/// Construct with [`SearchSpace::default_for`] and narrow axes from
/// there; every `Vec` axis must stay non-empty.
#[derive(Debug, Clone)]
pub struct SearchSpace {
    /// Display name of the kernel (`source.name()`).
    pub kernel: String,
    /// Where the program comes from: builtin generator, `.cfd` file, or
    /// inline string — any front-door source is explorable.
    pub source: KernelSource,
    /// Polynomial degrees (the paper evaluates p = 7 and p = 11;
    /// fixed-extent file/inline sources carry a single nominal degree).
    pub degrees: Vec<usize>,
    pub dtypes: Vec<DataType>,
    pub cu_counts: Vec<usize>,
    /// Dataflow decomposition: `None` = flat kernel, `Some(n)` =
    /// n-compute-group pipeline (clamped to the kernel's nest count by
    /// the explorer).
    pub dataflow: Vec<Option<usize>>,
    pub double_buffering: Vec<bool>,
    pub bus_modes: Vec<BusMode>,
    pub mem_sharing: Vec<bool>,
    /// Memory-plan partition-factor caps (`None` = match the unrolled
    /// access degree, conflict-free). Capping below a kernel's
    /// reduction trip saves BRAM/URAM banks at the price of simulated
    /// bank-conflict stalls — together with `mem_sharing` this is the
    /// memory axis (`hbmflow dse --mem-plan`). Caps at or above the
    /// kernel's max access degree normalize to `None` in `explore`.
    pub partition_caps: Vec<Option<usize>>,
    /// Stream FIFO depth in words (`None` = naive full-array sizing).
    pub fifo_depths: Vec<Option<usize>>,
    pub memories: Vec<MemoryKind>,
    /// Channel-allocation policies on the segmented AXI switch
    /// (`hbm::alloc`). Default: local-first only; add `Striped` to let
    /// the frontier demonstrate the cost of switch crossings.
    pub channel_policies: Vec<ChannelPolicy>,
}

impl SearchSpace {
    /// The default exploration space for a named builtin kernel: the
    /// full optimization ladder of the paper (Figs. 15–17) as
    /// independent axes. ~2k candidates for helmholtz after
    /// normalization.
    pub fn default_for(kernel: &str) -> SearchSpace {
        Self::for_source(KernelSource::builtin(kernel))
    }

    /// The same default axes over an arbitrary front-door source — a
    /// `.cfd` file explores exactly the space a builtin does. Degrees
    /// come from the source: p ∈ {7, 11} for parameterized builtins, a
    /// single nominal degree for fixed-extent programs (more would
    /// enumerate duplicate physical designs).
    pub fn for_source(source: KernelSource) -> SearchSpace {
        SearchSpace {
            kernel: source.name(),
            degrees: source.default_degrees(),
            source,
            dtypes: DataType::ALL.to_vec(),
            cu_counts: vec![1, 2, 3, 4],
            dataflow: vec![None, Some(1), Some(2), Some(3), Some(7)],
            double_buffering: vec![false, true],
            bus_modes: vec![
                BusMode::Narrow64,
                BusMode::Wide256Serial,
                BusMode::Wide256Parallel,
            ],
            mem_sharing: vec![false, true],
            partition_caps: vec![None],
            fifo_depths: vec![None, Some(64)],
            memories: vec![MemoryKind::Hbm],
            channel_policies: vec![ChannelPolicy::LocalFirst],
        }
    }

    /// Expand the axes into concrete design points. Points whose axis
    /// values normalize to the same options are emitted once (e.g. the
    /// multi-CU methodology forces `fifo_depth = Some(64)`, collapsing
    /// both FIFO axis values); dataflow clamping against the kernel's
    /// nest count happens later, in [`crate::dse::explore`].
    pub fn enumerate(&self) -> Vec<DesignPoint> {
        let mut seen = std::collections::HashSet::new();
        let mut points = Vec::new();
        for &p in &self.degrees {
            for &dtype in &self.dtypes {
                for &memory in &self.memories {
                    for &bus in &self.bus_modes {
                        for &db in &self.double_buffering {
                            for &dataflow in &self.dataflow {
                                for &sharing in &self.mem_sharing {
                                    for &fifo in &self.fifo_depths {
                                        if !coherent(dataflow, sharing, fifo) {
                                            continue;
                                        }
                                        for &cap in &self.partition_caps {
                                            for policy in &self.channel_policies {
                                                for &cus in &self.cu_counts {
                                                    let pt = self.point(
                                                        p, dtype, memory, bus,
                                                        db, dataflow, sharing,
                                                        cap, fifo,
                                                        policy.clone(), cus,
                                                    );
                                                    if seen.insert(pt.fingerprint()) {
                                                        points.push(pt);
                                                    }
                                                }
                                            }
                                        }
                                    }
                                }
                            }
                        }
                    }
                }
            }
        }
        points
    }

    #[allow(clippy::too_many_arguments)]
    fn point(
        &self,
        p: usize,
        dtype: DataType,
        memory: MemoryKind,
        bus: BusMode,
        double_buffering: bool,
        dataflow: Option<usize>,
        mem_sharing: bool,
        partition_cap: Option<usize>,
        fifo: Option<usize>,
        channel_policy: ChannelPolicy,
        cus: usize,
    ) -> DesignPoint {
        let mut opts = OlympusOpts {
            double_buffering,
            bus,
            memory,
            dataflow,
            mem_sharing,
            partition_cap,
            dtype,
            num_cus: 1,
            fifo_depth: None,
            lut_mult_shift: false,
            target_freq_mhz: 450.0,
            channel_policy,
        }
        // applies the paper's multi-CU methodology (225 MHz target,
        // reduced FIFOs, LUT multiplier shift) when cus > 1
        .with_cus(cus);
        if fifo.is_some() {
            opts.fifo_depth = fifo;
        }
        DesignPoint {
            kernel: self.kernel.clone(),
            p,
            opts,
        }
    }
}

/// Structural pruning: drop axis combinations that cannot change the
/// generated system.
fn coherent(dataflow: Option<usize>, sharing: bool, fifo: Option<usize>) -> bool {
    // stream FIFOs only exist *between* compute groups: flat kernels and
    // 1-group dataflows have none, so the sizing axis is inert there
    if fifo.is_some() && !dataflow.is_some_and(|g| g > 1) {
        return false;
    }
    // Mnemosyne sharing is modeled for flat / 1-group schedules only
    // (paper §3.6.4: lifetimes are scoped per subkernel); on >1 groups
    // the resource model ignores the plan, so the combo is a duplicate
    if sharing && dataflow.is_some_and(|g| g > 1) {
        return false;
    }
    true
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashSet;

    #[test]
    fn default_helmholtz_space_is_large_and_unique() {
        let points = SearchSpace::default_for("helmholtz").enumerate();
        assert!(points.len() >= 100, "only {} candidates", points.len());
        let unique: HashSet<String> =
            points.iter().map(|pt| pt.fingerprint()).collect();
        assert_eq!(unique.len(), points.len(), "raw enumeration never repeats");
    }

    #[test]
    fn incoherent_combinations_are_pruned() {
        let points = SearchSpace::default_for("helmholtz").enumerate();
        for pt in &points {
            if pt.opts.dataflow.unwrap_or(1) <= 1 {
                // multi-CU methodology may set a FIFO depth, but the
                // naive/reduced axis itself never reaches stream-less
                // (flat or 1-group) schedules
                assert!(
                    pt.opts.num_cus > 1 || pt.opts.fifo_depth.is_none(),
                    "{}",
                    pt.fingerprint()
                );
            }
            if pt.opts.mem_sharing {
                assert!(pt.opts.dataflow.unwrap_or(1) <= 1);
            }
        }
    }

    #[test]
    fn narrowing_axes_shrinks_the_space() {
        let mut space = SearchSpace::default_for("helmholtz");
        let full = space.enumerate().len();
        space.dtypes = vec![DataType::Fx32];
        space.degrees = vec![11];
        let narrowed = space.enumerate().len();
        assert!(narrowed < full / 4, "{narrowed} vs {full}");
        assert!(narrowed > 0);
    }

    #[test]
    fn multi_cu_points_carry_the_paper_methodology() {
        let points = SearchSpace::default_for("helmholtz").enumerate();
        for pt in points.iter().filter(|pt| pt.opts.num_cus > 1) {
            assert_eq!(pt.opts.target_freq_mhz, 225.0, "{}", pt.label());
            assert!(pt.opts.lut_mult_shift);
        }
    }

    #[test]
    fn partition_cap_axis_multiplies_the_space() {
        let mut s = SearchSpace::default_for("helmholtz");
        let base = s.enumerate().len();
        s.partition_caps = vec![None, Some(2), Some(4)];
        assert_eq!(s.enumerate().len(), 3 * base, "independent memory axis");
        // and the capped points carry the cap into the options
        let capped = s
            .enumerate()
            .into_iter()
            .filter(|pt| pt.opts.partition_cap == Some(2))
            .count();
        assert_eq!(capped, base);
    }

    #[test]
    fn policy_axis_multiplies_the_space() {
        let mut s = SearchSpace::default_for("helmholtz");
        let base = s.enumerate().len();
        s.channel_policies =
            vec![ChannelPolicy::LocalFirst, ChannelPolicy::Striped];
        assert_eq!(s.enumerate().len(), 2 * base, "independent axis");
    }

    #[test]
    fn gradient_space_uses_a_single_degree() {
        // the gradient generator ignores p (fixed 8x7x6 operator): one
        // nominal degree, no duplicate physical designs
        let space = SearchSpace::default_for("gradient");
        assert_eq!(space.degrees, vec![8]);
    }

    #[test]
    fn inline_source_space_enumerates_like_a_builtin() {
        let src = "var input A : [4 4]\n\
                   var input u : [4 4 4]\n\
                   var output w : [4 4 4]\n\
                   w = A # u . [[1 2]]\n";
        let space = SearchSpace::for_source(KernelSource::inline("mode0", src));
        assert_eq!(space.kernel, "mode0");
        assert_eq!(space.degrees, vec![4]);
        let points = space.enumerate();
        assert!(!points.is_empty());
        assert!(points.iter().all(|pt| pt.kernel == "mode0" && pt.p == 4));
    }

    #[test]
    fn labels_are_readable() {
        let space = SearchSpace::default_for("helmholtz");
        let pt = &space.enumerate()[0];
        let l = pt.label();
        assert!(l.contains("p="), "{l}");
        assert!(l.contains("CU"), "{l}");
    }
}
