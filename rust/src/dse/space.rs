//! Declarative design-space definition over `OlympusOpts` axes.
//!
//! The paper leaves exploration "up to the designer" (§3.6.4); here the
//! space itself is a value: a `SearchSpace` is the cross product of
//! independent axes — data type, bus mode, dataflow decomposition,
//! Mnemosyne sharing, memory-plan partition cap, FIFO depth, CU count,
//! HBM vs DDR4 — times kernel
//! and polynomial degree. `enumerate` expands it into concrete
//! `DesignPoint`s, pruning only combinations that are *structurally*
//! meaningless (FIFO depth without dataflow streams; sharing on multi-
//! group schedules, which the resource model scopes away per §3.6.4).
//! Everything else — including configurations Olympus will reject, like
//! three CUs on the two DDR4 banks — is enumerated and left to the
//! evaluator, so infeasibility is *reported*, not silently skipped.

use std::collections::HashMap;

use crate::datatype::DataType;
use crate::kernels::KernelSource;
use crate::olympus::{BusMode, CacheScheme, ChannelPolicy, MemoryKind, OlympusOpts};

/// Per-degree kernel facts the streaming iterator needs to normalize
/// candidates exactly like the eager explorer does: dataflow clamps to
/// the nest count, and partition caps at or above the max unrolled
/// access degree collapse onto the uncapped plan.
#[derive(Debug, Clone, Copy)]
pub struct DegreeInfo {
    pub nests: usize,
    pub max_read_degree: usize,
    /// Does the kernel contain a gather/scatter nest? When false, the
    /// cache-scheme axis is inert and collapses onto `Bypass`.
    pub has_indexed: bool,
}

/// Degree → [`DegreeInfo`], built once per sweep from the lowered
/// kernels (one `Session::lowered` call per distinct degree). A missing
/// entry means "no normalization for that degree".
pub type DegreeMap = HashMap<usize, DegreeInfo>;

/// One concrete candidate: `kernel` at degree `p` generated with `opts`.
#[derive(Debug, Clone)]
pub struct DesignPoint {
    pub kernel: String,
    pub p: usize,
    pub opts: OlympusOpts,
}

impl DesignPoint {
    /// Row label, e.g. `"Fixed Point 32 (p-dataflow 7) p=11 x1CU"`.
    pub fn label(&self) -> String {
        format!("{} p={} x{}CU", self.opts.label(), self.p, self.opts.num_cus)
    }

    /// Stable identity string used to deduplicate points whose axis
    /// values normalize to the same generated system (e.g. the multi-CU
    /// methodology forces `fifo_depth = Some(64)`, collapsing the naive
    /// FIFO axis value onto the reduced one).
    pub fn fingerprint(&self) -> String {
        format!("{}|p={}|{:?}", self.kernel, self.p, self.opts)
    }
}

/// The cross product of exploration axes for one kernel.
///
/// Construct with [`SearchSpace::default_for`] and narrow axes from
/// there; every `Vec` axis must stay non-empty.
#[derive(Debug, Clone)]
pub struct SearchSpace {
    /// Display name of the kernel (`source.name()`).
    pub kernel: String,
    /// Where the program comes from: builtin generator, `.cfd` file, or
    /// inline string — any front-door source is explorable.
    pub source: KernelSource,
    /// Polynomial degrees (the paper evaluates p = 7 and p = 11;
    /// fixed-extent file/inline sources carry a single nominal degree).
    pub degrees: Vec<usize>,
    pub dtypes: Vec<DataType>,
    pub cu_counts: Vec<usize>,
    /// Dataflow decomposition: `None` = flat kernel, `Some(n)` =
    /// n-compute-group pipeline (clamped to the kernel's nest count by
    /// the explorer).
    pub dataflow: Vec<Option<usize>>,
    pub double_buffering: Vec<bool>,
    pub bus_modes: Vec<BusMode>,
    pub mem_sharing: Vec<bool>,
    /// Memory-plan partition-factor caps (`None` = match the unrolled
    /// access degree, conflict-free). Capping below a kernel's
    /// reduction trip saves BRAM/URAM banks at the price of simulated
    /// bank-conflict stalls — together with `mem_sharing` this is the
    /// memory axis (`hbmflow dse --mem-plan`). Caps at or above the
    /// kernel's max access degree normalize to `None` in `explore`.
    pub partition_caps: Vec<Option<usize>>,
    /// Stream FIFO depth in words (`None` = naive full-array sizing).
    pub fifo_depths: Vec<Option<usize>>,
    pub memories: Vec<MemoryKind>,
    /// Scratchpad schemes for indirectly accessed arrays
    /// (`mnemosyne::CacheScheme`) — the irregular-access axis
    /// (`hbmflow dse --cache-scheme`). On kernels with no gather/scatter
    /// nests every scheme normalizes to `Bypass`.
    pub cache_schemes: Vec<CacheScheme>,
    /// Channel-allocation policies on the segmented AXI switch
    /// (`hbm::alloc`). Default: local-first only; add `Striped` to let
    /// the frontier demonstrate the cost of switch crossings.
    pub channel_policies: Vec<ChannelPolicy>,
}

impl SearchSpace {
    /// The default exploration space for a named builtin kernel: the
    /// full optimization ladder of the paper (Figs. 15–17) as
    /// independent axes. ~2k candidates for helmholtz after
    /// normalization.
    pub fn default_for(kernel: &str) -> SearchSpace {
        Self::for_source(KernelSource::builtin(kernel))
    }

    /// The same default axes over an arbitrary front-door source — a
    /// `.cfd` file explores exactly the space a builtin does. Degrees
    /// come from the source: p ∈ {7, 11} for parameterized builtins, a
    /// single nominal degree for fixed-extent programs (more would
    /// enumerate duplicate physical designs).
    pub fn for_source(source: KernelSource) -> SearchSpace {
        SearchSpace {
            kernel: source.name(),
            degrees: source.default_degrees(),
            source,
            dtypes: DataType::ALL.to_vec(),
            cu_counts: vec![1, 2, 3, 4],
            dataflow: vec![None, Some(1), Some(2), Some(3), Some(7)],
            double_buffering: vec![false, true],
            bus_modes: vec![
                BusMode::Narrow64,
                BusMode::Wide256Serial,
                BusMode::Wide256Parallel,
            ],
            mem_sharing: vec![false, true],
            partition_caps: vec![None],
            fifo_depths: vec![None, Some(64)],
            memories: vec![MemoryKind::Hbm],
            cache_schemes: vec![CacheScheme::Bypass],
            channel_policies: vec![ChannelPolicy::LocalFirst],
        }
    }

    /// Expand the axes into concrete design points. Points whose axis
    /// values normalize to the same options are emitted once (e.g. the
    /// multi-CU methodology forces `fifo_depth = Some(64)`, collapsing
    /// both FIFO axis values); dataflow clamping against the kernel's
    /// nest count happens later, in [`crate::dse::explore`].
    pub fn enumerate(&self) -> Vec<DesignPoint> {
        let mut seen = std::collections::HashSet::new();
        let mut points = Vec::new();
        for &p in &self.degrees {
            for &dtype in &self.dtypes {
                for &memory in &self.memories {
                    for &bus in &self.bus_modes {
                        for &db in &self.double_buffering {
                            for &dataflow in &self.dataflow {
                                for &sharing in &self.mem_sharing {
                                    for &fifo in &self.fifo_depths {
                                        if !coherent(dataflow, sharing, fifo) {
                                            continue;
                                        }
                                        for &cap in &self.partition_caps {
                                            for &cache in &self.cache_schemes {
                                                for policy in &self.channel_policies {
                                                    for &cus in &self.cu_counts {
                                                        let pt = self.point(
                                                            p, dtype, memory,
                                                            bus, db, dataflow,
                                                            sharing, cap,
                                                            cache, fifo,
                                                            policy.clone(), cus,
                                                        );
                                                        if seen.insert(pt.fingerprint()) {
                                                            points.push(pt);
                                                        }
                                                    }
                                                }
                                            }
                                        }
                                    }
                                }
                            }
                        }
                    }
                }
            }
        }
        points
    }

    #[allow(clippy::too_many_arguments)]
    pub(crate) fn point(
        &self,
        p: usize,
        dtype: DataType,
        memory: MemoryKind,
        bus: BusMode,
        double_buffering: bool,
        dataflow: Option<usize>,
        mem_sharing: bool,
        partition_cap: Option<usize>,
        cache_scheme: CacheScheme,
        fifo: Option<usize>,
        channel_policy: ChannelPolicy,
        cus: usize,
    ) -> DesignPoint {
        let mut opts = OlympusOpts {
            double_buffering,
            bus,
            memory,
            dataflow,
            mem_sharing,
            partition_cap,
            dtype,
            num_cus: 1,
            fifo_depth: None,
            lut_mult_shift: false,
            target_freq_mhz: 450.0,
            channel_policy,
            cache_scheme,
        }
        // applies the paper's multi-CU methodology (225 MHz target,
        // reduced FIFOs, LUT multiplier shift) when cus > 1
        .with_cus(cus);
        if fifo.is_some() {
            opts.fifo_depth = fifo;
        }
        DesignPoint {
            kernel: self.kernel.clone(),
            p,
            opts,
        }
    }

    /// Stream the *normalized, deduplicated* candidate sequence — the
    /// exact sequence [`crate::dse::explore`] evaluates — without ever
    /// materializing the cross product. Peak state is the O(1) odometer
    /// over the axis indices; each yielded point is canonical for its
    /// normalization class (dataflow clamped to the nest count from
    /// `info`, inert partition caps collapsed, the multi-CU FIFO
    /// override folded in), so no `HashSet` of fingerprints is needed.
    ///
    /// `info` must describe every degree in `self.degrees` for the
    /// clamping to match the eager path; a missing entry disables
    /// normalization for that degree.
    pub fn candidates<'a>(&'a self, info: &'a DegreeMap) -> Candidates<'a> {
        let done = self.axis_lens().contains(&0);
        Candidates {
            space: self,
            info,
            idx: [0; 12],
            done,
        }
    }

    /// Axis lengths in enumeration nesting order (outermost first).
    pub(crate) fn axis_lens(&self) -> [usize; 12] {
        [
            self.degrees.len(),
            self.dtypes.len(),
            self.memories.len(),
            self.bus_modes.len(),
            self.double_buffering.len(),
            self.dataflow.len(),
            self.mem_sharing.len(),
            self.fifo_depths.len(),
            self.partition_caps.len(),
            self.cache_schemes.len(),
            self.channel_policies.len(),
            self.cu_counts.len(),
        ]
    }
}

/// Streaming iterator over a [`SearchSpace`] — see
/// [`SearchSpace::candidates`]. State is one mixed-radix odometer; the
/// dedup that the eager path does with a fingerprint set is replaced by
/// an O(axis-width) *canonicality* test per combination: a combination
/// is emitted iff it is the first one, in enumeration order, that maps
/// to its normalized design point.
pub struct Candidates<'a> {
    space: &'a SearchSpace,
    info: &'a DegreeMap,
    /// Current axis indices, nesting order (degrees outermost … CUs
    /// innermost) — matches `SearchSpace::enumerate` exactly.
    idx: [usize; 12],
    done: bool,
}

impl Candidates<'_> {
    fn advance(&mut self) {
        let lens = self.space.axis_lens();
        for ax in (0..self.idx.len()).rev() {
            self.idx[ax] += 1;
            if self.idx[ax] < lens[ax] {
                return;
            }
            self.idx[ax] = 0;
        }
        self.done = true;
    }

    /// Build the current combination's normalized point if the
    /// combination is coherent *and* canonical for its class.
    fn current(&self) -> Option<DesignPoint> {
        let s = self.space;
        let [ip, idt, imem, ibus, idb, idf, ish, ifi, icap, icsh, ipol, icu] = self.idx;
        let p = s.degrees[ip];
        let dtype = s.dtypes[idt];
        let memory = s.memories[imem];
        let bus = s.bus_modes[ibus];
        let db = s.double_buffering[idb];
        let dataflow = s.dataflow[idf];
        let sharing = s.mem_sharing[ish];
        let fifo = s.fifo_depths[ifi];
        let cap = s.partition_caps[icap];
        let cache = s.cache_schemes[icsh];
        let policy = &s.channel_policies[ipol];
        let cus = s.cu_counts[icu];

        if !coherent(dataflow, sharing, fifo) {
            return None;
        }

        // Pass-through axes: canonical iff this index is the first
        // occurrence of the exact value in its axis list (duplicate
        // axis entries collapse onto the first).
        if s.degrees[..ip].contains(&p)
            || s.dtypes[..idt].contains(&dtype)
            || s.memories[..imem].contains(&memory)
            || s.bus_modes[..ibus].contains(&bus)
            || s.double_buffering[..idb].contains(&db)
            || s.mem_sharing[..ish].contains(&sharing)
            || s.channel_policies[..ipol].contains(policy)
            || s.cu_counts[..icu].contains(&cus)
        {
            return None;
        }

        let info = self.info.get(&p);
        let clamp = |g: Option<usize>| match (g, info) {
            (Some(g), Some(i)) => Some(g.min(i.nests)),
            _ => g,
        };
        let norm_cap = |c: Option<usize>| match (c, info) {
            (Some(c), Some(i)) if c >= i.max_read_degree => None,
            _ => c,
        };
        // the cache axis is inert on kernels with no indexed nests:
        // every scheme generates the bypass system
        let norm_cache = |c: CacheScheme| match info {
            Some(i) if !i.has_indexed => CacheScheme::Bypass,
            _ => c,
        };
        // the multi-CU methodology forces `fifo_depth = Some(64)`; the
        // raw FIFO axis value overrides it when explicitly set
        let eff = |f: Option<usize>| if cus > 1 { f.or(Some(64)) } else { f };

        // Partition cap never enters `coherent`, so it is canonical
        // independently: first index with the same *normalized* cap.
        if s.partition_caps[..icap]
            .iter()
            .any(|&c| norm_cap(c) == norm_cap(cap))
        {
            return None;
        }

        // Cache scheme normalizes independently too: first index with
        // the same normalized scheme wins.
        if s.cache_schemes[..icsh]
            .iter()
            .any(|&c| norm_cache(c) == norm_cache(cache))
        {
            return None;
        }

        // Dataflow and FIFO collapse jointly (clamping + the multi-CU
        // override) and the coherence filter couples them, so the
        // canonical member of the class is the lexicographically-first
        // *coherent* (dataflow, fifo) index pair with the same
        // (clamped dataflow, effective fifo). Scanning raw value
        // equality alone would miss classes whose componentwise-least
        // member is coherence-rejected while a later pair still maps
        // into the class (e.g. a 1-nest kernel: raw `(Some(2),
        // Some(64))` clamps to `(Some(1), Some(64))`, whose direct raw
        // spelling is incoherent).
        let target = (clamp(dataflow), eff(fifo));
        let mut first_pair = None;
        'scan: for (jd, &d) in s.dataflow.iter().enumerate() {
            if clamp(d) != target.0 {
                continue;
            }
            for (jf, &f) in s.fifo_depths.iter().enumerate() {
                if eff(f) == target.1 && coherent(d, sharing, f) {
                    first_pair = Some((jd, jf));
                    break 'scan;
                }
            }
        }
        if first_pair != Some((idf, ifi)) {
            return None;
        }

        let mut pt = s.point(
            p,
            dtype,
            memory,
            bus,
            db,
            dataflow,
            sharing,
            cap,
            cache,
            fifo,
            policy.clone(),
            cus,
        );
        pt.opts.dataflow = clamp(pt.opts.dataflow);
        pt.opts.partition_cap = norm_cap(pt.opts.partition_cap);
        pt.opts.cache_scheme = norm_cache(pt.opts.cache_scheme);
        Some(pt)
    }
}

impl Iterator for Candidates<'_> {
    type Item = DesignPoint;

    fn next(&mut self) -> Option<DesignPoint> {
        while !self.done {
            let pt = self.current();
            self.advance();
            if pt.is_some() {
                return pt;
            }
        }
        None
    }
}

/// Structural pruning: drop axis combinations that cannot change the
/// generated system.
pub(crate) fn coherent(
    dataflow: Option<usize>,
    sharing: bool,
    fifo: Option<usize>,
) -> bool {
    // stream FIFOs only exist *between* compute groups: flat kernels and
    // 1-group dataflows have none, so the sizing axis is inert there
    if fifo.is_some() && !dataflow.is_some_and(|g| g > 1) {
        return false;
    }
    // Mnemosyne sharing is modeled for flat / 1-group schedules only
    // (paper §3.6.4: lifetimes are scoped per subkernel); on >1 groups
    // the resource model ignores the plan, so the combo is a duplicate
    if sharing && dataflow.is_some_and(|g| g > 1) {
        return false;
    }
    true
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashSet;

    #[test]
    fn default_helmholtz_space_is_large_and_unique() {
        let points = SearchSpace::default_for("helmholtz").enumerate();
        assert!(points.len() >= 100, "only {} candidates", points.len());
        let unique: HashSet<String> =
            points.iter().map(|pt| pt.fingerprint()).collect();
        assert_eq!(unique.len(), points.len(), "raw enumeration never repeats");
    }

    #[test]
    fn incoherent_combinations_are_pruned() {
        let points = SearchSpace::default_for("helmholtz").enumerate();
        for pt in &points {
            if pt.opts.dataflow.unwrap_or(1) <= 1 {
                // multi-CU methodology may set a FIFO depth, but the
                // naive/reduced axis itself never reaches stream-less
                // (flat or 1-group) schedules
                assert!(
                    pt.opts.num_cus > 1 || pt.opts.fifo_depth.is_none(),
                    "{}",
                    pt.fingerprint()
                );
            }
            if pt.opts.mem_sharing {
                assert!(pt.opts.dataflow.unwrap_or(1) <= 1);
            }
        }
    }

    #[test]
    fn narrowing_axes_shrinks_the_space() {
        let mut space = SearchSpace::default_for("helmholtz");
        let full = space.enumerate().len();
        space.dtypes = vec![DataType::Fx32];
        space.degrees = vec![11];
        let narrowed = space.enumerate().len();
        assert!(narrowed < full / 4, "{narrowed} vs {full}");
        assert!(narrowed > 0);
    }

    #[test]
    fn multi_cu_points_carry_the_paper_methodology() {
        let points = SearchSpace::default_for("helmholtz").enumerate();
        for pt in points.iter().filter(|pt| pt.opts.num_cus > 1) {
            assert_eq!(pt.opts.target_freq_mhz, 225.0, "{}", pt.label());
            assert!(pt.opts.lut_mult_shift);
        }
    }

    #[test]
    fn partition_cap_axis_multiplies_the_space() {
        let mut s = SearchSpace::default_for("helmholtz");
        let base = s.enumerate().len();
        s.partition_caps = vec![None, Some(2), Some(4)];
        assert_eq!(s.enumerate().len(), 3 * base, "independent memory axis");
        // and the capped points carry the cap into the options
        let capped = s
            .enumerate()
            .into_iter()
            .filter(|pt| pt.opts.partition_cap == Some(2))
            .count();
        assert_eq!(capped, base);
    }

    #[test]
    fn cache_axis_multiplies_the_space() {
        let mut s = SearchSpace::default_for("mesh_gather");
        let base = s.enumerate().len();
        s.cache_schemes = vec![
            CacheScheme::Bypass,
            CacheScheme::Cached(128),
            CacheScheme::FullBuffer,
        ];
        assert_eq!(s.enumerate().len(), 3 * base, "independent cache axis");
        let cached = s
            .enumerate()
            .into_iter()
            .filter(|pt| pt.opts.cache_scheme == CacheScheme::Cached(128))
            .count();
        assert_eq!(cached, base);
    }

    #[test]
    fn cache_axis_collapses_on_dense_kernels() {
        // helmholtz has no indexed nests: with degree info present the
        // stream emits every scheme as the same bypass design, once
        let mut space = SearchSpace::default_for("helmholtz");
        space.cache_schemes = vec![
            CacheScheme::Bypass,
            CacheScheme::Cached(128),
            CacheScheme::FullBuffer,
        ];
        let mut info = DegreeMap::new();
        info.insert(7, DegreeInfo { nests: 7, max_read_degree: 8, has_indexed: false });
        info.insert(11, DegreeInfo { nests: 7, max_read_degree: 12, has_indexed: false });
        let streamed: Vec<DesignPoint> = space.candidates(&info).collect();
        assert!(streamed
            .iter()
            .all(|pt| pt.opts.cache_scheme == CacheScheme::Bypass));
        let eager = eager_normalized(&space, &info);
        let fps: Vec<String> =
            streamed.iter().map(|pt| pt.fingerprint()).collect();
        assert_eq!(fps, eager, "collapse matches the eager dedup");
    }

    #[test]
    fn policy_axis_multiplies_the_space() {
        let mut s = SearchSpace::default_for("helmholtz");
        let base = s.enumerate().len();
        s.channel_policies =
            vec![ChannelPolicy::LocalFirst, ChannelPolicy::Striped];
        assert_eq!(s.enumerate().len(), 2 * base, "independent axis");
    }

    #[test]
    fn gradient_space_uses_a_single_degree() {
        // the gradient generator ignores p (fixed 8x7x6 operator): one
        // nominal degree, no duplicate physical designs
        let space = SearchSpace::default_for("gradient");
        assert_eq!(space.degrees, vec![8]);
    }

    #[test]
    fn inline_source_space_enumerates_like_a_builtin() {
        let src = "var input A : [4 4]\n\
                   var input u : [4 4 4]\n\
                   var output w : [4 4 4]\n\
                   w = A # u . [[1 2]]\n";
        let space = SearchSpace::for_source(KernelSource::inline("mode0", src));
        assert_eq!(space.kernel, "mode0");
        assert_eq!(space.degrees, vec![4]);
        let points = space.enumerate();
        assert!(!points.is_empty());
        assert!(points.iter().all(|pt| pt.kernel == "mode0" && pt.p == 4));
    }

    /// The eager path the explorer performs: enumerate → normalize
    /// (clamp dataflow, collapse inert caps) → dedup by fingerprint.
    fn eager_normalized(space: &SearchSpace, info: &DegreeMap) -> Vec<String> {
        let mut pts = space.enumerate();
        for pt in &mut pts {
            if let Some(i) = info.get(&pt.p) {
                if let Some(g) = pt.opts.dataflow {
                    pt.opts.dataflow = Some(g.min(i.nests));
                }
                if let Some(c) = pt.opts.partition_cap {
                    if c >= i.max_read_degree {
                        pt.opts.partition_cap = None;
                    }
                }
                if !i.has_indexed {
                    pt.opts.cache_scheme = CacheScheme::Bypass;
                }
            }
        }
        let mut seen = HashSet::new();
        pts.retain(|pt| seen.insert(pt.fingerprint()));
        pts.iter().map(|pt| pt.fingerprint()).collect()
    }

    #[test]
    fn streaming_matches_eager_enumeration_on_the_default_space() {
        let mut space = SearchSpace::default_for("helmholtz");
        space.partition_caps = vec![None, Some(2), Some(99)];
        space.channel_policies =
            vec![ChannelPolicy::LocalFirst, ChannelPolicy::Striped];
        let mut info = DegreeMap::new();
        info.insert(7, DegreeInfo { nests: 7, max_read_degree: 8, has_indexed: false });
        info.insert(11, DegreeInfo { nests: 7, max_read_degree: 12, has_indexed: false });
        let eager = eager_normalized(&space, &info);
        let streamed: Vec<String> =
            space.candidates(&info).map(|pt| pt.fingerprint()).collect();
        assert_eq!(streamed, eager, "same points, same order");
    }

    #[test]
    fn streaming_without_degree_info_matches_raw_dedup() {
        let space = SearchSpace::default_for("helmholtz");
        let info = DegreeMap::new();
        let eager = eager_normalized(&space, &info);
        let streamed: Vec<String> =
            space.candidates(&info).map(|pt| pt.fingerprint()).collect();
        assert_eq!(streamed, eager);
    }

    #[test]
    fn streaming_rescues_classes_whose_least_member_is_incoherent() {
        // a 1-nest kernel: raw (dataflow Some(2), fifo Some(64)) is
        // coherent and clamps onto (Some(1), Some(64)) — whose direct
        // raw spelling the coherence filter rejects. The eager path
        // still emits the class; the stream must too.
        let mut space = SearchSpace::default_for("helmholtz");
        space.degrees = vec![4];
        space.dataflow = vec![None, Some(1), Some(2)];
        let mut info = DegreeMap::new();
        info.insert(4, DegreeInfo { nests: 1, max_read_degree: 4, has_indexed: false });
        let eager = eager_normalized(&space, &info);
        let streamed: Vec<DesignPoint> = space.candidates(&info).collect();
        let fps: Vec<String> = streamed.iter().map(|pt| pt.fingerprint()).collect();
        assert_eq!(fps, eager);
        assert!(
            streamed.iter().any(|pt| pt.opts.num_cus == 1
                && pt.opts.dataflow == Some(1)
                && pt.opts.fifo_depth == Some(64)),
            "rescued class present"
        );
    }

    #[test]
    fn labels_are_readable() {
        let space = SearchSpace::default_for("helmholtz");
        let pt = &space.enumerate()[0];
        let l = pt.label();
        assert!(l.contains("p="), "{l}");
        assert!(l.contains("CU"), "{l}");
    }
}
