//! Design-space exploration engine (DESIGN.md §5).
//!
//! The paper integrates compiler and hardware optimizations but leaves
//! the actual exploration "up to the designer" (§3.6.4). This subsystem
//! closes that gap: it turns the hand-rolled sweep loops of the early
//! examples into a first-class engine —
//!
//!  * [`SearchSpace`] — the space as a *value*: independent axes over
//!    `OlympusOpts` (dtype, bus mode, dataflow groups, memory sharing,
//!    memory-plan partition cap, FIFO depth, CU count, HBM vs DDR4) ×
//!    kernel × polynomial degree.
//!    The kernel is any `kernels::KernelSource` — a builtin generator,
//!    a user `.cfd` file (`hbmflow dse --file my.cfd`), or an inline
//!    program — so exploration is not limited to the published trio;
//!  * [`eval`] — a thin adapter turning design points into
//!    `flow::FlowRequest`s and running them through the shared
//!    `flow::Session` batch service (map → estimate → simulate per
//!    candidate, parse/lower memoized in the session's artifact cache,
//!    deterministic result ordering). By default the sweep is
//!    [`Fidelity::Adaptive`]: a closed-form `sim::analytic` screening
//!    pass prunes provably dominated candidates, and only the
//!    survivors pay for the full event timeline — same frontier,
//!    fraction of the simulation cost (`benches/perf_sim.rs` measures
//!    the ratio into `BENCH_7.json`);
//!  * [`search`] — the budget-aware engine (DESIGN.md §2.8): lazily
//!    streamed candidates ([`SearchSpace::candidates`] — the cross
//!    product is never materialized), pluggable strategies
//!    (exhaustive stream / random / Latin-hypercube / hill-climb), an
//!    incremental frontier keeping memory O(frontier + batch), and
//!    versioned [`checkpoint`]s that let a killed sweep resume where
//!    it stopped without re-evaluating anything;
//!  * [`pareto`] — feasibility filtering against the platform's resource
//!    budget and Pareto-frontier extraction over
//!    (GFLOPS, energy, BRAM/URAM/DSP, switch crossings);
//!  * [`report`] — ranked text / JSON / CSV output;
//!  * [`compose`] — the multi-kernel layout axis (DESIGN.md §2.10):
//!    which adjacent pipeline stages fuse on one device (FIFO-routed,
//!    channels partitioned) versus time-multiplex through
//!    reconfiguration, priced per layout and Pareto-ranked.
//!
//! Entry points: the `hbmflow dse` CLI subcommand, the
//! `examples/design_space.rs` thin client, and [`explore`] /
//! [`explore_in`] for programmatic use ([`explore_in`] shares a caller's
//! `flow::Session`, so a sweep reuses — and its cache counters witness —
//! one parse + one lower per distinct program). Every future
//! optimization PR should prove its win against the whole space (is the
//! new point on the frontier?) instead of a single hand-picked
//! configuration.

pub mod checkpoint;
pub mod compose;
pub mod eval;
pub mod pareto;
pub mod report;
pub mod search;
pub mod space;

use crate::datatype::DataType;
use crate::flow;
use crate::platform::Platform;

pub use compose::{explore_layouts, LayoutExploration, LayoutResult};
pub use eval::{EvalOutcome, Evaluated};
pub use pareto::{dominates, pareto_indices, Frontier};
pub use search::{search, search_in, SearchConfig, Strategy, SweepStats};
pub use space::{DegreeInfo, DegreeMap, DesignPoint, SearchSpace};

/// The result of exploring one [`SearchSpace`]: every outcome (in
/// deterministic enumeration order) plus the indices of the feasible
/// Pareto-frontier members.
#[derive(Debug, Clone)]
pub struct Exploration {
    pub kernel: String,
    pub n_elements: u64,
    pub outcomes: Vec<EvalOutcome>,
    /// Indices into `outcomes` of the non-dominated feasible candidates.
    pub frontier: Vec<usize>,
    /// Present when the result came from the budget-aware
    /// [`search`] engine: `outcomes` then holds only the frontier
    /// members (the sweep is memory-bounded), and the counters here
    /// describe everything the sweep considered.
    pub stats: Option<SweepStats>,
}

impl Exploration {
    /// Candidates the sweep considered. For the eager explorer this is
    /// `outcomes.len()`; a budget-aware search keeps only the frontier
    /// resident, so the count comes from its [`SweepStats`].
    pub fn enumerated(&self) -> usize {
        match &self.stats {
            Some(st) => st.considered,
            None => self.outcomes.len(),
        }
    }

    pub fn feasible_count(&self) -> usize {
        match &self.stats {
            Some(st) => st.feasible,
            None => self.outcomes.iter().filter(|o| o.is_feasible()).count(),
        }
    }

    /// Candidates Olympus refused to generate (channel/CU limits).
    pub fn rejected_count(&self) -> usize {
        match &self.stats {
            Some(st) => st.rejected,
            None => self.outcomes.iter().filter(|o| o.result.is_err()).count(),
        }
    }

    pub fn is_on_frontier(&self, idx: usize) -> bool {
        self.frontier.contains(&idx)
    }

    /// Find a candidate identifying one of the paper's figure points
    /// (Figs. 15–17): dtype, degree, dataflow groups, and CU count,
    /// with the figures' shared methodology pinned (wide parallel bus,
    /// double buffering, HBM, no sharing, no partition cap) so a
    /// Narrow-bus or bank-starved "Custom" variant can never answer for
    /// a published design point. Only the
    /// FIFO-depth refinement is left free (the multi-CU methodology
    /// forces it); frontier members are preferred so callers land on
    /// the surviving variant.
    pub fn find_config(
        &self,
        dtype: DataType,
        p: usize,
        dataflow: Option<usize>,
        cus: usize,
    ) -> Option<usize> {
        let matches = |o: &EvalOutcome| {
            o.point.p == p
                && o.point.opts.dtype == dtype
                && o.point.opts.dataflow == dataflow
                && o.point.opts.num_cus == cus
                && o.point.opts.bus == crate::olympus::BusMode::Wide256Parallel
                && o.point.opts.double_buffering
                && o.point.opts.memory == crate::olympus::MemoryKind::Hbm
                && !o.point.opts.mem_sharing
                && o.point.opts.partition_cap.is_none()
        };
        self.frontier
            .iter()
            .copied()
            .find(|&i| matches(&self.outcomes[i]))
            .or_else(|| self.outcomes.iter().position(matches))
    }

    /// Feasible candidates ranked by system GFLOPS, best first (ties
    /// broken by enumeration order, which is deterministic).
    pub fn ranked(&self) -> Vec<usize> {
        let mut idx: Vec<usize> = (0..self.outcomes.len())
            .filter(|&i| self.outcomes[i].is_feasible())
            .collect();
        idx.sort_by(|&a, &b| {
            let ga = self.outcomes[a].result.as_ref().unwrap().sim.gflops_system;
            let gb = self.outcomes[b].result.as_ref().unwrap().sim.gflops_system;
            gb.total_cmp(&ga).then(a.cmp(&b))
        });
        idx
    }
}

/// Explore a search space on a platform: enumerate, normalize (clamp
/// dataflow to the kernel's nest count), deduplicate, evaluate in
/// parallel, and extract the feasible Pareto frontier.
///
/// `threads = None` uses one worker per available core. Creates a
/// throwaway `flow::Session`; use [`explore_in`] to share a cache (and
/// its hit/miss counters) across sweeps.
pub fn explore(
    space: &SearchSpace,
    platform: &Platform,
    n_elements: u64,
    threads: Option<usize>,
) -> Result<Exploration, String> {
    explore_in(
        &flow::Session::new(platform.clone()),
        space,
        n_elements,
        threads,
    )
}

/// Simulation fidelity of a sweep.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Fidelity {
    /// Two-pass adaptive evaluation (the default): a closed-form
    /// `sim::analytic` screening pass over every candidate, then the
    /// full event simulator only for the candidates the screen cannot
    /// *prove* dominated. Pruning compares a candidate's optimistic
    /// objective vector (analytic lower bound) against rivals'
    /// conservative vectors (upper bound), so the reported frontier is
    /// identical to [`Fidelity::Exact`] — dominance chains of true
    /// makespans terminate at a surviving candidate (see
    /// DESIGN.md §2.7). Pruned candidates keep their conservative
    /// analytic results (marked by `sim.analytic`); frontier members
    /// always carry exact event-sim numbers.
    #[default]
    Adaptive,
    /// Full event simulation for every candidate.
    Exact,
}

/// [`explore`] over a caller-owned `flow::Session`: the sweep performs
/// exactly one parse + one lower per distinct (source, degree) through
/// the session's artifact cache, no matter how many dtypes, options, or
/// CU counts the axes multiply out to. Uses [`Fidelity::Adaptive`];
/// see [`explore_in_with`] to force exact event simulation everywhere.
pub fn explore_in(
    session: &flow::Session,
    space: &SearchSpace,
    n_elements: u64,
    threads: Option<usize>,
) -> Result<Exploration, String> {
    explore_in_with(session, space, n_elements, threads, Fidelity::Adaptive)
}

/// [`explore_in`] with an explicit simulation fidelity.
pub fn explore_in_with(
    session: &flow::Session,
    space: &SearchSpace,
    n_elements: u64,
    threads: Option<usize>,
    fidelity: Fidelity,
) -> Result<Exploration, String> {
    // snapshot file sources to their current text so every candidate —
    // and the normalization below — evaluates ONE program even if the
    // .cfd file is edited mid-sweep (the old evaluator's single
    // up-front read, preserved)
    let source = space.source.snapshot()?;

    // one lowered kernel per degree, straight from the session cache —
    // the evaluator's requests below hit the same entries. The nest
    // count and max access degree feed the streaming iterator's
    // normalization: dataflow decompositions clamp to one group per
    // nest (cli::cmd_compile does the same clamp) and partition caps
    // at or above the kernel's max access degree collapse onto the
    // uncapped plan.
    let info = degree_map(session, &source, &space.degrees)?;
    let points: Vec<DesignPoint> = space.candidates(&info).collect();

    let outcomes = match fidelity {
        Fidelity::Exact => eval::evaluate(session, &source, points, n_elements, threads),
        Fidelity::Adaptive => {
            adaptive_evaluate(session, &source, points, n_elements, threads)
        }
    };

    let feasible: Vec<usize> = (0..outcomes.len())
        .filter(|&i| outcomes[i].is_feasible())
        .collect();
    let vectors: Vec<Vec<f64>> = feasible
        .iter()
        .map(|&i| pareto::objectives(outcomes[i].result.as_ref().unwrap()))
        .collect();
    let frontier: Vec<usize> = pareto::pareto_indices(&vectors)
        .into_iter()
        .map(|j| feasible[j])
        .collect();

    Ok(Exploration {
        kernel: space.kernel.clone(),
        n_elements,
        outcomes,
        frontier,
        stats: None,
    })
}

/// One lowered kernel per distinct degree (cache-warm via the session)
/// summarized into the [`DegreeMap`] the streaming iterator needs.
pub(crate) fn degree_map(
    session: &flow::Session,
    source: &crate::kernels::KernelSource,
    degrees: &[usize],
) -> Result<DegreeMap, String> {
    let mut info = DegreeMap::new();
    for &p in degrees {
        if info.contains_key(&p) {
            continue;
        }
        let l = session.lowered(source, p).map_err(|e| e.to_string())?;
        info.insert(
            p,
            DegreeInfo {
                nests: l.kernel.nests.len(),
                max_read_degree: crate::ir::access::max_read_degree(&l.kernel),
                has_indexed: crate::ir::access::has_indexed(&l.kernel),
            },
        );
    }
    Ok(info)
}

/// The adaptive two-pass evaluation behind [`Fidelity::Adaptive`].
///
/// Pass 1 screens every candidate with the O(1) `sim::analytic` bounds.
/// A feasible candidate is *provably dominated* when some other
/// feasible candidate's conservative objective vector (throughput and
/// energy at its analytic **upper** bound) dominates the candidate's
/// optimistic vector (at its **lower** bound) — then the true vectors
/// dominate too, for any makespans inside the brackets. Pass 2 re-runs
/// only the unpruned survivors through the full event simulator and
/// splices the exact results back in. Loose brackets (few batches per
/// CU) simply prove less, pushing more candidates into pass 2 — never
/// a wrong frontier. The reported frontier is computed over survivors'
/// exact vectors and equals the all-exact frontier: every pruned
/// candidate's dominator chain terminates at a survivor, and stored
/// conservative values can neither dominate an exact frontier member
/// nor escape domination themselves (`tests/dse.rs` pins both
/// invariants over all stored outcomes).
fn adaptive_evaluate(
    session: &flow::Session,
    source: &crate::kernels::KernelSource,
    points: Vec<DesignPoint>,
    n_elements: u64,
    threads: Option<usize>,
) -> Vec<EvalOutcome> {
    let mut outcomes =
        eval::evaluate_analytic(session, source, points, n_elements, threads);

    let feasible: Vec<usize> = (0..outcomes.len())
        .filter(|&i| outcomes[i].is_feasible())
        .collect();
    // optimistic / conservative objective vectors from the brackets; a
    // result without a bracket (defensively) screens as unprunable
    let vectors: Vec<Option<(Vec<f64>, Vec<f64>)>> = feasible
        .iter()
        .map(|&i| {
            let e = outcomes[i].result.as_ref().unwrap();
            e.sim.analytic.map(|b| {
                (
                    pareto::objectives_with_time(e, b.lower_s),
                    pareto::objectives_with_time(e, b.upper_s),
                )
            })
        })
        .collect();
    let survivors: Vec<usize> = feasible
        .iter()
        .enumerate()
        .filter(|&(fi, _)| {
            let Some((opt, _)) = &vectors[fi] else {
                return true;
            };
            !vectors.iter().enumerate().any(|(fj, v)| {
                fj != fi
                    && v.as_ref()
                        .is_some_and(|(_, cons)| pareto::dominates(cons, opt))
            })
        })
        .map(|(_, &i)| i)
        .collect();

    // pass 2: exact event simulation for the survivors only (their
    // Mapped artifacts and HLS estimates come straight from the
    // session cache — only the timeline is recomputed)
    let pts: Vec<DesignPoint> = survivors
        .iter()
        .map(|&i| outcomes[i].point.clone())
        .collect();
    let exact = eval::evaluate(session, source, pts, n_elements, threads);
    for (&i, o) in survivors.iter().zip(exact) {
        outcomes[i] = o;
    }
    outcomes
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::KernelSource;
    use crate::olympus::BusMode;

    fn small_exploration() -> Exploration {
        let mut s = SearchSpace::default_for("helmholtz");
        s.degrees = vec![11];
        s.dtypes = vec![DataType::F64, DataType::Fx32];
        s.cu_counts = vec![1];
        s.dataflow = vec![Some(2), Some(7)];
        s.double_buffering = vec![true];
        s.bus_modes = vec![BusMode::Wide256Parallel];
        s.mem_sharing = vec![false];
        s.fifo_depths = vec![None];
        explore(&s, &Platform::alveo_u280(), 200_000, Some(2)).unwrap()
    }

    #[test]
    fn frontier_members_are_feasible_and_non_dominated() {
        let ex = small_exploration();
        assert!(!ex.frontier.is_empty());
        assert!(ex.feasible_count() > 0);
        for &i in &ex.frontier {
            assert!(ex.outcomes[i].is_feasible());
        }
        for &a in &ex.frontier {
            for &b in &ex.frontier {
                if a != b {
                    let oa = pareto::objectives(ex.outcomes[a].result.as_ref().unwrap());
                    let ob = pareto::objectives(ex.outcomes[b].result.as_ref().unwrap());
                    assert!(!dominates(&oa, &ob));
                }
            }
        }
    }

    #[test]
    fn ranking_is_descending_in_system_gflops() {
        let ex = small_exploration();
        let ranked = ex.ranked();
        let g = |i: usize| ex.outcomes[i].result.as_ref().unwrap().sim.gflops_system;
        for w in ranked.windows(2) {
            assert!(g(w[0]) >= g(w[1]));
        }
    }

    #[test]
    fn find_config_locates_the_df7_point() {
        let ex = small_exploration();
        let i = ex
            .find_config(DataType::Fx32, 11, Some(7), 1)
            .expect("fx32 p=11 DF7 1CU enumerated");
        assert_eq!(ex.outcomes[i].point.opts.dtype, DataType::Fx32);
        assert!(ex.find_config(DataType::F32, 99, None, 9).is_none());
    }

    #[test]
    fn memory_axis_trades_uram_for_stalls() {
        let mut s = SearchSpace::default_for("helmholtz");
        s.degrees = vec![11];
        s.dtypes = vec![DataType::F64];
        s.cu_counts = vec![1];
        s.dataflow = vec![Some(7)];
        s.double_buffering = vec![true];
        s.bus_modes = vec![BusMode::Wide256Parallel];
        s.mem_sharing = vec![false];
        s.fifo_depths = vec![None];
        s.partition_caps = vec![None, Some(4)];
        let ex = explore(&s, &Platform::alveo_u280(), 200_000, Some(2)).unwrap();
        assert_eq!(ex.enumerated(), 2);
        let by_cap = |cap: Option<usize>| {
            ex.outcomes
                .iter()
                .find(|o| o.point.opts.partition_cap == cap)
                .and_then(|o| o.result.as_ref().ok())
                .expect("both points evaluate")
        };
        let full = by_cap(None);
        let capped = by_cap(Some(4));
        assert_eq!(full.sim.conflict_stalls, 0);
        assert!(capped.sim.conflict_stalls > 0);
        assert!(capped.total.uram < full.total.uram);
        assert!(capped.sim.gflops_system < full.sim.gflops_system);
        // a genuine trade: both ends of the axis survive on the frontier
        for (i, o) in ex.outcomes.iter().enumerate() {
            assert!(ex.is_on_frontier(i), "{} dominated", o.point.label());
        }
    }

    #[test]
    fn oversized_partition_caps_normalize_to_uncapped() {
        let mut s = SearchSpace::default_for("helmholtz");
        s.degrees = vec![11];
        s.dtypes = vec![DataType::F64];
        s.cu_counts = vec![1];
        s.dataflow = vec![Some(7)];
        s.double_buffering = vec![true];
        s.bus_modes = vec![BusMode::Wide256Parallel];
        s.mem_sharing = vec![false];
        s.fifo_depths = vec![None];
        // helmholtz p=11 unrolls an 11-wide reduction: cap 16 is inert
        s.partition_caps = vec![None, Some(16)];
        let ex = explore(&s, &Platform::alveo_u280(), 100_000, Some(1)).unwrap();
        assert_eq!(ex.enumerated(), 1, "inert cap collapses onto uncapped");
        assert_eq!(ex.outcomes[0].point.opts.partition_cap, None);
    }

    #[test]
    fn unknown_kernel_is_an_exploration_error() {
        let s = SearchSpace::default_for("warp-drive");
        let err = explore(&s, &Platform::alveo_u280(), 100_000, Some(1)).unwrap_err();
        assert!(err.contains("unknown kernel"), "{err}");
    }

    #[test]
    fn missing_file_source_is_an_exploration_error() {
        let mut s = SearchSpace::for_source(KernelSource::file("/no/such.cfd"));
        s.degrees = vec![7];
        let err = explore(&s, &Platform::alveo_u280(), 100_000, Some(1)).unwrap_err();
        assert!(err.contains("/no/such.cfd"), "{err}");
    }

    #[test]
    fn oversized_dataflow_requests_clamp_and_dedupe() {
        let mut s = SearchSpace::default_for("helmholtz");
        s.degrees = vec![11];
        s.dtypes = vec![DataType::F64];
        s.cu_counts = vec![1];
        // helmholtz lowers to 7 nests: 7 and 99 normalize to the same point
        s.dataflow = vec![Some(7), Some(99)];
        s.double_buffering = vec![true];
        s.bus_modes = vec![BusMode::Wide256Parallel];
        s.mem_sharing = vec![false];
        s.fifo_depths = vec![None];
        let ex = explore(&s, &Platform::alveo_u280(), 100_000, Some(1)).unwrap();
        assert_eq!(ex.enumerated(), 1, "duplicate clamped point removed");
        assert_eq!(ex.outcomes[0].point.opts.dataflow, Some(7));
    }
}
