//! Versioned sweep checkpoints — the resumability half of the search
//! engine (DESIGN.md §2.8).
//!
//! A checkpoint is one JSON document holding everything a killed sweep
//! needs to continue: the stream cursor, the [`SweepStats`] counters,
//! and the current frontier members *with their full evaluations*
//! (options via the artifact codec, resources and simulation numbers
//! via the same encoders `flow::Artifact` uses — Rust float formatting
//! is shortest-round-trip, so the restored vectors are bit-identical
//! to the originals and frontier equivalence survives the hop through
//! text).
//!
//! The `space_key` field binds a checkpoint to the sweep that wrote it:
//! a fingerprint over the kernel source, every axis list, the degree
//! normalization facts, the platform, the workload size, and the
//! sampling parameters. Resuming with *anything* changed — a narrowed
//! axis, a different seed, another platform — is refused instead of
//! silently merging incompatible evaluations.
//!
//! Writes go to `<path>.tmp` then rename over the target, so a sweep
//! killed mid-write leaves the previous complete checkpoint intact.

use std::path::Path;

use crate::flow::{
    self,
    artifact::{
        opts_from_json, opts_to_json, resources_from_json, resources_json,
        sim_from_json, sim_json,
    },
};
use crate::platform::Platform;
use crate::util::json::{self, Json};

use super::eval::{EvalOutcome, Evaluated};
use super::search::{SearchConfig, SweepStats};
use super::space::{DegreeMap, DesignPoint, SearchSpace};

/// Bump when the checkpoint layout changes; old files are refused with
/// a clear message instead of being misread.
pub const CHECKPOINT_SCHEMA: u64 = 1;

const KIND: &str = "dse-checkpoint";

/// A restored checkpoint: resume the stream at `cursor` with this
/// frontier (entries keyed by the candidate's stream sequence number,
/// in first-admission order) and these counters.
#[derive(Debug)]
pub struct Checkpoint {
    pub cursor: usize,
    pub stats: SweepStats,
    pub frontier: Vec<(usize, DesignPoint, Evaluated)>,
}

/// Fingerprint of everything that determines the candidate sequence
/// and its evaluations. Two sweeps share a checkpoint iff their keys
/// match.
pub fn space_key(
    space: &SearchSpace,
    info: &DegreeMap,
    platform: &Platform,
    n_elements: u64,
    cfg: &SearchConfig,
) -> String {
    let mut degrees: Vec<(usize, usize, usize, bool)> = info
        .iter()
        .map(|(&p, i)| (p, i.nests, i.max_read_degree, i.has_indexed))
        .collect();
    degrees.sort_unstable();
    let text = format!(
        "kernel={} degrees={:?} dtypes={:?} memories={:?} buses={:?} \
         db={:?} dataflow={:?} sharing={:?} fifos={:?} caps={:?} \
         caches={:?} policies={:?} cus={:?} info={:?} platform={} \
         elements={} strategy={} seed={} budget={:?} batch={}",
        space.kernel,
        space.degrees,
        space.dtypes,
        space.memories,
        space.bus_modes,
        space.double_buffering,
        space.dataflow,
        space.mem_sharing,
        space.fifo_depths,
        space.partition_caps,
        space.cache_schemes,
        space.channel_policies,
        space.cu_counts,
        degrees,
        platform.name,
        n_elements,
        cfg.strategy.name(),
        cfg.seed,
        cfg.budget,
        cfg.batch,
    );
    flow::fingerprint(&space.kernel, &text)
}

/// Atomically write the sweep state. `entries` are the live frontier
/// members (sequence number + outcome) in first-admission order;
/// rejected/infeasible outcomes never reach a frontier, so every entry
/// carries a full evaluation.
pub fn save(
    path: &Path,
    key: &str,
    cursor: usize,
    stats: &SweepStats,
    entries: &[(usize, &EvalOutcome)],
) -> Result<(), String> {
    let frontier: Vec<Json> = entries
        .iter()
        .filter_map(|(seq, o)| {
            let ev = o.result.as_ref().ok()?;
            Some(Json::obj(vec![
                ("seq", Json::num(*seq as f64)),
                ("kernel", Json::str(o.point.kernel.clone())),
                ("p", Json::num(o.point.p as f64)),
                ("opts", opts_to_json(&o.point.opts)),
                ("feasible", Json::Bool(ev.feasible)),
                ("fmax_mhz", Json::num(ev.fmax_mhz)),
                ("max_utilization", Json::num(ev.max_utilization)),
                ("total", resources_json(&ev.total)),
                ("sim", sim_json(&ev.sim)),
            ]))
        })
        .collect();
    let doc = Json::obj(vec![
        ("schema", Json::num(CHECKPOINT_SCHEMA as f64)),
        ("kind", Json::str(KIND)),
        ("space_key", Json::str(key)),
        ("cursor", Json::num(cursor as f64)),
        ("stats", stats.to_json()),
        ("frontier", Json::Arr(frontier)),
    ]);
    let tmp = path.with_extension("tmp");
    std::fs::write(&tmp, format!("{doc}\n"))
        .map_err(|e| format!("write {}: {e}", tmp.display()))?;
    std::fs::rename(&tmp, path)
        .map_err(|e| format!("rename {} -> {}: {e}", tmp.display(), path.display()))
}

/// Load and validate a checkpoint. `expect_key` must match the stored
/// `space_key` — see [`space_key`] for what that covers.
pub fn load(path: &Path, expect_key: &str) -> Result<Checkpoint, String> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| format!("read {}: {e}", path.display()))?;
    let doc = json::parse(&text)
        .map_err(|e| format!("{}: not valid JSON: {e}", path.display()))?;
    if doc.get("kind").as_str() != Some(KIND) {
        return Err(format!("{}: not a dse checkpoint", path.display()));
    }
    match doc.get("schema").as_u64() {
        Some(CHECKPOINT_SCHEMA) => {}
        Some(n) => {
            return Err(format!(
                "{}: checkpoint schema v{n}, this build reads v{CHECKPOINT_SCHEMA}",
                path.display()
            ));
        }
        None => return Err(format!("{}: missing schema", path.display())),
    }
    match doc.get("space_key").as_str() {
        Some(k) if k == expect_key => {}
        _ => {
            return Err(format!(
                "{}: written by a different sweep (space, platform, workload, \
                 or sampling parameters changed) — delete it or rerun the \
                 original configuration",
                path.display()
            ));
        }
    }
    let cursor = doc
        .get("cursor")
        .as_u64()
        .ok_or_else(|| format!("{}: missing cursor", path.display()))?
        as usize;
    let stats = SweepStats::from_json(doc.get("stats"))
        .map_err(|e| format!("{}: bad stats: {e}", path.display()))?;
    let raw = doc
        .get("frontier")
        .as_arr()
        .ok_or_else(|| format!("{}: missing frontier", path.display()))?;
    let mut frontier = Vec::with_capacity(raw.len());
    for (i, entry) in raw.iter().enumerate() {
        let ctx = |e: String| format!("{}: frontier[{i}]: {e}", path.display());
        let seq = entry
            .get("seq")
            .as_u64()
            .ok_or_else(|| ctx("missing seq".into()))? as usize;
        let kernel = entry
            .get("kernel")
            .as_str()
            .ok_or_else(|| ctx("missing kernel".into()))?
            .to_string();
        let p = entry
            .get("p")
            .as_u64()
            .ok_or_else(|| ctx("missing p".into()))? as usize;
        let opts = opts_from_json(entry.get("opts")).map_err(ctx)?;
        let total = resources_from_json(entry.get("total")).map_err(ctx)?;
        let sim = sim_from_json(entry.get("sim")).map_err(ctx)?;
        let fmax_mhz = entry
            .get("fmax_mhz")
            .as_f64()
            .ok_or_else(|| ctx("missing fmax_mhz".into()))?;
        let max_utilization = entry
            .get("max_utilization")
            .as_f64()
            .ok_or_else(|| ctx("missing max_utilization".into()))?;
        let feasible = matches!(entry.get("feasible"), Json::Bool(true));
        frontier.push((
            seq,
            DesignPoint { kernel, p, opts },
            Evaluated {
                feasible,
                fmax_mhz,
                total,
                max_utilization,
                sim,
            },
        ));
    }
    Ok(Checkpoint {
        cursor,
        stats,
        frontier,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::flow::Session;
    use crate::kernels::KernelSource;

    fn evaluated_outcome() -> EvalOutcome {
        let session = Session::new(Platform::alveo_u280());
        let source = KernelSource::builtin("helmholtz");
        let space = SearchSpace::default_for("helmholtz");
        let pt = space.candidates(&DegreeMap::new()).next().unwrap();
        let mut outs = crate::dse::eval::evaluate(
            &session,
            &source,
            vec![pt],
            50_000,
            Some(1),
        );
        let o = outs.remove(0);
        assert!(o.result.is_ok(), "{:?}", o.result);
        o
    }

    #[test]
    fn checkpoint_round_trips_bit_exactly() {
        let dir = std::env::temp_dir();
        let path = dir.join("hbmflow_ck_roundtrip.json");
        let o = evaluated_outcome();
        let stats = SweepStats {
            considered: 9,
            feasible: 4,
            pruned: 2,
            exact_sims: 2,
            resumed_from: Some(3),
            ..SweepStats::default()
        };
        save(&path, "k123", 9, &stats, &[(5, &o)]).unwrap();
        let ck = load(&path, "k123").unwrap();
        assert_eq!(ck.cursor, 9);
        assert_eq!(ck.stats, stats);
        assert_eq!(ck.frontier.len(), 1);
        let (seq, pt, ev) = &ck.frontier[0];
        assert_eq!(*seq, 5);
        assert_eq!(pt.fingerprint(), o.point.fingerprint());
        let orig = o.result.as_ref().unwrap();
        // Debug formatting covers every field of every float — equality
        // here is bit-exactness of the whole evaluation
        assert_eq!(format!("{ev:?}"), format!("{orig:?}"));
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn mismatched_key_and_schema_are_refused() {
        let dir = std::env::temp_dir();
        let path = dir.join("hbmflow_ck_mismatch.json");
        let o = evaluated_outcome();
        save(&path, "the-key", 1, &SweepStats::default(), &[(0, &o)]).unwrap();
        let err = load(&path, "other-key").unwrap_err();
        assert!(err.contains("different sweep"), "{err}");
        // corrupt the schema number and the load names both versions
        let text = std::fs::read_to_string(&path).unwrap();
        std::fs::write(&path, text.replace("\"schema\":1", "\"schema\":99"))
            .unwrap();
        let err = load(&path, "the-key").unwrap_err();
        assert!(err.contains("schema v99"), "{err}");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn space_key_tracks_axes_and_sampling_parameters() {
        let platform = Platform::alveo_u280();
        let mut space = SearchSpace::default_for("helmholtz");
        let info = DegreeMap::new();
        let cfg = SearchConfig::default();
        let base = space_key(&space, &info, &platform, 1000, &cfg);
        assert_eq!(
            base,
            space_key(&space, &info, &platform, 1000, &cfg),
            "deterministic"
        );
        let seeded = SearchConfig {
            seed: 1,
            ..SearchConfig::default()
        };
        assert_ne!(base, space_key(&space, &info, &platform, 1000, &seeded));
        assert_ne!(base, space_key(&space, &info, &platform, 2000, &cfg));
        space.degrees = vec![7];
        assert_ne!(base, space_key(&space, &info, &platform, 1000, &cfg));
    }
}
