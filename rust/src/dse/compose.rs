//! The composition axis of the design space (DESIGN.md §2.10).
//!
//! Given a pipeline of K kernels, the designer's layout choice is *which
//! adjacent stages fuse on-chip* (one bitstream, channels partitioned,
//! intermediates through FIFOs) versus *which time-multiplex* (the
//! device is reconfigured between segments and every cross-segment edge
//! round-trips through the host). A layout is therefore a subset of the
//! K−1 pipeline edges to fuse; contiguous fused runs form *segments*.
//! This module enumerates all 2^(K−1) layouts, prices each one —
//!
//!  * a fused segment costs its composed event-timeline makespan
//!    ([`sim::compose::simulate_composed`]);
//!  * a singleton segment costs its standalone event-timeline makespan
//!    ([`sim::simulate`]);
//!  * segment times **add** (one device, run back to back) while
//!    segment resources **max** (each segment is its own bitstream, so
//!    the device only ever holds one segment at a time);
//!
//! — and extracts the Pareto frontier over (time, BRAM, URAM, DSP) with
//! the same larger-is-better orientation as [`pareto`](super::pareto).
//! Layouts whose fused segments do not fit (channels or area) are kept
//! in the result with their rejection reason: an infeasibility is a
//! data point about the space, not an error.

use crate::hls;
use crate::ir::affine::Kernel;
use crate::olympus::{self, OlympusOpts};
use crate::platform::{Platform, Resources};
use crate::sim;

use super::pareto_indices;

/// One layout of the pipeline onto the device: which edges fuse, what
/// the resulting segments are, and what the schedule costs.
#[derive(Debug, Clone)]
pub struct LayoutResult {
    /// Bit `i` set ⇔ the edge between stages `i` and `i+1` is fused.
    pub fuse_mask: u32,
    /// Contiguous segments as inclusive `(first, last)` stage indices.
    pub segments: Vec<(usize, usize)>,
    /// End-to-end seconds (segments run back to back); `None` when some
    /// segment was infeasible.
    pub total_s: Option<f64>,
    /// Element-wise max of the segment resources (the device holds one
    /// segment's bitstream at a time). Zero when infeasible.
    pub resources: Resources,
    /// Why the layout was rejected, when it was.
    pub rejected: Option<String>,
}

impl LayoutResult {
    pub fn is_feasible(&self) -> bool {
        self.total_s.is_some()
    }
}

/// Every layout of one pipeline, plus the feasible Pareto frontier
/// (indices into `layouts`) over (−time, −BRAM, −URAM, −DSP).
#[derive(Debug, Clone)]
pub struct LayoutExploration {
    /// All 2^(K−1) layouts in fuse-mask order (mask 0 = fully
    /// time-multiplexed, mask 2^(K−1)−1 = fully fused).
    pub layouts: Vec<LayoutResult>,
    pub frontier: Vec<usize>,
}

impl LayoutExploration {
    /// The feasible layout with the smallest end-to-end time.
    pub fn fastest(&self) -> Option<&LayoutResult> {
        self.layouts
            .iter()
            .filter(|l| l.is_feasible())
            .min_by(|a, b| {
                a.total_s
                    .unwrap()
                    .partial_cmp(&b.total_s.unwrap())
                    .expect("makespans are finite")
            })
    }
}

fn max_resources(a: Resources, b: Resources) -> Resources {
    Resources {
        lut: a.lut.max(b.lut),
        ff: a.ff.max(b.ff),
        bram: a.bram.max(b.bram),
        uram: a.uram.max(b.uram),
        dsp: a.dsp.max(b.dsp),
    }
}

/// Split stage indices `0..k` into contiguous segments under a fuse mask.
fn segments_of(k: usize, fuse_mask: u32) -> Vec<(usize, usize)> {
    let mut segs = Vec::new();
    let mut start = 0;
    for i in 0..k {
        let fused_to_next = i + 1 < k && (fuse_mask >> i) & 1 == 1;
        if !fused_to_next {
            segs.push((start, i));
            start = i + 1;
        }
    }
    segs
}

/// Price one segment: composed makespan for a fused run, standalone
/// event-timeline makespan for a singleton.
fn price_segment(
    members: &[(&Kernel, OlympusOpts)],
    platform: &Platform,
    n_elements: u64,
) -> Result<(f64, Resources), String> {
    if members.len() == 1 {
        let (kernel, opts) = &members[0];
        let spec = olympus::generate(kernel, opts, platform)?;
        let est = hls::estimate(&spec, platform);
        let r = sim::simulate(&spec, &est, platform, n_elements);
        Ok((r.total_time_s, est.total))
    } else {
        let sys = olympus::compose(members, platform)?;
        let r = sim::compose::simulate_composed(&sys, platform, n_elements);
        Ok((r.total_s, sys.resources))
    }
}

/// Enumerate and price every fuse/time-multiplex layout of the
/// pipeline. `members` are the stages in pipeline order, each with the
/// options its system generates under.
pub fn explore_layouts(
    members: &[(&Kernel, OlympusOpts)],
    platform: &Platform,
    n_elements: u64,
) -> LayoutExploration {
    let k = members.len();
    assert!(k >= 1, "a pipeline needs at least one stage");
    assert!(k <= 16, "2^(K-1) layout enumeration caps at 16 stages");
    let n_masks = 1u32 << (k - 1).min(31);
    let mut layouts = Vec::with_capacity(n_masks as usize);
    for mask in 0..n_masks {
        let segments = segments_of(k, mask);
        let mut total_s = 0.0;
        let mut resources = Resources::default();
        let mut rejected = None;
        for &(lo, hi) in &segments {
            match price_segment(&members[lo..=hi], platform, n_elements) {
                Ok((t, r)) => {
                    total_s += t;
                    resources = max_resources(resources, r);
                }
                Err(e) => {
                    rejected =
                        Some(format!("segment {lo}..={hi}: {e}"));
                    break;
                }
            }
        }
        layouts.push(if let Some(reason) = rejected {
            LayoutResult {
                fuse_mask: mask,
                segments,
                total_s: None,
                resources: Resources::default(),
                rejected: Some(reason),
            }
        } else {
            LayoutResult {
                fuse_mask: mask,
                segments,
                total_s: Some(total_s),
                resources,
                rejected: None,
            }
        });
    }

    // frontier over the feasible layouts, larger-is-better orientation
    let feasible: Vec<usize> = (0..layouts.len())
        .filter(|&i| layouts[i].is_feasible())
        .collect();
    let vectors: Vec<Vec<f64>> = feasible
        .iter()
        .map(|&i| {
            let l = &layouts[i];
            vec![
                -l.total_s.unwrap(),
                -(l.resources.bram as f64),
                -(l.resources.uram as f64),
                -(l.resources.dsp as f64),
            ]
        })
        .collect();
    let frontier = pareto_indices(&vectors)
        .into_iter()
        .map(|j| feasible[j])
        .collect();
    LayoutExploration { layouts, frontier }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::flow::Flow;
    use crate::kernels::KernelSource;

    fn lowered(name: &str) -> crate::flow::Lowered {
        Flow::from_source(KernelSource::builtin(name))
            .parse(7)
            .unwrap()
            .lower()
            .unwrap()
    }

    #[test]
    fn segments_partition_the_pipeline() {
        assert_eq!(segments_of(3, 0b00), vec![(0, 0), (1, 1), (2, 2)]);
        assert_eq!(segments_of(3, 0b11), vec![(0, 2)]);
        assert_eq!(segments_of(3, 0b01), vec![(0, 1), (2, 2)]);
        assert_eq!(segments_of(3, 0b10), vec![(0, 0), (1, 2)]);
        assert_eq!(segments_of(1, 0), vec![(0, 0)]);
    }

    #[test]
    fn layout_axis_enumerates_every_fuse_mask() {
        let a = lowered("interpolation");
        let b = lowered("gradient");
        let opts = OlympusOpts::baseline();
        let ex = explore_layouts(
            &[(&a.kernel, opts.clone()), (&b.kernel, opts.clone())],
            &Platform::alveo_u280(),
            50_000,
        );
        assert_eq!(ex.layouts.len(), 2);
        assert!(ex.layouts.iter().all(|l| l.is_feasible()));
        assert!(!ex.frontier.is_empty());
        // mask 1 fuses: one segment; mask 0 splits: two
        assert_eq!(ex.layouts[0].segments.len(), 2);
        assert_eq!(ex.layouts[1].segments.len(), 1);
        // the fully time-multiplexed layout pays both standalone runs;
        // the fused one overlaps them, so it must not be slower
        let split = ex.layouts[0].total_s.unwrap();
        let fused = ex.layouts[1].total_s.unwrap();
        assert!(fused <= split, "fused {fused} vs split {split}");
        assert!(ex.fastest().unwrap().fuse_mask == 1);
    }

    #[test]
    fn infeasible_fusions_are_data_points_not_errors() {
        let a = lowered("interpolation");
        let b = lowered("gradient");
        let c = lowered("helmholtz");
        // 16 CUs each fits alone but 3×16 overflows the 32 channels
        let opts = OlympusOpts::baseline().with_cus(16);
        let ex = explore_layouts(
            &[
                (&a.kernel, opts.clone()),
                (&b.kernel, opts.clone()),
                (&c.kernel, opts.clone()),
            ],
            &Platform::alveo_u280(),
            10_000,
        );
        assert_eq!(ex.layouts.len(), 4);
        let fully_fused = &ex.layouts[0b11];
        assert!(!fully_fused.is_feasible());
        assert!(fully_fused.rejected.is_some());
        let split = &ex.layouts[0b00];
        assert!(split.is_feasible(), "{:?}", split.rejected);
        // the frontier only ranks feasible layouts
        assert!(ex.frontier.iter().all(|&i| ex.layouts[i].is_feasible()));
    }
}
