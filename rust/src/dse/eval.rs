//! Candidate evaluation — a thin adapter over the flow batch service.
//!
//! Each design point becomes a [`FlowRequest`] and the whole candidate
//! list runs through [`flow::Session::evaluate_batch`]: the session's
//! shared artifact cache guarantees one parse + one lower per distinct
//! (source, degree) no matter how many option sets evaluate it, and the
//! scoped-thread pool (formerly private to this module) returns results
//! in enumeration order — exploration output stays deterministic.
//!
//! A point the generator rejects (e.g. three CUs on the two DDR4 banks)
//! is an `Err` outcome carrying the reason, not a missing row:
//! infeasibility is part of the answer the designer asked for.

use crate::flow::{self, EvalKind, FlowRequest};
use crate::kernels::KernelSource;
use crate::platform::Resources;
use crate::sim::SimResult;

use super::space::DesignPoint;

/// Everything measured about one generated system.
#[derive(Debug, Clone)]
pub struct Evaluated {
    /// Whole-design resources fit the device (paper Tables 3–5 check).
    pub feasible: bool,
    pub fmax_mhz: f64,
    /// Whole-design resources (CUs + shell).
    pub total: Resources,
    /// Worst resource-class utilization against the device budget.
    pub max_utilization: f64,
    pub sim: SimResult,
}

/// One design point plus its evaluation; `Err` carries the pipeline's
/// rejection reason.
#[derive(Debug, Clone)]
pub struct EvalOutcome {
    pub point: DesignPoint,
    pub result: Result<Evaluated, String>,
}

impl EvalOutcome {
    /// Generated and within the device's resource budget.
    pub fn is_feasible(&self) -> bool {
        self.result.as_ref().is_ok_and(|e| e.feasible)
    }
}

/// Evaluate every point through the session's batch service with the
/// full event-timeline simulator; results are in input order.
pub fn evaluate(
    session: &flow::Session,
    source: &KernelSource,
    points: Vec<DesignPoint>,
    n_elements: u64,
    threads: Option<usize>,
) -> Vec<EvalOutcome> {
    evaluate_kind(session, source, points, n_elements, threads, false)
}

/// Evaluate every point with the closed-form `sim::analytic` fast path
/// (conservative makespan, bracket on the `sim.analytic` field) —
/// dse's screening pass.
pub fn evaluate_analytic(
    session: &flow::Session,
    source: &KernelSource,
    points: Vec<DesignPoint>,
    n_elements: u64,
    threads: Option<usize>,
) -> Vec<EvalOutcome> {
    evaluate_kind(session, source, points, n_elements, threads, true)
}

fn evaluate_kind(
    session: &flow::Session,
    source: &KernelSource,
    points: Vec<DesignPoint>,
    n_elements: u64,
    threads: Option<usize>,
    analytic: bool,
) -> Vec<EvalOutcome> {
    let eval = if analytic {
        EvalKind::SimulateAnalytic {
            elements: n_elements,
        }
    } else {
        EvalKind::Simulate {
            elements: n_elements,
        }
    };
    let reqs: Vec<FlowRequest> = points
        .iter()
        .map(|pt| FlowRequest {
            source: source.clone(),
            p: pt.p,
            opts: pt.opts.clone(),
            eval,
        })
        .collect();
    let results = session.evaluate_batch_with(&reqs, threads);
    let budget = session.platform().total_resources();
    points
        .into_iter()
        .zip(results)
        .map(|(point, fr)| {
            let result = match fr.result {
                Ok(ev) => {
                    let total = ev.hls.total;
                    let feasible = total.fits_in(&budget);
                    let fmax_mhz = ev.hls.fmax_mhz;
                    let max_utilization = total.max_utilization(&budget);
                    match ev.sim {
                        Some(sim) => Ok(Evaluated {
                            feasible,
                            fmax_mhz,
                            total,
                            max_utilization,
                            sim,
                        }),
                        None => {
                            Err("internal: simulate request returned no sim result".into())
                        }
                    }
                }
                Err(e) => Err(e.to_string()),
            };
            EvalOutcome { point, result }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datatype::DataType;
    use crate::dse::SearchSpace;
    use crate::flow::Session;
    use crate::olympus::{BusMode, MemoryKind};
    use crate::platform::Platform;

    fn session() -> Session {
        Session::new(Platform::alveo_u280())
    }

    fn tiny_space() -> SearchSpace {
        let mut s = SearchSpace::default_for("helmholtz");
        s.degrees = vec![11];
        s.dtypes = vec![DataType::F64];
        s.cu_counts = vec![1, 2];
        s.dataflow = vec![Some(7)];
        s.double_buffering = vec![true];
        s.bus_modes = vec![BusMode::Wide256Parallel];
        s.mem_sharing = vec![false];
        s.fifo_depths = vec![None];
        s
    }

    #[test]
    fn results_are_deterministic_and_in_order() {
        let space = tiny_space();
        let points = space.enumerate();
        let serial = evaluate(&session(), &space.source, points.clone(), 200_000, Some(1));
        let parallel =
            evaluate(&session(), &space.source, points.clone(), 200_000, Some(4));
        assert_eq!(serial.len(), points.len());
        for (a, b) in serial.iter().zip(parallel.iter()) {
            assert_eq!(a.point.label(), b.point.label());
            let (ea, eb) = (a.result.as_ref().unwrap(), b.result.as_ref().unwrap());
            assert_eq!(ea.sim.gflops_system, eb.sim.gflops_system);
            assert_eq!(ea.total, eb.total);
        }
    }

    #[test]
    fn rejected_points_carry_the_generation_reason() {
        let mut s = tiny_space();
        s.memories = vec![MemoryKind::Ddr4];
        s.cu_counts = vec![3]; // DDR4 has two banks: rejected
        let points = s.enumerate();
        let out = evaluate(&session(), &s.source, points, 100_000, Some(2));
        assert!(!out.is_empty());
        for o in &out {
            assert!(o.result.is_err(), "{}", o.point.label());
            assert!(!o.is_feasible());
            assert!(
                o.result.as_ref().unwrap_err().contains("num_cus"),
                "{:?}",
                o.result
            );
        }
    }

    #[test]
    fn kernel_builds_run_once_per_degree_across_the_batch() {
        let mut s = tiny_space();
        s.degrees = vec![7, 11];
        let session = session();
        let points = s.enumerate();
        let n = points.len();
        let out = evaluate(&session, &s.source, points, 100_000, Some(4));
        assert_eq!(out.len(), n);
        let st = session.stats();
        assert_eq!(st.parsed_misses, 2, "{st:?}");
        assert_eq!(st.lowered_misses, 2, "{st:?}");
        assert_eq!(st.lowered_hits as usize, n - 2, "{st:?}");
    }

    #[test]
    fn unknown_kernels_error_per_outcome() {
        let s = SearchSpace::default_for("warp-drive");
        let mut points = s.enumerate();
        points.truncate(2);
        let out = evaluate(&session(), &s.source, points, 100_000, Some(1));
        for o in &out {
            let err = o.result.as_ref().unwrap_err();
            assert!(err.contains("unknown kernel"), "{err}");
        }
    }
}
