//! Parallel candidate evaluation: `olympus::generate` →
//! `hls::estimate` → `sim::simulate` per design point.
//!
//! The evaluator is a scoped-thread worker pool over an atomic work
//! cursor (the offline registry has no rayon): each worker claims the
//! next point, runs the full generate/estimate/simulate pipeline against
//! the shared platform model, and writes its slot. Kernel builds
//! (parse → rewrite → lower, by far the most expensive step) are
//! memoized per `(kernel, degree)` in [`build_kernels`] before the pool
//! starts, so every candidate evaluation is pure arithmetic over shared
//! immutable state. Results come back in enumeration order regardless of
//! completion order — exploration output is deterministic.
//!
//! A point Olympus rejects (e.g. three CUs on the two DDR4 banks) is an
//! `Err` outcome carrying the reason, not a missing row: infeasibility
//! is part of the answer the designer asked for.

use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use crate::hls;
use crate::ir::affine::Kernel;
use crate::kernels::KernelSource;
use crate::olympus;
use crate::platform::{Platform, Resources};
use crate::sim::{self, SimResult};

use super::space::DesignPoint;

/// Everything measured about one generated system.
#[derive(Debug, Clone)]
pub struct Evaluated {
    /// Whole-design resources fit the device (paper Tables 3–5 check).
    pub feasible: bool,
    pub fmax_mhz: f64,
    /// Whole-design resources (CUs + shell).
    pub total: Resources,
    /// Worst resource-class utilization against the device budget.
    pub max_utilization: f64,
    pub sim: SimResult,
}

/// One design point plus its evaluation; `Err` carries Olympus's
/// rejection reason.
#[derive(Debug, Clone)]
pub struct EvalOutcome {
    pub point: DesignPoint,
    pub result: Result<Evaluated, String>,
}

impl EvalOutcome {
    /// Generated and within the device's resource budget.
    pub fn is_feasible(&self) -> bool {
        self.result.as_ref().is_ok_and(|e| e.feasible)
    }
}

/// Worker count when the caller does not specify one.
pub fn default_threads() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

/// Build each distinct `(kernel, degree)` once from the space's source —
/// the memoized inputs the worker pool shares.
pub fn build_kernels(
    source: &KernelSource,
    points: &[DesignPoint],
) -> Result<HashMap<(String, usize), Kernel>, String> {
    let mut kernels = HashMap::new();
    for pt in points {
        let key = (pt.kernel.clone(), pt.p);
        if let std::collections::hash_map::Entry::Vacant(slot) = kernels.entry(key) {
            slot.insert(source.build(pt.p)?);
        }
    }
    Ok(kernels)
}

/// Evaluate every point in parallel; results are in input order.
pub fn evaluate(
    points: Vec<DesignPoint>,
    kernels: &HashMap<(String, usize), Kernel>,
    platform: &Platform,
    n_elements: u64,
    threads: Option<usize>,
) -> Vec<EvalOutcome> {
    let workers = threads
        .unwrap_or_else(default_threads)
        .clamp(1, points.len().max(1));
    let cursor = AtomicUsize::new(0);
    let slots: Vec<Mutex<Option<EvalOutcome>>> =
        points.iter().map(|_| Mutex::new(None)).collect();

    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| loop {
                let i = cursor.fetch_add(1, Ordering::Relaxed);
                if i >= points.len() {
                    break;
                }
                let pt = &points[i];
                let kernel = kernels
                    .get(&(pt.kernel.clone(), pt.p))
                    .expect("build_kernels covered every (kernel, p)");
                let outcome = eval_one(pt, kernel, platform, n_elements);
                *slots[i].lock().unwrap() = Some(outcome);
            });
        }
    });

    slots
        .into_iter()
        .map(|slot| {
            slot.into_inner()
                .unwrap()
                .expect("worker pool filled every slot")
        })
        .collect()
}

fn eval_one(
    pt: &DesignPoint,
    kernel: &Kernel,
    platform: &Platform,
    n_elements: u64,
) -> EvalOutcome {
    let result = olympus::generate(kernel, &pt.opts, platform).map(|spec| {
        let est = hls::estimate(&spec, platform);
        let budget = platform.total_resources();
        let sim = sim::simulate(&spec, &est, platform, n_elements);
        Evaluated {
            feasible: est.total.fits_in(&budget),
            fmax_mhz: est.fmax_mhz,
            total: est.total,
            max_utilization: est.total.max_utilization(&budget),
            sim,
        }
    });
    EvalOutcome {
        point: pt.clone(),
        result,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datatype::DataType;
    use crate::dse::SearchSpace;
    use crate::olympus::{BusMode, MemoryKind};

    fn tiny_space() -> SearchSpace {
        let mut s = SearchSpace::default_for("helmholtz");
        s.degrees = vec![11];
        s.dtypes = vec![DataType::F64];
        s.cu_counts = vec![1, 2];
        s.dataflow = vec![Some(7)];
        s.double_buffering = vec![true];
        s.bus_modes = vec![BusMode::Wide256Parallel];
        s.mem_sharing = vec![false];
        s.fifo_depths = vec![None];
        s
    }

    #[test]
    fn results_are_deterministic_and_in_order() {
        let platform = Platform::alveo_u280();
        let space = tiny_space();
        let points = space.enumerate();
        let kernels = build_kernels(&space.source, &points).unwrap();
        let serial = evaluate(points.clone(), &kernels, &platform, 200_000, Some(1));
        let parallel = evaluate(points.clone(), &kernels, &platform, 200_000, Some(4));
        assert_eq!(serial.len(), points.len());
        for (a, b) in serial.iter().zip(parallel.iter()) {
            assert_eq!(a.point.label(), b.point.label());
            let (ea, eb) = (a.result.as_ref().unwrap(), b.result.as_ref().unwrap());
            assert_eq!(ea.sim.gflops_system, eb.sim.gflops_system);
            assert_eq!(ea.total, eb.total);
        }
    }

    #[test]
    fn rejected_points_carry_the_olympus_reason() {
        let mut s = tiny_space();
        s.memories = vec![MemoryKind::Ddr4];
        s.cu_counts = vec![3]; // DDR4 has two banks: rejected
        let points = s.enumerate();
        let kernels = build_kernels(&s.source, &points).unwrap();
        let platform = Platform::alveo_u280();
        let out = evaluate(points, &kernels, &platform, 100_000, Some(2));
        assert!(!out.is_empty());
        for o in &out {
            assert!(o.result.is_err(), "{}", o.point.label());
            assert!(!o.is_feasible());
        }
    }

    #[test]
    fn kernel_builds_are_memoized_per_degree() {
        let mut s = tiny_space();
        s.degrees = vec![7, 11];
        let points = s.enumerate();
        let kernels = build_kernels(&s.source, &points).unwrap();
        assert_eq!(kernels.len(), 2);
    }

    #[test]
    fn unknown_kernel_is_a_build_error() {
        let s = SearchSpace::default_for("warp-drive");
        let err = build_kernels(&s.source, &s.enumerate()).unwrap_err();
        assert!(err.contains("unknown kernel"), "{err}");
    }

    #[test]
    fn missing_file_source_is_a_build_error() {
        let mut s = SearchSpace::for_source(KernelSource::file("/no/such.cfd"));
        s.degrees = vec![7];
        let err = build_kernels(&s.source, &s.enumerate()).unwrap_err();
        assert!(err.contains("/no/such.cfd"), "{err}");
    }
}
