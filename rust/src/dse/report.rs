//! Ranked reporting for exploration results: text tables for humans,
//! JSON/CSV for downstream tooling (plotting the Figs. 15–17 frontier,
//! regression-tracking a PR's claimed win against the whole space).
//!
//! The text report footers the paper's own chosen configurations
//! (Figs. 15–17) with their frontier status, so a reader can see at a
//! glance whether the reproduction's frontier passes through the
//! published design points.

use crate::datatype::DataType;
use crate::report as fmt;
use crate::util::json::Json;

use super::{EvalOutcome, Exploration};

/// Human-readable ranked table. `top_k = 0` shows every feasible row;
/// `pareto_only` restricts to frontier members.
pub fn text(ex: &Exploration, top_k: usize, pareto_only: bool) -> String {
    let mut shown: Vec<usize> = ex.ranked();
    if pareto_only {
        shown.retain(|&i| ex.is_on_frontier(i));
    }
    if top_k > 0 {
        shown.truncate(top_k);
    }

    let rows: Vec<Vec<String>> = shown
        .iter()
        .map(|&i| {
            let o = &ex.outcomes[i];
            let e = o.result.as_ref().unwrap();
            vec![
                o.point.label(),
                if ex.is_on_frontier(i) { "*" } else { "" }.into(),
                fmt::f(e.fmax_mhz),
                fmt::f(e.sim.gflops_cu),
                fmt::f(e.sim.gflops_system),
                format!("{:.2}", e.sim.efficiency_gflops_w),
                fmt::f(e.sim.energy_j),
                e.total.bram.to_string(),
                e.total.uram.to_string(),
                e.total.dsp.to_string(),
                e.sim.mem_banks.to_string(),
                e.sim.mem_shared_words.to_string(),
                e.sim.conflict_stalls.to_string(),
                format!("{:.2}", e.sim.max_channel_utilization),
                e.sim.switch_crossings.to_string(),
                e.sim.bottleneck.clone(),
            ]
        })
        .collect();

    let mut out = format!(
        "kernel: {} ({} elements/run)\n",
        ex.kernel, ex.n_elements
    );
    out.push_str(&fmt::table(
        &[
            "configuration",
            "P",
            "f(MHz)",
            "CU",
            "System",
            "GF/W",
            "J",
            "BRAM",
            "URAM",
            "DSP",
            "banks",
            "shmem",
            "stalls",
            "ch.util",
            "xings",
            "bound",
        ],
        &rows,
    ));
    out.push('\n');
    out.push_str(&summary(ex));
    if ex.kernel == "helmholtz" {
        out.push('\n');
        out.push_str(&paper_reference_footer(ex));
    }
    out
}

fn summary(ex: &Exploration) -> String {
    match &ex.stats {
        // a budget-aware sweep: report what the stream considered and
        // what the analytic screen saved, not just what is resident
        Some(s) => {
            let mut line = format!(
                "{} candidates considered ({} feasible, {} over budget, {} \
                 rejected by olympus); {} pruned analytically, {} exact \
                 sims, peak resident {}; Pareto frontier: {} designs",
                s.considered,
                s.feasible,
                s.over_budget,
                s.rejected,
                s.pruned,
                s.exact_sims,
                s.peak_resident,
                ex.frontier.len(),
            );
            if !s.complete {
                line.push_str(" (sweep paused — resume to finish)");
            }
            line
        }
        None => format!(
            "{} candidates enumerated ({} feasible, {} over budget, {} rejected \
             by olympus); Pareto frontier: {} designs",
            ex.enumerated(),
            ex.feasible_count(),
            ex.enumerated() - ex.feasible_count() - ex.rejected_count(),
            ex.rejected_count(),
            ex.frontier.len(),
        ),
    }
}

/// Frontier status of the paper's published design points (Figs. 15–17).
fn paper_reference_footer(ex: &Exploration) -> String {
    let refs = [
        ("Fig. 15 Dataflow-7 double ", DataType::F64, 11, 1, 43.410),
        ("Fig. 16 custom precision  ", DataType::Fx32, 11, 1, 103.0),
        ("Fig. 17 replication       ", DataType::Fx32, 11, 3, 87.0),
    ];
    let mut out = String::from("paper reference points:\n");
    for (name, dtype, p, cus, paper_gflops) in refs {
        let line = match ex.find_config(dtype, p, Some(7), cus) {
            Some(i) => {
                let o = &ex.outcomes[i];
                let status = if ex.is_on_frontier(i) {
                    "on frontier"
                } else if o.is_feasible() {
                    "feasible, off frontier"
                } else {
                    "infeasible"
                };
                match &o.result {
                    Ok(e) => format!(
                        "  {name} ({} p={p} x{cus}CU): {status} — {} GFLOPS (paper {})",
                        o.point.opts.dtype,
                        fmt::f(e.sim.gflops_system),
                        fmt::f(paper_gflops),
                    ),
                    Err(reason) => format!("  {name}: rejected — {reason}"),
                }
            }
            None => format!("  {name}: not enumerated in this space"),
        };
        out.push_str(&line);
        out.push('\n');
    }
    out
}

/// Machine-readable JSON: summary plus one record per outcome
/// (rejections included, carrying their reason).
pub fn json(ex: &Exploration) -> String {
    let candidates: Vec<Json> = ex
        .outcomes
        .iter()
        .enumerate()
        .map(|(i, o)| candidate_json(ex, i, o))
        .collect();
    let mut pairs = vec![
        ("kernel", Json::str(ex.kernel.clone())),
        ("elements", Json::num(ex.n_elements as f64)),
        ("enumerated", Json::num(ex.enumerated() as f64)),
        ("feasible", Json::num(ex.feasible_count() as f64)),
        ("rejected", Json::num(ex.rejected_count() as f64)),
        ("frontier_size", Json::num(ex.frontier.len() as f64)),
        ("candidates", Json::Arr(candidates)),
    ];
    if let Some(s) = &ex.stats {
        pairs.push(("search", s.to_json()));
    }
    Json::obj(pairs).to_string()
}

fn candidate_json(ex: &Exploration, i: usize, o: &EvalOutcome) -> Json {
    let opts = &o.point.opts;
    let mut pairs = vec![
        ("label", Json::str(o.point.label())),
        ("kernel", Json::str(o.point.kernel.clone())),
        ("p", Json::num(o.point.p as f64)),
        ("dtype", Json::str(opts.dtype.name())),
        ("cus", Json::num(opts.num_cus as f64)),
        ("bus", Json::str(opts.bus.name())),
        ("memory", Json::str(opts.memory.name())),
        ("double_buffering", Json::Bool(opts.double_buffering)),
        (
            "dataflow",
            opts.dataflow.map(|g| Json::num(g as f64)).unwrap_or(Json::Null),
        ),
        ("mem_sharing", Json::Bool(opts.mem_sharing)),
        (
            "partition_cap",
            opts.partition_cap.map(|c| Json::num(c as f64)).unwrap_or(Json::Null),
        ),
        (
            "fifo_depth",
            opts.fifo_depth.map(|d| Json::num(d as f64)).unwrap_or(Json::Null),
        ),
        ("policy", Json::str(opts.channel_policy.name())),
        ("cache_scheme", Json::str(opts.cache_scheme.name())),
        ("pareto", Json::Bool(ex.is_on_frontier(i))),
    ];
    match &o.result {
        Ok(e) => pairs.extend([
            ("feasible", Json::Bool(e.feasible)),
            ("fmax_mhz", Json::num(e.fmax_mhz)),
            ("gflops_cu", Json::num(e.sim.gflops_cu)),
            ("gflops_system", Json::num(e.sim.gflops_system)),
            ("gflops_per_w", Json::num(e.sim.efficiency_gflops_w)),
            ("power_w", Json::num(e.sim.avg_power_w)),
            ("energy_j", Json::num(e.sim.energy_j)),
            ("lut", Json::num(e.total.lut as f64)),
            ("ff", Json::num(e.total.ff as f64)),
            ("bram", Json::num(e.total.bram as f64)),
            ("uram", Json::num(e.total.uram as f64)),
            ("dsp", Json::num(e.total.dsp as f64)),
            ("mem_banks", Json::num(e.sim.mem_banks as f64)),
            ("mem_shared_words", Json::num(e.sim.mem_shared_words as f64)),
            ("mem_unshared_words", Json::num(e.sim.mem_unshared_words as f64)),
            ("conflict_stalls", Json::num(e.sim.conflict_stalls as f64)),
            ("max_utilization", Json::num(e.max_utilization)),
            (
                "max_channel_util",
                Json::num(e.sim.max_channel_utilization),
            ),
            (
                "switch_crossings",
                Json::num(e.sim.switch_crossings as f64),
            ),
            (
                "channel_utilization",
                Json::Arr(
                    e.sim
                        .channel_utilization
                        .iter()
                        .map(|&(pc, u)| {
                            Json::obj(vec![
                                ("channel", Json::num(pc as f64)),
                                ("utilization", Json::num(u)),
                            ])
                        })
                        .collect(),
                ),
            ),
            ("bottleneck", Json::str(e.sim.bottleneck.clone())),
        ]),
        Err(reason) => pairs.extend([
            ("feasible", Json::Bool(false)),
            ("rejected", Json::str(reason.clone())),
        ]),
    }
    Json::obj(pairs)
}

/// CSV with one row per outcome; rejected candidates keep their axis
/// columns and carry the reason in the last field.
pub fn csv(ex: &Exploration) -> String {
    let mut out = String::from(
        "kernel,p,dtype,cus,bus,memory,double_buffering,dataflow,mem_sharing,\
         partition_cap,fifo_depth,policy,cache_scheme,status,feasible,pareto,\
         fmax_mhz,gflops_cu,gflops_system,gflops_per_w,energy_j,lut,ff,bram,\
         uram,dsp,mem_banks,mem_shared_words,conflict_stalls,\
         max_channel_util,switch_crossings,bottleneck,reject_reason\n",
    );
    for (i, o) in ex.outcomes.iter().enumerate() {
        let opts = &o.point.opts;
        let axes = format!(
            "{},{},{},{},{},{},{},{},{},{},{},{},{}",
            o.point.kernel,
            o.point.p,
            opts.dtype.name(),
            opts.num_cus,
            opts.bus.name(),
            opts.memory.name(),
            opts.double_buffering,
            opts.dataflow.map(|g| g.to_string()).unwrap_or_default(),
            opts.mem_sharing,
            opts.partition_cap.map(|c| c.to_string()).unwrap_or_default(),
            opts.fifo_depth.map(|d| d.to_string()).unwrap_or_default(),
            opts.channel_policy.name(),
            opts.cache_scheme.name(),
        );
        let row = match &o.result {
            Ok(e) => format!(
                "{axes},ok,{},{},{:.3},{:.4},{:.4},{:.4},{:.4},{},{},{},{},{},\
                 {},{},{},{:.3},{},{},\n",
                e.feasible,
                ex.is_on_frontier(i),
                e.fmax_mhz,
                e.sim.gflops_cu,
                e.sim.gflops_system,
                e.sim.efficiency_gflops_w,
                e.sim.energy_j,
                e.total.lut,
                e.total.ff,
                e.total.bram,
                e.total.uram,
                e.total.dsp,
                e.sim.mem_banks,
                e.sim.mem_shared_words,
                e.sim.conflict_stalls,
                e.sim.max_channel_utilization,
                e.sim.switch_crossings,
                e.sim.bottleneck,
            ),
            Err(reason) => format!(
                "{axes},rejected,false,false,,,,,,,,,,,,,,,,,{}\n",
                reason.replace(',', ";"),
            ),
        };
        out.push_str(&row);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dse::{explore, SearchSpace};
    use crate::olympus::BusMode;
    use crate::platform::Platform;
    use crate::util::json;

    fn small() -> Exploration {
        let mut s = SearchSpace::default_for("helmholtz");
        s.degrees = vec![11];
        s.dtypes = vec![DataType::F64, DataType::Fx32];
        s.cu_counts = vec![1];
        s.dataflow = vec![Some(7)];
        s.double_buffering = vec![true];
        s.bus_modes = vec![BusMode::Wide256Parallel];
        s.mem_sharing = vec![false];
        s.fifo_depths = vec![None];
        explore(&s, &Platform::alveo_u280(), 200_000, Some(2)).unwrap()
    }

    #[test]
    fn text_report_ranks_and_footers() {
        let ex = small();
        let t = text(&ex, 0, false);
        assert!(t.contains("configuration"), "{t}");
        assert!(t.contains("Pareto frontier"), "{t}");
        assert!(t.contains("Fig. 16 custom precision"), "{t}");
        // pareto-only is a subset of the full report
        let p = text(&ex, 0, true);
        assert!(p.lines().count() <= t.lines().count());
        assert!(p.contains('*'));
    }

    #[test]
    fn top_k_truncates_rows() {
        let ex = small();
        let all = text(&ex, 0, false);
        let one = text(&ex, 1, false);
        assert!(one.lines().count() < all.lines().count());
    }

    #[test]
    fn json_roundtrips_through_the_parser() {
        let ex = small();
        let j = json::parse(&json(&ex)).expect("valid JSON");
        assert_eq!(j.get("kernel").as_str(), Some("helmholtz"));
        let cands = j.get("candidates").as_arr().unwrap();
        assert_eq!(cands.len(), ex.enumerated());
        assert_eq!(cands[0].get("dtype").as_str(), Some("f64"));
        assert!(cands[0].get("gflops_system").as_f64().unwrap() > 0.0);
    }

    #[test]
    fn search_results_report_sweep_counters() {
        let mut s = SearchSpace::default_for("helmholtz");
        s.degrees = vec![11];
        s.dtypes = vec![DataType::Fx32];
        s.cu_counts = vec![1];
        s.dataflow = vec![Some(7)];
        s.double_buffering = vec![true];
        s.bus_modes = vec![BusMode::Wide256Parallel];
        s.mem_sharing = vec![false];
        s.fifo_depths = vec![None];
        let cfg = crate::dse::SearchConfig {
            threads: Some(2),
            ..crate::dse::SearchConfig::default()
        };
        let ex = crate::dse::search(&s, &Platform::alveo_u280(), 200_000, &cfg)
            .unwrap();
        let t = text(&ex, 0, false);
        assert!(t.contains("candidates considered"), "{t}");
        assert!(t.contains("exact sims"), "{t}");
        assert!(!t.contains("paused"), "completed sweep: {t}");
        let j = json::parse(&json(&ex)).expect("valid JSON");
        let search = j.get("search");
        assert_eq!(search.get("complete"), &json::Json::Bool(true));
        assert!(search.get("considered").as_u64().unwrap() >= 1);
        // CSV rows cover exactly the resident (frontier) outcomes
        let c = csv(&ex);
        assert_eq!(c.lines().count(), 1 + ex.outcomes.len());
    }

    #[test]
    fn csv_has_one_row_per_outcome_plus_header() {
        let ex = small();
        let c = csv(&ex);
        assert_eq!(c.lines().count(), 1 + ex.enumerated());
        assert!(c.starts_with("kernel,p,dtype"));
        assert!(c.contains("fx32"));
        let ncols = c.lines().next().unwrap().split(',').count();
        for line in c.lines() {
            assert_eq!(line.split(',').count(), ncols, "{line}");
        }
    }
}
