//! Pareto-frontier extraction over candidate objective vectors.
//!
//! Objectives are oriented so that **larger is always better**; quantities
//! the designer minimizes (energy, BRAM/URAM/DSP) are negated when the
//! vector is assembled. A candidate `a` *dominates* `b` when `a` is at
//! least as good in every objective and strictly better in at least one —
//! the standard strict Pareto dominance, so exact ties survive (two
//! candidates with identical vectors are both frontier members; the
//! designer breaks the tie on axes the objectives do not capture).
//!
//! The extraction is the O(n²) pairwise scan: with the full default
//! helmholtz space (~2k candidates, 6 objectives) that is ~10⁷ float
//! comparisons — noise next to the evaluation pass that produced the
//! vectors. Replace with a divide-and-conquer skyline only if spaces grow
//! by orders of magnitude.

use super::eval::Evaluated;

/// `true` when `a` Pareto-dominates `b` (both oriented larger-is-better):
/// `a[i] >= b[i]` for all `i` and `a[j] > b[j]` for some `j`.
///
/// Vectors must be the same length and free of NaN (every objective in
/// `objectives` is a finite simulator/estimator output).
pub fn dominates(a: &[f64], b: &[f64]) -> bool {
    debug_assert_eq!(a.len(), b.len());
    let mut strictly_better = false;
    for (x, y) in a.iter().zip(b.iter()) {
        if x < y {
            return false;
        }
        if x > y {
            strictly_better = true;
        }
    }
    strictly_better
}

/// Indices of the non-dominated points (the Pareto frontier), in input
/// order. Empty input yields an empty frontier; a singleton is always
/// its own frontier.
pub fn pareto_indices(points: &[Vec<f64>]) -> Vec<usize> {
    (0..points.len())
        .filter(|&i| {
            !points
                .iter()
                .enumerate()
                .any(|(j, q)| j != i && dominates(q, &points[i]))
        })
        .collect()
}

/// Objective vector of one evaluated candidate, larger-is-better:
/// `[system GFLOPS, −energy (J), −BRAM, −URAM, −DSP, −switch
/// crossings]` — the throughput / energy / resource trade the paper's
/// Figs. 15–18 walk by hand, plus the interconnect-routing cost the
/// `hbm` model now measures (all-local allocations tie at zero, so the
/// axis only discriminates when a policy actually crosses the switch).
pub fn objectives(e: &Evaluated) -> Vec<f64> {
    vec![
        e.sim.gflops_system,
        -e.sim.energy_j,
        -(e.total.bram as f64),
        -(e.total.uram as f64),
        -(e.total.dsp as f64),
        -(e.sim.switch_crossings as f64),
    ]
}

/// [`objectives`] evaluated at a *hypothetical* makespan `total_s`
/// instead of the simulated one. Only throughput and energy depend on
/// time; resources and switch crossings are exact in every simulation
/// mode. The adaptive explorer calls this with an analytic bound's
/// lower/upper endpoints to form a candidate's optimistic/conservative
/// vectors: a candidate whose *optimistic* vector is dominated by
/// another's *conservative* vector is dominated for any true makespans
/// inside the brackets, so it can be pruned without running the event
/// simulator.
pub fn objectives_with_time(e: &Evaluated, total_s: f64) -> Vec<f64> {
    let t = total_s.max(1e-12);
    vec![
        e.sim.total_flops as f64 / t / 1e9,
        -(e.sim.avg_power_w * t),
        -(e.total.bram as f64),
        -(e.total.uram as f64),
        -(e.total.dsp as f64),
        -(e.sim.switch_crossings as f64),
    ]
}

/// Incrementally-maintained Pareto frontier over keyed objective
/// vectors — the memory-bounded replacement for collecting every
/// outcome and calling [`pareto_indices`] at the end. `offer` either
/// rejects a dominated candidate or admits it and evicts the members it
/// dominates; ties survive, exactly like the batch scan, so offering a
/// sequence point-by-point yields the same surviving set (by key) as
/// one [`pareto_indices`] call over the whole sequence, in first-offer
/// order.
#[derive(Debug, Clone, Default)]
pub struct Frontier {
    entries: Vec<(usize, Vec<f64>)>,
    peak: usize,
}

impl Frontier {
    pub fn new() -> Frontier {
        Frontier::default()
    }

    /// Offer a candidate; returns `true` when it joins the frontier.
    /// A candidate dominated by (or merely tied with part of) the
    /// current frontier is handled exactly as the batch scan would:
    /// dominated ⇒ rejected, otherwise admitted and every member it
    /// dominates is evicted.
    pub fn offer(&mut self, key: usize, v: Vec<f64>) -> bool {
        if self.entries.iter().any(|(_, q)| dominates(q, &v)) {
            return false;
        }
        self.entries.retain(|(_, q)| !dominates(&v, q));
        self.entries.push((key, v));
        self.peak = self.peak.max(self.entries.len());
        true
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Keys of the surviving members, in first-offer order.
    pub fn keys(&self) -> Vec<usize> {
        self.entries.iter().map(|(k, _)| *k).collect()
    }

    /// Surviving members as (key, objective vector) pairs.
    pub fn entries(&self) -> &[(usize, Vec<f64>)] {
        &self.entries
    }

    /// Largest member count ever held — the frontier's own contribution
    /// to a sweep's peak resident set.
    pub fn peak_len(&self) -> usize {
        self.peak
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn domination_requires_strict_improvement_somewhere() {
        assert!(dominates(&[2.0, 1.0], &[1.0, 1.0]));
        assert!(dominates(&[2.0, 2.0], &[1.0, 1.0]));
        assert!(!dominates(&[1.0, 1.0], &[1.0, 1.0]), "equal never dominates");
        assert!(!dominates(&[1.0, 2.0], &[2.0, 1.0]), "trade-off: incomparable");
        assert!(!dominates(&[0.5, 2.0], &[1.0, 1.0]));
    }

    #[test]
    fn frontier_drops_dominated_points() {
        let pts = vec![
            vec![1.0, 1.0], // dominated by [2,2]
            vec![2.0, 2.0],
            vec![3.0, 0.0], // trade-off: survives
        ];
        assert_eq!(pareto_indices(&pts), vec![1, 2]);
    }

    #[test]
    fn exact_ties_both_survive() {
        let pts = vec![vec![1.0, 2.0], vec![1.0, 2.0], vec![0.5, 0.5]];
        assert_eq!(pareto_indices(&pts), vec![0, 1]);
    }

    #[test]
    fn empty_and_singleton_spaces() {
        assert!(pareto_indices(&[]).is_empty());
        assert_eq!(pareto_indices(&[vec![-1.0, -1.0]]), vec![0]);
    }

    #[test]
    fn single_objective_keeps_only_the_max() {
        let pts = vec![vec![1.0], vec![3.0], vec![2.0], vec![3.0]];
        assert_eq!(pareto_indices(&pts), vec![1, 3], "tied maxima both kept");
    }

    #[test]
    fn incremental_frontier_matches_the_batch_scan() {
        // every insertion order detail is pinned against pareto_indices
        // over the same sequence: identical surviving keys
        let mut pts = Vec::new();
        for i in 0..6 {
            for j in 0..6 {
                pts.push(vec![i as f64, j as f64, -(((i * j) % 5) as f64)]);
            }
        }
        let mut f = Frontier::new();
        for (k, v) in pts.iter().enumerate() {
            f.offer(k, v.clone());
        }
        let batch = pareto_indices(&pts);
        let mut inc = f.keys();
        inc.sort_unstable();
        assert_eq!(inc, batch);
        assert!(f.peak_len() >= f.len());
        assert!(f.peak_len() <= pts.len());
    }

    #[test]
    fn incremental_frontier_keeps_ties_and_evicts_dominated() {
        let mut f = Frontier::new();
        assert!(f.offer(0, vec![1.0, 1.0]));
        assert!(f.offer(1, vec![1.0, 1.0]), "exact tie survives");
        assert!(!f.offer(2, vec![0.5, 0.5]), "dominated rejected");
        assert!(f.offer(3, vec![2.0, 2.0]), "dominator evicts both ties");
        assert_eq!(f.keys(), vec![3]);
        assert_eq!(f.peak_len(), 2);
    }

    #[test]
    fn frontier_is_mutually_non_dominating() {
        // a small grid: frontier members must be pairwise incomparable
        let mut pts = Vec::new();
        for i in 0..5 {
            for j in 0..5 {
                pts.push(vec![i as f64, j as f64, -((i * j) as f64)]);
            }
        }
        let front = pareto_indices(&pts);
        assert!(!front.is_empty());
        for &a in &front {
            for &b in &front {
                if a != b {
                    assert!(!dominates(&pts[a], &pts[b]), "{a} dominates {b}");
                }
            }
        }
    }
}
