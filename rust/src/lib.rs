//! hbmflow: automatic creation of high-bandwidth memory architectures
//! from a tensor DSL — reproduction of Soldavini et al., ACM TRETS 2022
//! (DOI 10.1145/3563553) as a three-layer Rust + JAX + Pallas stack.
//!
//! See DESIGN.md for the system inventory and experiment index,
//! README.md for the quickstart, and docs/CFDLANG.md for the language
//! reference; see the module docs for per-subsystem detail. The public
//! API is the `flow` module: a typed staged pipeline
//! (`Parsed → Lowered → Mapped → Evaluated`) with persistable artifacts
//! and a thread-safe caching `Session` for batch evaluation. The
//! `kernels` front door (`kernels::KernelSource`) feeds *any* CFDlang
//! program — builtin, `.cfd` file, or inline — through the same stages,
//! and `dse` explores the whole option space the pipeline walks one
//! configuration of:
//!
//! ```
//! use hbmflow::prelude::*;
//! use hbmflow::olympus::OlympusOpts;
//! use hbmflow::platform::Platform;
//!
//! let flow = Flow::from_source(KernelSource::builtin("helmholtz"));
//! let ev = flow
//!     .parse(7)?                                            // DSL -> teil (+rewrite)
//!     .lower()?                                             // -> affine kernel
//!     .map(&OlympusOpts::dataflow(7), &Platform::alveo_u280())? // -> SystemSpec
//!     .simulate(100_000);                                   // -> estimate + sim
//! assert!(ev.sim().unwrap().gflops_system > 0.0);
//! # Ok::<(), hbmflow::flow::FlowError>(())
//! ```

pub mod baselines;
pub mod cli;
pub mod codegen;
pub mod coordinator;
pub mod datatype;
pub mod dse;
pub mod dsl;
pub mod flow;
pub mod hbm;
pub mod hls;
pub mod ir;
pub mod kernels;
pub mod mnemosyne;
pub mod olympus;
pub mod platform;
pub mod precision;
pub mod report;
pub mod runtime;
pub mod sim;
pub mod util;

/// Convenience re-exports for examples and tests.
pub mod prelude {
    pub use crate::dsl::{parse, Program};
    pub use crate::flow::{EvalKind, Flow, FlowRequest, Session};
    pub use crate::ir::affine::Kernel;
    pub use crate::ir::schedule::Schedule;
    pub use crate::kernels::KernelSource;
    pub use crate::util::tensor::Tensor;
}
