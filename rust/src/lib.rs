//! hbmflow: automatic creation of high-bandwidth memory architectures
//! from a tensor DSL — reproduction of Soldavini et al., ACM TRETS 2022
//! (DOI 10.1145/3563553) as a three-layer Rust + JAX + Pallas stack.
//!
//! See DESIGN.md for the system inventory and experiment index,
//! README.md for the quickstart, and docs/CFDLANG.md for the language
//! reference; see the module docs for per-subsystem detail. The `dse`
//! module explores the whole option space the pipeline below walks one
//! configuration of, and the `kernels` front door
//! (`kernels::KernelSource`) feeds *any* CFDlang program — builtin,
//! `.cfd` file, or inline — through the same stages. The top-level
//! pipeline:
//!
//! ```no_run
//! use hbmflow::prelude::*;
//!
//! let src = hbmflow::dsl::inverse_helmholtz_source(11);
//! let program = hbmflow::dsl::parse(&src).unwrap();
//! let module = hbmflow::ir::teil::from_ast(&program).unwrap();
//! let module = hbmflow::ir::rewrite::optimize(module);
//! let kernel = hbmflow::ir::lower::lower_kernel(&module, "helmholtz").unwrap();
//! let schedule = hbmflow::ir::schedule::fixed(&kernel, 7).unwrap();
//! ```

pub mod baselines;
pub mod cli;
pub mod codegen;
pub mod coordinator;
pub mod datatype;
pub mod dse;
pub mod dsl;
pub mod hbm;
pub mod hls;
pub mod ir;
pub mod kernels;
pub mod mnemosyne;
pub mod olympus;
pub mod platform;
pub mod precision;
pub mod report;
pub mod runtime;
pub mod sim;
pub mod util;

/// Convenience re-exports for examples and tests.
pub mod prelude {
    pub use crate::dsl::{parse, Program};
    pub use crate::ir::affine::Kernel;
    pub use crate::ir::schedule::Schedule;
    pub use crate::kernels::KernelSource;
    pub use crate::util::tensor::Tensor;
}
