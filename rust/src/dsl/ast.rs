//! CFDlang abstract syntax tree.
//!
//! Mirrors the paper's `cfdlang` MLIR dialect (§3.3.1): the AST stays as
//! close to the source as possible; canonicalization happens in the teil
//! middle-end, not here.

use std::fmt;

/// Variable role in the kernel interface (paper Fig. 13).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum VarKind {
    /// `var input` — streamed from HBM into the CU.
    Input,
    /// `var output` — streamed from the CU back to HBM.
    Output,
    /// plain `var` — an internal buffer, candidate for Mnemosyne sharing.
    Temp,
}

/// `var input S : [11 11]`
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Decl {
    pub name: String,
    pub kind: VarKind,
    pub shape: Vec<usize>,
}

/// One index pair of a contraction spec: positions into the flattened
/// index space of the contracted expression (paper Fig. 2 line 7).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct IndexPair {
    pub a: usize,
    pub b: usize,
}

/// Expression tree. `Prod` is the tensor (outer) product `#`;
/// `Contract` applies index-pair contraction `.[[a b]..]`; `Gather` is
/// the indirect row read `base[idx]` through a rank-1 index variable.
#[derive(Debug, Clone, PartialEq)]
pub enum Expr {
    Var(String),
    Add(Box<Expr>, Box<Expr>),
    Sub(Box<Expr>, Box<Expr>),
    Mul(Box<Expr>, Box<Expr>),
    Div(Box<Expr>, Box<Expr>),
    Prod(Box<Expr>, Box<Expr>),
    Contract(Box<Expr>, Vec<IndexPair>),
    Gather(Box<Expr>, String),
}

impl Expr {
    pub fn var(name: &str) -> Expr {
        Expr::Var(name.to_string())
    }

    /// All variable names referenced by this expression, in order of
    /// first appearance.
    pub fn vars(&self) -> Vec<&str> {
        let mut out = Vec::new();
        self.visit(&mut |e| {
            let name = match e {
                Expr::Var(n) => Some(n.as_str()),
                // the index variable is a real data dependency even
                // though it is not an Expr::Var node
                Expr::Gather(_, ix) => Some(ix.as_str()),
                _ => None,
            };
            if let Some(n) = name {
                if !out.contains(&n) {
                    out.push(n);
                }
            }
        });
        out
    }

    pub fn visit<'a>(&'a self, f: &mut impl FnMut(&'a Expr)) {
        f(self);
        match self {
            Expr::Var(_) => {}
            Expr::Add(a, b)
            | Expr::Sub(a, b)
            | Expr::Mul(a, b)
            | Expr::Div(a, b)
            | Expr::Prod(a, b) => {
                a.visit(f);
                b.visit(f);
            }
            Expr::Contract(a, _) | Expr::Gather(a, _) => a.visit(f),
        }
    }
}

impl fmt::Display for Expr {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Expr::Var(n) => write!(f, "{n}"),
            Expr::Add(a, b) => write!(f, "({a} + {b})"),
            Expr::Sub(a, b) => write!(f, "({a} - {b})"),
            Expr::Mul(a, b) => write!(f, "({a} * {b})"),
            Expr::Div(a, b) => write!(f, "({a} / {b})"),
            Expr::Prod(a, b) => write!(f, "{a} # {b}"),
            Expr::Contract(a, pairs) => {
                write!(f, "{a} . [")?;
                for p in pairs {
                    write!(f, "[{} {}]", p.a, p.b)?;
                }
                write!(f, "]")
            }
            // parenthesize non-variable bases so the postfix index
            // reparses onto the same subtree
            Expr::Gather(a, ix) => match a.as_ref() {
                Expr::Var(n) => write!(f, "{n}[{ix}]"),
                _ => write!(f, "({a})[{ix}]"),
            },
        }
    }
}

/// `t = <expr>`, or the indirect-write forms `t[idx] = <expr>` /
/// `t[idx] += <expr>` (scatter; `accumulate` marks `+=`).
#[derive(Debug, Clone, PartialEq)]
pub struct Stmt {
    pub target: String,
    pub expr: Expr,
    /// Index variable of a scatter target (`t[idx] = ...`).
    pub index: Option<String>,
    /// `+=`: duplicate indices accumulate instead of overwriting.
    pub accumulate: bool,
}

/// A full CFDlang program: declarations then assignments.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct Program {
    pub decls: Vec<Decl>,
    pub stmts: Vec<Stmt>,
}

impl Program {
    pub fn decl(&self, name: &str) -> Option<&Decl> {
        self.decls.iter().find(|d| d.name == name)
    }

    pub fn inputs(&self) -> impl Iterator<Item = &Decl> {
        self.decls.iter().filter(|d| d.kind == VarKind::Input)
    }

    pub fn outputs(&self) -> impl Iterator<Item = &Decl> {
        self.decls.iter().filter(|d| d.kind == VarKind::Output)
    }

    pub fn temps(&self) -> impl Iterator<Item = &Decl> {
        self.decls.iter().filter(|d| d.kind == VarKind::Temp)
    }
}

impl fmt::Display for Program {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        for d in &self.decls {
            let kind = match d.kind {
                VarKind::Input => "input ",
                VarKind::Output => "output ",
                VarKind::Temp => "",
            };
            write!(f, "var {kind}{} : [", d.name)?;
            for (i, s) in d.shape.iter().enumerate() {
                if i > 0 {
                    write!(f, " ")?;
                }
                write!(f, "{s}")?;
            }
            writeln!(f, "]")?;
        }
        for s in &self.stmts {
            match &s.index {
                Some(ix) => writeln!(
                    f,
                    "{}[{}] {}= {}",
                    s.target,
                    ix,
                    if s.accumulate { "+" } else { "" },
                    s.expr
                )?,
                None => writeln!(f, "{} = {}", s.target, s.expr)?,
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn expr_vars_dedup_in_order() {
        let e = Expr::Prod(
            Box::new(Expr::var("S")),
            Box::new(Expr::Prod(
                Box::new(Expr::var("S")),
                Box::new(Expr::var("u")),
            )),
        );
        assert_eq!(e.vars(), vec!["S", "u"]);
    }

    #[test]
    fn display_roundtrips_through_parser() {
        let src = crate::dsl::inverse_helmholtz_source(7);
        let p1 = crate::dsl::parse(&src).unwrap();
        let p2 = crate::dsl::parse(&p1.to_string()).unwrap();
        assert_eq!(p1, p2);
    }

    #[test]
    fn program_role_filters() {
        let p = crate::dsl::parse(&crate::dsl::inverse_helmholtz_source(5)).unwrap();
        assert_eq!(p.inputs().count(), 3);
        assert_eq!(p.outputs().count(), 1);
        assert_eq!(p.temps().count(), 2);
        assert!(p.decl("S").is_some());
        assert!(p.decl("nope").is_none());
    }
}
