//! CFDlang recursive-descent parser with semantic checks.
//!
//! Precedence (loosest to tightest): contraction `.` < `+`/`-` < `*`/`/`
//! < `#`. This matches the paper's listing where
//! `t = S#S#S#u . [[1 6][3 7][5 8]]` contracts the *whole* product.
//!
//! Every error — lexical, syntactic, or semantic — carries a source
//! position (`line L, col C` for token errors, the statement's line for
//! semantic ones), so a typo in a user `.cfd` file points at the
//! offending token. See docs/CFDLANG.md for the full grammar.

use super::ast::{Decl, Expr, IndexPair, Program, Stmt, VarKind};
use super::lexer::{lex, Spanned, Tok};

/// Parse and semantically validate a CFDlang program.
pub fn parse(src: &str) -> Result<Program, String> {
    let toks = lex(src)?;
    let mut p = Parser {
        toks,
        pos: 0,
        decl_lines: Vec::new(),
        stmt_lines: Vec::new(),
    };
    let prog = p.program()?;
    validate(&prog, &p.decl_lines, &p.stmt_lines)?;
    Ok(prog)
}

struct Parser {
    toks: Vec<Spanned>,
    pos: usize,
    /// Source line of each declaration / statement, parallel to
    /// `Program::decls` / `Program::stmts` — anchors semantic errors.
    decl_lines: Vec<usize>,
    stmt_lines: Vec<usize>,
}

impl Parser {
    fn peek(&self) -> Option<&Tok> {
        self.toks.get(self.pos).map(|s| &s.tok)
    }

    /// (line, col) of the current token, or of the last token when the
    /// input ended early.
    fn here(&self) -> (usize, usize) {
        self.toks
            .get(self.pos.min(self.toks.len().saturating_sub(1)))
            .map(|s| (s.line, s.col))
            .unwrap_or((0, 0))
    }

    fn bump(&mut self) -> Option<Tok> {
        let t = self.toks.get(self.pos).map(|s| s.tok.clone());
        if t.is_some() {
            self.pos += 1;
        }
        t
    }

    fn expect(&mut self, want: &Tok) -> Result<(), String> {
        let (line, col) = self.here();
        match self.bump() {
            Some(ref t) if t == want => Ok(()),
            got => Err(format!(
                "line {line}, col {col}: expected '{want}', got {}",
                got.map(|t| t.to_string()).unwrap_or_else(|| "EOF".into())
            )),
        }
    }

    fn ident(&mut self) -> Result<String, String> {
        let (line, col) = self.here();
        match self.bump() {
            Some(Tok::Ident(s)) => Ok(s),
            got => Err(format!(
                "line {line}, col {col}: expected identifier, got {}",
                got.map(|t| t.to_string()).unwrap_or_else(|| "EOF".into())
            )),
        }
    }

    fn int(&mut self) -> Result<usize, String> {
        let (line, col) = self.here();
        match self.bump() {
            Some(Tok::Int(n)) => Ok(n),
            got => Err(format!(
                "line {line}, col {col}: expected integer, got {}",
                got.map(|t| t.to_string()).unwrap_or_else(|| "EOF".into())
            )),
        }
    }

    fn program(&mut self) -> Result<Program, String> {
        let mut prog = Program::default();
        while self.peek() == Some(&Tok::Var) {
            let line = self.here().0;
            prog.decls.push(self.decl()?);
            self.decl_lines.push(line);
        }
        while self.peek().is_some() {
            let line = self.here().0;
            prog.stmts.push(self.stmt()?);
            self.stmt_lines.push(line);
        }
        Ok(prog)
    }

    fn decl(&mut self) -> Result<Decl, String> {
        let (line, col) = self.here();
        self.expect(&Tok::Var)?;
        let kind = match self.peek() {
            Some(Tok::Input) => {
                self.bump();
                VarKind::Input
            }
            Some(Tok::Output) => {
                self.bump();
                VarKind::Output
            }
            _ => VarKind::Temp,
        };
        let name = self.ident()?;
        self.expect(&Tok::Colon)?;
        self.expect(&Tok::LBracket)?;
        let mut shape = Vec::new();
        while let Some(Tok::Int(_)) = self.peek() {
            shape.push(self.int()?);
        }
        self.expect(&Tok::RBracket)?;
        if shape.is_empty() {
            return Err(format!(
                "line {line}, col {col}: variable {name} has empty shape"
            ));
        }
        Ok(Decl { name, kind, shape })
    }

    /// stmt := ident ('[' ident ']')? ('='|'+=') expr
    /// (`+=` only with an indexed target: scatter-add)
    fn stmt(&mut self) -> Result<Stmt, String> {
        let (line, col) = self.here();
        let target = self.ident()?;
        let mut index = None;
        if self.peek() == Some(&Tok::LBracket) {
            self.bump();
            index = Some(self.ident()?);
            self.expect(&Tok::RBracket)?;
        }
        let accumulate = if self.peek() == Some(&Tok::Plus) {
            self.bump();
            true
        } else {
            false
        };
        self.expect(&Tok::Equals)?;
        if accumulate && index.is_none() {
            return Err(format!(
                "line {line}, col {col}: '+=' requires an indexed target \
                 ('{target}[idx] += ...')"
            ));
        }
        let expr = self.expr()?;
        Ok(Stmt {
            target,
            expr,
            index,
            accumulate,
        })
    }

    /// expr := add ( '.' contraction )?
    fn expr(&mut self) -> Result<Expr, String> {
        let e = self.add()?;
        if self.peek() == Some(&Tok::Dot) {
            self.bump();
            let pairs = self.contraction()?;
            return Ok(Expr::Contract(Box::new(e), pairs));
        }
        Ok(e)
    }

    fn add(&mut self) -> Result<Expr, String> {
        let mut e = self.mul()?;
        loop {
            match self.peek() {
                Some(Tok::Plus) => {
                    self.bump();
                    e = Expr::Add(Box::new(e), Box::new(self.mul()?));
                }
                Some(Tok::Minus) => {
                    self.bump();
                    e = Expr::Sub(Box::new(e), Box::new(self.mul()?));
                }
                _ => return Ok(e),
            }
        }
    }

    fn mul(&mut self) -> Result<Expr, String> {
        let mut e = self.prod()?;
        loop {
            match self.peek() {
                Some(Tok::Star) => {
                    self.bump();
                    e = Expr::Mul(Box::new(e), Box::new(self.prod()?));
                }
                Some(Tok::Slash) => {
                    self.bump();
                    e = Expr::Div(Box::new(e), Box::new(self.prod()?));
                }
                _ => return Ok(e),
            }
        }
    }

    fn prod(&mut self) -> Result<Expr, String> {
        let mut e = self.primary()?;
        while self.peek() == Some(&Tok::Hash) {
            self.bump();
            e = Expr::Prod(Box::new(e), Box::new(self.primary()?));
        }
        Ok(e)
    }

    /// primary := ( '(' expr ')' | ident ) ('[' ident ']')*
    /// — the postfix index is the gather form `base[idx]`.
    fn primary(&mut self) -> Result<Expr, String> {
        let mut e = match self.peek() {
            Some(Tok::LParen) => {
                self.bump();
                let e = self.expr()?;
                self.expect(&Tok::RParen)?;
                e
            }
            Some(Tok::Ident(_)) => Expr::Var(self.ident()?),
            other => {
                let (line, col) = self.here();
                return Err(format!(
                    "line {line}, col {col}: expected expression, got {}",
                    other
                        .map(|t| t.to_string())
                        .unwrap_or_else(|| "EOF".into())
                ));
            }
        };
        while self.peek() == Some(&Tok::LBracket) {
            self.bump();
            let ix = self.ident()?;
            self.expect(&Tok::RBracket)?;
            e = Expr::Gather(Box::new(e), ix);
        }
        Ok(e)
    }

    /// contraction := '[' ('[' int int ']')+ ']'
    fn contraction(&mut self) -> Result<Vec<IndexPair>, String> {
        let (line, col) = self.here();
        self.expect(&Tok::LBracket)?;
        let mut pairs = Vec::new();
        while self.peek() == Some(&Tok::LBracket) {
            self.bump();
            let a = self.int()?;
            let b = self.int()?;
            self.expect(&Tok::RBracket)?;
            pairs.push(IndexPair { a, b });
        }
        self.expect(&Tok::RBracket)?;
        if pairs.is_empty() {
            return Err(format!(
                "line {line}, col {col}: empty contraction spec"
            ));
        }
        Ok(pairs)
    }
}

/// Semantic checks: declared-before-use, single assignment, every output
/// assigned, no input assigned, contraction pairs in range and disjoint.
/// Errors are anchored to the offending statement's (or declaration's)
/// source line via the parallel line tables the parser records.
fn validate(prog: &Program, decl_lines: &[usize], stmt_lines: &[usize]) -> Result<(), String> {
    use std::collections::HashSet;
    let mut assigned = HashSet::new();
    for (si, stmt) in prog.stmts.iter().enumerate() {
        let line = stmt_lines.get(si).copied().unwrap_or(0);
        let decl = prog.decl(&stmt.target).ok_or_else(|| {
            format!(
                "line {line}: assignment to undeclared variable {}",
                stmt.target
            )
        })?;
        if decl.kind == VarKind::Input {
            return Err(format!(
                "line {line}: cannot assign to input variable {}",
                stmt.target
            ));
        }
        if !assigned.insert(stmt.target.clone()) {
            return Err(format!(
                "line {line}: variable {} assigned twice",
                stmt.target
            ));
        }
        for v in stmt.expr.vars() {
            let vd = prog.decl(v).ok_or_else(|| {
                format!("line {line}: use of undeclared variable {v}")
            })?;
            if vd.kind != VarKind::Input && !assigned.contains(v) {
                return Err(format!(
                    "line {line}: variable {v} used before assignment in '{} = ...'",
                    stmt.target
                ));
            }
        }
        if let Some(ix) = &stmt.index {
            let ixd = prog.decl(ix).ok_or_else(|| {
                format!("line {line}: use of undeclared index variable {ix}")
            })?;
            if ixd.shape.len() != 1 {
                return Err(format!(
                    "line {line}: index variable {ix} must be rank 1, got {:?}",
                    ixd.shape
                ));
            }
            if ixd.kind != VarKind::Input && !assigned.contains(ix) {
                return Err(format!(
                    "line {line}: variable {ix} used before assignment in '{} = ...'",
                    stmt.target
                ));
            }
        }
        validate_contractions(&stmt.expr, prog)
            .map_err(|e| format!("line {line}: {e}"))?;
    }
    for (di, d) in prog.decls.iter().enumerate() {
        if d.kind == VarKind::Output && !assigned.contains(&d.name) {
            return Err(format!(
                "line {}: output variable {} never assigned",
                decl_lines.get(di).copied().unwrap_or(0),
                d.name
            ));
        }
    }
    Ok(())
}

fn expr_rank(e: &Expr, prog: &Program) -> Result<usize, String> {
    match e {
        Expr::Var(n) => Ok(prog
            .decl(n)
            .ok_or_else(|| format!("undeclared {n}"))?
            .shape
            .len()),
        Expr::Add(a, _) | Expr::Sub(a, _) | Expr::Mul(a, _) | Expr::Div(a, _) => {
            expr_rank(a, prog)
        }
        Expr::Prod(a, b) => Ok(expr_rank(a, prog)? + expr_rank(b, prog)?),
        Expr::Contract(a, pairs) => {
            let r = expr_rank(a, prog)?;
            Ok(r - 2 * pairs.len())
        }
        // gather replaces the base's row axis with the (rank-1) index
        // axis, so the rank is unchanged
        Expr::Gather(a, _) => expr_rank(a, prog),
    }
}

fn validate_contractions(e: &Expr, prog: &Program) -> Result<(), String> {
    let mut result = Ok(());
    e.visit(&mut |node| {
        if result.is_err() {
            return;
        }
        if let Expr::Gather(_, ix) = node {
            match prog.decl(ix) {
                None => {
                    result =
                        Err(format!("use of undeclared index variable {ix}"));
                }
                Some(d) if d.shape.len() != 1 => {
                    result = Err(format!(
                        "index variable {ix} must be rank 1, got {:?}",
                        d.shape
                    ));
                }
                _ => {}
            }
            if result.is_err() {
                return;
            }
        }
        if let Expr::Contract(inner, pairs) = node {
            let rank = match expr_rank(inner, prog) {
                Ok(r) => r,
                Err(e) => {
                    result = Err(e);
                    return;
                }
            };
            let mut seen = std::collections::HashSet::new();
            for p in pairs {
                if p.a >= rank || p.b >= rank {
                    result = Err(format!(
                        "contraction pair [{} {}] out of range for rank {rank}",
                        p.a, p.b
                    ));
                    return;
                }
                if p.a == p.b || !seen.insert(p.a) || !seen.insert(p.b) {
                    result = Err(format!(
                        "contraction indices must be distinct: [{} {}]",
                        p.a, p.b
                    ));
                    return;
                }
            }
        }
    });
    result
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_helmholtz() {
        let prog = parse(&crate::dsl::inverse_helmholtz_source(11)).unwrap();
        assert_eq!(prog.stmts.len(), 3);
        let t = &prog.stmts[0];
        assert_eq!(t.target, "t");
        match &t.expr {
            Expr::Contract(inner, pairs) => {
                assert_eq!(pairs.len(), 3);
                assert_eq!(pairs[0], IndexPair { a: 1, b: 6 });
                assert_eq!(inner.vars(), vec!["S", "u"]);
            }
            other => panic!("expected contraction, got {other:?}"),
        }
    }

    #[test]
    fn hash_binds_tighter_than_star() {
        let src = "var input a : [2]\nvar input b : [2]\nvar output c : [2 2]\nc = a # b * a # b";
        // a # (b * a)? no: '*' loosest of the two -> (a#b) * (a#b)
        let prog = parse(src).unwrap();
        match &prog.stmts[0].expr {
            Expr::Mul(l, r) => {
                assert!(matches!(**l, Expr::Prod(_, _)));
                assert!(matches!(**r, Expr::Prod(_, _)));
            }
            other => panic!("expected Mul, got {other:?}"),
        }
    }

    #[test]
    fn contraction_applies_to_whole_sum() {
        let src = "var input a : [2 2]\nvar output c : [2]\nc = (a + a) . [[0 1]]";
        let prog = parse(src).unwrap();
        assert!(matches!(prog.stmts[0].expr, Expr::Contract(_, _)));
    }

    #[test]
    fn rejects_undeclared_use() {
        let err = parse("var output x : [2]\nx = y").unwrap_err();
        assert!(err.contains("undeclared"), "{err}");
    }

    #[test]
    fn rejects_use_before_assignment() {
        let src = "var t : [2]\nvar output x : [2]\nx = t\nt = x";
        let err = parse(src).unwrap_err();
        assert!(err.contains("before assignment"), "{err}");
    }

    #[test]
    fn rejects_assign_to_input() {
        let err = parse("var input x : [2]\nx = x").unwrap_err();
        assert!(err.contains("input"), "{err}");
    }

    #[test]
    fn rejects_double_assignment() {
        let src = "var input a : [2]\nvar output x : [2]\nx = a\nx = a";
        let err = parse(src).unwrap_err();
        assert!(err.contains("twice"), "{err}");
    }

    #[test]
    fn rejects_unassigned_output() {
        let err = parse("var output x : [2]").unwrap_err();
        assert!(err.contains("never assigned"), "{err}");
    }

    #[test]
    fn rejects_out_of_range_contraction() {
        let src = "var input a : [2 2]\nvar output x : [2 2]\nx = a . [[0 5]]";
        let err = parse(src).unwrap_err();
        assert!(err.contains("out of range"), "{err}");
    }

    #[test]
    fn rejects_overlapping_contraction_pairs() {
        let src =
            "var input a : [2 2 2 2]\nvar output x : [2 2]\nx = a . [[0 1][1 2]]";
        let err = parse(src).unwrap_err();
        assert!(err.contains("distinct"), "{err}");
    }

    #[test]
    fn parse_error_reports_line() {
        let err = parse("var input a : [2]\nvar output x : [2]\nx = = a").unwrap_err();
        assert!(err.contains("line 3"), "{err}");
    }

    #[test]
    fn stray_token_in_expression_points_at_the_column() {
        // the second '=' sits on line 3, column 5
        let err = parse("var input a : [2]\nvar output x : [2]\nx = = a").unwrap_err();
        assert!(err.contains("line 3, col 5"), "{err}");
        assert!(err.contains("expected expression"), "{err}");
    }

    #[test]
    fn missing_shape_bracket_points_at_the_offending_token() {
        // ':' is followed by '2' where '[' is required (line 2, col 16)
        let err = parse("var input a : [2]\nvar output x : 2]\nx = a").unwrap_err();
        assert!(err.contains("line 2, col 16"), "{err}");
        assert!(err.contains("expected '['"), "{err}");
    }

    #[test]
    fn malformed_contraction_pair_points_at_the_column() {
        // contraction pair wants an integer, finds ']' on line 3
        let err =
            parse("var input a : [2 2]\nvar output x : [2]\nx = a . [[0]]").unwrap_err();
        assert!(err.contains("line 3, col 12"), "{err}");
        assert!(err.contains("expected integer"), "{err}");
    }

    #[test]
    fn truncated_program_reports_last_token_position() {
        let err = parse("var input a : [2]\nvar output x : [2]\nx =").unwrap_err();
        assert!(err.contains("line 3"), "{err}");
        assert!(err.contains("EOF"), "{err}");
    }

    #[test]
    fn semantic_errors_are_anchored_to_statement_lines() {
        let err = parse(
            "var input a : [2]\nvar output x : [2]\n\nx = a\nx = a\n",
        )
        .unwrap_err();
        assert!(err.contains("line 5"), "{err}");
        assert!(err.contains("assigned twice"), "{err}");
        let err = parse("var input a : [2 2]\nvar output x : [2 2]\nx = a . [[0 5]]")
            .unwrap_err();
        assert!(err.contains("line 3"), "{err}");
        let err = parse("var output x : [2]").unwrap_err();
        assert!(err.contains("line 1"), "{err}");
        assert!(err.contains("never assigned"), "{err}");
    }

    #[test]
    fn parenthesized_expression() {
        let src = "var input a : [2]\nvar input b : [2]\nvar output x : [2]\nx = (a + b) * a";
        let prog = parse(src).unwrap();
        assert!(matches!(prog.stmts[0].expr, Expr::Mul(_, _)));
    }
}
