//! CFDlang lexer.

use std::fmt;

#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Tok {
    Var,
    Input,
    Output,
    Ident(String),
    Int(usize),
    Colon,
    Equals,
    LBracket,
    RBracket,
    LParen,
    RParen,
    Plus,
    Minus,
    Star,
    Slash,
    Hash,
    Dot,
}

impl fmt::Display for Tok {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Tok::Var => write!(f, "var"),
            Tok::Input => write!(f, "input"),
            Tok::Output => write!(f, "output"),
            Tok::Ident(s) => write!(f, "{s}"),
            Tok::Int(n) => write!(f, "{n}"),
            Tok::Colon => write!(f, ":"),
            Tok::Equals => write!(f, "="),
            Tok::LBracket => write!(f, "["),
            Tok::RBracket => write!(f, "]"),
            Tok::LParen => write!(f, "("),
            Tok::RParen => write!(f, ")"),
            Tok::Plus => write!(f, "+"),
            Tok::Minus => write!(f, "-"),
            Tok::Star => write!(f, "*"),
            Tok::Slash => write!(f, "/"),
            Tok::Hash => write!(f, "#"),
            Tok::Dot => write!(f, "."),
        }
    }
}

/// A token with its source position (1-based line and column) for
/// diagnostics. Columns count characters from the start of the line.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Spanned {
    pub tok: Tok,
    pub line: usize,
    pub col: usize,
}

/// Tokenize CFDlang source. `//` starts a line comment.
pub fn lex(src: &str) -> Result<Vec<Spanned>, String> {
    let mut out = Vec::new();
    for (lineno, line) in src.lines().enumerate() {
        let line_num = lineno + 1;
        let code = match line.find("//") {
            Some(i) => &line[..i],
            None => line,
        };
        let mut chars = code.char_indices().peekable();
        // byte offset -> 1-based character column (identifiers and the
        // grammar are ASCII; comments may not be, but they are stripped)
        let col_of = |byte: usize| code[..byte].chars().count() + 1;
        while let Some(&(i, c)) = chars.peek() {
            let tok_col = col_of(i);
            let tok = match c {
                c if c.is_whitespace() => {
                    chars.next();
                    continue;
                }
                ':' => {
                    chars.next();
                    Tok::Colon
                }
                '=' => {
                    chars.next();
                    Tok::Equals
                }
                '[' => {
                    chars.next();
                    Tok::LBracket
                }
                ']' => {
                    chars.next();
                    Tok::RBracket
                }
                '(' => {
                    chars.next();
                    Tok::LParen
                }
                ')' => {
                    chars.next();
                    Tok::RParen
                }
                '+' => {
                    chars.next();
                    Tok::Plus
                }
                '-' => {
                    chars.next();
                    Tok::Minus
                }
                '*' => {
                    chars.next();
                    Tok::Star
                }
                '/' => {
                    chars.next();
                    Tok::Slash
                }
                '#' => {
                    chars.next();
                    Tok::Hash
                }
                '.' => {
                    chars.next();
                    Tok::Dot
                }
                c if c.is_ascii_digit() => {
                    let mut end = i;
                    while let Some(&(j, d)) = chars.peek() {
                        if d.is_ascii_digit() {
                            end = j;
                            chars.next();
                        } else {
                            break;
                        }
                    }
                    let text = &code[i..=end];
                    Tok::Int(text.parse().map_err(|e| {
                        format!(
                            "line {line_num}, col {tok_col}: bad integer {text:?}: {e}"
                        )
                    })?)
                }
                c if c.is_alphabetic() || c == '_' => {
                    let mut end = i;
                    while let Some(&(j, d)) = chars.peek() {
                        if d.is_alphanumeric() || d == '_' {
                            end = j;
                            chars.next();
                        } else {
                            break;
                        }
                    }
                    match &code[i..=end] {
                        "var" => Tok::Var,
                        "input" => Tok::Input,
                        "output" => Tok::Output,
                        ident => Tok::Ident(ident.to_string()),
                    }
                }
                other => {
                    return Err(format!(
                        "line {line_num}, col {tok_col}: unexpected character {other:?}"
                    ))
                }
            };
            out.push(Spanned {
                tok,
                line: line_num,
                col: tok_col,
            });
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toks(src: &str) -> Vec<Tok> {
        lex(src).unwrap().into_iter().map(|s| s.tok).collect()
    }

    #[test]
    fn lexes_decl() {
        assert_eq!(
            toks("var input S : [11 11]"),
            vec![
                Tok::Var,
                Tok::Input,
                Tok::Ident("S".into()),
                Tok::Colon,
                Tok::LBracket,
                Tok::Int(11),
                Tok::Int(11),
                Tok::RBracket
            ]
        );
    }

    #[test]
    fn lexes_contraction_stmt() {
        let t = toks("t = S#S#u . [[1 6]]");
        assert!(t.contains(&Tok::Hash));
        assert!(t.contains(&Tok::Dot));
        assert_eq!(t.iter().filter(|x| **x == Tok::Hash).count(), 2);
    }

    #[test]
    fn comments_are_skipped() {
        assert_eq!(toks("// hello\nx = y // trailing"), toks("x = y"));
    }

    #[test]
    fn keywords_vs_idents() {
        assert_eq!(
            toks("variable inputs"),
            vec![
                Tok::Ident("variable".into()),
                Tok::Ident("inputs".into())
            ]
        );
    }

    #[test]
    fn rejects_unknown_chars_with_position() {
        let err = lex("x = $").unwrap_err();
        assert!(err.contains("line 1, col 5"), "{err}");
    }

    #[test]
    fn tracks_line_and_column_numbers() {
        let spanned = lex("var x : [1]\nx = y").unwrap();
        let first = spanned.first().unwrap();
        assert_eq!((first.line, first.col), (1, 1));
        let last = spanned.last().unwrap();
        assert_eq!((last.line, last.col), (2, 5));
        // the `x` ident on line 1 starts at column 5
        let x = &spanned[1];
        assert_eq!((x.line, x.col), (1, 5));
    }
}
