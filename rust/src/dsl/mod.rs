//! CFDlang DSL front-end (paper §2.1, Fig. 2).
//!
//! CFDlang is a small declarative language for tensor expressions used by
//! spectral-element CFD codes. The full language reference — grammar,
//! contraction semantics, rewriter guarantees, lowering boundary — is
//! docs/CFDLANG.md; arbitrary programs enter the flow through
//! `crate::kernels::KernelSource` (`hbmflow compile --file my.cfd`).
//! The grammar implemented here covers the published language:
//!
//! ```text
//! program   := decl* stmt*
//! decl      := "var" ("input" | "output")? ident ":" "[" int+ "]"
//! stmt      := ident "=" expr
//! expr      := add ( "." contraction )?
//! add       := mul ( ("+" | "-") mul )*
//! mul       := prod ( ("*" | "/") prod )*
//! prod      := primary ( "#" primary )*          // tensor (outer) product
//! primary   := ident | "(" expr ")"
//! contraction := "[" pair+ "]"                    // e.g. [[1 6][3 7][5 8]]
//! pair      := "[" int int "]"
//! ```
//!
//! The running example (Fig. 2, Inverse Helmholtz, p = 11):
//!
//! ```text
//! var input  S : [11 11]
//! var input  D : [11 11 11]
//! var input  u : [11 11 11]
//! var output v : [11 11 11]
//! var t : [11 11 11]
//! var r : [11 11 11]
//! t = S # S # S # u . [[1 6][3 7][5 8]]
//! r = D * t
//! v = S # S # S # r . [[0 6][2 7][4 8]]
//! ```

pub mod ast;
pub mod lexer;
pub mod parser;

pub use ast::{Decl, Expr, Program, Stmt, VarKind};
pub use parser::parse;

/// The paper's Inverse Helmholtz program (Fig. 2) for a given degree.
/// `p` is the polynomial degree; tensors have extent p (the paper's
/// listing uses extent 11 for p = 11, i.e. indices 0..=p-1).
pub fn inverse_helmholtz_source(p: usize) -> String {
    format!(
        "var input S : [{p} {p}]\n\
         var input D : [{p} {p} {p}]\n\
         var input u : [{p} {p} {p}]\n\
         var output v : [{p} {p} {p}]\n\
         var t : [{p} {p} {p}]\n\
         var r : [{p} {p} {p}]\n\
         t = S # S # S # u . [[1 6][3 7][5 8]]\n\
         r = D * t\n\
         v = S # S # S # r . [[0 6][2 7][4 8]]\n"
    )
}

/// Interpolation kernel source (paper §4.3): u' = A # A # A # u contracted.
pub fn interpolation_source(m: usize, n: usize) -> String {
    format!(
        "var input A : [{m} {n}]\n\
         var input u : [{n} {n} {n}]\n\
         var output w : [{m} {m} {m}]\n\
         w = A # A # A # u . [[1 6][3 7][5 8]]\n"
    )
}

/// Gradient kernel source (paper §4.3): three independent mode products.
///
/// CFDlang contraction semantics order the result axes as "remaining
/// global indices", so `gy`/`gz` come out with the derivative axis first:
/// gy : [ny nx nz], gz : [nz nx ny]. The compiler restores mode order via
/// `teil.move_axis` when useful; the DSL types reflect the raw semantics.
pub fn gradient_source(nx: usize, ny: usize, nz: usize) -> String {
    format!(
        "var input Dx : [{nx} {nx}]\n\
         var input Dy : [{ny} {ny}]\n\
         var input Dz : [{nz} {nz}]\n\
         var input u : [{nx} {ny} {nz}]\n\
         var output gx : [{nx} {ny} {nz}]\n\
         var output gy : [{ny} {nx} {nz}]\n\
         var output gz : [{nz} {nx} {ny}]\n\
         gx = Dx # u . [[1 2]]\n\
         gy = Dy # u . [[1 3]]\n\
         gz = Dz # u . [[1 4]]\n"
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builtin_sources_parse() {
        for src in [
            inverse_helmholtz_source(11),
            inverse_helmholtz_source(7),
            interpolation_source(11, 11),
            gradient_source(8, 7, 6),
        ] {
            let prog = parse(&src).expect("builtin source must parse");
            assert!(!prog.stmts.is_empty());
        }
    }

    #[test]
    fn helmholtz_has_expected_decls() {
        let prog = parse(&inverse_helmholtz_source(11)).unwrap();
        assert_eq!(prog.decls.len(), 6);
        let v = prog.decls.iter().find(|d| d.name == "v").unwrap();
        assert_eq!(v.kind, VarKind::Output);
        assert_eq!(v.shape, vec![11, 11, 11]);
        let t = prog.decls.iter().find(|d| d.name == "t").unwrap();
        assert_eq!(t.kind, VarKind::Temp);
    }
}
