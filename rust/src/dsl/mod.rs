//! CFDlang DSL front-end (paper §2.1, Fig. 2).
//!
//! CFDlang is a small declarative language for tensor expressions used by
//! spectral-element CFD codes. The full language reference — grammar,
//! contraction semantics, rewriter guarantees, lowering boundary — is
//! docs/CFDLANG.md; arbitrary programs enter the flow through
//! `crate::kernels::KernelSource` (`hbmflow compile --file my.cfd`).
//! The grammar implemented here covers the published language:
//!
//! ```text
//! program   := decl* stmt*
//! decl      := "var" ("input" | "output")? ident ":" "[" int+ "]"
//! stmt      := ident ("[" ident "]")? ("=" | "+=") expr
//! expr      := add ( "." contraction )?
//! add       := mul ( ("+" | "-") mul )*
//! mul       := prod ( ("*" | "/") prod )*
//! prod      := primary ( "#" primary )*          // tensor (outer) product
//! primary   := ( ident | "(" expr ")" ) ("[" ident "]")*
//! contraction := "[" pair+ "]"                    // e.g. [[1 6][3 7][5 8]]
//! pair      := "[" int int "]"
//! ```
//!
//! The postfix index `base[idx]` is the *gather* form (indirect row
//! read through a rank-1 index variable), and an indexed assignment
//! target `t[idx] = e` / `t[idx] += e` is the *scatter* form — the
//! unstructured-mesh access modes of Karp et al. (arXiv 2108.12188);
//! see docs/CFDLANG.md "Indexing syntax".
//!
//! The running example (Fig. 2, Inverse Helmholtz, p = 11):
//!
//! ```text
//! var input  S : [11 11]
//! var input  D : [11 11 11]
//! var input  u : [11 11 11]
//! var output v : [11 11 11]
//! var t : [11 11 11]
//! var r : [11 11 11]
//! t = S # S # S # u . [[1 6][3 7][5 8]]
//! r = D * t
//! v = S # S # S # r . [[0 6][2 7][4 8]]
//! ```

pub mod ast;
pub mod lexer;
pub mod parser;

pub use ast::{Decl, Expr, Program, Stmt, VarKind};
pub use parser::parse;

/// The paper's Inverse Helmholtz program (Fig. 2) for a given degree.
/// `p` is the polynomial degree; tensors have extent p (the paper's
/// listing uses extent 11 for p = 11, i.e. indices 0..=p-1).
pub fn inverse_helmholtz_source(p: usize) -> String {
    format!(
        "var input S : [{p} {p}]\n\
         var input D : [{p} {p} {p}]\n\
         var input u : [{p} {p} {p}]\n\
         var output v : [{p} {p} {p}]\n\
         var t : [{p} {p} {p}]\n\
         var r : [{p} {p} {p}]\n\
         t = S # S # S # u . [[1 6][3 7][5 8]]\n\
         r = D * t\n\
         v = S # S # S # r . [[0 6][2 7][4 8]]\n"
    )
}

/// Interpolation kernel source (paper §4.3): u' = A # A # A # u contracted.
pub fn interpolation_source(m: usize, n: usize) -> String {
    format!(
        "var input A : [{m} {n}]\n\
         var input u : [{n} {n} {n}]\n\
         var output w : [{m} {m} {m}]\n\
         w = A # A # A # u . [[1 6][3 7][5 8]]\n"
    )
}

/// Gradient kernel source (paper §4.3): three independent mode products.
///
/// CFDlang contraction semantics order the result axes as "remaining
/// global indices", so `gy`/`gz` come out with the derivative axis first:
/// gy : [ny nx nz], gz : [nz nx ny]. The compiler restores mode order via
/// `teil.move_axis` when useful; the DSL types reflect the raw semantics.
pub fn gradient_source(nx: usize, ny: usize, nz: usize) -> String {
    format!(
        "var input Dx : [{nx} {nx}]\n\
         var input Dy : [{ny} {ny}]\n\
         var input Dz : [{nz} {nz}]\n\
         var input u : [{nx} {ny} {nz}]\n\
         var output gx : [{nx} {ny} {nz}]\n\
         var output gy : [{ny} {nx} {nz}]\n\
         var output gz : [{nz} {nx} {ny}]\n\
         gx = Dx # u . [[1 2]]\n\
         gy = Dy # u . [[1 3]]\n\
         gz = Dz # u . [[1 4]]\n"
    )
}

/// Mesh gather-interpolation kernel (Karp et al., arXiv 2108.12188,
/// §"gather"): read `n` element rows of a nodal field `u : [m k]`
/// through the element-to-node map `gi`, then apply a dense `k x k`
/// operator along the per-element axis. The contraction's axis
/// semantics put the operator axis first (`w : [k n]`), like the
/// gradient builtin's derivative-axis-first outputs.
pub fn mesh_gather_source(m: usize, n: usize, k: usize) -> String {
    format!(
        "var input u : [{m} {k}]\n\
         var input gi : [{n}]\n\
         var input D : [{k} {k}]\n\
         var output w : [{k} {n}]\n\
         var t : [{n} {k}]\n\
         t = u[gi]\n\
         w = D # t . [[1 3]]\n"
    )
}

/// Scatter-add assembly kernel (Karp et al.'s gather-scatter pair):
/// gather `n` element rows of `u : [m k]`, scale by per-element
/// weights, and accumulate back into the `m`-row result through the
/// scatter map `si` — duplicate indices sum (finite-element assembly).
pub fn scatter_assembly_source(m: usize, n: usize, k: usize) -> String {
    format!(
        "var input u : [{m} {k}]\n\
         var input gi : [{n}]\n\
         var input si : [{n}]\n\
         var input w : [{n} {k}]\n\
         var output r : [{m} {k}]\n\
         var t : [{n} {k}]\n\
         t = u[gi] * w\n\
         r[si] += t\n"
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builtin_sources_parse() {
        for src in [
            inverse_helmholtz_source(11),
            inverse_helmholtz_source(7),
            interpolation_source(11, 11),
            gradient_source(8, 7, 6),
            mesh_gather_source(256, 1024, 8),
            scatter_assembly_source(256, 1024, 8),
        ] {
            let prog = parse(&src).expect("builtin source must parse");
            assert!(!prog.stmts.is_empty());
        }
    }

    #[test]
    fn indexed_sources_roundtrip_through_display() {
        for src in [
            mesh_gather_source(8, 16, 4),
            scatter_assembly_source(8, 16, 4),
        ] {
            let p1 = parse(&src).unwrap();
            let p2 = parse(&p1.to_string()).unwrap();
            assert_eq!(p1, p2);
        }
    }

    #[test]
    fn helmholtz_has_expected_decls() {
        let prog = parse(&inverse_helmholtz_source(11)).unwrap();
        assert_eq!(prog.decls.len(), 6);
        let v = prog.decls.iter().find(|d| d.name == "v").unwrap();
        assert_eq!(v.kind, VarKind::Output);
        assert_eq!(v.shape, vec![11, 11, 11]);
        let t = prog.decls.iter().find(|d| d.name == "t").unwrap();
        assert_eq!(t.kind, VarKind::Temp);
    }
}
