//! Olympus: system-level hardware generation (paper §3.5–§3.6).
//!
//! Olympus wraps the compiler-produced kernel into compute units (CUs),
//! decides lane parallelism from the bus width, applies the HBM
//! optimizations (double buffering, bus widening, dataflow decomposition,
//! memory sharing, fixed-point conversion), binds CU ports to HBM
//! pseudo-channels through an explicit allocation policy
//! ([`ChannelPolicy`]: local-first / striped / user-pinned, resolved
//! against the segmented AXI switch model in `hbm`), sizes batches, and
//! emits the system configuration + host steps (see `config`). The
//! result — a `SystemSpec` carrying the flat channel map, the routed
//! `hbm::ChannelMap`, and the unified `mnemosyne::MemoryPlan` (banking
//! composed with lifetime sharing) — is consumed by the HLS estimator,
//! the platform simulator, and the runtime coordinator.

pub mod compose;
pub mod config;

pub use compose::{compose, ComposedSystem, StageLink};

use crate::datatype::DataType;
use crate::hbm::{self, PortDemand};
pub use crate::hbm::ChannelPolicy;
use crate::ir::affine::Kernel;
use crate::ir::schedule::{self, Schedule};
use crate::mnemosyne::{self, MemoryPlan};
pub use crate::mnemosyne::CacheScheme;
use crate::platform::Platform;

/// AXI bus configuration of a CU's data ports (paper §4.2 "Bus Opt").
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BusMode {
    /// 64-bit AXI: one word per cycle, one kernel per CU (Baseline).
    Narrow64,
    /// 256-bit AXI, one kernel: packed words serialized into the local
    /// buffers (the paper's *degrading* variant).
    Wide256Serial,
    /// 256-bit AXI split into `256/bits(dtype)` lanes, one kernel each.
    Wide256Parallel,
}

impl BusMode {
    /// Short name used in DSE reports and CSV/JSON output.
    pub fn name(self) -> &'static str {
        match self {
            BusMode::Narrow64 => "64b",
            BusMode::Wide256Serial => "256b-serial",
            BusMode::Wide256Parallel => "256b-parallel",
        }
    }

    /// Inverse of [`BusMode::name`] (flow artifact round-trips).
    pub fn parse(s: &str) -> Option<BusMode> {
        match s {
            "64b" => Some(BusMode::Narrow64),
            "256b-serial" => Some(BusMode::Wide256Serial),
            "256b-parallel" => Some(BusMode::Wide256Parallel),
            _ => None,
        }
    }
}

/// Global-memory technology backing the CU channels (paper §2.3:
/// "DDR4 memory is excellent for accessing large data sets with modest
/// latency, but the transfer bandwidth is limited to 36 GB/s and no
/// more than two parallel accesses").
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MemoryKind {
    Hbm,
    Ddr4,
}

impl MemoryKind {
    /// Short name used in DSE reports and CSV/JSON output.
    pub fn name(self) -> &'static str {
        match self {
            MemoryKind::Hbm => "hbm",
            MemoryKind::Ddr4 => "ddr4",
        }
    }

    /// Inverse of [`MemoryKind::name`] (flow artifact round-trips).
    pub fn parse(s: &str) -> Option<MemoryKind> {
        match s {
            "hbm" => Some(MemoryKind::Hbm),
            "ddr4" => Some(MemoryKind::Ddr4),
            _ => None,
        }
    }
}

/// Designer-selected optimizations (paper Fig. 5 "Optimize" step).
#[derive(Debug, Clone)]
pub struct OlympusOpts {
    pub double_buffering: bool,
    pub bus: BusMode,
    /// Global memory the CUs attach to (HBM pseudo-channels vs the two
    /// DDR4 banks; the DDR path exists for the paper's §2.3 comparison).
    pub memory: MemoryKind,
    /// Number of compute dataflow groups (None = flat kernel, no
    /// read/compute/write overlap).
    pub dataflow: Option<usize>,
    /// Mnemosyne bank sharing (effective for 1-compute dataflow).
    pub mem_sharing: bool,
    /// Cap on the memory plan's per-array partition factor (None =
    /// match the unrolled datapath's access degree, conflict-free).
    /// Capping below a contraction's reduction trip saves BRAM/URAM
    /// banks but makes the simulator charge bank-conflict stalls —
    /// the DSE memory axis.
    pub partition_cap: Option<usize>,
    pub dtype: DataType,
    pub num_cus: usize,
    /// Stream FIFO depth in words (None = full array size, the paper's
    /// naive sizing; Some(d) = reduced depth, saves BRAM, may stall).
    pub fifo_depth: Option<usize>,
    /// Route some fixed-point multipliers to LUTs (paper §4.2 pragma).
    pub lut_mult_shift: bool,
    /// Synthesis frequency target in MHz.
    pub target_freq_mhz: f64,
    /// How CU ports are bound to pseudo-channels on the segmented AXI
    /// switch (paper §3.6.1; `hbm::alloc`).
    pub channel_policy: ChannelPolicy,
    /// Scratchpad policy for indirectly accessed (gather/scatter)
    /// arrays — the irregular-access DSE axis (`mnemosyne::CacheScheme`;
    /// inert on kernels without indexed nests).
    pub cache_scheme: CacheScheme,
}

impl OlympusOpts {
    /// The paper's Fig. 15 optimization ladder, cumulative presets.
    pub fn baseline() -> Self {
        OlympusOpts {
            double_buffering: false,
            bus: BusMode::Narrow64,
            memory: MemoryKind::Hbm,
            dataflow: None,
            mem_sharing: false,
            partition_cap: None,
            dtype: DataType::F64,
            num_cus: 1,
            fifo_depth: None,
            lut_mult_shift: false,
            target_freq_mhz: 450.0,
            channel_policy: ChannelPolicy::LocalFirst,
            cache_scheme: CacheScheme::Bypass,
        }
    }

    pub fn double_buffering() -> Self {
        OlympusOpts {
            double_buffering: true,
            ..Self::baseline()
        }
    }

    pub fn bus_serial() -> Self {
        OlympusOpts {
            bus: BusMode::Wide256Serial,
            ..Self::double_buffering()
        }
    }

    pub fn bus_parallel() -> Self {
        OlympusOpts {
            bus: BusMode::Wide256Parallel,
            ..Self::double_buffering()
        }
    }

    pub fn dataflow(compute_groups: usize) -> Self {
        OlympusOpts {
            dataflow: Some(compute_groups),
            ..Self::bus_parallel()
        }
    }

    pub fn mem_sharing() -> Self {
        OlympusOpts {
            mem_sharing: true,
            ..Self::dataflow(1)
        }
    }

    pub fn fixed_point(dtype: DataType) -> Self {
        OlympusOpts {
            dtype,
            ..Self::dataflow(7)
        }
    }

    pub fn with_cus(mut self, n: usize) -> Self {
        self.num_cus = n;
        // Paper §4.2 multi-CU methodology: target 225 MHz, shrink the
        // stream FIFOs from naive full-size, and shift some fixed-point
        // multipliers onto LUTs to relieve DSP pressure.
        if n > 1 {
            self.target_freq_mhz = 225.0;
            self.fifo_depth = Some(64);
            self.lut_mult_shift = true;
        }
        self
    }

    pub fn with_fifo_depth(mut self, d: usize) -> Self {
        self.fifo_depth = Some(d);
        self
    }

    pub fn on_ddr4(mut self) -> Self {
        self.memory = MemoryKind::Ddr4;
        self
    }

    pub fn with_policy(mut self, p: ChannelPolicy) -> Self {
        self.channel_policy = p;
        self
    }

    pub fn with_partition_cap(mut self, cap: usize) -> Self {
        self.partition_cap = Some(cap);
        self
    }

    pub fn with_cache_scheme(mut self, s: CacheScheme) -> Self {
        self.cache_scheme = s;
        self
    }

    /// Short label used in reports (matches paper row names).
    pub fn label(&self) -> String {
        let mut base = self.base_label();
        if let Some(c) = self.partition_cap {
            base.push_str(&format!(" cap{c}"));
        }
        match self.cache_scheme {
            CacheScheme::Bypass => {}
            CacheScheme::Cached(w) => base.push_str(&format!(" cache{w}")),
            CacheScheme::FullBuffer => base.push_str(" cacheFull"),
        }
        base
    }

    fn base_label(&self) -> String {
        if self.dtype.is_fixed() {
            return format!(
                "{} (p-dataflow {})",
                self.dtype.display(),
                self.dataflow.unwrap_or(0)
            );
        }
        match (self.double_buffering, self.bus, self.dataflow, self.mem_sharing) {
            (false, BusMode::Narrow64, None, _) => "Baseline".into(),
            (true, BusMode::Narrow64, None, _) => "Double Buffering".into(),
            (true, BusMode::Wide256Serial, None, _) => "Bus Opt (Serial)".into(),
            (true, BusMode::Wide256Parallel, None, _) => "Bus Opt (Parallel)".into(),
            (true, BusMode::Wide256Parallel, Some(n), false) => {
                format!("Dataflow ({n} compute)")
            }
            (true, BusMode::Wide256Parallel, Some(n), true) => {
                format!("Mem Sharing ({n} compute)")
            }
            _ => "Custom".into(),
        }
    }
}

/// HBM pseudo-channel assignment for one CU (paper §3.6.1).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CuChannels {
    /// Channels the CU reads inputs from (ping, then pong when double
    /// buffering).
    pub read: Vec<u32>,
    /// Channels the CU writes outputs to (may alias `read` when the CU
    /// shares one channel for both directions).
    pub write: Vec<u32>,
}

impl CuChannels {
    pub fn all(&self) -> Vec<u32> {
        let mut v = self.read.clone();
        for &c in &self.write {
            if !v.contains(&c) {
                v.push(c);
            }
        }
        v
    }
}

/// The generated system: everything downstream consumers need.
#[derive(Debug, Clone)]
pub struct SystemSpec {
    pub name: String,
    pub kernel: Kernel,
    /// Compute-group schedule (single group when flat).
    pub schedule: Schedule,
    /// Whether groups execute as an overlapped dataflow pipeline.
    pub dataflow: bool,
    /// The unified on-chip memory plan (banking + lifetime sharing) —
    /// the single source the HLS estimator, the simulator's conflict
    /// model, and the DSE reports derive memory answers from.
    pub memory: MemoryPlan,
    pub dtype: DataType,
    /// Kernel lanes per CU.
    pub lanes: usize,
    /// AXI data bus width in bits.
    pub bus_bits: u32,
    /// Wide bus feeding a single kernel through serialization.
    pub serial_packing: bool,
    pub num_cus: usize,
    pub channels: Vec<CuChannels>,
    /// Resolved port→channel routing on the segmented AXI switch
    /// (masters, hops, timing); `channels` is the flat projection of
    /// this map kept for config emission and capacity checks.
    pub hbm_map: hbm::ChannelMap,
    /// Elements per batch per CU (paper's E).
    pub batch_elements: usize,
    pub double_buffering: bool,
    pub opts: OlympusOpts,
}

impl SystemSpec {
    /// Bytes streamed from HBM per element (inputs).
    pub fn input_bytes_per_element(&self) -> u64 {
        self.kernel.input_words() as u64 * self.dtype.bytes() as u64
    }

    /// Bytes streamed to HBM per element (outputs).
    pub fn output_bytes_per_element(&self) -> u64 {
        self.kernel.output_words() as u64 * self.dtype.bytes() as u64
    }

    pub fn flops_per_element(&self) -> u64 {
        self.kernel.flops_per_element()
    }

    /// Total pseudo-channels in use.
    pub fn total_pcs(&self) -> usize {
        self.channels.iter().map(|c| c.all().len()).sum()
    }

    /// Structural invariants (property-tested).
    pub fn validate(&self, platform: &Platform) -> Result<(), String> {
        self.schedule.validate(&self.kernel)?;
        self.memory.validate(&self.kernel)?;
        if self.channels.len() != self.num_cus {
            return Err("one channel map per CU required".into());
        }
        let mut seen = std::collections::HashSet::new();
        for (i, c) in self.channels.iter().enumerate() {
            if c.read.is_empty() || c.write.is_empty() {
                return Err(format!("CU {i} lacks channels"));
            }
            // A double-buffered CU needs distinct ping and pong channels
            // in each direction: the coordinator's PingPong state machine
            // wraps `phase % len`, so a single channel would serve both
            // phases and silently serialize the double buffer. Reject the
            // shape here instead of letting it limp through the runtime.
            if self.double_buffering && (c.read.len() < 2 || c.write.len() < 2) {
                return Err(format!(
                    "CU {i} double-buffers but has {} read / {} write \
                     channels; ping and pong would collide on one channel \
                     (need 2 of each)",
                    c.read.len(),
                    c.write.len()
                ));
            }
            for pc in c.all() {
                if pc >= platform.hbm.pseudo_channels {
                    return Err(format!("CU {i} uses nonexistent PC {pc}"));
                }
                if !seen.insert(pc) {
                    return Err(format!("PC {pc} assigned to multiple CUs"));
                }
            }
        }
        if self.hbm_map.cus.len() != self.num_cus {
            return Err("one switch route map per CU required".into());
        }
        for (i, (c, r)) in self.channels.iter().zip(&self.hbm_map.cus).enumerate() {
            let rd: Vec<u32> = r.read.iter().map(|x| x.channel).collect();
            let wr: Vec<u32> = r.write.iter().map(|x| x.channel).collect();
            if rd != c.read || wr != c.write {
                return Err(format!("CU {i}: channel map and switch routes disagree"));
            }
        }
        if self.batch_elements == 0 {
            return Err("batch must hold at least one element".into());
        }
        // batch data must fit the per-channel capacity
        let cap = platform.hbm.pc_capacity_bytes;
        let in_b = self.input_bytes_per_element() * self.batch_elements as u64;
        let out_b = self.output_bytes_per_element() * self.batch_elements as u64;
        let shares_channel = self.channels[0].read == self.channels[0].write;
        if shares_channel {
            if in_b + out_b > cap {
                return Err("batch exceeds PC capacity (shared channel)".into());
            }
        } else if in_b > cap || out_b > cap {
            return Err("batch exceeds PC capacity".into());
        }
        Ok(())
    }
}

/// Whether the buffering mode separates input and output channels
/// (double buffering below 8 CUs on HBM, paper §3.6.1).
pub(crate) fn separate_io(opts: &OlympusOpts) -> bool {
    opts.double_buffering && opts.num_cus < 8 && opts.memory == MemoryKind::Hbm
}

/// Per-CU channel demand implied by the buffering mode: one shared
/// channel flat, shared ping/pong pairs when buffers double, fully
/// separated directions below 8 CUs. Composition concatenates one such
/// demand group per member kernel into a single allocation.
pub(crate) fn cu_port_demand(opts: &OlympusOpts) -> PortDemand {
    match (opts.double_buffering, separate_io(opts)) {
        (false, _) => PortDemand {
            reads: 1,
            writes: 1,
            shared: true,
        },
        (true, false) => PortDemand {
            reads: 2,
            writes: 2,
            shared: true,
        },
        (true, true) => PortDemand {
            reads: 2,
            writes: 2,
            shared: false,
        },
    }
}

/// Generate the system architecture for a kernel + options on a platform.
pub fn generate(
    kernel: &Kernel,
    opts: &OlympusOpts,
    platform: &Platform,
) -> Result<SystemSpec, String> {
    // ---- lanes and bus ----
    let (bus_bits, lanes, serial_packing) = match opts.bus {
        BusMode::Narrow64 => (64u32, 1usize, false),
        BusMode::Wide256Serial => (platform.hbm.pc_bus_bits, 1, true),
        BusMode::Wide256Parallel => {
            let l = (platform.hbm.pc_bus_bits / opts.dtype.bits()) as usize;
            (platform.hbm.pc_bus_bits, l, false)
        }
    };

    // ---- schedule ----
    let (schedule, dataflow) = match opts.dataflow {
        Some(n) => (schedule::fixed(kernel, n)?, true),
        None => (schedule::fixed(kernel, 1)?, false),
    };

    // ---- memory plan (paper §3.5) ----
    // One plan per design: access-pattern-driven banking composed with
    // lifetime sharing. Sharing operates only inside each subkernel
    // (paper §3.6.4): with more than one compute group every module
    // buffers privately and sharing does not apply.
    let memory = mnemosyne::plan(
        kernel,
        &schedule,
        dataflow,
        opts.dtype.bytes() as usize,
        &mnemosyne::PlanOpts {
            sharing: opts.mem_sharing,
            partition_cap: opts.partition_cap,
            fifo_depth: opts.fifo_depth,
            cache: opts.cache_scheme,
        },
    );

    // ---- channel allocation (paper §3.6.1) ----
    // DDR4 offers only two banks ("no more than two parallel accesses",
    // §2.3): at most two CUs without double buffering, one with.
    let max_cus = match (opts.memory, opts.double_buffering) {
        (MemoryKind::Ddr4, false) => 2,
        (MemoryKind::Ddr4, true) => 1,
        (MemoryKind::Hbm, false) => 32,
        (MemoryKind::Hbm, true) => 16,
    };
    if opts.num_cus == 0 || opts.num_cus > max_cus {
        return Err(format!(
            "num_cus {} out of range (max {max_cus} with{} double buffering)",
            opts.num_cus,
            if opts.double_buffering { "" } else { "out" }
        ));
    }
    let separate_io = separate_io(opts);
    let demand = cu_port_demand(opts);
    let interconnect = match opts.memory {
        MemoryKind::Hbm => hbm::Interconnect::hbm(&platform.hbm),
        MemoryKind::Ddr4 => hbm::Interconnect::ddr4(&platform.hbm),
    };
    // over-demand is caught authoritatively inside hbm::allocate
    let demands = vec![demand; opts.num_cus];
    let routes = hbm::allocate(&opts.channel_policy, &demands, &interconnect)
        .map_err(|e| format!("channel allocation ({}): {e}", opts.channel_policy.name()))?;
    let channels: Vec<CuChannels> = routes
        .iter()
        .map(|cu| CuChannels {
            read: cu.read.iter().map(|r| r.channel).collect(),
            write: cu.write.iter().map(|r| r.channel).collect(),
        })
        .collect();
    let hbm_map = hbm::ChannelMap {
        interconnect,
        cus: routes,
    };

    // ---- batch sizing (paper §3.6: elements per HBM channel) ----
    let in_bytes = kernel.input_words() as u64 * opts.dtype.bytes() as u64;
    let out_bytes = kernel.output_words() as u64 * opts.dtype.bytes() as u64;
    let cap = match opts.memory {
        MemoryKind::Hbm => platform.hbm.pc_capacity_bytes,
        // a DDR4 bank is 16 GB, but keep batches HBM-sized so host
        // transfer chunks stay comparable across the ablation
        MemoryKind::Ddr4 => platform.hbm.pc_capacity_bytes,
    };
    let batch_elements = if separate_io || opts.double_buffering && !separate_io {
        // inputs and outputs in (possibly shared ping/pong) channels:
        // when sharing a channel both directions split the capacity
        if separate_io {
            ((cap / in_bytes).min(cap / out_bytes)) as usize
        } else {
            (cap / (in_bytes + out_bytes)) as usize
        }
    } else {
        (cap / (in_bytes + out_bytes)) as usize
    };
    // keep batches lane-aligned so every lane gets the same element count
    let batch_elements = (batch_elements / lanes.max(1)) * lanes.max(1);
    if batch_elements == 0 {
        return Err("element too large for one HBM pseudo-channel".into());
    }

    let spec = SystemSpec {
        name: format!("{}_{}", kernel.name, opts.label().replace(' ', "_")),
        kernel: kernel.clone(),
        schedule,
        dataflow,
        memory,
        dtype: opts.dtype,
        lanes,
        bus_bits,
        serial_packing,
        num_cus: opts.num_cus,
        channels,
        hbm_map,
        batch_elements,
        double_buffering: opts.double_buffering,
        opts: opts.clone(),
    };
    spec.validate(platform)?;
    Ok(spec)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dsl;
    use crate::ir::{lower, rewrite, teil};
    use crate::util::prop;

    fn helmholtz(p: usize) -> Kernel {
        let prog = dsl::parse(&dsl::inverse_helmholtz_source(p)).unwrap();
        let m = rewrite::optimize(teil::from_ast(&prog).unwrap());
        lower::lower_kernel(&m, "helmholtz").unwrap()
    }

    fn u280() -> Platform {
        Platform::alveo_u280()
    }

    #[test]
    fn baseline_is_one_pc_one_lane() {
        let s = generate(&helmholtz(11), &OlympusOpts::baseline(), &u280()).unwrap();
        assert_eq!(s.lanes, 1);
        assert_eq!(s.bus_bits, 64);
        assert_eq!(s.total_pcs(), 1);
        assert!(!s.dataflow);
        assert_eq!(s.channels[0].read, s.channels[0].write);
    }

    #[test]
    fn double_buffering_uses_four_pcs_for_single_cu() {
        // num_cus < 8 -> separate input and output channels (paper §3.6.1)
        let s = generate(
            &helmholtz(11),
            &OlympusOpts::double_buffering(),
            &u280(),
        )
        .unwrap();
        assert_eq!(s.total_pcs(), 4);
        assert_ne!(s.channels[0].read, s.channels[0].write);
    }

    #[test]
    fn eight_cus_share_io_on_pingpong_channels() {
        let s = generate(
            &helmholtz(11),
            &OlympusOpts::double_buffering().with_cus(8),
            &u280(),
        )
        .unwrap();
        assert_eq!(s.total_pcs(), 16);
        assert_eq!(s.channels[0].read.len(), 2);
        assert_eq!(s.channels[0].read, s.channels[0].write);
    }

    #[test]
    fn lane_counts_follow_dtype_width() {
        let p64 = generate(&helmholtz(11), &OlympusOpts::bus_parallel(), &u280()).unwrap();
        assert_eq!(p64.lanes, 4, "256/64");
        let fx32 = generate(
            &helmholtz(11),
            &OlympusOpts::fixed_point(crate::datatype::DataType::Fx32),
            &u280(),
        )
        .unwrap();
        assert_eq!(fx32.lanes, 8, "256/32 (paper: eight kernels per CU)");
    }

    #[test]
    fn serial_mode_is_one_kernel_wide_bus() {
        let s = generate(&helmholtz(11), &OlympusOpts::bus_serial(), &u280()).unwrap();
        assert_eq!(s.lanes, 1);
        assert_eq!(s.bus_bits, 256);
        assert!(s.serial_packing);
    }

    #[test]
    fn dataflow_7_has_seven_compute_groups() {
        let s = generate(&helmholtz(11), &OlympusOpts::dataflow(7), &u280()).unwrap();
        assert!(s.dataflow);
        assert_eq!(s.schedule.num_groups(), 7);
    }

    #[test]
    fn mem_sharing_populates_plan() {
        let s = generate(&helmholtz(11), &OlympusOpts::mem_sharing(), &u280()).unwrap();
        assert!(s.memory.sharing.is_some());
        assert!(s.memory.shared_words() < s.memory.unshared_words(&s.kernel));
    }

    #[test]
    fn every_spec_carries_a_validated_memory_plan() {
        for opts in [
            OlympusOpts::baseline(),
            OlympusOpts::dataflow(1),
            OlympusOpts::dataflow(7),
            OlympusOpts::mem_sharing(),
        ] {
            let s = generate(&helmholtz(11), &opts, &u280()).unwrap();
            s.memory.validate(&s.kernel).unwrap();
            assert!(!s.memory.arrays.is_empty());
        }
    }

    #[test]
    fn partition_cap_shrinks_banks_and_labels() {
        let o = OlympusOpts::dataflow(7).with_partition_cap(4);
        assert!(o.label().ends_with("cap4"), "{}", o.label());
        let capped = generate(&helmholtz(11), &o, &u280()).unwrap();
        let full = generate(&helmholtz(11), &OlympusOpts::dataflow(7), &u280()).unwrap();
        assert!(
            capped.memory.total_banks() < full.memory.total_banks(),
            "cap {} vs full {}",
            capped.memory.total_banks(),
            full.memory.total_banks()
        );
        capped.memory.validate(&capped.kernel).unwrap();
    }

    #[test]
    fn double_buffered_single_channel_cu_is_rejected() {
        // Pre-fix, this shape validated cleanly and the runtime's
        // `phase % len` wrap returned the same channel for ping and pong,
        // silently serializing the double buffer.
        let mut s = generate(
            &helmholtz(11),
            &OlympusOpts::double_buffering(),
            &u280(),
        )
        .unwrap();
        s.validate(&u280()).unwrap();
        s.channels[0].read.truncate(1);
        s.channels[0].write.truncate(1);
        s.hbm_map.cus[0].read.truncate(1);
        s.hbm_map.cus[0].write.truncate(1);
        let err = s.validate(&u280()).unwrap_err();
        assert!(err.contains("ping and pong"), "{err}");
        // single-buffered CUs legitimately share one channel per phase
        let flat = generate(&helmholtz(11), &OlympusOpts::baseline(), &u280()).unwrap();
        assert_eq!(flat.channels[0].read.len(), 1);
        flat.validate(&u280()).unwrap();
    }

    #[test]
    fn max_cus_enforced() {
        assert!(generate(
            &helmholtz(11),
            &OlympusOpts::double_buffering().with_cus(17),
            &u280()
        )
        .is_err());
        assert!(generate(&helmholtz(11), &OlympusOpts::baseline().with_cus(32), &u280()).is_ok());
    }

    #[test]
    fn batch_fills_channel_capacity() {
        let s = generate(&helmholtz(11), &OlympusOpts::baseline(), &u280()).unwrap();
        // per element: in (121 + 2*1331)*8 B, out 1331*8 B, shared channel
        let per = (121 + 2 * 1331 + 1331) * 8u64;
        let expect = (256u64 * 1024 * 1024) / per;
        assert!((s.batch_elements as u64) <= expect);
        assert!((s.batch_elements as u64) >= expect - 1);
    }

    #[test]
    fn batch_is_lane_aligned() {
        let s = generate(
            &helmholtz(11),
            &OlympusOpts::fixed_point(crate::datatype::DataType::Fx32),
            &u280(),
        )
        .unwrap();
        assert_eq!(s.batch_elements % 8, 0);
    }

    #[test]
    fn multi_cu_targets_225mhz() {
        let o = OlympusOpts::dataflow(7).with_cus(2);
        assert_eq!(o.target_freq_mhz, 225.0);
        let s = generate(&helmholtz(11), &o, &u280()).unwrap();
        assert_eq!(s.num_cus, 2);
        assert_eq!(s.total_pcs(), 8);
    }

    #[test]
    fn labels_match_paper_rows() {
        assert_eq!(OlympusOpts::baseline().label(), "Baseline");
        assert_eq!(OlympusOpts::bus_serial().label(), "Bus Opt (Serial)");
        assert_eq!(OlympusOpts::dataflow(3).label(), "Dataflow (3 compute)");
        assert!(OlympusOpts::fixed_point(crate::datatype::DataType::Fx32)
            .label()
            .contains("Fixed Point 32"));
    }

    #[test]
    fn local_first_reproduces_sequential_numbering() {
        let s = generate(
            &helmholtz(11),
            &OlympusOpts::dataflow(7).with_cus(2),
            &u280(),
        )
        .unwrap();
        assert_eq!(s.channels[0].read, vec![0, 1]);
        assert_eq!(s.channels[0].write, vec![2, 3]);
        assert_eq!(s.channels[1].read, vec![4, 5]);
        assert_eq!(s.hbm_map.switch_crossings(), 0, "all routes local");
    }

    #[test]
    fn striped_policy_spreads_and_crosses_segments() {
        let o = OlympusOpts::dataflow(7).with_policy(ChannelPolicy::Striped);
        let s = generate(&helmholtz(11), &o, &u280()).unwrap();
        assert_eq!(s.channels[0].read, vec![0, 4], "one channel per segment");
        assert_eq!(s.channels[0].write, vec![8, 12]);
        assert!(s.hbm_map.switch_crossings() > 0);
        s.validate(&u280()).unwrap();
    }

    #[test]
    fn pinned_policy_honors_and_rejects() {
        let pin = ChannelPolicy::Pinned(vec![vec![31]]);
        let s = generate(
            &helmholtz(11),
            &OlympusOpts::baseline().with_policy(pin),
            &u280(),
        )
        .unwrap();
        assert_eq!(s.channels[0].read, vec![31]);
        assert_eq!(s.hbm_map.cus[0].read[0].hops, 7);
        let bad = ChannelPolicy::Pinned(vec![vec![99]]);
        assert!(generate(
            &helmholtz(11),
            &OlympusOpts::baseline().with_policy(bad),
            &u280()
        )
        .is_err());
    }

    #[test]
    fn property_channel_maps_never_overlap() {
        prop::check("olympus channel allocation", 32, |rng| {
            let db = rng.bool();
            let max = if db { 16 } else { 32 };
            let n = rng.range_usize(1, max);
            let mut o = if db {
                OlympusOpts::double_buffering()
            } else {
                OlympusOpts::baseline()
            };
            o = o.with_cus(n);
            let s = generate(&helmholtz(7), &o, &u280()).map_err(|e| e)?;
            s.validate(&u280()).map_err(|e| e)?;
            // every batch is nonzero and every PC < 32, checked by
            // validate; also: total PCs <= 32
            prop::assert_prop(s.total_pcs() <= 32, format!("{}", s.total_pcs()))
        });
    }
}
