//! Multi-kernel composition: several kernels' CUs placed on one device
//! (DESIGN.md §2.10).
//!
//! The paper's CFD use case is a solver *pipeline* — interpolation →
//! gradient → Helmholtz per timestep — but a single [`SystemSpec`]
//! hosts exactly one kernel. This module answers the system-level
//! layout question the way CHARM does for diverse accelerators on one
//! U280: every member keeps its own compute architecture (schedule,
//! memory plan, lanes, CUs), while the device-level shared resources
//! are partitioned once:
//!
//!  * the 32 HBM pseudo-channels are split by a **single**
//!    [`hbm::allocate`] call over the concatenated per-kernel
//!    [`PortDemand`] groups — master slots advance sequentially across
//!    all members, so one policy yields a disjoint partition on one
//!    shared [`Interconnect`](crate::hbm::Interconnect);
//!  * BRAM/URAM/DSP budgets are checked at generation time against the
//!    whole-device total (member CUs + one platform shell + the link
//!    FIFOs), so an infeasible composition fails here, not in Vitis;
//!  * producer→consumer edges stream through on-chip FIFOs sized by
//!    [`mnemosyne::link_fifo`] instead of round-tripping HBM — only the
//!    first stage pays PCIe-in and only the last pays PCIe-out.
//!
//! All stages march in lockstep over a **common batch size** (the
//! smallest member batch, aligned to every member's lane count), which
//! is what lets the simulator chain per-stage timelines by FIFO credit
//! (`sim::compose`).

use crate::hbm::{self, PortDemand};
use crate::hls;
use crate::ir::affine::Kernel;
use crate::mnemosyne::{self, LinkFifo};
use crate::platform::{Platform, Resources};

use super::{cu_port_demand, generate, CuChannels, MemoryKind, OlympusOpts, SystemSpec};

/// An on-chip producer→consumer edge between two adjacent stages.
#[derive(Debug, Clone)]
pub struct StageLink {
    /// Index of the upstream stage in [`ComposedSystem::stages`].
    pub producer: usize,
    /// Index of the downstream stage (always `producer + 1`).
    pub consumer: usize,
    /// The stream FIFO carrying the producer's output elements.
    pub fifo: LinkFifo,
}

/// Several kernels' CUs on one device, chained by on-chip FIFOs.
#[derive(Debug, Clone)]
pub struct ComposedSystem {
    pub name: String,
    /// Member systems in pipeline order. Each keeps its own compute
    /// architecture; `channels`/`hbm_map` hold its slice of the global
    /// channel partition and `batch_elements` the common batch size.
    pub stages: Vec<SystemSpec>,
    /// One link per adjacent stage pair (`stages.len() - 1` entries).
    pub links: Vec<StageLink>,
    /// Common elements per batch — every stage's `batch_elements`.
    pub batch_elements: usize,
    /// Whole-device resources: member CUs + one shell + link FIFOs
    /// (the quantity the feasibility check compared to the platform).
    pub resources: Resources,
}

impl ComposedSystem {
    /// Total pseudo-channels in use across all stages.
    pub fn total_pcs(&self) -> usize {
        self.stages.iter().map(|s| s.total_pcs()).sum()
    }

    /// Structural invariants (pinned by `tests/compose.rs`): every
    /// member validates on its own, the channel partition is disjoint
    /// *across* members, links chain adjacent stages, and all stages
    /// share the common batch.
    pub fn validate(&self, platform: &Platform) -> Result<(), String> {
        if self.stages.is_empty() {
            return Err("composed system has no stages".into());
        }
        let mut seen = std::collections::HashSet::new();
        for (i, s) in self.stages.iter().enumerate() {
            s.validate(platform)
                .map_err(|e| format!("stage {i} ({}): {e}", s.kernel.name))?;
            for c in &s.channels {
                for pc in c.all() {
                    if !seen.insert(pc) {
                        return Err(format!(
                            "PC {pc} assigned to multiple composed stages"
                        ));
                    }
                }
            }
            if s.batch_elements != self.batch_elements {
                return Err(format!(
                    "stage {i} batch {} != common batch {}",
                    s.batch_elements, self.batch_elements
                ));
            }
        }
        if self.links.len() + 1 != self.stages.len() {
            return Err(format!(
                "{} links cannot chain {} stages",
                self.links.len(),
                self.stages.len()
            ));
        }
        for (i, l) in self.links.iter().enumerate() {
            if l.producer != i || l.consumer != i + 1 {
                return Err(format!("link {i} does not chain stage {i}→{}", i + 1));
            }
            if l.fifo.depth_words == 0 {
                return Err(format!("link {i} has a zero-depth FIFO"));
            }
        }
        if self.batch_elements == 0 {
            return Err("composed batch must hold at least one element".into());
        }
        if !self.resources.fits_in(&platform.total_resources()) {
            return Err("composed system exceeds the device budget".into());
        }
        Ok(())
    }
}

fn gcd(a: usize, b: usize) -> usize {
    if b == 0 {
        a
    } else {
        gcd(b, a % b)
    }
}

fn lcm(a: usize, b: usize) -> usize {
    a / gcd(a, b) * b
}

/// Place several kernels on one device as a pipeline, in slice order.
///
/// Every member is generated standalone first (schedule, memory plan,
/// batch sizing, per-member validation), then the device-level shared
/// state is rebuilt: one global channel allocation under the *first*
/// member's policy, a common lockstep batch, mnemosyne-sized link
/// FIFOs, and the whole-device resource feasibility check.
pub fn compose(
    members: &[(&Kernel, OlympusOpts)],
    platform: &Platform,
) -> Result<ComposedSystem, String> {
    if members.is_empty() {
        return Err("compose needs at least one kernel".into());
    }
    for (i, (k, o)) in members.iter().enumerate() {
        if o.memory != MemoryKind::Hbm {
            return Err(format!(
                "stage {i} ({}): composition partitions the 32 HBM \
                 pseudo-channels; DDR4 members are not composable",
                k.name
            ));
        }
    }

    // ---- members, standalone ----
    let mut stages: Vec<SystemSpec> = Vec::with_capacity(members.len());
    for (i, (k, o)) in members.iter().enumerate() {
        stages.push(
            generate(k, o, platform)
                .map_err(|e| format!("stage {i} ({}): {e}", k.name))?,
        );
    }

    // ---- one global channel partition (paper §3.6.1, CHARM-style) ----
    // Concatenating the per-kernel demand groups into a single allocate
    // call is what guarantees cross-kernel disjointness: master slots
    // advance sequentially over the whole slice, and the policy never
    // hands out a channel twice.
    let policy = &members[0].1.channel_policy;
    let interconnect = hbm::Interconnect::hbm(&platform.hbm);
    let demands: Vec<PortDemand> = members
        .iter()
        .flat_map(|(_, o)| {
            let d = cu_port_demand(o);
            (0..o.num_cus).map(move |_| d)
        })
        .collect();
    let routes = hbm::allocate(policy, &demands, &interconnect).map_err(|e| {
        format!("composed channel allocation ({}): {e}", policy.name())
    })?;
    let mut cursor = 0usize;
    for spec in stages.iter_mut() {
        let slice = &routes[cursor..cursor + spec.num_cus];
        cursor += spec.num_cus;
        spec.channels = slice
            .iter()
            .map(|cu| CuChannels {
                read: cu.read.iter().map(|r| r.channel).collect(),
                write: cu.write.iter().map(|r| r.channel).collect(),
            })
            .collect();
        spec.hbm_map = hbm::ChannelMap {
            interconnect,
            cus: slice.to_vec(),
        };
    }

    // ---- common lockstep batch ----
    // The pipeline advances one batch through every stage per step, so
    // all stages share one batch size: the smallest member batch,
    // truncated to a multiple of every member's lane count.
    let align = stages.iter().map(|s| s.lanes.max(1)).fold(1, lcm);
    let min_batch = stages
        .iter()
        .map(|s| s.batch_elements)
        .min()
        .expect("members is non-empty");
    let common = (min_batch / align) * align;
    if common == 0 {
        return Err(format!(
            "no common batch: smallest member batch {min_batch} cannot \
             align to {align} lanes"
        ));
    }
    for spec in stages.iter_mut() {
        spec.batch_elements = common;
    }

    // ---- producer→consumer links through on-chip FIFOs ----
    let links: Vec<StageLink> = stages
        .windows(2)
        .enumerate()
        .map(|(i, w)| StageLink {
            producer: i,
            consumer: i + 1,
            fifo: mnemosyne::link_fifo(
                w[0].kernel.output_words(),
                w[1].kernel.input_words(),
                w[0].dtype.bytes() as usize,
                w[1].opts.fifo_depth,
            ),
        })
        .collect();

    // ---- whole-device resource feasibility ----
    // One shell + every member's CUs + the link FIFOs. Using the HLS
    // estimator here keeps the check consistent with what `dse` and the
    // reports see for single-kernel systems.
    let ests: Vec<hls::Estimate> =
        stages.iter().map(|s| hls::estimate(s, platform)).collect();
    let mut resources = ests[0].total;
    for (spec, est) in stages.iter().zip(&ests).skip(1) {
        resources = resources.add(&est.per_cu.scale(spec.num_cus as u64));
    }
    let fifo_halves: u64 = links.iter().map(|l| l.fifo.bram_halves()).sum();
    resources.bram += fifo_halves.div_ceil(2);
    let budget = platform.total_resources();
    if !resources.fits_in(&budget) {
        let names: Vec<&str> =
            stages.iter().map(|s| s.kernel.name.as_str()).collect();
        return Err(format!(
            "composed system [{}] exceeds the device: needs LUT {} FF {} \
             BRAM {} URAM {} DSP {} of budget LUT {} FF {} BRAM {} URAM {} \
             DSP {}",
            names.join("+"),
            resources.lut,
            resources.ff,
            resources.bram,
            resources.uram,
            resources.dsp,
            budget.lut,
            budget.ff,
            budget.bram,
            budget.uram,
            budget.dsp,
        ));
    }

    let name = stages
        .iter()
        .map(|s| s.kernel.name.as_str())
        .collect::<Vec<_>>()
        .join("+");
    let sys = ComposedSystem {
        name,
        stages,
        links,
        batch_elements: common,
        resources,
    };
    sys.validate(platform)?;
    Ok(sys)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dsl;
    use crate::ir::{lower, rewrite, teil};

    fn kernel(src: &str, name: &str) -> Kernel {
        let prog = dsl::parse(src).unwrap();
        let m = rewrite::optimize(teil::from_ast(&prog).unwrap());
        lower::lower_kernel(&m, name).unwrap()
    }

    fn helmholtz(p: usize) -> Kernel {
        kernel(&dsl::inverse_helmholtz_source(p), "helmholtz")
    }

    fn u280() -> Platform {
        Platform::alveo_u280()
    }

    #[test]
    fn composing_one_kernel_is_a_degenerate_pipeline() {
        let k = helmholtz(7);
        let sys =
            compose(&[(&k, OlympusOpts::baseline())], &u280()).unwrap();
        assert_eq!(sys.stages.len(), 1);
        assert!(sys.links.is_empty());
        sys.validate(&u280()).unwrap();
    }

    #[test]
    fn members_share_one_disjoint_channel_partition() {
        let k = helmholtz(7);
        let sys = compose(
            &[
                (&k, OlympusOpts::baseline()),
                (&k, OlympusOpts::double_buffering()),
                (&k, OlympusOpts::baseline().with_cus(2)),
            ],
            &u280(),
        )
        .unwrap();
        let mut seen = std::collections::HashSet::new();
        for s in &sys.stages {
            for c in &s.channels {
                for pc in c.all() {
                    assert!(seen.insert(pc), "PC {pc} reused");
                }
            }
        }
        // 1 + 4 (db single-CU separates IO) + 2 shared channels
        assert_eq!(sys.total_pcs(), 7);
        sys.validate(&u280()).unwrap();
    }

    #[test]
    fn ddr4_members_are_rejected() {
        let k = helmholtz(7);
        let err = compose(
            &[
                (&k, OlympusOpts::baseline()),
                (&k, OlympusOpts::baseline().on_ddr4()),
            ],
            &u280(),
        )
        .unwrap_err();
        assert!(err.contains("DDR4"), "{err}");
    }

    #[test]
    fn stages_march_on_the_smallest_lane_aligned_batch() {
        let k = helmholtz(11);
        let sys = compose(
            &[
                (&k, OlympusOpts::bus_parallel()),  // 4 lanes
                (&k, OlympusOpts::double_buffering()), // smaller batch
            ],
            &u280(),
        )
        .unwrap();
        let min = sys.stages.iter().map(|s| s.batch_elements).min().unwrap();
        assert_eq!(sys.batch_elements, min);
        assert_eq!(sys.batch_elements % 4, 0, "aligned to the 4-lane stage");
        for s in &sys.stages {
            assert_eq!(s.batch_elements, sys.batch_elements);
        }
    }

    #[test]
    fn links_chain_adjacent_stages_with_mnemosyne_fifos() {
        let k = helmholtz(7);
        let sys = compose(
            &[
                (&k, OlympusOpts::baseline()),
                (&k, OlympusOpts::baseline()),
                (&k, OlympusOpts::baseline()),
            ],
            &u280(),
        )
        .unwrap();
        assert_eq!(sys.links.len(), 2);
        for (i, l) in sys.links.iter().enumerate() {
            assert_eq!((l.producer, l.consumer), (i, i + 1));
            let expect = mnemosyne::link_fifo(
                sys.stages[i].kernel.output_words(),
                sys.stages[i + 1].kernel.input_words(),
                8,
                None,
            );
            assert_eq!(l.fifo, expect);
            assert!(l.fifo.bram_halves() >= 1);
        }
    }

    #[test]
    fn channel_over_demand_across_members_is_rejected() {
        let k = helmholtz(7);
        // 3 members x 16 shared channels = 48 > 32
        let err = compose(
            &[
                (&k, OlympusOpts::baseline().with_cus(16)),
                (&k, OlympusOpts::baseline().with_cus(16)),
                (&k, OlympusOpts::baseline().with_cus(16)),
            ],
            &u280(),
        )
        .unwrap_err();
        assert!(err.contains("composed channel allocation"), "{err}");
    }

    #[test]
    fn resource_infeasible_compositions_fail_at_generation() {
        // enough replicated dataflow-7 members to blow the DSP budget
        let k = helmholtz(11);
        let members: Vec<(&Kernel, OlympusOpts)> = (0..8)
            .map(|_| (&k, OlympusOpts::dataflow(7).with_cus(4)))
            .collect();
        let err = compose(&members, &u280()).unwrap_err();
        assert!(
            err.contains("exceeds the device")
                || err.contains("composed channel allocation"),
            "{err}"
        );
    }
}
