//! Scalar data formats (paper §3.4.2, §3.6.4).
//!
//! The flow supports IEEE double/float and the two `ap_fixed` formats the
//! paper evaluates. Fixed-point values are *carried* as f64 on the XLA
//! side (fake quantization; see python/compile/kernels/quant.py) but keep
//! their true bit width for all bandwidth/resource accounting here.

use std::fmt;

/// A scalar format usable by the datapath.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DataType {
    /// IEEE 754 binary64 — the paper's default CPU type.
    F64,
    /// IEEE 754 binary32.
    F32,
    /// ap_fixed<64, 24>: Q24.40 (paper "Fixed Point 64").
    Fx64,
    /// ap_fixed<32, 8>: Q8.24 (paper "Fixed Point 32").
    Fx32,
}

impl DataType {
    pub const ALL: [DataType; 4] = [
        DataType::F64,
        DataType::F32,
        DataType::Fx64,
        DataType::Fx32,
    ];

    /// Bit width on the AXI bus and in on-chip storage.
    pub fn bits(self) -> u32 {
        match self {
            DataType::F64 | DataType::Fx64 => 64,
            DataType::F32 | DataType::Fx32 => 32,
        }
    }

    pub fn bytes(self) -> u32 {
        self.bits() / 8
    }

    pub fn is_fixed(self) -> bool {
        matches!(self, DataType::Fx64 | DataType::Fx32)
    }

    /// Artifact-manifest dtype string (matches python/compile/model.py).
    pub fn name(self) -> &'static str {
        match self {
            DataType::F64 => "f64",
            DataType::F32 => "f32",
            DataType::Fx64 => "fx64",
            DataType::Fx32 => "fx32",
        }
    }

    /// Paper display name.
    pub fn display(self) -> &'static str {
        match self {
            DataType::F64 => "Double",
            DataType::F32 => "Float",
            DataType::Fx64 => "Fixed Point 64",
            DataType::Fx32 => "Fixed Point 32",
        }
    }

    /// Fractional bits of the fixed-point grid (None for floats).
    pub fn frac_bits(self) -> Option<u32> {
        match self {
            DataType::Fx64 => Some(40),
            DataType::Fx32 => Some(24),
            _ => None,
        }
    }

    pub fn parse(s: &str) -> Option<DataType> {
        match s {
            "f64" | "double" => Some(DataType::F64),
            "f32" | "float" => Some(DataType::F32),
            "fx64" => Some(DataType::Fx64),
            "fx32" => Some(DataType::Fx32),
            _ => None,
        }
    }
}

impl fmt::Display for DataType {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.name())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn widths() {
        assert_eq!(DataType::F64.bits(), 64);
        assert_eq!(DataType::Fx64.bits(), 64);
        assert_eq!(DataType::F32.bytes(), 4);
        assert_eq!(DataType::Fx32.bytes(), 4);
    }

    #[test]
    fn fixed_point_grids_match_paper() {
        assert_eq!(DataType::Fx64.frac_bits(), Some(40)); // Q24.40
        assert_eq!(DataType::Fx32.frac_bits(), Some(24)); // Q8.24
        assert_eq!(DataType::F64.frac_bits(), None);
    }

    #[test]
    fn parse_roundtrip() {
        for d in DataType::ALL {
            assert_eq!(DataType::parse(d.name()), Some(d));
        }
        assert_eq!(DataType::parse("double"), Some(DataType::F64));
        assert_eq!(DataType::parse("q8"), None);
    }
}
