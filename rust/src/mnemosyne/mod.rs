//! Mnemosyne: the on-chip memory planner (paper §3.5, Fig. 13/14d;
//! Pilato et al., IEEE TCAD 2017; Soldavini & Pilato, *Compiler
//! Infrastructure for Specializing Domain-Specific Memory Templates*).
//!
//! Two layers, composed by [`plan`] into the single [`MemoryPlan`] every
//! downstream consumer (HLS resource estimation, the cycle simulator,
//! the DSE reports) derives its memory answers from:
//!
//!  * **Lifetime sharing** ([`share`]) — given the buffer compatibility
//!    graph exported by the compiler's liveness analysis, assign temp
//!    buffers to physical banks so that buffers with overlapping
//!    lifetimes never share a bank. This is interval-graph coloring on
//!    the *conflict* graph (complement of the compatibility graph); we
//!    color greedily in def order, which is optimal for interval graphs
//!    (left-edge algorithm). The bank's physical size is the maximum
//!    word count of its residents — the BRAM/URAM saving the paper
//!    reports for the 1-compute dataflow implementation (BRAM −14.5%,
//!    URAM −48.3%, Table 3 "Mem Sharing").
//!
//!  * **Access-pattern-driven banking** — each physical array must
//!    sustain the parallel reads of the unrolled datapath
//!    (`ir::access`): a buffer read by a contraction nest with its
//!    reduction loop fully unrolled needs `red_trip` words per cycle,
//!    so the planner partitions it cyclically into that many banks
//!    (one read port per bank; the second RAM port is the writer's).
//!    Storage below the LUTRAM bound is completely partitioned into
//!    distributed registers; everything else maps onto BRAM18 halves,
//!    BRAM36 tiles, or URAM blocks by size. A DSE-imposed partition
//!    cap under-provisions ports and the simulator charges the
//!    resulting bank-conflict stalls — the mechanism that lets the
//!    frontier trade BRAM/URAM against throughput.

use crate::ir::access;
use crate::ir::affine::{BufId, BufKind, Kernel, NestKind};
use crate::ir::liveness::{self, Liveness};
use crate::ir::schedule::Schedule;

/// URAM eligibility threshold: Vitis maps arrays to URAM only when they
/// are large enough; 8 KiB reproduces the paper's switches (p=11 doubles
/// -> URAM; p=7 or 32-bit -> BRAM; Tables 3-4).
const URAM_MIN_BYTES: u64 = 8 * 1024;
/// Below this, arrays land in LUTRAM (distributed memory), not BRAM.
const LUTRAM_MAX_BYTES: u64 = 2 * 1024;
/// BRAM36 tile: 4 KiB payload; a half tile (BRAM18) holds 2 KiB.
const BRAM_TILE_BYTES: u64 = 4 * 1024;

/// A physical bank shared by one or more temp buffers.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Bank {
    /// Buffer ids assigned to this bank (disjoint lifetimes).
    pub residents: Vec<usize>,
    /// Physical size = max resident words.
    pub words: usize,
}

/// Result of the lifetime-sharing optimization.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SharingPlan {
    /// bank id per buffer (None for inputs/outputs — not shared — and
    /// for unused temps, which need no storage at all).
    pub bank_of: Vec<Option<usize>>,
    pub banks: Vec<Bank>,
}

impl SharingPlan {
    /// Words of on-chip storage for temps *without* sharing.
    pub fn unshared_words(&self, k: &Kernel) -> usize {
        k.temps().map(|(_, b)| b.words()).sum()
    }

    /// Words of on-chip storage for temps *with* sharing.
    pub fn shared_words(&self) -> usize {
        self.banks.iter().map(|b| b.words).sum()
    }

    /// Validate: residents of every bank are pairwise lifetime-disjoint.
    pub fn validate(&self, k: &Kernel, lv: &Liveness) -> Result<(), String> {
        for (bi, bank) in self.banks.iter().enumerate() {
            for (x, &i) in bank.residents.iter().enumerate() {
                if k.buffers[i].kind != BufKind::Temp {
                    return Err(format!("bank {bi} holds non-temp buffer {i}"));
                }
                for &j in &bank.residents[x + 1..] {
                    let (a, b) = match (&lv.intervals[i], &lv.intervals[j]) {
                        (Some(a), Some(b)) => (a, b),
                        _ => return Err(format!("bank {bi} holds unanalyzed buffer")),
                    };
                    if !a.disjoint(b) {
                        return Err(format!(
                            "bank {bi}: buffers {} and {} overlap",
                            k.buffers[i].name, k.buffers[j].name
                        ));
                    }
                }
                if k.buffers[i].words() > bank.words {
                    return Err(format!("bank {bi} smaller than resident {i}"));
                }
            }
        }
        // every *live* temp must be placed exactly once; an unused temp
        // (never written — liveness has no interval for it) needs no
        // storage and must stay unplaced
        for (i, b) in k.buffers.iter().enumerate() {
            let placed = self.bank_of[i].is_some();
            let needs_bank = b.kind == BufKind::Temp && lv.intervals[i].is_some();
            if needs_bank != placed {
                return Err(format!("buffer {} placement inconsistent", b.name));
            }
            if let Some(bk) = self.bank_of[i] {
                if !self.banks[bk].residents.contains(&i) {
                    return Err(format!("bank_of[{i}] not in bank residents"));
                }
            }
        }
        Ok(())
    }
}

/// Greedy left-edge bank assignment over temp-buffer lifetimes.
///
/// `scope`: optionally restrict sharing to buffers whose entire lifetime
/// falls inside one schedule group (the paper: "sharing opportunities can
/// operate only inside each subkernel", §3.6.4). Pass group (start, end)
/// nest ranges; buffers crossing a boundary get private banks.
pub fn share(k: &Kernel, lv: &Liveness, scope: Option<&[(usize, usize)]>) -> SharingPlan {
    let mut order: Vec<usize> = k
        .buffers
        .iter()
        .enumerate()
        .filter(|(i, b)| b.kind == BufKind::Temp && lv.intervals[*i].is_some())
        .map(|(i, _)| i)
        .collect();
    order.sort_by_key(|&i| lv.intervals[i].unwrap().def);

    // group id of a buffer's lifetime, or None if it crosses groups
    let group_of = |i: usize| -> Option<usize> {
        let iv = lv.intervals[i].unwrap();
        scope?.iter().position(|&(s, e)| iv.def >= s && iv.last_use < e)
    };

    let mut banks: Vec<Bank> = Vec::new();
    let mut bank_group: Vec<Option<usize>> = Vec::new();
    let mut bank_of: Vec<Option<usize>> = vec![None; k.buffers.len()];
    for &i in &order {
        let iv = lv.intervals[i].unwrap();
        let grp = group_of(i);
        let crosses = scope.is_some() && grp.is_none();
        let mut placed = false;
        if !crosses {
            for (bi, bank) in banks.iter_mut().enumerate() {
                if scope.is_some() && bank_group[bi] != grp {
                    continue;
                }
                let ok = bank.residents.iter().all(|&r| {
                    lv.intervals[r].unwrap().disjoint(&iv)
                });
                if ok {
                    bank.residents.push(i);
                    bank.words = bank.words.max(k.buffers[i].words());
                    bank_of[i] = Some(bi);
                    placed = true;
                    break;
                }
            }
        }
        if !placed {
            banks.push(Bank {
                residents: vec![i],
                words: k.buffers[i].words(),
            });
            bank_group.push(if crosses { None } else { grp });
            bank_of[i] = Some(banks.len() - 1);
        }
    }
    SharingPlan { bank_of, banks }
}

// ---------------------------------------------------------------------
// Memory plan: banking + storage mapping composed with sharing
// ---------------------------------------------------------------------

/// How an array's words are distributed over its banks.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BankingScheme {
    /// Word `i` lives in bank `i % factor` — the scheme for reduction-
    /// unrolled reads, which touch `factor` consecutive words per cycle.
    Cyclic,
    /// One contiguous bank (factor 1): stream-order or strided access,
    /// one word per cycle.
    Block,
    /// Every word its own register (LUTRAM / full partitioning): any
    /// access pattern is conflict-free.
    Complete,
}

/// Physical RAM primitive backing one bank.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RamKind {
    /// Distributed LUT memory (arrays below the 2 KiB bound).
    Lutram,
    /// Half BRAM tile (≤ 2 KiB payload per bank).
    Bram18,
    /// Full BRAM36 tiles (> 2 KiB per bank).
    Bram36,
    /// UltraRAM block (arrays ≥ 8 KiB).
    Uram,
}

impl RamKind {
    /// Physical ports per bank. Every hard RAM primitive on UltraScale+
    /// is dual-port; the planner dedicates one port to the writer, so a
    /// bank delivers one read per cycle.
    pub fn ports(self) -> usize {
        2
    }

    pub fn name(self) -> &'static str {
        match self {
            RamKind::Lutram => "lutram",
            RamKind::Bram18 => "bram18",
            RamKind::Bram36 => "bram36",
            RamKind::Uram => "uram",
        }
    }
}

/// One physical array in the generated hardware (per lane): a buffer —
/// or a lifetime-shared set of temp buffers — mapped to banks of one
/// RAM primitive.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ArrayInstance {
    /// Buffers resident in this storage (one unless lifetime-shared).
    pub residents: Vec<BufId>,
    /// Physical words = max resident words.
    pub words: usize,
    /// Array size in bytes at the design's data type.
    pub bytes: u64,
    /// Parallel reads the unrolled datapath demands (max over residents).
    pub access_degree: usize,
    /// Chosen number of banks (≤ `access_degree` when capped).
    pub factor: usize,
    pub scheme: BankingScheme,
    pub ram: RamKind,
    /// Dataflow group that instantiates this copy (`None` = the flat /
    /// single-group kernel's global storage).
    pub group: Option<usize>,
}

impl ArrayInstance {
    /// Parallel words per cycle the banked storage can deliver: one read
    /// port per bank (the second port belongs to the writer), except
    /// completely-partitioned storage where every word is a register.
    pub fn read_ports(&self) -> usize {
        match self.scheme {
            BankingScheme::Complete => self.words.max(self.access_degree).max(1),
            _ => self.factor.max(1),
        }
    }

    /// Storage cost of this array: (bram18 halves, uram blocks, lutram
    /// LUTs). Mirrors the Vitis mapping the paper's Tables 3–4 exhibit.
    pub fn footprint(&self) -> (u64, u64, u64) {
        let parts = self.factor.max(1) as u64;
        match self.ram {
            RamKind::Uram => (0, parts, 0),
            // distributed RAM: ~1 LUT per 64 bits plus addressing
            RamKind::Lutram => (0, 0, self.bytes / 4 + 32),
            RamKind::Bram18 => (parts, 0, 0),
            RamKind::Bram36 => {
                let per_bank = self.bytes.div_ceil(parts);
                (parts * 2 * per_bank.div_ceil(BRAM_TILE_BYTES), 0, 0)
            }
        }
    }
}

/// On-chip storage policy for *indirectly accessed* arrays (a gather
/// nest's data operand, a scatter nest's target) — the reuse-aware
/// scratchpad axis of the irregular-access subsystem (DESIGN.md §2.11).
///
/// Indexed accesses cannot stream: each one lands on a data-dependent
/// row, so serving them straight from HBM pays the pseudo-random
/// penalty `hbm::traffic::AccessPattern` prices. The scheme decides how
/// much on-chip storage to spend to absorb that traffic.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum CacheScheme {
    /// No on-chip structure: every indexed access is pseudo-random HBM
    /// traffic (free, slow).
    Bypass,
    /// Direct-mapped scratchpad of the given capacity in words: captures
    /// the reuse fraction of the covered footprint (cheap, faster).
    Cached(usize),
    /// The whole indexed array resident on chip: indexed accesses are
    /// local and free of HBM penalties (expensive, fastest).
    FullBuffer,
}

impl CacheScheme {
    /// Every form [`CacheScheme::parse`] accepts — the single source of
    /// truth the CLI's unknown `--cache-scheme` error lists (same
    /// contract as `ChannelPolicy::PARSE_NAMES` for `--policy`).
    pub const PARSE_NAMES: &'static [&'static str] =
        &["bypass", "cached:<words>", "full"];

    /// Short name used in labels and CSV/JSON output; round-trips
    /// through [`CacheScheme::parse`].
    pub fn name(&self) -> String {
        match self {
            CacheScheme::Bypass => "bypass".into(),
            CacheScheme::Cached(w) => format!("cached:{w}"),
            CacheScheme::FullBuffer => "full".into(),
        }
    }

    /// Inverse of [`CacheScheme::name`] (CLI flags, flow artifacts).
    pub fn parse(s: &str) -> Option<CacheScheme> {
        match s {
            "bypass" => Some(CacheScheme::Bypass),
            "full" => Some(CacheScheme::FullBuffer),
            _ => s
                .strip_prefix("cached:")?
                .parse::<usize>()
                .ok()
                .filter(|&w| w > 0)
                .map(CacheScheme::Cached),
        }
    }
}

impl Default for CacheScheme {
    fn default() -> Self {
        CacheScheme::Bypass
    }
}

/// One reuse-aware scratchpad instance: on-chip storage absorbing the
/// indexed accesses of one buffer. Unlike an [`ArrayInstance`], a cache
/// may be *smaller* than the buffer it fronts (the whole point of
/// [`CacheScheme::Cached`]), which is why caches live beside the arrays
/// rather than among them — the array invariants (words == max resident
/// words, factor == planned target) do not apply here.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CacheInstance {
    /// The indirectly accessed buffer this cache fronts.
    pub buf: BufId,
    /// Capacity in words (== the buffer's words under `FullBuffer`).
    pub words: usize,
    /// Capacity in bytes at the design's data type.
    pub bytes: u64,
    /// Physical RAM primitive, by the same size bounds as the arrays.
    pub ram: RamKind,
}

impl CacheInstance {
    /// Fraction of the fronted buffer resident on chip (≤ 1).
    pub fn coverage(&self, k: &Kernel) -> f64 {
        let total = k.buffers[self.buf].words().max(1) as f64;
        (self.words as f64 / total).min(1.0)
    }

    /// Storage cost: (bram18 halves, uram blocks, lutram LUTs) — same
    /// primitive mapping as [`ArrayInstance::footprint`], single bank
    /// (indexed demand is one word per cycle; `ir::access`).
    pub fn footprint(&self) -> (u64, u64, u64) {
        match self.ram {
            RamKind::Uram => (0, self.bytes.div_ceil(32 * 1024).max(1), 0),
            RamKind::Lutram => (0, 0, self.bytes / 4 + 32),
            RamKind::Bram18 => (1, 0, 0),
            RamKind::Bram36 => (2 * self.bytes.div_ceil(BRAM_TILE_BYTES), 0, 0),
        }
    }
}

/// Options the designer (or the DSE memory axis) feeds the planner.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PlanOpts {
    /// Apply lifetime sharing to the temps (flat / 1-group schedules).
    pub sharing: bool,
    /// Cap the partition factor below the access degree (None = match
    /// the demand exactly — conflict-free by construction).
    pub partition_cap: Option<usize>,
    /// Inter-group stream FIFO depth in words (None = full array size).
    pub fifo_depth: Option<usize>,
    /// Scratchpad policy for indirectly accessed arrays (inert on
    /// kernels without gather/scatter nests).
    pub cache: CacheScheme,
}

/// The unified on-chip memory plan of one generated system (per lane).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct MemoryPlan {
    pub arrays: Vec<ArrayInstance>,
    /// Inter-group stream FIFO depths in words (empty unless the
    /// dataflow schedule has ≥ 2 groups).
    pub fifos: Vec<usize>,
    /// Bytes per word at the design's data type.
    pub word_bytes: usize,
    /// The cap the plan was built under (recorded for validation).
    pub partition_cap: Option<usize>,
    /// The lifetime-sharing coloring, when applied.
    pub sharing: Option<SharingPlan>,
    /// Reuse-aware scratchpads fronting indirectly accessed buffers
    /// (empty under [`CacheScheme::Bypass`] or when the kernel has no
    /// gather/scatter nests).
    pub caches: Vec<CacheInstance>,
    /// The scheme the caches were built under (recorded for validation
    /// and for the traffic model's per-scheme miss pricing).
    pub cache_scheme: CacheScheme,
}

/// Summary numbers the DSE reports surface.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PlanStats {
    /// Physical array instances per lane.
    pub arrays: usize,
    /// Total banks across all instances.
    pub banks: usize,
    /// Physical on-chip words (after sharing).
    pub shared_words: usize,
    /// Words if every resident had private storage.
    pub unshared_words: usize,
}

impl MemoryPlan {
    /// Physical on-chip words per lane (bank-merged residents counted
    /// once, at the bank's size).
    pub fn shared_words(&self) -> usize {
        self.arrays.iter().map(|a| a.words).sum()
    }

    /// Words if every resident buffer had private storage — the
    /// baseline the sharing saving is measured against.
    pub fn unshared_words(&self, k: &Kernel) -> usize {
        self.arrays
            .iter()
            .map(|a| a.residents.iter().map(|&b| k.buffers[b].words()).sum::<usize>())
            .sum()
    }

    /// Total banks across all array instances.
    pub fn total_banks(&self) -> usize {
        self.arrays.iter().map(|a| a.factor).sum()
    }

    /// On-chip words spent on indexed-access scratchpads per lane.
    pub fn cache_words(&self) -> usize {
        self.caches.iter().map(|c| c.words).sum()
    }

    /// The scratchpad fronting `buf`, if the scheme planned one.
    pub fn cache_for(&self, buf: BufId) -> Option<&CacheInstance> {
        self.caches.iter().find(|c| c.buf == buf)
    }

    /// BRAM18 halves consumed by the inter-group stream FIFOs (FIFOs
    /// are always BRAM: URAM has no FIFO primitive and LUTRAM depths
    /// this size would swamp the logic budget).
    pub fn fifo_bram_halves(&self) -> u64 {
        self.fifos
            .iter()
            .map(|&d| {
                let bytes = d as u64 * self.word_bytes as u64;
                if bytes <= BRAM_TILE_BYTES / 2 {
                    1
                } else {
                    2 * bytes.div_ceil(BRAM_TILE_BYTES)
                }
            })
            .sum()
    }

    pub fn stats(&self, k: &Kernel) -> PlanStats {
        PlanStats {
            arrays: self.arrays.len(),
            banks: self.total_banks(),
            shared_words: self.shared_words(),
            unshared_words: self.unshared_words(k),
        }
    }

    /// The instance serving reads of `buf` issued from dataflow group
    /// `group` (falls back to the global flat storage).
    pub fn instance_for(&self, buf: BufId, group: Option<usize>) -> Option<&ArrayInstance> {
        self.arrays
            .iter()
            .find(|a| a.group == group && a.residents.contains(&buf))
            .or_else(|| {
                self.arrays
                    .iter()
                    .find(|a| a.group.is_none() && a.residents.contains(&buf))
            })
    }

    /// Cycles one iteration of nest `ni` (issued from `group`) takes
    /// relative to the conflict-free ideal of 1: the limiting read
    /// buffer's `ceil(demand / provisioned ports)`. 1 when the plan
    /// provisions the full access degree (the uncapped default).
    pub fn nest_conflict_factor(&self, k: &Kernel, ni: usize, group: Option<usize>) -> u64 {
        k.nests[ni]
            .reads
            .iter()
            .map(|&b| {
                let demand = access::nest_read_degree(k, ni, b).max(1);
                let ports = self
                    .instance_for(b, group)
                    .map(|a| a.read_ports())
                    .unwrap_or(demand);
                (demand as u64).div_ceil(ports.max(1) as u64)
            })
            .max()
            .unwrap_or(1)
    }

    /// Structural invariants; property-tested in
    /// `rust/tests/memory_plan_prop.rs`.
    pub fn validate(&self, k: &Kernel) -> Result<(), String> {
        let lv = liveness::analyze(k);
        if let Some(sp) = &self.sharing {
            sp.validate(k, &lv)?;
        }
        for (i, a) in self.arrays.iter().enumerate() {
            if a.residents.is_empty() {
                return Err(format!("array {i} has no residents"));
            }
            let max_words = a
                .residents
                .iter()
                .map(|&b| k.buffers[b].words())
                .max()
                .unwrap();
            if a.words != max_words {
                return Err(format!(
                    "array {i}: words {} != max resident words {max_words}",
                    a.words
                ));
            }
            if a.bytes != a.words as u64 * self.word_bytes as u64 {
                return Err(format!("array {i}: byte size inconsistent"));
            }
            if a.factor == 0 || a.factor > a.words.max(1) {
                return Err(format!(
                    "array {i}: factor {} out of range (words {})",
                    a.factor, a.words
                ));
            }
            // the factor never exceeds the demand, and meets it unless
            // the designer capped it
            if a.factor > a.access_degree.max(1) {
                return Err(format!("array {i}: over-partitioned"));
            }
            let target = match self.partition_cap {
                Some(c) => a.access_degree.min(c.max(1)),
                None => a.access_degree,
            }
            .min(a.words.max(1));
            if a.factor != target {
                return Err(format!(
                    "array {i}: factor {} != planned {target}",
                    a.factor
                ));
            }
            // conflict-free guarantee: uncapped plans provision at least
            // the access degree
            if self.partition_cap.is_none() && a.read_ports() < a.access_degree {
                return Err(format!(
                    "array {i}: {} read ports < access degree {}",
                    a.read_ports(),
                    a.access_degree
                ));
            }
            // shared banks only hold lifetime-disjoint temps
            if a.residents.len() > 1 {
                for (x, &bi) in a.residents.iter().enumerate() {
                    if k.buffers[bi].kind != BufKind::Temp {
                        return Err(format!("array {i} shares a non-temp buffer"));
                    }
                    for &bj in &a.residents[x + 1..] {
                        match (&lv.intervals[bi], &lv.intervals[bj]) {
                            (Some(x), Some(y)) if x.disjoint(y) => {}
                            _ => {
                                return Err(format!(
                                    "array {i}: residents {} and {} have \
                                     overlapping lifetimes",
                                    k.buffers[bi].name, k.buffers[bj].name
                                ))
                            }
                        }
                    }
                }
            }
        }
        if self.shared_words() > self.unshared_words(k) {
            return Err("sharing increased the footprint".into());
        }
        // scratchpads: exactly the indexed buffers under a caching
        // scheme, sized by the scheme, never oversized
        let indexed = access::indexed_cache_buffers(k);
        match self.cache_scheme {
            CacheScheme::Bypass => {
                if !self.caches.is_empty() {
                    return Err("bypass scheme planned caches".into());
                }
            }
            scheme => {
                let fronted: Vec<BufId> = self.caches.iter().map(|c| c.buf).collect();
                if fronted != indexed {
                    return Err(format!(
                        "caches front buffers {fronted:?}, kernel indexes {indexed:?}"
                    ));
                }
                for (i, c) in self.caches.iter().enumerate() {
                    let total = k.buffers[c.buf].words();
                    let want = match scheme {
                        CacheScheme::Cached(w) => w.min(total).max(1),
                        _ => total.max(1),
                    };
                    if c.words != want {
                        return Err(format!(
                            "cache {i}: {} words != planned {want}",
                            c.words
                        ));
                    }
                    if c.bytes != c.words as u64 * self.word_bytes as u64 {
                        return Err(format!("cache {i}: byte size inconsistent"));
                    }
                }
            }
        }
        Ok(())
    }
}

/// Storage mapping of one array: RAM primitive by size (see module
/// docs), matching Vitis' eligibility bounds.
fn ram_for(bytes: u64, factor: usize) -> RamKind {
    if bytes >= URAM_MIN_BYTES {
        RamKind::Uram
    } else if bytes < LUTRAM_MAX_BYTES {
        RamKind::Lutram
    } else {
        let per_bank = bytes.div_ceil(factor.max(1) as u64);
        if per_bank <= BRAM_TILE_BYTES / 2 {
            RamKind::Bram18
        } else {
            RamKind::Bram36
        }
    }
}

/// Assemble one array instance: choose factor (demand capped by the
/// designer and by the word count), RAM primitive, and banking scheme.
fn instance(
    residents: Vec<BufId>,
    words: usize,
    degree: usize,
    word_bytes: usize,
    cap: Option<usize>,
    group: Option<usize>,
) -> ArrayInstance {
    let degree = degree.max(1);
    let factor = match cap {
        Some(c) => degree.min(c.max(1)),
        None => degree,
    }
    .min(words.max(1));
    let bytes = words as u64 * word_bytes as u64;
    let ram = ram_for(bytes, factor);
    let scheme = if ram == RamKind::Lutram || factor >= words.max(1) {
        BankingScheme::Complete
    } else if factor > 1 {
        BankingScheme::Cyclic
    } else {
        BankingScheme::Block
    };
    ArrayInstance {
        residents,
        words,
        bytes,
        access_degree: degree,
        factor,
        scheme,
        ram,
        group,
    }
}

/// An inter-*kernel* stream FIFO: the on-chip link carrying one composed
/// stage's output elements into the next stage (olympus composition,
/// DESIGN.md §2.10). Sized here so every on-chip memory answer — intra-
/// kernel banking, inter-group streams, and inter-kernel links — comes
/// from mnemosyne.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LinkFifo {
    /// Depth in words.
    pub depth_words: usize,
    /// Bytes per word (the producer's data type).
    pub word_bytes: usize,
}

impl LinkFifo {
    pub fn bytes(&self) -> u64 {
        self.depth_words as u64 * self.word_bytes as u64
    }

    /// BRAM18 halves, same tile math as [`MemoryPlan::fifo_bram_halves`].
    pub fn bram_halves(&self) -> u64 {
        let bytes = self.bytes();
        if bytes <= BRAM_TILE_BYTES / 2 {
            1
        } else {
            2 * bytes.div_ceil(BRAM_TILE_BYTES)
        }
    }
}

/// Size the stream FIFO between a producer stage emitting
/// `producer_words` per element and a consumer reading `consumer_words`
/// per element. The natural depth double-buffers the larger footprint —
/// the producer can emit element e+1 while the consumer drains e —
/// and `depth` overrides it (the composed system's fifo-depth knob).
pub fn link_fifo(
    producer_words: usize,
    consumer_words: usize,
    word_bytes: usize,
    depth: Option<usize>,
) -> LinkFifo {
    let natural = producer_words.max(consumer_words).max(1) * 2;
    LinkFifo {
        depth_words: depth.unwrap_or(natural).max(1),
        word_bytes: word_bytes.max(1),
    }
}

/// Build the unified memory plan for a kernel under a schedule.
///
/// Flat and 1-group schedules get global storage, with lifetime sharing
/// composed in when requested (shared banks are partitioned for the max
/// demand of their residents). Multi-group dataflow schedules follow
/// the paper's §4.2 buffering: every group privately buffers each
/// external array it reads and each intra-group temp; a sharing request
/// there only records the per-group scoped coloring for audit ("each
/// compute module only uses arrays that cannot be shared"), which is
/// why the `dse` space prunes that combination as a duplicate.
pub fn plan(
    k: &Kernel,
    schedule: &Schedule,
    dataflow: bool,
    word_bytes: usize,
    opts: &PlanOpts,
) -> MemoryPlan {
    let cap = opts.partition_cap;
    let mut arrays: Vec<ArrayInstance> = Vec::new();
    let mut fifos: Vec<usize> = Vec::new();
    let mut sharing = None;

    if dataflow && schedule.num_groups() > 1 {
        // Sharing "can operate only inside each subkernel" (§3.6.4);
        // when requested, record the per-group scoped coloring so the
        // designer can audit why it saves nothing here (for the paper's
        // kernels every scoped bank is private, Table 3) — the arrays
        // below still buffer privately per group either way.
        if opts.sharing {
            let lv = liveness::analyze(k);
            let ranges: Vec<(usize, usize)> =
                schedule.groups.iter().map(|g| (g.start, g.end)).collect();
            sharing = Some(share(k, &lv, Some(&ranges)));
        }
        // Every group buffers each array it reads that is produced
        // outside the group (paper §4.2: "the S array is needed by both
        // modules and must be buffered twice"). The group's last write
        // is streamed out — the *consumer* buffers it.
        for (gi, g) in schedule.groups.iter().enumerate() {
            let local: Vec<usize> = g.nests().map(|ni| k.nests[ni].write).collect();
            let mut buffered: Vec<usize> = Vec::new();
            for ni in g.nests() {
                let n = &k.nests[ni];
                for (slot, &r) in n.reads.iter().enumerate() {
                    // a gather's data operand is the cache scheme's
                    // job, not a private group copy: under
                    // bypass/cached it stays off chip (HBM pays, per
                    // `hbm::traffic`), under full buffering the
                    // scratchpad below holds it — `sim`'s fill model
                    // makes the same call
                    if slot == 0 && matches!(n.kind, NestKind::Gather { .. }) {
                        continue;
                    }
                    if !local.contains(&r) && !buffered.contains(&r) {
                        buffered.push(r);
                    }
                }
            }
            for b in buffered {
                let deg = access::read_degree_in(k, g.nests(), b);
                arrays.push(instance(
                    vec![b],
                    k.buffers[b].words(),
                    deg,
                    word_bytes,
                    cap,
                    Some(gi),
                ));
            }
            // intra-group temporaries: writes consumed by a later nest
            // of the same group
            for (pos, ni) in g.nests().enumerate() {
                let w = k.nests[ni].write;
                let read_later = g
                    .nests()
                    .skip(pos + 1)
                    .any(|nj| k.nests[nj].reads.contains(&w));
                if read_later {
                    let deg = access::read_degree_in(k, g.nests(), w);
                    arrays.push(instance(
                        vec![w],
                        k.buffers[w].words(),
                        deg,
                        word_bytes,
                        cap,
                        Some(gi),
                    ));
                }
            }
        }
        // inter-group stream FIFOs: the producing group's output array
        for (gi, g) in schedule.groups.iter().enumerate() {
            if gi + 1 == schedule.num_groups() {
                break;
            }
            let width = k.buffers[k.nests[g.end - 1].write].words();
            fifos.push(opts.fifo_depth.unwrap_or(width));
        }
    } else {
        // flat kernel (or 1-group dataflow): every buffer lives once;
        // Mnemosyne sharing applies to the temps.
        let am = access::analyze(k);
        let lv = liveness::analyze(k);
        if opts.sharing {
            let sp = share(k, &lv, None);
            for bank in &sp.banks {
                let deg = bank
                    .residents
                    .iter()
                    .map(|&b| am.read_degree[b])
                    .max()
                    .unwrap_or(1);
                arrays.push(instance(
                    bank.residents.clone(),
                    bank.words,
                    deg,
                    word_bytes,
                    cap,
                    None,
                ));
            }
            sharing = Some(sp);
        }
        for (b, buf) in k.buffers.iter().enumerate() {
            if opts.sharing && buf.kind == BufKind::Temp {
                continue; // placed (or unused) under the sharing plan
            }
            if buf.kind == BufKind::Temp && lv.intervals[b].is_none() {
                continue; // unused temp: never written, needs no storage
            }
            arrays.push(instance(vec![b], buf.words(), am.read_degree[b], word_bytes, cap, None));
        }
    }

    // Reuse-aware scratchpads for the indirectly accessed buffers
    // (gather data operands and scatter targets): sized by the scheme,
    // mapped to a RAM primitive by the same bounds as the arrays, and
    // priced by `hls::resources`. The miss traffic the residual
    // coverage leaves behind is charged by `hbm::traffic`.
    let caches = match opts.cache {
        CacheScheme::Bypass => Vec::new(),
        scheme => access::indexed_cache_buffers(k)
            .into_iter()
            .map(|b| {
                let total = k.buffers[b].words();
                let words = match scheme {
                    CacheScheme::Cached(w) => w.min(total).max(1),
                    _ => total.max(1),
                };
                let bytes = words as u64 * word_bytes as u64;
                CacheInstance {
                    buf: b,
                    words,
                    bytes,
                    ram: ram_for(bytes, 1),
                }
            })
            .collect(),
    };

    MemoryPlan {
        arrays,
        fifos,
        word_bytes,
        partition_cap: cap,
        sharing,
        caches,
        cache_scheme: opts.cache,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dsl;
    use crate::ir::{liveness, lower, rewrite, schedule, teil};
    use crate::util::prop;

    fn helmholtz(p: usize) -> Kernel {
        let prog = dsl::parse(&dsl::inverse_helmholtz_source(p)).unwrap();
        let m = rewrite::optimize(teil::from_ast(&prog).unwrap());
        lower::lower_kernel(&m, "helmholtz").unwrap()
    }

    fn flat_plan(k: &Kernel, sharing: bool, cap: Option<usize>) -> MemoryPlan {
        let s = schedule::fixed(k, 1).unwrap();
        plan(
            k,
            &s,
            false,
            8,
            &PlanOpts {
                sharing,
                partition_cap: cap,
                fifo_depth: None,
                cache: CacheScheme::Bypass,
            },
        )
    }

    #[test]
    fn sharing_reduces_words_on_flat_helmholtz() {
        // Paper Table 3: Mem Sharing cuts BRAM/URAM on the 1-compute
        // dataflow variant. Unscoped sharing == 1-compute case.
        let k = helmholtz(11);
        let lv = liveness::analyze(&k);
        let plan = share(&k, &lv, None);
        plan.validate(&k, &lv).unwrap();
        assert!(
            plan.shared_words() < plan.unshared_words(&k),
            "shared {} !< unshared {}",
            plan.shared_words(),
            plan.unshared_words(&k)
        );
    }

    #[test]
    fn per_group_scope_blocks_cross_stage_sharing() {
        // Paper §4.2: sharing "cannot be applied to the 2/3/7-compute
        // implementations because each compute module only uses arrays
        // that cannot be shared".
        let k = helmholtz(11);
        let lv = liveness::analyze(&k);
        let s = schedule::fixed(&k, 7).unwrap();
        let ranges: Vec<(usize, usize)> =
            s.groups.iter().map(|g| (g.start, g.end)).collect();
        let plan = share(&k, &lv, Some(&ranges));
        plan.validate(&k, &lv).unwrap();
        // all banks private -> no saving
        assert_eq!(plan.shared_words(), plan.unshared_words(&k));
    }

    #[test]
    fn bank_count_leq_buffer_count() {
        let k = helmholtz(7);
        let lv = liveness::analyze(&k);
        let plan = share(&k, &lv, None);
        assert!(plan.banks.len() <= k.temps().count());
        assert!(plan.banks.len() >= 1);
    }

    #[test]
    fn property_no_bank_holds_overlapping_lifetimes() {
        // random kernels: random chain of contraction nests over random
        // temp usage is hard to fabricate; instead randomize p and groups
        prop::check("mnemosyne soundness", 16, |rng| {
            let p = rng.range_usize(2, 9);
            let k = helmholtz(p);
            let lv = liveness::analyze(&k);
            let scoped = rng.bool();
            let plan = if scoped {
                let n = rng.range_usize(1, k.nests.len());
                let s = schedule::fixed(&k, n).unwrap();
                let ranges: Vec<(usize, usize)> =
                    s.groups.iter().map(|g| (g.start, g.end)).collect();
                share(&k, &lv, Some(&ranges))
            } else {
                share(&k, &lv, None)
            };
            plan.validate(&k, &lv).map_err(|e| e.to_string())
        });
    }

    #[test]
    fn savings_ratio_is_substantial_for_p11() {
        // 4 temp intermediates of p^3 + t + r (p^3) collapse markedly.
        let k = helmholtz(11);
        let lv = liveness::analyze(&k);
        let plan = share(&k, &lv, None);
        let ratio = plan.shared_words() as f64 / plan.unshared_words(&k) as f64;
        assert!(ratio < 0.7, "ratio {ratio}");
    }

    #[test]
    fn uncapped_plan_is_conflict_free() {
        let k = helmholtz(11);
        let mp = flat_plan(&k, true, None);
        mp.validate(&k).unwrap();
        for a in &mp.arrays {
            assert!(a.read_ports() >= a.access_degree, "{a:?}");
        }
        for ni in 0..k.nests.len() {
            assert_eq!(mp.nest_conflict_factor(&k, ni, None), 1, "nest {ni}");
        }
    }

    #[test]
    fn capped_plan_reports_conflicts_on_unrolled_reads() {
        let k = helmholtz(11);
        let mp = flat_plan(&k, false, Some(4));
        mp.validate(&k).unwrap();
        // the gemm nests read p=11 words/cycle from 4 banks -> 3 cycles
        let worst = (0..k.nests.len())
            .map(|ni| mp.nest_conflict_factor(&k, ni, None))
            .max()
            .unwrap();
        assert_eq!(worst, 3, "ceil(11/4)");
    }

    #[test]
    fn banking_schemes_follow_the_access_pattern() {
        let k = helmholtz(11);
        let mp = flat_plan(&k, false, None);
        for a in &mp.arrays {
            match a.ram {
                RamKind::Lutram => assert_eq!(a.scheme, BankingScheme::Complete),
                _ if a.factor > 1 => assert_eq!(a.scheme, BankingScheme::Cyclic),
                _ => assert_eq!(a.scheme, BankingScheme::Block),
            }
        }
        // the p=11 doubles tensors are URAM; the 11x11 operator is LUTRAM
        assert!(mp.arrays.iter().any(|a| a.ram == RamKind::Uram));
        assert!(mp.arrays.iter().any(|a| a.ram == RamKind::Lutram));
    }

    #[test]
    fn shared_plan_banks_meet_max_resident_demand() {
        let k = helmholtz(11);
        let mp = flat_plan(&k, true, None);
        let sp = mp.sharing.as_ref().unwrap();
        assert!(!sp.banks.is_empty());
        for a in mp.arrays.iter().filter(|a| a.residents.len() > 1) {
            // a bank resident read by a gemm nest forces the whole bank
            // to that partition factor
            assert_eq!(a.factor, a.access_degree);
        }
        assert!(mp.shared_words() < mp.unshared_words(&k));
    }

    #[test]
    fn multi_group_plan_buffers_per_group() {
        let k = helmholtz(11);
        let s = schedule::fixed(&k, 7).unwrap();
        let mp = plan(
            &k,
            &s,
            true,
            8,
            &PlanOpts {
                sharing: false,
                partition_cap: None,
                fifo_depth: None,
                cache: CacheScheme::Bypass,
            },
        );
        mp.validate(&k).unwrap();
        assert_eq!(mp.fifos.len(), 6, "one stream between adjacent groups");
        assert!(mp.arrays.iter().all(|a| a.group.is_some()));
        // the operator matrix is buffered by every gemm group privately
        let s_copies = mp
            .arrays
            .iter()
            .filter(|a| a.residents == vec![0] || k.buffers[a.residents[0]].words() == 121)
            .count();
        assert!(s_copies >= 2, "operator buffered per group, got {s_copies}");
    }

    #[test]
    fn multi_group_sharing_request_records_the_scoped_coloring() {
        // paper §3.6.4 / Table 3: on multi-group schedules sharing is
        // inert (all scoped banks private) — the plan records the
        // coloring for audit but the arrays still buffer per group
        let k = helmholtz(11);
        let s = schedule::fixed(&k, 7).unwrap();
        let mp = plan(
            &k,
            &s,
            true,
            8,
            &PlanOpts {
                sharing: true,
                partition_cap: None,
                fifo_depth: None,
                cache: CacheScheme::Bypass,
            },
        );
        mp.validate(&k).unwrap();
        let sp = mp.sharing.as_ref().unwrap();
        assert_eq!(sp.shared_words(), sp.unshared_words(&k), "all private");
        // identical physical arrays to the no-sharing multi-group plan
        let without = plan(
            &k,
            &s,
            true,
            8,
            &PlanOpts {
                sharing: false,
                partition_cap: None,
                fifo_depth: None,
                cache: CacheScheme::Bypass,
            },
        );
        assert_eq!(mp.arrays, without.arrays);
    }

    #[test]
    fn fifo_depth_override_is_recorded() {
        let k = helmholtz(11);
        let s = schedule::fixed(&k, 7).unwrap();
        let mp = plan(
            &k,
            &s,
            true,
            8,
            &PlanOpts {
                sharing: false,
                partition_cap: None,
                fifo_depth: Some(64),
                cache: CacheScheme::Bypass,
            },
        );
        assert!(mp.fifos.iter().all(|&d| d == 64));
    }

    #[test]
    fn plans_are_deterministic() {
        let k = helmholtz(9);
        let a = flat_plan(&k, true, Some(3));
        let b = flat_plan(&k, true, Some(3));
        assert_eq!(a, b);
    }
}
