//! Mnemosyne: on-chip memory sharing (paper §3.5, Fig. 13/14d; Pilato et
//! al., IEEE TCAD 2017).
//!
//! Given the buffer compatibility graph exported by the compiler's
//! liveness analysis, assign temp buffers to physical banks so that
//! buffers with overlapping lifetimes never share a bank. This is
//! interval-graph coloring on the *conflict* graph (complement of the
//! compatibility graph); we color greedily in def order, which is optimal
//! for interval graphs (left-edge algorithm).
//!
//! The bank's physical size is the maximum word count of its residents —
//! the BRAM/URAM saving the paper reports for the 1-compute dataflow
//! implementation (BRAM −14.5%, URAM −48.3%, Table 3 "Mem Sharing").

use crate::ir::affine::{BufKind, Kernel};
use crate::ir::liveness::Liveness;

/// A physical bank shared by one or more temp buffers.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Bank {
    /// Buffer ids assigned to this bank (disjoint lifetimes).
    pub residents: Vec<usize>,
    /// Physical size = max resident words.
    pub words: usize,
}

/// Result of the sharing optimization.
#[derive(Debug, Clone)]
pub struct SharingPlan {
    /// bank id per buffer (None for inputs/outputs — not shared).
    pub bank_of: Vec<Option<usize>>,
    pub banks: Vec<Bank>,
}

impl SharingPlan {
    /// Words of on-chip storage for temps *without* sharing.
    pub fn unshared_words(&self, k: &Kernel) -> usize {
        k.temps().map(|(_, b)| b.words()).sum()
    }

    /// Words of on-chip storage for temps *with* sharing.
    pub fn shared_words(&self) -> usize {
        self.banks.iter().map(|b| b.words).sum()
    }

    /// Validate: residents of every bank are pairwise lifetime-disjoint.
    pub fn validate(&self, k: &Kernel, lv: &Liveness) -> Result<(), String> {
        for (bi, bank) in self.banks.iter().enumerate() {
            for (x, &i) in bank.residents.iter().enumerate() {
                if k.buffers[i].kind != BufKind::Temp {
                    return Err(format!("bank {bi} holds non-temp buffer {i}"));
                }
                for &j in &bank.residents[x + 1..] {
                    let (a, b) = match (&lv.intervals[i], &lv.intervals[j]) {
                        (Some(a), Some(b)) => (a, b),
                        _ => return Err(format!("bank {bi} holds unanalyzed buffer")),
                    };
                    if !a.disjoint(b) {
                        return Err(format!(
                            "bank {bi}: buffers {} and {} overlap",
                            k.buffers[i].name, k.buffers[j].name
                        ));
                    }
                }
                if k.buffers[i].words() > bank.words {
                    return Err(format!("bank {bi} smaller than resident {i}"));
                }
            }
        }
        // every temp must be placed exactly once
        for (i, b) in k.buffers.iter().enumerate() {
            let placed = self.bank_of[i].is_some();
            if (b.kind == BufKind::Temp) != placed {
                return Err(format!("buffer {} placement inconsistent", b.name));
            }
            if let Some(bk) = self.bank_of[i] {
                if !self.banks[bk].residents.contains(&i) {
                    return Err(format!("bank_of[{i}] not in bank residents"));
                }
            }
        }
        Ok(())
    }
}

/// Greedy left-edge bank assignment over temp-buffer lifetimes.
///
/// `scope`: optionally restrict sharing to buffers whose entire lifetime
/// falls inside one schedule group (the paper: "sharing opportunities can
/// operate only inside each subkernel", §3.6.4). Pass group (start, end)
/// nest ranges; buffers crossing a boundary get private banks.
pub fn share(k: &Kernel, lv: &Liveness, scope: Option<&[(usize, usize)]>) -> SharingPlan {
    let mut order: Vec<usize> = k
        .buffers
        .iter()
        .enumerate()
        .filter(|(i, b)| b.kind == BufKind::Temp && lv.intervals[*i].is_some())
        .map(|(i, _)| i)
        .collect();
    order.sort_by_key(|&i| lv.intervals[i].unwrap().def);

    // group id of a buffer's lifetime, or None if it crosses groups
    let group_of = |i: usize| -> Option<usize> {
        let iv = lv.intervals[i].unwrap();
        scope?.iter().position(|&(s, e)| iv.def >= s && iv.last_use < e)
    };

    let mut banks: Vec<Bank> = Vec::new();
    let mut bank_group: Vec<Option<usize>> = Vec::new();
    let mut bank_of: Vec<Option<usize>> = vec![None; k.buffers.len()];
    for &i in &order {
        let iv = lv.intervals[i].unwrap();
        let grp = group_of(i);
        let crosses = scope.is_some() && grp.is_none();
        let mut placed = false;
        if !crosses {
            for (bi, bank) in banks.iter_mut().enumerate() {
                if scope.is_some() && bank_group[bi] != grp {
                    continue;
                }
                let ok = bank.residents.iter().all(|&r| {
                    lv.intervals[r].unwrap().disjoint(&iv)
                });
                if ok {
                    bank.residents.push(i);
                    bank.words = bank.words.max(k.buffers[i].words());
                    bank_of[i] = Some(bi);
                    placed = true;
                    break;
                }
            }
        }
        if !placed {
            banks.push(Bank {
                residents: vec![i],
                words: k.buffers[i].words(),
            });
            bank_group.push(if crosses { None } else { grp });
            bank_of[i] = Some(banks.len() - 1);
        }
    }
    SharingPlan { bank_of, banks }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dsl;
    use crate::ir::{liveness, lower, rewrite, schedule, teil};
    use crate::util::prop;

    fn helmholtz(p: usize) -> Kernel {
        let prog = dsl::parse(&dsl::inverse_helmholtz_source(p)).unwrap();
        let m = rewrite::optimize(teil::from_ast(&prog).unwrap());
        lower::lower_kernel(&m, "helmholtz").unwrap()
    }

    #[test]
    fn sharing_reduces_words_on_flat_helmholtz() {
        // Paper Table 3: Mem Sharing cuts BRAM/URAM on the 1-compute
        // dataflow variant. Unscoped sharing == 1-compute case.
        let k = helmholtz(11);
        let lv = liveness::analyze(&k);
        let plan = share(&k, &lv, None);
        plan.validate(&k, &lv).unwrap();
        assert!(
            plan.shared_words() < plan.unshared_words(&k),
            "shared {} !< unshared {}",
            plan.shared_words(),
            plan.unshared_words(&k)
        );
    }

    #[test]
    fn per_group_scope_blocks_cross_stage_sharing() {
        // Paper §4.2: sharing "cannot be applied to the 2/3/7-compute
        // implementations because each compute module only uses arrays
        // that cannot be shared".
        let k = helmholtz(11);
        let lv = liveness::analyze(&k);
        let s = schedule::fixed(&k, 7).unwrap();
        let ranges: Vec<(usize, usize)> =
            s.groups.iter().map(|g| (g.start, g.end)).collect();
        let plan = share(&k, &lv, Some(&ranges));
        plan.validate(&k, &lv).unwrap();
        // all banks private -> no saving
        assert_eq!(plan.shared_words(), plan.unshared_words(&k));
    }

    #[test]
    fn bank_count_leq_buffer_count() {
        let k = helmholtz(7);
        let lv = liveness::analyze(&k);
        let plan = share(&k, &lv, None);
        assert!(plan.banks.len() <= k.temps().count());
        assert!(plan.banks.len() >= 1);
    }

    #[test]
    fn property_no_bank_holds_overlapping_lifetimes() {
        // random kernels: random chain of contraction nests over random
        // temp usage is hard to fabricate; instead randomize p and groups
        prop::check("mnemosyne soundness", 16, |rng| {
            let p = rng.range_usize(2, 9);
            let k = helmholtz(p);
            let lv = liveness::analyze(&k);
            let scoped = rng.bool();
            let plan = if scoped {
                let n = rng.range_usize(1, k.nests.len());
                let s = schedule::fixed(&k, n).unwrap();
                let ranges: Vec<(usize, usize)> =
                    s.groups.iter().map(|g| (g.start, g.end)).collect();
                share(&k, &lv, Some(&ranges))
            } else {
                share(&k, &lv, None)
            };
            plan.validate(&k, &lv).map_err(|e| e.to_string())
        });
    }

    #[test]
    fn savings_ratio_is_substantial_for_p11() {
        // 4 temp intermediates of p^3 + t + r (p^3) collapse markedly.
        let k = helmholtz(11);
        let lv = liveness::analyze(&k);
        let plan = share(&k, &lv, None);
        let ratio = plan.shared_words() as f64 / plan.unshared_words(&k) as f64;
        assert!(ratio < 0.7, "ratio {ratio}");
    }
}
