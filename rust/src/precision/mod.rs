//! Fixed-point precision exploration (the paper's base2 dialect plus the
//! §3.4.5 / §5 future-work item: "coupling the compiler with exploration
//! frameworks [49, 8]" for custom number formats).
//!
//! Two analyses over the teil module:
//!
//!  * **Range analysis** (interval arithmetic): propagates value bounds
//!    from the input domain through every op. The integer bit width of a
//!    candidate `ap_fixed` format must cover the widest intermediate —
//!    this is what saturated naive Q8.24 runs before the workload's S
//!    rescaling (see coordinator::workload).
//!  * **Noise analysis**: propagates quantization noise power (step²/12
//!    injected at every operator output, amplified by contraction gains)
//!    to predict the output MSE of a format — the quantity the paper
//!    reports (9.39e-22 / 3.58e-12).
//!
//! `explore` walks total widths and splits, keeps formats whose predicted
//! range and MSE meet the budget, and ranks them by estimated DSP cost,
//! producing the accuracy-vs-cost frontier the designer chooses from
//! (paper: "It is up to the application designer to determine what an
//! acceptable error is").

use crate::ir::teil::{Module, Op};

/// Closed interval bound.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Interval {
    pub lo: f64,
    pub hi: f64,
}

impl Interval {
    pub fn new(lo: f64, hi: f64) -> Interval {
        assert!(lo <= hi);
        Interval { lo, hi }
    }

    pub fn symmetric(a: f64) -> Interval {
        Interval::new(-a.abs(), a.abs())
    }

    pub fn max_abs(&self) -> f64 {
        self.lo.abs().max(self.hi.abs())
    }

    fn add(&self, o: &Interval) -> Interval {
        Interval::new(self.lo + o.lo, self.hi + o.hi)
    }

    fn sub(&self, o: &Interval) -> Interval {
        Interval::new(self.lo - o.hi, self.hi - o.lo)
    }

    fn mul(&self, o: &Interval) -> Interval {
        let c = [
            self.lo * o.lo,
            self.lo * o.hi,
            self.hi * o.lo,
            self.hi * o.hi,
        ];
        Interval::new(
            c.iter().copied().fold(f64::INFINITY, f64::min),
            c.iter().copied().fold(f64::NEG_INFINITY, f64::max),
        )
    }

    fn scale(&self, k: f64) -> Interval {
        assert!(k >= 0.0);
        Interval::new(self.lo * k, self.hi * k).union_sym()
    }

    fn union_sym(self) -> Interval {
        // contraction sums of signed terms are symmetric
        Interval::symmetric(self.max_abs())
    }
}

/// Result of range analysis: per-value bounds plus the global max.
#[derive(Debug, Clone)]
pub struct RangeAnalysis {
    pub per_value: Vec<Interval>,
    pub max_abs: f64,
}

/// Propagate input intervals through the module. `input_range` applies
/// to every Arg (the paper rescales all physical data into [-1, 1]).
pub fn analyze_ranges(m: &Module, input_range: Interval) -> RangeAnalysis {
    let mut iv: Vec<Interval> = Vec::with_capacity(m.values.len());
    for v in &m.values {
        let r = match &v.op {
            Op::Arg { .. } => input_range,
            Op::Add { a, b } => iv[*a].add(&iv[*b]),
            Op::Sub { a, b } => iv[*a].sub(&iv[*b]),
            Op::Mul { a, b } | Op::Prod { a, b } => iv[*a].mul(&iv[*b]),
            Op::Div { a, b } => {
                // conservative: assume |denominator| >= 1 is NOT known;
                // division by an interval containing 0 is unbounded.
                let d = iv[*b];
                if d.lo <= 0.0 && d.hi >= 0.0 {
                    Interval::symmetric(f64::INFINITY)
                } else {
                    let inv = Interval::new(1.0 / d.hi, 1.0 / d.lo);
                    iv[*a].mul(&inv)
                }
            }
            Op::Diag { x, .. } | Op::MoveAxis { x, .. } => iv[*x],
            Op::Red { x, axis } => {
                // sum of `extent` signed terms
                let extent = m.shape(*x)[*axis] as f64;
                iv[*x].scale(extent)
            }
            Op::ModeApply { m: mat, x, .. } => {
                // |out| <= k * max|m| * max|x| over the contracted extent
                let k = m.shape(*mat)[1] as f64;
                iv[*mat].mul(&iv[*x]).scale(k)
            }
        };
        iv.push(r);
    }
    let max_abs = m
        .defs
        .iter()
        .map(|d| iv[d.value].max_abs())
        .chain(iv.iter().map(|i| i.max_abs()))
        .fold(0.0, f64::max);
    RangeAnalysis {
        per_value: iv,
        max_abs,
    }
}

/// Predict the output MSE of quantizing every operator output to a grid
/// with `frac_bits` fractional bits (operator-granularity rounding, the
/// same policy as python/compile/kernels/quant.py).
pub fn predict_mse(m: &Module, frac_bits: u32) -> f64 {
    let step = (2.0f64).powi(-(frac_bits as i32));
    let q = step * step / 12.0; // one rounding's noise power
    // noise power per value, propagated with contraction gains
    let mut noise: Vec<f64> = Vec::with_capacity(m.values.len());
    for v in &m.values {
        let n = match &v.op {
            Op::Arg { .. } => q, // inputs are quantized once
            Op::Add { a, b } | Op::Sub { a, b } => noise[*a] + noise[*b] + q,
            // |x|,|y| <= 1 in the rescaled domain: var(xy) noise ~
            // n_a * E[y^2] + n_b * E[x^2] <= n_a + n_b
            Op::Mul { a, b } | Op::Prod { a, b } => noise[*a] + noise[*b] + q,
            Op::Div { a, b } => noise[*a] + noise[*b] + q,
            Op::Diag { x, .. } | Op::MoveAxis { x, .. } => noise[*x],
            Op::Red { x, axis } => {
                let extent = m.shape(*x)[*axis] as f64;
                extent * noise[*x] + q
            }
            Op::ModeApply { m: mat, x, .. } => {
                // sum over k products: k * (n_mat + n_x) + one rounding.
                // In the rescaled domain each product term has |.| <= 1/k
                // (operator rows are O(1)), so noise does not amplify
                // beyond the term count.
                let k = m.shape(*mat)[1] as f64;
                k * (noise[*mat] / k + noise[*x] / k) + q
            }
        };
        noise.push(n);
    }
    m.outputs()
        .map(|d| noise[d.value])
        .fold(0.0, f64::max)
}

/// A candidate fixed-point format.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Candidate {
    pub int_bits: u32,
    pub frac_bits: u32,
    pub predicted_mse: f64,
    /// DSP cost of one multiplier at this width (UltraScale+ granularity:
    /// one DSP48 per started 16x16 partial-product tile... modeled as
    /// ceil(w/16)^2 ).
    pub dsp_per_mult: u32,
}

impl Candidate {
    pub fn total_bits(&self) -> u32 {
        self.int_bits + self.frac_bits
    }

    pub fn name(&self) -> String {
        format!("ap_fixed<{}, {}>", self.total_bits(), self.int_bits)
    }
}

/// Explore fixed-point formats for a module: every format whose integer
/// part covers the analyzed range and whose predicted MSE meets
/// `mse_budget`, ranked by multiplier cost then accuracy.
pub fn explore(
    m: &Module,
    input_range: Interval,
    mse_budget: f64,
    max_total_bits: u32,
) -> Vec<Candidate> {
    let ranges = analyze_ranges(m, input_range);
    // +1 sign bit; ranges are symmetric
    let int_needed = (ranges.max_abs.log2().ceil().max(0.0) as u32) + 1;
    let mut out = Vec::new();
    for total in 8..=max_total_bits {
        if total <= int_needed {
            continue;
        }
        let frac = total - int_needed;
        let mse = predict_mse(m, frac);
        if mse <= mse_budget {
            let tiles = total.div_ceil(16);
            out.push(Candidate {
                int_bits: int_needed,
                frac_bits: frac,
                predicted_mse: mse,
                dsp_per_mult: tiles * tiles,
            });
        }
    }
    out.sort_by(|a, b| {
        (a.dsp_per_mult, a.total_bits())
            .cmp(&(b.dsp_per_mult, b.total_bits()))
    });
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dsl;
    use crate::ir::{rewrite, teil};

    fn helmholtz(p: usize) -> Module {
        let prog = dsl::parse(&dsl::inverse_helmholtz_source(p)).unwrap();
        rewrite::optimize(teil::from_ast(&prog).unwrap())
    }

    #[test]
    fn unit_inputs_with_unit_operators_blow_up_by_p_cubed() {
        // with raw [-1,1] inputs the contractions amplify by p per mode:
        // |v| <= p^3 * p^3 = p^6 across both contraction chains
        let m = helmholtz(4);
        let r = analyze_ranges(&m, Interval::symmetric(1.0));
        assert!(r.max_abs >= 4096.0, "got {}", r.max_abs); // 4^6
        assert!(r.max_abs.is_finite());
    }

    #[test]
    fn rescaled_operator_rows_keep_range_bounded() {
        // the workload's S-scaling (entries ~ 1/p) keeps |t|,|v| <= 1;
        // model it as input range 1/p for the matrix factor by analyzing
        // with inputs in [-1/p, 1/p]: every product of three S entries
        // and u stays within p^3 * (1/p)^3 = 1 per contraction.
        let p = 4;
        let m = helmholtz(p);
        let r = analyze_ranges(&m, Interval::symmetric(1.0 / p as f64));
        // u is also scaled here, so the bound is conservative but finite
        // and small
        assert!(r.max_abs <= 2.0, "got {}", r.max_abs);
    }

    #[test]
    fn predicted_mse_tracks_grid_squared() {
        let m = helmholtz(7);
        let a = predict_mse(&m, 24);
        let b = predict_mse(&m, 40);
        // ratio ~ (2^-24 / 2^-40)^2 = 2^32
        let ratio = a / b;
        assert!(
            (2f64.powi(30)..2f64.powi(34)).contains(&ratio),
            "ratio {ratio}"
        );
        // fx32-scale prediction lands in the measured magnitude band
        assert!((1e-17..1e-12).contains(&a), "fx32-ish mse {a}");
    }

    #[test]
    fn explore_produces_sorted_feasible_frontier() {
        let m = helmholtz(11);
        let cands = explore(&m, Interval::symmetric(1.0 / 11.0), 1e-10, 64);
        assert!(!cands.is_empty());
        // sorted by DSP cost
        for w in cands.windows(2) {
            assert!(w[0].dsp_per_mult <= w[1].dsp_per_mult);
        }
        // every candidate meets the budget and covers the range
        for c in &cands {
            assert!(c.predicted_mse <= 1e-10);
            assert!(c.int_bits >= 1);
            assert!(c.name().starts_with("ap_fixed<"));
        }
        // a tighter budget shrinks (or keeps) the set
        let tight = explore(&m, Interval::symmetric(1.0 / 11.0), 1e-20, 64);
        assert!(tight.len() <= cands.len());
        // the paper's Q8.24-scale format is feasible for its 3.58e-12 MSE
        let loose = explore(&m, Interval::symmetric(1.0 / 11.0), 3.6e-12, 32);
        assert!(
            loose.iter().any(|c| c.total_bits() <= 32),
            "a 32-bit format must satisfy the paper's own fx32 MSE"
        );
    }

    #[test]
    fn division_by_zero_interval_is_unbounded() {
        let src = "var input a : [2]\nvar input b : [2]\nvar output c : [2]\nc = a / b";
        let prog = dsl::parse(src).unwrap();
        let m = teil::from_ast(&prog).unwrap();
        let r = analyze_ranges(&m, Interval::symmetric(1.0));
        assert!(r.max_abs.is_infinite());
    }

    #[test]
    fn interval_arithmetic_basics() {
        let a = Interval::new(-1.0, 2.0);
        let b = Interval::new(0.5, 3.0);
        assert_eq!(a.add(&b), Interval::new(-0.5, 5.0));
        assert_eq!(a.sub(&b), Interval::new(-4.0, 1.5));
        let m = a.mul(&b);
        assert_eq!(m, Interval::new(-3.0, 6.0));
        assert_eq!(Interval::symmetric(-2.0).max_abs(), 2.0);
    }
}
