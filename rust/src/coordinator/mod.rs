//! L3 coordinator: the runtime counterpart of the Olympus-generated host
//! program (paper §3.1, §3.5).
//!
//! The coordinator owns batching (N_b = N_eq / E, I = N_b / N_cu),
//! the ping/pong double-buffer state machine, lane interleaving, and
//! dispatch of real numerics through the PJRT runtime. Performance
//! numbers for the FPGA come from `sim`; the coordinator produces the
//! *numerical* results (and the measured XLA-CPU throughput used by the
//! Fig. 19 software baselines).

pub mod batch;
pub mod driver;
pub mod workload;

pub use batch::{BatchPlan, PingPong};
pub use driver::{run_gradient, run_interpolation, Driver, RunReport};
pub use workload::{GradientWorkload, HelmholtzWorkload, InterpolationWorkload};
