//! L3 coordinator: the runtime counterpart of the Olympus-generated host
//! program (paper §3.1, §3.5).
//!
//! Where `sim` predicts how fast the generated system *would* run on
//! the U280, the coordinator actually *runs* it: real numerics through
//! the AOT-compiled PJRT artifacts, following the same host steps
//! Olympus emits (`olympus::config::host_batch_steps`). Three layers:
//!
//!  * [`batch`] — the batching arithmetic the paper fixes per system
//!    (N_b = N_eq / E batches of E elements, dealt round-robin over
//!    N_cu CUs) plus the ping/pong double-buffer state machine
//!    ([`PingPong`]) and the lane interleave/deinterleave permutations
//!    of §3.6.2, validated by round-trip.
//!  * [`workload`] — deterministic synthetic workloads for the three
//!    published kernels (Helmholtz, Interpolation, Gradient), each with
//!    a native f64 oracle (`expected_element`) for MSE cross-checks —
//!    plus [`GenericWorkload`], the front-door counterpart: seeded
//!    inputs derived from any program's declared shapes and a
//!    `teil::eval` oracle against the lowered kernel (`ir::interp`),
//!    so user `.cfd` kernels get MSE cross-checks with no hand-written
//!    closed form.
//!  * [`driver`] — executes a workload against a `SystemSpec`:
//!    interleave → transfer → invoke per CU with ping/pong bookkeeping →
//!    de-interleave, chunked to the artifact's executable batch size.
//!    Returns a [`RunReport`] with measured XLA-CPU GFLOPS (the Fig. 19
//!    software-comparison datapath) and sampled MSE against the oracle
//!    (the *measured* Fig. 16 / Table 4 numerics).
//!
//! Host transfers here are memcpys into PJRT literals — the PCIe cost
//! they stand in for is modeled by `sim::event`, which mirrors the
//! independent per-direction queues this driver issues its
//! `TransferIn`/`TransferOut` steps on. Everything degrades gracefully
//! when the artifacts or the `pjrt` feature are absent: `Runtime`
//! construction fails and callers skip.

pub mod batch;
pub mod driver;
pub mod workload;

pub use batch::{BatchPlan, PingPong};
pub use driver::{run_gradient, run_interpolation, Driver, RunReport};
pub use workload::{
    GenericWorkload, GradientWorkload, HelmholtzWorkload, InterpolationWorkload,
    OracleCheck,
};
