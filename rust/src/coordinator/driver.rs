//! The runtime driver: execute a workload's real numerics through the
//! PJRT artifacts, following the Olympus host program (interleave →
//! transfer → invoke per CU with ping/pong bookkeeping → de-interleave).

use std::time::Instant;

use anyhow::{anyhow, Result};

use super::batch::{deinterleave, interleave, BatchPlan, PingPong};
use super::workload::HelmholtzWorkload;
use crate::olympus::SystemSpec;
use crate::runtime::Runtime;

/// Outcome of a real-numerics run.
#[derive(Debug, Clone)]
pub struct RunReport {
    pub artifact: String,
    pub elements: u64,
    pub invocations: u64,
    pub wall_s: f64,
    /// Measured XLA-CPU throughput of the datapath.
    pub measured_gflops: f64,
    /// Mean squared error vs the f64 native oracle (sampled elements).
    pub mse_vs_oracle: f64,
    pub max_abs_err: f64,
    /// Per-CU element counts (round-robin bookkeeping).
    pub per_cu_elements: Vec<u64>,
    /// Ping/pong phases used per CU (for state-machine validation).
    pub phases_used: Vec<Vec<usize>>,
    /// The flattened outputs (v tensors, element-major).
    pub outputs: Vec<f64>,
}

/// Drives a `SystemSpec` with real numerics.
pub struct Driver<'rt> {
    pub runtime: &'rt mut Runtime,
    pub spec: SystemSpec,
    pub artifact: String,
}

impl<'rt> Driver<'rt> {
    pub fn new(
        runtime: &'rt mut Runtime,
        spec: SystemSpec,
        artifact: impl Into<String>,
    ) -> Driver<'rt> {
        Driver {
            runtime,
            spec,
            artifact: artifact.into(),
        }
    }

    /// Pick the matching artifact for a spec, preferring the §Perf
    /// batch-blocked variant when it exists.
    pub fn artifact_for(runtime: &Runtime, spec: &SystemSpec, p: usize) -> Result<String> {
        let m = &runtime.manifest;
        m.find(&spec.kernel.name, p, spec.dtype.name(), "pallas_blocked")
            .or_else(|| m.find(&spec.kernel.name, p, spec.dtype.name(), "pallas"))
            .map(|a| a.name.clone())
            .ok_or_else(|| {
                anyhow!(
                    "no artifact for kernel={} p={p} dtype={}; run `make artifacts`",
                    spec.kernel.name,
                    spec.dtype.name()
                )
            })
    }

    /// Execute the workload. `oracle_sample` bounds how many elements are
    /// cross-checked against the native oracle (it is O(p^4) per element).
    pub fn run(
        &mut self,
        w: &HelmholtzWorkload,
        oracle_sample: usize,
    ) -> Result<RunReport> {
        let meta = self
            .runtime
            .meta(&self.artifact)
            .ok_or_else(|| anyhow!("unknown artifact {}", self.artifact))?
            .clone();
        if meta.p != w.p {
            return Err(anyhow!(
                "artifact p={} but workload p={}",
                meta.p,
                w.p
            ));
        }
        let exec_batch = meta.batch;
        let block = w.block();
        let plan = BatchPlan::new(&self.spec, w.n_elements as u64, exec_batch);
        plan.validate().map_err(|e| anyhow!(e))?;
        let mut pp = PingPong::new(self.spec.num_cus);
        let mut per_cu_elements = vec![0u64; self.spec.num_cus];
        let mut phases_used = vec![Vec::new(); self.spec.num_cus];
        let mut outputs = vec![0.0f64; w.n_elements * block];
        let lanes = self.spec.lanes;
        let s_flat = w.s.data().to_vec();

        let mut invocations = 0u64;
        let t0 = Instant::now();
        for b in 0..plan.n_batches {
            let cu = plan.cu_of(b);
            let phase = pp.advance(cu);
            phases_used[cu].push(phase);
            let (start, end) = plan
                .element_range(b)
                .ok_or_else(|| anyhow!("batch {b} out of range"))?;
            per_cu_elements[cu] += end - start;

            // Olympus host step: interleave the batch across lanes.
            // (The executable computes per-element results independent of
            // order; interleave/deinterleave mirror the generated host
            // code and are validated by the round-trip. A ragged tail
            // batch is padded to the lane boundary by interleave itself.)
            let n_batch = (end - start) as usize;
            let d_il = interleave(
                &w.d[start as usize * block..end as usize * block],
                block,
                lanes,
            );
            let u_il = interleave(
                &w.u[start as usize * block..end as usize * block],
                block,
                lanes,
            );
            let aligned = d_il.len() / block;

            // invoke the CU in executable-batch chunks. Full chunks pass
            // slices straight out of the interleaved image (§Perf: no
            // per-invocation scratch copy); only a short tail pads.
            let mut out_il = vec![0.0; aligned * block];
            let mut e0 = 0usize;
            let mut d_pad: Vec<f64> = Vec::new();
            let mut u_pad: Vec<f64> = Vec::new();
            while e0 < aligned {
                let chunk = exec_batch.min(aligned - e0);
                let range = e0 * block..(e0 + chunk) * block;
                let outs = if chunk == exec_batch {
                    self.runtime.run_f64_slices(
                        &self.artifact,
                        &[&s_flat, &d_il[range.clone()], &u_il[range.clone()]],
                    )?
                } else {
                    d_pad.clear();
                    d_pad.resize(exec_batch * block, 0.0);
                    u_pad.clear();
                    u_pad.resize(exec_batch * block, 0.0);
                    d_pad[..chunk * block].copy_from_slice(&d_il[range.clone()]);
                    u_pad[..chunk * block].copy_from_slice(&u_il[range.clone()]);
                    self.runtime
                        .run_f64_slices(&self.artifact, &[&s_flat, &d_pad, &u_pad])?
                };
                invocations += 1;
                out_il[range].copy_from_slice(&outs[0][..chunk * block]);
                e0 += chunk;
            }
            let out_b = deinterleave(&out_il, block, lanes);
            outputs[start as usize * block..end as usize * block]
                .copy_from_slice(&out_b[..n_batch * block]);
        }
        let wall_s = t0.elapsed().as_secs_f64();

        // sampled oracle cross-check
        let sample = oracle_sample.min(w.n_elements);
        let mut se = 0.0f64;
        let mut max_err = 0.0f64;
        let mut count = 0u64;
        for e in 0..sample {
            let want = self.spec_expected(w, e);
            for (i, &x) in want.iter().enumerate() {
                let got = outputs[e * block + i];
                let err = got - x;
                se += err * err;
                max_err = max_err.max(err.abs());
                count += 1;
            }
        }
        let mse = if count > 0 { se / count as f64 } else { 0.0 };

        let flops = w.n_elements as u64 * meta.flops_per_element;
        Ok(RunReport {
            artifact: self.artifact.clone(),
            elements: w.n_elements as u64,
            invocations,
            wall_s,
            measured_gflops: flops as f64 / wall_s / 1e9,
            mse_vs_oracle: mse,
            max_abs_err: max_err,
            per_cu_elements,
            phases_used,
            outputs,
        })
    }

    /// Oracle value of element `e` in f64 (the fixed-point MSE baseline).
    fn spec_expected(&self, w: &HelmholtzWorkload, e: usize) -> Vec<f64> {
        w.expected_element(e).into_data()
    }
}

/// Execute an Interpolation workload through its artifact. Returns
/// (flattened outputs, MSE vs oracle over `oracle_sample` elements).
pub fn run_interpolation(
    rt: &mut Runtime,
    w: &super::workload::InterpolationWorkload,
    oracle_sample: usize,
) -> Result<(Vec<f64>, f64)> {
    let meta = rt
        .manifest
        .find("interpolation", w.n, "f64", "pallas")
        .ok_or_else(|| anyhow!("no interpolation artifact"))?
        .clone();
    let b = meta.batch;
    let (ib, ob) = (w.in_block(), w.out_block());
    let a_flat = w.a.data().to_vec();
    let mut out = vec![0.0; w.n_elements * ob];
    let mut e0 = 0usize;
    while e0 < w.n_elements {
        let chunk = b.min(w.n_elements - e0);
        let mut u_c = vec![0.0; b * ib];
        u_c[..chunk * ib].copy_from_slice(&w.u[e0 * ib..(e0 + chunk) * ib]);
        let outs = rt.run_f64(&meta.name, &[a_flat.clone(), u_c])?;
        out[e0 * ob..(e0 + chunk) * ob].copy_from_slice(&outs[0][..chunk * ob]);
        e0 += chunk;
    }
    let mut se = 0.0;
    let mut count = 0u64;
    for e in 0..oracle_sample.min(w.n_elements) {
        let want = w.expected_element(e);
        for (i, &x) in want.data().iter().enumerate() {
            let d = out[e * ob + i] - x;
            se += d * d;
            count += 1;
        }
    }
    Ok((out, if count > 0 { se / count as f64 } else { 0.0 }))
}

/// Execute a Gradient workload through its artifact. Returns the three
/// flattened gradients and the MSE vs oracle.
pub fn run_gradient(
    rt: &mut Runtime,
    w: &super::workload::GradientWorkload,
    oracle_sample: usize,
) -> Result<([Vec<f64>; 3], f64)> {
    let (nx, _, _) = w.dims;
    let meta = rt
        .manifest
        .find("gradient", nx, "f64", "pallas")
        .ok_or_else(|| anyhow!("no gradient artifact"))?
        .clone();
    let b = meta.batch;
    let blk = w.block();
    let mats: Vec<Vec<f64>> = vec![
        w.dx.data().to_vec(),
        w.dy.data().to_vec(),
        w.dz.data().to_vec(),
    ];
    let mut out = [
        vec![0.0; w.n_elements * blk],
        vec![0.0; w.n_elements * blk],
        vec![0.0; w.n_elements * blk],
    ];
    let mut e0 = 0usize;
    while e0 < w.n_elements {
        let chunk = b.min(w.n_elements - e0);
        let mut u_c = vec![0.0; b * blk];
        u_c[..chunk * blk].copy_from_slice(&w.u[e0 * blk..(e0 + chunk) * blk]);
        let outs = rt.run_f64(
            &meta.name,
            &[mats[0].clone(), mats[1].clone(), mats[2].clone(), u_c],
        )?;
        for (g, o) in out.iter_mut().zip(&outs) {
            g[e0 * blk..(e0 + chunk) * blk].copy_from_slice(&o[..chunk * blk]);
        }
        e0 += chunk;
    }
    let mut se = 0.0;
    let mut count = 0u64;
    for e in 0..oracle_sample.min(w.n_elements) {
        let wants = w.expected_element(e);
        for (g, want) in out.iter().zip(&wants) {
            for (i, &x) in want.data().iter().enumerate() {
                let d = g[e * blk + i] - x;
                se += d * d;
                count += 1;
            }
        }
    }
    Ok((out, if count > 0 { se / count as f64 } else { 0.0 }))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datatype::DataType;
    use crate::flow::Flow;
    use crate::kernels::KernelSource;
    use crate::olympus::OlympusOpts;
    use crate::platform::Platform;

    fn spec(opts: OlympusOpts, p: usize) -> SystemSpec {
        // the driver consumes systems produced by the flow pipeline —
        // the tests build theirs the same way
        Flow::from_source(KernelSource::builtin("helmholtz"))
            .parse(p)
            .unwrap()
            .lower()
            .unwrap()
            .map(&opts, &Platform::alveo_u280())
            .unwrap()
            .spec
    }

    fn runtime() -> Option<Runtime> {
        Runtime::from_default_dir().ok()
    }

    #[test]
    fn f64_run_matches_oracle_to_float_precision() {
        let Some(mut rt) = runtime() else {
            eprintln!("artifacts missing; skipping");
            return;
        };
        let s = spec(OlympusOpts::dataflow(7), 7);
        let name = Driver::artifact_for(&rt, &s, 7).unwrap();
        let w = HelmholtzWorkload::generate(7, 100, 5);
        let mut d = Driver::new(&mut rt, s, name);
        let r = d.run(&w, 20).unwrap();
        assert!(r.mse_vs_oracle < 1e-24, "mse {}", r.mse_vs_oracle);
        assert!(r.max_abs_err < 1e-10);
        assert_eq!(r.elements, 100);
        assert!(r.measured_gflops > 0.0);
    }

    #[test]
    fn fx32_run_reproduces_paper_mse_scale() {
        // Paper §4.2: Fixed Point 32 MSE = 3.58e-12 (vs double).
        let Some(mut rt) = runtime() else { return };
        let s = spec(OlympusOpts::fixed_point(DataType::Fx32), 11);
        let name = Driver::artifact_for(&rt, &s, 11).unwrap();
        let w = HelmholtzWorkload::generate(11, 32, 6);
        let mut d = Driver::new(&mut rt, s, name);
        let r = d.run(&w, 16).unwrap();
        assert!(
            (1e-16..1e-9).contains(&r.mse_vs_oracle),
            "fx32 mse {}",
            r.mse_vs_oracle
        );
    }

    #[test]
    fn fx64_mse_is_far_smaller_than_fx32() {
        let Some(mut rt) = runtime() else { return };
        let w = HelmholtzWorkload::generate(11, 32, 7);
        let s64 = spec(OlympusOpts::fixed_point(DataType::Fx64), 11);
        let n64 = Driver::artifact_for(&rt, &s64, 11).unwrap();
        let m64 = Driver::new(&mut rt, s64, n64).run(&w, 8).unwrap().mse_vs_oracle;
        let s32 = spec(OlympusOpts::fixed_point(DataType::Fx32), 11);
        let n32 = Driver::artifact_for(&rt, &s32, 11).unwrap();
        let m32 = Driver::new(&mut rt, s32, n32).run(&w, 8).unwrap().mse_vs_oracle;
        assert!(m64 > 0.0 && m32 > 0.0);
        let ratio = m32 / m64;
        assert!(
            ratio > 1e6,
            "paper ratio ~2^32; got fx32 {m32} / fx64 {m64} = {ratio}"
        );
    }

    #[test]
    fn multi_cu_round_robin_and_pingpong() {
        let Some(mut rt) = runtime() else { return };
        let s = spec(OlympusOpts::dataflow(7).with_cus(2), 7);
        let name = Driver::artifact_for(&rt, &s, 7).unwrap();
        // force several batches: shrink batch size via a small workload
        // relative to E is impractical (E is ~14k), so run one batch per
        // CU instead and validate bookkeeping.
        let w = HelmholtzWorkload::generate(7, 64, 8);
        let mut d = Driver::new(&mut rt, s, name);
        let r = d.run(&w, 4).unwrap();
        assert_eq!(r.per_cu_elements.iter().sum::<u64>(), 64);
        // every used phase strictly alternates per CU
        for phases in &r.phases_used {
            for (i, &ph) in phases.iter().enumerate() {
                assert_eq!(ph, i % 2);
            }
        }
    }

    #[test]
    fn interpolation_workload_runs_and_matches_oracle() {
        let Some(mut rt) = runtime() else { return };
        let w = crate::coordinator::workload::InterpolationWorkload::generate(
            11, 11, 70, 12,
        );
        let (out, mse) = run_interpolation(&mut rt, &w, 16).unwrap();
        assert_eq!(out.len(), 70 * 11 * 11 * 11);
        assert!(mse < 1e-24, "mse {mse}");
    }

    #[test]
    fn gradient_workload_runs_and_matches_oracle() {
        let Some(mut rt) = runtime() else { return };
        let w = crate::coordinator::workload::GradientWorkload::generate(
            (8, 7, 6),
            50,
            13,
        );
        let (out, mse) = run_gradient(&mut rt, &w, 16).unwrap();
        assert_eq!(out[0].len(), 50 * 336);
        assert!(mse < 1e-24, "mse {mse}");
    }

    #[test]
    fn artifact_p_mismatch_is_rejected() {
        let Some(mut rt) = runtime() else { return };
        let s = spec(OlympusOpts::dataflow(7), 7);
        let w = HelmholtzWorkload::generate(11, 8, 9);
        let mut d = Driver::new(&mut rt, s, "helmholtz_p7_f64_b8");
        assert!(d.run(&w, 1).is_err());
    }
}
