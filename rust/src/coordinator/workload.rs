//! Workload generation: CFD-like element data.
//!
//! The paper simulates N_eq = 2,000,000 independent spectral elements
//! with physical quantities rescaled into [-1, 1] (§3.6.4). We generate
//! synthetic elements in that domain with a seeded PRNG; the S matrix is
//! a dense spectral operator shared by all elements.
//!
//! The three named workloads (Helmholtz, Interpolation, Gradient) carry
//! hand-written closed-form oracles for the published trio.
//! [`GenericWorkload`] replaces that pattern for *arbitrary* front-door
//! programs: it derives seeded random inputs from a program's declared
//! input shapes and cross-checks the lowered affine kernel
//! (`ir::interp`) against `teil::eval` of the rewritten module — an
//! oracle that exists for every kernel the DSL accepts.

use std::collections::HashMap;

use crate::ir::affine::Kernel;
use crate::ir::interp;
use crate::ir::teil::{self, Module};
use crate::kernels::KernelSource;
use crate::util::prng::Prng;
use crate::util::tensor::Tensor;

/// A Helmholtz workload: shared S plus per-element D, u.
#[derive(Debug, Clone)]
pub struct HelmholtzWorkload {
    pub p: usize,
    pub n_elements: usize,
    /// (p, p) operator matrix.
    pub s: Tensor,
    /// (n, p^3) flattened Hadamard factors.
    pub d: Vec<f64>,
    /// (n, p^3) flattened inputs.
    pub u: Vec<f64>,
}

impl HelmholtzWorkload {
    pub fn generate(p: usize, n_elements: usize, seed: u64) -> HelmholtzWorkload {
        let mut rng = Prng::new(seed);
        // SEM spectral operators are near-orthonormal: row sums are O(1).
        // Scaling entries by 1/p keeps every intermediate (t, r, v) inside
        // [-1, 1] — the rescaled domain the paper's fixed-point formats
        // assume (§3.6.4). Unscaled random S would saturate Q8.24.
        let mut s = Tensor::random(&[p, p], &mut rng);
        for x in s.data_mut() {
            *x /= p as f64;
        }
        let block = p * p * p;
        HelmholtzWorkload {
            p,
            n_elements,
            s,
            d: rng.unit_vec(n_elements * block),
            u: rng.unit_vec(n_elements * block),
        }
    }

    pub fn block(&self) -> usize {
        self.p * self.p * self.p
    }

    /// Per-element view of D.
    pub fn d_element(&self, e: usize) -> &[f64] {
        let b = self.block();
        &self.d[e * b..(e + 1) * b]
    }

    pub fn u_element(&self, e: usize) -> &[f64] {
        let b = self.block();
        &self.u[e * b..(e + 1) * b]
    }

    /// Exact result for element `e` via the native oracle (Eq. 1a-1c).
    pub fn expected_element(&self, e: usize) -> Tensor {
        let p = self.p;
        let d = Tensor::from_vec(&[p, p, p], self.d_element(e).to_vec());
        let u = Tensor::from_vec(&[p, p, p], self.u_element(e).to_vec());
        let t = u
            .mode_apply(&self.s, 0)
            .mode_apply(&self.s, 1)
            .mode_apply(&self.s, 2);
        let r = d.zip(&t, |a, b| a * b);
        let st = self.s.transposed();
        r.mode_apply(&st, 0).mode_apply(&st, 1).mode_apply(&st, 2)
    }
}

/// An Interpolation workload: shared A plus per-element u (paper §4.3).
#[derive(Debug, Clone)]
pub struct InterpolationWorkload {
    pub m: usize,
    pub n: usize,
    pub n_elements: usize,
    pub a: Tensor,
    /// (n_elements, n^3) flattened inputs.
    pub u: Vec<f64>,
}

impl InterpolationWorkload {
    pub fn generate(m: usize, n: usize, n_elements: usize, seed: u64) -> Self {
        let mut rng = Prng::new(seed);
        let mut a = Tensor::random(&[m, n], &mut rng);
        for x in a.data_mut() {
            *x /= n as f64; // near-orthonormal interpolation operator
        }
        InterpolationWorkload {
            m,
            n,
            n_elements,
            a,
            u: rng.unit_vec(n_elements * n * n * n),
        }
    }

    pub fn in_block(&self) -> usize {
        self.n * self.n * self.n
    }

    pub fn out_block(&self) -> usize {
        self.m * self.m * self.m
    }

    pub fn u_element(&self, e: usize) -> &[f64] {
        let b = self.in_block();
        &self.u[e * b..(e + 1) * b]
    }

    pub fn expected_element(&self, e: usize) -> Tensor {
        let n = self.n;
        let u = Tensor::from_vec(&[n, n, n], self.u_element(e).to_vec());
        u.mode_apply(&self.a, 0)
            .mode_apply(&self.a, 1)
            .mode_apply(&self.a, 2)
    }
}

/// A Gradient workload on the paper's (8, 7, 6) element.
#[derive(Debug, Clone)]
pub struct GradientWorkload {
    pub dims: (usize, usize, usize),
    pub n_elements: usize,
    pub dx: Tensor,
    pub dy: Tensor,
    pub dz: Tensor,
    pub u: Vec<f64>,
}

impl GradientWorkload {
    pub fn generate(dims: (usize, usize, usize), n_elements: usize, seed: u64) -> Self {
        let mut rng = Prng::new(seed);
        let (nx, ny, nz) = dims;
        let scale = |mut t: Tensor, n: usize| {
            for x in t.data_mut() {
                *x /= n as f64;
            }
            t
        };
        GradientWorkload {
            dims,
            n_elements,
            dx: scale(Tensor::random(&[nx, nx], &mut rng), nx),
            dy: scale(Tensor::random(&[ny, ny], &mut rng), ny),
            dz: scale(Tensor::random(&[nz, nz], &mut rng), nz),
            u: rng.unit_vec(n_elements * nx * ny * nz),
        }
    }

    pub fn block(&self) -> usize {
        self.dims.0 * self.dims.1 * self.dims.2
    }

    pub fn u_element(&self, e: usize) -> &[f64] {
        let b = self.block();
        &self.u[e * b..(e + 1) * b]
    }

    /// (gx, gy, gz) oracle for element `e`, each in (nx, ny, nz) order
    /// (the artifact layout; the DSL's move-axis form differs — see
    /// dsl::gradient_source docs).
    pub fn expected_element(&self, e: usize) -> [Tensor; 3] {
        let (nx, ny, nz) = self.dims;
        let u = Tensor::from_vec(&[nx, ny, nz], self.u_element(e).to_vec());
        [
            u.mode_apply(&self.dx, 0),
            u.mode_apply(&self.dy, 1),
            u.mode_apply(&self.dz, 2),
        ]
    }
}

/// Seeded random inputs plus the generic numerics oracle for any
/// front-door program: the lowered kernel (the datapath the hardware
/// flow implements) is checked element-by-element against `teil::eval`
/// of the rewritten module. No per-kernel closed form required.
#[derive(Debug, Clone)]
pub struct GenericWorkload {
    pub name: String,
    /// Rewritten teil module — the oracle semantics.
    pub module: Module,
    /// Lowered affine kernel — the datapath under test.
    pub kernel: Kernel,
    pub seed: u64,
}

/// Result of a [`GenericWorkload::check`]: the MSE and worst absolute
/// error of the lowered kernel against the teil-eval oracle.
#[derive(Debug, Clone, Copy)]
pub struct OracleCheck {
    pub elements: usize,
    pub mse: f64,
    pub max_abs_err: f64,
}

impl GenericWorkload {
    pub fn new(name: &str, module: Module, kernel: Kernel, seed: u64) -> Self {
        GenericWorkload {
            name: name.to_string(),
            module,
            kernel,
            seed,
        }
    }

    /// Build module + kernel from a [`KernelSource`] at degree `p`
    /// (one parse: the oracle always checks the program it lowered).
    pub fn from_source(source: &KernelSource, p: usize, seed: u64) -> Result<Self, String> {
        let (module, kernel) = source.compile(p)?;
        Ok(GenericWorkload::new(&source.name(), module, kernel, seed))
    }

    /// Deterministic random inputs for element `e`, derived from the
    /// module's declared input shapes: every value lies in (-1, 1), and
    /// rank-2 inputs (operator matrices) are additionally scaled by
    /// 1/cols — the near-orthonormal convention of the named workloads
    /// that keeps contraction chains inside the paper's rescaled unit
    /// domain (§3.6.4).
    pub fn element_inputs(&self, e: usize) -> HashMap<String, Tensor> {
        let mut rng =
            Prng::new(self.seed ^ 0x9e3779b97f4a7c15u64.wrapping_mul(e as u64 + 1));
        let index_bounds = self.module.index_input_bounds();
        let mut out = HashMap::new();
        for (name, shape) in &self.module.inputs {
            let mut t = Tensor::random(shape, &mut rng);
            if let Some((_, bound)) = index_bounds.iter().find(|(n, _)| n == name) {
                // index maps carry whole numbers in [0, bound), not
                // unit-domain reals; uniform draws naturally produce
                // the duplicates and out-of-order rows the oracle must
                // agree on
                for x in t.data_mut() {
                    *x = (rng.next_u64() % *bound as u64) as f64;
                }
            } else if shape.len() == 2 {
                let cols = shape[1] as f64;
                for x in t.data_mut() {
                    *x /= cols;
                }
            }
            out.insert(name.clone(), t);
        }
        out
    }

    /// Oracle result for element `e` via `teil::eval` (replaces the
    /// named workloads' `expected_element` closed forms).
    pub fn expected_element(&self, e: usize) -> Result<HashMap<String, Tensor>, String> {
        teil::eval(&self.module, &self.element_inputs(e))
    }

    /// Run `elements` seeded elements through the lowered kernel and
    /// compare every output against the oracle. Both paths evaluate the
    /// same f64 mode-product chain in the same order, so a correct
    /// lowering yields MSE = 0 exactly; any nonzero error is a lowering
    /// bug, not roundoff.
    pub fn check(&self, elements: usize) -> Result<OracleCheck, String> {
        let mut se = 0.0f64;
        let mut count = 0u64;
        let mut max_abs_err = 0.0f64;
        for e in 0..elements {
            let inputs = self.element_inputs(e);
            let want = teil::eval(&self.module, &inputs)?;
            let got = interp::interpret(&self.kernel, &inputs)?;
            for d in self.module.outputs() {
                let w = want.get(&d.name).ok_or_else(|| {
                    format!("oracle missing output {}", d.name)
                })?;
                let g = got.get(&d.name).ok_or_else(|| {
                    format!("kernel missing output {}", d.name)
                })?;
                if w.shape() != g.shape() {
                    return Err(format!(
                        "output {}: oracle shape {:?} vs kernel {:?}",
                        d.name,
                        w.shape(),
                        g.shape()
                    ));
                }
                for (a, b) in w.data().iter().zip(g.data()) {
                    let err = (a - b).abs();
                    max_abs_err = max_abs_err.max(err);
                    se += err * err;
                    count += 1;
                }
            }
        }
        Ok(OracleCheck {
            elements,
            mse: se / count.max(1) as f64,
            max_abs_err,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generation_is_deterministic() {
        let a = HelmholtzWorkload::generate(7, 10, 99);
        let b = HelmholtzWorkload::generate(7, 10, 99);
        assert_eq!(a.s, b.s);
        assert_eq!(a.d, b.d);
        let c = HelmholtzWorkload::generate(7, 10, 100);
        assert_ne!(a.u, c.u);
    }

    #[test]
    fn values_in_unit_domain() {
        let w = HelmholtzWorkload::generate(5, 20, 1);
        assert!(w.d.iter().chain(&w.u).all(|x| (-1.0..1.0).contains(x)));
        assert_eq!(w.d.len(), 20 * 125);
    }

    #[test]
    fn element_views_are_disjoint() {
        let w = HelmholtzWorkload::generate(3, 4, 2);
        assert_eq!(w.d_element(0).len(), 27);
        assert_ne!(w.d_element(0), w.d_element(1));
    }

    #[test]
    fn generic_oracle_is_exact_on_the_builtin_trio() {
        for (name, p) in [("helmholtz", 5), ("interpolation", 6), ("gradient", 8)] {
            let w = GenericWorkload::from_source(
                &KernelSource::builtin(name),
                p,
                2024,
            )
            .unwrap();
            let c = w.check(2).unwrap();
            assert_eq!(c.mse, 0.0, "{name}: MSE {:.3e}", c.mse);
            assert_eq!(c.max_abs_err, 0.0, "{name}");
            assert_eq!(c.elements, 2);
        }
    }

    #[test]
    fn generic_oracle_covers_indexed_kernels() {
        // the irregular builtins: seeded integer index maps (duplicates
        // and out-of-order rows included) flow through both evaluators
        for name in ["mesh_gather", "scatter_assembly"] {
            let w = GenericWorkload::from_source(
                &KernelSource::builtin(name),
                0,
                2024,
            )
            .unwrap();
            let c = w.check(2).unwrap();
            assert_eq!(c.mse, 0.0, "{name}: MSE {:.3e}", c.mse);
            assert_eq!(c.max_abs_err, 0.0, "{name}");
        }
    }

    #[test]
    fn index_inputs_are_seeded_as_in_range_whole_numbers() {
        let w = GenericWorkload::from_source(
            &KernelSource::builtin("scatter_assembly"),
            0,
            9,
        )
        .unwrap();
        let bounds = w.module.index_input_bounds();
        assert_eq!(bounds.len(), 2, "{bounds:?}"); // gi and si
        let inputs = w.element_inputs(0);
        for (name, bound) in &bounds {
            let t = &inputs[name];
            assert!(
                t.data().iter().all(|&x| {
                    x.fract() == 0.0 && x >= 0.0 && (x as usize) < *bound
                }),
                "{name} not whole numbers in [0, {bound})"
            );
            // a 1024-draw uniform over 256 rows repeats with certainty
            let mut sorted: Vec<u64> = t.data().iter().map(|&x| x as u64).collect();
            sorted.sort_unstable();
            sorted.dedup();
            assert!(sorted.len() < t.len(), "{name}: no duplicate indices");
        }
        assert_eq!(inputs["gi"], w.element_inputs(0)["gi"], "deterministic");
    }

    #[test]
    fn generic_inputs_are_deterministic_and_bounded() {
        let w = GenericWorkload::from_source(
            &KernelSource::builtin("helmholtz"),
            4,
            7,
        )
        .unwrap();
        let a = w.element_inputs(0);
        let b = w.element_inputs(0);
        assert_eq!(a["u"], b["u"]);
        assert_ne!(a["u"], w.element_inputs(1)["u"]);
        // operator matrices carry the 1/cols near-orthonormal scaling
        assert!(a["S"].data().iter().all(|x| x.abs() < 1.0 / 4.0 + 1e-12));
        assert!(a["u"].data().iter().all(|x| x.abs() < 1.0));
    }

    #[test]
    fn generic_oracle_matches_the_closed_form_helmholtz() {
        // teil::eval and the hand-written expected_element agree on the
        // same inputs: the generic oracle subsumes the closed form.
        let p = 4;
        let w = GenericWorkload::from_source(
            &KernelSource::builtin("helmholtz"),
            p,
            11,
        )
        .unwrap();
        let inputs = w.element_inputs(0);
        let out = w.expected_element(0).unwrap();
        let t = inputs["u"]
            .mode_apply(&inputs["S"], 0)
            .mode_apply(&inputs["S"], 1)
            .mode_apply(&inputs["S"], 2);
        let r = inputs["D"].zip(&t, |a, b| a * b);
        let st = inputs["S"].transposed();
        let want = r.mode_apply(&st, 0).mode_apply(&st, 1).mode_apply(&st, 2);
        assert!(out["v"].max_abs_diff(&want) < 1e-12);
    }

    #[test]
    fn expected_element_matches_identity_case() {
        let mut w = HelmholtzWorkload::generate(4, 2, 3);
        w.s = Tensor::identity(4);
        let v = w.expected_element(1);
        for (i, &x) in v.data().iter().enumerate() {
            let want = w.d_element(1)[i] * w.u_element(1)[i];
            assert!((x - want).abs() < 1e-14);
        }
    }
}
