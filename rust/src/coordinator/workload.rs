//! Workload generation: CFD-like element data.
//!
//! The paper simulates N_eq = 2,000,000 independent spectral elements
//! with physical quantities rescaled into [-1, 1] (§3.6.4). We generate
//! synthetic elements in that domain with a seeded PRNG; the S matrix is
//! a dense spectral operator shared by all elements.

use crate::util::prng::Prng;
use crate::util::tensor::Tensor;

/// A Helmholtz workload: shared S plus per-element D, u.
#[derive(Debug, Clone)]
pub struct HelmholtzWorkload {
    pub p: usize,
    pub n_elements: usize,
    /// (p, p) operator matrix.
    pub s: Tensor,
    /// (n, p^3) flattened Hadamard factors.
    pub d: Vec<f64>,
    /// (n, p^3) flattened inputs.
    pub u: Vec<f64>,
}

impl HelmholtzWorkload {
    pub fn generate(p: usize, n_elements: usize, seed: u64) -> HelmholtzWorkload {
        let mut rng = Prng::new(seed);
        // SEM spectral operators are near-orthonormal: row sums are O(1).
        // Scaling entries by 1/p keeps every intermediate (t, r, v) inside
        // [-1, 1] — the rescaled domain the paper's fixed-point formats
        // assume (§3.6.4). Unscaled random S would saturate Q8.24.
        let mut s = Tensor::random(&[p, p], &mut rng);
        for x in s.data_mut() {
            *x /= p as f64;
        }
        let block = p * p * p;
        HelmholtzWorkload {
            p,
            n_elements,
            s,
            d: rng.unit_vec(n_elements * block),
            u: rng.unit_vec(n_elements * block),
        }
    }

    pub fn block(&self) -> usize {
        self.p * self.p * self.p
    }

    /// Per-element view of D.
    pub fn d_element(&self, e: usize) -> &[f64] {
        let b = self.block();
        &self.d[e * b..(e + 1) * b]
    }

    pub fn u_element(&self, e: usize) -> &[f64] {
        let b = self.block();
        &self.u[e * b..(e + 1) * b]
    }

    /// Exact result for element `e` via the native oracle (Eq. 1a-1c).
    pub fn expected_element(&self, e: usize) -> Tensor {
        let p = self.p;
        let d = Tensor::from_vec(&[p, p, p], self.d_element(e).to_vec());
        let u = Tensor::from_vec(&[p, p, p], self.u_element(e).to_vec());
        let t = u
            .mode_apply(&self.s, 0)
            .mode_apply(&self.s, 1)
            .mode_apply(&self.s, 2);
        let r = d.zip(&t, |a, b| a * b);
        let st = transpose(&self.s);
        r.mode_apply(&st, 0).mode_apply(&st, 1).mode_apply(&st, 2)
    }
}

/// An Interpolation workload: shared A plus per-element u (paper §4.3).
#[derive(Debug, Clone)]
pub struct InterpolationWorkload {
    pub m: usize,
    pub n: usize,
    pub n_elements: usize,
    pub a: Tensor,
    /// (n_elements, n^3) flattened inputs.
    pub u: Vec<f64>,
}

impl InterpolationWorkload {
    pub fn generate(m: usize, n: usize, n_elements: usize, seed: u64) -> Self {
        let mut rng = Prng::new(seed);
        let mut a = Tensor::random(&[m, n], &mut rng);
        for x in a.data_mut() {
            *x /= n as f64; // near-orthonormal interpolation operator
        }
        InterpolationWorkload {
            m,
            n,
            n_elements,
            a,
            u: rng.unit_vec(n_elements * n * n * n),
        }
    }

    pub fn in_block(&self) -> usize {
        self.n * self.n * self.n
    }

    pub fn out_block(&self) -> usize {
        self.m * self.m * self.m
    }

    pub fn u_element(&self, e: usize) -> &[f64] {
        let b = self.in_block();
        &self.u[e * b..(e + 1) * b]
    }

    pub fn expected_element(&self, e: usize) -> Tensor {
        let n = self.n;
        let u = Tensor::from_vec(&[n, n, n], self.u_element(e).to_vec());
        u.mode_apply(&self.a, 0)
            .mode_apply(&self.a, 1)
            .mode_apply(&self.a, 2)
    }
}

/// A Gradient workload on the paper's (8, 7, 6) element.
#[derive(Debug, Clone)]
pub struct GradientWorkload {
    pub dims: (usize, usize, usize),
    pub n_elements: usize,
    pub dx: Tensor,
    pub dy: Tensor,
    pub dz: Tensor,
    pub u: Vec<f64>,
}

impl GradientWorkload {
    pub fn generate(dims: (usize, usize, usize), n_elements: usize, seed: u64) -> Self {
        let mut rng = Prng::new(seed);
        let (nx, ny, nz) = dims;
        let scale = |mut t: Tensor, n: usize| {
            for x in t.data_mut() {
                *x /= n as f64;
            }
            t
        };
        GradientWorkload {
            dims,
            n_elements,
            dx: scale(Tensor::random(&[nx, nx], &mut rng), nx),
            dy: scale(Tensor::random(&[ny, ny], &mut rng), ny),
            dz: scale(Tensor::random(&[nz, nz], &mut rng), nz),
            u: rng.unit_vec(n_elements * nx * ny * nz),
        }
    }

    pub fn block(&self) -> usize {
        self.dims.0 * self.dims.1 * self.dims.2
    }

    pub fn u_element(&self, e: usize) -> &[f64] {
        let b = self.block();
        &self.u[e * b..(e + 1) * b]
    }

    /// (gx, gy, gz) oracle for element `e`, each in (nx, ny, nz) order
    /// (the artifact layout; the DSL's move-axis form differs — see
    /// dsl::gradient_source docs).
    pub fn expected_element(&self, e: usize) -> [Tensor; 3] {
        let (nx, ny, nz) = self.dims;
        let u = Tensor::from_vec(&[nx, ny, nz], self.u_element(e).to_vec());
        [
            u.mode_apply(&self.dx, 0),
            u.mode_apply(&self.dy, 1),
            u.mode_apply(&self.dz, 2),
        ]
    }
}

fn transpose(t: &Tensor) -> Tensor {
    let (r, c) = (t.shape()[0], t.shape()[1]);
    let mut out = Tensor::zeros(&[c, r]);
    for i in 0..r {
        for j in 0..c {
            out.set(&[j, i], t.get(&[i, j]));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generation_is_deterministic() {
        let a = HelmholtzWorkload::generate(7, 10, 99);
        let b = HelmholtzWorkload::generate(7, 10, 99);
        assert_eq!(a.s, b.s);
        assert_eq!(a.d, b.d);
        let c = HelmholtzWorkload::generate(7, 10, 100);
        assert_ne!(a.u, c.u);
    }

    #[test]
    fn values_in_unit_domain() {
        let w = HelmholtzWorkload::generate(5, 20, 1);
        assert!(w.d.iter().chain(&w.u).all(|x| (-1.0..1.0).contains(x)));
        assert_eq!(w.d.len(), 20 * 125);
    }

    #[test]
    fn element_views_are_disjoint() {
        let w = HelmholtzWorkload::generate(3, 4, 2);
        assert_eq!(w.d_element(0).len(), 27);
        assert_ne!(w.d_element(0), w.d_element(1));
    }

    #[test]
    fn expected_element_matches_identity_case() {
        let mut w = HelmholtzWorkload::generate(4, 2, 3);
        w.s = Tensor::identity(4);
        let v = w.expected_element(1);
        for (i, &x) in v.data().iter().enumerate() {
            let want = w.d_element(1)[i] * w.u_element(1)[i];
            assert!((x - want).abs() < 1e-14);
        }
    }
}
