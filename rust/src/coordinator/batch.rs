//! Batch planning, lane interleaving, and the ping/pong state machine.

use crate::olympus::SystemSpec;
use crate::util::ceil_div;

/// How a workload of N_eq elements maps onto batches, CUs, and
/// executable invocations (paper §3.1).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct BatchPlan {
    pub n_elements: u64,
    /// E — elements per HBM batch per CU.
    pub batch_elements: u64,
    /// N_b = ceil(N_eq / E).
    pub n_batches: u64,
    pub n_cus: usize,
    /// I = ceil(N_b / N_cu) — iterations per CU.
    pub iterations_per_cu: u64,
    /// Elements per executable invocation (the AOT artifact's batch dim).
    pub exec_batch: usize,
}

impl BatchPlan {
    pub fn new(spec: &SystemSpec, n_elements: u64, exec_batch: usize) -> BatchPlan {
        let e = spec.batch_elements as u64;
        let n_batches = ceil_div(n_elements, e);
        BatchPlan {
            n_elements,
            batch_elements: e,
            n_batches,
            n_cus: spec.num_cus,
            iterations_per_cu: ceil_div(n_batches, spec.num_cus as u64),
            exec_batch,
        }
    }

    /// Elements in batch `b` (the last batch may be short). Returns
    /// `None` when `b >= n_batches`: the old `debug_assert!` version
    /// silently underflowed `n_elements - b * batch_elements` in release
    /// builds and handed callers a wrapped, near-2^64 count.
    pub fn elements_in_batch(&self, b: u64) -> Option<u64> {
        if b >= self.n_batches {
            return None;
        }
        Some(if b + 1 == self.n_batches {
            self.n_elements - b * self.batch_elements
        } else {
            self.batch_elements
        })
    }

    /// CU that executes batch `b` (round-robin, like the Olympus host).
    pub fn cu_of(&self, b: u64) -> usize {
        (b % self.n_cus as u64) as usize
    }

    /// Executable invocations needed for batch `b` (`None` out of range).
    pub fn invocations_in_batch(&self, b: u64) -> Option<u64> {
        Some(ceil_div(self.elements_in_batch(b)?, self.exec_batch as u64))
    }

    /// Global element range [start, end) of batch `b` (`None` out of range).
    pub fn element_range(&self, b: u64) -> Option<(u64, u64)> {
        let start = b * self.batch_elements;
        Some((start, start + self.elements_in_batch(b)?))
    }

    /// Invariants (property-tested): batches tile the workload exactly.
    ///
    /// The `n_elements == 0` plan is deliberately valid: it has
    /// `n_batches == 0`, so the loop body never runs, `covered` stays 0,
    /// and the final coverage check passes as `0 == 0`. Drivers see an
    /// empty batch range and do no work — the correct semantics for an
    /// empty workload, not a vacuous accident.
    pub fn validate(&self) -> Result<(), String> {
        let mut covered = 0u64;
        for b in 0..self.n_batches {
            let (s, e) = self
                .element_range(b)
                .expect("b < n_batches by loop bound");
            if s != covered {
                return Err(format!("batch {b} starts at {s}, expected {covered}"));
            }
            if e <= s {
                return Err(format!("batch {b} is empty"));
            }
            covered = e;
        }
        if covered != self.n_elements {
            return Err(format!(
                "batches cover {covered} of {} elements",
                self.n_elements
            ));
        }
        Ok(())
    }
}

/// Ping/pong double-buffer state per CU (paper §3.6.1: "the host reads
/// the output from the last iteration and writes new input into the
/// 'even' channels while the PCs operate on the data in the 'odd'
/// channels, and vice versa").
#[derive(Debug, Clone)]
pub struct PingPong {
    phase: Vec<u8>,
}

impl PingPong {
    pub fn new(n_cus: usize) -> PingPong {
        PingPong {
            phase: vec![0; n_cus],
        }
    }

    /// Phase the next batch on `cu` must use; flips on advance.
    pub fn phase(&self, cu: usize) -> usize {
        self.phase[cu] as usize
    }

    pub fn advance(&mut self, cu: usize) -> usize {
        let p = self.phase[cu];
        self.phase[cu] ^= 1;
        p as usize
    }

    /// Channel the CU reads from in its current phase.
    ///
    /// The `% len` wrap is load-bearing for single-buffered CUs, where
    /// one channel legitimately serves both phases. A *double-buffered*
    /// CU with a single channel would wrap both phases onto the same
    /// channel and silently serialize the ping/pong; that shape is
    /// rejected at generation time by `SystemSpec::validate`, so it
    /// never reaches this state machine.
    pub fn read_channel(&self, spec: &SystemSpec, cu: usize) -> u32 {
        let ch = &spec.channels[cu];
        ch.read[self.phase(cu) % ch.read.len()]
    }

    pub fn write_channel(&self, spec: &SystemSpec, cu: usize) -> u32 {
        let ch = &spec.channels[cu];
        ch.write[self.phase(cu) % ch.write.len()]
    }
}

/// Interleave per-element blocks across `lanes` (paper §3.6.2: "Olympus
/// modifies the host code to interleave the input for the multiple
/// elements before sending it to HBM"). Element e's block goes to lane
/// e % lanes; the HBM image is lane-major.
///
/// A ragged element count — any short tail batch, which
/// [`BatchPlan::elements_in_batch`] produces for almost every realistic
/// `n_elements` — is padded with zero elements up to the lane boundary,
/// so the returned image holds `n.next_multiple_of(lanes)` elements.
/// (This used to `assert_eq!(n % lanes, 0)` and abort real host
/// marshalling on the tail batch.) Callers recover the logical count by
/// truncating after [`deinterleave`].
pub fn interleave(data: &[f64], block: usize, lanes: usize) -> Vec<f64> {
    assert!(block > 0 && lanes > 0);
    assert_eq!(data.len() % block, 0, "data must be whole elements");
    let n = data.len() / block;
    let aligned = n.next_multiple_of(lanes);
    let per_lane = aligned / lanes;
    let mut out = vec![0.0; aligned * block];
    for e in 0..n {
        let lane = e % lanes;
        let slot = e / lanes;
        let dst = (lane * per_lane + slot) * block;
        out[dst..dst + block].copy_from_slice(&data[e * block..(e + 1) * block]);
    }
    out
}

/// Inverse of `interleave` on the lane-aligned HBM image. The image is
/// lane-aligned by construction (interleave pads); the caller truncates
/// any pad elements from the element-major result.
pub fn deinterleave(data: &[f64], block: usize, lanes: usize) -> Vec<f64> {
    assert!(block > 0 && lanes > 0);
    assert_eq!(data.len() % block, 0);
    let n = data.len() / block;
    assert_eq!(n % lanes, 0);
    let per_lane = n / lanes;
    let mut out = vec![0.0; data.len()];
    for e in 0..n {
        let lane = e % lanes;
        let slot = e / lanes;
        let src = (lane * per_lane + slot) * block;
        out[e * block..(e + 1) * block].copy_from_slice(&data[src..src + block]);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dsl;
    use crate::ir::{lower, rewrite, teil};
    use crate::olympus::{generate, OlympusOpts};
    use crate::platform::Platform;
    use crate::util::prng::Prng;
    use crate::util::prop;

    fn spec(opts: OlympusOpts) -> SystemSpec {
        let prog = dsl::parse(&dsl::inverse_helmholtz_source(11)).unwrap();
        let m = rewrite::optimize(teil::from_ast(&prog).unwrap());
        let k = lower::lower_kernel(&m, "helmholtz").unwrap();
        generate(&k, &opts, &Platform::alveo_u280()).unwrap()
    }

    #[test]
    fn plan_covers_workload_exactly() {
        let s = spec(OlympusOpts::dataflow(7).with_cus(2));
        let plan = BatchPlan::new(&s, 2_000_000, 32);
        plan.validate().unwrap();
        let total: u64 = (0..plan.n_batches)
            .map(|b| plan.elements_in_batch(b).unwrap())
            .sum();
        assert_eq!(total, 2_000_000);
        assert_eq!(
            plan.iterations_per_cu,
            plan.n_batches.div_ceil(2)
        );
    }

    #[test]
    fn out_of_range_batch_index_is_an_error_not_a_wrap() {
        // Pre-fix, a release build computed n_elements - b*batch_elements
        // for b >= n_batches and wrapped to a near-2^64 element count.
        let s = spec(OlympusOpts::dataflow(7));
        let plan = BatchPlan::new(&s, 100_000, 32);
        assert!(plan.n_batches >= 1);
        assert_eq!(plan.elements_in_batch(plan.n_batches), None);
        assert_eq!(plan.elements_in_batch(plan.n_batches + 7), None);
        assert_eq!(plan.invocations_in_batch(plan.n_batches), None);
        assert_eq!(plan.element_range(plan.n_batches), None);
        // in-range indices still answer
        assert!(plan.elements_in_batch(plan.n_batches - 1).is_some());
    }

    #[test]
    fn empty_workload_plan_is_valid_and_does_nothing() {
        let s = spec(OlympusOpts::dataflow(7));
        let plan = BatchPlan::new(&s, 0, 32);
        assert_eq!(plan.n_batches, 0);
        assert_eq!(plan.iterations_per_cu, 0);
        plan.validate().unwrap();
        assert_eq!(plan.elements_in_batch(0), None, "no batch 0 to ask about");
    }

    #[test]
    fn property_batching_loses_no_elements() {
        prop::check("batch plan conservation", 48, |rng| {
            let cus = rng.range_usize(1, 4);
            let n = rng.range_u64(1, 5_000_000);
            let s = spec(OlympusOpts::dataflow(7).with_cus(cus));
            let plan = BatchPlan::new(&s, n, 32);
            plan.validate()?;
            // round-robin covers every CU index
            for b in 0..plan.n_batches.min(16) {
                prop::assert_prop(plan.cu_of(b) < cus, "cu in range".to_string())?;
            }
            Ok(())
        });
    }

    #[test]
    fn pingpong_alternates_and_maps_channels() {
        let s = spec(OlympusOpts::dataflow(7));
        let mut pp = PingPong::new(s.num_cus);
        let c0 = pp.read_channel(&s, 0);
        assert_eq!(pp.advance(0), 0);
        let c1 = pp.read_channel(&s, 0);
        assert_eq!(pp.advance(0), 1);
        let c2 = pp.read_channel(&s, 0);
        assert_ne!(c0, c1, "ping and pong differ");
        assert_eq!(c0, c2, "phase wraps");
        // read/write channels are disjoint for a single double-buffered CU
        assert_ne!(pp.read_channel(&s, 0), pp.write_channel(&s, 0));
    }

    #[test]
    fn property_pingpong_strict_alternation() {
        prop::check("pingpong alternation", 32, |rng| {
            let cus = rng.range_usize(1, 4);
            let s = spec(OlympusOpts::dataflow(7).with_cus(cus));
            let mut pp = PingPong::new(cus);
            for step in 0..50 {
                let cu = rng.range_usize(0, cus - 1);
                let before = pp.phase(cu);
                let used = pp.advance(cu);
                prop::assert_prop(used == before, format!("step {step}"))?;
                prop::assert_prop(
                    pp.phase(cu) == 1 - before,
                    format!("flip at {step}"),
                )?;
                let _ = pp.read_channel(&s, cu);
                let _ = pp.write_channel(&s, cu);
            }
            Ok(())
        });
    }

    #[test]
    fn interleave_roundtrip() {
        let mut rng = Prng::new(1);
        let block = 5;
        let n = 12;
        let data = rng.unit_vec(block * n);
        for lanes in [1, 2, 3, 4, 6] {
            let inter = interleave(&data, block, lanes);
            let back = deinterleave(&inter, block, lanes);
            assert_eq!(back, data, "lanes {lanes}");
        }
    }

    #[test]
    fn interleave_pads_ragged_tails_and_roundtrips() {
        // Pre-fix this panicked: 7 elements across 4 lanes is exactly the
        // short tail batch every realistic BatchPlan produces.
        let mut rng = Prng::new(3);
        let block = 3;
        let data = rng.unit_vec(block * 7);
        let inter = interleave(&data, block, 4);
        assert_eq!(inter.len(), 8 * block, "padded to the lane boundary");
        let back = deinterleave(&inter, block, 4);
        assert_eq!(&back[..data.len()], &data[..], "prefix round-trips");
        assert!(back[data.len()..].iter().all(|&x| x == 0.0), "zero pad");
    }

    #[test]
    fn property_ragged_interleave_roundtrips() {
        prop::check("ragged interleave roundtrip", 48, |rng| {
            let lanes = rng.range_usize(1, 8);
            let block = rng.range_usize(1, 7);
            let n = rng.range_usize(1, 40); // usually not lane-aligned
            let data: Vec<f64> = (1..=n * block).map(|i| i as f64).collect();
            let inter = interleave(&data, block, lanes);
            let aligned = n.next_multiple_of(lanes);
            prop::assert_prop(
                inter.len() == aligned * block,
                format!("len {} != {}", inter.len(), aligned * block),
            )?;
            let back = deinterleave(&inter, block, lanes);
            prop::assert_prop(
                back[..data.len()] == data[..],
                format!("n {n} lanes {lanes} block {block}"),
            )
        });
    }

    #[test]
    fn interleave_lane_major_layout() {
        // elements 0..4, block 1, 2 lanes -> lane0: [0, 2], lane1: [1, 3]
        let data = vec![0.0, 1.0, 2.0, 3.0];
        let inter = interleave(&data, 1, 2);
        assert_eq!(inter, vec![0.0, 2.0, 1.0, 3.0]);
    }

    #[test]
    fn property_interleave_is_permutation() {
        prop::check("interleave permutation", 32, |rng| {
            let lanes = rng.range_usize(1, 8);
            let per = rng.range_usize(1, 6);
            let block = rng.range_usize(1, 7);
            let n = lanes * per;
            let data: Vec<f64> = (0..n * block).map(|i| i as f64).collect();
            let inter = interleave(&data, block, lanes);
            let mut sorted = inter.clone();
            sorted.sort_by(f64::total_cmp);
            prop::assert_prop(
                sorted == data && deinterleave(&inter, block, lanes) == data,
                format!("lanes {lanes} per {per} block {block}"),
            )
        });
    }
}
