//! Artifact manifest: the contract between `python/compile/aot.py` and
//! the Rust runtime. Parsed from `artifacts/manifest.json`.

use std::path::{Path, PathBuf};

use crate::util::json::{self, Json};

/// One AOT-compiled computation.
#[derive(Debug, Clone, PartialEq)]
pub struct ArtifactMeta {
    pub name: String,
    pub path: String,
    pub kernel: String,
    pub p: usize,
    pub dtype: String,
    pub batch: usize,
    pub variant: String,
    pub flops_per_element: u64,
    pub num_outputs: usize,
    /// (shape, dtype) per positional input.
    pub inputs: Vec<(Vec<usize>, String)>,
}

impl ArtifactMeta {
    fn from_json(j: &Json) -> Result<ArtifactMeta, String> {
        let req_str = |k: &str| {
            j.get(k)
                .as_str()
                .map(str::to_string)
                .ok_or_else(|| format!("artifact missing {k}"))
        };
        let req_num = |k: &str| {
            j.get(k)
                .as_u64()
                .ok_or_else(|| format!("artifact missing {k}"))
        };
        let inputs = j
            .get("inputs")
            .as_arr()
            .ok_or("artifact missing inputs")?
            .iter()
            .map(|i| {
                let shape = i
                    .get("shape")
                    .as_arr()
                    .ok_or("input missing shape")?
                    .iter()
                    .map(|d| d.as_u64().ok_or("bad dim").map(|d| d as usize))
                    .collect::<Result<Vec<_>, _>>()?;
                let dt = i.get("dtype").as_str().ok_or("input missing dtype")?;
                Ok::<_, String>((shape, dt.to_string()))
            })
            .collect::<Result<Vec<_>, _>>()?;
        Ok(ArtifactMeta {
            name: req_str("name")?,
            path: req_str("path")?,
            kernel: req_str("kernel")?,
            p: req_num("p")? as usize,
            dtype: req_str("dtype")?,
            batch: req_num("batch")? as usize,
            variant: req_str("variant")?,
            flops_per_element: req_num("flops_per_element")?,
            num_outputs: req_num("num_outputs")? as usize,
            inputs,
        })
    }
}

/// The parsed manifest plus its directory.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub artifacts: Vec<ArtifactMeta>,
}

impl Manifest {
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest, String> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .map_err(|e| format!("cannot read {}: {e} (run `make artifacts`)", path.display()))?;
        let j = json::parse(&text)?;
        if j.get("format").as_str() != Some("hlo-text") {
            return Err("manifest format must be hlo-text".into());
        }
        let artifacts = j
            .get("artifacts")
            .as_arr()
            .ok_or("manifest missing artifacts")?
            .iter()
            .map(ArtifactMeta::from_json)
            .collect::<Result<Vec<_>, _>>()?;
        Ok(Manifest { dir, artifacts })
    }

    pub fn get(&self, name: &str) -> Option<&ArtifactMeta> {
        self.artifacts.iter().find(|a| a.name == name)
    }

    /// Find an artifact by attributes; `variant` is "pallas" or "ref".
    pub fn find(
        &self,
        kernel: &str,
        p: usize,
        dtype: &str,
        variant: &str,
    ) -> Option<&ArtifactMeta> {
        self.artifacts
            .iter()
            .filter(|a| {
                a.kernel == kernel && a.p == p && a.dtype == dtype && a.variant == variant
            })
            .max_by_key(|a| a.batch)
    }

    pub fn hlo_path(&self, a: &ArtifactMeta) -> PathBuf {
        self.dir.join(&a.path)
    }
}

/// Repository-default artifacts directory.
pub fn default_dir() -> PathBuf {
    PathBuf::from(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn manifest() -> Option<Manifest> {
        Manifest::load(default_dir()).ok()
    }

    #[test]
    fn loads_real_manifest() {
        let Some(m) = manifest() else {
            eprintln!("artifacts not built; skipping");
            return;
        };
        assert!(m.artifacts.len() >= 10);
        let h = m.get("helmholtz_p11_f64_b32").expect("main artifact");
        assert_eq!(h.kernel, "helmholtz");
        assert_eq!(h.p, 11);
        assert_eq!(h.batch, 32);
        assert_eq!(h.flops_per_element, 177_023);
        assert_eq!(h.num_outputs, 1);
        assert_eq!(h.inputs.len(), 3);
        assert_eq!(h.inputs[0].0, vec![11, 11]);
        assert_eq!(h.inputs[1].0, vec![32, 11, 11, 11]);
        assert!(m.hlo_path(h).exists());
    }

    #[test]
    fn find_prefers_largest_batch() {
        let Some(m) = manifest() else { return };
        let a = m.find("helmholtz", 11, "f64", "pallas").unwrap();
        assert_eq!(a.batch, 32);
        let r = m.find("helmholtz", 11, "f64", "ref").unwrap();
        assert_eq!(r.variant, "ref");
        assert!(m.find("helmholtz", 13, "f64", "pallas").is_none());
    }

    #[test]
    fn gradient_artifact_has_three_outputs() {
        let Some(m) = manifest() else { return };
        let g = m.find("gradient", 8, "f64", "pallas").unwrap();
        assert_eq!(g.num_outputs, 3);
        assert_eq!(g.inputs.len(), 4);
    }

    #[test]
    fn rejects_bad_manifest() {
        let dir = std::env::temp_dir().join("hbmflow_bad_manifest");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), "{\"format\": \"nope\"}").unwrap();
        assert!(Manifest::load(&dir).is_err());
        std::fs::write(dir.join("manifest.json"), "not json").unwrap();
        assert!(Manifest::load(&dir).is_err());
    }
}
