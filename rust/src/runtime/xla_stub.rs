//! Offline stand-in for the private `xla` crate (the PJRT /
//! xla_extension closure carried only by the offline registry).
//!
//! Compiled when the `pjrt` feature is **off** — the default for a bare
//! checkout, where the real crate cannot be fetched. It mirrors exactly
//! the API surface `runtime` touches so the module typechecks, and
//! fails at the first constructor ([`PjRtClient::cpu`]): `Runtime::new`
//! returns an error, and every runtime-dependent test and bench already
//! skips gracefully when the runtime is unavailable. Enable `pjrt` (and
//! add the `xla` dependency from the offline registry — see the
//! commented block in `rust/Cargo.toml`) to execute real numerics.

use std::fmt;

/// Error every stub entry point returns.
#[derive(Debug)]
pub struct Unavailable;

impl fmt::Display for Unavailable {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "PJRT runtime not built: enable the `pjrt` feature with the \
             offline registry's `xla` crate (see rust/Cargo.toml)"
        )
    }
}

impl std::error::Error for Unavailable {}

pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, Unavailable> {
        Err(Unavailable)
    }

    pub fn platform_name(&self) -> String {
        String::new()
    }

    pub fn compile(
        &self,
        _comp: &XlaComputation,
    ) -> Result<PjRtLoadedExecutable, Unavailable> {
        Err(Unavailable)
    }
}

pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto, Unavailable> {
        Err(Unavailable)
    }
}

pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>, Unavailable> {
        Err(Unavailable)
    }
}

pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Unavailable> {
        Err(Unavailable)
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    F64,
    F32,
    /// The real crate has many more element types; one stand-in keeps
    /// the `other =>` match arms in `runtime` reachable.
    Unsupported,
}

pub struct Literal;

impl Literal {
    pub fn vec1<T>(_data: &[T]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal, Unavailable> {
        Err(Unavailable)
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>, Unavailable> {
        Err(Unavailable)
    }

    pub fn ty(&self) -> Result<ElementType, Unavailable> {
        Err(Unavailable)
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>, Unavailable> {
        Err(Unavailable)
    }
}
