//! HLO-text statistics: a lightweight cost analysis over the AOT
//! artifacts (the §Perf L2 tooling — "JAX tracer / HLO cost analysis on
//! the lowered module").
//!
//! Parses the HLO text far enough to count computations, instructions,
//! fusions, while loops, and dot/convolution ops. Used to verify the
//! lowering structure: the per-element-grid Pallas artifact carries a
//! `while` loop (serial grid); the batch-blocked variant must not.

use std::collections::BTreeMap;

/// Counts over one HLO module.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct HloStats {
    pub computations: usize,
    pub instructions: usize,
    pub fusions: usize,
    pub while_loops: usize,
    pub dots: usize,
    pub custom_calls: usize,
    /// instruction opcode histogram
    pub opcodes: BTreeMap<String, usize>,
}

impl HloStats {
    /// The datapath is serial when the entry computation loops.
    pub fn has_serial_grid(&self) -> bool {
        self.while_loops > 0
    }
}

/// Analyze HLO text (the `artifacts/*.hlo.txt` format).
pub fn analyze(text: &str) -> HloStats {
    let mut stats = HloStats::default();
    for line in text.lines() {
        let trimmed = line.trim_start();
        if trimmed.starts_with("HloModule") {
            continue;
        }
        // computation headers: "ENTRY %name" or "%name (args) -> ty {"
        if (trimmed.starts_with("ENTRY") || trimmed.starts_with('%'))
            && trimmed.ends_with('{')
        {
            stats.computations += 1;
            continue;
        }
        // instructions look like: "%x = f64[...] opcode(...)" or
        // "ROOT %x = ..."
        let body = trimmed.strip_prefix("ROOT ").unwrap_or(trimmed);
        let Some(eq) = body.find(" = ") else { continue };
        if !body.starts_with('%') && !body.starts_with(char::is_alphabetic) {
            continue;
        }
        let rhs = &body[eq + 3..];
        // rhs: "f64[2,2]{1,0} opcode(...)" — or a tuple type
        // "(f64[..], s32[]) opcode(...)", which contains spaces: skip a
        // parenthesized type by matching parens first.
        let after_ty = if let Some(stripped) = rhs.strip_prefix('(') {
            let mut depth = 1usize;
            let mut idx = None;
            for (i, c) in stripped.char_indices() {
                match c {
                    '(' => depth += 1,
                    ')' => {
                        depth -= 1;
                        if depth == 0 {
                            idx = Some(i + 1);
                            break;
                        }
                    }
                    _ => {}
                }
            }
            match idx {
                Some(i) => &stripped[i..],
                None => continue,
            }
        } else {
            match rhs.find(' ') {
                Some(i) => &rhs[i..],
                None => continue,
            }
        };
        let Some(op_tok) = after_ty.split_whitespace().next() else {
            continue;
        };
        let opcode: String = op_tok
            .chars()
            .take_while(|c| c.is_ascii_alphanumeric() || *c == '-' || *c == '_')
            .collect();
        if opcode.is_empty() {
            continue;
        }
        stats.instructions += 1;
        *stats.opcodes.entry(opcode.clone()).or_insert(0) += 1;
        match opcode.as_str() {
            "fusion" => stats.fusions += 1,
            "while" => stats.while_loops += 1,
            "dot" => stats.dots += 1,
            "custom-call" => stats.custom_calls += 1,
            _ => {}
        }
    }
    stats
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
HloModule jit_fn

%fused (p: f64[2,2]) -> f64[2,2] {
  %p = f64[2,2]{1,0} parameter(0)
  ROOT %a = f64[2,2]{1,0} add(%p, %p)
}

ENTRY %main (x: f64[2,2], y: f64[2,2]) -> (f64[2,2]) {
  %x = f64[2,2]{1,0} parameter(0)
  %y = f64[2,2]{1,0} parameter(1)
  %d = f64[2,2]{1,0} dot(%x, %y), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %f = f64[2,2]{1,0} fusion(%d), kind=kLoop, calls=%fused
  %w = f64[2,2]{1,0} while(%f), condition=%c, body=%b
  ROOT %t = (f64[2,2]{1,0}) tuple(%w)
}
";

    #[test]
    fn counts_sample_module() {
        let s = analyze(SAMPLE);
        assert_eq!(s.computations, 2);
        assert_eq!(s.dots, 1);
        assert_eq!(s.fusions, 1);
        assert_eq!(s.while_loops, 1);
        assert!(s.has_serial_grid());
        assert_eq!(s.opcodes["parameter"], 3);
        assert!(s.instructions >= 8);
    }

    #[test]
    fn real_artifacts_grid_vs_blocked() {
        let dir = super::super::manifest::default_dir();
        let read = |name: &str| std::fs::read_to_string(dir.join(name)).ok();
        let (Some(grid_text), Some(blocked_text)) = (
            read("helmholtz_p11_f64_b32.hlo.txt"),
            read("helmholtz_p11_f64_b32_pallas_blocked.hlo.txt"),
        ) else {
            eprintln!("artifacts missing; skipping");
            return;
        };
        let grid = analyze(&grid_text);
        let blocked = analyze(&blocked_text);
        // Interpret-mode pallas always wraps the grid in a while loop,
        // even for grid=() — the §Perf structural difference is the
        // iteration space: the grid variant loops B=32 times over tiny
        // (121, 11) GEMMs, the blocked variant runs one iteration over
        // batch-sized (3872, 11) GEMMs.
        assert!(grid.has_serial_grid(), "{grid:?}");
        assert!(
            grid_text.contains("constant(32)"),
            "grid loop trips the batch count"
        );
        assert!(
            blocked_text.contains("f64[3872,11]"),
            "blocked mode products are batch-sized GEMMs"
        );
        assert!(
            !grid_text.contains("f64[3872,11]"),
            "grid mode products are per-element"
        );
        assert!(blocked.dots >= 6, "six mode products: {blocked:?}");
    }

    #[test]
    fn ref_artifact_is_fused_and_loop_free() {
        let dir = super::super::manifest::default_dir();
        let Ok(text) = std::fs::read_to_string(dir.join("helmholtz_p11_f64_b32_ref.hlo.txt"))
        else {
            return;
        };
        let s = analyze(&text);
        assert_eq!(s.while_loops, 0);
        assert!(s.dots >= 6);
    }

    #[test]
    fn empty_input_is_empty_stats() {
        assert_eq!(analyze(""), HloStats::default());
    }
}
