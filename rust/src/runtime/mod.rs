//! PJRT runtime: load AOT-compiled HLO-text artifacts and execute them
//! on the request path — Python is never involved here.
//!
//! Pattern (see /opt/xla-example/load_hlo): `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `client.compile` → `execute`. HLO *text* is the interchange format;
//! serialized protos from jax ≥ 0.5 are rejected by xla_extension 0.5.1.
//!
//! The `xla` crate lives only in the offline registry, so it is gated
//! behind the `pjrt` feature: a bare checkout builds against the
//! in-crate stub (`xla_stub`), whose client constructor fails — every
//! caller already skips gracefully when `Runtime` cannot come up.

pub mod hlo_stats;
pub mod manifest;

#[cfg(not(feature = "pjrt"))]
#[path = "xla_stub.rs"]
mod xla;

use std::collections::HashMap;

use anyhow::{anyhow, Context, Result};

pub use manifest::{ArtifactMeta, Manifest};

/// A loaded artifact ready to execute.
pub struct Executable {
    pub meta: ArtifactMeta,
    exe: xla::PjRtLoadedExecutable,
}

/// The PJRT CPU runtime with an executable cache.
pub struct Runtime {
    client: xla::PjRtClient,
    pub manifest: Manifest,
    cache: HashMap<String, Executable>,
}

impl Runtime {
    /// Create a runtime over an artifacts directory.
    pub fn new(dir: impl AsRef<std::path::Path>) -> Result<Runtime> {
        let manifest = Manifest::load(dir).map_err(|e| anyhow!(e))?;
        let client = xla::PjRtClient::cpu().context("PJRT CPU client")?;
        Ok(Runtime {
            client,
            manifest,
            cache: HashMap::new(),
        })
    }

    /// Runtime over the repository-default `artifacts/` directory.
    pub fn from_default_dir() -> Result<Runtime> {
        Runtime::new(manifest::default_dir())
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an artifact (cached).
    pub fn load(&mut self, name: &str) -> Result<&Executable> {
        if !self.cache.contains_key(name) {
            let meta = self
                .manifest
                .get(name)
                .ok_or_else(|| anyhow!("unknown artifact {name}"))?
                .clone();
            let path = self.manifest.hlo_path(&meta);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("artifact path")?,
            )
            .with_context(|| format!("parse {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .with_context(|| format!("compile {name}"))?;
            self.cache.insert(name.to_string(), Executable { meta, exe });
        }
        Ok(&self.cache[name])
    }

    /// Execute an artifact on f64 row-major inputs. Input shapes must
    /// match the manifest. Returns the flattened outputs.
    pub fn run_f64(&mut self, name: &str, inputs: &[Vec<f64>]) -> Result<Vec<Vec<f64>>> {
        let slices: Vec<&[f64]> = inputs.iter().map(|v| v.as_slice()).collect();
        self.run_f64_slices(name, &slices)
    }

    /// Slice-based variant of `run_f64` — the coordinator's hot path
    /// (§Perf: avoids one buffer copy per invocation).
    pub fn run_f64_slices(
        &mut self,
        name: &str,
        inputs: &[&[f64]],
    ) -> Result<Vec<Vec<f64>>> {
        let exec = self.load(name)?;
        let meta = exec.meta.clone();
        if inputs.len() != meta.inputs.len() {
            return Err(anyhow!(
                "{name}: expected {} inputs, got {}",
                meta.inputs.len(),
                inputs.len()
            ));
        }
        let mut literals = Vec::with_capacity(inputs.len());
        for (data, (shape, dtype)) in inputs.iter().zip(&meta.inputs) {
            let want: usize = shape.iter().product();
            if data.len() != want {
                return Err(anyhow!(
                    "{name}: input size {} != shape {:?}",
                    data.len(),
                    shape
                ));
            }
            let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
            let lit = match dtype.as_str() {
                "float64" => xla::Literal::vec1(*data).reshape(&dims)?,
                "float32" => {
                    let f32s: Vec<f32> = data.iter().map(|&x| x as f32).collect();
                    xla::Literal::vec1(&f32s).reshape(&dims)?
                }
                other => return Err(anyhow!("unsupported input dtype {other}")),
            };
            literals.push(lit);
        }
        let result = exec.exe.execute::<xla::Literal>(&literals)?[0][0]
            .to_literal_sync()?;
        // AOT lowers with return_tuple=True
        let parts = result.to_tuple()?;
        if parts.len() != meta.num_outputs {
            return Err(anyhow!(
                "{name}: expected {} outputs, got {}",
                meta.num_outputs,
                parts.len()
            ));
        }
        let mut outs = Vec::with_capacity(parts.len());
        for part in parts {
            let out = match part.ty()? {
                xla::ElementType::F64 => part.to_vec::<f64>()?,
                xla::ElementType::F32 => part
                    .to_vec::<f32>()?
                    .into_iter()
                    .map(|x| x as f64)
                    .collect(),
                other => return Err(anyhow!("unsupported output type {other:?}")),
            };
            outs.push(out);
        }
        Ok(outs)
    }

    /// Metadata accessor that does not require loading.
    pub fn meta(&self, name: &str) -> Option<&ArtifactMeta> {
        self.manifest.get(name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Prng;
    use crate::util::tensor::Tensor;

    fn runtime() -> Option<Runtime> {
        Runtime::from_default_dir().ok()
    }

    #[test]
    fn cpu_client_comes_up() {
        let Some(rt) = runtime() else {
            eprintln!("artifacts missing; skipping");
            return;
        };
        assert!(rt.platform().to_lowercase().contains("cpu") || !rt.platform().is_empty());
    }

    #[test]
    fn helmholtz_artifact_matches_native_oracle() {
        let Some(mut rt) = runtime() else { return };
        let name = "helmholtz_p7_f64_b8";
        let meta = rt.meta(name).expect("artifact").clone();
        let (p, b) = (meta.p, meta.batch);
        let mut rng = Prng::new(42);
        let s = Tensor::random(&[p, p], &mut rng);
        let d = Tensor::random(&[b, p, p, p], &mut rng);
        let u = Tensor::random(&[b, p, p, p], &mut rng);
        let outs = rt
            .run_f64(
                name,
                &[s.data().to_vec(), d.data().to_vec(), u.data().to_vec()],
            )
            .unwrap();
        assert_eq!(outs.len(), 1);
        let v = &outs[0];
        assert_eq!(v.len(), b * p * p * p);
        // native oracle per element
        let st = {
            let mut t = Tensor::zeros(&[p, p]);
            for i in 0..p {
                for j in 0..p {
                    t.set(&[j, i], s.get(&[i, j]));
                }
            }
            t
        };
        for e in 0..b {
            let off = e * p * p * p;
            let de = Tensor::from_vec(&[p, p, p], d.data()[off..off + p * p * p].to_vec());
            let ue = Tensor::from_vec(&[p, p, p], u.data()[off..off + p * p * p].to_vec());
            let t = ue.mode_apply(&s, 0).mode_apply(&s, 1).mode_apply(&s, 2);
            let r = de.zip(&t, |a, b| a * b);
            let want = r.mode_apply(&st, 0).mode_apply(&st, 1).mode_apply(&st, 2);
            for (i, &w) in want.data().iter().enumerate() {
                assert!(
                    (v[off + i] - w).abs() < 1e-10,
                    "element {e} idx {i}: {} vs {w}",
                    v[off + i]
                );
            }
        }
    }

    #[test]
    fn executables_are_cached() {
        let Some(mut rt) = runtime() else { return };
        rt.load("helmholtz_p7_f64_b8").unwrap();
        assert_eq!(rt.cache.len(), 1);
        rt.load("helmholtz_p7_f64_b8").unwrap();
        assert_eq!(rt.cache.len(), 1);
    }

    #[test]
    fn wrong_input_count_is_rejected() {
        let Some(mut rt) = runtime() else { return };
        let err = rt.run_f64("helmholtz_p7_f64_b8", &[vec![0.0]]).unwrap_err();
        assert!(err.to_string().contains("expected 3 inputs"));
    }

    #[test]
    fn unknown_artifact_is_rejected() {
        let Some(mut rt) = runtime() else { return };
        assert!(rt.run_f64("nope", &[]).is_err());
    }

    #[test]
    fn gradient_artifact_returns_three_outputs() {
        let Some(mut rt) = runtime() else { return };
        let name = "gradient_8x7x6_f64_b32";
        let Some(meta) = rt.meta(name).cloned() else { return };
        let b = meta.batch;
        let mut rng = Prng::new(3);
        let dx = rng.unit_vec(8 * 8);
        let dy = rng.unit_vec(7 * 7);
        let dz = rng.unit_vec(6 * 6);
        let u = rng.unit_vec(b * 8 * 7 * 6);
        let outs = rt.run_f64(name, &[dx, dy, dz, u]).unwrap();
        assert_eq!(outs.len(), 3);
        for o in &outs {
            assert_eq!(o.len(), b * 8 * 7 * 6);
        }
    }
}
