//! Measured CPU baselines (paper §4.3, Fig. 19).

use std::time::Instant;

use anyhow::Result;

use crate::coordinator::workload::HelmholtzWorkload;
use crate::platform::power::{AMD_EPYC_AVG_W, INTEL_XEON_AVG_W};
use crate::runtime::Runtime;

/// One measured software execution.
#[derive(Debug, Clone)]
pub struct CpuMeasurement {
    pub label: String,
    pub elements: u64,
    pub wall_s: f64,
    pub gflops: f64,
    /// Assumed average power (paper convention).
    pub power_w: f64,
    pub gflops_per_w: f64,
}

impl CpuMeasurement {
    fn new(label: &str, elements: u64, flops: u64, wall_s: f64, power_w: f64) -> Self {
        let gflops = flops as f64 / wall_s.max(1e-12) / 1e9;
        CpuMeasurement {
            label: label.to_string(),
            elements,
            wall_s,
            gflops,
            power_w,
            gflops_per_w: gflops / power_w,
        }
    }
}

/// Naive single-thread Inverse Helmholtz over `n` elements: the paper's
/// plain software execution analog. Straight loops over Eq. 1a-1c with
/// no blocking or vectorization hints.
pub fn measure_naive(w: &HelmholtzWorkload, n: usize) -> CpuMeasurement {
    let p = w.p;
    let n = n.min(w.n_elements);
    let block = w.block();
    let s = w.s.data();
    let mut v_out = vec![0.0f64; block];
    let mut t = vec![0.0f64; block];
    let mut t2 = vec![0.0f64; block];

    let t0 = Instant::now();
    for e in 0..n {
        let d = w.d_element(e);
        let u = w.u_element(e);
        // t = S x0 S x1 S x2 u, one mode at a time (factorized — even the
        // "naive" code uses the O(p^4) algorithm, like the paper's
        // software reference; the difference is scalar loops vs MKL).
        mode0(s, u, &mut t, p);
        mode1(s, &t, &mut t2, p);
        mode2(s, &t2, &mut t, p);
        // r = D * t (reuse t in place)
        for i in 0..block {
            t[i] *= d[i];
        }
        // v = S^T x0 S^T x1 S^T x2 r
        mode0_t(s, &t, &mut t2, p);
        mode1_t(s, &t2, &mut v_out, p);
        mode2_t(s, &v_out, &mut t2, p);
        std::hint::black_box(&t2);
    }
    let wall = t0.elapsed().as_secs_f64();
    let flops = n as u64 * (12 * p as u64 + 1) * (p as u64).pow(3);
    CpuMeasurement::new("naive CPU (1 thread)", n as u64, flops, wall, AMD_EPYC_AVG_W)
}

fn mode0(s: &[f64], x: &[f64], out: &mut [f64], p: usize) {
    let pp = p * p;
    for i in 0..p {
        for jk in 0..pp {
            let mut acc = 0.0;
            for l in 0..p {
                acc += s[i * p + l] * x[l * pp + jk];
            }
            out[i * pp + jk] = acc;
        }
    }
}

fn mode0_t(s: &[f64], x: &[f64], out: &mut [f64], p: usize) {
    let pp = p * p;
    for i in 0..p {
        for jk in 0..pp {
            let mut acc = 0.0;
            for l in 0..p {
                acc += s[l * p + i] * x[l * pp + jk];
            }
            out[i * pp + jk] = acc;
        }
    }
}

fn mode1(s: &[f64], x: &[f64], out: &mut [f64], p: usize) {
    let pp = p * p;
    for i in 0..p {
        for j in 0..p {
            for k in 0..p {
                let mut acc = 0.0;
                for l in 0..p {
                    acc += s[j * p + l] * x[i * pp + l * p + k];
                }
                out[i * pp + j * p + k] = acc;
            }
        }
    }
}

fn mode1_t(s: &[f64], x: &[f64], out: &mut [f64], p: usize) {
    let pp = p * p;
    for i in 0..p {
        for j in 0..p {
            for k in 0..p {
                let mut acc = 0.0;
                for l in 0..p {
                    acc += s[l * p + j] * x[i * pp + l * p + k];
                }
                out[i * pp + j * p + k] = acc;
            }
        }
    }
}

fn mode2(s: &[f64], x: &[f64], out: &mut [f64], p: usize) {
    let pp = p * p;
    for i in 0..p {
        for j in 0..p {
            for k in 0..p {
                let mut acc = 0.0;
                for l in 0..p {
                    acc += s[k * p + l] * x[i * pp + j * p + l];
                }
                out[i * pp + j * p + k] = acc;
            }
        }
    }
}

fn mode2_t(s: &[f64], x: &[f64], out: &mut [f64], p: usize) {
    let pp = p * p;
    for i in 0..p {
        for j in 0..p {
            for k in 0..p {
                let mut acc = 0.0;
                for l in 0..p {
                    acc += s[l * p + k] * x[i * pp + j * p + l];
                }
                out[i * pp + j * p + k] = acc;
            }
        }
    }
}

/// XLA-CPU execution of the pure-jnp `_ref` artifact — the optimized-CPU
/// analog. Measures steady-state throughput over `n` elements.
pub fn measure_xla_ref(
    rt: &mut Runtime,
    w: &HelmholtzWorkload,
    n: usize,
) -> Result<CpuMeasurement> {
    let meta = rt
        .manifest
        .find("helmholtz", w.p, "f64", "ref")
        .ok_or_else(|| anyhow::anyhow!("no ref artifact for p={}", w.p))?
        .clone();
    let b = meta.batch;
    let block = w.block();
    let n = n.min(w.n_elements) / b * b;
    let s = w.s.data().to_vec();
    // warm up (compile + first run)
    let d0 = w.d[..b * block].to_vec();
    let u0 = w.u[..b * block].to_vec();
    rt.run_f64(&meta.name, &[s.clone(), d0, u0])?;

    let t0 = Instant::now();
    let mut e = 0usize;
    while e < n {
        let d = w.d[e * block..(e + b) * block].to_vec();
        let u = w.u[e * block..(e + b) * block].to_vec();
        let out = rt.run_f64(&meta.name, &[s.clone(), d, u])?;
        std::hint::black_box(&out);
        e += b;
    }
    let wall = t0.elapsed().as_secs_f64();
    let flops = n as u64 * meta.flops_per_element;
    Ok(CpuMeasurement::new(
        "XLA-CPU (optimized ref)",
        n as u64,
        flops,
        wall,
        INTEL_XEON_AVG_W,
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn naive_matches_oracle() {
        // verify the hand-written loops against the tensor oracle
        let w = HelmholtzWorkload::generate(5, 3, 11);
        let p = 5;
        let block = w.block();
        let s = w.s.data();
        let mut t = vec![0.0; block];
        let mut t2 = vec![0.0; block];
        let mut t3 = vec![0.0; block];
        let u = w.u_element(1);
        let d = w.d_element(1);
        mode0(s, u, &mut t, p);
        mode1(s, &t, &mut t2, p);
        mode2(s, &t2, &mut t, p);
        for i in 0..block {
            t[i] *= d[i];
        }
        mode0_t(s, &t, &mut t2, p);
        mode1_t(s, &t2, &mut t3, p);
        mode2_t(s, &t3, &mut t2, p);
        let want = w.expected_element(1);
        for (i, &x) in want.data().iter().enumerate() {
            assert!((t2[i] - x).abs() < 1e-12, "idx {i}: {} vs {x}", t2[i]);
        }
    }

    #[test]
    fn naive_measurement_reports_throughput() {
        let w = HelmholtzWorkload::generate(7, 200, 3);
        let m = measure_naive(&w, 200);
        assert_eq!(m.elements, 200);
        assert!(m.gflops > 0.05, "{}", m.gflops);
        assert!(m.gflops < 100.0);
        assert!((m.gflops_per_w - m.gflops / 100.0).abs() < 1e-12);
    }

    #[test]
    fn xla_ref_beats_naive() {
        // the Fig. 19 premise: optimized CPU >> naive CPU per element
        let Ok(mut rt) = Runtime::from_default_dir() else {
            eprintln!("artifacts missing; skipping");
            return;
        };
        let w = HelmholtzWorkload::generate(11, 512, 4);
        let naive = measure_naive(&w, 256);
        let xla = measure_xla_ref(&mut rt, &w, 512).unwrap();
        assert!(
            xla.gflops > naive.gflops,
            "xla {} !> naive {}",
            xla.gflops,
            naive.gflops
        );
    }
}
