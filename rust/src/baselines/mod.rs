//! Software baselines for the Fig. 19 comparison.
//!
//! Two measured CPU datapaths stand in for the paper's testbeds:
//!
//!  * `naive` — straight nested loops, single thread: the analog of the
//!    plain software execution on the AMD EPYC 7282 host (black bars).
//!  * the XLA-CPU execution of the `_ref` artifacts through the PJRT
//!    runtime: the analog of the MKL-based "highly-optimized Intel
//!    implementations" [44] (red bars) — an aggressively fused,
//!    vectorized compile of the same math.
//!
//! Energy for CPUs uses the paper's own convention: a conservative
//! 100 W average under kernel load (§4.3).

pub mod cpu;

pub use cpu::{measure_naive, measure_xla_ref, CpuMeasurement};
