//! Report formatting and the paper's published numbers.
//!
//! Every bench target prints paper-value vs measured-value rows through
//! these helpers; `paper` holds the published data transcribed from the
//! evaluation section (Tables 2-5, Figs. 15-19).

pub mod paper;

/// Render an aligned text table.
pub fn table(headers: &[&str], rows: &[Vec<String>]) -> String {
    let ncols = headers.len();
    let mut widths: Vec<usize> = headers.iter().map(|h| h.len()).collect();
    for row in rows {
        for (c, cell) in row.iter().enumerate().take(ncols) {
            widths[c] = widths[c].max(cell.len());
        }
    }
    let mut out = String::new();
    let fmt_row = |cells: &[String], widths: &[usize]| -> String {
        let mut line = String::new();
        for (c, cell) in cells.iter().enumerate() {
            if c > 0 {
                line.push_str("  ");
            }
            if c == 0 {
                line.push_str(&format!("{:<w$}", cell, w = widths[c]));
            } else {
                line.push_str(&format!("{:>w$}", cell, w = widths[c]));
            }
        }
        line
    };
    let hdr: Vec<String> = headers.iter().map(|s| s.to_string()).collect();
    out.push_str(&fmt_row(&hdr, &widths));
    out.push('\n');
    out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (ncols - 1)));
    out.push('\n');
    for row in rows {
        out.push_str(&fmt_row(row, &widths));
        out.push('\n');
    }
    out
}

/// Format a float with sensible precision for reports.
pub fn f(x: f64) -> String {
    if x == 0.0 {
        "0".into()
    } else if x.abs() >= 100.0 {
        format!("{x:.0}")
    } else if x.abs() >= 1.0 {
        format!("{x:.2}")
    } else {
        format!("{x:.3}")
    }
}

/// Ratio annotation "measured (paper P, x1.10)".
pub fn vs_paper(measured: f64, paper: f64) -> String {
    if paper == 0.0 {
        return f(measured);
    }
    format!("{} (paper {}, x{:.2})", f(measured), f(paper), measured / paper)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_aligns_columns() {
        let t = table(
            &["name", "value"],
            &[
                vec!["a".into(), "1".into()],
                vec!["long-name".into(), "123".into()],
            ],
        );
        let lines: Vec<&str> = t.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].starts_with("name"));
        assert!(lines[3].starts_with("long-name"));
        // right-aligned numbers end at the same column
        assert_eq!(lines[2].len(), lines[3].len());
    }

    #[test]
    fn float_formatting() {
        assert_eq!(f(0.0), "0");
        assert_eq!(f(0.123456), "0.123");
        assert_eq!(f(3.14159), "3.14");
        assert_eq!(f(274.6), "275");
    }

    #[test]
    fn vs_paper_annotates_ratio() {
        let s = vs_paper(2.0, 1.0);
        assert!(s.contains("x2.00"), "{s}");
        assert_eq!(vs_paper(1.5, 0.0), "1.50");
    }
}
