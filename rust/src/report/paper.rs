//! Published evaluation numbers, transcribed from the paper
//! (Soldavini et al., ACM TRETS 2022, §4). Used by the bench harnesses
//! to print paper-vs-measured rows and by EXPERIMENTS.md.

/// One Fig. 15 / Table 2 row: the p=11, 1-CU optimization ladder.
#[derive(Debug, Clone, Copy)]
pub struct LadderRow {
    pub label: &'static str,
    /// Table 2 "# Ops".
    pub ops: u32,
    /// Table 2 "f (MHz)".
    pub f_mhz: f64,
    /// Table 2 "Achieved GFLOPS" (system, Fig. 15 azure bars).
    pub gflops: f64,
    /// Table 2 "Efficiency".
    pub efficiency: f64,
}

/// Table 2 (identical to the Fig. 15 series), p = 11, 1 CU, double.
pub const TABLE2: [LadderRow; 8] = [
    LadderRow { label: "Baseline", ops: 22, f_mhz: 274.6, gflops: 2.903, efficiency: 0.481 },
    LadderRow { label: "Double Buffering", ops: 22, f_mhz: 259.8, gflops: 3.055, efficiency: 0.535 },
    LadderRow { label: "Bus Opt (Serial)", ops: 4, f_mhz: 286.5, gflops: 0.959, efficiency: 0.837 },
    LadderRow { label: "Bus Opt (Parallel)", ops: 16, f_mhz: 296.6, gflops: 3.759, efficiency: 0.792 },
    LadderRow { label: "Dataflow (1 compute)", ops: 88, f_mhz: 286.2, gflops: 13.842, efficiency: 0.550 },
    LadderRow { label: "Dataflow (2 compute)", ops: 176, f_mhz: 291.9, gflops: 23.363, efficiency: 0.455 },
    LadderRow { label: "Dataflow (3 compute)", ops: 180, f_mhz: 266.3, gflops: 20.136, efficiency: 0.420 },
    LadderRow { label: "Dataflow (7 compute)", ops: 532, f_mhz: 199.5, gflops: 43.410, efficiency: 0.409 },
];

/// One Table 3/4 row: resource utilization, p=11 (or 4) 1 CU.
#[derive(Debug, Clone, Copy)]
pub struct ResourceRow {
    pub label: &'static str,
    pub p: usize,
    pub f_mhz: f64,
    pub lut: u64,
    pub ff: u64,
    pub bram: u64,
    pub uram: u64,
    pub dsp: u64,
}

/// Table 3: resource utilization per optimization (p = 11, 1 CU).
pub const TABLE3: [ResourceRow; 11] = [
    ResourceRow { label: "Baseline", p: 11, f_mhz: 274.6, lut: 141_137, ff: 214_402, bram: 244, uram: 57, dsp: 150 },
    ResourceRow { label: "Double Buffering", p: 11, f_mhz: 259.8, lut: 148_873, ff: 228_561, bram: 246, uram: 57, dsp: 150 },
    ResourceRow { label: "Bus Opt (Serial)", p: 11, f_mhz: 286.5, lut: 146_088, ff: 225_542, bram: 268, uram: 3, dsp: 55 },
    ResourceRow { label: "Bus Opt (Parallel)", p: 11, f_mhz: 296.6, lut: 182_632, ff: 295_340, bram: 330, uram: 12, dsp: 192 },
    ResourceRow { label: "Dataflow (1 compute)", p: 11, f_mhz: 286.2, lut: 215_199, ff: 335_009, bram: 330, uram: 240, dsp: 592 },
    ResourceRow { label: "Dataflow (2 compute)", p: 11, f_mhz: 291.9, lut: 291_964, ff: 446_258, bram: 330, uram: 240, dsp: 1_068 },
    ResourceRow { label: "Dataflow (3 compute)", p: 11, f_mhz: 266.3, lut: 293_757, ff: 448_385, bram: 298, uram: 164, dsp: 1_096 },
    ResourceRow { label: "Dataflow (7 compute)", p: 11, f_mhz: 199.5, lut: 473_743, ff: 735_030, bram: 330, uram: 252, dsp: 3_016 },
    ResourceRow { label: "Mem Sharing (1 compute)", p: 11, f_mhz: 282.4, lut: 229_115, ff: 336_133, bram: 282, uram: 124, dsp: 592 },
    ResourceRow { label: "Fixed Point 64", p: 11, f_mhz: 233.8, lut: 254_242, ff: 342_390, bram: 330, uram: 252, dsp: 4_368 },
    ResourceRow { label: "Fixed Point 32", p: 11, f_mhz: 244.5, lut: 231_062, ff: 346_507, bram: 1_338, uram: 0, dsp: 2_294 },
];

/// Table 4: data representation x polynomial degree (Dataflow-7, 1 CU).
pub const TABLE4: [ResourceRow; 6] = [
    ResourceRow { label: "Double", p: 11, f_mhz: 199.5, lut: 473_743, ff: 735_030, bram: 330, uram: 252, dsp: 3_016 },
    ResourceRow { label: "Double", p: 7, f_mhz: 225.9, lut: 328_267, ff: 527_809, bram: 438, uram: 0, dsp: 1_888 },
    ResourceRow { label: "Fixed Point 64", p: 11, f_mhz: 233.8, lut: 254_242, ff: 342_390, bram: 330, uram: 252, dsp: 4_368 },
    ResourceRow { label: "Fixed Point 64", p: 7, f_mhz: 201.4, lut: 191_348, ff: 299_992, bram: 438, uram: 0, dsp: 2_760 },
    ResourceRow { label: "Fixed Point 32", p: 11, f_mhz: 244.5, lut: 231_062, ff: 346_507, bram: 1_338, uram: 0, dsp: 2_294 },
    ResourceRow { label: "Fixed Point 32", p: 7, f_mhz: 297.0, lut: 177_280, ff: 306_386, bram: 438, uram: 0, dsp: 1_382 },
];

/// One Table 5 / Fig. 17 row: multi-CU replication.
#[derive(Debug, Clone, Copy)]
pub struct MultiCuRow {
    pub label: &'static str,
    pub p: usize,
    pub cus: usize,
    pub f_mhz: f64,
    pub lut: u64,
    pub dsp: u64,
}

/// Table 5: multi-CU builds (225 MHz target).
pub const TABLE5: [MultiCuRow; 6] = [
    MultiCuRow { label: "Double", p: 11, cus: 2, f_mhz: 146.0, lut: 760_903, dsp: 6_020 },
    MultiCuRow { label: "Double", p: 7, cus: 3, f_mhz: 179.2, lut: 777_208, dsp: 5_651 },
    MultiCuRow { label: "Fixed Point 64", p: 11, cus: 2, f_mhz: 132.3, lut: 755_752, dsp: 7_316 },
    MultiCuRow { label: "Fixed Point 64", p: 7, cus: 2, f_mhz: 168.2, lut: 268_285, dsp: 5_508 },
    MultiCuRow { label: "Fixed Point 32", p: 11, cus: 3, f_mhz: 194.0, lut: 479_387, dsp: 6_868 },
    MultiCuRow { label: "Fixed Point 32", p: 7, cus: 4, f_mhz: 178.3, lut: 404_747, dsp: 5_508 },
];

/// Fig. 16 system GFLOPS (Dataflow-7, 1 CU) by dtype and p.
/// fx values are GOPS. (Fig. 16 is read off the described speedups:
/// fx64 = 1.19x double, fx32 = 2.37x double at p=11; §4.2 text.)
pub fn fig16_gflops(dtype: &str, p: usize) -> f64 {
    match (dtype, p) {
        ("f64", 11) => 43.410,
        ("fx64", 11) => 43.410 * 1.19,
        ("fx32", 11) => 103.0,
        // p=7 "slightly slower" than p=11 counterparts
        ("f64", 7) => 38.0,
        ("fx64", 7) => 45.0,
        ("fx32", 7) => 90.0,
        _ => 0.0,
    }
}

/// Fig. 17: multi-CU kernel (CU) and system GOPS for fx32 p=11, 3 CUs.
pub const FIG17_FX32_P11_CU: f64 = 172.0;
pub const FIG17_FX32_P11_SYSTEM: f64 = 87.0;

/// Fig. 18 headline: most efficient implementation ~4 GOPS/W (fx32 p=11
/// 1 CU); 24.5x the Intel estimate.
pub const FIG18_BEST_GOPS_PER_W: f64 = 4.0;
pub const FIG18_INTEL_RATIO: f64 = 24.5;

/// Fig. 19 reference points (double precision).
pub struct Fig19 {
    /// Optimized-FPGA over naive-CPU speedup range reported.
    pub fpga_opt_over_naive: (f64, f64),
    /// Baseline-FPGA over naive-CPU speedup range.
    pub fpga_base_over_naive: (f64, f64),
    /// Optimized FPGA over Intel-optimized, Inverse Helmholtz.
    pub helmholtz_vs_intel: f64,
    /// Optimized FPGA over Intel-optimized, Interpolation.
    pub interp_vs_intel: f64,
    /// Energy-efficiency gains vs Intel (double precision).
    pub efficiency_helmholtz: f64,
    pub efficiency_interp: f64,
}

pub const FIG19: Fig19 = Fig19 {
    fpga_opt_over_naive: (36.4, 160.2),
    fpga_base_over_naive: (10.7, 38.3),
    helmholtz_vs_intel: 2.7,
    interp_vs_intel: 1.4,
    efficiency_helmholtz: 7.0,
    efficiency_interp: 4.8,
};

/// Intel-optimized CPU GFLOPS implied by Fig. 19 (43.41 / 2.7 etc.).
pub fn intel_optimized_gflops(kernel: &str) -> f64 {
    match kernel {
        "helmholtz" => 43.410 / FIG19.helmholtz_vs_intel,
        "interpolation" => 30.0 / FIG19.interp_vs_intel, // approx read-off
        _ => 0.0,
    }
}

/// Fixed-point MSE reported in §4.2.
pub const MSE_FX64: f64 = 9.39e-22;
pub const MSE_FX32: f64 = 3.58e-12;

/// The paper's workload size.
pub const N_ELEMENTS: u64 = 2_000_000;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_ops_column_is_fig15_consistent() {
        assert_eq!(TABLE2[0].ops, 22);
        assert_eq!(TABLE2[7].ops, 532);
        // ideal = ops x f must exceed achieved everywhere
        for r in TABLE2 {
            let ideal = r.ops as f64 * r.f_mhz / 1e3;
            assert!(ideal > r.gflops, "{}", r.label);
            let eff = r.gflops / ideal;
            assert!((eff - r.efficiency).abs() < 0.01, "{}: {eff}", r.label);
        }
    }

    #[test]
    fn mse_ratio_is_about_2_pow_32() {
        let ratio = MSE_FX32 / MSE_FX64;
        assert!(ratio > 2f64.powi(30) && ratio < 2f64.powi(34));
    }

    #[test]
    fn table3_rows_align_with_table4() {
        assert_eq!(TABLE3[7].lut, TABLE4[0].lut);
        assert_eq!(TABLE3[10].bram, TABLE4[4].bram);
    }
}
