//! Command-line interface (hand-rolled; the offline registry has no
//! clap) — a thin client of the `flow` pipeline.
//!
//! ```text
//! hbmflow compile  [--kernel helmholtz|interpolation|gradient | --file prog.cfd]
//!                  [--p 11] [--dataflow N] [--dtype f64|f32|fx64|fx32]
//!                  [--emit c|cfg|wrapper|host|teil|vitis]
//!                  [--save-artifact out.json] [--from-artifact in.json]
//! hbmflow emit-vitis [--kernel .. | --file prog.cfd] [--p 11] [--dtype ..]
//!                  [--preset .. | --dataflow N] [--cus N]
//!                  [--policy local|striped] [--partition-cap N]
//!                  [--cache-scheme bypass|cached:<words>|full] --out DIR
//! hbmflow estimate [--kernel .. | --file ..] [--p ..] [--preset ..] [--cus N]
//! hbmflow simulate [--kernel .. | --file ..] [--p ..] [--preset ..] [--cus N]
//!                  [--elements N] [--cache-scheme ..]   # alias: sim
//! hbmflow run      [--p 7|11] [--dtype ..] [--elements N] [--artifacts DIR]
//! hbmflow sweep    [--elements N]
//! hbmflow ladder   [--elements N]       # the Fig. 15 ladder
//! hbmflow dse      [--kernel .. | --file ..] [--p 7,11] [--dtype ..]
//!                  [--max-cus N] [--ddr4] [--mem-plan] [--top-k N]
//!                  [--pareto-only] [--exact] [--format text|json|csv]
//! hbmflow compose  K1 K2 ... [--p 7] [--dtype ..] [--preset ..] [--cus N]
//!                  [--policy ..] [--elements N] [--layouts]
//!                  # K: builtin name or .cfd path; positional, in
//!                  # pipeline order
//! ```
//!
//! Flags are `--key value` pairs validated against a per-subcommand
//! registry (a misspelled flag errors with a did-you-mean suggestion
//! instead of being silently swallowed); the registered boolean flags
//! (`--pareto-only`, `--ddr4`, `--mem-plan`) may appear bare.
//! `--file prog.cfd` feeds an arbitrary CFDlang program (see
//! docs/CFDLANG.md) through the same flow as the builtin kernels;
//! `--kernel` and `--file` are mutually exclusive. Every subcommand
//! reaches the pipeline through `flow::{Flow, Session}` — this module
//! owns no stage wiring of its own.

use std::collections::HashMap;

use anyhow::{anyhow, bail, Context, Result};

use crate::coordinator::{Driver, HelmholtzWorkload};
use crate::datatype::DataType;
use crate::dse;
use crate::flow::{Artifact, Flow, Session};
use crate::kernels::KernelSource;
use crate::olympus::{self, CacheScheme, ChannelPolicy, OlympusOpts};
use crate::platform::Platform;
use crate::report;
use crate::runtime::Runtime;

/// Flags that may appear bare (no value); all other flags require one.
const BOOL_FLAGS: &[&str] = &["pareto-only", "ddr4", "mem-plan", "exact", "layouts"];

/// Valid `--emit` modes for `compile` — the single source of truth for
/// the dispatch below and the unknown-mode error message.
const EMIT_MODES: &[&str] = &["c", "cfg", "wrapper", "host", "teil", "vitis"];

/// Flags shared by `simulate` and its `sim` alias.
const SIM_FLAGS: &[&str] = &[
    "kernel",
    "file",
    "p",
    "dtype",
    "preset",
    "cus",
    "elements",
    "policy",
    "partition-cap",
    "cache-scheme",
];

/// Per-subcommand flag registry: every flag a command reads. Anything
/// else is a typo and errors at parse time with a suggestion.
const FLAG_REGISTRY: &[(&str, &[&str])] = &[
    (
        "compile",
        &[
            "kernel",
            "file",
            "p",
            "dtype",
            "dataflow",
            "emit",
            "save-artifact",
            "from-artifact",
        ],
    ),
    (
        "emit-vitis",
        &[
            "kernel",
            "file",
            "p",
            "dtype",
            "dataflow",
            "preset",
            "cus",
            "policy",
            "partition-cap",
            "cache-scheme",
            "out",
        ],
    ),
    (
        "estimate",
        &[
            "kernel",
            "file",
            "p",
            "dtype",
            "preset",
            "cus",
            "partition-cap",
            "cache-scheme",
        ],
    ),
    ("simulate", SIM_FLAGS),
    ("sim", SIM_FLAGS),
    ("run", &["p", "dtype", "elements", "cus", "artifacts"]),
    ("ladder", &["elements"]),
    ("sweep", &["elements"]),
    ("explore", &["kernel", "file", "p", "mse-budget", "max-bits"]),
    (
        "dse",
        &[
            "kernel",
            "file",
            "p",
            "dtype",
            "max-cus",
            "ddr4",
            "mem-plan",
            "top-k",
            "pareto-only",
            "format",
            "threads",
            "elements",
            "policy",
            "cache-scheme",
            "exact",
            "strategy",
            "budget",
            "seed",
            "batch",
            "resume",
            "stop-after",
        ],
    ),
    (
        "compose",
        &["p", "dtype", "preset", "cus", "policy", "elements", "layouts"],
    ),
];

/// Known flags for a subcommand (None for unknown commands and help,
/// which are handled by the dispatcher).
fn known_flags(cmd: &str) -> Option<&'static [&'static str]> {
    FLAG_REGISTRY
        .iter()
        .find(|(c, _)| *c == cmd)
        .map(|(_, flags)| *flags)
}

/// Levenshtein edit distance (registry is tiny; O(n·m) is fine).
fn edit_distance(a: &str, b: &str) -> usize {
    let a: Vec<char> = a.chars().collect();
    let b: Vec<char> = b.chars().collect();
    let mut prev: Vec<usize> = (0..=b.len()).collect();
    for (i, &ca) in a.iter().enumerate() {
        let mut cur = vec![i + 1];
        for (j, &cb) in b.iter().enumerate() {
            let cost = usize::from(ca != cb);
            cur.push((prev[j] + cost).min(prev[j + 1] + 1).min(cur[j] + 1));
        }
        prev = cur;
    }
    prev[b.len()]
}

/// The closest registered flag within edit distance 2, if any. An
/// exact match is never a suggestion (hinting "--p" at a user who
/// typed "--p" helps nobody).
fn suggestion(flag: &str, known: &[&'static str]) -> Option<&'static str> {
    known
        .iter()
        .copied()
        .map(|k| (edit_distance(flag, k), k))
        .filter(|&(d, _)| (1..=2).contains(&d))
        .min_by_key(|&(d, _)| d)
        .map(|(_, k)| k)
}

/// `" (did you mean --X?)"` when a close registered flag exists.
fn suggestion_suffix(cmd: &str, flag: &str) -> String {
    known_flags(cmd)
        .and_then(|known| suggestion(flag, known))
        .map(|s| format!(" (did you mean --{s}?)"))
        .unwrap_or_default()
}

/// Parsed `--key value` flags.
pub struct Args {
    pub cmd: String,
    flags: HashMap<String, String>,
}

impl Args {
    pub fn parse(argv: &[String]) -> Result<Args> {
        let cmd = argv.first().cloned().unwrap_or_else(|| "help".into());
        let mut flags = HashMap::new();
        let mut i = 1;
        while i < argv.len() {
            let k = argv[i]
                .strip_prefix("--")
                .ok_or_else(|| anyhow!("expected --flag, got {}", argv[i]))?;
            // registered boolean flags may appear bare; every other flag
            // still requires a value
            let next_is_flag = match argv.get(i + 1) {
                Some(v) => v.starts_with("--"),
                None => true,
            };
            if BOOL_FLAGS.contains(&k) && next_is_flag {
                flags.insert(k.to_string(), "true".to_string());
                i += 1;
                continue;
            }
            if next_is_flag {
                // no value follows: a typo'd flag must not swallow the
                // next --flag token (or die with a bare missing-value
                // message), so name the real problem here
                if let Some(known) = known_flags(&cmd) {
                    if !known.contains(&k) {
                        bail!(
                            "unknown flag --{k} for {cmd}{}",
                            suggestion_suffix(&cmd, k)
                        );
                    }
                }
                bail!("--{k} needs a value");
            }
            flags.insert(k.to_string(), argv[i + 1].clone());
            i += 2;
        }
        // reject unknown/misspelled flags instead of swallowing them
        if let Some(known) = known_flags(&cmd) {
            let mut keys: Vec<&String> = flags.keys().collect();
            keys.sort();
            for k in keys {
                if !known.contains(&k.as_str()) {
                    bail!(
                        "unknown flag --{k} for {cmd}{}",
                        suggestion_suffix(&cmd, k)
                    );
                }
            }
        }
        Ok(Args { cmd, flags })
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    /// Boolean flag: present (bare or any value but false/0) = true.
    pub fn flag(&self, key: &str) -> bool {
        self.get(key).is_some_and(|v| v != "false" && v != "0")
    }

    pub fn usize_or(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            Some(v) => v.parse().with_context(|| format!("--{key} {v}")),
            None => Ok(default),
        }
    }

    pub fn u64_or(&self, key: &str, default: u64) -> Result<u64> {
        match self.get(key) {
            Some(v) => v.parse().with_context(|| format!("--{key} {v}")),
            None => Ok(default),
        }
    }

    pub fn dtype_or(&self, default: DataType) -> Result<DataType> {
        match self.get("dtype") {
            Some(v) => DataType::parse(v).ok_or_else(|| anyhow!("unknown dtype {v}")),
            None => Ok(default),
        }
    }

    /// `--partition-cap N`: cap the memory plan's partition factor
    /// (None = match the access degree, conflict-free).
    pub fn partition_cap(&self) -> Result<Option<usize>> {
        match self.get("partition-cap") {
            Some(v) => v
                .parse()
                .map(Some)
                .with_context(|| format!("--partition-cap {v}")),
            None => Ok(None),
        }
    }

    /// `--policy local|striped` (single value; defaults to local-first).
    /// An unknown name lists the full accepted set, same contract as the
    /// `EMIT_MODES` error.
    pub fn policy(&self) -> Result<ChannelPolicy> {
        match self.get("policy") {
            Some(v) => ChannelPolicy::parse(v).ok_or_else(|| {
                anyhow!(
                    "unknown --policy {v} (valid: {})",
                    ChannelPolicy::PARSE_NAMES.join("|")
                )
            }),
            None => Ok(ChannelPolicy::LocalFirst),
        }
    }

    /// `--cache-scheme bypass|cached:<words>|full` (single value;
    /// defaults to bypass — no scratchpad in front of indexed arrays).
    /// Same unknown-name contract as `--policy`.
    pub fn cache_scheme(&self) -> Result<CacheScheme> {
        match self.get("cache-scheme") {
            Some(v) => CacheScheme::parse(v).ok_or_else(|| {
                anyhow!(
                    "unknown --cache-scheme {v} (valid: {})",
                    CacheScheme::PARSE_NAMES.join("|")
                )
            }),
            None => Ok(CacheScheme::Bypass),
        }
    }
}

/// Build the kernel for a named builtin operator (thin wrapper over the
/// registry, kept for tests/benches/examples).
pub fn build_kernel(kernel: &str, p: usize) -> Result<crate::ir::affine::Kernel> {
    KernelSource::builtin(kernel).build(p).map_err(|e| anyhow!(e))
}

/// Resolve the `--kernel` / `--file` flag pair into a program source.
fn source_from(args: &Args) -> Result<KernelSource> {
    KernelSource::from_flags(args.get("kernel"), args.get("file")).map_err(|e| anyhow!(e))
}

/// Effective degree: `--p` for parameterized builtins; fixed-extent
/// sources (files, inline, gradient) report their nominal degree, and
/// an explicit `--p` on them is an error (it could not be applied) —
/// consistent across compile/estimate/simulate/explore/dse.
fn degree_for(source: &KernelSource, args: &Args, default: usize) -> Result<usize> {
    if source.parameterized() {
        args.usize_or("p", default)
    } else if args.get("p").is_some() {
        bail!(
            "--p only applies to the parameterized builtin kernels; {} has \
             fixed extents",
            source.name()
        );
    } else {
        Ok(source.nominal_degree())
    }
}

/// Resolve a preset name to Olympus options.
pub fn preset(name: &str, dtype: DataType, cus: usize) -> Result<OlympusOpts> {
    let opts = match name {
        "baseline" => OlympusOpts::baseline(),
        "double-buffering" | "db" => OlympusOpts::double_buffering(),
        "bus-serial" => OlympusOpts::bus_serial(),
        "bus-parallel" => OlympusOpts::bus_parallel(),
        "dataflow1" => OlympusOpts::dataflow(1),
        "dataflow2" => OlympusOpts::dataflow(2),
        "dataflow3" => OlympusOpts::dataflow(3),
        "dataflow7" => OlympusOpts::dataflow(7),
        "mem-sharing" => OlympusOpts::mem_sharing(),
        "best" => {
            if dtype.is_fixed() {
                OlympusOpts::fixed_point(dtype)
            } else {
                let mut o = OlympusOpts::dataflow(7);
                o.dtype = dtype;
                o
            }
        }
        other => bail!("unknown preset {other}"),
    };
    let mut opts = opts;
    if name != "best" {
        opts.dtype = dtype;
    }
    Ok(opts.with_cus(cus.max(1)))
}

/// Entry point for the binary.
pub fn main_with_args(argv: &[String]) -> Result<String> {
    // `compose` takes positional kernel operands (builtin names or .cfd
    // paths, in pipeline order) ahead of its flags; peel them off before
    // the flag parser, which rejects bare tokens.
    if argv.first().map(String::as_str) == Some("compose") {
        let operands: Vec<&str> = argv[1..]
            .iter()
            .take_while(|a| !a.starts_with("--"))
            .map(String::as_str)
            .collect();
        let rest: Vec<String> = std::iter::once("compose".to_string())
            .chain(argv[1 + operands.len()..].iter().cloned())
            .collect();
        let args = Args::parse(&rest)?;
        return cmd_compose(&operands, &args);
    }
    let args = Args::parse(argv)?;
    match args.cmd.as_str() {
        "compile" => cmd_compile(&args),
        "emit-vitis" => cmd_emit_vitis(&args),
        "estimate" => cmd_estimate(&args),
        "simulate" | "sim" => cmd_simulate(&args),
        "run" => cmd_run(&args),
        "ladder" => cmd_ladder(&args),
        "sweep" => cmd_sweep(&args),
        "explore" => cmd_explore(&args),
        "dse" => cmd_dse(&args),
        "help" | "-h" | "--help" => Ok(HELP.to_string()),
        other => bail!("unknown command {other}\n{HELP}"),
    }
}

const HELP: &str = "\
hbmflow — DSL-to-HBM-architecture flow (Soldavini et al. 2022 repro)

commands:
  compile   emit C99 / system.cfg / CU wrapper / host steps / teil IR
            (--emit vitis bundles the full Vitis package to stdout)
  emit-vitis  write the complete Vitis package — CU C++, host.cpp,
            link.cfg, Makefile, versioned manifest — under --out DIR
  estimate  HLS resource + frequency estimate for a configuration
  simulate  cycle-approximate system simulation (GFLOPS, power) plus the
            teil::eval numerics oracle (alias: sim)
  run       real numerics through the PJRT artifacts
  ladder    the full Fig. 15 optimization ladder
  sweep     dtype x p x CUs design-space sweep
  explore   fixed-point format exploration under an error budget
  dse       parallel design-space exploration with Pareto-frontier
            extraction over (GFLOPS, energy, BRAM/URAM/DSP)
  compose   place several kernels on one device as a FIFO-chained
            pipeline: channels partitioned, intermediates on-chip;
            positional operands in pipeline order (builtin names or
            .cfd paths), e.g.
              hbmflow compose interpolation gradient helmholtz
            --layouts also prices every fuse/time-multiplex layout

kernel sources (compile / emit-vitis / estimate / simulate / explore / dse):
  --kernel helmholtz|interpolation|gradient   builtin generators
  --file prog.cfd                             any CFDlang program
  (mutually exclusive; see docs/CFDLANG.md and examples/kernels/*.cfd)

flags: --kernel --file --p --dtype --preset --cus --elements --emit
       --artifacts --mse-budget --max-bits
       --out DIR (emit-vitis: output directory, required)
       --policy local|striped (channel allocation)
       --partition-cap N (cap the memory plan's banking factor;
         estimate/simulate — below the reduction trip the simulator
         charges bank-conflict stalls)
       --cache-scheme bypass|cached:<words>|full (scratchpad fronting
         indirectly accessed arrays — gather/scatter kernels; bypass
         pays the pseudo-random HBM penalty, cached:<words> captures
         the reuse fraction its capacity covers, full buffers the
         whole array on chip)
compile artifacts (the flow's staged pipeline, persisted):
       --save-artifact out.json (write the mapped-stage artifact:
         versioned JSON embedding the program + options; reloads to
         bit-identical downstream results)
       --from-artifact in.json  (resume a saved parsed/lowered/mapped
         artifact instead of --kernel/--file)
dse flags: --p 7,11  --max-cus N  --ddr4  --threads N  --elements N
           --policy local,striped  --mem-plan (explore partition-factor
           caps x sharing)  --cache-scheme bypass,cached:128,full
           (sweep indexed-array scratchpad schemes; dense kernels
           collapse the axis)  --top-k N (0 = all)  --pareto-only
           --exact (full event sim for every candidate; default is the
           adaptive analytic screen — same frontier, faster)
           --format text|json|csv
           --strategy stream|random|lhs|hillclimb (budget-aware
             streaming search: never materializes the cross product,
             O(frontier + batch) resident memory; stream reproduces the
             eager frontier bit-for-bit)
           --budget N (candidates to consider; sampling default 256)
           --seed N (sampling PRNG seed; same seed = same report)
           --batch N (evaluate/checkpoint granularity, default 64)
           --resume ck.json (checkpoint file: written after every
             batch, restored on restart; refuses checkpoints from a
             different space/platform/workload/seed)
           --stop-after N (pause after N batches; rerun with the same
             --resume file to continue where it stopped)

unknown or misspelled flags are rejected with a did-you-mean hint.
";

/// Compile options from `--dtype` / `--dataflow`, clamped to the
/// kernel's nest count like the dse normalization.
fn compile_opts(lowered: &crate::flow::Lowered, dtype: DataType, groups: usize) -> OlympusOpts {
    let mut o = OlympusOpts::dataflow(groups.min(lowered.kernel.nests.len()));
    o.dtype = dtype;
    o
}

fn cmd_compile(args: &Args) -> Result<String> {
    let dtype = args.dtype_or(DataType::F64)?;
    let groups = args.usize_or("dataflow", 7)?;
    let platform = Platform::alveo_u280();

    let mapped = if let Some(path) = args.get("from-artifact") {
        if args.get("kernel").is_some() || args.get("file").is_some() {
            bail!("--from-artifact replaces --kernel/--file");
        }
        if args.get("p").is_some() {
            bail!("--p is recorded in the artifact");
        }
        match Artifact::load(path)? {
            Artifact::Parsed(parsed) => {
                let lowered = parsed.lower()?;
                let opts = compile_opts(&lowered, dtype, groups);
                lowered.map(&opts, &platform)?
            }
            Artifact::Lowered(lowered) => {
                let opts = compile_opts(&lowered, dtype, groups);
                lowered.map(&opts, &platform)?
            }
            Artifact::Mapped(mapped) => {
                if args.get("dtype").is_some() || args.get("dataflow").is_some() {
                    bail!(
                        "--dtype/--dataflow are recorded in a mapped artifact; \
                         resume a parsed or lowered artifact to change them"
                    );
                }
                mapped
            }
            Artifact::Evaluated(_) => bail!(
                "evaluated artifacts record results; compile resumes from a \
                 parsed, lowered, or mapped artifact"
            ),
        }
    } else {
        let source = source_from(args)?;
        let p = degree_for(&source, args, 11)?;
        let lowered = Flow::from_source(source).parse(p)?.lower()?;
        let opts = compile_opts(&lowered, dtype, groups);
        lowered.map(&opts, &platform)?
    };

    if let Some(path) = args.get("save-artifact") {
        Artifact::Mapped(mapped.clone()).save(path)?;
    }

    let emit = args.get("emit").unwrap_or("c");
    let out = match emit {
        "c" => crate::codegen::c_emit::emit(
            &mapped.spec.kernel,
            &mapped.spec.schedule,
            mapped.spec.dtype.name(),
        ),
        "cfg" => olympus::config::system_cfg(&mapped.spec),
        "wrapper" => olympus::config::cu_wrapper(&mapped.spec),
        "host" => olympus::config::host_program(&mapped.spec),
        "teil" => mapped.module.to_string(),
        "vitis" => mapped.vitis_package().bundle(),
        other => bail!("unknown --emit {other} (valid: {})", EMIT_MODES.join("|")),
    };
    Ok(out)
}

/// `emit-vitis`: materialize the complete Vitis package — CU C++,
/// host.cpp, link.cfg, Makefile, and the versioned manifest — for one
/// mapped system under `--out DIR` (DESIGN.md §2.9).
fn cmd_emit_vitis(args: &Args) -> Result<String> {
    let source = source_from(args)?;
    let p = degree_for(&source, args, 11)?;
    let dtype = args.dtype_or(DataType::F64)?;
    let cus = args.usize_or("cus", 1)?;
    let groups = args.usize_or("dataflow", 7)?;
    let out = args.get("out").ok_or_else(|| anyhow!("emit-vitis requires --out DIR"))?;
    let platform = Platform::alveo_u280();
    let lowered = Flow::from_source(source).parse(p)?.lower()?;
    let mut opts = match args.get("preset") {
        Some(name) => preset(name, dtype, cus)?,
        None => compile_opts(&lowered, dtype, groups).with_cus(cus.max(1)),
    };
    opts = opts
        .with_policy(args.policy()?)
        .with_cache_scheme(args.cache_scheme()?);
    opts.partition_cap = args.partition_cap()?;
    let mapped = lowered.map(&opts, &platform)?;
    let pkg = mapped.vitis_package();
    let paths = mapped.emit_vitis(out)?;
    let mut text = format!(
        "{} -> {out}: {} files, fingerprint {}\n",
        mapped.spec.name,
        paths.len(),
        pkg.fingerprint()
    );
    for p in &paths {
        text.push_str(&format!("  {}\n", p.display()));
    }
    Ok(text)
}

fn cmd_estimate(args: &Args) -> Result<String> {
    let source = source_from(args)?;
    let p = degree_for(&source, args, 11)?;
    let dtype = args.dtype_or(DataType::F64)?;
    let cus = args.usize_or("cus", 1)?;
    let mut opts = preset(args.get("preset").unwrap_or("dataflow7"), dtype, cus)?
        .with_cache_scheme(args.cache_scheme()?);
    opts.partition_cap = args.partition_cap()?;
    let platform = Platform::alveo_u280();
    let mapped = Flow::from_source(source)
        .parse(p)?
        .lower()?
        .map(&opts, &platform)?;
    let ev = mapped.estimate();
    let e = &ev.hls;
    let u = e.utilization(&platform);
    Ok(format!(
        "{} p={p} dtype={} cus={cus}\n\
         ops: {} ({} mult + {} add), II={}\n\
         fmax: {:.1} MHz (target {}), SLR span {}\n\
         LUT  {:>9} ({:.1}%)\nFF   {:>9} ({:.1}%)\nBRAM {:>9} ({:.1}%)\n\
         URAM {:>9} ({:.1}%)\nDSP  {:>9} ({:.1}%)\n\
         batch: {} elements/channel, lanes {}",
        opts.label(),
        dtype,
        e.ops(),
        e.mults,
        e.adds,
        e.ii,
        e.fmax_mhz,
        opts.target_freq_mhz,
        e.slr_span,
        e.total.lut,
        u[0] * 100.0,
        e.total.ff,
        u[1] * 100.0,
        e.total.bram,
        u[2] * 100.0,
        e.total.uram,
        u[3] * 100.0,
        e.total.dsp,
        u[4] * 100.0,
        mapped.spec.batch_elements,
        mapped.spec.lanes,
    ))
}

fn cmd_simulate(args: &Args) -> Result<String> {
    let source = source_from(args)?;
    let p = degree_for(&source, args, 11)?;
    let dtype = args.dtype_or(DataType::F64)?;
    let cus = args.usize_or("cus", 1)?;
    let n = args.u64_or("elements", report::paper::N_ELEMENTS)?;
    let mut opts = preset(args.get("preset").unwrap_or("dataflow7"), dtype, cus)?
        .with_policy(args.policy()?)
        .with_cache_scheme(args.cache_scheme()?);
    opts.partition_cap = args.partition_cap()?;
    let platform = Platform::alveo_u280();
    let mapped = Flow::from_source(source)
        .parse(p)?
        .lower()?
        .map(&opts, &platform)?;
    // generic numerics oracle: the lowered kernel vs teil::eval on a few
    // seeded elements (no closed form needed — works for any --file);
    // the Mapped stage carries module and kernel from one parse, so the
    // cross-check is always of the same program
    let oracle = mapped.oracle(2024, 4)?;
    let ev = mapped.simulate(n);
    let r = ev.sim().expect("simulate evaluation carries a sim result");
    let stages: Vec<String> = r
        .stage_intervals
        .iter()
        .map(|(n, c)| format!("{n}={c}"))
        .collect();
    let channels: Vec<String> = r
        .channel_utilization
        .iter()
        .map(|(pc, u)| format!("HBM[{pc}]={u:.2}"))
        .collect();
    Ok(format!(
        "{} [{}] p={p} dtype={} cus={cus} elements={n}\n\
         CU     : {:.3} GFLOPS ({:.3} s busy)\n\
         System : {:.3} GFLOPS ({:.3} s wall)\n\
         f={:.1} MHz  ideal={:.2} GFLOPS  efficiency={:.3}\n\
         power {:.1} W  ->  {:.2} GFLOPS/W  ({:.0} J)\n\
         bottleneck: {}  stages/element: {}\n\
         interconnect ({}): {} switch crossings, fill {} cyc/batch\n\
         channel utilization: {}\n\
         memory plan: {} arrays in {} banks, {} words/lane on chip \
         ({} unshared), conflict stalls {} cyc/element\n\
         oracle : MSE {:.3e}  max|err| {:.3e} (lowered kernel vs \
         teil::eval, {} elements)",
        r.label,
        mapped.provenance.kernel,
        dtype,
        r.gflops_cu,
        r.cu_time_s,
        r.gflops_system,
        r.total_time_s,
        r.freq_mhz,
        r.ideal_gflops,
        r.efficiency_vs_ideal,
        r.avg_power_w,
        r.efficiency_gflops_w,
        r.energy_j,
        r.bottleneck,
        stages.join(" "),
        mapped.spec.opts.channel_policy.name(),
        r.switch_crossings,
        r.hbm_fill_cycles,
        channels.join(" "),
        mapped.spec.memory.arrays.len(),
        r.mem_banks,
        r.mem_shared_words,
        r.mem_unshared_words,
        r.conflict_stalls,
        oracle.mse,
        oracle.max_abs_err,
        oracle.elements,
    ))
}

fn cmd_run(args: &Args) -> Result<String> {
    let p = args.usize_or("p", 7)?;
    let dtype = args.dtype_or(DataType::F64)?;
    let n = args.u64_or("elements", 256)? as usize;
    let cus = args.usize_or("cus", 1)?;
    let mut rt = match args.get("artifacts") {
        Some(dir) => Runtime::new(dir)?,
        None => Runtime::from_default_dir()?,
    };
    let opts = preset("best", dtype, cus)?;
    let mapped = Flow::from_source(KernelSource::builtin("helmholtz"))
        .parse(p)?
        .lower()?
        .map(&opts, &Platform::alveo_u280())?;
    let artifact = Driver::artifact_for(&rt, &mapped.spec, p)?;
    let w = HelmholtzWorkload::generate(p, n, 2024);
    let mut driver = Driver::new(&mut rt, mapped.spec.clone(), artifact);
    let r = driver.run(&w, 16.min(n))?;
    Ok(format!(
        "artifact {}  elements {}  invocations {}\n\
         wall {:.3} s  ->  measured {:.3} GFLOPS (XLA-CPU datapath)\n\
         numerics vs f64 oracle: MSE {:.3e}  max |err| {:.3e}\n\
         per-CU elements: {:?}",
        r.artifact,
        r.elements,
        r.invocations,
        r.wall_s,
        r.measured_gflops,
        r.mse_vs_oracle,
        r.max_abs_err,
        r.per_cu_elements,
    ))
}

fn cmd_ladder(args: &Args) -> Result<String> {
    let n = args.u64_or("elements", report::paper::N_ELEMENTS)?;
    // one Session: the eight rungs share a single parse + lower
    let session = Session::new(Platform::alveo_u280());
    let src = KernelSource::builtin("helmholtz");
    let ladder: Vec<(usize, OlympusOpts)> = vec![
        (0, OlympusOpts::baseline()),
        (1, OlympusOpts::double_buffering()),
        (2, OlympusOpts::bus_serial()),
        (3, OlympusOpts::bus_parallel()),
        (4, OlympusOpts::dataflow(1)),
        (5, OlympusOpts::dataflow(2)),
        (6, OlympusOpts::dataflow(3)),
        (7, OlympusOpts::dataflow(7)),
    ];
    let mut rows = Vec::new();
    for (i, opts) in ladder {
        let ev = session.mapped(&src, 11, &opts)?.simulate(n);
        let r = ev.sim().expect("simulate evaluation carries a sim result");
        let paper = report::paper::TABLE2[i];
        rows.push(vec![
            opts.label(),
            format!("{}", ev.hls.ops()),
            report::f(r.freq_mhz),
            report::f(r.gflops_cu),
            report::f(r.gflops_system),
            report::f(paper.gflops),
            format!("{:.2}", r.gflops_system / paper.gflops),
            format!("{:.3}", r.efficiency_vs_ideal),
            format!("{:.3}", paper.efficiency),
        ]);
    }
    Ok(report::table(
        &[
            "implementation",
            "#Ops",
            "f(MHz)",
            "CU",
            "System",
            "paper",
            "ratio",
            "eff",
            "eff(paper)",
        ],
        &rows,
    ))
}

fn cmd_sweep(args: &Args) -> Result<String> {
    let n = args.u64_or("elements", report::paper::N_ELEMENTS)?;
    let session = Session::new(Platform::alveo_u280());
    let src = KernelSource::builtin("helmholtz");
    let budget = session.platform().total_resources();
    let mut rows = Vec::new();
    for p in [11usize, 7] {
        for dtype in [DataType::F64, DataType::Fx64, DataType::Fx32] {
            for cus in [1usize, 2, 3, 4] {
                let mut opts = if dtype.is_fixed() {
                    OlympusOpts::fixed_point(dtype)
                } else {
                    OlympusOpts::dataflow(7)
                };
                opts = opts.with_cus(cus);
                let Ok(mapped) = session.mapped(&src, p, &opts) else {
                    continue;
                };
                // one evaluation: the estimate rides along with the sim
                let ev = mapped.simulate(n);
                if !ev.hls.total.fits_in(&budget) {
                    continue; // infeasible replication
                }
                let r = ev.sim().expect("simulate evaluation carries a sim result");
                rows.push(vec![
                    format!("{} p={p} x{cus}", dtype.display()),
                    report::f(r.freq_mhz),
                    report::f(r.gflops_cu),
                    report::f(r.gflops_system),
                    report::f(r.avg_power_w),
                    format!("{:.2}", r.efficiency_gflops_w),
                    r.bottleneck.clone(),
                ]);
            }
        }
    }
    Ok(report::table(
        &["configuration", "f(MHz)", "CU", "System", "W", "GF/W", "bound"],
        &rows,
    ))
}

fn cmd_explore(args: &Args) -> Result<String> {
    use crate::precision::{self, Interval};
    let source = source_from(args)?;
    let p = degree_for(&source, args, 11)?;
    let budget: f64 = match args.get("mse-budget") {
        Some(v) => v.parse().with_context(|| format!("--mse-budget {v}"))?,
        None => 3.6e-12, // the paper's fx32 error
    };
    let max_bits = args.usize_or("max-bits", 64)? as u32;
    let parsed = Flow::from_source(source).parse(p)?;
    // the workload rescales operators to near-orthonormal rows (~1/p)
    let range = Interval::symmetric(1.0 / p.max(1) as f64);
    let analysis = precision::analyze_ranges(&parsed.module, range);
    let cands = precision::explore(&parsed.module, range, budget, max_bits);
    let mut rows = Vec::new();
    for c in cands.iter().take(10) {
        rows.push(vec![
            c.name(),
            format!("{}", c.total_bits()),
            format!("{:.2e}", c.predicted_mse),
            format!("{}", c.dsp_per_mult),
        ]);
    }
    Ok(format!(
        "range analysis: max |value| = {:.3} -> {} integer bits\n\
         {} feasible formats under MSE budget {budget:.1e} (showing cheapest 10):\n{}",
        analysis.max_abs,
        cands.first().map(|c| c.int_bits).unwrap_or(0),
        cands.len(),
        report::table(&["format", "bits", "pred. MSE", "DSP/mult"], &rows)
    ))
}

fn cmd_dse(args: &Args) -> Result<String> {
    let source = source_from(args)?;
    let mut space = dse::SearchSpace::for_source(source);
    if let Some(list) = args.get("p") {
        if !space.source.parameterized() {
            // fixed-extent programs (files, inline, gradient) would
            // enumerate duplicate physical designs per degree
            bail!(
                "--p only applies to the parameterized builtin kernels; \
                 {} has fixed extents",
                space.kernel
            );
        }
        space.degrees = list
            .split(',')
            .map(|s| s.trim().parse().with_context(|| format!("--p {list}")))
            .collect::<Result<Vec<usize>>>()?;
    }
    if let Some(d) = args.get("dtype") {
        if d != "all" {
            let dt = DataType::parse(d).ok_or_else(|| anyhow!("unknown dtype {d}"))?;
            space.dtypes = vec![dt];
        }
    }
    let max_cus = args.usize_or("max-cus", 4)?.max(1);
    space.cu_counts = (1..=max_cus).collect();
    if args.flag("ddr4") {
        space.memories.push(crate::olympus::MemoryKind::Ddr4);
    }
    if args.flag("mem-plan") {
        // the memory axis: partition-factor caps below the kernel's
        // access degree trade BRAM/URAM banks for simulated
        // bank-conflict stalls (sharing on/off is already a default
        // axis; inert caps normalize away in dse::explore)
        space.partition_caps = vec![None, Some(4), Some(2)];
    }
    if let Some(list) = args.get("policy") {
        space.channel_policies = list
            .split(',')
            .map(|s| {
                ChannelPolicy::parse(s.trim()).ok_or_else(|| {
                    anyhow!(
                        "unknown --policy {s} (valid: {})",
                        ChannelPolicy::PARSE_NAMES.join("|")
                    )
                })
            })
            .collect::<Result<Vec<_>>>()?;
    }
    if let Some(list) = args.get("cache-scheme") {
        // the irregular-access axis: scratchpad schemes for indexed
        // arrays (dense kernels normalize every scheme back to bypass)
        space.cache_schemes = list
            .split(',')
            .map(|s| {
                CacheScheme::parse(s.trim()).ok_or_else(|| {
                    anyhow!(
                        "unknown --cache-scheme {s} (valid: {})",
                        CacheScheme::PARSE_NAMES.join("|")
                    )
                })
            })
            .collect::<Result<Vec<_>>>()?;
    }
    let n = args.u64_or("elements", report::paper::N_ELEMENTS)?;
    let threads = match args.get("threads") {
        Some(t) => Some(t.parse::<usize>().with_context(|| format!("--threads {t}"))?),
        None => None,
    };

    // default: adaptive fidelity (analytic screen + exact event sim for
    // the survivors — same frontier); --exact forces full event
    // simulation for every candidate
    let fidelity = if args.flag("exact") {
        dse::Fidelity::Exact
    } else {
        dse::Fidelity::Adaptive
    };
    let session = Session::new(Platform::alveo_u280());
    let ex = if let Some(name) = args.get("strategy") {
        let strategy = dse::Strategy::parse(name).ok_or_else(|| {
            anyhow!("unknown --strategy {name} (stream|random|lhs|hillclimb)")
        })?;
        let budget = match args.get("budget") {
            Some(v) => {
                Some(v.parse::<usize>().with_context(|| format!("--budget {v}"))?)
            }
            None => None,
        };
        let stop_after = match args.get("stop-after") {
            Some(v) => Some(
                v.parse::<usize>()
                    .with_context(|| format!("--stop-after {v}"))?,
            ),
            None => None,
        };
        let cfg = dse::SearchConfig {
            strategy,
            seed: args.u64_or("seed", 0)?,
            budget,
            batch: args.usize_or("batch", 64)?,
            threads,
            prune: !args.flag("exact"),
            checkpoint: args.get("resume").map(std::path::PathBuf::from),
            stop_after,
        };
        dse::search_in(&session, &space, n, &cfg).map_err(|e| anyhow!(e))?
    } else {
        for f in ["budget", "seed", "batch", "resume", "stop-after"] {
            if args.get(f).is_some() {
                bail!("--{f} requires --strategy stream|random|lhs|hillclimb");
            }
        }
        dse::explore_in_with(&session, &space, n, threads, fidelity)
            .map_err(|e| anyhow!(e))?
    };

    // default: whole frontier with --pareto-only, top 25 otherwise
    let pareto_only = args.flag("pareto-only");
    let top_k = args.usize_or("top-k", if pareto_only { 0 } else { 25 })?;
    match args.get("format").unwrap_or("text") {
        "text" => Ok(dse::report::text(&ex, top_k, pareto_only)),
        "json" => Ok(dse::report::json(&ex)),
        "csv" => Ok(dse::report::csv(&ex)),
        other => bail!("unknown --format {other} (text|json|csv)"),
    }
}

/// `hbmflow compose K1 K2 ... [flags]`: fuse several kernels on one
/// device as a FIFO-chained pipeline (DESIGN.md §2.10). Operands are
/// positional, in pipeline order: builtin names or `.cfd` paths.
fn cmd_compose(operands: &[&str], args: &Args) -> Result<String> {
    if operands.is_empty() {
        bail!(
            "compose needs kernel operands in pipeline order (builtin \
             names or .cfd paths), e.g. `hbmflow compose interpolation \
             gradient helmholtz`"
        );
    }
    let platform = Platform::alveo_u280();
    let dtype = args.dtype_or(DataType::F64)?;
    let cus = args.usize_or("cus", 1)?;
    let mut opts = preset(args.get("preset").unwrap_or("baseline"), dtype, cus)?;
    opts.channel_policy = args.policy()?;
    let elements = args.u64_or("elements", 100_000)?;

    let mut lowered = Vec::new();
    for op in operands {
        let source = if op.ends_with(".cfd") {
            KernelSource::file(*op)
        } else {
            KernelSource::builtin(op)
        };
        // --p parameterizes the builtins that take a degree; fixed-extent
        // members (files, gradient) keep their nominal degree
        let p = if source.parameterized() {
            args.usize_or("p", 7)?
        } else {
            source.nominal_degree()
        };
        lowered.push(Flow::from_source(source).parse(p)?.lower()?);
    }
    let composed = crate::flow::compose(&lowered, &opts, &platform)?;
    let r = composed.simulate(elements);

    let sys = &composed.system;
    let mut out = String::new();
    use std::fmt::Write as _;
    let _ = writeln!(out, "composed system {}", sys.name);
    let _ = writeln!(
        out,
        "  stages {}   pseudo-channels {}/{}   common batch {} elements",
        sys.stages.len(),
        sys.total_pcs(),
        platform.hbm.pseudo_channels,
        sys.batch_elements,
    );
    for (i, (name, t)) in r.stage_names.iter().zip(&r.stage_t_batch_s).enumerate() {
        let _ = writeln!(
            out,
            "  stage {i}: {name}  cus {}  t_batch {:.3} us",
            sys.stages[i].num_cus,
            t * 1e6,
        );
    }
    for l in &sys.links {
        let _ = writeln!(
            out,
            "  link {}->{}: fifo {} x {} B ({} B on-chip, no HBM round trip)",
            l.producer,
            l.consumer,
            l.fifo.depth_words,
            l.fifo.word_bytes,
            l.fifo.bytes(),
        );
    }
    let _ = writeln!(
        out,
        "  resources: {} LUT, {} BRAM, {} URAM, {} DSP (fits {})",
        sys.resources.lut,
        sys.resources.bram,
        sys.resources.uram,
        sys.resources.dsp,
        platform.name,
    );
    let _ = writeln!(
        out,
        "  {} elements @ {:.1} MHz: fifo-routed {:.3} ms vs \
         time-multiplexed {:.3} ms (speedup {:.2}x)",
        r.n_elements,
        r.freq_mhz,
        r.total_s * 1e3,
        r.time_multiplexed_s * 1e3,
        r.speedup_vs_time_multiplexed,
    );
    let _ = writeln!(
        out,
        "  analytic bracket [{:.3}, {:.3}] ms   bottleneck {}   {:.2} GFLOPS",
        r.analytic.lower_s * 1e3,
        r.analytic.upper_s * 1e3,
        r.bottleneck,
        r.gflops_system,
    );

    if args.flag("layouts") {
        let members: Vec<(&crate::ir::affine::Kernel, OlympusOpts)> = lowered
            .iter()
            .map(|l| (&l.kernel, opts.clone()))
            .collect();
        let ex = dse::explore_layouts(&members, &platform, elements);
        let _ = writeln!(out, "\nlayouts ({} fuse masks):", ex.layouts.len());
        for (i, l) in ex.layouts.iter().enumerate() {
            let segs: Vec<String> = l
                .segments
                .iter()
                .map(|&(lo, hi)| {
                    r.stage_names[lo..=hi].join("+")
                })
                .collect();
            let tag = if ex.frontier.contains(&i) { "  *" } else { "" };
            match (l.total_s, &l.rejected) {
                (Some(t), _) => {
                    let _ = writeln!(
                        out,
                        "  [{}] {:.3} ms  bram {}  dsp {}{tag}",
                        segs.join(" | "),
                        t * 1e3,
                        l.resources.bram,
                        l.resources.dsp,
                    );
                }
                (None, reason) => {
                    let _ = writeln!(
                        out,
                        "  [{}] infeasible: {}",
                        segs.join(" | "),
                        reason.as_deref().unwrap_or("unknown"),
                    );
                }
            }
        }
        let _ = writeln!(out, "  (* = Pareto frontier over time/BRAM/URAM/DSP)");
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn run(args: &[&str]) -> Result<String> {
        let v: Vec<String> = args.iter().map(|s| s.to_string()).collect();
        main_with_args(&v)
    }

    #[test]
    fn help_prints() {
        assert!(run(&["help"]).unwrap().contains("hbmflow"));
        assert!(run(&["bogus"]).is_err());
    }

    #[test]
    fn compile_emits_c() {
        let c = run(&["compile", "--p", "7", "--emit", "c"]).unwrap();
        assert!(c.contains("#pragma HLS pipeline"));
    }

    #[test]
    fn compile_emits_cfg_and_wrapper_and_teil() {
        assert!(run(&["compile", "--emit", "cfg"]).unwrap().contains("[connectivity]"));
        assert!(run(&["compile", "--emit", "wrapper"]).unwrap().contains("dataflow"));
        assert!(run(&["compile", "--emit", "host"]).unwrap().contains("TransferIn"));
        assert!(run(&["compile", "--emit", "teil"]).unwrap().contains("mode_apply"));
    }

    #[test]
    fn compile_unknown_kernel_is_an_error_in_every_emit_mode() {
        // regression: --emit teil used to fall through to the gradient
        // source for any unrecognized --kernel name
        for &emit in EMIT_MODES {
            let err = run(&["compile", "--kernel", "bogus", "--emit", emit])
                .unwrap_err()
                .to_string();
            assert!(err.contains("unknown kernel"), "--emit {emit}: {err}");
        }
    }

    #[test]
    fn compile_emit_vitis_bundles_the_package() {
        let s = run(&["compile", "--p", "7", "--emit", "vitis"]).unwrap();
        assert!(s.contains("==== src/helmholtz.cpp ===="), "{s}");
        assert!(s.contains("==== link.cfg ===="), "{s}");
        assert!(s.contains("XCL_MEM_TOPOLOGY"), "{s}");
    }

    #[test]
    fn unknown_emit_mode_lists_the_valid_set() {
        let err = run(&["compile", "--emit", "bogus"]).unwrap_err().to_string();
        assert!(err.contains("unknown --emit bogus"), "{err}");
        for &mode in EMIT_MODES {
            assert!(err.contains(mode), "{mode} missing from: {err}");
        }
        // and every listed mode actually works
        for &mode in EMIT_MODES {
            assert!(run(&["compile", "--p", "7", "--emit", mode]).is_ok(), "{mode}");
        }
    }

    #[test]
    fn emit_vitis_writes_the_package_tree() {
        let dir = std::env::temp_dir().join("hbmflow_cli_vitis");
        std::fs::remove_dir_all(&dir).ok();
        let d = dir.to_str().unwrap();
        let s = run(&["emit-vitis", "--p", "7", "--cus", "2", "--out", d]).unwrap();
        assert!(s.contains("5 files"), "{s}");
        for f in ["src/helmholtz.cpp", "src/host.cpp", "link.cfg", "Makefile", "package.json"] {
            assert!(dir.join(f).is_file(), "{f} not written");
        }
        let cfg = std::fs::read_to_string(dir.join("link.cfg")).unwrap();
        assert!(cfg.contains("nk=helmholtz:2:helmholtz_1.helmholtz_2"), "{cfg}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn emit_vitis_requires_out() {
        let err = run(&["emit-vitis", "--p", "7"]).unwrap_err().to_string();
        assert!(err.contains("--out"), "{err}");
    }

    #[test]
    fn kernel_and_file_are_mutually_exclusive() {
        let err = run(&["compile", "--kernel", "helmholtz", "--file", "x.cfd"])
            .unwrap_err()
            .to_string();
        assert!(err.contains("mutually exclusive"), "{err}");
    }

    #[test]
    fn compile_from_file_supports_every_emit_mode() {
        let path = std::env::temp_dir().join("hbmflow_cli_compile.cfd");
        std::fs::write(
            &path,
            "var input A : [4 4]\nvar input u : [4 4 4]\n\
             var output w : [4 4 4]\nw = A # u . [[1 2]]\n",
        )
        .unwrap();
        let f = path.to_str().unwrap();
        assert!(run(&["compile", "--file", f, "--emit", "c"])
            .unwrap()
            .contains("#pragma HLS"));
        assert!(run(&["compile", "--file", f, "--emit", "cfg"])
            .unwrap()
            .contains("[connectivity]"));
        assert!(run(&["compile", "--file", f, "--emit", "wrapper"])
            .unwrap()
            .contains("void"));
        assert!(run(&["compile", "--file", f, "--emit", "host"])
            .unwrap()
            .contains("TransferIn"));
        assert!(run(&["compile", "--file", f, "--emit", "teil"])
            .unwrap()
            .contains("mode_apply"));
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn compile_missing_file_reports_path() {
        let err = run(&["compile", "--file", "/no/such/prog.cfd"])
            .unwrap_err()
            .to_string();
        assert!(err.contains("/no/such/prog.cfd"), "{err}");
    }

    #[test]
    fn compile_save_and_from_artifact_round_trip() {
        let path = std::env::temp_dir().join("hbmflow_cli_artifact.json");
        let f = path.to_str().unwrap();
        let direct =
            run(&["compile", "--p", "7", "--emit", "cfg", "--save-artifact", f]).unwrap();
        let resumed = run(&["compile", "--from-artifact", f, "--emit", "cfg"]).unwrap();
        assert_eq!(direct, resumed, "artifact resume is bit-identical");
        let c = run(&["compile", "--from-artifact", f, "--emit", "c"]).unwrap();
        assert!(c.contains("#pragma HLS"), "{c}");
        // a mapped artifact pins its recorded configuration
        assert!(run(&["compile", "--from-artifact", f, "--dtype", "f32"]).is_err());
        assert!(run(&["compile", "--from-artifact", f, "--p", "11"]).is_err());
        assert!(run(&["compile", "--from-artifact", f, "--kernel", "gradient"]).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn from_artifact_rejects_missing_and_garbage_files() {
        assert!(run(&["compile", "--from-artifact", "/no/such.json"]).is_err());
        let path = std::env::temp_dir().join("hbmflow_cli_garbage.json");
        std::fs::write(&path, "{\"schema\": 1}").unwrap();
        let err = run(&["compile", "--from-artifact", path.to_str().unwrap()])
            .unwrap_err()
            .to_string();
        assert!(err.contains("missing"), "{err}");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn unknown_flags_error_with_suggestions() {
        let err = run(&["compile", "--kernl", "helmholtz"]).unwrap_err().to_string();
        assert!(err.contains("unknown flag --kernl"), "{err}");
        assert!(err.contains("did you mean --kernel"), "{err}");
        let err = run(&["simulate", "--element", "100"]).unwrap_err().to_string();
        assert!(err.contains("did you mean --elements"), "{err}");
        // a flag valid for another subcommand is still unknown here
        let err = run(&["ladder", "--kernel", "helmholtz"]).unwrap_err().to_string();
        assert!(err.contains("unknown flag --kernel for ladder"), "{err}");
        // misspelled bare boolean flags are named, trailing or mid-argv
        // (a typo must never swallow the next --flag token as its value)
        let err = run(&["dse", "--p", "11", "--ddr"]).unwrap_err().to_string();
        assert!(err.contains("did you mean --ddr4"), "{err}");
        let err = run(&["dse", "--ddr", "--p", "11"]).unwrap_err().to_string();
        assert!(err.contains("unknown flag --ddr"), "{err}");
        assert!(err.contains("did you mean --ddr4"), "{err}");
        // a known flag missing its value is not "suggested" back
        let err = run(&["compile", "--p"]).unwrap_err().to_string();
        assert!(err.contains("--p needs a value"), "{err}");
        assert!(!err.contains("did you mean"), "{err}");
    }

    #[test]
    fn simulate_reports_the_generic_oracle() {
        let s = run(&["simulate", "--preset", "baseline", "--elements", "50000"])
            .unwrap();
        assert!(s.contains("oracle"), "{s}");
        assert!(s.contains("MSE"), "{s}");
        assert!(s.contains("teil::eval"), "{s}");
        // exact lowering: the f64 datapaths agree bit-for-bit
        assert!(s.contains("MSE 0.000e0") || s.contains("MSE 0e0"), "{s}");
    }

    #[test]
    fn sim_alias_matches_simulate() {
        let a = run(&["sim", "--preset", "baseline", "--elements", "50000"]).unwrap();
        let b = run(&["simulate", "--preset", "baseline", "--elements", "50000"])
            .unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn explicit_p_on_fixed_extent_sources_errors_everywhere() {
        // consistent across subcommands: --p cannot be applied to a
        // fixed-extent program, so it is an error rather than ignored
        for cmd in ["compile", "estimate", "simulate", "explore", "dse"] {
            let err = run(&[cmd, "--kernel", "gradient", "--p", "7"])
                .unwrap_err()
                .to_string();
            assert!(err.contains("fixed extents"), "{cmd}: {err}");
        }
    }

    #[test]
    fn estimate_reports_resources() {
        let s = run(&["estimate", "--preset", "dataflow7"]).unwrap();
        assert!(s.contains("ops: 532"), "{s}");
        assert!(s.contains("fmax"));
    }

    #[test]
    fn simulate_reports_gflops() {
        let s = run(&["simulate", "--preset", "baseline", "--elements", "100000"]).unwrap();
        assert!(s.contains("System"), "{s}");
        assert!(s.contains("bottleneck"));
    }

    #[test]
    fn simulate_reports_channel_utilization_and_crossings() {
        let local = run(&["simulate", "--preset", "dataflow7", "--elements", "100000"])
            .unwrap();
        assert!(local.contains("channel utilization"), "{local}");
        assert!(local.contains("HBM[0]"), "{local}");
        assert!(local.contains("0 switch crossings"), "{local}");
        let striped = run(&[
            "simulate", "--preset", "dataflow7", "--elements", "100000",
            "--policy", "striped",
        ])
        .unwrap();
        assert!(striped.contains("(striped)"), "{striped}");
        assert!(!striped.contains(" 0 switch crossings"), "{striped}");
        assert!(run(&["simulate", "--policy", "bogus"]).is_err());
    }

    #[test]
    fn unknown_policy_lists_the_valid_set() {
        // same contract as the EMIT_MODES error: every accepted name is
        // in the message, and every listed name actually parses
        for cmd_args in [
            vec!["simulate", "--policy", "zigzag"],
            vec!["dse", "--p", "11", "--policy", "zigzag"],
        ] {
            let err = run(&cmd_args).unwrap_err().to_string();
            assert!(err.contains("unknown --policy zigzag"), "{err}");
            for name in ChannelPolicy::PARSE_NAMES {
                assert!(err.contains(name), "{name} missing from: {err}");
            }
        }
        for name in ChannelPolicy::PARSE_NAMES {
            assert!(ChannelPolicy::parse(name).is_some(), "{name}");
        }
    }

    #[test]
    fn unknown_cache_scheme_lists_the_valid_set() {
        // same contract as --policy: every accepted form is in the
        // message, and every concrete listed form actually parses
        for cmd_args in [
            vec!["simulate", "--cache-scheme", "zigzag"],
            vec!["estimate", "--cache-scheme", "cached:0"],
            vec!["dse", "--p", "11", "--cache-scheme", "zigzag"],
        ] {
            let err = run(&cmd_args).unwrap_err().to_string();
            assert!(err.contains("unknown --cache-scheme"), "{err}");
            for name in CacheScheme::PARSE_NAMES {
                assert!(err.contains(name), "{name} missing from: {err}");
            }
        }
        for name in ["bypass", "cached:128", "full"] {
            assert!(CacheScheme::parse(name).is_some(), "{name}");
        }
    }

    #[test]
    fn ladder_has_eight_rows() {
        let s = run(&["ladder", "--elements", "200000"]).unwrap();
        assert_eq!(s.lines().count(), 2 + 8, "{s}");
        assert!(s.contains("Dataflow (7 compute)"));
    }

    #[test]
    fn explore_lists_formats() {
        let s = run(&["explore", "--mse-budget", "1e-12"]).unwrap();
        assert!(s.contains("ap_fixed<"), "{s}");
        assert!(s.contains("feasible formats"));
        let tight = run(&["explore", "--mse-budget", "1e-22"]).unwrap();
        assert!(tight.contains("ap_fixed<"));
    }

    #[test]
    fn bad_flags_are_rejected() {
        assert!(run(&["simulate", "oops"]).is_err());
        assert!(run(&["simulate", "--p"]).is_err(), "--p needs a value");
        assert!(run(&["run", "--artifacts"]).is_err(), "--artifacts needs a value");
        assert!(run(&["simulate", "--dtype", "q4"]).is_err());
    }

    #[test]
    fn bare_flags_parse_as_booleans() {
        let a = Args::parse(&[
            "dse".into(),
            "--pareto-only".into(),
            "--p".into(),
            "11".into(),
            "--ddr4".into(),
        ])
        .unwrap();
        assert!(a.flag("pareto-only"));
        assert!(a.flag("ddr4"));
        assert!(!a.flag("absent"));
        assert_eq!(a.get("p"), Some("11"));
    }

    #[test]
    fn dse_reports_a_frontier() {
        // narrow slice of the space so the debug-mode test stays fast
        let s = run(&[
            "dse", "--p", "11", "--dtype", "fx32", "--max-cus", "2",
            "--elements", "200000", "--threads", "2", "--pareto-only",
        ])
        .unwrap();
        assert!(s.contains("Pareto frontier"), "{s}");
        assert!(s.contains("Fixed Point 32"), "{s}");
        assert!(s.contains("candidates enumerated"), "{s}");
    }

    #[test]
    fn dse_strategy_runs_a_budgeted_sweep() {
        let s = run(&[
            "dse", "--p", "11", "--dtype", "fx32", "--max-cus", "1",
            "--elements", "100000", "--threads", "2", "--strategy", "lhs",
            "--budget", "8", "--seed", "7",
        ])
        .unwrap();
        assert!(s.contains("candidates considered"), "{s}");
        assert!(s.contains("Pareto frontier"), "{s}");
        assert!(run(&["dse", "--strategy", "bogus"]).is_err());
    }

    #[test]
    fn dse_search_flags_require_a_strategy() {
        let err = run(&["dse", "--budget", "8"]).unwrap_err();
        assert!(err.to_string().contains("--strategy"), "{err}");
        let err = run(&["dse", "--seed", "3"]).unwrap_err();
        assert!(err.to_string().contains("--strategy"), "{err}");
    }

    #[test]
    fn dse_hillclimb_refuses_resume() {
        let err = run(&[
            "dse", "--strategy", "hillclimb", "--resume", "/tmp/ck_none.json",
        ])
        .unwrap_err();
        assert!(err.to_string().contains("not resumable"), "{err}");
    }

    #[test]
    fn simulate_reports_the_memory_plan_and_cap_stalls() {
        let s = run(&["simulate", "--preset", "dataflow7", "--elements", "100000"])
            .unwrap();
        assert!(s.contains("memory plan:"), "{s}");
        assert!(s.contains("conflict stalls 0 cyc/element"), "{s}");
        let capped = run(&[
            "simulate", "--preset", "dataflow7", "--elements", "100000",
            "--partition-cap", "4",
        ])
        .unwrap();
        assert!(!capped.contains("conflict stalls 0 cyc/element"), "{capped}");
        assert!(capped.contains("cap4"), "label carries the cap: {capped}");
        assert!(run(&["simulate", "--partition-cap", "x"]).is_err());
    }

    #[test]
    fn dse_mem_plan_flag_explores_the_memory_axis() {
        let s = run(&[
            "dse", "--p", "11", "--dtype", "f64", "--max-cus", "1",
            "--elements", "100000", "--threads", "2", "--mem-plan",
            "--format", "csv",
        ])
        .unwrap();
        assert!(s.contains("partition_cap"), "{s}");
        assert!(s.contains("conflict_stalls"), "{s}");
        // capped candidates are enumerated (column 9 = partition_cap)
        let capped_rows = s
            .lines()
            .skip(1)
            .filter_map(|l| l.split(',').nth(9))
            .filter(|c| !c.is_empty())
            .count();
        assert!(capped_rows > 0, "capped candidates enumerated:\n{s}");
    }

    #[test]
    fn dse_emits_json_and_csv() {
        let base = [
            "dse", "--p", "11", "--dtype", "f64", "--max-cus", "1",
            "--elements", "100000", "--threads", "2",
        ];
        let mut j = base.to_vec();
        j.extend(["--format", "json"]);
        let js = run(&j).unwrap();
        assert!(js.trim_start().starts_with('{'), "{js}");
        assert!(js.contains("\"frontier_size\""), "{js}");
        let mut c = base.to_vec();
        c.extend(["--format", "csv"]);
        let cs = run(&c).unwrap();
        assert!(cs.starts_with("kernel,p,dtype"), "{cs}");
        let mut bad = base.to_vec();
        bad.extend(["--format", "xml"]);
        assert!(run(&bad).is_err());
    }

    #[test]
    fn compose_fuses_kernels_from_the_command_line() {
        let out = run(&[
            "compose", "interpolation", "gradient", "--elements", "20000",
        ])
        .unwrap();
        assert!(out.contains("composed system interpolation+gradient"), "{out}");
        assert!(out.contains("fifo-routed"), "{out}");
        assert!(out.contains("no HBM round trip"), "{out}");
        assert!(out.contains("analytic bracket"), "{out}");
        // operands are required, and flags stay registry-checked
        assert!(run(&["compose"]).is_err());
        let err = run(&[
            "compose", "interpolation", "gradient", "--element", "5",
        ])
        .unwrap_err()
        .to_string();
        assert!(err.contains("did you mean --elements"), "{err}");
    }

    #[test]
    fn compose_layouts_prices_the_fuse_axis() {
        let out = run(&[
            "compose", "interpolation", "gradient", "--elements", "10000",
            "--layouts",
        ])
        .unwrap();
        assert!(out.contains("layouts (2 fuse masks)"), "{out}");
        assert!(out.contains("Pareto frontier"), "{out}");
        assert!(out.contains("interpolation+gradient"), "{out}");
        assert!(out.contains("interpolation | gradient"), "{out}");
    }
}
