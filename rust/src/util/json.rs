//! Minimal JSON parser/emitter (the offline registry has no serde_json).
//!
//! Supports the full JSON grammar minus exotic number forms; good enough
//! for `artifacts/manifest.json` and Olympus configuration files.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Objects use BTreeMap for deterministic emission order.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().map(|n| n as u64)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Field access on an object; returns Null for missing keys.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        self.as_obj().and_then(|o| o.get(key)).unwrap_or(&NULL)
    }

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(
            pairs
                .into_iter()
                .map(|(k, v)| (k.to_string(), v))
                .collect(),
        )
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    pub fn num(n: impl Into<f64>) -> Json {
        Json::Num(n.into())
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(o) => {
                write!(f, "{{")?;
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

/// Parse a JSON document. Errors carry the byte offset.
pub fn parse(input: &str) -> Result<Json, String> {
    let mut p = Parser {
        bytes: input.as_bytes(),
        pos: 0,
    };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(format!("trailing data at byte {}", p.pos));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), String> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            Err(format!(
                "expected '{}' at byte {}",
                b as char,
                self.pos.saturating_sub(1)
            ))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {:?} at byte {}", other, self.pos)),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.pos))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|e| format!("bad number {s:?} at byte {start}: {e}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err("unterminated string".into()),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'n') => out.push('\n'),
                    Some(b't') => out.push('\t'),
                    Some(b'r') => out.push('\r'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().ok_or("bad \\u escape")?;
                            code = code * 16
                                + (c as char)
                                    .to_digit(16)
                                    .ok_or("bad \\u escape")?;
                        }
                        out.push(
                            char::from_u32(code).unwrap_or('\u{fffd}'),
                        );
                    }
                    other => {
                        return Err(format!("bad escape {other:?}"));
                    }
                },
                Some(c) => {
                    // Collect the full UTF-8 sequence.
                    if c < 0x80 {
                        out.push(c as char);
                    } else {
                        let len = utf8_len(c);
                        let end = self.pos - 1 + len;
                        let s = std::str::from_utf8(
                            &self.bytes[self.pos - 1..end.min(self.bytes.len())],
                        )
                        .map_err(|e| format!("bad utf8: {e}"))?;
                        out.push_str(s);
                        self.pos += len - 1;
                    }
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                other => return Err(format!("expected , or ] got {other:?}")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                other => return Err(format!("expected , or }} got {other:?}")),
            }
        }
    }
}

fn utf8_len(b: u8) -> usize {
    if b >= 0xF0 {
        4
    } else if b >= 0xE0 {
        3
    } else {
        2
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("false").unwrap(), Json::Bool(false));
        assert_eq!(parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("c").as_str(), Some("x"));
        let arr = v.get("a").as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[2].get("b"), &Json::Null);
    }

    #[test]
    fn parses_escapes_and_unicode() {
        let v = parse(r#""a\n\t\"\\ A ü""#).unwrap();
        assert_eq!(v.as_str(), Some("a\n\t\"\\ A ü"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("12 34").is_err());
        assert!(parse("nul").is_err());
    }

    #[test]
    fn roundtrip_display_parse() {
        let v = Json::obj(vec![
            ("x", Json::num(1.5)),
            ("y", Json::Arr(vec![Json::Bool(true), Json::Null])),
            ("s", Json::str("a\"b\nc")),
        ]);
        let text = v.to_string();
        assert_eq!(parse(&text).unwrap(), v);
    }

    #[test]
    fn integers_emit_without_fraction() {
        assert_eq!(Json::num(32.0).to_string(), "32");
        assert_eq!(Json::num(1.25).to_string(), "1.25");
    }

    #[test]
    fn parses_real_manifest_if_present() {
        let path = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/manifest.json");
        if let Ok(text) = std::fs::read_to_string(path) {
            let m = parse(&text).unwrap();
            assert_eq!(m.get("format").as_str(), Some("hlo-text"));
            assert!(!m.get("artifacts").as_arr().unwrap().is_empty());
        }
    }
}
