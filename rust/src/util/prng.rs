//! Deterministic PRNG (xoshiro256**) — no `rand` in the offline registry.
//!
//! Used by property tests, workload generators, and the native baselines.
//! Seeded explicitly everywhere so every test and benchmark is
//! reproducible bit-for-bit.

/// xoshiro256** by Blackman & Vigna (public domain reference algorithm).
#[derive(Debug, Clone)]
pub struct Prng {
    s: [u64; 4],
}

impl Prng {
    /// Seed via SplitMix64 so any u64 seed (including 0) works.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Prng {
            s: [next(), next(), next(), next()],
        }
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Uniform integer in [lo, hi] inclusive.
    pub fn range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo <= hi);
        lo + self.next_u64() % (hi - lo + 1)
    }

    pub fn range_usize(&mut self, lo: usize, hi: usize) -> usize {
        self.range_u64(lo as u64, hi as u64) as usize
    }

    pub fn bool(&mut self) -> bool {
        self.next_u64() & 1 == 1
    }

    /// Vector of uniform values in [-1, 1) — the paper's rescaled input
    /// domain (§3.6.4).
    pub fn unit_vec(&mut self, n: usize) -> Vec<f64> {
        (0..n).map(|_| self.range_f64(-1.0, 1.0)).collect()
    }

    /// Pick one element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.range_usize(0, xs.len() - 1)]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_same_seed() {
        let mut a = Prng::new(42);
        let mut b = Prng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Prng::new(1);
        let mut b = Prng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut p = Prng::new(7);
        for _ in 0..1000 {
            let x = p.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn range_u64_inclusive_bounds_hit() {
        let mut p = Prng::new(3);
        let mut lo_seen = false;
        let mut hi_seen = false;
        for _ in 0..1000 {
            match p.range_u64(0, 3) {
                0 => lo_seen = true,
                3 => hi_seen = true,
                1 | 2 => {}
                _ => panic!("out of range"),
            }
        }
        assert!(lo_seen && hi_seen);
    }

    #[test]
    fn unit_vec_in_domain() {
        let mut p = Prng::new(9);
        let v = p.unit_vec(256);
        assert_eq!(v.len(), 256);
        assert!(v.iter().all(|x| (-1.0..1.0).contains(x)));
        // crude uniformity check
        let mean = v.iter().sum::<f64>() / v.len() as f64;
        assert!(mean.abs() < 0.2);
    }

    #[test]
    fn zero_seed_is_fine() {
        let mut p = Prng::new(0);
        let a = p.next_u64();
        let b = p.next_u64();
        assert!(a != 0 || b != 0);
    }
}
