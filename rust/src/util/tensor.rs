//! Dense row-major f64 tensors for the teil interpreter and baselines.
//!
//! This is deliberately small: shapes are `Vec<usize>`, storage is a flat
//! `Vec<f64>`. It backs (a) the semantic oracle for IR rewrites, (b) the
//! naive-CPU baseline of Fig. 19, and (c) host-side batch assembly in the
//! coordinator.

use std::fmt;

/// Dense row-major tensor.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f64>,
}

impl Tensor {
    pub fn zeros(shape: &[usize]) -> Self {
        let n: usize = shape.iter().product();
        Tensor {
            shape: shape.to_vec(),
            data: vec![0.0; n],
        }
    }

    pub fn from_vec(shape: &[usize], data: Vec<f64>) -> Self {
        assert_eq!(
            shape.iter().product::<usize>(),
            data.len(),
            "shape {shape:?} does not match data length {}",
            data.len()
        );
        Tensor {
            shape: shape.to_vec(),
            data,
        }
    }

    /// Random tensor with entries in [-1, 1) (the paper's input domain).
    pub fn random(shape: &[usize], rng: &mut super::prng::Prng) -> Self {
        let n: usize = shape.iter().product();
        Tensor::from_vec(shape, rng.unit_vec(n))
    }

    pub fn identity(n: usize) -> Self {
        let mut t = Tensor::zeros(&[n, n]);
        for i in 0..n {
            t.data[i * n + i] = 1.0;
        }
        t
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn rank(&self) -> usize {
        self.shape.len()
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn data(&self) -> &[f64] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f64] {
        &mut self.data
    }

    pub fn into_data(self) -> Vec<f64> {
        self.data
    }

    /// Flat index from a multi-index.
    pub fn flat(&self, idx: &[usize]) -> usize {
        debug_assert_eq!(idx.len(), self.shape.len());
        let mut f = 0;
        for (i, (&ix, &dim)) in idx.iter().zip(&self.shape).enumerate() {
            debug_assert!(ix < dim, "index {ix} out of bound {dim} at axis {i}");
            f = f * dim + ix;
        }
        f
    }

    pub fn get(&self, idx: &[usize]) -> f64 {
        self.data[self.flat(idx)]
    }

    pub fn set(&mut self, idx: &[usize], v: f64) {
        let f = self.flat(idx);
        self.data[f] = v;
    }

    /// Reshape (same element count).
    pub fn reshape(&self, shape: &[usize]) -> Tensor {
        Tensor::from_vec(shape, self.data.clone())
    }

    /// Outer (tensor) product: shape = self.shape ++ other.shape.
    pub fn outer(&self, other: &Tensor) -> Tensor {
        let mut shape = self.shape.clone();
        shape.extend_from_slice(&other.shape);
        let mut data = Vec::with_capacity(self.data.len() * other.data.len());
        for &a in &self.data {
            for &b in &other.data {
                data.push(a * b);
            }
        }
        Tensor { shape, data }
    }

    /// Elementwise binary op (shapes must match).
    pub fn zip(&self, other: &Tensor, f: impl Fn(f64, f64) -> f64) -> Tensor {
        assert_eq!(self.shape, other.shape, "elementwise shape mismatch");
        let data = self
            .data
            .iter()
            .zip(&other.data)
            .map(|(&a, &b)| f(a, b))
            .collect();
        Tensor {
            shape: self.shape.clone(),
            data,
        }
    }

    /// Take the diagonal of axes (i, j): result drops axis j and every
    /// element has index_i == index_j. Matches `teil.diag`.
    pub fn diag(&self, i: usize, j: usize) -> Tensor {
        assert!(i < j, "diag expects i < j");
        assert_eq!(self.shape[i], self.shape[j], "diag axes must match");
        let mut out_shape = self.shape.clone();
        out_shape.remove(j);
        let mut out = Tensor::zeros(&out_shape);
        let mut idx = vec![0usize; out_shape.len()];
        let mut full = vec![0usize; self.shape.len()];
        loop {
            // reconstruct the full index: insert idx[i] at position j
            for (k, v) in idx.iter().enumerate() {
                match k.cmp(&j) {
                    std::cmp::Ordering::Less => full[k] = *v,
                    _ => full[k + 1] = *v,
                }
            }
            full[j] = idx[i];
            let flat_out = out.flat(&idx);
            out.data[flat_out] = self.get(&full);
            if !increment(&mut idx, &out_shape) {
                break;
            }
        }
        out
    }

    /// Sum-reduce axis `axis`. Matches `teil.red add`.
    pub fn reduce_add(&self, axis: usize) -> Tensor {
        let mut out_shape = self.shape.clone();
        let n = out_shape.remove(axis);
        let mut out = Tensor::zeros(&out_shape);
        let mut idx = vec![0usize; out_shape.len()];
        let mut full = vec![0usize; self.shape.len()];
        if out_shape.is_empty() {
            let s: f64 = self.data.iter().sum();
            return Tensor::from_vec(&[], vec![s]);
        }
        loop {
            for (k, v) in idx.iter().enumerate() {
                if k < axis {
                    full[k] = *v;
                } else {
                    full[k + 1] = *v;
                }
            }
            let mut s = 0.0;
            for r in 0..n {
                full[axis] = r;
                s += self.get(&full);
            }
            let flat_out = out.flat(&idx);
            out.data[flat_out] = s;
            if !increment(&mut idx, &out_shape) {
                break;
            }
        }
        out
    }

    /// n-mode product: contract `m`'s second index with `self`'s `mode`
    /// axis: out[.., i, ..] = sum_l m[i, l] * self[.., l, ..].
    pub fn mode_apply(&self, m: &Tensor, mode: usize) -> Tensor {
        assert_eq!(m.rank(), 2);
        let (rows, cols) = (m.shape[0], m.shape[1]);
        assert_eq!(self.shape[mode], cols, "mode product dim mismatch");
        let mut out_shape = self.shape.clone();
        out_shape[mode] = rows;
        let mut out = Tensor::zeros(&out_shape);

        // strides for walking the mode axis
        let inner: usize = self.shape[mode + 1..].iter().product();
        let outer: usize = self.shape[..mode].iter().product();
        for o in 0..outer {
            for i in 0..rows {
                for inn in 0..inner {
                    let mut s = 0.0;
                    for l in 0..cols {
                        s += m.data[i * cols + l]
                            * self.data[(o * cols + l) * inner + inn];
                    }
                    out.data[(o * rows + i) * inner + inn] = s;
                }
            }
        }
        out
    }

    /// Matrix transpose (rank-2 only).
    pub fn transposed(&self) -> Tensor {
        assert_eq!(self.rank(), 2, "transposed is rank-2 only");
        let (r, c) = (self.shape[0], self.shape[1]);
        let mut out = Tensor::zeros(&[c, r]);
        for i in 0..r {
            for j in 0..c {
                out.data[j * r + i] = self.data[i * c + j];
            }
        }
        out
    }

    /// Move axis `from` to position `to` (numpy moveaxis semantics).
    pub fn move_axis(&self, from: usize, to: usize) -> Tensor {
        assert!(from < self.rank() && to < self.rank());
        if from == to {
            return self.clone();
        }
        let mut perm: Vec<usize> = (0..self.rank()).collect();
        let ax = perm.remove(from);
        perm.insert(to, ax);
        // perm[k] = source axis for destination axis k
        let out_shape: Vec<usize> = perm.iter().map(|&a| self.shape[a]).collect();
        let mut out = Tensor::zeros(&out_shape);
        let mut idx = vec![0usize; out_shape.len()];
        let mut src = vec![0usize; out_shape.len()];
        loop {
            for (k, &a) in perm.iter().enumerate() {
                src[a] = idx[k];
            }
            let fo = out.flat(&idx);
            out.data[fo] = self.get(&src);
            if !increment(&mut idx, &out_shape) {
                break;
            }
        }
        out
    }

    /// Indirect row read: `out[i, ..] = self[idx[i], ..]` for a rank-1
    /// index tensor. Index entries are f64 (the flow is
    /// single-datatype); they must round to in-range row numbers.
    /// Matches `teil.gather`.
    pub fn gather_rows(&self, idx: &Tensor) -> Tensor {
        assert_eq!(idx.rank(), 1, "gather index must be rank-1");
        assert!(self.rank() >= 1, "gather base must have a row axis");
        let rows = self.shape[0];
        let inner: usize = self.shape[1..].iter().product();
        let mut out_shape = vec![idx.len()];
        out_shape.extend_from_slice(&self.shape[1..]);
        let mut out = Tensor::zeros(&out_shape);
        for (i, &v) in idx.data.iter().enumerate() {
            let r = round_index(v, rows);
            out.data[i * inner..(i + 1) * inner]
                .copy_from_slice(&self.data[r * inner..(r + 1) * inner]);
        }
        out
    }

    /// Indirect row write: `out[idx[i], ..] (+)= self[i, ..]` into a
    /// fresh zero tensor with `rows` rows. Rows are written in
    /// ascending data order, so duplicate indices accumulate (or, with
    /// `add == false`, last-writer-wins) deterministically — the same
    /// order every evaluator must use. Matches `teil.scatter`.
    pub fn scatter_rows(&self, idx: &Tensor, rows: usize, add: bool) -> Tensor {
        assert_eq!(idx.rank(), 1, "scatter index must be rank-1");
        assert!(self.rank() >= 1, "scatter data must have a row axis");
        assert_eq!(idx.len(), self.shape[0], "index length != data rows");
        let inner: usize = self.shape[1..].iter().product();
        let mut out_shape = vec![rows];
        out_shape.extend_from_slice(&self.shape[1..]);
        let mut out = Tensor::zeros(&out_shape);
        for (i, &v) in idx.data.iter().enumerate() {
            let r = round_index(v, rows);
            for k in 0..inner {
                let d = self.data[i * inner + k];
                if add {
                    out.data[r * inner + k] += d;
                } else {
                    out.data[r * inner + k] = d;
                }
            }
        }
        out
    }

    /// Mean squared error against another tensor.
    pub fn mse(&self, other: &Tensor) -> f64 {
        assert_eq!(self.shape, other.shape);
        let n = self.data.len().max(1) as f64;
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b) * (a - b))
            .sum::<f64>()
            / n
    }

    pub fn max_abs_diff(&self, other: &Tensor) -> f64 {
        assert_eq!(self.shape, other.shape);
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f64::max)
    }
}

impl fmt::Display for Tensor {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Tensor{:?}", self.shape)
    }
}

/// Round an f64 index entry to an in-range row number.
fn round_index(v: f64, rows: usize) -> usize {
    let r = v.round();
    assert!(
        r >= 0.0 && (r as usize) < rows,
        "index {v} out of range 0..{rows}"
    );
    r as usize
}

/// Odometer increment; returns false on wrap-around (iteration done).
fn increment(idx: &mut [usize], shape: &[usize]) -> bool {
    for k in (0..idx.len()).rev() {
        idx[k] += 1;
        if idx[k] < shape[k] {
            return true;
        }
        idx[k] = 0;
    }
    false
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Prng;

    #[test]
    fn flat_index_row_major() {
        let t = Tensor::zeros(&[2, 3, 4]);
        assert_eq!(t.flat(&[0, 0, 0]), 0);
        assert_eq!(t.flat(&[0, 0, 3]), 3);
        assert_eq!(t.flat(&[0, 1, 0]), 4);
        assert_eq!(t.flat(&[1, 2, 3]), 23);
    }

    #[test]
    fn outer_product_shape_and_values() {
        let a = Tensor::from_vec(&[2], vec![1.0, 2.0]);
        let b = Tensor::from_vec(&[3], vec![3.0, 4.0, 5.0]);
        let o = a.outer(&b);
        assert_eq!(o.shape(), &[2, 3]);
        assert_eq!(o.get(&[1, 2]), 10.0);
        assert_eq!(o.get(&[0, 0]), 3.0);
    }

    #[test]
    fn diag_of_outer_is_elementwise() {
        // diag_{0,1}(a (x) b) over matching dims == a * b elementwise
        let a = Tensor::from_vec(&[3], vec![1.0, 2.0, 3.0]);
        let b = Tensor::from_vec(&[3], vec![4.0, 5.0, 6.0]);
        let d = a.outer(&b).diag(0, 1);
        assert_eq!(d.shape(), &[3]);
        assert_eq!(d.data(), &[4.0, 10.0, 18.0]);
    }

    #[test]
    fn reduce_add_matches_manual() {
        let t = Tensor::from_vec(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let r0 = t.reduce_add(0);
        assert_eq!(r0.shape(), &[3]);
        assert_eq!(r0.data(), &[5., 7., 9.]);
        let r1 = t.reduce_add(1);
        assert_eq!(r1.data(), &[6., 15.]);
    }

    #[test]
    fn reduce_add_to_scalar() {
        let t = Tensor::from_vec(&[3], vec![1., 2., 3.]);
        let r = t.reduce_add(0);
        assert_eq!(r.shape(), &[] as &[usize]);
        assert_eq!(r.data(), &[6.0]);
    }

    #[test]
    fn mode_apply_identity_is_noop() {
        let mut rng = Prng::new(5);
        let u = Tensor::random(&[4, 4, 4], &mut rng);
        let i = Tensor::identity(4);
        for mode in 0..3 {
            assert_eq!(u.mode_apply(&i, mode), u);
        }
    }

    #[test]
    fn mode_apply_equals_diag_red_of_outer() {
        // The teil lowering identity (Fig. 7b): prod + diag + red == GEMM.
        let mut rng = Prng::new(6);
        let s = Tensor::random(&[3, 3], &mut rng);
        let u = Tensor::random(&[3, 3, 3], &mut rng);
        // mode-0 apply: out_ijk = sum_l s_il u_ljk
        let via_gemm = u.mode_apply(&s, 0);
        // prod: s (x) u -> [3,3,3,3,3]; diag axes (1, 2) pairs l; red over it
        let via_teil = s.outer(&u).diag(1, 2).reduce_add(1);
        for i in 0..via_gemm.len() {
            assert!((via_gemm.data()[i] - via_teil.data()[i]).abs() < 1e-12);
        }
    }

    #[test]
    fn mode_apply_nonsquare() {
        let a = Tensor::from_vec(&[2, 3], vec![1., 0., 0., 0., 1., 0.]);
        let mut rng = Prng::new(8);
        let u = Tensor::random(&[3, 3, 3], &mut rng);
        let out = u.mode_apply(&a, 1);
        assert_eq!(out.shape(), &[3, 2, 3]);
        assert_eq!(out.get(&[1, 0, 2]), u.get(&[1, 0, 2]));
        assert_eq!(out.get(&[1, 1, 2]), u.get(&[1, 1, 2]));
    }

    #[test]
    fn gather_rows_reads_through_the_index() {
        let base = Tensor::from_vec(&[3, 2], vec![1., 2., 3., 4., 5., 6.]);
        let idx = Tensor::from_vec(&[4], vec![2.0, 0.0, 2.0, 1.0]);
        let g = base.gather_rows(&idx);
        assert_eq!(g.shape(), &[4, 2]);
        assert_eq!(g.data(), &[5., 6., 1., 2., 5., 6., 3., 4.]);
    }

    #[test]
    fn scatter_rows_accumulates_duplicates_in_data_order() {
        let data = Tensor::from_vec(&[3], vec![1.0, 10.0, 100.0]);
        let idx = Tensor::from_vec(&[3], vec![1.0, 1.0, 0.0]);
        let add = data.scatter_rows(&idx, 2, true);
        assert_eq!(add.data(), &[100.0, 11.0]);
        let wr = data.scatter_rows(&idx, 2, false);
        assert_eq!(wr.data(), &[100.0, 10.0], "last writer wins");
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn gather_rejects_out_of_range_indices() {
        let base = Tensor::from_vec(&[2], vec![1.0, 2.0]);
        let idx = Tensor::from_vec(&[1], vec![5.0]);
        base.gather_rows(&idx);
    }

    #[test]
    fn mse_and_max_abs_diff() {
        let a = Tensor::from_vec(&[2], vec![1.0, 2.0]);
        let b = Tensor::from_vec(&[2], vec![1.0, 4.0]);
        assert_eq!(a.mse(&b), 2.0);
        assert_eq!(a.max_abs_diff(&b), 2.0);
    }

    #[test]
    #[should_panic(expected = "shape")]
    fn from_vec_validates_length() {
        Tensor::from_vec(&[2, 2], vec![1.0]);
    }
}
