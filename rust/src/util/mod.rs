//! Small self-contained utilities.
//!
//! The offline crate registry only carries the `xla` closure, so JSON,
//! property testing, benchmarking, and tensors are implemented in-crate.

pub mod bench;
pub mod json;
pub mod prng;
pub mod prop;
pub mod tensor;

/// Format a byte count human-readably (GiB/MiB/KiB).
pub fn fmt_bytes(bytes: u64) -> String {
    const K: f64 = 1024.0;
    let b = bytes as f64;
    if b >= K * K * K {
        format!("{:.2} GiB", b / (K * K * K))
    } else if b >= K * K {
        format!("{:.2} MiB", b / (K * K))
    } else if b >= K {
        format!("{:.2} KiB", b / K)
    } else {
        format!("{bytes} B")
    }
}

/// Integer ceiling division.
pub fn ceil_div(a: u64, b: u64) -> u64 {
    assert!(b != 0, "ceil_div by zero");
    a.div_ceil(b)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ceil_div_rounds_up() {
        assert_eq!(ceil_div(10, 3), 4);
        assert_eq!(ceil_div(9, 3), 3);
        assert_eq!(ceil_div(0, 3), 0);
        assert_eq!(ceil_div(1, 1), 1);
    }

    #[test]
    #[should_panic]
    fn ceil_div_zero_divisor_panics() {
        ceil_div(1, 0);
    }

    #[test]
    fn fmt_bytes_scales() {
        assert_eq!(fmt_bytes(512), "512 B");
        assert_eq!(fmt_bytes(2048), "2.00 KiB");
        assert_eq!(fmt_bytes(3 * 1024 * 1024), "3.00 MiB");
        assert_eq!(fmt_bytes(8 * 1024 * 1024 * 1024), "8.00 GiB");
    }
}
