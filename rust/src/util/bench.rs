//! Micro-benchmark harness (the offline registry has no criterion).
//!
//! `Bench::new("name").run(|| ...)` warms up, then samples wall-clock
//! iterations until a time budget is reached and reports min/median/mean.
//! Used by the `rust/benches/*` targets (harness = false) and the §Perf
//! pass in EXPERIMENTS.md.

use std::time::{Duration, Instant};

#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub samples: usize,
    pub min: Duration,
    pub median: Duration,
    pub mean: Duration,
}

impl BenchResult {
    pub fn report(&self) -> String {
        format!(
            "{:<44} {:>10} min  {:>10} med  {:>10} mean  ({} samples)",
            self.name,
            fmt_dur(self.min),
            fmt_dur(self.median),
            fmt_dur(self.mean),
            self.samples
        )
    }
}

pub fn fmt_dur(d: Duration) -> String {
    let ns = d.as_nanos();
    if ns < 1_000 {
        format!("{ns} ns")
    } else if ns < 1_000_000 {
        format!("{:.2} µs", ns as f64 / 1e3)
    } else if ns < 1_000_000_000 {
        format!("{:.2} ms", ns as f64 / 1e6)
    } else {
        format!("{:.3} s", ns as f64 / 1e9)
    }
}

pub struct Bench {
    name: String,
    warmup: Duration,
    budget: Duration,
    max_samples: usize,
}

impl Bench {
    pub fn new(name: impl Into<String>) -> Self {
        Bench {
            name: name.into(),
            warmup: Duration::from_millis(50),
            budget: Duration::from_millis(500),
            max_samples: 1000,
        }
    }

    pub fn budget(mut self, budget: Duration) -> Self {
        self.budget = budget;
        self
    }

    pub fn warmup(mut self, warmup: Duration) -> Self {
        self.warmup = warmup;
        self
    }

    /// Time `f` repeatedly; `f`'s return value is black-boxed.
    pub fn run<T>(&self, mut f: impl FnMut() -> T) -> BenchResult {
        // Warmup.
        let start = Instant::now();
        while start.elapsed() < self.warmup {
            std::hint::black_box(f());
        }
        // Sample.
        let mut samples = Vec::new();
        let start = Instant::now();
        while start.elapsed() < self.budget && samples.len() < self.max_samples {
            let t0 = Instant::now();
            std::hint::black_box(f());
            samples.push(t0.elapsed());
        }
        if samples.is_empty() {
            let t0 = Instant::now();
            std::hint::black_box(f());
            samples.push(t0.elapsed());
        }
        samples.sort();
        let min = samples[0];
        let median = samples[samples.len() / 2];
        let mean = samples.iter().sum::<Duration>() / samples.len() as u32;
        BenchResult {
            name: self.name.clone(),
            samples: samples.len(),
            min,
            median,
            mean,
        }
    }
}

/// Print a standard bench section header (keeps bench binaries uniform).
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_produces_samples() {
        let r = Bench::new("noop")
            .warmup(Duration::from_millis(1))
            .budget(Duration::from_millis(10))
            .run(|| 1 + 1);
        assert!(r.samples >= 1);
        assert!(r.min <= r.median);
        assert!(!r.report().is_empty());
    }

    #[test]
    fn fmt_dur_units() {
        assert!(fmt_dur(Duration::from_nanos(10)).contains("ns"));
        assert!(fmt_dur(Duration::from_micros(10)).contains("µs"));
        assert!(fmt_dur(Duration::from_millis(10)).contains("ms"));
        assert!(fmt_dur(Duration::from_secs(2)).ends_with("s"));
    }
}
