//! Micro-benchmark harness (the offline registry has no criterion).
//!
//! `Bench::new("name").run(|| ...)` warms up, then samples wall-clock
//! iterations until a time budget is reached and reports min/median/mean.
//! Used by the `rust/benches/*` targets (harness = false) and the §Perf
//! pass in EXPERIMENTS.md.
//!
//! Two sampling modes:
//!
//!  * **time budget** (default) — warm up for `warmup`, then sample
//!    until `budget` elapses (capped at `max_samples`); right for
//!    interactive perf work, but the sample count depends on machine
//!    speed.
//!  * **fixed iterations** — exactly one warmup call plus `k` samples,
//!    no clocks consulted for control flow: the run does the same work
//!    on every machine, which is what a CI perf-smoke step needs.
//!    Selected per-bench with [`Bench::fixed_iters`] or globally via
//!    the `HBMFLOW_BENCH_ITERS` environment variable through
//!    [`Bench::from_env`] (the `benches/*` binaries construct through
//!    it, so `HBMFLOW_BENCH_ITERS=3 cargo bench` is deterministic).
//!
//! Results serialize to `util::json` documents ([`BenchResult::to_json`]
//! / [`BenchResult::from_json`]): the decoder requires **every** field,
//! so a serialization change that drops one fails the round-trip unit
//! test below (and the `perf_sim` bench round-trips each result before
//! writing `BENCH_*.json`, failing the CI step the same way).

use std::time::{Duration, Instant};

use super::json::Json;

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct BenchResult {
    pub name: String,
    pub samples: usize,
    pub min: Duration,
    pub median: Duration,
    pub mean: Duration,
}

impl BenchResult {
    pub fn report(&self) -> String {
        format!(
            "{:<44} {:>10} min  {:>10} med  {:>10} mean  ({} samples)",
            self.name,
            fmt_dur(self.min),
            fmt_dur(self.median),
            fmt_dur(self.mean),
            self.samples
        )
    }

    /// Serialize to a JSON object. Durations are integral nanoseconds
    /// (exact in an f64 for any run shorter than ~104 days).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::str(self.name.as_str())),
            ("samples", Json::num(self.samples as f64)),
            ("min_ns", Json::num(self.min.as_nanos() as f64)),
            ("median_ns", Json::num(self.median.as_nanos() as f64)),
            ("mean_ns", Json::num(self.mean.as_nanos() as f64)),
        ])
    }

    /// Decode a [`to_json`](BenchResult::to_json) document. Every field
    /// is required — a missing or mistyped one is an error, never a
    /// default (the schema guard the CI perf-smoke step relies on).
    pub fn from_json(v: &Json) -> Result<BenchResult, String> {
        let field = |k: &str| -> Result<u64, String> {
            v.get(k)
                .as_u64()
                .ok_or_else(|| format!("bench result: missing or non-integer {k:?}"))
        };
        Ok(BenchResult {
            name: v
                .get("name")
                .as_str()
                .ok_or("bench result: missing name")?
                .to_string(),
            samples: field("samples")? as usize,
            min: Duration::from_nanos(field("min_ns")?),
            median: Duration::from_nanos(field("median_ns")?),
            mean: Duration::from_nanos(field("mean_ns")?),
        })
    }
}

pub fn fmt_dur(d: Duration) -> String {
    let ns = d.as_nanos();
    if ns < 1_000 {
        format!("{ns} ns")
    } else if ns < 1_000_000 {
        format!("{:.2} µs", ns as f64 / 1e3)
    } else if ns < 1_000_000_000 {
        format!("{:.2} ms", ns as f64 / 1e6)
    } else {
        format!("{:.3} s", ns as f64 / 1e9)
    }
}

/// How [`Bench::run`] decides when to stop sampling.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Sampling {
    /// Sample until the time budget elapses (machine-dependent count).
    TimeBudget,
    /// Exactly this many samples after one warmup call (deterministic).
    Fixed(usize),
}

pub struct Bench {
    name: String,
    warmup: Duration,
    budget: Duration,
    max_samples: usize,
    sampling: Sampling,
}

impl Bench {
    pub fn new(name: impl Into<String>) -> Self {
        Bench {
            name: name.into(),
            warmup: Duration::from_millis(50),
            budget: Duration::from_millis(500),
            max_samples: 1000,
            sampling: Sampling::TimeBudget,
        }
    }

    /// [`Bench::new`], honoring `HBMFLOW_BENCH_ITERS=k`: when the
    /// variable is set to a positive integer the bench runs in the
    /// deterministic fixed-iteration mode with `k` samples.
    pub fn from_env(name: impl Into<String>) -> Self {
        let b = Bench::new(name);
        match std::env::var("HBMFLOW_BENCH_ITERS")
            .ok()
            .and_then(|v| v.parse::<usize>().ok())
        {
            Some(k) if k > 0 => b.fixed_iters(k),
            _ => b,
        }
    }

    pub fn budget(mut self, budget: Duration) -> Self {
        self.budget = budget;
        self
    }

    pub fn warmup(mut self, warmup: Duration) -> Self {
        self.warmup = warmup;
        self
    }

    /// Switch to the deterministic fixed-iteration mode: one warmup
    /// call, then exactly `iters.max(1)` timed samples.
    pub fn fixed_iters(mut self, iters: usize) -> Self {
        self.sampling = Sampling::Fixed(iters.max(1));
        self
    }

    /// Time `f` repeatedly; `f`'s return value is black-boxed.
    pub fn run<T>(&self, mut f: impl FnMut() -> T) -> BenchResult {
        let mut samples = Vec::new();
        match self.sampling {
            Sampling::Fixed(k) => {
                std::hint::black_box(f()); // one warmup call
                for _ in 0..k {
                    let t0 = Instant::now();
                    std::hint::black_box(f());
                    samples.push(t0.elapsed());
                }
            }
            Sampling::TimeBudget => {
                let start = Instant::now();
                while start.elapsed() < self.warmup {
                    std::hint::black_box(f());
                }
                let start = Instant::now();
                while start.elapsed() < self.budget && samples.len() < self.max_samples {
                    let t0 = Instant::now();
                    std::hint::black_box(f());
                    samples.push(t0.elapsed());
                }
                if samples.is_empty() {
                    let t0 = Instant::now();
                    std::hint::black_box(f());
                    samples.push(t0.elapsed());
                }
            }
        }
        samples.sort();
        let min = samples[0];
        let mean = samples.iter().sum::<Duration>() / samples.len() as u32;
        BenchResult {
            name: self.name.clone(),
            samples: samples.len(),
            min,
            median: median_of_sorted(&samples),
            mean,
        }
    }
}

/// Median of an ascending-sorted, non-empty sample list: the middle
/// element for odd counts, the mean of the two middle elements for even
/// counts (the usual definition — the old `samples[len / 2]` picked the
/// upper of the two and biased even-count medians high).
fn median_of_sorted(samples: &[Duration]) -> Duration {
    let n = samples.len();
    if n % 2 == 1 {
        samples[n / 2]
    } else {
        (samples[n / 2 - 1] + samples[n / 2]) / 2
    }
}

/// Print a standard bench section header (keeps bench binaries uniform).
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_produces_samples() {
        let r = Bench::new("noop")
            .warmup(Duration::from_millis(1))
            .budget(Duration::from_millis(10))
            .run(|| 1 + 1);
        assert!(r.samples >= 1);
        assert!(r.min <= r.median);
        assert!(!r.report().is_empty());
    }

    #[test]
    fn fixed_iteration_mode_takes_exactly_k_samples() {
        for k in [1usize, 3, 8] {
            let r = Bench::new("fixed").fixed_iters(k).run(|| 1 + 1);
            assert_eq!(r.samples, k);
        }
        // degenerate request still samples once
        assert_eq!(Bench::new("z").fixed_iters(0).run(|| ()).samples, 1);
    }

    #[test]
    fn median_is_well_defined_for_even_counts() {
        let d = |ms: u64| Duration::from_millis(ms);
        assert_eq!(median_of_sorted(&[d(10)]), d(10));
        assert_eq!(median_of_sorted(&[d(10), d(20)]), d(15));
        assert_eq!(median_of_sorted(&[d(10), d(20), d(30)]), d(20));
        assert_eq!(median_of_sorted(&[d(10), d(20), d(30), d(100)]), d(25));
    }

    #[test]
    fn json_round_trip_preserves_every_field() {
        let r = BenchResult {
            name: "sim/event seq".into(),
            samples: 42,
            min: Duration::from_nanos(1_234),
            median: Duration::from_nanos(5_678),
            mean: Duration::from_nanos(6_000),
        };
        let back = BenchResult::from_json(&r.to_json()).unwrap();
        assert_eq!(back, r);
    }

    #[test]
    fn decoder_rejects_documents_with_dropped_fields() {
        let r = Bench::new("x").fixed_iters(2).run(|| 1 + 1);
        let full = r.to_json();
        assert!(BenchResult::from_json(&full).is_ok());
        // drop each required field in turn: decode must fail, so a
        // serializer change that loses a field cannot pass CI silently
        let obj = full.as_obj().unwrap();
        for key in obj.keys() {
            let mut pruned = obj.clone();
            pruned.remove(key);
            let doc = Json::Obj(pruned);
            assert!(
                BenchResult::from_json(&doc).is_err(),
                "decoding succeeded without {key:?}"
            );
        }
    }

    #[test]
    fn fmt_dur_units() {
        assert!(fmt_dur(Duration::from_nanos(10)).contains("ns"));
        assert!(fmt_dur(Duration::from_micros(10)).contains("µs"));
        assert!(fmt_dur(Duration::from_millis(10)).contains("ms"));
        assert!(fmt_dur(Duration::from_secs(2)).ends_with("s"));
    }
}
