//! Tiny property-testing harness (the offline registry has no proptest).
//!
//! `check(name, cases, |rng| ...)` runs a closure over `cases` seeded
//! PRNGs; a failure reports the seed so the case can be replayed with
//! `replay(seed, ...)`. No shrinking — generators are kept small enough
//! that raw counterexamples are readable.
//!
//! ```no_run
//! use hbmflow::util::prop;
//! prop::check("addition commutes", 64, |rng| {
//!     let (a, b) = (rng.next_f64(), rng.next_f64());
//!     prop::assert_prop(a + b == b + a, format!("{a} {b}"))
//! });
//! ```
//! (no_run: doctest binaries lack the xla_extension rpath in this image)

use super::prng::Prng;

/// Result of one property case: Ok or a human-readable counterexample.
pub type CaseResult = Result<(), String>;

/// Assert helper returning a `CaseResult`.
pub fn assert_prop(cond: bool, detail: impl Into<String>) -> CaseResult {
    if cond {
        Ok(())
    } else {
        Err(detail.into())
    }
}

/// Approximate float equality for property checks over numerics.
pub fn close(a: f64, b: f64, rtol: f64) -> bool {
    let scale = a.abs().max(b.abs()).max(1e-30);
    (a - b).abs() <= rtol * scale
}

/// Element-wise closeness of two slices.
pub fn all_close(a: &[f64], b: &[f64], rtol: f64) -> CaseResult {
    if a.len() != b.len() {
        return Err(format!("length mismatch {} vs {}", a.len(), b.len()));
    }
    for (i, (&x, &y)) in a.iter().zip(b.iter()).enumerate() {
        if !close(x, y, rtol) {
            return Err(format!("index {i}: {x} vs {y} (rtol {rtol})"));
        }
    }
    Ok(())
}

/// Run `f` over `cases` independently seeded PRNGs; panic with the seed
/// of the first failing case.
pub fn check<F>(name: &str, cases: u64, mut f: F)
where
    F: FnMut(&mut Prng) -> CaseResult,
{
    // Base seed is fixed: property suites are fully deterministic in CI.
    for case in 0..cases {
        let seed = 0xC0FFEE ^ (case.wrapping_mul(0x9E3779B97F4A7C15));
        let mut rng = Prng::new(seed);
        if let Err(detail) = f(&mut rng) {
            panic!(
                "property '{name}' failed at case {case} (seed {seed:#x}): {detail}\n\
                 replay with prop::replay({seed:#x}, ...)"
            );
        }
    }
}

/// Re-run a single failing case by seed.
pub fn replay<F>(seed: u64, mut f: F) -> CaseResult
where
    F: FnMut(&mut Prng) -> CaseResult,
{
    let mut rng = Prng::new(seed);
    f(&mut rng)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("xor is involutive", 32, |rng| {
            let x = rng.next_u64();
            let k = rng.next_u64();
            assert_prop((x ^ k) ^ k == x, format!("{x} {k}"))
        });
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn failing_property_reports_seed() {
        check("always fails", 4, |_| Err("nope".into()));
    }

    #[test]
    fn close_handles_scales() {
        assert!(close(1.0, 1.0 + 1e-13, 1e-12));
        assert!(!close(1.0, 1.1, 1e-12));
        assert!(close(0.0, 0.0, 1e-12));
        assert!(close(1e20, 1e20 * (1.0 + 1e-13), 1e-12));
    }

    #[test]
    fn all_close_reports_index() {
        let e = all_close(&[1.0, 2.0], &[1.0, 3.0], 1e-9).unwrap_err();
        assert!(e.contains("index 1"));
        assert!(all_close(&[1.0], &[1.0, 2.0], 1e-9).is_err());
        assert!(all_close(&[1.0, 2.0], &[1.0, 2.0], 1e-9).is_ok());
    }

    #[test]
    fn replay_reproduces() {
        let seed = 0xDEAD;
        let a = replay(seed, |rng| Err(format!("{}", rng.next_u64())));
        let b = replay(seed, |rng| Err(format!("{}", rng.next_u64())));
        assert_eq!(a, b);
    }
}
