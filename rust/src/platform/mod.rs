//! Target-platform model: Xilinx Alveo U280 (paper §2.2, Fig. 3, Table 1).
//!
//! Everything the evaluation depends on is modeled architecturally: the
//! three SLRs with their resource pools, the 32 HBM pseudo-channels, the
//! DDR4 banks, PLRAM, and the PCIe host link. This is the substitution
//! for the physical card (see DESIGN.md "Hardware substitutions"): all
//! §4 effects are functions of these parameters, not of silicon.

pub mod power;

/// FPGA resource vector (LUT, FF, BRAM tiles, URAM, DSP).
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct Resources {
    pub lut: u64,
    pub ff: u64,
    pub bram: u64,
    pub uram: u64,
    pub dsp: u64,
}

impl Resources {
    pub fn add(&self, o: &Resources) -> Resources {
        Resources {
            lut: self.lut + o.lut,
            ff: self.ff + o.ff,
            bram: self.bram + o.bram,
            uram: self.uram + o.uram,
            dsp: self.dsp + o.dsp,
        }
    }

    pub fn scale(&self, k: u64) -> Resources {
        Resources {
            lut: self.lut * k,
            ff: self.ff * k,
            bram: self.bram * k,
            uram: self.uram * k,
            dsp: self.dsp * k,
        }
    }

    pub fn fits_in(&self, budget: &Resources) -> bool {
        self.lut <= budget.lut
            && self.ff <= budget.ff
            && self.bram <= budget.bram
            && self.uram <= budget.uram
            && self.dsp <= budget.dsp
    }

    /// Utilization fractions against a budget (lut, ff, bram, uram, dsp).
    pub fn utilization(&self, budget: &Resources) -> [f64; 5] {
        [
            self.lut as f64 / budget.lut as f64,
            self.ff as f64 / budget.ff as f64,
            self.bram as f64 / budget.bram as f64,
            self.uram as f64 / budget.uram as f64,
            self.dsp as f64 / budget.dsp as f64,
        ]
    }

    pub fn max_utilization(&self, budget: &Resources) -> f64 {
        self.utilization(budget)
            .into_iter()
            .fold(0.0, f64::max)
    }
}

/// One super logic region (paper Table 1).
#[derive(Debug, Clone, Copy)]
pub struct Slr {
    pub resources: Resources,
    pub has_hbm: bool,
    pub ddr4_gb: u64,
    pub plram_mb: u64,
}

/// Segmented-AXI-switch and channel-controller timing parameters
/// (paper §2.2 Fig. 3: the 32 pseudo-channels sit behind eight 4×4
/// switch units chained by lateral links; §2.3 Challenge 2: read/write
/// turnaround). Consumed by `hbm::Interconnect`; the calibration of
/// each value is tabulated in DESIGN.md §"Memory interconnect model".
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SwitchConfig {
    /// Masters/channels per 4×4 switch unit.
    pub segment_channels: u32,
    /// Local round-trip latency of one AXI transaction (cycles).
    pub base_latency_cycles: u64,
    /// Extra round-trip cycles per switch boundary a route crosses.
    pub lateral_hop_cycles: u64,
    /// Outstanding AXI transactions a master sustains.
    pub max_outstanding: u64,
    /// Words per AXI burst.
    pub burst_words: u64,
    /// Controller read→write turnaround (tRTW class, cycles).
    pub t_rtw_cycles: u64,
    /// Controller write→read turnaround (tWTR class, cycles).
    pub t_wtr_cycles: u64,
}

/// HBM subsystem parameters (paper §2.2).
#[derive(Debug, Clone, Copy)]
pub struct HbmConfig {
    pub pseudo_channels: u32,
    pub pc_capacity_bytes: u64,
    pub pc_bus_bits: u32,
    pub pc_clock_mhz: f64,
    /// Segmented AXI switch in front of the channels.
    pub switch: SwitchConfig,
}

impl HbmConfig {
    /// Per-PC bandwidth: 256 bit * 450 MHz = 14.4 GB/s.
    pub fn pc_bandwidth_bytes_per_sec(&self) -> f64 {
        (self.pc_bus_bits as f64 / 8.0) * self.pc_clock_mhz * 1e6
    }

    /// Aggregate theoretical bandwidth: 460.8 GB/s on the U280.
    pub fn total_bandwidth_bytes_per_sec(&self) -> f64 {
        self.pc_bandwidth_bytes_per_sec() * self.pseudo_channels as f64
    }
}

/// The whole card.
#[derive(Debug, Clone)]
pub struct Platform {
    pub name: String,
    pub slrs: Vec<Slr>,
    pub hbm: HbmConfig,
    /// Effective host<->HBM bandwidth over PCIe with XRT overheads.
    /// Theoretical Gen3 x16 is ~15.8 GB/s; measured effective transfer
    /// rates for XRT buffer migration land far lower. Calibrated so the
    /// paper's Baseline CU-vs-System gap (9.2%, §4.2) is reproduced.
    pub pcie_eff_bytes_per_sec: f64,
    /// Default platform clock target (Vitis `--kernel_frequency`).
    pub target_freq_mhz: f64,
}

impl Platform {
    /// The Xilinx Alveo U280 (paper Table 1).
    pub fn alveo_u280() -> Platform {
        Platform {
            name: "xilinx_u280".into(),
            slrs: vec![
                Slr {
                    resources: Resources {
                        lut: 369_000,
                        ff: 746_000,
                        bram: 507,
                        uram: 320,
                        dsp: 2_733,
                    },
                    has_hbm: true,
                    ddr4_gb: 16,
                    plram_mb: 8,
                },
                Slr {
                    resources: Resources {
                        lut: 333_000,
                        ff: 675_000,
                        bram: 468,
                        uram: 320,
                        dsp: 2_877,
                    },
                    has_hbm: false,
                    ddr4_gb: 16,
                    plram_mb: 8,
                },
                Slr {
                    resources: Resources {
                        lut: 367_000,
                        ff: 729_000,
                        bram: 512,
                        uram: 320,
                        dsp: 2_880,
                    },
                    has_hbm: false,
                    ddr4_gb: 0,
                    plram_mb: 8,
                },
            ],
            hbm: HbmConfig {
                pseudo_channels: 32,
                pc_capacity_bytes: 256 * 1024 * 1024,
                pc_bus_bits: 256,
                pc_clock_mhz: 450.0,
                switch: SwitchConfig {
                    segment_channels: 4,
                    // 4 transactions x 16-word bursts exactly cover the
                    // 64-cycle local round trip: local ports stream at
                    // one word/cycle, every boundary past that window
                    // throttles proportionally (DESIGN.md penalty table)
                    base_latency_cycles: 64,
                    lateral_hop_cycles: 32,
                    max_outstanding: 4,
                    burst_words: 16,
                    t_rtw_cycles: 64,
                    t_wtr_cycles: 64,
                },
            },
            pcie_eff_bytes_per_sec: 7.0e9,
            target_freq_mhz: 450.0,
        }
    }

    /// Device-total resources (sum over SLRs) — the denominators of the
    /// utilization percentages in paper Tables 3–5.
    pub fn total_resources(&self) -> Resources {
        self.slrs
            .iter()
            .fold(Resources::default(), |acc, s| acc.add(&s.resources))
    }

    /// How many SLRs a design of `r` resources must span (paper
    /// Challenge 5: CUs that do not fit in one SLR pay SLL crossings).
    pub fn slr_span(&self, r: &Resources) -> usize {
        let mut need = 1usize;
        for take in 1..=self.slrs.len() {
            let budget = self
                .slrs
                .iter()
                .take(take)
                .fold(Resources::default(), |acc, s| acc.add(&s.resources));
            need = take;
            if r.fits_in(&budget) {
                break;
            }
        }
        need
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn u280_matches_table1_totals() {
        let p = Platform::alveo_u280();
        let t = p.total_resources();
        assert_eq!(t.lut, 1_069_000);
        assert_eq!(t.ff, 2_150_000);
        assert_eq!(t.bram, 1_487);
        assert_eq!(t.uram, 960);
        assert_eq!(t.dsp, 8_490);
    }

    #[test]
    fn hbm_bandwidth_matches_paper() {
        let p = Platform::alveo_u280();
        let per_pc = p.hbm.pc_bandwidth_bytes_per_sec();
        assert!((per_pc - 14.4e9).abs() < 1e6, "{per_pc}");
        let total = p.hbm.total_bandwidth_bytes_per_sec();
        assert!((total - 460.8e9).abs() < 1e7, "{total}");
    }

    #[test]
    fn hbm_capacity_is_8_gb() {
        let p = Platform::alveo_u280();
        let total = p.hbm.pc_capacity_bytes * p.hbm.pseudo_channels as u64;
        assert_eq!(total, 8 * 1024 * 1024 * 1024);
    }

    #[test]
    fn switch_outstanding_window_covers_local_latency_exactly() {
        // local ports must stream at one word/cycle (the seed's read
        // model); any slack here would silently speed up every design
        let p = Platform::alveo_u280();
        let s = p.hbm.switch;
        assert_eq!(p.hbm.pseudo_channels / s.segment_channels, 8, "8 units");
        assert_eq!(s.max_outstanding * s.burst_words, s.base_latency_cycles);
    }

    #[test]
    fn only_slr0_has_hbm() {
        let p = Platform::alveo_u280();
        assert!(p.slrs[0].has_hbm);
        assert!(!p.slrs[1].has_hbm);
        assert!(!p.slrs[2].has_hbm);
    }

    #[test]
    fn slr_span_grows_with_demand() {
        let p = Platform::alveo_u280();
        let small = Resources {
            lut: 100_000,
            ff: 100_000,
            bram: 100,
            uram: 50,
            dsp: 500,
        };
        assert_eq!(p.slr_span(&small), 1);
        let big = small.scale(6);
        assert!(p.slr_span(&big) >= 2);
    }

    #[test]
    fn resource_arithmetic() {
        let a = Resources {
            lut: 1,
            ff: 2,
            bram: 3,
            uram: 4,
            dsp: 5,
        };
        let b = a.scale(2);
        assert_eq!(b.dsp, 10);
        let c = a.add(&b);
        assert_eq!(c.lut, 3);
        assert!(a.fits_in(&c));
        assert!(!c.fits_in(&a));
        let u = a.utilization(&b);
        assert!((u[0] - 0.5).abs() < 1e-12);
        assert!((a.max_utilization(&b) - 0.5).abs() < 1e-12);
    }
}
