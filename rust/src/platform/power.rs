//! Power model (paper §4.1: XRT-profiled average power; Fig. 18).
//!
//! Substitutes XRT telemetry with a standard static + dynamic CMOS model:
//!
//!   P = P_static + f/f_nom * Σ_r c_r · used_r + P_io(channels)
//!
//! The per-resource activity coefficients are calibrated against public
//! Alveo U280 power characterizations (Xilinx XPE-class estimates) such
//! that the Fig. 18 *ratios* — fixed > float efficiency, 32 > 64 bit,
//! multi-CU less efficient — emerge from resources × frequency × time.

use super::Resources;

/// Calibrated activity coefficients (Watts per unit at 450 MHz).
#[derive(Debug, Clone, Copy)]
pub struct PowerModel {
    /// Shell + HBM controller + idle card power.
    pub static_w: f64,
    pub lut_w: f64,
    pub ff_w: f64,
    pub bram_w: f64,
    pub uram_w: f64,
    pub dsp_w: f64,
    /// Per active HBM pseudo-channel interface.
    pub hbm_pc_w: f64,
    /// Nominal frequency the coefficients are normalized to.
    pub f_nom_mhz: f64,
}

impl Default for PowerModel {
    fn default() -> Self {
        PowerModel {
            static_w: 22.0, // U280 idle (shell + HBM stacks) ~20-25 W
            lut_w: 11.0e-6,
            ff_w: 2.5e-6,
            bram_w: 2.6e-3,
            uram_w: 9.0e-3,
            dsp_w: 2.2e-3,
            hbm_pc_w: 0.30,
            f_nom_mhz: 450.0,
        }
    }
}

impl PowerModel {
    /// Average power of a design using `r` resources at `f_mhz`, with
    /// `active_pcs` HBM pseudo-channels in use.
    pub fn average_power_w(&self, r: &Resources, f_mhz: f64, active_pcs: u32) -> f64 {
        let scale = f_mhz / self.f_nom_mhz;
        let dynamic = self.lut_w * r.lut as f64
            + self.ff_w * r.ff as f64
            + self.bram_w * r.bram as f64
            + self.uram_w * r.uram as f64
            + self.dsp_w * r.dsp as f64;
        self.static_w + scale * dynamic + self.hbm_pc_w * active_pcs as f64
    }

    /// Peak power estimate (all toggling, +30% over average activity).
    pub fn max_power_w(&self, r: &Resources, f_mhz: f64, active_pcs: u32) -> f64 {
        let avg_dynamic =
            self.average_power_w(r, f_mhz, active_pcs) - self.static_w;
        self.static_w + 1.3 * avg_dynamic
    }
}

/// The paper's CPU baseline power assumptions (§4.3): a conservative
/// 100 W average for the Intel Xeon E5-2680 v3 under kernel load
/// (TDP 120 W).
pub const INTEL_XEON_AVG_W: f64 = 100.0;
/// AMD EPYC 7282 (120 W TDP); same conservative convention.
pub const AMD_EPYC_AVG_W: f64 = 100.0;

#[cfg(test)]
mod tests {
    use super::*;

    fn df7_fx32_resources() -> Resources {
        // paper Table 3, Fixed Point 32 row
        Resources {
            lut: 231_062,
            ff: 346_507,
            bram: 1_338,
            uram: 0,
            dsp: 2_294,
        }
    }

    #[test]
    fn fx32_power_in_paper_range() {
        // Paper headline: ~103 GOPS at ~4 GOPS/W -> ~26 W average.
        let pm = PowerModel::default();
        let w = pm.average_power_w(&df7_fx32_resources(), 244.5, 16);
        assert!(
            (20.0..35.0).contains(&w),
            "fx32 average power {w} W out of plausible range"
        );
    }

    #[test]
    fn power_scales_with_frequency() {
        let pm = PowerModel::default();
        let r = df7_fx32_resources();
        let lo = pm.average_power_w(&r, 150.0, 2);
        let hi = pm.average_power_w(&r, 300.0, 2);
        assert!(hi > lo);
        // dynamic part exactly doubles
        let d_lo = lo - pm.static_w - 2.0 * pm.hbm_pc_w;
        let d_hi = hi - pm.static_w - 2.0 * pm.hbm_pc_w;
        assert!((d_hi / d_lo - 2.0).abs() < 1e-9);
    }

    #[test]
    fn max_exceeds_average() {
        let pm = PowerModel::default();
        let r = df7_fx32_resources();
        assert!(pm.max_power_w(&r, 244.5, 2) > pm.average_power_w(&r, 244.5, 2));
    }

    #[test]
    fn static_floor_without_logic() {
        let pm = PowerModel::default();
        let w = pm.average_power_w(&Resources::default(), 450.0, 0);
        assert!((w - pm.static_w).abs() < 1e-9);
    }
}
