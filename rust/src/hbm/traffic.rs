//! Per-channel traffic analysis of a routed system: the stage penalties
//! the cycle simulator applies and the per-channel utilization the
//! reports surface.
//!
//! Everything here is derived from the [`super::ChannelMap`] Olympus
//! stored on the `SystemSpec` — the switch geometry is resolved once at
//! generation time and consumed mechanistically here:
//!
//!  * **turnaround** — a CU whose read and write ports share a channel
//!    pays the controller's tWTR before each element's read burst and
//!    tRTW before its write burst (paper Challenge 2); CUs with
//!    separated directions pay nothing.
//!  * **contention** — when the dataflow pipeline overlaps the Read and
//!    Write stages *and* both directions share a channel (the ≥8-CU
//!    ping/pong layout), each stage also waits out the other direction's
//!    words on the wire: the channel, not the stage, is the binding
//!    resource.
//!  * **crossing slowdown** — a route through the segmented switch that
//!    is longer than the outstanding-transaction window sustains less
//!    than one word per cycle ([`super::Interconnect::effective_rate`]);
//!    the worst route of each direction throttles that stage.
//!
//! The simulator applies the worst CU's penalties to the representative
//! stage intervals (CUs are homogeneous under `LocalFirst`/`Striped`;
//! under `Pinned` the worst-routed CU bounds the system, which is the
//! conservative choice for a makespan model).

use super::{ChannelMap, CuRoutes};
use crate::olympus::SystemSpec;

/// Additive/multiplicative corrections to the Read/Write stage
/// intervals of one element, derived per channel from the routing.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct StagePenalty {
    /// tWTR-class wait before an element's read burst (cycles).
    pub read_turnaround: u64,
    /// tRTW-class wait before an element's write burst (cycles).
    pub write_turnaround: u64,
    /// Channel cycles the Read stage loses to overlapped writes.
    pub read_contention: u64,
    /// Channel cycles the Write stage loses to overlapped reads.
    pub write_contention: u64,
    /// ≥ 1.0; switch-crossing bandwidth throttle on the Read stage.
    pub read_slowdown: f64,
    /// ≥ 1.0; switch-crossing bandwidth throttle on the Write stage.
    pub write_slowdown: f64,
    /// Round-trip latency the pipeline fills once per batch (cycles).
    pub fill_cycles: u64,
}

impl StagePenalty {
    fn none() -> StagePenalty {
        StagePenalty {
            read_turnaround: 0,
            write_turnaround: 0,
            read_contention: 0,
            write_contention: 0,
            read_slowdown: 1.0,
            write_slowdown: 1.0,
            fill_cycles: 0,
        }
    }
}

/// Worst-case stage penalties over the system's CUs (see module docs
/// for why the worst CU is the representative one).
pub fn stage_penalty(spec: &SystemSpec) -> StagePenalty {
    let map = &spec.hbm_map;
    let t = map.interconnect.timing;
    let in_words = spec.kernel.input_words() as u64;
    let out_words = spec.kernel.output_words() as u64;
    let mut p = StagePenalty::none();
    for cu in &map.cus {
        let shared = shares_direction(cu);
        if shared && spec.dataflow {
            // Overlapped Read/Write stages are channel-bound: each sees
            // the channel's full per-element busy time — the other
            // direction's words plus both turnarounds (the channel
            // switches W→R and R→W once per element period).
            let pair = t.t_wtr_cycles + t.t_rtw_cycles;
            p.read_turnaround = p.read_turnaround.max(pair);
            p.write_turnaround = p.write_turnaround.max(pair);
            p.read_contention = p.read_contention.max(out_words);
            p.write_contention = p.write_contention.max(in_words);
        } else if shared {
            // serial stages: each direction only waits out its own
            // switch before streaming
            p.read_turnaround = p.read_turnaround.max(t.t_wtr_cycles);
            p.write_turnaround = p.write_turnaround.max(t.t_rtw_cycles);
        }
        let slow = |routes: &[super::Route]| {
            routes
                .iter()
                .map(|r| 1.0 / map.interconnect.effective_rate(r.hops))
                .fold(1.0f64, f64::max)
        };
        p.read_slowdown = p.read_slowdown.max(slow(&cu.read));
        p.write_slowdown = p.write_slowdown.max(slow(&cu.write));
    }
    p.fill_cycles = map.fill_latency_cycles();
    p
}

fn shares_direction(cu: &CuRoutes) -> bool {
    cu.shared
        || cu
            .read
            .iter()
            .any(|r| cu.write.iter().any(|w| w.channel == r.channel))
}

/// Time-averaged load on one pseudo-channel while its CU streams.
#[derive(Debug, Clone)]
pub struct ChannelLoad {
    pub channel: u32,
    pub cu: usize,
    /// Read words per element served by this channel (ping/pong
    /// alternation averaged over batches).
    pub read_words: f64,
    /// Write words per element served by this channel.
    pub write_words: f64,
    /// Direction-turnaround cycles per element on this channel.
    pub turnaround_cycles: f64,
    /// Busy fraction of the channel against the CU's element service
    /// interval (1.0 = the channel is the pace-setter).
    pub utilization: f64,
}

/// Everything the reports surface about the memory interconnect.
#[derive(Debug, Clone)]
pub struct HbmReport {
    pub channels: Vec<ChannelLoad>,
    /// Routes crossing at least one switch boundary.
    pub switch_crossings: u64,
    /// Total boundary hops (penalty-weighted crossing count).
    pub total_hops: u64,
    /// Pipeline-fill latency paid once per batch (cycles).
    pub fill_cycles: u64,
    pub max_utilization: f64,
}

/// Analyze the channel loads of a routed system. `element_interval` is
/// the CU's steady-state element service interval in cycles (the
/// bottleneck stage interval for dataflow systems, the stage sum for
/// flat ones).
pub fn report(spec: &SystemSpec, element_interval: u64) -> HbmReport {
    let map: &ChannelMap = &spec.hbm_map;
    let t = map.interconnect.timing;
    let interval = element_interval.max(1) as f64;
    let in_words = spec.kernel.input_words() as f64;
    let out_words = spec.kernel.output_words() as f64;

    let mut channels = Vec::new();
    let mut max_util = 0.0f64;
    for (cu, routes) in map.cus.iter().enumerate() {
        let shared = shares_direction(routes);
        let n_r = routes.read.len().max(1) as f64;
        let n_w = routes.write.len().max(1) as f64;
        for r in routes.unique_routes() {
            let serves_read = routes.read.iter().any(|x| x.channel == r.channel);
            let serves_write =
                routes.write.iter().any(|x| x.channel == r.channel);
            let read_words = if serves_read { in_words / n_r } else { 0.0 };
            let write_words = if serves_write { out_words / n_w } else { 0.0 };
            let turnaround = if shared && serves_read && serves_write {
                (t.t_rtw_cycles + t.t_wtr_cycles) as f64 / n_r
            } else {
                0.0
            };
            let utilization =
                (read_words + write_words + turnaround) / interval;
            max_util = max_util.max(utilization);
            channels.push(ChannelLoad {
                channel: r.channel,
                cu,
                read_words,
                write_words,
                turnaround_cycles: turnaround,
                utilization,
            });
        }
    }
    channels.sort_by_key(|c| c.channel);
    HbmReport {
        channels,
        switch_crossings: map.switch_crossings(),
        total_hops: map.total_hops(),
        fill_cycles: map.fill_latency_cycles(),
        max_utilization: max_util,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dsl;
    use crate::ir::{lower, rewrite, teil};
    use crate::olympus::{generate, OlympusOpts};
    use crate::platform::Platform;

    fn spec(opts: OlympusOpts) -> SystemSpec {
        let prog = dsl::parse(&dsl::inverse_helmholtz_source(11)).unwrap();
        let m = rewrite::optimize(teil::from_ast(&prog).unwrap());
        let k = lower::lower_kernel(&m, "helmholtz").unwrap();
        generate(&k, &opts, &Platform::alveo_u280()).unwrap()
    }

    #[test]
    fn separated_directions_pay_no_turnaround_or_contention() {
        let s = spec(OlympusOpts::dataflow(7)); // 1 CU < 8: separate I/O
        let p = stage_penalty(&s);
        assert_eq!(p.read_turnaround, 0);
        assert_eq!(p.write_turnaround, 0);
        assert_eq!(p.read_contention, 0);
        assert_eq!(p.write_contention, 0);
        assert_eq!(p.read_slowdown, 1.0, "local-first routes at full rate");
        assert_eq!(p.write_slowdown, 1.0);
    }

    #[test]
    fn shared_channels_pay_turnaround_and_overlap_contention() {
        let s = spec(OlympusOpts::dataflow(7).with_cus(8)); // ping/pong shared
        let t = s.hbm_map.interconnect.timing;
        let p = stage_penalty(&s);
        let pair = t.t_wtr_cycles + t.t_rtw_cycles;
        assert_eq!(p.read_turnaround, pair, "channel-bound: both switches");
        assert_eq!(p.write_turnaround, pair);
        assert_eq!(p.read_contention, s.kernel.output_words() as u64);
        assert_eq!(p.write_contention, s.kernel.input_words() as u64);
    }

    #[test]
    fn flat_kernels_pay_turnaround_but_never_contend() {
        let s = spec(OlympusOpts::baseline()); // one shared channel, serial
        let p = stage_penalty(&s);
        assert!(p.read_turnaround > 0);
        assert_eq!(p.read_contention, 0, "no stage overlap to contend");
        assert_eq!(p.write_contention, 0);
    }

    #[test]
    fn channel_report_covers_every_allocated_channel() {
        let s = spec(OlympusOpts::dataflow(7).with_cus(2));
        let rep = report(&s, 2783);
        assert_eq!(rep.channels.len(), s.total_pcs());
        assert_eq!(rep.switch_crossings, 0, "local-first default");
        for c in &rep.channels {
            assert!(c.utilization > 0.0 && c.utilization <= 1.0, "{c:?}");
        }
        // ping/pong read channels each carry half the input stream
        let in_words = s.kernel.input_words() as f64;
        let read_loads: Vec<&ChannelLoad> = rep
            .channels
            .iter()
            .filter(|c| c.read_words > 0.0)
            .collect();
        assert_eq!(read_loads.len(), 4, "2 CUs x ping/pong inputs");
        for c in read_loads {
            assert_eq!(c.read_words, in_words / 2.0);
        }
    }
}
