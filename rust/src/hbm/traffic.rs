//! Per-channel traffic analysis of a routed system: the stage penalties
//! the cycle simulator applies and the per-channel utilization the
//! reports surface.
//!
//! Everything here is derived from the [`super::ChannelMap`] Olympus
//! stored on the `SystemSpec` — the switch geometry is resolved once at
//! generation time and consumed mechanistically here:
//!
//!  * **turnaround** — a CU whose read and write ports share a channel
//!    pays the controller's tWTR before each element's read burst and
//!    tRTW before its write burst (paper Challenge 2); CUs with
//!    separated directions pay nothing.
//!  * **contention** — when the dataflow pipeline overlaps the Read and
//!    Write stages *and* both directions share a channel (the ≥8-CU
//!    ping/pong layout), each stage also waits out the other direction's
//!    words on the wire: the channel, not the stage, is the binding
//!    resource.
//!  * **crossing slowdown** — a route through the segmented switch that
//!    is longer than the outstanding-transaction window sustains less
//!    than one word per cycle ([`super::Interconnect::effective_rate`]);
//!    the worst route of each direction throttles that stage.
//!
//! The simulator applies the worst CU's penalties to the representative
//! stage intervals (CUs are homogeneous under `LocalFirst`/`Striped`;
//! under `Pinned` the worst-routed CU bounds the system, which is the
//! conservative choice for a makespan model).

use super::{ChannelMap, CuRoutes};
use crate::ir::affine::{BufId, NestKind};
use crate::mnemosyne::CacheScheme;
use crate::olympus::SystemSpec;

/// DRAM cycles one activate/precharge pair costs when an access leaves
/// the controller's open row. Calibrated against the Xilinx pseudo-random
/// HBM benchmark shape: a 16-word random burst sustains
/// `16 / (16 + 28) ≈ 36%` of streaming bandwidth — the ~3x collapse the
/// vendor measurements show for short random bursts.
pub const ROW_MISS_CYCLES: u64 = 28;

/// Mechanistic model of the DRAM-side behavior of one indexed stream
/// (paper §2's open-row/burst discussion, applied to gather/scatter).
///
/// A streaming access (`stride_entropy = 0`) pays nothing beyond the
/// words on the wire. A pseudo-random access opens a new row on
/// (almost) every burst; on-chip reuse divides those misses because
/// repeated touches of a row are served without reopening it.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AccessPattern {
    /// Contiguous words moved per indexed access (the row slice).
    pub burst_words: u64,
    /// Fraction of accesses landing outside the open row:
    /// 0 = streaming, 1 = pseudo-random.
    pub stride_entropy: f64,
    /// Mean accesses per distinct row (≥ 1); reuse captured on chip
    /// amortizes the row misses.
    pub reuse: f64,
}

impl AccessPattern {
    /// Sequential burst traffic — the dense-kernel baseline.
    pub fn streaming(burst_words: u64) -> AccessPattern {
        AccessPattern { burst_words, stride_entropy: 0.0, reuse: 1.0 }
    }

    /// Pseudo-random bursts with a given captured-reuse degree.
    pub fn random(burst_words: u64, reuse: f64) -> AccessPattern {
        AccessPattern { burst_words, stride_entropy: 1.0, reuse }
    }

    /// Fraction of streaming bandwidth the pattern sustains, in
    /// `(0, 1]`: `B / (B + entropy * ROW_MISS_CYCLES / reuse)`.
    pub fn efficiency(&self) -> f64 {
        let b = self.burst_words.max(1) as f64;
        let entropy = self.stride_entropy.clamp(0.0, 1.0);
        let miss = entropy * ROW_MISS_CYCLES as f64 / self.reuse.max(1.0);
        b / (b + miss)
    }

    /// ≥ 1.0 multiplier on the stream's stage interval.
    pub fn slowdown(&self) -> f64 {
        1.0 / self.efficiency()
    }
}

/// The pattern an indexed stream presents to HBM *after* the memory
/// plan's cache scheme filters it. `reuse` is the stream's intrinsic
/// accesses-per-row degree; `coverage` is the fraction of the array a
/// capacity-bounded scratchpad holds (`mnemosyne::CacheInstance`).
pub fn schemed_pattern(
    burst_words: u64,
    reuse: f64,
    scheme: CacheScheme,
    coverage: f64,
) -> AccessPattern {
    match scheme {
        // no on-chip structure: every access is a fresh row activation
        CacheScheme::Bypass => AccessPattern::random(burst_words, 1.0),
        // the whole array lives on chip; HBM sees one streaming pass
        CacheScheme::FullBuffer => AccessPattern::streaming(burst_words),
        // a direct-mapped scratchpad catches the re-touches
        // (1 - 1/reuse of the accesses) that fall inside its coverage
        CacheScheme::Cached(_) => {
            let hit = (1.0 - 1.0 / reuse.max(1.0)) * coverage.clamp(0.0, 1.0);
            AccessPattern {
                burst_words,
                stride_entropy: 1.0 - hit,
                reuse: 1.0,
            }
        }
    }
}

/// Worst-case (read, write) slowdown multipliers the kernel's indexed
/// nests impose on their stages: gathers throttle the Read stream,
/// scatters the Write stream. Kernels with no gather/scatter nests
/// return exactly `(1.0, 1.0)` — the dense path is bit-identical.
pub fn indexed_slowdowns(spec: &SystemSpec) -> (f64, f64) {
    let mut read = 1.0f64;
    let mut write = 1.0f64;
    for n in &spec.kernel.nests {
        match n.kind {
            NestKind::Gather { .. } => {
                read = read.max(indexed_buffer_slowdown(spec, n.reads[0], n.out_trips[0]));
            }
            NestKind::Scatter { .. } => {
                write = write.max(indexed_buffer_slowdown(spec, n.write, n.out_trips[0]));
            }
            _ => {}
        }
    }
    (read, write)
}

/// One indexed buffer's slowdown under the spec's cache scheme: burst =
/// the row slice, reuse = accesses per row, coverage from the plan's
/// cache instance (0 when the plan fronted nothing).
fn indexed_buffer_slowdown(spec: &SystemSpec, buf: BufId, accesses: usize) -> f64 {
    let shape = &spec.kernel.buffers[buf].shape;
    let burst = shape[1..].iter().product::<usize>().max(1) as u64;
    let rows = shape.first().copied().unwrap_or(1).max(1);
    let reuse = (accesses as f64 / rows as f64).max(1.0);
    let coverage = spec
        .memory
        .cache_for(buf)
        .map(|c| c.coverage(&spec.kernel))
        .unwrap_or(0.0);
    schemed_pattern(burst, reuse, spec.opts.cache_scheme, coverage).slowdown()
}

/// Additive/multiplicative corrections to the Read/Write stage
/// intervals of one element, derived per channel from the routing.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct StagePenalty {
    /// tWTR-class wait before an element's read burst (cycles).
    pub read_turnaround: u64,
    /// tRTW-class wait before an element's write burst (cycles).
    pub write_turnaround: u64,
    /// Channel cycles the Read stage loses to overlapped writes.
    pub read_contention: u64,
    /// Channel cycles the Write stage loses to overlapped reads.
    pub write_contention: u64,
    /// ≥ 1.0; switch-crossing bandwidth throttle on the Read stage.
    pub read_slowdown: f64,
    /// ≥ 1.0; switch-crossing bandwidth throttle on the Write stage.
    pub write_slowdown: f64,
    /// Round-trip latency the pipeline fills once per batch (cycles).
    pub fill_cycles: u64,
}

impl StagePenalty {
    fn none() -> StagePenalty {
        StagePenalty {
            read_turnaround: 0,
            write_turnaround: 0,
            read_contention: 0,
            write_contention: 0,
            read_slowdown: 1.0,
            write_slowdown: 1.0,
            fill_cycles: 0,
        }
    }
}

/// Worst-case stage penalties over the system's CUs (see module docs
/// for why the worst CU is the representative one).
pub fn stage_penalty(spec: &SystemSpec) -> StagePenalty {
    let map = &spec.hbm_map;
    let t = map.interconnect.timing;
    let in_words = spec.kernel.input_words() as u64;
    let out_words = spec.kernel.output_words() as u64;
    let mut p = StagePenalty::none();
    for cu in &map.cus {
        let shared = shares_direction(cu);
        if shared && spec.dataflow {
            // Overlapped Read/Write stages are channel-bound: each sees
            // the channel's full per-element busy time — the other
            // direction's words plus both turnarounds (the channel
            // switches W→R and R→W once per element period).
            let pair = t.t_wtr_cycles + t.t_rtw_cycles;
            p.read_turnaround = p.read_turnaround.max(pair);
            p.write_turnaround = p.write_turnaround.max(pair);
            p.read_contention = p.read_contention.max(out_words);
            p.write_contention = p.write_contention.max(in_words);
        } else if shared {
            // serial stages: each direction only waits out its own
            // switch before streaming
            p.read_turnaround = p.read_turnaround.max(t.t_wtr_cycles);
            p.write_turnaround = p.write_turnaround.max(t.t_rtw_cycles);
        }
        let slow = |routes: &[super::Route]| {
            routes
                .iter()
                .map(|r| 1.0 / map.interconnect.effective_rate(r.hops))
                .fold(1.0f64, f64::max)
        };
        p.read_slowdown = p.read_slowdown.max(slow(&cu.read));
        p.write_slowdown = p.write_slowdown.max(slow(&cu.write));
    }
    // irregular-access throttle: gather streams price their row-miss
    // behavior into the Read stage, scatters into Write (dense kernels
    // multiply by exactly 1.0)
    let (gather, scatter) = indexed_slowdowns(spec);
    p.read_slowdown *= gather;
    p.write_slowdown *= scatter;
    p.fill_cycles = map.fill_latency_cycles();
    p
}

fn shares_direction(cu: &CuRoutes) -> bool {
    cu.shared
        || cu
            .read
            .iter()
            .any(|r| cu.write.iter().any(|w| w.channel == r.channel))
}

/// Time-averaged load on one pseudo-channel while its CU streams.
#[derive(Debug, Clone)]
pub struct ChannelLoad {
    pub channel: u32,
    pub cu: usize,
    /// Read words per element served by this channel (ping/pong
    /// alternation averaged over batches).
    pub read_words: f64,
    /// Write words per element served by this channel.
    pub write_words: f64,
    /// Direction-turnaround cycles per element on this channel.
    pub turnaround_cycles: f64,
    /// Busy fraction of the channel against the CU's element service
    /// interval (1.0 = the channel is the pace-setter).
    pub utilization: f64,
}

/// Everything the reports surface about the memory interconnect.
#[derive(Debug, Clone)]
pub struct HbmReport {
    pub channels: Vec<ChannelLoad>,
    /// Routes crossing at least one switch boundary.
    pub switch_crossings: u64,
    /// Total boundary hops (penalty-weighted crossing count).
    pub total_hops: u64,
    /// Pipeline-fill latency paid once per batch (cycles).
    pub fill_cycles: u64,
    pub max_utilization: f64,
}

/// Analyze the channel loads of a routed system. `element_interval` is
/// the CU's steady-state element service interval in cycles (the
/// bottleneck stage interval for dataflow systems, the stage sum for
/// flat ones).
pub fn report(spec: &SystemSpec, element_interval: u64) -> HbmReport {
    let map: &ChannelMap = &spec.hbm_map;
    let t = map.interconnect.timing;
    let interval = element_interval.max(1) as f64;
    let in_words = spec.kernel.input_words() as f64;
    let out_words = spec.kernel.output_words() as f64;

    let mut channels = Vec::new();
    let mut max_util = 0.0f64;
    for (cu, routes) in map.cus.iter().enumerate() {
        let shared = shares_direction(routes);
        let n_r = routes.read.len().max(1) as f64;
        let n_w = routes.write.len().max(1) as f64;
        for r in routes.unique_routes() {
            let serves_read = routes.read.iter().any(|x| x.channel == r.channel);
            let serves_write =
                routes.write.iter().any(|x| x.channel == r.channel);
            let read_words = if serves_read { in_words / n_r } else { 0.0 };
            let write_words = if serves_write { out_words / n_w } else { 0.0 };
            let turnaround = if shared && serves_read && serves_write {
                (t.t_rtw_cycles + t.t_wtr_cycles) as f64 / n_r
            } else {
                0.0
            };
            let utilization =
                (read_words + write_words + turnaround) / interval;
            max_util = max_util.max(utilization);
            channels.push(ChannelLoad {
                channel: r.channel,
                cu,
                read_words,
                write_words,
                turnaround_cycles: turnaround,
                utilization,
            });
        }
    }
    channels.sort_by_key(|c| c.channel);
    HbmReport {
        channels,
        switch_crossings: map.switch_crossings(),
        total_hops: map.total_hops(),
        fill_cycles: map.fill_latency_cycles(),
        max_utilization: max_util,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dsl;
    use crate::ir::{lower, rewrite, teil};
    use crate::olympus::{generate, OlympusOpts};
    use crate::platform::Platform;

    fn spec(opts: OlympusOpts) -> SystemSpec {
        let prog = dsl::parse(&dsl::inverse_helmholtz_source(11)).unwrap();
        let m = rewrite::optimize(teil::from_ast(&prog).unwrap());
        let k = lower::lower_kernel(&m, "helmholtz").unwrap();
        generate(&k, &opts, &Platform::alveo_u280()).unwrap()
    }

    #[test]
    fn separated_directions_pay_no_turnaround_or_contention() {
        let s = spec(OlympusOpts::dataflow(7)); // 1 CU < 8: separate I/O
        let p = stage_penalty(&s);
        assert_eq!(p.read_turnaround, 0);
        assert_eq!(p.write_turnaround, 0);
        assert_eq!(p.read_contention, 0);
        assert_eq!(p.write_contention, 0);
        assert_eq!(p.read_slowdown, 1.0, "local-first routes at full rate");
        assert_eq!(p.write_slowdown, 1.0);
    }

    #[test]
    fn shared_channels_pay_turnaround_and_overlap_contention() {
        let s = spec(OlympusOpts::dataflow(7).with_cus(8)); // ping/pong shared
        let t = s.hbm_map.interconnect.timing;
        let p = stage_penalty(&s);
        let pair = t.t_wtr_cycles + t.t_rtw_cycles;
        assert_eq!(p.read_turnaround, pair, "channel-bound: both switches");
        assert_eq!(p.write_turnaround, pair);
        assert_eq!(p.read_contention, s.kernel.output_words() as u64);
        assert_eq!(p.write_contention, s.kernel.input_words() as u64);
    }

    #[test]
    fn flat_kernels_pay_turnaround_but_never_contend() {
        let s = spec(OlympusOpts::baseline()); // one shared channel, serial
        let p = stage_penalty(&s);
        assert!(p.read_turnaround > 0);
        assert_eq!(p.read_contention, 0, "no stage overlap to contend");
        assert_eq!(p.write_contention, 0);
    }

    fn mesh_spec(scheme: CacheScheme) -> SystemSpec {
        let prog = dsl::parse(&dsl::mesh_gather_source(64, 256, 8)).unwrap();
        let m = rewrite::optimize(teil::from_ast(&prog).unwrap());
        let k = lower::lower_kernel(&m, "mesh_gather").unwrap();
        generate(
            &k,
            &OlympusOpts::baseline().with_cache_scheme(scheme),
            &Platform::alveo_u280(),
        )
        .unwrap()
    }

    #[test]
    fn random_sixteen_word_burst_matches_the_xilinx_calibration() {
        let eff = AccessPattern::random(16, 1.0).efficiency();
        assert!((eff - 16.0 / 44.0).abs() < 1e-12, "{eff}");
    }

    #[test]
    fn streaming_patterns_pay_nothing() {
        for b in [1, 8, 64, 4096] {
            assert_eq!(AccessPattern::streaming(b).efficiency(), 1.0);
            assert_eq!(AccessPattern::streaming(b).slowdown(), 1.0);
        }
    }

    #[test]
    fn efficiency_is_bounded_and_monotone() {
        let mut last = 0.0;
        for reuse in [1.0, 2.0, 4.0, 16.0, 256.0] {
            let eff = AccessPattern::random(8, reuse).efficiency();
            assert!(eff > 0.0 && eff <= 1.0);
            assert!(eff >= last, "reuse {reuse}: {eff} < {last}");
            last = eff;
        }
        let mut last = 0.0;
        for burst in [1, 2, 8, 64, 1024] {
            let eff = AccessPattern::random(burst, 1.0).efficiency();
            assert!(eff >= last, "burst {burst}: {eff} < {last}");
            last = eff;
        }
    }

    #[test]
    fn dense_kernels_carry_no_indexed_slowdown() {
        let s = spec(OlympusOpts::dataflow(7));
        assert_eq!(indexed_slowdowns(&s), (1.0, 1.0));
    }

    #[test]
    fn cache_schemes_order_the_gather_slowdown() {
        // u : [64 8] read through a 256-entry map: burst 8, reuse 4
        let bypass = indexed_slowdowns(&mesh_spec(CacheScheme::Bypass)).0;
        let cached = indexed_slowdowns(&mesh_spec(CacheScheme::Cached(128))).0;
        let full = indexed_slowdowns(&mesh_spec(CacheScheme::FullBuffer)).0;
        assert_eq!(bypass, (8.0 + 28.0) / 8.0, "every access reopens a row");
        assert_eq!(full, 1.0, "on-chip copy streams");
        assert!(full < cached && cached < bypass, "{full} {cached} {bypass}");
        // and the penalty lands on the Read stage of the stage model
        let p = stage_penalty(&mesh_spec(CacheScheme::Bypass));
        assert!(p.read_slowdown >= bypass);
    }

    #[test]
    fn cached_slowdown_improves_with_capacity() {
        let mut last = f64::MAX;
        for words in [16, 64, 128, 256, 512] {
            let s = indexed_slowdowns(&mesh_spec(CacheScheme::Cached(words))).0;
            assert!(s <= last, "cache {words}: {s} > {last}");
            last = s;
        }
    }

    #[test]
    fn channel_report_covers_every_allocated_channel() {
        let s = spec(OlympusOpts::dataflow(7).with_cus(2));
        let rep = report(&s, 2783);
        assert_eq!(rep.channels.len(), s.total_pcs());
        assert_eq!(rep.switch_crossings, 0, "local-first default");
        for c in &rep.channels {
            assert!(c.utilization > 0.0 && c.utilization <= 1.0, "{c:?}");
        }
        // ping/pong read channels each carry half the input stream
        let in_words = s.kernel.input_words() as f64;
        let read_loads: Vec<&ChannelLoad> = rep
            .channels
            .iter()
            .filter(|c| c.read_words > 0.0)
            .collect();
        assert_eq!(read_loads.len(), 4, "2 CUs x ping/pong inputs");
        for c in read_loads {
            assert_eq!(c.read_words, in_words / 2.0);
        }
    }
}
