//! Explicit channel-allocation policies (replaces Olympus's implicit
//! sequential numbering, paper §3.6.1).
//!
//! Master slots are fixed by CU placement: the ports of CU 0, then
//! CU 1, … occupy consecutive AXI master positions on the switch (one
//! slot per allocated channel; a shared read/write channel is one
//! bundled port). The *policy* decides which pseudo-channel number each
//! slot is bound to:
//!
//!  * [`ChannelPolicy::LocalFirst`] — each slot takes the nearest free
//!    channel (fewest switch boundaries, lowest number on ties). With an
//!    empty switch this is the identity mapping, i.e. exactly the
//!    sequential numbering the seed hard-coded — zero crossings.
//!  * [`ChannelPolicy::Striped`] — slots round-robin across switch
//!    segments, spreading each CU's traffic over the HBM stacks at the
//!    cost of lateral-link crossings. This is the allocation the `dse`
//!    engine must be able to *reject* mechanistically.
//!  * [`ChannelPolicy::Pinned`] — the designer supplies the channel list
//!    per CU (read channels first, then write channels; one list entry
//!    per allocated channel). Invalid pins are a generation error, which
//!    the DSE evaluator reports as a rejection.

use super::{CuRoutes, Interconnect, Route};

/// How Olympus binds CU ports to pseudo-channels.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ChannelPolicy {
    LocalFirst,
    Striped,
    /// Per-CU explicit channel lists: read channels first, then write
    /// channels (omit the write half when the CU shares channels).
    Pinned(Vec<Vec<u32>>),
}

impl ChannelPolicy {
    /// Every name [`ChannelPolicy::parse`] accepts — the single source
    /// of truth the CLI's unknown-policy error lists (same contract as
    /// `EMIT_MODES` for `--emit`).
    pub const PARSE_NAMES: &'static [&'static str] =
        &["local", "local-first", "striped"];

    /// Short name used in labels and CSV/JSON output.
    pub fn name(&self) -> &'static str {
        match self {
            ChannelPolicy::LocalFirst => "local-first",
            ChannelPolicy::Striped => "striped",
            ChannelPolicy::Pinned(_) => "pinned",
        }
    }

    /// Parse a CLI policy name (`local` / `local-first` / `striped`).
    pub fn parse(s: &str) -> Option<ChannelPolicy> {
        match s {
            "local" | "local-first" => Some(ChannelPolicy::LocalFirst),
            "striped" => Some(ChannelPolicy::Striped),
            _ => None,
        }
    }
}

/// Channel demand of one CU, as Olympus derives it from the buffering
/// mode: `shared` means the read and write sets are the same channels
/// (ping/pong carrying both directions), so only `reads` channels are
/// allocated.
#[derive(Debug, Clone, Copy)]
pub struct PortDemand {
    pub reads: u32,
    pub writes: u32,
    pub shared: bool,
}

impl PortDemand {
    /// Physical channels this CU occupies.
    pub fn slots(&self) -> u32 {
        if self.shared {
            self.reads
        } else {
            self.reads + self.writes
        }
    }
}

/// Bind every CU's ports to channels under `policy`. Master slots are
/// assigned sequentially in CU order; the returned routes carry the
/// switch distance of each binding. Fails when the demand exceeds the
/// interconnect or a pinned list is malformed.
pub fn allocate(
    policy: &ChannelPolicy,
    demands: &[PortDemand],
    ic: &Interconnect,
) -> Result<Vec<CuRoutes>, String> {
    let total: u32 = demands.iter().map(|d| d.slots()).sum();
    if total > ic.channels {
        return Err(format!(
            "{total} channels required, {} available",
            ic.channels
        ));
    }
    for (i, d) in demands.iter().enumerate() {
        if d.reads == 0 || d.writes == 0 {
            return Err(format!("CU {i} demands no channels"));
        }
        if d.shared && d.reads != d.writes {
            return Err(format!(
                "CU {i}: shared channels need matching read/write counts"
            ));
        }
    }

    let mut free = vec![true; ic.channels as usize];
    let mut master = 0u32;
    let mut stripe = 0u32; // striped policy's rolling position
    let mut out = Vec::with_capacity(demands.len());
    for (cu, d) in demands.iter().enumerate() {
        let mut routes = Vec::with_capacity(d.slots() as usize);
        for _ in 0..d.slots() {
            let channel = match policy {
                ChannelPolicy::LocalFirst => nearest_free(&free, master, ic),
                ChannelPolicy::Striped => {
                    let c = striped_free(&free, &mut stripe, ic);
                    stripe += 1;
                    c
                }
                ChannelPolicy::Pinned(lists) => {
                    pinned(lists, cu, routes.len(), &free, ic)?
                }
            };
            free[channel as usize] = false;
            routes.push(Route {
                master,
                channel,
                hops: ic.hops(master, channel),
            });
            master += 1;
        }
        let (read, write) = if d.shared {
            (routes.clone(), routes)
        } else {
            let write = routes.split_off(d.reads as usize);
            (routes, write)
        };
        out.push(CuRoutes {
            read,
            write,
            shared: d.shared,
        });
    }
    Ok(out)
}

/// Free channel with the fewest switch boundaries from `master`, lowest
/// channel number on ties.
fn nearest_free(free: &[bool], master: u32, ic: &Interconnect) -> u32 {
    let mut best = None;
    for (c, &ok) in free.iter().enumerate() {
        if !ok {
            continue;
        }
        let h = ic.hops(master, c as u32);
        let better = match best {
            None => true,
            Some((bh, _)) => h < bh,
        };
        if better {
            best = Some((h, c as u32));
        }
    }
    best.expect("allocate checked total demand <= channels").1
}

/// Next free channel in segment-transposed order: position `k` targets
/// segment `k mod segments`, walking one channel deeper per full round.
fn striped_free(free: &[bool], stripe: &mut u32, ic: &Interconnect) -> u32 {
    let nseg = ic.segments().max(1);
    loop {
        let k = *stripe;
        let c = (k % nseg) * ic.segment_channels + (k / nseg) % ic.segment_channels;
        if free[c as usize] {
            return c;
        }
        *stripe += 1;
    }
}

fn pinned(
    lists: &[Vec<u32>],
    cu: usize,
    slot: usize,
    free: &[bool],
    ic: &Interconnect,
) -> Result<u32, String> {
    let list = lists
        .get(cu)
        .ok_or_else(|| format!("pinned policy lists no channels for CU {cu}"))?;
    let &c = list.get(slot).ok_or_else(|| {
        format!(
            "pinned policy lists {} channels for CU {cu}, slot {slot} needed",
            list.len()
        )
    })?;
    if c >= ic.channels {
        return Err(format!("CU {cu} pinned to nonexistent channel {c}"));
    }
    if !free[c as usize] {
        return Err(format!("channel {c} pinned twice"));
    }
    Ok(c)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::platform::Platform;

    fn ic() -> Interconnect {
        Interconnect::hbm(&Platform::alveo_u280().hbm)
    }

    fn sep(n: usize) -> Vec<PortDemand> {
        vec![
            PortDemand {
                reads: 2,
                writes: 2,
                shared: false,
            };
            n
        ]
    }

    #[test]
    fn local_first_on_an_empty_switch_is_the_identity() {
        let routes = allocate(&ChannelPolicy::LocalFirst, &sep(2), &ic()).unwrap();
        let all: Vec<u32> = routes
            .iter()
            .flat_map(|r| r.read.iter().chain(&r.write).map(|x| x.channel))
            .collect();
        assert_eq!(all, vec![0, 1, 2, 3, 4, 5, 6, 7]);
        assert!(routes
            .iter()
            .flat_map(|r| r.unique_routes())
            .all(|r| r.hops == 0));
    }

    #[test]
    fn striped_spreads_across_segments() {
        let routes = allocate(&ChannelPolicy::Striped, &sep(1), &ic()).unwrap();
        let chans: Vec<u32> = routes[0]
            .read
            .iter()
            .chain(&routes[0].write)
            .map(|r| r.channel)
            .collect();
        assert_eq!(chans, vec![0, 4, 8, 12], "one channel per segment");
        let hops: Vec<u32> = routes[0]
            .read
            .iter()
            .chain(&routes[0].write)
            .map(|r| r.hops)
            .collect();
        assert_eq!(hops, vec![0, 1, 2, 3]);
    }

    #[test]
    fn shared_demand_reuses_the_same_routes_both_ways() {
        let d = [PortDemand {
            reads: 2,
            writes: 2,
            shared: true,
        }];
        let routes = allocate(&ChannelPolicy::LocalFirst, &d, &ic()).unwrap();
        assert_eq!(routes[0].read, routes[0].write);
        assert_eq!(routes[0].unique_routes().len(), 2);
    }

    #[test]
    fn pinned_routes_follow_the_designer() {
        let policy = ChannelPolicy::Pinned(vec![vec![30, 31]]);
        let d = [PortDemand {
            reads: 1,
            writes: 1,
            shared: false,
        }];
        let routes = allocate(&policy, &d, &ic()).unwrap();
        assert_eq!(routes[0].read[0].channel, 30);
        assert_eq!(routes[0].write[0].channel, 31);
        assert_eq!(routes[0].read[0].hops, 7, "master 0 to segment 7");
    }

    #[test]
    fn malformed_pins_are_rejected() {
        let d = [PortDemand {
            reads: 1,
            writes: 1,
            shared: false,
        }];
        let short = ChannelPolicy::Pinned(vec![vec![0]]);
        assert!(allocate(&short, &d, &ic()).is_err(), "list too short");
        let oob = ChannelPolicy::Pinned(vec![vec![0, 99]]);
        assert!(allocate(&oob, &d, &ic()).is_err(), "nonexistent channel");
        let dup = ChannelPolicy::Pinned(vec![vec![5, 5]]);
        assert!(allocate(&dup, &d, &ic()).is_err(), "channel pinned twice");
    }

    #[test]
    fn over_demand_is_rejected() {
        let err = allocate(&ChannelPolicy::LocalFirst, &sep(9), &ic());
        assert!(err.is_err(), "36 channels on a 32-channel switch");
    }

    #[test]
    fn policy_names_and_parsing() {
        assert_eq!(ChannelPolicy::LocalFirst.name(), "local-first");
        assert_eq!(ChannelPolicy::parse("striped"), Some(ChannelPolicy::Striped));
        assert_eq!(
            ChannelPolicy::parse("local"),
            Some(ChannelPolicy::LocalFirst)
        );
        assert_eq!(ChannelPolicy::parse("bogus"), None);
        // PARSE_NAMES is exactly the accepted set
        for name in ChannelPolicy::PARSE_NAMES {
            assert!(ChannelPolicy::parse(name).is_some(), "{name}");
        }
    }
}
