//! Mechanistic model of the U280 memory interconnect (paper §2.2–§2.3,
//! Challenges 1–2): the 32 HBM pseudo-channels behind the segmented AXI
//! switch, plus the 2-bank DDR4 alternative.
//!
//! The physical switch is eight 4×4 full-crossbar units chained by
//! lateral links: a master reaches the four pseudo-channels of its own
//! unit at full bandwidth, and every other channel by crossing one
//! switch boundary per segment of distance. Three effects follow, and
//! this module models each one explicitly instead of folding them into
//! fitted constants:
//!
//!  * **switch-crossing latency** — every boundary adds round-trip
//!    cycles; with a bounded number of outstanding AXI transactions the
//!    latency·bandwidth product caps the sustainable rate, so far
//!    crossings *throttle* a port, not just delay it
//!    ([`Interconnect::effective_rate`]);
//!  * **direction turnaround** — a pseudo-channel that serves both
//!    reads and writes pays tWTR/tRTW-class controller penalties on
//!    every direction switch (paper Challenge 2); the penalty is now a
//!    per-channel property of the routing, not a global constant
//!    (`traffic::stage_penalty`);
//!  * **bandwidth sharing** — ports that overlap in time on one channel
//!    (the ≥8-CU ping/pong layout streams reads *and* writes through
//!    the same channel while dataflow overlaps the stages) contend for
//!    its word slots (`traffic`).
//!
//! [`alloc`] turns Olympus's implicit sequential channel numbering into
//! an explicit policy (local-first, striped, user-pinned); [`traffic`]
//! converts a routed system into the stage penalties and per-channel
//! utilization the simulator and the `dse` reports consume. The retired
//! constants and the calibration of the new parameters are tabulated in
//! DESIGN.md §"Memory interconnect model".

pub mod alloc;
pub mod traffic;

pub use alloc::{allocate, ChannelPolicy, PortDemand};
pub use traffic::{HbmReport, StagePenalty};

use crate::platform::{HbmConfig, SwitchConfig};

/// The memory-side interconnect a generated system routes through:
/// channel count, switch segmentation, and the timing parameters of one
/// channel/switch unit.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Interconnect {
    /// Pseudo-channels (HBM: 32) or banks (DDR4: 2).
    pub channels: u32,
    /// Masters/channels per switch unit (HBM: 4). A single segment
    /// spanning every channel models a switchless memory (DDR4).
    pub segment_channels: u32,
    pub timing: SwitchConfig,
}

impl Interconnect {
    /// The U280 HBM subsystem: 32 pseudo-channels behind eight 4×4
    /// switch units.
    pub fn hbm(cfg: &HbmConfig) -> Interconnect {
        Interconnect {
            channels: cfg.pseudo_channels,
            segment_channels: cfg.switch.segment_channels,
            timing: cfg.switch,
        }
    }

    /// The two DDR4 banks: no segmented switch (one segment spans both
    /// banks, so no route ever crosses), but the same controller-class
    /// read/write turnaround timings apply.
    pub fn ddr4(cfg: &HbmConfig) -> Interconnect {
        Interconnect {
            channels: 2,
            segment_channels: 2,
            timing: cfg.switch,
        }
    }

    pub fn segments(&self) -> u32 {
        self.channels / self.segment_channels.max(1)
    }

    /// Switch unit a channel (or the equally-numbered master slot)
    /// belongs to.
    pub fn segment_of(&self, slot: u32) -> u32 {
        slot / self.segment_channels.max(1)
    }

    /// Switch boundaries between a master slot and a channel.
    pub fn hops(&self, master: u32, channel: u32) -> u32 {
        self.segment_of(master).abs_diff(self.segment_of(channel))
    }

    /// Round-trip latency of one transaction over `hops` boundaries.
    pub fn round_trip_cycles(&self, hops: u32) -> u64 {
        self.timing.base_latency_cycles
            + hops as u64 * self.timing.lateral_hop_cycles
    }

    /// Sustainable fraction of the port's word rate at `hops` distance:
    /// with `max_outstanding` transactions of `burst_words` in flight,
    /// the latency·bandwidth product caps throughput at
    /// `outstanding · burst / round_trip` words per cycle (≤ 1). Local
    /// access is calibrated to exactly 1.0; every boundary past the
    /// covered latency throttles proportionally.
    pub fn effective_rate(&self, hops: u32) -> f64 {
        let in_flight =
            (self.timing.max_outstanding * self.timing.burst_words) as f64;
        (in_flight / self.round_trip_cycles(hops) as f64).min(1.0)
    }
}

/// One routed CU port: the AXI master slot it occupies, the channel the
/// allocation policy bound it to, and the switch distance between them.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Route {
    pub master: u32,
    pub channel: u32,
    pub hops: u32,
}

/// The routed ports of one CU. When `shared` is true the read and write
/// routes are the same physical channels (ping/pong carrying both
/// directions); otherwise the sets are disjoint.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CuRoutes {
    pub read: Vec<Route>,
    pub write: Vec<Route>,
    pub shared: bool,
}

impl CuRoutes {
    /// Physical routes, counting a shared read/write channel once.
    pub fn unique_routes(&self) -> Vec<&Route> {
        let mut v: Vec<&Route> = self.read.iter().collect();
        for w in &self.write {
            if !v
                .iter()
                .any(|r| r.master == w.master && r.channel == w.channel)
            {
                v.push(w);
            }
        }
        v
    }
}

/// Resolved port→channel routing for a whole generated system, stored on
/// the `SystemSpec` so downstream consumers (sim, reports) never have to
/// re-derive switch geometry.
#[derive(Debug, Clone, PartialEq)]
pub struct ChannelMap {
    pub interconnect: Interconnect,
    pub cus: Vec<CuRoutes>,
}

impl ChannelMap {
    /// Routes that cross at least one switch boundary.
    pub fn switch_crossings(&self) -> u64 {
        self.cus
            .iter()
            .flat_map(|cu| cu.unique_routes())
            .filter(|r| r.hops > 0)
            .count() as u64
    }

    /// Total boundary hops over all routes (the penalty-weighted count).
    pub fn total_hops(&self) -> u64 {
        self.cus
            .iter()
            .flat_map(|cu| cu.unique_routes())
            .map(|r| r.hops as u64)
            .sum()
    }

    /// Worst round-trip latency any CU's pipeline must fill (cycles).
    pub fn fill_latency_cycles(&self) -> u64 {
        self.cus
            .iter()
            .flat_map(|cu| cu.unique_routes())
            .map(|r| self.interconnect.round_trip_cycles(r.hops))
            .max()
            .unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::platform::Platform;

    fn ic() -> Interconnect {
        Interconnect::hbm(&Platform::alveo_u280().hbm)
    }

    #[test]
    fn u280_switch_is_eight_4x4_units() {
        let ic = ic();
        assert_eq!(ic.channels, 32);
        assert_eq!(ic.segment_channels, 4);
        assert_eq!(ic.segments(), 8);
        assert_eq!(ic.segment_of(0), 0);
        assert_eq!(ic.segment_of(3), 0);
        assert_eq!(ic.segment_of(4), 1);
        assert_eq!(ic.segment_of(31), 7);
    }

    #[test]
    fn hops_are_symmetric_segment_distances() {
        let ic = ic();
        assert_eq!(ic.hops(0, 3), 0, "same unit");
        assert_eq!(ic.hops(0, 4), 1);
        assert_eq!(ic.hops(4, 0), 1, "symmetric");
        assert_eq!(ic.hops(0, 31), 7, "corner to corner");
    }

    #[test]
    fn latency_grows_per_boundary() {
        let ic = ic();
        assert!(ic.round_trip_cycles(0) < ic.round_trip_cycles(1));
        assert!(ic.round_trip_cycles(1) < ic.round_trip_cycles(3));
        let per_hop = ic.round_trip_cycles(1) - ic.round_trip_cycles(0);
        assert_eq!(
            ic.round_trip_cycles(3) - ic.round_trip_cycles(2),
            per_hop,
            "linear in hops"
        );
    }

    #[test]
    fn local_rate_is_full_and_crossings_throttle() {
        let ic = ic();
        assert_eq!(ic.effective_rate(0), 1.0, "local access calibrated to 1");
        assert!(ic.effective_rate(1) < 1.0);
        assert!(ic.effective_rate(3) < ic.effective_rate(1));
    }

    #[test]
    fn ddr4_has_two_banks_and_no_crossings() {
        let ic = Interconnect::ddr4(&Platform::alveo_u280().hbm);
        assert_eq!(ic.channels, 2);
        assert_eq!(ic.segments(), 1);
        assert_eq!(ic.hops(0, 1), 0);
        assert_eq!(ic.effective_rate(0), 1.0);
    }

    #[test]
    fn unique_routes_count_shared_channels_once() {
        let r = Route {
            master: 0,
            channel: 0,
            hops: 0,
        };
        let shared = CuRoutes {
            read: vec![r],
            write: vec![r],
            shared: true,
        };
        assert_eq!(shared.unique_routes().len(), 1);
        let separate = CuRoutes {
            read: vec![r],
            write: vec![Route {
                master: 1,
                channel: 1,
                hops: 0,
            }],
            shared: false,
        };
        assert_eq!(separate.unique_routes().len(), 2);
    }
}
