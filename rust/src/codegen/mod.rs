//! Code generation back-ends.
//!
//! `c_emit` produces the HLS-ready C99 the paper's flow hands to Vitis
//! (Fig. 12b): one function per dataflow group, `#pragma HLS pipeline`
//! on the innermost pipelined loop, the reduction unrolled. In this
//! reproduction the C output is an auditable artifact (and golden-tested)
//! — the executable datapath is the AOT-compiled HLO (see DESIGN.md).
//!
//! `vitis` wraps the `c_emit` groups into a complete, self-consistent
//! Vitis package per `SystemSpec` — CU C++ with `m_axi` interfaces,
//! `XCL_MEM_TOPOLOGY` host code, `sp=` link cfg, Makefile, and a
//! versioned manifest (DESIGN.md §2.9).

pub mod c_emit;
pub mod vitis;
