//! Vitis package emission (paper §V: the generated system is handed to
//! Vitis as HLS C++, host code, and a connectivity configuration that
//! binds each CU AXI port to its HBM pseudo-channel).
//!
//! One [`SystemSpec`] becomes one self-consistent package of five files:
//!
//! | path                | content                                        |
//! |---------------------|------------------------------------------------|
//! | `src/{kernel}.cpp`  | HLS C++ CU: `c_emit` groups + `m_axi` top level |
//! | `src/host.cpp`      | XRT host with `XCL_MEM_TOPOLOGY` placement     |
//! | `link.cfg`          | `v++ --config`: `nk=` / `sp=` / `slr=` lines   |
//! | `Makefile`          | `v++ -c` / `-l` / host build recipe            |
//! | `package.json`      | manifest: schema, fingerprint, connectivity    |
//!
//! Every cross-file fact (CU instance names, AXI port names, channel
//! numbers) is derived from the same sources — `config::cu_instance` /
//! `read_port` / `write_port` and `SystemSpec::channels` — so the files
//! cannot disagree. Emission is byte-deterministic: all iteration is
//! over `Vec`s and the manifest serializes through `util::json`'s
//! `BTreeMap`. The parsers at the bottom ([`parse_connectivity`],
//! [`cfg_channel_assignment`], [`parse_host_topology`]) power the
//! property tests that prove the package agrees with the routed
//! `hbm::ChannelMap` the simulator was driven from.
//!
//! Ping/pong port semantics: each of a CU's read channels carries the
//! *full* input frame of alternate batches (paper §3.6.1 double
//! buffering), so every read port is a complete input pointer and the
//! host passes a `phase` scalar to select the pair — mirroring
//! `config::host_batch_steps`' `read[phase % len]`.

use std::fmt::Write as _;
use std::path::{Path, PathBuf};

use crate::codegen::c_emit;
use crate::datatype::DataType;
use crate::mnemosyne::{BankingScheme, MemoryPlan};
use crate::olympus::config;
use crate::olympus::{CuChannels, MemoryKind, SystemSpec};
use crate::platform::Platform;
use crate::util::json::Json;

/// Version of the emitted package layout. Bump when file names, cfg
/// grammar, or manifest keys change shape; recorded in `package.json`
/// and in the `vitis` section of saved flow artifacts.
pub const EMIT_SCHEMA_VERSION: u64 = 1;

/// A fully rendered Vitis package: relative path → file text, in fixed
/// emission order (payload files first, `package.json` last).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct VitisPackage {
    files: Vec<(String, String)>,
}

impl VitisPackage {
    /// The files in emission order.
    pub fn files(&self) -> &[(String, String)] {
        &self.files
    }

    /// Text of one file by relative path.
    pub fn file(&self, path: &str) -> Option<&str> {
        self.files
            .iter()
            .find(|(p, _)| p == path)
            .map(|(_, t)| t.as_str())
    }

    /// FNV-1a fingerprint of the payload files (everything except the
    /// manifest, which records this value and so cannot hash itself).
    pub fn fingerprint(&self) -> String {
        let payload = self
            .files
            .iter()
            .filter(|(p, _)| p != "package.json")
            .map(|(p, t)| (p.as_str(), t.as_str()));
        format!("{:016x}", fnv64(payload))
    }

    /// All files concatenated with `// ==== path ====` separators — the
    /// `--emit vitis` stdout form.
    pub fn bundle(&self) -> String {
        let mut out = String::new();
        for (path, text) in &self.files {
            let _ = writeln!(out, "// ==== {path} ====");
            out.push_str(text);
            out.push('\n');
        }
        out
    }

    /// Write the package under `dir`, creating subdirectories as
    /// needed. Returns the written paths in emission order.
    pub fn write_to(&self, dir: &Path) -> Result<Vec<PathBuf>, String> {
        let mut written = Vec::new();
        for (rel, text) in &self.files {
            let path = dir.join(rel);
            if let Some(parent) = path.parent() {
                std::fs::create_dir_all(parent)
                    .map_err(|e| format!("create {}: {e}", parent.display()))?;
            }
            std::fs::write(&path, text)
                .map_err(|e| format!("write {}: {e}", path.display()))?;
            written.push(path);
        }
        Ok(written)
    }
}

/// Emit the complete package for a generated system.
pub fn emit(spec: &SystemSpec, platform: &Platform) -> VitisPackage {
    let mut files = vec![
        (format!("src/{}.cpp", spec.kernel.name), kernel_cpp(spec)),
        ("src/host.cpp".to_string(), host_cpp(spec)),
        ("link.cfg".to_string(), link_cfg(spec, platform)),
        ("Makefile".to_string(), makefile(spec, platform)),
    ];
    let fp = format!("{:016x}", fnv64(files.iter().map(|(p, t)| (p.as_str(), t.as_str()))));
    let manifest = manifest_json(spec, platform, &files, &fp);
    files.push(("package.json".to_string(), format!("{manifest}\n")));
    VitisPackage { files }
}

/// Same constants as `flow::fingerprint`, over (path NUL text NUL).
fn fnv64<'a>(files: impl Iterator<Item = (&'a str, &'a str)>) -> u64 {
    const PRIME: u64 = 0x0000_0100_0000_01b3;
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for (path, text) in files {
        for &b in path
            .as_bytes()
            .iter()
            .chain(std::iter::once(&0u8))
            .chain(text.as_bytes())
            .chain(std::iter::once(&0u8))
        {
            h ^= u64::from(b);
            h = h.wrapping_mul(PRIME);
        }
    }
    h
}

/// Memory tag used in `sp=` lines and host topology comments.
fn memory_tag(kind: MemoryKind) -> &'static str {
    match kind {
        MemoryKind::Hbm => "HBM",
        MemoryKind::Ddr4 => "DDR",
    }
}

/// Host-side element type: fixed-point formats travel as raw integers
/// (paper §3.6.4 — double↔fixed conversion happens in host code).
fn host_type(dtype: DataType) -> &'static str {
    match dtype {
        DataType::F64 => "double",
        DataType::F32 => "float",
        DataType::Fx64 => "uint64_t",
        DataType::Fx32 => "uint32_t",
    }
}

/// `e * frame + off` with the `+ 0` elided.
fn axi_index(frame: usize, off: usize) -> String {
    if off == 0 {
        format!("e * {frame}")
    } else {
        format!("e * {frame} + {off}")
    }
}

/// Array-partition pragma for a kernel buffer, from the memory plan's
/// banking decision (first instance hosting the buffer; instances of
/// one buffer never differ in scheme across groups).
fn partition_pragma(plan: &MemoryPlan, buf: usize, name: &str) -> Option<String> {
    let inst = plan.arrays.iter().find(|a| a.residents.contains(&buf))?;
    match inst.scheme {
        BankingScheme::Complete => Some(format!(
            "#pragma HLS array_partition variable={name} complete dim=1"
        )),
        BankingScheme::Cyclic if inst.factor > 1 => Some(format!(
            "#pragma HLS array_partition variable={name} cyclic factor={} dim=1",
            inst.factor
        )),
        _ => None,
    }
}

/// HLS C++ for one compute unit: the `c_emit` group functions plus an
/// `extern "C"` top level with `m_axi` ports per routed channel.
fn kernel_cpp(spec: &SystemSpec) -> String {
    let k = &spec.kernel;
    let s = &spec.schedule;
    let ty = c_emit::c_type(spec.dtype.name());
    let nread = spec.channels[0].read.len();
    let nwrite = spec.channels[0].write.len();
    let phased = nread > 1 || nwrite > 1;
    let in_frame = k.input_words();
    let out_frame = k.output_words();
    let width = spec.bus_bits;

    let mut out = String::new();
    let _ = writeln!(
        out,
        "// {} — Vitis HLS compute unit (emit-schema v{EMIT_SCHEMA_VERSION})",
        spec.name
    );
    let _ = writeln!(
        out,
        "// generated by hbmflow — regenerate with `hbmflow emit-vitis`, do not edit"
    );
    if phased {
        let _ = writeln!(out, "// Every read port carries a full input frame; the host's `phase`");
        let _ = writeln!(out, "// argument selects the ping/pong buffer pair for this batch.");
    }
    let _ = writeln!(out);
    out.push_str(&c_emit::emit(k, s, spec.dtype.name()));

    let _ = writeln!(out, "static void copy_words(const {ty}* src, {ty}* dst, int n) {{");
    let _ = writeln!(out, "  for (int i = 0; i < n; i += 1) {{");
    let _ = writeln!(out, "#pragma HLS pipeline II=1");
    let _ = writeln!(out, "    dst[i] = src[i];");
    let _ = writeln!(out, "  }}");
    let _ = writeln!(out, "}}");
    let _ = writeln!(out);

    let mut params: Vec<String> = Vec::new();
    for j in 0..nread {
        params.push(format!("const {ty}* {}", config::read_port(j)));
    }
    for j in 0..nwrite {
        params.push(format!("{ty}* {}", config::write_port(j)));
    }
    params.push("int n_elements".to_string());
    if phased {
        params.push("int phase".to_string());
    }
    let _ = writeln!(out, "extern \"C\" void {}({}) {{", k.name, params.join(", "));
    for j in 0..nread {
        let p = config::read_port(j);
        let b = format!("gmem_read{j}");
        let _ = writeln!(
            out,
            "#pragma HLS INTERFACE m_axi port={p} offset=slave bundle={b} max_widen_bitwidth={width}"
        );
    }
    for j in 0..nwrite {
        let p = config::write_port(j);
        let b = format!("gmem_write{j}");
        let _ = writeln!(
            out,
            "#pragma HLS INTERFACE m_axi port={p} offset=slave bundle={b} max_widen_bitwidth={width}"
        );
    }
    let _ = writeln!(out, "#pragma HLS INTERFACE s_axilite port=n_elements bundle=control");
    if phased {
        let _ = writeln!(out, "#pragma HLS INTERFACE s_axilite port=phase bundle=control");
    }
    let _ = writeln!(out, "#pragma HLS INTERFACE s_axilite port=return bundle=control");

    let _ = writeln!(out, "  const {ty}* rd = {};", config::read_port(0));
    let _ = writeln!(out, "  {ty}* wr = {};", config::write_port(0));
    for j in 1..nread {
        let _ = writeln!(out, "  if (phase % {nread} == {j}) {{");
        let _ = writeln!(out, "    rd = {};", config::read_port(j));
        let _ = writeln!(out, "  }}");
    }
    for j in 1..nwrite {
        let _ = writeln!(out, "  if (phase % {nwrite} == {j}) {{");
        let _ = writeln!(out, "    wr = {};", config::write_port(j));
        let _ = writeln!(out, "  }}");
    }
    let _ = writeln!(out, "  for (int e = 0; e < n_elements; e += 1) {{");
    if spec.dataflow {
        let _ = writeln!(out, "#pragma HLS dataflow");
    }
    for (b, buf) in k.buffers.iter().enumerate() {
        let _ = writeln!(out, "    {ty} {}[{}];", buf.name, buf.words());
        if let Some(p) = partition_pragma(&spec.memory, b, &buf.name) {
            let _ = writeln!(out, "{p}");
        }
    }
    let mut off = 0usize;
    for (_, buf) in k.inputs() {
        let idx = axi_index(in_frame, off);
        let _ = writeln!(out, "    copy_words(rd + {idx}, {}, {});", buf.name, buf.words());
        off += buf.words();
    }
    for (gi, g) in s.groups.iter().enumerate() {
        let args: Vec<&str> = c_emit::group_params(k, s, gi)
            .into_iter()
            .map(|(b, _)| k.buffers[b].name.as_str())
            .collect();
        let _ = writeln!(out, "    {}({});", g.name, args.join(", "));
    }
    let mut off = 0usize;
    for (_, buf) in k.outputs() {
        let idx = axi_index(out_frame, off);
        let _ = writeln!(out, "    copy_words({}, wr + {idx}, {});", buf.name, buf.words());
        off += buf.words();
    }
    let _ = writeln!(out, "  }}");
    let _ = writeln!(out, "}}");
    out
}

/// XRT host program: one `cl_mem_ext_ptr_t`-placed buffer per routed
/// CU port, with the topology flag taken from the channel map. Each
/// flag line ends in a structured `// cu.port -> TAG[pc]` comment that
/// [`parse_host_topology`] reads back for the differential tests.
fn host_cpp(spec: &SystemSpec) -> String {
    let k = &spec.kernel.name;
    let hty = host_type(spec.dtype);
    let tag = memory_tag(spec.opts.memory);
    let bytes = spec.dtype.bytes();
    let nread = spec.channels[0].read.len();
    let nwrite = spec.channels[0].write.len();
    let phased = nread > 1 || nwrite > 1;

    let mut out = String::new();
    let _ = writeln!(out, "// {} — XRT host (emit-schema v{EMIT_SCHEMA_VERSION})", spec.name);
    let _ = writeln!(
        out,
        "// generated by hbmflow — regenerate with `hbmflow emit-vitis`, do not edit"
    );
    if spec.dtype.is_fixed() {
        let _ = writeln!(
            out,
            "// {hty} carries raw ap_fixed bits; double<->fixed conversion is host-side"
        );
    }
    let _ = writeln!(out, "#define CL_HPP_TARGET_OPENCL_VERSION 120");
    let _ = writeln!(out, "#define CL_HPP_MINIMUM_OPENCL_VERSION 120");
    let _ = writeln!(out, "#define CL_HPP_CL_1_2_DEFAULT_BUILD");
    let _ = writeln!(out, "#include <CL/cl2.hpp>");
    let _ = writeln!(out, "#include <CL/cl_ext_xilinx.h>");
    let _ = writeln!(out, "#include <cstdint>");
    let _ = writeln!(out, "#include <cstdlib>");
    let _ = writeln!(out, "#include <fstream>");
    let _ = writeln!(out, "#include <iostream>");
    let _ = writeln!(out, "#include <vector>");
    let _ = writeln!(out);
    let _ = writeln!(out, "static const int N_ELEMENTS = {};", spec.batch_elements);
    let _ = writeln!(out, "static const int IN_FRAME_WORDS = {};", spec.kernel.input_words());
    let _ = writeln!(out, "static const int OUT_FRAME_WORDS = {};", spec.kernel.output_words());
    let _ = writeln!(out, "static const long IN_WORDS = (long)N_ELEMENTS * IN_FRAME_WORDS;");
    let _ = writeln!(out, "static const long OUT_WORDS = (long)N_ELEMENTS * OUT_FRAME_WORDS;");
    let _ = writeln!(out, "static const long IN_BYTES = IN_WORDS * {bytes};");
    let _ = writeln!(out, "static const long OUT_BYTES = OUT_WORDS * {bytes};");
    let _ = writeln!(out);
    let _ = writeln!(out, "static std::vector<unsigned char> read_binary(const char* path) {{");
    let _ = writeln!(out, "  std::ifstream f(path, std::ios::binary | std::ios::ate);");
    let _ = writeln!(out, "  if (!f) {{");
    let _ = writeln!(out, "    std::cerr << \"cannot open \" << path << \"\\n\";");
    let _ = writeln!(out, "    std::exit(1);");
    let _ = writeln!(out, "  }}");
    let _ = writeln!(out, "  std::streamsize n = f.tellg();");
    let _ = writeln!(out, "  f.seekg(0);");
    let _ = writeln!(out, "  std::vector<unsigned char> buf(n);");
    let _ = writeln!(out, "  f.read(reinterpret_cast<char*>(buf.data()), n);");
    let _ = writeln!(out, "  return buf;");
    let _ = writeln!(out, "}}");
    let _ = writeln!(out);
    let _ = writeln!(out, "int main(int argc, char** argv) {{");
    let _ = writeln!(out, "  if (argc != 2) {{");
    let _ = writeln!(out, "    std::cerr << \"usage: \" << argv[0] << \" <xclbin>\\n\";");
    let _ = writeln!(out, "    return 1;");
    let _ = writeln!(out, "  }}");
    let _ = writeln!(out, "  cl_int err = CL_SUCCESS;");
    let _ = writeln!(out, "  std::vector<cl::Platform> platforms;");
    let _ = writeln!(out, "  cl::Platform::get(&platforms);");
    let _ = writeln!(out, "  cl::Platform xil;");
    let _ = writeln!(out, "  for (size_t i = 0; i < platforms.size(); i += 1) {{");
    let _ = writeln!(out, "    std::string name = platforms[i].getInfo<CL_PLATFORM_NAME>();");
    let _ = writeln!(out, "    if (name.find(\"Xilinx\") != std::string::npos) {{");
    let _ = writeln!(out, "      xil = platforms[i];");
    let _ = writeln!(out, "    }}");
    let _ = writeln!(out, "  }}");
    let _ = writeln!(out, "  std::vector<cl::Device> devices;");
    let _ = writeln!(out, "  xil.getDevices(CL_DEVICE_TYPE_ACCELERATOR, &devices);");
    let _ = writeln!(out, "  cl::Device device = devices.at(0);");
    let _ = writeln!(out, "  cl::Context context(device, nullptr, nullptr, nullptr, &err);");
    let _ = writeln!(
        out,
        "  cl::CommandQueue queue(context, device, CL_QUEUE_OUT_OF_ORDER_EXEC_MODE_ENABLE, &err);"
    );
    let _ = writeln!(out, "  std::vector<unsigned char> bin = read_binary(argv[1]);");
    let _ = writeln!(out, "  cl::Program::Binaries bins{{{{bin.data(), bin.size()}}}};");
    let _ = writeln!(out, "  cl::Program program(context, {{device}}, bins, nullptr, &err);");

    // one placed buffer per routed port of every CU
    for (i, ch) in spec.channels.iter().enumerate() {
        let inst = config::cu_instance(k, i);
        let _ = writeln!(out);
        let _ = writeln!(out, "  // ---- {inst} ----");
        let _ = writeln!(out, "  cl::Kernel k_{inst}(program, \"{k}:{{{inst}}}\", &err);");
        for (j, pc) in ch.read.iter().enumerate() {
            let port = config::read_port(j);
            let var = format!("{inst}_read{j}");
            let _ = writeln!(out, "  std::vector<{hty}> host_{var}(IN_WORDS);");
            let _ = writeln!(out, "  cl_mem_ext_ptr_t ext_{var};");
            let _ = writeln!(out, "  ext_{var}.obj = host_{var}.data();");
            let _ = writeln!(out, "  ext_{var}.param = nullptr;");
            let _ = writeln!(
                out,
                "  ext_{var}.flags = {pc} | XCL_MEM_TOPOLOGY; // {inst}.{port} -> {tag}[{pc}]"
            );
            let _ = writeln!(out, "  cl::Buffer buf_{var}(");
            let _ = writeln!(
                out,
                "      context, CL_MEM_USE_HOST_PTR | CL_MEM_READ_ONLY | CL_MEM_EXT_PTR_XILINX,"
            );
            let _ = writeln!(out, "      IN_BYTES, &ext_{var}, &err);");
        }
        for (j, pc) in ch.write.iter().enumerate() {
            let port = config::write_port(j);
            let var = format!("{inst}_write{j}");
            let _ = writeln!(out, "  std::vector<{hty}> host_{var}(OUT_WORDS);");
            let _ = writeln!(out, "  cl_mem_ext_ptr_t ext_{var};");
            let _ = writeln!(out, "  ext_{var}.obj = host_{var}.data();");
            let _ = writeln!(out, "  ext_{var}.param = nullptr;");
            let _ = writeln!(
                out,
                "  ext_{var}.flags = {pc} | XCL_MEM_TOPOLOGY; // {inst}.{port} -> {tag}[{pc}]"
            );
            let _ = writeln!(out, "  cl::Buffer buf_{var}(");
            let _ = writeln!(
                out,
                "      context, CL_MEM_USE_HOST_PTR | CL_MEM_WRITE_ONLY | CL_MEM_EXT_PTR_XILINX,"
            );
            let _ = writeln!(out, "      OUT_BYTES, &ext_{var}, &err);");
        }
    }

    // launch: set args in port order, migrate in, run, migrate out
    for (i, ch) in spec.channels.iter().enumerate() {
        let inst = config::cu_instance(k, i);
        let _ = writeln!(out);
        let _ = writeln!(out, "  int arg_{inst} = 0;");
        for j in 0..ch.read.len() {
            let _ = writeln!(out, "  k_{inst}.setArg(arg_{inst}++, buf_{inst}_read{j});");
        }
        for j in 0..ch.write.len() {
            let _ = writeln!(out, "  k_{inst}.setArg(arg_{inst}++, buf_{inst}_write{j});");
        }
        let _ = writeln!(out, "  k_{inst}.setArg(arg_{inst}++, (int)N_ELEMENTS);");
        if phased {
            let _ = writeln!(out, "  k_{inst}.setArg(arg_{inst}++, (int)0); // phase");
        }
        let reads: Vec<String> = (0..ch.read.len())
            .map(|j| format!("buf_{inst}_read{j}"))
            .collect();
        let writes: Vec<String> = (0..ch.write.len())
            .map(|j| format!("buf_{inst}_write{j}"))
            .collect();
        let _ = writeln!(out, "  queue.enqueueMigrateMemObjects({{{}}}, 0);", reads.join(", "));
        let _ = writeln!(out, "  queue.enqueueTask(k_{inst});");
        let _ = writeln!(
            out,
            "  queue.enqueueMigrateMemObjects({{{}}}, CL_MIGRATE_MEM_OBJECT_HOST);",
            writes.join(", ")
        );
    }
    let _ = writeln!(out);
    let _ = writeln!(out, "  queue.finish();");
    let _ = writeln!(
        out,
        "  std::cout << \"{}: \" << N_ELEMENTS << \" elements per CU done\\n\";",
        spec.name
    );
    let _ = writeln!(out, "  return 0;");
    let _ = writeln!(out, "}}");
    out
}

/// `v++ --config` link file: CU replication, port→channel bindings,
/// and SLR pinning, all derived from the same spec fields the host and
/// kernel emitters use.
fn link_cfg(spec: &SystemSpec, platform: &Platform) -> String {
    let tag = memory_tag(spec.opts.memory);
    let insts: Vec<String> = (0..spec.num_cus)
        .map(|i| config::cu_instance(&spec.kernel.name, i))
        .collect();
    let mut out = String::new();
    let _ = writeln!(out, "# hbmflow Vitis link configuration — {} (do not edit)", spec.name);
    let _ = writeln!(
        out,
        "# emit-schema: v{EMIT_SCHEMA_VERSION} — regenerate with `hbmflow emit-vitis`"
    );
    let _ = writeln!(out, "platform={}", platform.name);
    let _ = writeln!(out, "kernel_frequency={}", spec.opts.target_freq_mhz as u64);
    let _ = writeln!(out);
    let _ = writeln!(out, "[connectivity]");
    let _ = writeln!(out, "nk={}:{}:{}", spec.kernel.name, spec.num_cus, insts.join("."));
    for (i, ch) in spec.channels.iter().enumerate() {
        for (j, pc) in ch.read.iter().enumerate() {
            let _ = writeln!(out, "sp={}.{}:{tag}[{pc}]", insts[i], config::read_port(j));
        }
        for (j, pc) in ch.write.iter().enumerate() {
            let _ = writeln!(out, "sp={}.{}:{tag}[{pc}]", insts[i], config::write_port(j));
        }
    }
    // HBM-attached CUs belong in SLR0 (paper Challenge 5)
    for inst in &insts {
        let _ = writeln!(out, "slr={inst}:SLR0");
    }
    out
}

/// Build recipe: `v++ -c` per kernel, `v++ -l` against `link.cfg`, and
/// the host link line. CI checks the text, not the build — running it
/// needs a Vitis installation.
fn makefile(spec: &SystemSpec, platform: &Platform) -> String {
    let mut out = String::new();
    let _ = writeln!(
        out,
        "# Makefile for {} — generated by hbmflow (emit-schema v{EMIT_SCHEMA_VERSION})",
        spec.name
    );
    let _ = writeln!(out, "# Requires a Vitis installation and a platform .xpfm.");
    let _ = writeln!(out);
    let _ = writeln!(out, "PLATFORM ?= {}", platform.name);
    let _ = writeln!(out, "TARGET ?= hw");
    let _ = writeln!(out, "FREQ_MHZ ?= {}", spec.opts.target_freq_mhz as u64);
    let _ = writeln!(out, "KERNEL := {}", spec.kernel.name);
    let _ = writeln!(out);
    let _ = writeln!(out, "XO := xclbin/$(KERNEL).$(TARGET).xo");
    let _ = writeln!(out, "XCLBIN := xclbin/$(KERNEL).$(TARGET).xclbin");
    let _ = writeln!(out);
    let _ = writeln!(out, ".PHONY: all host clean");
    let _ = writeln!(out);
    let _ = writeln!(out, "all: $(XCLBIN) host");
    let _ = writeln!(out);
    let _ = writeln!(out, "$(XO): src/$(KERNEL).cpp");
    let _ = writeln!(out, "\tmkdir -p xclbin");
    let _ = writeln!(
        out,
        "\tv++ -c -t $(TARGET) --platform $(PLATFORM) --kernel_frequency $(FREQ_MHZ) \\"
    );
    let _ = writeln!(out, "\t\t-k $(KERNEL) -o $@ $<");
    let _ = writeln!(out);
    let _ = writeln!(out, "$(XCLBIN): $(XO) link.cfg");
    let _ = writeln!(
        out,
        "\tv++ -l -t $(TARGET) --platform $(PLATFORM) --config link.cfg -o $@ $<"
    );
    let _ = writeln!(out);
    let _ = writeln!(out, "host: src/host.cpp");
    let _ = writeln!(out, "\t$(CXX) -std=c++14 -O2 -o $@ $< -lOpenCL -pthread");
    let _ = writeln!(out);
    let _ = writeln!(out, "clean:");
    let _ = writeln!(out, "\trm -rf xclbin host _x *.log");
    out
}

/// The `package.json` manifest document (sorted keys via `Json::Obj`).
fn manifest_json(
    spec: &SystemSpec,
    platform: &Platform,
    payload: &[(String, String)],
    fingerprint: &str,
) -> Json {
    let connectivity: Vec<Json> = spec
        .channels
        .iter()
        .enumerate()
        .map(|(i, ch)| {
            let pcs = |v: &[u32]| Json::Arr(v.iter().map(|&pc| Json::Num(pc as f64)).collect());
            Json::obj(vec![
                ("cu", Json::str(config::cu_instance(&spec.kernel.name, i))),
                ("read", pcs(&ch.read)),
                ("write", pcs(&ch.write)),
            ])
        })
        .collect();
    Json::obj(vec![
        ("batch_elements", Json::Num(spec.batch_elements as f64)),
        ("bus_bits", Json::Num(spec.bus_bits as f64)),
        ("channel_policy", Json::str(spec.opts.channel_policy.name())),
        ("connectivity", Json::Arr(connectivity)),
        ("dataflow_groups", Json::Num(spec.schedule.num_groups() as f64)),
        ("double_buffering", Json::Bool(spec.double_buffering)),
        ("dtype", Json::str(spec.dtype.name())),
        ("emit_schema", Json::Num(EMIT_SCHEMA_VERSION as f64)),
        ("files", Json::Arr(payload.iter().map(|(p, _)| Json::str(p.as_str())).collect())),
        ("fingerprint", Json::str(fingerprint)),
        ("frequency_mhz", Json::Num(spec.opts.target_freq_mhz)),
        ("generator", Json::str("hbmflow")),
        ("kernel", Json::str(spec.kernel.name.as_str())),
        ("lanes", Json::Num(spec.lanes as f64)),
        ("memory", Json::str(spec.opts.memory.name())),
        ("num_cus", Json::Num(spec.num_cus as f64)),
        ("platform", Json::str(platform.name.as_str())),
        ("system", Json::str(spec.name.as_str())),
    ])
}

/// One `sp=` binding (or one host topology flag): a CU instance port
/// bound to a memory channel.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SpBinding {
    pub cu: String,
    pub port: String,
    /// Memory tag from the cfg (`HBM` / `DDR`).
    pub memory: String,
    pub channel: u32,
}

/// Parsed `[connectivity]` facts of a link cfg.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ConnectivityCfg {
    pub kernel: String,
    /// CU instance names from the `nk=` line, in declaration order.
    pub instances: Vec<String>,
    /// `sp=` bindings in file order.
    pub sp: Vec<SpBinding>,
}

/// Parse the `nk=` / `sp=` lines of an emitted `link.cfg` back into
/// structured form — the inverse the differential tests diff against
/// the `hbm::ChannelMap`.
pub fn parse_connectivity(cfg: &str) -> Result<ConnectivityCfg, String> {
    let mut kernel: Option<String> = None;
    let mut instances: Vec<String> = Vec::new();
    let mut sp: Vec<SpBinding> = Vec::new();
    for raw in cfg.lines() {
        let line = raw.trim();
        if let Some(rest) = line.strip_prefix("nk=") {
            let mut it = rest.split(':');
            let name = match it.next() {
                Some(n) if !n.is_empty() => n,
                _ => return Err(format!("nk= missing kernel name: {line}")),
            };
            let count: usize = it
                .next()
                .ok_or_else(|| format!("nk= missing CU count: {line}"))?
                .parse()
                .map_err(|_| format!("nk= count is not a number: {line}"))?;
            let insts: Vec<String> = match it.next() {
                Some(list) => list.split('.').map(str::to_string).collect(),
                None => (0..count).map(|i| config::cu_instance(name, i)).collect(),
            };
            if insts.len() != count {
                return Err(format!(
                    "nk= declares {count} CUs but names {}: {line}",
                    insts.len()
                ));
            }
            kernel = Some(name.to_string());
            instances = insts;
        } else if let Some(rest) = line.strip_prefix("sp=") {
            let (lhs, rhs) = rest
                .split_once(':')
                .ok_or_else(|| format!("sp= missing ':': {line}"))?;
            let (cu, port) = lhs
                .rsplit_once('.')
                .ok_or_else(|| format!("sp= missing port: {line}"))?;
            let (mem, chan) = rhs
                .split_once('[')
                .ok_or_else(|| format!("sp= missing channel: {line}"))?;
            let chan = chan.strip_suffix(']').ok_or_else(|| format!("sp= missing ']': {line}"))?;
            let channel: u32 = chan
                .parse()
                .map_err(|_| format!("sp= channel is not a number: {line}"))?;
            sp.push(SpBinding {
                cu: cu.to_string(),
                port: port.to_string(),
                memory: mem.to_string(),
                channel,
            });
        }
    }
    let kernel = kernel.ok_or_else(|| "no nk= line in cfg".to_string())?;
    Ok(ConnectivityCfg { kernel, instances, sp })
}

/// Recover the per-CU channel assignment from a parsed cfg: the exact
/// structure `SystemSpec::channels` holds, so a differential test can
/// assert the emitted package and the simulated model agree.
pub fn cfg_channel_assignment(cfg: &ConnectivityCfg) -> Result<Vec<CuChannels>, String> {
    let mut out = Vec::new();
    for inst in &cfg.instances {
        let mut read: Vec<(usize, u32)> = Vec::new();
        let mut write: Vec<(usize, u32)> = Vec::new();
        for b in cfg.sp.iter().filter(|b| &b.cu == inst) {
            if let Some(j) = b.port.strip_prefix("m_axi_read") {
                let j: usize = j.parse().map_err(|_| format!("bad read port index: {}", b.port))?;
                read.push((j, b.channel));
            } else if let Some(j) = b.port.strip_prefix("m_axi_write") {
                let j: usize = j.parse().map_err(|_| format!("bad write port index: {}", b.port))?;
                write.push((j, b.channel));
            } else {
                return Err(format!("unknown port name {} on {inst}", b.port));
            }
        }
        if read.is_empty() || write.is_empty() {
            return Err(format!("CU {inst} lacks sp= bindings"));
        }
        read.sort_unstable();
        write.sort_unstable();
        out.push(CuChannels {
            read: read.into_iter().map(|(_, pc)| pc).collect(),
            write: write.into_iter().map(|(_, pc)| pc).collect(),
        });
    }
    Ok(out)
}

/// Extract the buffer placements from an emitted `host.cpp` via the
/// structured `// cu.port -> TAG[pc]` flag comments, cross-checking the
/// numeric flag against the comment. Returns bindings in emission order
/// (per CU: reads, then writes) — the same order `link.cfg` uses, so
/// one-to-one agreement is a plain equality.
pub fn parse_host_topology(host: &str) -> Result<Vec<SpBinding>, String> {
    let mut out = Vec::new();
    for line in host.lines() {
        let Some((head, tail)) = line.split_once("| XCL_MEM_TOPOLOGY; // ") else {
            continue;
        };
        let (cu_port, mem_chan) = tail
            .split_once(" -> ")
            .ok_or_else(|| format!("bad topology comment: {line}"))?;
        let (cu, port) = cu_port
            .rsplit_once('.')
            .ok_or_else(|| format!("bad topology comment: {line}"))?;
        let (mem, chan) = mem_chan
            .split_once('[')
            .ok_or_else(|| format!("bad topology comment: {line}"))?;
        let chan = chan.strip_suffix(']').ok_or_else(|| format!("bad topology comment: {line}"))?;
        let channel: u32 = chan.parse().map_err(|_| format!("bad topology channel: {line}"))?;
        let flag: u32 = head
            .rsplit_once('=')
            .map(|(_, v)| v.trim())
            .ok_or_else(|| format!("bad topology flags: {line}"))?
            .parse()
            .map_err(|_| format!("bad topology flags: {line}"))?;
        if flag != channel {
            return Err(format!(
                "flag {flag} disagrees with comment channel {channel}: {line}"
            ));
        }
        out.push(SpBinding {
            cu: cu.to_string(),
            port: port.to_string(),
            memory: mem.to_string(),
            channel,
        });
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dsl;
    use crate::ir::{lower, rewrite, teil};
    use crate::olympus::{generate, OlympusOpts};
    use crate::util::json;

    fn spec(opts: OlympusOpts) -> SystemSpec {
        let prog = dsl::parse(&dsl::inverse_helmholtz_source(7)).unwrap();
        let m = rewrite::optimize(teil::from_ast(&prog).unwrap());
        let k = lower::lower_kernel(&m, "helmholtz").unwrap();
        generate(&k, &opts, &Platform::alveo_u280()).unwrap()
    }

    fn pkg(opts: OlympusOpts) -> (SystemSpec, VitisPackage) {
        let s = spec(opts);
        let p = emit(&s, &Platform::alveo_u280());
        (s, p)
    }

    #[test]
    fn package_has_five_files_in_fixed_order() {
        let (_, p) = pkg(OlympusOpts::dataflow(7));
        let paths: Vec<&str> = p.files().iter().map(|(p, _)| p.as_str()).collect();
        assert_eq!(
            paths,
            ["src/helmholtz.cpp", "src/host.cpp", "link.cfg", "Makefile", "package.json"]
        );
    }

    #[test]
    fn emission_is_deterministic() {
        let (s, p1) = pkg(OlympusOpts::dataflow(7).with_cus(2));
        let p2 = emit(&s, &Platform::alveo_u280());
        assert_eq!(p1, p2);
        assert_eq!(p1.fingerprint(), p2.fingerprint());
    }

    #[test]
    fn cfg_round_trips_to_the_channel_map() {
        for opts in [
            OlympusOpts::baseline(),
            OlympusOpts::dataflow(7),
            OlympusOpts::dataflow(7).with_cus(2),
            OlympusOpts::double_buffering().with_cus(8),
        ] {
            let (s, p) = pkg(opts);
            let cfg = parse_connectivity(p.file("link.cfg").unwrap()).unwrap();
            assert_eq!(cfg.kernel, "helmholtz");
            assert_eq!(cfg_channel_assignment(&cfg).unwrap(), s.channels);
        }
    }

    #[test]
    fn host_topology_matches_cfg_one_to_one() {
        let (_, p) = pkg(OlympusOpts::dataflow(7).with_cus(2));
        let cfg = parse_connectivity(p.file("link.cfg").unwrap()).unwrap();
        let host = parse_host_topology(p.file("src/host.cpp").unwrap()).unwrap();
        assert_eq!(host, cfg.sp);
    }

    #[test]
    fn sp_ports_exist_in_the_kernel_cpp() {
        let (_, p) = pkg(OlympusOpts::dataflow(7));
        let cpp = p.file("src/helmholtz.cpp").unwrap();
        let cfg = parse_connectivity(p.file("link.cfg").unwrap()).unwrap();
        for b in &cfg.sp {
            assert!(cpp.contains(&b.port), "port {} missing from C++", b.port);
        }
        assert_eq!(cfg.instances, ["helmholtz_1"]);
    }

    #[test]
    fn partition_pragmas_follow_the_memory_plan() {
        let (s, p) = pkg(OlympusOpts::dataflow(7));
        let cpp = p.file("src/helmholtz.cpp").unwrap();
        assert!(cpp.contains("#pragma HLS array_partition"));
        let banked = s
            .memory
            .arrays
            .iter()
            .any(|a| a.factor > 1 || a.scheme == BankingScheme::Complete);
        assert!(banked, "dataflow plan should bank at least one array");
    }

    #[test]
    fn phase_argument_appears_only_with_pingpong_channels() {
        let (_, flat) = pkg(OlympusOpts::baseline());
        assert!(!flat.file("src/helmholtz.cpp").unwrap().contains("int phase"));
        let (_, db) = pkg(OlympusOpts::dataflow(7));
        assert!(db.file("src/helmholtz.cpp").unwrap().contains("int phase"));
        assert!(db.file("src/host.cpp").unwrap().contains("// phase"));
    }

    #[test]
    fn manifest_records_fingerprint_and_schema() {
        let (s, p) = pkg(OlympusOpts::fixed_point(crate::datatype::DataType::Fx32));
        let doc = json::parse(p.file("package.json").unwrap()).unwrap();
        assert_eq!(doc.get("fingerprint").unwrap().as_str(), Some(p.fingerprint().as_str()));
        assert_eq!(doc.get("emit_schema").unwrap().as_u64(), Some(EMIT_SCHEMA_VERSION));
        assert_eq!(doc.get("dtype").unwrap().as_str(), Some("fx32"));
        assert_eq!(doc.get("num_cus").unwrap().as_u64(), Some(s.num_cus as u64));
        assert_eq!(doc.get("platform").unwrap().as_str(), Some("xilinx_u280"));
    }

    #[test]
    fn fixed_point_host_buffers_carry_raw_bits() {
        let (_, p) = pkg(OlympusOpts::fixed_point(crate::datatype::DataType::Fx32));
        let host = p.file("src/host.cpp").unwrap();
        assert!(host.contains("std::vector<uint32_t>"));
        let cpp = p.file("src/helmholtz.cpp").unwrap();
        assert!(cpp.contains("ap_fixed<32, 8>"));
    }

    #[test]
    fn ddr4_systems_use_the_ddr_tag() {
        let (_, p) = pkg(OlympusOpts::baseline().on_ddr4());
        assert!(p.file("link.cfg").unwrap().contains(":DDR["));
        assert!(p.file("src/host.cpp").unwrap().contains("-> DDR["));
    }

    #[test]
    fn malformed_cfgs_are_rejected() {
        assert!(parse_connectivity("sp=only.port:HBM[0]").is_err());
        assert!(parse_connectivity("nk=k:two").is_err());
        assert!(parse_connectivity("nk=k:2:a").is_err());
        let cfg = parse_connectivity("nk=k:1\nsp=k_1.weird:HBM[0]").unwrap();
        assert!(cfg_channel_assignment(&cfg).is_err());
        let bad = "x.flags = 3 | XCL_MEM_TOPOLOGY; // k_1.m_axi_read0 -> HBM[4]";
        assert!(parse_host_topology(bad).is_err());
    }

    #[test]
    fn write_to_materializes_the_tree() {
        let (_, p) = pkg(OlympusOpts::baseline());
        let dir = std::env::temp_dir().join("hbmflow_vitis_unit");
        let _ = std::fs::remove_dir_all(&dir);
        let written = p.write_to(&dir).unwrap();
        assert_eq!(written.len(), 5);
        for path in &written {
            assert!(path.exists(), "{} missing", path.display());
        }
        let _ = std::fs::remove_dir_all(&dir);
    }
}
