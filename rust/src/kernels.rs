//! Kernel registry: where programs enter the flow.
//!
//! The paper's premise (§2.1, §3) is that a *domain expert* writes a
//! CFDlang tensor program and the toolchain produces the HBM
//! architecture automatically. [`KernelSource`] is that front door: a
//! program can come from the builtin generators reproducing the
//! published trio (Inverse Helmholtz, Interpolation, Gradient), from a
//! `.cfd` file on disk (`hbmflow compile --file my.cfd`), or from an
//! inline string (tests, embedding). Every consumer — the CLI, the dse
//! search space, the generic oracle — resolves programs through this one
//! type, so a user kernel flows through exactly the same
//! parse → rewrite → lower pipeline as the paper's figures.
//!
//! See docs/CFDLANG.md for the language reference and the shipped
//! kernel library under `examples/kernels/*.cfd`.

use std::path::PathBuf;

use crate::dsl::{self, Program};
use crate::ir::affine::Kernel;
use crate::ir::{lower, rewrite, teil};

/// Names accepted by [`KernelSource::Builtin`]: the published trio plus
/// the unstructured-mesh pair (gather interpolation and scatter-add
/// assembly, Karp et al. arXiv 2108.12188).
pub const BUILTIN_NAMES: &[&str] = &[
    "helmholtz",
    "interpolation",
    "gradient",
    "mesh_gather",
    "scatter_assembly",
];

/// Fixed mesh extents for the unstructured builtins: `m` nodal rows
/// gathered `n` times (reuse degree n/m = 4) with `k` values per node.
pub const MESH_NODES: usize = 256;
pub const MESH_GATHERS: usize = 1024;
pub const MESH_VALUES: usize = 8;

/// Where a kernel's CFDlang source comes from.
///
/// `Builtin` resolves lazily: an unknown name is an error at
/// [`KernelSource::source`] time, not at construction, so callers like
/// the dse space can be built first and report the failure per point.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum KernelSource {
    /// A named builtin generator (`helmholtz`, `interpolation`,
    /// `gradient`), parameterized by polynomial degree `p`.
    Builtin(String),
    /// A `.cfd` program on disk; extents are fixed by the file.
    File(PathBuf),
    /// An inline program string under a chosen display name.
    Inline { name: String, source: String },
}

impl KernelSource {
    pub fn builtin(name: &str) -> KernelSource {
        KernelSource::Builtin(name.to_string())
    }

    pub fn file(path: impl Into<PathBuf>) -> KernelSource {
        KernelSource::File(path.into())
    }

    pub fn inline(name: &str, source: &str) -> KernelSource {
        KernelSource::Inline {
            name: name.to_string(),
            source: source.to_string(),
        }
    }

    /// Resolve the CLI's `--kernel` / `--file` flag pair.
    pub fn from_flags(kernel: Option<&str>, file: Option<&str>) -> Result<KernelSource, String> {
        match (kernel, file) {
            (Some(_), Some(_)) => Err("--kernel and --file are mutually exclusive".into()),
            (_, Some(f)) => Ok(KernelSource::file(f)),
            (k, None) => Ok(KernelSource::builtin(k.unwrap_or("helmholtz"))),
        }
    }

    /// Display name: the builtin name, the file stem, or the inline name.
    pub fn name(&self) -> String {
        match self {
            KernelSource::Builtin(n) => n.clone(),
            KernelSource::File(p) => p
                .file_stem()
                .map(|s| s.to_string_lossy().into_owned())
                .unwrap_or_else(|| "kernel".into()),
            KernelSource::Inline { name, .. } => name.clone(),
        }
    }

    /// Where the program text lives, for error prefixes.
    pub fn origin(&self) -> String {
        match self {
            KernelSource::Builtin(n) => format!("builtin {n}"),
            KernelSource::File(p) => p.display().to_string(),
            KernelSource::Inline { name, .. } => format!("inline {name}"),
        }
    }

    /// True when the degree argument `p` changes the generated program.
    /// File and inline programs carry fixed extents; the gradient builtin
    /// uses the paper's fixed (8, 7, 6) operator.
    pub fn parameterized(&self) -> bool {
        matches!(self, KernelSource::Builtin(n)
            if n == "helmholtz" || n == "interpolation")
    }

    /// The CFDlang source text. `p` parameterizes builtin generators and
    /// is ignored by file / inline sources.
    pub fn source(&self, p: usize) -> Result<String, String> {
        match self {
            KernelSource::Builtin(n) => match n.as_str() {
                "helmholtz" => Ok(dsl::inverse_helmholtz_source(p)),
                "interpolation" => Ok(dsl::interpolation_source(p, p)),
                "gradient" => Ok(dsl::gradient_source(8, 7, 6)),
                "mesh_gather" => {
                    Ok(dsl::mesh_gather_source(MESH_NODES, MESH_GATHERS, MESH_VALUES))
                }
                "scatter_assembly" => Ok(dsl::scatter_assembly_source(
                    MESH_NODES,
                    MESH_GATHERS,
                    MESH_VALUES,
                )),
                other => Err(format!(
                    "unknown kernel {other} (builtins: {}; use --file for a \
                     .cfd program)",
                    BUILTIN_NAMES.join("|"),
                )),
            },
            KernelSource::File(path) => std::fs::read_to_string(path)
                .map_err(|e| format!("cannot read {}: {e}", path.display())),
            KernelSource::Inline { source, .. } => Ok(source.clone()),
        }
    }

    /// Parse and semantically validate the program.
    pub fn program(&self, p: usize) -> Result<Program, String> {
        dsl::parse(&self.source(p)?).map_err(|e| format!("{}: {e}", self.origin()))
    }

    /// The unrewritten teil module — reference semantics straight from
    /// the AST (naive `prod`/`diag`/`red` contractions).
    pub fn module_naive(&self, p: usize) -> Result<teil::Module, String> {
        teil::from_ast(&self.program(p)?)
            .map_err(|e| format!("{}: {e}", self.origin()))
    }

    /// The rewritten (factorized, GEMM-shaped) teil module the hardware
    /// flow implements.
    pub fn module(&self, p: usize) -> Result<teil::Module, String> {
        Ok(rewrite::optimize(self.module_naive(p)?))
    }

    /// Full front-end in one pass: parse → rewrite once, then lower
    /// from that same module. Callers needing both IR forms (e.g. the
    /// generic oracle cross-checking the lowered kernel against
    /// `teil::eval`) must use this rather than separate `module` /
    /// `build` calls — a file source could change between reads.
    pub fn compile(&self, p: usize) -> Result<(teil::Module, Kernel), String> {
        let m = self.module(p)?;
        let k = lower::lower_kernel(&m, &self.name())
            .map_err(|e| format!("{}: {e}", self.origin()))?;
        Ok((m, k))
    }

    /// Full front-end: parse → rewrite → lower to an affine kernel.
    pub fn build(&self, p: usize) -> Result<Kernel, String> {
        Ok(self.compile(p)?.1)
    }

    /// Pin a file source to its current on-disk text (an `Inline`
    /// source under the same display name — and, because the flow
    /// fingerprint hashes (name, text), the same cache identity).
    /// Long-running consumers like a dse sweep snapshot up front so a
    /// mid-run edit to the `.cfd` file cannot mix two different
    /// programs in one result set. Builtin and inline sources are
    /// already immutable and clone through.
    pub fn snapshot(&self) -> Result<KernelSource, String> {
        match self {
            KernelSource::File(_) => Ok(KernelSource::Inline {
                name: self.name(),
                // file extents are fixed; the degree argument is unused
                source: self.source(0)?,
            }),
            other => Ok(other.clone()),
        }
    }

    /// Degrees the dse explores by default: the paper's p ∈ {7, 11} for
    /// parameterized builtins, a single nominal degree otherwise (the
    /// program is fixed, so more degrees would enumerate duplicates).
    pub fn default_degrees(&self) -> Vec<usize> {
        if self.parameterized() {
            vec![7, 11]
        } else {
            vec![self.nominal_degree()]
        }
    }

    /// Display degree for fixed-extent sources: the largest declared
    /// extent (a readable stand-in for `p` in reports). Falls back to 7
    /// for unknown builtin names so the space still enumerates and the
    /// build step reports the real error.
    pub fn nominal_degree(&self) -> usize {
        self.program(7)
            .ok()
            .and_then(|prog| {
                prog.decls
                    .iter()
                    .flat_map(|d| d.shape.iter().copied())
                    .max()
            })
            .unwrap_or(7)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builtin_sources_build() {
        for name in BUILTIN_NAMES {
            let k = KernelSource::builtin(name).build(7).unwrap();
            assert!(!k.nests.is_empty(), "{name}");
            assert_eq!(k.name, *name);
        }
    }

    #[test]
    fn mesh_builtins_lower_to_indexed_nests() {
        use crate::ir::affine::NestKind;
        let g = KernelSource::builtin("mesh_gather").build(0).unwrap();
        assert!(g
            .nests
            .iter()
            .any(|n| matches!(n.kind, NestKind::Gather { .. })));
        assert!(crate::ir::access::has_indexed(&g));
        let s = KernelSource::builtin("scatter_assembly").build(0).unwrap();
        assert!(s
            .nests
            .iter()
            .any(|n| matches!(n.kind, NestKind::Scatter { add: true, .. })));
        // both are fixed-extent: the degree argument is ignored
        assert!(!KernelSource::builtin("mesh_gather").parameterized());
    }

    #[test]
    fn unknown_builtin_is_an_error_with_suggestions() {
        let err = KernelSource::builtin("warp-drive").build(7).unwrap_err();
        assert!(err.contains("unknown kernel"), "{err}");
        assert!(err.contains("helmholtz"), "{err}");
    }

    #[test]
    fn from_flags_resolves_precedence() {
        assert_eq!(
            KernelSource::from_flags(None, None).unwrap(),
            KernelSource::builtin("helmholtz")
        );
        assert_eq!(
            KernelSource::from_flags(Some("gradient"), None).unwrap(),
            KernelSource::builtin("gradient")
        );
        assert!(matches!(
            KernelSource::from_flags(None, Some("a.cfd")).unwrap(),
            KernelSource::File(_)
        ));
        assert!(KernelSource::from_flags(Some("x"), Some("a.cfd")).is_err());
    }

    #[test]
    fn inline_source_builds_end_to_end() {
        let src = "var input A : [4 4]\n\
                   var input u : [4 4 4]\n\
                   var output w : [4 4 4]\n\
                   w = A # u . [[1 2]]\n";
        let s = KernelSource::inline("mode0", src);
        assert_eq!(s.name(), "mode0");
        assert!(!s.parameterized());
        assert_eq!(s.nominal_degree(), 4);
        assert_eq!(s.default_degrees(), vec![4]);
        let k = s.build(0).unwrap();
        assert_eq!(k.nests.len(), 1);
        assert_eq!(k.name, "mode0");
    }

    #[test]
    fn file_source_reads_and_names_from_stem() {
        let dir = std::env::temp_dir();
        let path = dir.join("hbmflow_kernels_test.cfd");
        std::fs::write(
            &path,
            "var input a : [3]\nvar input b : [3]\nvar output c : [3]\nc = a + b\n",
        )
        .unwrap();
        let s = KernelSource::file(&path);
        assert_eq!(s.name(), "hbmflow_kernels_test");
        let k = s.build(0).unwrap();
        assert_eq!(k.nests.len(), 1);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn missing_file_reports_the_path() {
        let err = KernelSource::file("/no/such/dir/x.cfd").build(0).unwrap_err();
        assert!(err.contains("/no/such/dir/x.cfd"), "{err}");
    }

    #[test]
    fn snapshot_pins_file_sources_to_their_text() {
        let path = std::env::temp_dir().join("hbmflow_snapshot_test.cfd");
        std::fs::write(
            &path,
            "var input a : [3]\nvar input b : [3]\nvar output c : [3]\nc = a + b\n",
        )
        .unwrap();
        let file = KernelSource::file(&path);
        let snap = file.snapshot().unwrap();
        assert_eq!(snap.name(), file.name());
        // an on-disk edit after the snapshot does not reach it
        std::fs::write(&path, "var input a : [3]\nvar output c : [3]\nc = a\n").unwrap();
        assert!(snap.source(0).unwrap().contains("a + b"));
        // immutable sources clone through
        assert_eq!(
            KernelSource::builtin("gradient").snapshot().unwrap(),
            KernelSource::builtin("gradient")
        );
        assert!(KernelSource::file("/no/such.cfd").snapshot().is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn parse_errors_carry_the_origin() {
        let s = KernelSource::inline("bad", "var input a : [2]\na = = a\n");
        let err = s.program(0).unwrap_err();
        assert!(err.contains("inline bad"), "{err}");
        assert!(err.contains("line 2"), "{err}");
    }

    #[test]
    fn builtin_degrees_match_the_paper() {
        assert_eq!(
            KernelSource::builtin("helmholtz").default_degrees(),
            vec![7, 11]
        );
        // the gradient generator ignores p (fixed 8x7x6 operator)
        let g = KernelSource::builtin("gradient");
        assert!(!g.parameterized());
        assert_eq!(g.default_degrees(), vec![8]);
    }
}
