//! Operator scheduling: partitioning nests into dataflow groups
//! (paper §3.4.3, Fig. 11).
//!
//! Groups become dataflow stages connected by streams. The group with the
//! longest interval bounds the pipeline throughput, so `fixed(n)` picks
//! the contiguous n-way partition minimizing the maximum group interval,
//! preferring cuts at statement boundaries (the paper's 2-compute split
//! is "the first three loop nests … and the last four" — a statement
//! boundary cut) and then earlier cuts.
//!
//! `auto(budget)` implements the paper's collapse heuristic: start from
//! singleton groups ("aggressively partitions the graph into the smallest
//! possible operators") and merge adjacent groups while the merged
//! interval stays within the budget, preferring chain collapses that
//! remove FIFOs.

use super::affine::Kernel;

/// One dataflow stage: a contiguous run of nest indices.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Group {
    pub name: String,
    /// Contiguous nest indices [start, end).
    pub start: usize,
    pub end: usize,
}

impl Group {
    pub fn nests(&self) -> std::ops::Range<usize> {
        self.start..self.end
    }

    pub fn len(&self) -> usize {
        self.end - self.start
    }

    pub fn is_empty(&self) -> bool {
        self.start >= self.end
    }
}

/// A dataflow schedule over a kernel.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Schedule {
    pub groups: Vec<Group>,
}

impl Schedule {
    /// Interval (pipelined iterations) of group `g` — the paper's
    /// "sum of trip counts of child loops" estimate.
    pub fn interval(&self, k: &Kernel, g: usize) -> u64 {
        self.groups[g]
            .nests()
            .map(|ni| k.nests[ni].iterations())
            .sum()
    }

    /// The bottleneck interval (max over groups).
    pub fn max_interval(&self, k: &Kernel) -> u64 {
        (0..self.groups.len())
            .map(|g| self.interval(k, g))
            .max()
            .unwrap_or(0)
    }

    pub fn num_groups(&self) -> usize {
        self.groups.len()
    }

    /// Invariants: groups contiguous, ordered, covering all nests.
    pub fn validate(&self, k: &Kernel) -> Result<(), String> {
        let mut pos = 0;
        for g in &self.groups {
            if g.start != pos {
                return Err(format!(
                    "group {} starts at {} expected {pos}",
                    g.name, g.start
                ));
            }
            if g.is_empty() {
                return Err(format!("group {} is empty", g.name));
            }
            pos = g.end;
        }
        if pos != k.nests.len() {
            return Err(format!(
                "schedule covers {pos} of {} nests",
                k.nests.len()
            ));
        }
        Ok(())
    }
}

/// Statement-boundary cut positions (cut before nest i is a boundary
/// when nests i-1 and i implement different statements).
fn stmt_boundaries(k: &Kernel) -> Vec<usize> {
    (1..k.nests.len())
        .filter(|&i| k.nests[i - 1].stmt != k.nests[i].stmt)
        .collect()
}

/// Partition into exactly `n` contiguous groups minimizing
/// (max interval, non-statement-boundary cuts, earliest cuts).
pub fn fixed(k: &Kernel, n: usize) -> Result<Schedule, String> {
    let nn = k.nests.len();
    if n == 0 || n > nn {
        return Err(format!("cannot split {nn} nests into {n} groups"));
    }
    let bounds = stmt_boundaries(k);
    let lat: Vec<u64> = k.nests.iter().map(|x| x.iterations()).collect();

    // enumerate cut sets: choose n-1 cut positions from 1..nn
    let mut best: Option<(u64, usize, Vec<usize>)> = None;
    let mut cuts = vec![0usize; n - 1];
    enumerate_cuts(1, nn, &mut cuts, 0, &mut |cs: &[usize]| {
        let mut maxi = 0u64;
        let mut prev = 0usize;
        for &c in cs.iter().chain(std::iter::once(&nn)) {
            let s: u64 = lat[prev..c].iter().sum();
            maxi = maxi.max(s);
            prev = c;
        }
        let off_boundary = cs.iter().filter(|c| !bounds.contains(c)).count();
        let cand = (maxi, off_boundary, cs.to_vec());
        let better = match &best {
            None => true,
            Some((bm, bo, bc)) => {
                (cand.0, cand.1, &cand.2) < (*bm, *bo, bc)
            }
        };
        if better {
            best = Some(cand);
        }
    });
    let (_, _, cuts) = best.expect("at least one partition exists");
    Ok(build_schedule(k, &cuts))
}

fn enumerate_cuts(
    lo: usize,
    nn: usize,
    cuts: &mut [usize],
    depth: usize,
    f: &mut impl FnMut(&[usize]),
) {
    if depth == cuts.len() {
        f(cuts);
        return;
    }
    let remaining = cuts.len() - depth - 1;
    for c in lo..(nn - remaining) {
        cuts[depth] = c;
        enumerate_cuts(c + 1, nn, cuts, depth + 1, f);
    }
}

/// The paper's collapse heuristic: singleton groups merged under an
/// interval budget. Default budget = the longest single-nest interval
/// ("the group with the longest interval determines the lower bound …
/// our heuristic uses that interval as a budget").
pub fn auto(k: &Kernel, budget: Option<u64>) -> Schedule {
    let lat: Vec<u64> = k.nests.iter().map(|x| x.iterations()).collect();
    let budget = budget.unwrap_or_else(|| lat.iter().copied().max().unwrap_or(0));
    let mut groups: Vec<(usize, usize, u64)> = lat
        .iter()
        .enumerate()
        .map(|(i, &l)| (i, i + 1, l))
        .collect();
    loop {
        // find the adjacent pair with the smallest merged interval
        let mut pick: Option<(usize, u64)> = None;
        for i in 0..groups.len().saturating_sub(1) {
            let merged = groups[i].2 + groups[i + 1].2;
            if merged <= budget && pick.map(|(_, m)| merged < m).unwrap_or(true) {
                pick = Some((i, merged));
            }
        }
        match pick {
            Some((i, merged)) => {
                groups[i] = (groups[i].0, groups[i + 1].1, merged);
                groups.remove(i + 1);
            }
            None => break,
        }
    }
    let cuts: Vec<usize> = groups.iter().skip(1).map(|g| g.0).collect();
    build_schedule(k, &cuts)
}

fn build_schedule(k: &Kernel, cuts: &[usize]) -> Schedule {
    let nn = k.nests.len();
    let mut groups = Vec::new();
    let mut prev = 0usize;
    for (gi, &c) in cuts.iter().chain(std::iter::once(&nn)).enumerate() {
        groups.push(Group {
            name: group_name(k, prev, c, gi),
            start: prev,
            end: c,
        });
        prev = c;
    }
    Schedule { groups }
}

/// Name groups after the paper's Fig. 11 vocabulary where recognizable.
fn group_name(k: &Kernel, start: usize, end: usize, gi: usize) -> String {
    use super::affine::NestKind;
    let kinds: Vec<&NestKind> = k.nests[start..end].iter().map(|n| &n.kind).collect();
    let all_contraction = kinds
        .iter()
        .all(|x| matches!(x, NestKind::Contraction { .. }));
    let all_elementwise = kinds
        .iter()
        .all(|x| matches!(x, NestKind::Elementwise(_)));
    if all_contraction {
        let transposed = k.nests[start..end].iter().all(|n| {
            matches!(n.kind, NestKind::Contraction { transpose: true, .. })
        });
        if transposed {
            format!("gemm_inv_{gi}")
        } else {
            format!("gemm_{gi}")
        }
    } else if all_elementwise {
        format!("mmult_{gi}")
    } else {
        format!("stage_{gi}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dsl;
    use crate::ir::{lower, rewrite, teil};

    fn helmholtz_kernel(p: usize) -> Kernel {
        let prog = dsl::parse(&dsl::inverse_helmholtz_source(p)).unwrap();
        let m = rewrite::optimize(teil::from_ast(&prog).unwrap());
        lower::lower_kernel(&m, "helmholtz").unwrap()
    }

    #[test]
    fn fixed_1_is_whole_kernel() {
        let k = helmholtz_kernel(11);
        let s = fixed(&k, 1).unwrap();
        s.validate(&k).unwrap();
        assert_eq!(s.num_groups(), 1);
        assert_eq!(s.groups[0].len(), 7);
    }

    #[test]
    fn fixed_2_matches_paper_three_four_split() {
        // Paper §4.2: "first module with the first three loop nests …
        // second module with the last four".
        let k = helmholtz_kernel(11);
        let s = fixed(&k, 2).unwrap();
        s.validate(&k).unwrap();
        assert_eq!(s.groups[0].len(), 3);
        assert_eq!(s.groups[1].len(), 4);
    }

    #[test]
    fn fixed_3_matches_paper_gemm_mmult_gemminv() {
        // Paper: "the most natural division … first three loop nests
        // implement gemm, the fourth mmult, the last three gemm_inv".
        let k = helmholtz_kernel(11);
        let s = fixed(&k, 3).unwrap();
        s.validate(&k).unwrap();
        let lens: Vec<usize> = s.groups.iter().map(|g| g.len()).collect();
        assert_eq!(lens, vec![3, 1, 3]);
        assert!(s.groups[0].name.starts_with("gemm"));
        assert!(s.groups[1].name.starts_with("mmult"));
        assert!(s.groups[2].name.starts_with("gemm_inv"));
    }

    #[test]
    fn fixed_7_is_one_nest_per_group() {
        let k = helmholtz_kernel(11);
        let s = fixed(&k, 7).unwrap();
        s.validate(&k).unwrap();
        assert!(s.groups.iter().all(|g| g.len() == 1));
        // every group interval is p^3 (paper: compute stages just below
        // the read module's interval)
        for g in 0..7 {
            assert_eq!(s.interval(&k, g), 1331);
        }
    }

    #[test]
    fn fixed_rejects_bad_counts() {
        let k = helmholtz_kernel(7);
        assert!(fixed(&k, 0).is_err());
        assert!(fixed(&k, 8).is_err());
    }

    #[test]
    fn max_interval_decreases_with_more_groups() {
        let k = helmholtz_kernel(11);
        let m1 = fixed(&k, 1).unwrap().max_interval(&k);
        let m2 = fixed(&k, 2).unwrap().max_interval(&k);
        let m7 = fixed(&k, 7).unwrap().max_interval(&k);
        assert!(m1 > m2);
        assert!(m2 > m7);
        assert_eq!(m1, 7 * 1331);
        assert_eq!(m7, 1331);
    }

    #[test]
    fn auto_with_default_budget_keeps_singletons() {
        // budget = max nest interval = p^3; no merge fits within it
        let k = helmholtz_kernel(11);
        let s = auto(&k, None);
        s.validate(&k).unwrap();
        assert_eq!(s.num_groups(), 7);
    }

    #[test]
    fn auto_with_generous_budget_collapses_all() {
        let k = helmholtz_kernel(11);
        let s = auto(&k, Some(u64::MAX));
        s.validate(&k).unwrap();
        assert_eq!(s.num_groups(), 1);
    }

    #[test]
    fn auto_with_mid_budget_is_between() {
        let k = helmholtz_kernel(11);
        let s = auto(&k, Some(3 * 1331));
        s.validate(&k).unwrap();
        assert!(s.num_groups() > 1 && s.num_groups() < 7);
        assert!(s.max_interval(&k) <= 3 * 1331);
    }

    #[test]
    fn validate_catches_gaps() {
        let k = helmholtz_kernel(7);
        let mut s = fixed(&k, 2).unwrap();
        s.groups[1].start += 1;
        assert!(s.validate(&k).is_err());
    }
}
