//! Shape inference and verification for teil ops (paper §3.3.2: TeIL
//! values carry static shapes; every op's result shape is derivable).
//!
//! `infer` computes the result shape of one op against the module's
//! existing values. `teil::Module::push` runs it on every op insertion,
//! so malformed programs fail at IR-construction time, not at lowering —
//! and since the rewriter (`ir::rewrite`) rebuilds modules through the
//! same path, factorized contraction chains are shape-checked too.

use super::teil::{Module, Op};

/// Infer the result shape of `op` given the module's existing values.
pub fn infer(m: &Module, op: &Op) -> Result<Vec<usize>, String> {
    match op {
        // Arg shapes are patched in by the builder right after push.
        Op::Arg { .. } => Ok(vec![]),
        Op::Prod { a, b } => {
            let mut s = m.shape(*a).to_vec();
            s.extend_from_slice(m.shape(*b));
            Ok(s)
        }
        Op::Diag { x, i, j } => {
            let s = m.shape(*x);
            if *i >= *j {
                return Err(format!("diag expects i < j, got ({i}, {j})"));
            }
            if *j >= s.len() {
                return Err(format!("diag axis {j} out of range for {s:?}"));
            }
            if s[*i] != s[*j] {
                return Err(format!(
                    "diag axes must have equal extent: {} vs {}",
                    s[*i], s[*j]
                ));
            }
            let mut out = s.to_vec();
            out.remove(*j);
            Ok(out)
        }
        Op::Red { x, axis } => {
            let s = m.shape(*x);
            if *axis >= s.len() {
                return Err(format!("red axis {axis} out of range for {s:?}"));
            }
            let mut out = s.to_vec();
            out.remove(*axis);
            Ok(out)
        }
        Op::Add { a, b } | Op::Sub { a, b } | Op::Mul { a, b } | Op::Div { a, b } => {
            if m.shape(*a) != m.shape(*b) {
                return Err(format!(
                    "elementwise shape mismatch: {:?} vs {:?}",
                    m.shape(*a),
                    m.shape(*b)
                ));
            }
            Ok(m.shape(*a).to_vec())
        }
        Op::ModeApply {
            m: mat,
            x,
            mode,
            transpose,
        } => {
            let ms = m.shape(*mat);
            if ms.len() != 2 {
                return Err(format!("mode_apply matrix must be rank 2, got {ms:?}"));
            }
            let (rows, cols) = if *transpose {
                (ms[1], ms[0])
            } else {
                (ms[0], ms[1])
            };
            let xs = m.shape(*x);
            if *mode >= xs.len() {
                return Err(format!("mode {mode} out of range for {xs:?}"));
            }
            if xs[*mode] != cols {
                return Err(format!(
                    "mode_apply contract dim mismatch: matrix cols {cols} vs tensor axis {}",
                    xs[*mode]
                ));
            }
            let mut out = xs.to_vec();
            out[*mode] = rows;
            Ok(out)
        }
        Op::MoveAxis { x, from, to } => {
            let s = m.shape(*x);
            if *from >= s.len() || *to >= s.len() {
                return Err(format!(
                    "move_axis ({from} -> {to}) out of range for {s:?}"
                ));
            }
            let mut out = s.to_vec();
            let ax = out.remove(*from);
            out.insert(*to, ax);
            Ok(out)
        }
        Op::Gather { x, idx } => {
            let xs = m.shape(*x);
            let is = m.shape(*idx);
            if xs.is_empty() {
                return Err("gather base must have a row axis".into());
            }
            if is.len() != 1 {
                return Err(format!("gather index must be rank 1, got {is:?}"));
            }
            let mut out = vec![is[0]];
            out.extend_from_slice(&xs[1..]);
            Ok(out)
        }
        Op::Scatter { x, idx, rows, .. } => {
            let xs = m.shape(*x);
            let is = m.shape(*idx);
            if xs.is_empty() {
                return Err("scatter data must have a row axis".into());
            }
            if is.len() != 1 {
                return Err(format!("scatter index must be rank 1, got {is:?}"));
            }
            if is[0] != xs[0] {
                return Err(format!(
                    "scatter index length {} != data rows {}",
                    is[0], xs[0]
                ));
            }
            if *rows == 0 {
                return Err("scatter target must have at least one row".into());
            }
            let mut out = vec![*rows];
            out.extend_from_slice(&xs[1..]);
            Ok(out)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::teil::{Module, Op};

    fn module_with_args() -> (Module, usize, usize) {
        let mut m = Module::default();
        let s = m.push(Op::Arg { name: "S".into() }).unwrap();
        m.values[s].shape = vec![4, 4];
        let u = m.push(Op::Arg { name: "u".into() }).unwrap();
        m.values[u].shape = vec![4, 4, 4];
        (m, s, u)
    }

    #[test]
    fn prod_concats_shapes() {
        let (mut m, s, u) = module_with_args();
        let p = m.push(Op::Prod { a: s, b: u }).unwrap();
        assert_eq!(m.shape(p), &[4, 4, 4, 4, 4]);
    }

    #[test]
    fn diag_drops_second_axis() {
        let (mut m, s, u) = module_with_args();
        let p = m.push(Op::Prod { a: s, b: u }).unwrap();
        let d = m.push(Op::Diag { x: p, i: 1, j: 2 }).unwrap();
        assert_eq!(m.shape(d), &[4, 4, 4, 4]);
    }

    #[test]
    fn diag_requires_i_lt_j_and_equal_extents() {
        let (mut m, s, _) = module_with_args();
        assert!(m.push(Op::Diag { x: s, i: 1, j: 1 }).is_err());
        assert!(m.push(Op::Diag { x: s, i: 0, j: 5 }).is_err());
        let a = m.push(Op::Arg { name: "A".into() }).unwrap();
        m.values[a].shape = vec![2, 3];
        assert!(m.push(Op::Diag { x: a, i: 0, j: 1 }).is_err());
    }

    #[test]
    fn red_removes_axis() {
        let (mut m, _, u) = module_with_args();
        let r = m.push(Op::Red { x: u, axis: 1 }).unwrap();
        assert_eq!(m.shape(r), &[4, 4]);
        assert!(m.push(Op::Red { x: u, axis: 9 }).is_err());
    }

    #[test]
    fn elementwise_requires_matching_shapes() {
        let (mut m, s, u) = module_with_args();
        assert!(m.push(Op::Mul { a: s, b: u }).is_err());
        let ok = m.push(Op::Mul { a: u, b: u }).unwrap();
        assert_eq!(m.shape(ok), &[4, 4, 4]);
    }

    #[test]
    fn mode_apply_shapes() {
        let (mut m, s, u) = module_with_args();
        let a = m
            .push(Op::ModeApply {
                m: s,
                x: u,
                mode: 2,
                transpose: false,
            })
            .unwrap();
        assert_eq!(m.shape(a), &[4, 4, 4]);
        // non-square matrix changes the mode extent
        let w = m.push(Op::Arg { name: "W".into() }).unwrap();
        m.values[w].shape = vec![6, 4];
        let b = m
            .push(Op::ModeApply {
                m: w,
                x: u,
                mode: 0,
                transpose: false,
            })
            .unwrap();
        assert_eq!(m.shape(b), &[6, 4, 4]);
        // transposed: contracts rows instead
        assert!(m
            .push(Op::ModeApply {
                m: w,
                x: u,
                mode: 0,
                transpose: true,
            })
            .is_err()); // W^T has cols 6 != 4
    }
}
