//! Lowering teil → affine (paper §3.4.4).
//!
//! Requires a *rewritten* module: every contraction must already be
//! GEMM-shaped (`ModeApply`). Naive `prod`/`diag`/`red` remnants are a
//! compiler limitation surfaced as an error (paper §3.3.4 discusses the
//! analogous TeIL-mappability boundary) — materializing an outer product
//! on-chip is never a sensible hardware implementation.

use std::collections::HashMap;

use super::affine::{BufId, BufKind, Buffer, EwOp, Kernel, LoopNest, NestKind};
use super::teil::{Module, Op, ValId};

/// Lower a rewritten teil module to an affine kernel.
pub fn lower_kernel(m: &Module, name: &str) -> Result<Kernel, String> {
    let mut k = Kernel {
        name: name.to_string(),
        buffers: Vec::new(),
        nests: Vec::new(),
    };
    // value -> buffer holding it
    let mut buf_of: HashMap<ValId, BufId> = HashMap::new();

    // name lookup for defs (a def may alias an earlier value)
    let def_of: HashMap<ValId, (&str, bool)> = m
        .defs
        .iter()
        .map(|d| (d.value, (d.name.as_str(), d.is_output)))
        .collect();

    // statement index of each def value (for schedule boundaries)
    let stmt_of_def: HashMap<ValId, usize> =
        m.defs.iter().enumerate().map(|(i, d)| (d.value, i)).collect();

    let mut tmp_count = 0usize;
    for (v, val) in m.values.iter().enumerate() {
        match &val.op {
            Op::Arg { name } => {
                let id = push_buf(&mut k, name, &val.shape, BufKind::Input);
                buf_of.insert(v, id);
            }
            Op::Prod { .. } | Op::Diag { .. } | Op::Red { .. } => {
                return Err(format!(
                    "value %{v} is an unfactorized contraction op ({:?}); \
                     run rewrite::optimize before lowering",
                    val.op
                ));
            }
            _ => {
                // destination buffer: program name if this value is a def,
                // else a fresh temp.
                let (bname, kind) = match def_of.get(&v) {
                    Some((n, true)) => (n.to_string(), BufKind::Output),
                    Some((n, false)) => (n.to_string(), BufKind::Temp),
                    None => {
                        tmp_count += 1;
                        (format!("tmp{tmp_count}"), BufKind::Temp)
                    }
                };
                let out = push_buf(&mut k, &bname, &val.shape, kind);
                buf_of.insert(v, out);
                let stmt = stmt_for(m, v, &stmt_of_def);
                let nest = build_nest(m, v, val, &buf_of, out, stmt)?;
                k.nests.push(nest);
            }
        }
    }
    k.validate()?;
    Ok(k)
}

fn push_buf(k: &mut Kernel, name: &str, shape: &[usize], kind: BufKind) -> BufId {
    k.buffers.push(Buffer {
        name: name.to_string(),
        shape: shape.to_vec(),
        kind,
    });
    k.buffers.len() - 1
}

/// Find the statement that (transitively) consumes value v: the first def
/// whose value is reachable from v's users. Conservatively: the def with
/// the smallest index >= any def containing v in its subtree.
fn stmt_for(m: &Module, v: ValId, stmt_of_def: &HashMap<ValId, usize>) -> usize {
    if let Some(&s) = stmt_of_def.get(&v) {
        return s;
    }
    // walk defs in order; the first def whose subtree contains v owns it
    for (i, d) in m.defs.iter().enumerate() {
        if subtree_contains(m, d.value, v) {
            return i;
        }
    }
    m.defs.len().saturating_sub(1)
}

fn subtree_contains(m: &Module, root: ValId, needle: ValId) -> bool {
    if root == needle {
        return true;
    }
    match &m.values[root].op {
        Op::Arg { .. } => false,
        Op::Prod { a, b }
        | Op::Add { a, b }
        | Op::Sub { a, b }
        | Op::Mul { a, b }
        | Op::Div { a, b } => {
            subtree_contains(m, *a, needle) || subtree_contains(m, *b, needle)
        }
        Op::Diag { x, .. } | Op::Red { x, .. } | Op::MoveAxis { x, .. } => {
            subtree_contains(m, *x, needle)
        }
        Op::ModeApply { m: mm, x, .. } => {
            subtree_contains(m, *mm, needle) || subtree_contains(m, *x, needle)
        }
        Op::Gather { x, idx } | Op::Scatter { x, idx, .. } => {
            subtree_contains(m, *x, needle) || subtree_contains(m, *idx, needle)
        }
    }
}

fn build_nest(
    m: &Module,
    v: ValId,
    val: &super::teil::Value,
    buf_of: &HashMap<ValId, BufId>,
    out: BufId,
    stmt: usize,
) -> Result<LoopNest, String> {
    let get = |x: &ValId| -> Result<BufId, String> {
        buf_of
            .get(x)
            .copied()
            .ok_or_else(|| format!("value %{x} has no buffer (topological order violated)"))
    };
    match &val.op {
        Op::ModeApply {
            m: mat,
            x,
            mode,
            transpose,
        } => {
            let mb = get(mat)?;
            let xb = get(x)?;
            let red = m.shape(*x)[*mode];
            Ok(LoopNest {
                name: format!(
                    "mode{}{}_{}",
                    mode,
                    if *transpose { "t" } else { "" },
                    v
                ),
                out_trips: val.shape.clone(),
                red_trip: red,
                reads: vec![mb, xb],
                write: out,
                kind: NestKind::Contraction {
                    matrix: mb,
                    transpose: *transpose,
                    mode: *mode,
                },
                stmt,
            })
        }
        Op::Add { a, b } | Op::Sub { a, b } | Op::Mul { a, b } | Op::Div { a, b } => {
            let ew = match &val.op {
                Op::Add { .. } => EwOp::Add,
                Op::Sub { .. } => EwOp::Sub,
                Op::Mul { .. } => EwOp::Mul,
                _ => EwOp::Div,
            };
            Ok(LoopNest {
                name: format!("ew{ew:?}_{v}").to_lowercase(),
                out_trips: val.shape.clone(),
                red_trip: 1,
                reads: vec![get(a)?, get(b)?],
                write: out,
                kind: NestKind::Elementwise(ew),
                stmt,
            })
        }
        Op::MoveAxis { x, from, to } => Ok(LoopNest {
            name: format!("permute_{v}"),
            out_trips: val.shape.clone(),
            red_trip: 1,
            reads: vec![get(x)?],
            write: out,
            kind: NestKind::Permute {
                from: *from,
                to: *to,
            },
            stmt,
        }),
        // operand order for both indirect forms is [data, index] —
        // `ir::interp` and `codegen::c_emit` rely on it
        Op::Gather { x, idx } => {
            let xb = get(x)?;
            let ib = get(idx)?;
            Ok(LoopNest {
                name: format!("gather_{v}"),
                out_trips: val.shape.clone(),
                red_trip: 1,
                reads: vec![xb, ib],
                write: out,
                kind: NestKind::Gather { index: ib },
                stmt,
            })
        }
        Op::Scatter { x, idx, add, .. } => {
            let xb = get(x)?;
            let ib = get(idx)?;
            Ok(LoopNest {
                name: format!("scatter_{v}"),
                // iterates over the *data* shape; the written buffer may
                // be larger (validate exempts scatter from the dense
                // word-count identity)
                out_trips: m.shape(*x).to_vec(),
                red_trip: 1,
                reads: vec![xb, ib],
                write: out,
                kind: NestKind::Scatter {
                    index: ib,
                    add: *add,
                },
                stmt,
            })
        }
        other => Err(format!("cannot lower {other:?}")),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dsl;
    use crate::ir::{rewrite, teil};

    fn helmholtz_kernel(p: usize) -> Kernel {
        let prog = dsl::parse(&dsl::inverse_helmholtz_source(p)).unwrap();
        let m = rewrite::optimize(teil::from_ast(&prog).unwrap());
        lower_kernel(&m, "helmholtz").unwrap()
    }

    #[test]
    fn helmholtz_lowered_has_seven_nests() {
        // Paper §3.6.4: "composed of seven loops executed in sequence".
        let k = helmholtz_kernel(11);
        assert_eq!(k.nests.len(), 7);
        let contractions = k
            .nests
            .iter()
            .filter(|n| matches!(n.kind, NestKind::Contraction { .. }))
            .count();
        assert_eq!(contractions, 6);
    }

    #[test]
    fn helmholtz_flops_match_paper_eq2() {
        assert_eq!(helmholtz_kernel(11).flops_per_element(), 177_023);
        assert_eq!(helmholtz_kernel(7).flops_per_element(), 29_155);
    }

    #[test]
    fn helmholtz_io_words() {
        // inputs: S (p^2) + D (p^3) + u (p^3); output: v (p^3)
        let k = helmholtz_kernel(11);
        assert_eq!(k.input_words(), 121 + 1331 + 1331);
        assert_eq!(k.output_words(), 1331);
    }

    #[test]
    fn helmholtz_nests_follow_statements() {
        let k = helmholtz_kernel(11);
        let stmts: Vec<usize> = k.nests.iter().map(|n| n.stmt).collect();
        assert_eq!(stmts, vec![0, 0, 0, 1, 2, 2, 2]);
    }

    #[test]
    fn gradient_lowers_with_permutes() {
        let prog = dsl::parse(&dsl::gradient_source(8, 7, 6)).unwrap();
        let m = rewrite::optimize(teil::from_ast(&prog).unwrap());
        let k = lower_kernel(&m, "gradient").unwrap();
        let permutes = k
            .nests
            .iter()
            .filter(|n| matches!(n.kind, NestKind::Permute { .. }))
            .count();
        assert_eq!(permutes, 2);
        assert_eq!(k.outputs().count(), 3);
        k.validate().unwrap();
    }

    #[test]
    fn interpolation_lowers() {
        let prog = dsl::parse(&dsl::interpolation_source(11, 11)).unwrap();
        let m = rewrite::optimize(teil::from_ast(&prog).unwrap());
        let k = lower_kernel(&m, "interp").unwrap();
        assert_eq!(k.nests.len(), 3);
        assert_eq!(k.flops_per_element(), 2 * 11 * (3 * 11u64.pow(3)));
    }

    #[test]
    fn unfactorized_module_is_rejected() {
        let prog = dsl::parse(&dsl::inverse_helmholtz_source(3)).unwrap();
        let naive = teil::from_ast(&prog).unwrap();
        let err = lower_kernel(&naive, "x").unwrap_err();
        assert!(err.contains("unfactorized"), "{err}");
    }

    #[test]
    fn temp_buffers_are_shared_candidates() {
        let k = helmholtz_kernel(7);
        // t and r are program temps; mode-product intermediates add more
        assert!(k.temps().count() >= 2);
        assert!(k.temps().any(|(_, b)| b.name == "t"));
        assert!(k.temps().any(|(_, b)| b.name == "r"));
    }
}
