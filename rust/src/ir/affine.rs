//! Affine loop-nest IR (paper §3.4.4, Fig. 12a).
//!
//! A `Kernel` is the hardware-facing form of one DSL program applied to
//! one element: a list of `Buffer`s (BRAM/URAM candidates) and a sequence
//! of `LoopNest`s. Each contraction nest has its innermost reduction loop
//! fully unrolled (the paper's 11-parallel-multiplier MAC) and the
//! remaining loops pipelined.

use std::fmt;

pub type BufId = usize;

/// Buffer role in the kernel interface (paper Fig. 13).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BufKind {
    /// Streamed in from HBM; buffered on-chip for random access.
    Input,
    /// Streamed out to HBM.
    Output,
    /// Internal; candidate for Mnemosyne bank sharing.
    Temp,
}

#[derive(Debug, Clone, PartialEq)]
pub struct Buffer {
    pub name: String,
    pub shape: Vec<usize>,
    pub kind: BufKind,
}

impl Buffer {
    pub fn words(&self) -> usize {
        self.shape.iter().product()
    }
}

/// Elementwise operation of an `Elementwise` nest.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EwOp {
    Add,
    Sub,
    Mul,
    Div,
}

/// What a nest computes; drives operator counting in the HLS estimator.
#[derive(Debug, Clone, PartialEq)]
pub enum NestKind {
    /// GEMM-shaped n-mode product: the paper's `gemm` / `gemm_inv` nests.
    Contraction {
        matrix: BufId,
        transpose: bool,
        mode: usize,
    },
    /// Hadamard-style elementwise nest: the paper's `mmult`.
    Elementwise(EwOp),
    /// Pure data movement with axis permutation (zero flops).
    Permute { from: usize, to: usize },
    /// Indirect read: `w[i, j..] = data[idx[i], j..]` where `idx` is the
    /// index buffer. The data operand is the first entry of `reads`,
    /// the index buffer the second. Unstructured-mesh gather (Karp et
    /// al., arXiv 2108.12188); the data access is pseudo-random.
    Gather { index: BufId },
    /// Indirect write: `w[idx[i], j..] (+)= data[i, j..]`. With
    /// `add: true` the write accumulates (scatter-add assembly),
    /// otherwise it overwrites. `out_trips` covers the *data* shape —
    /// the written buffer may be larger (rows not hit keep zero) or
    /// hit more than once (duplicates accumulate in ascending data
    /// order).
    Scatter { index: BufId, add: bool },
}

impl NestKind {
    /// Buffers this nest addresses non-sequentially. The on-chip plan
    /// must provision true dual-port random access for these; streaming
    /// FIFOs are enough for the rest. Shared by `sim`,
    /// `mnemosyne::plan`, and the irregular-access subsystem so the
    /// three can never disagree on what counts as random access.
    pub fn is_random_access(&self) -> bool {
        match self {
            NestKind::Contraction { .. } | NestKind::Permute { .. } => true,
            NestKind::Gather { .. } | NestKind::Scatter { .. } => true,
            NestKind::Elementwise(_) => false,
        }
    }

    /// The index buffer when this nest reads or writes through one.
    pub fn index_buffer(&self) -> Option<BufId> {
        match *self {
            NestKind::Gather { index } | NestKind::Scatter { index, .. } => Some(index),
            _ => None,
        }
    }
}

#[derive(Debug, Clone, PartialEq)]
pub struct LoopNest {
    pub name: String,
    /// Trip counts of the pipelined output loops.
    pub out_trips: Vec<usize>,
    /// Trip count of the fully-unrolled innermost reduction (1 if none).
    pub red_trip: usize,
    /// Buffers read (includes the contraction matrix).
    pub reads: Vec<BufId>,
    pub write: BufId,
    pub kind: NestKind,
    /// Which program statement this nest implements (0-based).
    pub stmt: usize,
}

impl LoopNest {
    /// Pipelined iterations = product of output trip counts. With II=1
    /// this is the nest's cycle interval — the paper estimates group
    /// intervals "by the sum of trip counts of their child loops".
    pub fn iterations(&self) -> u64 {
        self.out_trips.iter().product::<usize>() as u64
    }

    /// Floating-point operations executed per element.
    pub fn flops(&self) -> u64 {
        match self.kind {
            // mul + add per reduction step per output element
            NestKind::Contraction { .. } => 2 * self.iterations() * self.red_trip as u64,
            NestKind::Elementwise(_) => self.iterations(),
            NestKind::Permute { .. } | NestKind::Gather { .. } => 0,
            // one accumulate per scattered word; a plain overwrite moves
            // data without arithmetic
            NestKind::Scatter { add, .. } => {
                if add {
                    self.iterations()
                } else {
                    0
                }
            }
        }
    }

    /// Multipliers required to sustain II=1 with the reduction unrolled.
    pub fn multipliers(&self) -> u32 {
        match self.kind {
            NestKind::Contraction { .. } => self.red_trip as u32,
            NestKind::Elementwise(EwOp::Mul) | NestKind::Elementwise(EwOp::Div) => 1,
            _ => 0,
        }
    }

    /// Adders required (the paper's sequential adder chain).
    pub fn adders(&self) -> u32 {
        match self.kind {
            NestKind::Contraction { .. } => self.red_trip as u32,
            NestKind::Elementwise(EwOp::Add) | NestKind::Elementwise(EwOp::Sub) => 1,
            NestKind::Scatter { add: true, .. } => 1,
            _ => 0,
        }
    }
}

/// A lowered kernel: one element's computation.
#[derive(Debug, Clone, PartialEq)]
pub struct Kernel {
    pub name: String,
    pub buffers: Vec<Buffer>,
    pub nests: Vec<LoopNest>,
}

impl Kernel {
    pub fn inputs(&self) -> impl Iterator<Item = (BufId, &Buffer)> {
        self.buffers
            .iter()
            .enumerate()
            .filter(|(_, b)| b.kind == BufKind::Input)
    }

    pub fn outputs(&self) -> impl Iterator<Item = (BufId, &Buffer)> {
        self.buffers
            .iter()
            .enumerate()
            .filter(|(_, b)| b.kind == BufKind::Output)
    }

    pub fn temps(&self) -> impl Iterator<Item = (BufId, &Buffer)> {
        self.buffers
            .iter()
            .enumerate()
            .filter(|(_, b)| b.kind == BufKind::Temp)
    }

    /// Words streamed in from HBM per element.
    pub fn input_words(&self) -> usize {
        self.inputs().map(|(_, b)| b.words()).sum()
    }

    /// Words streamed out to HBM per element.
    pub fn output_words(&self) -> usize {
        self.outputs().map(|(_, b)| b.words()).sum()
    }

    /// Total flops per element (paper Eq. 2 for the Helmholtz kernel).
    pub fn flops_per_element(&self) -> u64 {
        self.nests.iter().map(|n| n.flops()).sum()
    }

    /// Structural invariants; lowering and all transforms must preserve.
    pub fn validate(&self) -> Result<(), String> {
        use std::collections::HashSet;
        let nb = self.buffers.len();
        let mut written = HashSet::new();
        for (i, n) in self.nests.iter().enumerate() {
            if n.write >= nb {
                return Err(format!("nest {i} writes out-of-range buffer"));
            }
            if self.buffers[n.write].kind == BufKind::Input {
                return Err(format!("nest {i} writes input buffer"));
            }
            if !written.insert(n.write) {
                return Err(format!(
                    "buffer {} written by multiple nests",
                    self.buffers[n.write].name
                ));
            }
            for &r in &n.reads {
                if r >= nb {
                    return Err(format!("nest {i} reads out-of-range buffer"));
                }
                if self.buffers[r].kind != BufKind::Input && !written.contains(&r) {
                    return Err(format!(
                        "nest {i} reads {} before it is written",
                        self.buffers[r].name
                    ));
                }
            }
            if n.out_trips.is_empty() || n.red_trip == 0 {
                return Err(format!("nest {i} has degenerate trip counts"));
            }
            if let Some(idx) = n.kind.index_buffer() {
                if idx >= nb {
                    return Err(format!("nest {i} indexes out-of-range buffer"));
                }
                if !n.reads.contains(&idx) {
                    return Err(format!(
                        "nest {i} does not read its index buffer {}",
                        self.buffers[idx].name
                    ));
                }
            }
            // a scatter iterates over its *data* shape: the written
            // buffer may be larger (untouched rows) or hit repeatedly
            // (duplicate indices), so the dense word-count identity
            // does not apply
            let expect = self.buffers[n.write].words() as u64;
            let scatter = matches!(n.kind, NestKind::Scatter { .. });
            if !scatter && n.iterations() != expect {
                return Err(format!(
                    "nest {i} iterations {} != output words {expect}",
                    n.iterations()
                ));
            }
        }
        for (id, b) in self.outputs() {
            if !written.contains(&id) {
                return Err(format!("output {} never written", b.name));
            }
        }
        Ok(())
    }
}

impl fmt::Display for Kernel {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "kernel @{} {{", self.name)?;
        for (i, b) in self.buffers.iter().enumerate() {
            writeln!(
                f,
                "  buf %{i} {:?} {:9} {:?} ({} words)",
                b.kind,
                b.name,
                b.shape,
                b.words()
            )?;
        }
        for (i, n) in self.nests.iter().enumerate() {
            writeln!(
                f,
                "  nest {i} {:20} trips {:?} x{} -> %{} [{} flops]",
                n.name,
                n.out_trips,
                n.red_trip,
                n.write,
                n.flops()
            )?;
        }
        write!(f, "}}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_kernel() -> Kernel {
        Kernel {
            name: "k".into(),
            buffers: vec![
                Buffer {
                    name: "a".into(),
                    shape: vec![4, 4],
                    kind: BufKind::Input,
                },
                Buffer {
                    name: "x".into(),
                    shape: vec![4, 4, 4],
                    kind: BufKind::Input,
                },
                Buffer {
                    name: "y".into(),
                    shape: vec![4, 4, 4],
                    kind: BufKind::Output,
                },
            ],
            nests: vec![LoopNest {
                name: "mode0".into(),
                out_trips: vec![4, 4, 4],
                red_trip: 4,
                reads: vec![0, 1],
                write: 2,
                kind: NestKind::Contraction {
                    matrix: 0,
                    transpose: false,
                    mode: 0,
                },
                stmt: 0,
            }],
        }
    }

    #[test]
    fn valid_kernel_passes() {
        tiny_kernel().validate().unwrap();
    }

    #[test]
    fn nest_flops_counts_two_per_mac() {
        let k = tiny_kernel();
        assert_eq!(k.nests[0].flops(), 2 * 64 * 4);
        assert_eq!(k.flops_per_element(), 512);
    }

    #[test]
    fn multipliers_match_unroll() {
        let k = tiny_kernel();
        assert_eq!(k.nests[0].multipliers(), 4);
        assert_eq!(k.nests[0].adders(), 4);
    }

    #[test]
    fn io_word_counts() {
        let k = tiny_kernel();
        assert_eq!(k.input_words(), 16 + 64);
        assert_eq!(k.output_words(), 64);
    }

    #[test]
    fn validate_rejects_write_to_input() {
        let mut k = tiny_kernel();
        k.nests[0].write = 0;
        assert!(k.validate().unwrap_err().contains("input"));
    }

    #[test]
    fn validate_rejects_double_write() {
        let mut k = tiny_kernel();
        let mut n = k.nests[0].clone();
        n.name = "again".into();
        k.nests.push(n);
        assert!(k.validate().unwrap_err().contains("multiple"));
    }

    #[test]
    fn validate_rejects_read_before_write() {
        let mut k = tiny_kernel();
        k.buffers.push(Buffer {
            name: "t".into(),
            shape: vec![4, 4, 4],
            kind: BufKind::Temp,
        });
        k.nests[0].reads.push(3);
        assert!(k.validate().unwrap_err().contains("before it is written"));
    }

    #[test]
    fn validate_rejects_iteration_mismatch() {
        let mut k = tiny_kernel();
        k.nests[0].out_trips = vec![4, 4];
        assert!(k.validate().unwrap_err().contains("iterations"));
    }
}
