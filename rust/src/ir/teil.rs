//! The `teil` tensor IR: immutable tensor values, no array semantics
//! (paper §3.3.2, Fig. 7b).
//!
//! Operations follow TeIL's primitives: `prod` (outer product), `diag`
//! (axis pairing), `red` (add-reduction), plus elementwise arithmetic.
//! After rewriting (§3.4.1), factorized contractions appear as
//! `ModeApply` values — the GEMM-shaped n-mode products the hardware
//! flow schedules onto dataflow stages.

use std::collections::HashMap;
use std::fmt;

use crate::dsl::{Expr, Program, VarKind};
use crate::util::tensor::Tensor;

/// Index of a value in the module's value list.
pub type ValId = usize;

/// A teil operation producing one tensor value.
#[derive(Debug, Clone, PartialEq)]
pub enum Op {
    /// Kernel argument (program input variable).
    Arg { name: String },
    /// Outer (tensor) product.
    Prod { a: ValId, b: ValId },
    /// Diagonal of axes (i, j), i < j: result drops axis j.
    Diag { x: ValId, i: usize, j: usize },
    /// Add-reduction over `axis`.
    Red { x: ValId, axis: usize },
    /// Elementwise ops.
    Add { a: ValId, b: ValId },
    Sub { a: ValId, b: ValId },
    Mul { a: ValId, b: ValId },
    Div { a: ValId, b: ValId },
    /// n-mode product: contract matrix `m`'s 2nd index (or 1st when
    /// `transpose`) against axis `mode` of `x`. Introduced by rewriting.
    ModeApply {
        m: ValId,
        x: ValId,
        mode: usize,
        transpose: bool,
    },
    /// Move axis `from` to position `to` (introduced by rewriting to
    /// restore contraction axis order; zero flops — address remapping).
    MoveAxis { x: ValId, from: usize, to: usize },
    /// Indirect row read through a rank-1 index tensor:
    /// `out[i, ..] = x[idx[i], ..]` (unstructured-mesh gather).
    Gather { x: ValId, idx: ValId },
    /// Indirect row write into a fresh `rows`-row zero tensor:
    /// `out[idx[i], ..] (+)= x[i, ..]`, rows applied in ascending data
    /// order so duplicate indices are deterministic (scatter-add
    /// assembly when `add`; last-writer-wins otherwise).
    Scatter {
        x: ValId,
        idx: ValId,
        rows: usize,
        add: bool,
    },
}

/// A value: its defining op and inferred shape.
#[derive(Debug, Clone, PartialEq)]
pub struct Value {
    pub op: Op,
    pub shape: Vec<usize>,
}

/// A named result the program assigns (program temp or output).
#[derive(Debug, Clone, PartialEq)]
pub struct Def {
    pub name: String,
    pub value: ValId,
    pub is_output: bool,
}

/// A teil module: SSA-style value list plus named defs.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Module {
    pub values: Vec<Value>,
    pub defs: Vec<Def>,
    /// Input declarations (name, shape) in program order.
    pub inputs: Vec<(String, Vec<usize>)>,
}

impl Module {
    pub fn shape(&self, v: ValId) -> &[usize] {
        &self.values[v].shape
    }

    pub fn push(&mut self, op: Op) -> Result<ValId, String> {
        let shape = super::shape::infer(self, &op)?;
        self.values.push(Value { op, shape });
        Ok(self.values.len() - 1)
    }

    pub fn outputs(&self) -> impl Iterator<Item = &Def> {
        self.defs.iter().filter(|d| d.is_output)
    }

    pub fn def(&self, name: &str) -> Option<&Def> {
        self.defs.iter().find(|d| d.name == name)
    }

    /// Total scalar multiply+add count to evaluate the module as written
    /// (the naive cost the rewriter must beat; see `rewrite::optimize`).
    pub fn flops(&self) -> u64 {
        let mut used = vec![false; self.values.len()];
        for d in &self.defs {
            mark_used(self, d.value, &mut used);
        }
        self.values
            .iter()
            .enumerate()
            .filter(|(i, _)| used[*i])
            .map(|(_, v)| op_flops(self, v))
            .sum()
    }

    /// Input names used as index tensors by gather/scatter values, with
    /// the exclusive row bound their entries must stay below. Workload
    /// generators seed these with whole numbers in `[0, bound)` instead
    /// of unit-domain reals (duplicates and arbitrary order allowed —
    /// that is the point of the irregular-access kernels).
    pub fn index_input_bounds(&self) -> Vec<(String, usize)> {
        let mut out: Vec<(String, usize)> = Vec::new();
        for v in &self.values {
            let (idx, rows) = match v.op {
                Op::Gather { x, idx } => (idx, self.shape(x)[0]),
                Op::Scatter { idx, rows, .. } => (idx, rows),
                _ => continue,
            };
            if let Op::Arg { name } = &self.values[idx].op {
                match out.iter_mut().find(|(n, _)| n == name) {
                    // one map may index several arrays (gather/scatter
                    // pairs); its values must be valid for all of them
                    Some((_, b)) => *b = (*b).min(rows),
                    None => out.push((name.clone(), rows)),
                }
            }
        }
        out
    }
}

fn mark_used(m: &Module, v: ValId, used: &mut [bool]) {
    if used[v] {
        return;
    }
    used[v] = true;
    match &m.values[v].op {
        Op::Arg { .. } => {}
        Op::Prod { a, b }
        | Op::Add { a, b }
        | Op::Sub { a, b }
        | Op::Mul { a, b }
        | Op::Div { a, b } => {
            mark_used(m, *a, used);
            mark_used(m, *b, used);
        }
        Op::Diag { x, .. } | Op::Red { x, .. } | Op::MoveAxis { x, .. } => {
            mark_used(m, *x, used)
        }
        Op::ModeApply { m: mm, x, .. } => {
            mark_used(m, *mm, used);
            mark_used(m, *x, used);
        }
        Op::Gather { x, idx } | Op::Scatter { x, idx, .. } => {
            mark_used(m, *x, used);
            mark_used(m, *idx, used);
        }
    }
}

fn op_flops(m: &Module, v: &Value) -> u64 {
    let n: u64 = v.shape.iter().product::<usize>() as u64;
    match &v.op {
        Op::Arg { .. } | Op::Diag { .. } | Op::MoveAxis { .. } => 0,
        Op::Prod { .. } | Op::Mul { .. } | Op::Add { .. } | Op::Sub { .. } | Op::Div { .. } => n,
        // reduction: (extent-1) adds per output — count as extent for the
        // paper's 2-flops-per-MAC convention handled by ModeApply below.
        Op::Red { x, axis } => {
            let extent = m.shape(*x)[*axis] as u64;
            n * extent.saturating_sub(1)
        }
        // 2 flops (mul + add) per contraction step per output element.
        Op::ModeApply { m: mat, .. } => {
            let k = m.shape(*mat)[1] as u64;
            2 * n * k
        }
        // address remapping only; scatter-add pays one accumulate per
        // *data* word (the output may be larger and mostly untouched)
        Op::Gather { .. } | Op::Scatter { add: false, .. } => 0,
        Op::Scatter { x, add: true, .. } => {
            m.shape(*x).iter().product::<usize>() as u64
        }
    }
}

impl fmt::Display for Module {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        for (i, v) in self.values.iter().enumerate() {
            write!(f, "%{i} = ")?;
            match &v.op {
                Op::Arg { name } => write!(f, "teil.arg @{name}")?,
                Op::Prod { a, b } => write!(f, "teil.prod %{a}, %{b}")?,
                Op::Diag { x, i: a, j: b } => write!(f, "teil.diag {a} {b} %{x}")?,
                Op::Red { x, axis } => write!(f, "teil.red add {axis} %{x}")?,
                Op::Add { a, b } => write!(f, "teil.add %{a}, %{b}")?,
                Op::Sub { a, b } => write!(f, "teil.sub %{a}, %{b}")?,
                Op::Mul { a, b } => write!(f, "teil.mul %{a}, %{b}")?,
                Op::Div { a, b } => write!(f, "teil.div %{a}, %{b}")?,
                Op::ModeApply {
                    m,
                    x,
                    mode,
                    transpose,
                } => write!(
                    f,
                    "teil.mode_apply{} {mode} %{m}, %{x}",
                    if *transpose { "_t" } else { "" }
                )?,
                Op::MoveAxis { x, from, to } => {
                    write!(f, "teil.move_axis {from}->{to} %{x}")?
                }
                Op::Gather { x, idx } => write!(f, "teil.gather %{x}[%{idx}]")?,
                Op::Scatter { x, idx, rows, add } => write!(
                    f,
                    "teil.scatter{} {rows} %{x}[%{idx}]",
                    if *add { "_add" } else { "" }
                )?,
            }
            writeln!(f, " : tensor<{:?}>", v.shape)?;
        }
        for d in &self.defs {
            writeln!(
                f,
                "teil.define @{} = %{}{}",
                d.name,
                d.value,
                if d.is_output { " (output)" } else { "" }
            )?;
        }
        Ok(())
    }
}

/// Translate a validated CFDlang program into teil (paper Fig. 7a→7b's
/// first step: `cfdlang` ops become `prod`/`diag`/`red` chains).
pub fn from_ast(prog: &Program) -> Result<Module, String> {
    let mut m = Module::default();
    let mut env: HashMap<String, ValId> = HashMap::new();

    for d in &prog.decls {
        if d.kind == VarKind::Input {
            let id = m.push(Op::Arg {
                name: d.name.clone(),
            })?;
            m.values[id].shape = d.shape.clone();
            m.inputs.push((d.name.clone(), d.shape.clone()));
            env.insert(d.name.clone(), id);
        }
    }

    for stmt in &prog.stmts {
        let mut v = build_expr(&mut m, &stmt.expr, &env)?;
        let decl = prog.decl(&stmt.target).expect("validated");
        if let Some(ix) = &stmt.index {
            let idx = *env
                .get(ix)
                .ok_or_else(|| format!("unbound index variable {ix}"))?;
            if decl.shape.is_empty() {
                return Err(format!(
                    "scatter target {} must have a row axis",
                    stmt.target
                ));
            }
            v = m.push(Op::Scatter {
                x: v,
                idx,
                rows: decl.shape[0],
                add: stmt.accumulate,
            })?;
        }
        if m.shape(v) != decl.shape.as_slice() {
            return Err(format!(
                "shape mismatch assigning {}: declared {:?}, inferred {:?}",
                stmt.target,
                decl.shape,
                m.shape(v)
            ));
        }
        env.insert(stmt.target.clone(), v);
        m.defs.push(Def {
            name: stmt.target.clone(),
            value: v,
            is_output: decl.kind == VarKind::Output,
        });
    }
    Ok(m)
}

fn build_expr(
    m: &mut Module,
    e: &Expr,
    env: &HashMap<String, ValId>,
) -> Result<ValId, String> {
    match e {
        Expr::Var(n) => env
            .get(n)
            .copied()
            .ok_or_else(|| format!("unbound variable {n}")),
        Expr::Add(a, b) => {
            let (a, b) = (build_expr(m, a, env)?, build_expr(m, b, env)?);
            m.push(Op::Add { a, b })
        }
        Expr::Sub(a, b) => {
            let (a, b) = (build_expr(m, a, env)?, build_expr(m, b, env)?);
            m.push(Op::Sub { a, b })
        }
        Expr::Mul(a, b) => {
            let (a, b) = (build_expr(m, a, env)?, build_expr(m, b, env)?);
            m.push(Op::Mul { a, b })
        }
        Expr::Div(a, b) => {
            let (a, b) = (build_expr(m, a, env)?, build_expr(m, b, env)?);
            m.push(Op::Div { a, b })
        }
        Expr::Prod(a, b) => {
            let (a, b) = (build_expr(m, a, env)?, build_expr(m, b, env)?);
            m.push(Op::Prod { a, b })
        }
        Expr::Contract(inner, pairs) => {
            let x = build_expr(m, inner, env)?;
            // Lower each pair to diag + red. Axis numbers shift as axes
            // disappear: process pairs sorted by first index, adjusting
            // later pairs for the two axes each diag+red removes.
            let mut remaining: Vec<(usize, usize)> = pairs
                .iter()
                .map(|p| (p.a.min(p.b), p.a.max(p.b)))
                .collect();
            remaining.sort();
            let mut cur = x;
            for k in 0..remaining.len() {
                let (i, j) = remaining[k];
                let d = m.push(Op::Diag { x: cur, i, j })?;
                let r = m.push(Op::Red { x: d, axis: i })?;
                cur = r;
                // diag removed axis j; red removed axis i (i < j).
                for (a, b) in remaining.iter_mut().skip(k + 1) {
                    for ax in [a, b] {
                        debug_assert!(*ax != i && *ax != j);
                        if *ax > j {
                            *ax -= 2;
                        } else if *ax > i {
                            *ax -= 1;
                        }
                    }
                }
            }
            Ok(cur)
        }
        Expr::Gather(base, ix) => {
            let x = build_expr(m, base, env)?;
            let idx = *env
                .get(ix)
                .ok_or_else(|| format!("unbound index variable {ix}"))?;
            m.push(Op::Gather { x, idx })
        }
    }
}

/// Evaluate a module on concrete inputs — the semantic oracle for
/// rewriting and the naive-CPU baseline datapath.
pub fn eval(
    m: &Module,
    inputs: &HashMap<String, Tensor>,
) -> Result<HashMap<String, Tensor>, String> {
    let mut vals: Vec<Option<Tensor>> = vec![None; m.values.len()];
    for (i, v) in m.values.iter().enumerate() {
        let t = match &v.op {
            Op::Arg { name } => inputs
                .get(name)
                .ok_or_else(|| format!("missing input {name}"))?
                .clone(),
            Op::Prod { a, b } => vals[*a].as_ref().unwrap().outer(vals[*b].as_ref().unwrap()),
            Op::Diag { x, i, j } => vals[*x].as_ref().unwrap().diag(*i, *j),
            Op::Red { x, axis } => vals[*x].as_ref().unwrap().reduce_add(*axis),
            Op::Add { a, b } => vals[*a]
                .as_ref()
                .unwrap()
                .zip(vals[*b].as_ref().unwrap(), |x, y| x + y),
            Op::Sub { a, b } => vals[*a]
                .as_ref()
                .unwrap()
                .zip(vals[*b].as_ref().unwrap(), |x, y| x - y),
            Op::Mul { a, b } => vals[*a]
                .as_ref()
                .unwrap()
                .zip(vals[*b].as_ref().unwrap(), |x, y| x * y),
            Op::Div { a, b } => vals[*a]
                .as_ref()
                .unwrap()
                .zip(vals[*b].as_ref().unwrap(), |x, y| x / y),
            Op::ModeApply {
                m: mat,
                x,
                mode,
                transpose,
            } => {
                let matt = vals[*mat].as_ref().unwrap();
                let matt = if *transpose {
                    matt.transposed()
                } else {
                    matt.clone()
                };
                vals[*x].as_ref().unwrap().mode_apply(&matt, *mode)
            }
            Op::MoveAxis { x, from, to } => {
                vals[*x].as_ref().unwrap().move_axis(*from, *to)
            }
            Op::Gather { x, idx } => vals[*x]
                .as_ref()
                .unwrap()
                .gather_rows(vals[*idx].as_ref().unwrap()),
            Op::Scatter { x, idx, rows, add } => vals[*x]
                .as_ref()
                .unwrap()
                .scatter_rows(vals[*idx].as_ref().unwrap(), *rows, *add),
        };
        if t.shape() != v.shape.as_slice() {
            return Err(format!(
                "eval shape mismatch at %{i}: expected {:?}, got {:?}",
                v.shape,
                t.shape()
            ));
        }
        vals[i] = Some(t);
    }
    let mut out = HashMap::new();
    for d in &m.defs {
        out.insert(d.name.clone(), vals[d.value].clone().unwrap());
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dsl;
    use crate::util::prng::Prng;

    fn helmholtz_inputs(p: usize, seed: u64) -> HashMap<String, Tensor> {
        let mut rng = Prng::new(seed);
        let mut m = HashMap::new();
        m.insert("S".into(), Tensor::random(&[p, p], &mut rng));
        m.insert("D".into(), Tensor::random(&[p, p, p], &mut rng));
        m.insert("u".into(), Tensor::random(&[p, p, p], &mut rng));
        m
    }

    /// Direct dense evaluation of Eq. 1a-1c, independent of the IR.
    fn helmholtz_direct(inp: &HashMap<String, Tensor>) -> Tensor {
        let s = &inp["S"];
        let d = &inp["D"];
        let u = &inp["u"];
        let t = u.mode_apply(s, 0).mode_apply(s, 1).mode_apply(s, 2);
        let r = d.zip(&t, |a, b| a * b);
        let st = s.transposed();
        r.mode_apply(&st, 0).mode_apply(&st, 1).mode_apply(&st, 2)
    }

    #[test]
    fn from_ast_builds_helmholtz() {
        let prog = dsl::parse(&dsl::inverse_helmholtz_source(5)).unwrap();
        let m = from_ast(&prog).unwrap();
        assert_eq!(m.inputs.len(), 3);
        assert_eq!(m.defs.len(), 3);
        assert_eq!(m.outputs().count(), 1);
        assert_eq!(m.def("v").unwrap().is_output, true);
        assert_eq!(m.shape(m.def("t").unwrap().value), &[5, 5, 5]);
    }

    #[test]
    fn naive_eval_matches_direct_helmholtz() {
        // The unrewritten teil program (outer products + diag + red) must
        // compute exactly Eq. 1a-1c. p kept small: naive is O(p^9).
        let p = 3;
        let prog = dsl::parse(&dsl::inverse_helmholtz_source(p)).unwrap();
        let m = from_ast(&prog).unwrap();
        let inputs = helmholtz_inputs(p, 11);
        let out = eval(&m, &inputs).unwrap();
        let want = helmholtz_direct(&inputs);
        assert!(out["v"].max_abs_diff(&want) < 1e-12);
    }

    #[test]
    fn gradient_eval_matches_mode_products() {
        let prog = dsl::parse(&dsl::gradient_source(4, 3, 2)).unwrap();
        let m = from_ast(&prog).unwrap();
        let mut rng = Prng::new(3);
        let mut inp = HashMap::new();
        inp.insert("Dx".into(), Tensor::random(&[4, 4], &mut rng));
        inp.insert("Dy".into(), Tensor::random(&[3, 3], &mut rng));
        inp.insert("Dz".into(), Tensor::random(&[2, 2], &mut rng));
        inp.insert("u".into(), Tensor::random(&[4, 3, 2], &mut rng));
        let out = eval(&m, &inp).unwrap();
        // contraction axis order: derivative axis first for gy/gz
        assert!(
            out["gx"].max_abs_diff(&inp["u"].mode_apply(&inp["Dx"], 0)) < 1e-12
        );
        assert!(
            out["gy"].max_abs_diff(
                &inp["u"].mode_apply(&inp["Dy"], 1).move_axis(1, 0)
            ) < 1e-12
        );
        assert!(
            out["gz"].max_abs_diff(
                &inp["u"].mode_apply(&inp["Dz"], 2).move_axis(2, 0)
            ) < 1e-12
        );
    }

    #[test]
    fn shape_mismatch_is_rejected() {
        let src = "var input a : [2 2]\nvar output x : [3]\nx = a . [[0 1]]";
        let prog = dsl::parse(src).unwrap();
        let err = from_ast(&prog).unwrap_err();
        assert!(err.contains("shape mismatch"), "{err}");
    }

    #[test]
    fn flops_counts_naive_cost() {
        let p = 3;
        let prog = dsl::parse(&dsl::inverse_helmholtz_source(p)).unwrap();
        let m = from_ast(&prog).unwrap();
        // naive cost must dominate the outer-product materialization
        // p^2 * p^2 * p^2 * p^3 = p^9 per contraction
        assert!(m.flops() > (p as u64).pow(9));
    }

    #[test]
    fn mode_apply_flops_matches_paper_eq2() {
        // Build a module of 6 mode products + 1 hadamard by rewriting.
        let p = 11;
        let prog = dsl::parse(&dsl::inverse_helmholtz_source(p)).unwrap();
        let m = crate::ir::rewrite::optimize(from_ast(&prog).unwrap());
        // (12p + 1) p^3 = 177,023 (paper Eq. 2)
        assert_eq!(m.flops(), 177_023);
    }
}
