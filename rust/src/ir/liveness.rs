//! Buffer liveness analysis and compatibility graph (paper §3.4.4, §3.5).
//!
//! The CFDlang compiler computes buffer lifetimes over the (sequential)
//! nest schedule and exports the *compatibility graph* — pairs of
//! internal buffers whose lifetimes do not overlap — as metadata for
//! Mnemosyne's bank-sharing optimization (paper Fig. 13/14d).

use super::affine::{BufKind, Kernel};

/// Lifetime of a buffer in nest indices: written at `def`, last read at
/// `last_use` (def == last_use means produced and never read — dead).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Interval {
    pub def: usize,
    pub last_use: usize,
}

impl Interval {
    /// Two lifetimes are compatible (can share storage) iff disjoint.
    /// A buffer is live from the start of its defining nest through the
    /// end of its last reading nest, so sharing requires strict
    /// separation: one's last_use precedes the other's def.
    pub fn disjoint(&self, other: &Interval) -> bool {
        self.last_use < other.def || other.last_use < self.def
    }
}

/// Result of liveness analysis over one kernel.
#[derive(Debug, Clone)]
pub struct Liveness {
    /// Per-buffer lifetime; `None` for inputs/outputs (live throughout —
    /// they interface with the Read/Write dataflow modules).
    pub intervals: Vec<Option<Interval>>,
    /// Compatibility edges between temp buffers (i < j).
    pub compat: Vec<(usize, usize)>,
}

/// Compute temp-buffer lifetimes over the sequential nest order.
pub fn analyze(k: &Kernel) -> Liveness {
    let mut intervals: Vec<Option<Interval>> = vec![None; k.buffers.len()];
    for (ni, nest) in k.nests.iter().enumerate() {
        if k.buffers[nest.write].kind == BufKind::Temp {
            let e = intervals[nest.write].get_or_insert(Interval {
                def: ni,
                last_use: ni,
            });
            e.def = e.def.min(ni);
        }
        for &r in &nest.reads {
            if k.buffers[r].kind == BufKind::Temp {
                if let Some(e) = intervals[r].as_mut() {
                    e.last_use = e.last_use.max(ni);
                }
            }
        }
    }
    let mut compat = Vec::new();
    for i in 0..k.buffers.len() {
        for j in (i + 1)..k.buffers.len() {
            if let (Some(a), Some(b)) = (&intervals[i], &intervals[j]) {
                if a.disjoint(b) {
                    compat.push((i, j));
                }
            }
        }
    }
    Liveness { intervals, compat }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dsl;
    use crate::ir::{lower, rewrite, teil};
    use crate::util::prop;

    fn helmholtz_kernel(p: usize) -> Kernel {
        let prog = dsl::parse(&dsl::inverse_helmholtz_source(p)).unwrap();
        let m = rewrite::optimize(teil::from_ast(&prog).unwrap());
        lower::lower_kernel(&m, "helmholtz").unwrap()
    }

    #[test]
    fn inputs_and_outputs_have_no_interval() {
        let k = helmholtz_kernel(7);
        let lv = analyze(&k);
        for (i, b) in k.buffers.iter().enumerate() {
            match b.kind {
                BufKind::Temp => assert!(lv.intervals[i].is_some(), "{}", b.name),
                _ => assert!(lv.intervals[i].is_none(), "{}", b.name),
            }
        }
    }

    #[test]
    fn helmholtz_has_sharing_opportunities() {
        // Early mode-product intermediates die before the late ones are
        // born — the sharing Mnemosyne exploits in the paper (Fig. 14d).
        let k = helmholtz_kernel(11);
        let lv = analyze(&k);
        assert!(
            !lv.compat.is_empty(),
            "expected at least one compatible temp pair"
        );
    }

    #[test]
    fn compat_edges_really_are_disjoint() {
        let k = helmholtz_kernel(11);
        let lv = analyze(&k);
        for &(i, j) in &lv.compat {
            let (a, b) = (lv.intervals[i].unwrap(), lv.intervals[j].unwrap());
            assert!(a.disjoint(&b));
            assert!(i < j);
        }
    }

    #[test]
    fn t_is_live_until_hadamard() {
        let k = helmholtz_kernel(11);
        let lv = analyze(&k);
        let (tid, _) = k
            .buffers
            .iter()
            .enumerate()
            .find(|(_, b)| b.name == "t")
            .unwrap();
        let iv = lv.intervals[tid].unwrap();
        assert_eq!(iv.def, 2, "t written by third mode product");
        assert_eq!(iv.last_use, 3, "t read by the hadamard nest");
    }

    use crate::ir::affine::{Buffer, EwOp, LoopNest, NestKind};

    fn buf(name: &str, kind: BufKind) -> Buffer {
        Buffer {
            name: name.into(),
            shape: vec![4, 4],
            kind,
        }
    }

    fn ew_nest(name: &str, reads: Vec<usize>, write: usize, stmt: usize) -> LoopNest {
        LoopNest {
            name: name.into(),
            out_trips: vec![4, 4],
            red_trip: 1,
            reads,
            write,
            kind: NestKind::Elementwise(EwOp::Add),
            stmt,
        }
    }

    #[test]
    fn single_statement_kernel_has_no_temp_intervals() {
        // one nest, input -> output: nothing for Mnemosyne to color
        let k = Kernel {
            name: "copyish".into(),
            buffers: vec![buf("a", BufKind::Input), buf("y", BufKind::Output)],
            nests: vec![ew_nest("only", vec![0], 1, 0)],
        };
        k.validate().unwrap();
        let lv = analyze(&k);
        assert!(lv.intervals.iter().all(|iv| iv.is_none()));
        assert!(lv.compat.is_empty());
        // and the sharing pass degenerates gracefully to zero banks
        let plan = crate::mnemosyne::share(&k, &lv, None);
        plan.validate(&k, &lv).unwrap();
        assert_eq!(plan.shared_words(), 0);
    }

    #[test]
    fn write_only_temp_is_dead_on_arrival() {
        // t is produced and never consumed: its lifetime is the single
        // defining nest, and it still needs (its own) storage
        let k = Kernel {
            name: "deadtemp".into(),
            buffers: vec![
                buf("a", BufKind::Input),
                buf("t", BufKind::Temp),
                buf("y", BufKind::Output),
            ],
            nests: vec![
                ew_nest("mk_t", vec![0], 1, 0),
                ew_nest("mk_y", vec![0], 2, 1),
            ],
        };
        k.validate().unwrap();
        let lv = analyze(&k);
        let iv = lv.intervals[1].expect("written temp is analyzed");
        assert_eq!((iv.def, iv.last_use), (0, 0), "dead on arrival");
        let plan = crate::mnemosyne::share(&k, &lv, None);
        plan.validate(&k, &lv).unwrap();
        assert_eq!(plan.banks.len(), 1);
    }

    #[test]
    fn two_dead_temps_at_different_nests_share_one_bank() {
        let k = Kernel {
            name: "twodead".into(),
            buffers: vec![
                buf("a", BufKind::Input),
                buf("t0", BufKind::Temp),
                buf("t1", BufKind::Temp),
                buf("y", BufKind::Output),
            ],
            nests: vec![
                ew_nest("mk_t0", vec![0], 1, 0),
                ew_nest("mk_t1", vec![0], 2, 1),
                ew_nest("mk_y", vec![0], 3, 2),
            ],
        };
        k.validate().unwrap();
        let lv = analyze(&k);
        let plan = crate::mnemosyne::share(&k, &lv, None);
        plan.validate(&k, &lv).unwrap();
        // [0,0] and [1,1] are disjoint: the left-edge pass merges them
        assert_eq!(plan.banks.len(), 1);
        assert_eq!(plan.shared_words(), 16);
    }

    #[test]
    fn unused_temp_is_unanalyzed_and_needs_no_bank() {
        // a temp buffer that is never written (and never read) passes
        // kernel validation but has no lifetime; the sharing plan must
        // leave it unplaced rather than reject the kernel (regression:
        // SharingPlan::validate used to demand a bank for every temp)
        let k = Kernel {
            name: "unused".into(),
            buffers: vec![
                buf("a", BufKind::Input),
                buf("ghost", BufKind::Temp),
                buf("y", BufKind::Output),
            ],
            nests: vec![ew_nest("mk_y", vec![0], 2, 0)],
        };
        k.validate().unwrap();
        let lv = analyze(&k);
        assert!(lv.intervals[1].is_none(), "never written -> no lifetime");
        let plan = crate::mnemosyne::share(&k, &lv, None);
        plan.validate(&k, &lv).unwrap();
        assert!(plan.bank_of[1].is_none());
        assert!(plan.banks.is_empty());
    }

    #[test]
    fn interval_disjointness_is_symmetric_and_irreflexive() {
        prop::check("interval disjointness", 64, |rng| {
            let a = Interval {
                def: rng.range_usize(0, 10),
                last_use: rng.range_usize(0, 10),
            };
            let a = Interval {
                def: a.def.min(a.last_use),
                last_use: a.def.max(a.last_use),
            };
            let b = Interval {
                def: rng.range_usize(0, 10),
                last_use: rng.range_usize(0, 10),
            };
            let b = Interval {
                def: b.def.min(b.last_use),
                last_use: b.def.max(b.last_use),
            };
            prop::assert_prop(
                a.disjoint(&b) == b.disjoint(&a) && !a.disjoint(&a),
                format!("{a:?} {b:?}"),
            )
        });
    }
}
