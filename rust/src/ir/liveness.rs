//! Buffer liveness analysis and compatibility graph (paper §3.4.4, §3.5).
//!
//! The CFDlang compiler computes buffer lifetimes over the (sequential)
//! nest schedule and exports the *compatibility graph* — pairs of
//! internal buffers whose lifetimes do not overlap — as metadata for
//! Mnemosyne's bank-sharing optimization (paper Fig. 13/14d).

use super::affine::{BufKind, Kernel};

/// Lifetime of a buffer in nest indices: written at `def`, last read at
/// `last_use` (def == last_use means produced and never read — dead).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Interval {
    pub def: usize,
    pub last_use: usize,
}

impl Interval {
    /// Two lifetimes are compatible (can share storage) iff disjoint.
    /// A buffer is live from the start of its defining nest through the
    /// end of its last reading nest, so sharing requires strict
    /// separation: one's last_use precedes the other's def.
    pub fn disjoint(&self, other: &Interval) -> bool {
        self.last_use < other.def || other.last_use < self.def
    }
}

/// Result of liveness analysis over one kernel.
#[derive(Debug, Clone)]
pub struct Liveness {
    /// Per-buffer lifetime; `None` for inputs/outputs (live throughout —
    /// they interface with the Read/Write dataflow modules).
    pub intervals: Vec<Option<Interval>>,
    /// Compatibility edges between temp buffers (i < j).
    pub compat: Vec<(usize, usize)>,
}

/// Compute temp-buffer lifetimes over the sequential nest order.
pub fn analyze(k: &Kernel) -> Liveness {
    let mut intervals: Vec<Option<Interval>> = vec![None; k.buffers.len()];
    for (ni, nest) in k.nests.iter().enumerate() {
        if k.buffers[nest.write].kind == BufKind::Temp {
            let e = intervals[nest.write].get_or_insert(Interval {
                def: ni,
                last_use: ni,
            });
            e.def = e.def.min(ni);
        }
        for &r in &nest.reads {
            if k.buffers[r].kind == BufKind::Temp {
                if let Some(e) = intervals[r].as_mut() {
                    e.last_use = e.last_use.max(ni);
                }
            }
        }
    }
    let mut compat = Vec::new();
    for i in 0..k.buffers.len() {
        for j in (i + 1)..k.buffers.len() {
            if let (Some(a), Some(b)) = (&intervals[i], &intervals[j]) {
                if a.disjoint(b) {
                    compat.push((i, j));
                }
            }
        }
    }
    Liveness { intervals, compat }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dsl;
    use crate::ir::{lower, rewrite, teil};
    use crate::util::prop;

    fn helmholtz_kernel(p: usize) -> Kernel {
        let prog = dsl::parse(&dsl::inverse_helmholtz_source(p)).unwrap();
        let m = rewrite::optimize(teil::from_ast(&prog).unwrap());
        lower::lower_kernel(&m, "helmholtz").unwrap()
    }

    #[test]
    fn inputs_and_outputs_have_no_interval() {
        let k = helmholtz_kernel(7);
        let lv = analyze(&k);
        for (i, b) in k.buffers.iter().enumerate() {
            match b.kind {
                BufKind::Temp => assert!(lv.intervals[i].is_some(), "{}", b.name),
                _ => assert!(lv.intervals[i].is_none(), "{}", b.name),
            }
        }
    }

    #[test]
    fn helmholtz_has_sharing_opportunities() {
        // Early mode-product intermediates die before the late ones are
        // born — the sharing Mnemosyne exploits in the paper (Fig. 14d).
        let k = helmholtz_kernel(11);
        let lv = analyze(&k);
        assert!(
            !lv.compat.is_empty(),
            "expected at least one compatible temp pair"
        );
    }

    #[test]
    fn compat_edges_really_are_disjoint() {
        let k = helmholtz_kernel(11);
        let lv = analyze(&k);
        for &(i, j) in &lv.compat {
            let (a, b) = (lv.intervals[i].unwrap(), lv.intervals[j].unwrap());
            assert!(a.disjoint(&b));
            assert!(i < j);
        }
    }

    #[test]
    fn t_is_live_until_hadamard() {
        let k = helmholtz_kernel(11);
        let lv = analyze(&k);
        let (tid, _) = k
            .buffers
            .iter()
            .enumerate()
            .find(|(_, b)| b.name == "t")
            .unwrap();
        let iv = lv.intervals[tid].unwrap();
        assert_eq!(iv.def, 2, "t written by third mode product");
        assert_eq!(iv.last_use, 3, "t read by the hadamard nest");
    }

    #[test]
    fn interval_disjointness_is_symmetric_and_irreflexive() {
        prop::check("interval disjointness", 64, |rng| {
            let a = Interval {
                def: rng.range_usize(0, 10),
                last_use: rng.range_usize(0, 10),
            };
            let a = Interval {
                def: a.def.min(a.last_use),
                last_use: a.def.max(a.last_use),
            };
            let b = Interval {
                def: rng.range_usize(0, 10),
                last_use: rng.range_usize(0, 10),
            };
            let b = Interval {
                def: b.def.min(b.last_use),
                last_use: b.def.max(b.last_use),
            };
            prop::assert_prop(
                a.disjoint(&b) == b.disjoint(&a) && !a.disjoint(&a),
                format!("{a:?} {b:?}"),
            )
        });
    }
}
