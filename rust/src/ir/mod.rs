//! Compiler intermediate representations (paper §3.3–§3.4).
//!
//! The pipeline mirrors the paper's MLIR dialect stack:
//!
//! ```text
//! dsl::Program  ── teil::from_ast ──►  teil::Module   (value-based tensor IR)
//!                                        │ rewrite::optimize   (§3.4.1)
//!                                        ▼
//!                                     teil::Module   (factorized, GEMM-shaped)
//!                                        │ lower::lower_kernel (§3.4.4)
//!                                        ▼
//!                                     affine::Kernel (loop nests + buffers)
//!                                        │ liveness / access / schedule (§3.4.3)
//!                                        ▼
//!                  codegen::c_emit / the Olympus generator
//!                  (both reached through the `flow` staged pipeline)
//! ```

pub mod access;
pub mod affine;
pub mod interp;
pub mod liveness;
pub mod lower;
pub mod rewrite;
pub mod schedule;
pub mod shape;
pub mod teil;
