//! Reference interpreter for lowered affine kernels.
//!
//! Executes a [`Kernel`]'s loop nests in order on dense f64 buffers —
//! the software twin of the generated hardware datapath. This is what
//! makes a *generic* numerics oracle possible: for any program the
//! front door accepts (`kernels::KernelSource`), the lowered kernel is
//! run here and cross-checked against `teil::eval` of the rewritten
//! module (see `coordinator::GenericWorkload`), with no hand-written
//! closed form per kernel. Both paths evaluate the same mode-product
//! chain in the same order, so agreement is exact in f64; any deviation
//! indicates a lowering bug (wrong mode, missing transpose, bad buffer
//! wiring), not roundoff.

use std::collections::HashMap;

use super::affine::{BufKind, EwOp, Kernel, LoopNest, NestKind};
use crate::util::tensor::Tensor;

/// Operand `slot` of a nest (operand order follows `lower::build_nest`:
/// contraction reads are `[matrix, tensor]`, elementwise `[lhs, rhs]`).
fn operand<'a>(
    bufs: &'a [Option<Tensor>],
    n: &LoopNest,
    slot: usize,
) -> Result<&'a Tensor, String> {
    let id = *n
        .reads
        .get(slot)
        .ok_or_else(|| format!("nest {}: missing read operand {slot}", n.name))?;
    bufs[id]
        .as_ref()
        .ok_or_else(|| format!("nest {}: reads unwritten buffer", n.name))
}

/// Run the kernel on named input tensors; returns its output buffers by
/// name. Inputs must match the kernel's declared buffer shapes.
pub fn interpret(
    k: &Kernel,
    inputs: &HashMap<String, Tensor>,
) -> Result<HashMap<String, Tensor>, String> {
    let mut bufs: Vec<Option<Tensor>> = vec![None; k.buffers.len()];
    for (id, b) in k.buffers.iter().enumerate() {
        if b.kind == BufKind::Input {
            let t = inputs
                .get(&b.name)
                .ok_or_else(|| format!("missing input {}", b.name))?;
            if t.shape() != b.shape.as_slice() {
                return Err(format!(
                    "input {}: shape {:?} does not match declared {:?}",
                    b.name,
                    t.shape(),
                    b.shape
                ));
            }
            bufs[id] = Some(t.clone());
        }
    }

    for n in &k.nests {
        let out = match &n.kind {
            NestKind::Contraction {
                transpose, mode, ..
            } => {
                let m = operand(&bufs, n, 0)?;
                let x = operand(&bufs, n, 1)?;
                let m = if *transpose { m.transposed() } else { m.clone() };
                x.mode_apply(&m, *mode)
            }
            NestKind::Elementwise(op) => {
                let a = operand(&bufs, n, 0)?;
                let b = operand(&bufs, n, 1)?;
                match op {
                    EwOp::Add => a.zip(b, |x, y| x + y),
                    EwOp::Sub => a.zip(b, |x, y| x - y),
                    EwOp::Mul => a.zip(b, |x, y| x * y),
                    EwOp::Div => a.zip(b, |x, y| x / y),
                }
            }
            NestKind::Permute { from, to } => {
                operand(&bufs, n, 0)?.move_axis(*from, *to)
            }
            NestKind::Gather { .. } => {
                let x = operand(&bufs, n, 0)?;
                let idx = operand(&bufs, n, 1)?;
                x.gather_rows(idx)
            }
            NestKind::Scatter { add, .. } => {
                let x = operand(&bufs, n, 0)?;
                let idx = operand(&bufs, n, 1)?;
                // same ascending-data-order accumulation as teil::eval,
                // so oracle agreement stays exact even with duplicates
                x.scatter_rows(idx, k.buffers[n.write].shape[0], *add)
            }
        };
        if out.shape() != k.buffers[n.write].shape.as_slice() {
            return Err(format!(
                "nest {}: produced shape {:?}, buffer {} declares {:?}",
                n.name,
                out.shape(),
                k.buffers[n.write].name,
                k.buffers[n.write].shape
            ));
        }
        bufs[n.write] = Some(out);
    }

    let mut out = HashMap::new();
    for (id, b) in k.outputs() {
        let t = bufs[id]
            .clone()
            .ok_or_else(|| format!("output {} never written", b.name))?;
        out.insert(b.name.clone(), t);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dsl;
    use crate::ir::{lower, rewrite, teil};
    use crate::util::prng::Prng;

    fn lowered(src: &str) -> (teil::Module, Kernel) {
        let prog = dsl::parse(src).unwrap();
        let m = rewrite::optimize(teil::from_ast(&prog).unwrap());
        let k = lower::lower_kernel(&m, "k").unwrap();
        (m, k)
    }

    #[test]
    fn helmholtz_kernel_matches_teil_eval_exactly() {
        let p = 5;
        let (m, k) = lowered(&dsl::inverse_helmholtz_source(p));
        let mut rng = Prng::new(7);
        let mut inputs = HashMap::new();
        inputs.insert("S".into(), Tensor::random(&[p, p], &mut rng));
        inputs.insert("D".into(), Tensor::random(&[p, p, p], &mut rng));
        inputs.insert("u".into(), Tensor::random(&[p, p, p], &mut rng));
        let want = teil::eval(&m, &inputs).unwrap();
        let got = interpret(&k, &inputs).unwrap();
        // identical op order in f64: exact agreement, not tolerance
        assert_eq!(want["v"].data(), got["v"].data());
    }

    #[test]
    fn gradient_kernel_matches_including_permutes() {
        let (m, k) = lowered(&dsl::gradient_source(4, 3, 2));
        let mut rng = Prng::new(9);
        let mut inputs = HashMap::new();
        inputs.insert("Dx".into(), Tensor::random(&[4, 4], &mut rng));
        inputs.insert("Dy".into(), Tensor::random(&[3, 3], &mut rng));
        inputs.insert("Dz".into(), Tensor::random(&[2, 2], &mut rng));
        inputs.insert("u".into(), Tensor::random(&[4, 3, 2], &mut rng));
        let want = teil::eval(&m, &inputs).unwrap();
        let got = interpret(&k, &inputs).unwrap();
        for name in ["gx", "gy", "gz"] {
            assert_eq!(want[name].data(), got[name].data(), "{name}");
            assert_eq!(want[name].shape(), got[name].shape(), "{name}");
        }
    }

    #[test]
    fn elementwise_kernel_evaluates() {
        let (m, k) = lowered(
            "var input a : [3]\nvar input b : [3]\nvar output c : [3]\nc = a + b * a",
        );
        let mut rng = Prng::new(1);
        let mut inputs = HashMap::new();
        inputs.insert("a".into(), Tensor::random(&[3], &mut rng));
        inputs.insert("b".into(), Tensor::random(&[3], &mut rng));
        let want = teil::eval(&m, &inputs).unwrap();
        let got = interpret(&k, &inputs).unwrap();
        assert_eq!(want["c"].data(), got["c"].data());
    }

    #[test]
    fn missing_and_misshapen_inputs_are_rejected() {
        let (_, k) = lowered(
            "var input a : [3]\nvar input b : [3]\nvar output c : [3]\nc = a + b",
        );
        let mut rng = Prng::new(2);
        let mut inputs = HashMap::new();
        inputs.insert("a".into(), Tensor::random(&[3], &mut rng));
        let err = interpret(&k, &inputs).unwrap_err();
        assert!(err.contains("missing input b"), "{err}");
        inputs.insert("b".into(), Tensor::random(&[4], &mut rng));
        let err = interpret(&k, &inputs).unwrap_err();
        assert!(err.contains("does not match"), "{err}");
    }
}
